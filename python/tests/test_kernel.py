"""Kernel vs. pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes, dtypes, block sizes, and operator choices; every
kernel must match ref.py to float tolerance under all of them.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile import kernels as K
from compile.kernels import ref

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("kernels")

# Interpret-mode pallas is slow; keep vectors modest but varied.
sizes = st.sampled_from([8, 64, 128, 256, 1024, 2048])
blocks = st.sampled_from([None, 8, 64, 256])
dtypes = st.sampled_from([np.float32])
seeds = st.integers(min_value=0, max_value=2**31 - 1)


def _vec(n, seed, dtype=np.float32, positive=False):
    rng = np.random.default_rng(seed)
    v = rng.standard_normal(n).astype(dtype)
    if positive:
        v = np.abs(v) + 0.1
    return v


def _blk_ok(n, block):
    return block is None or (n % block == 0 and block <= n)


# ---------------------------------------------------------------------------
# vmul_reduce — the headline
# ---------------------------------------------------------------------------

@given(n=sizes, block=blocks, seed=seeds)
def test_vmul_reduce_matches_ref(n, block, seed):
    hypothesis.assume(_blk_ok(n, block))
    a, b = _vec(n, seed), _vec(n, seed + 1)
    got = K.vmul_reduce(jnp.array(a), jnp.array(b), block=block)
    want = ref.vmul_reduce(jnp.array(a), jnp.array(b))
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5, atol=1e-4)


def test_vmul_reduce_paper_shape():
    """The paper's 16 KB workload: 4096 f32 per operand."""
    a, b = _vec(4096, 7), _vec(4096, 11)
    got = K.vmul_reduce(jnp.array(a), jnp.array(b))
    np.testing.assert_allclose(
        float(got), float(np.sum(a.astype(np.float64) * b)), rtol=1e-4
    )


def test_vmul_reduce_rejects_mismatched_shapes():
    with pytest.raises(ValueError):
        K.vmul_reduce(jnp.zeros(8), jnp.zeros(16))


def test_vmul_reduce_rejects_nondivisible_block():
    with pytest.raises(ValueError):
        K.vmul_reduce(jnp.zeros(10), jnp.zeros(10), block=4)


def test_vmul_reduce_zero_vectors():
    assert float(K.vmul_reduce(jnp.zeros(64), jnp.zeros(64))) == 0.0


# ---------------------------------------------------------------------------
# reduce_sum
# ---------------------------------------------------------------------------

@given(n=sizes, block=blocks, seed=seeds)
def test_reduce_sum_matches_ref(n, block, seed):
    hypothesis.assume(_blk_ok(n, block))
    x = _vec(n, seed)
    got = K.reduce_sum(jnp.array(x), block=block)
    np.testing.assert_allclose(float(got), float(np.sum(x)), rtol=1e-5, atol=1e-4)


def test_reduce_sum_single_block_equals_multi_block():
    x = _vec(1024, 3)
    one = K.reduce_sum(jnp.array(x), block=1024)
    many = K.reduce_sum(jnp.array(x), block=64)
    np.testing.assert_allclose(float(one), float(many), rtol=1e-6)


# ---------------------------------------------------------------------------
# map_unary / map_chain
# ---------------------------------------------------------------------------

@given(op=st.sampled_from(ref.UNARY_SMALL + ref.UNARY_LARGE), n=sizes, seed=seeds)
def test_map_unary_matches_ref(op, n, seed):
    x = _vec(n, seed, positive=op in ("sqrt", "log"))
    got = K.map_unary(op, jnp.array(x))
    want = ref.map_unary(op, jnp.array(x))
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-5, atol=1e-6)


@given(
    ops=st.lists(st.sampled_from(("neg", "abs", "square", "relu")), min_size=1, max_size=4),
    n=sizes,
    block=blocks,
    seed=seeds,
)
def test_map_chain_matches_ref(ops, n, block, seed):
    hypothesis.assume(_blk_ok(n, block))
    x = _vec(n, seed)
    got = K.map_chain(tuple(ops), jnp.array(x), block=block)
    want = ref.map_chain(ops, jnp.array(x))
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-5, atol=1e-6)


def test_map_chain_empty_rejected():
    with pytest.raises(ValueError):
        K.map_chain((), jnp.zeros(8))


def test_map_chain_fusion_equals_staged():
    """One fused chain kernel == separate map_unary launches (contiguity)."""
    x = _vec(512, 9, positive=True)
    fused = K.map_chain(("sqrt", "log", "neg"), jnp.array(x))
    staged = K.map_unary("neg", K.map_unary("log", K.map_unary("sqrt", jnp.array(x))))
    np.testing.assert_allclose(np.array(fused), np.array(staged), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# zip_binary
# ---------------------------------------------------------------------------

@given(op=st.sampled_from(ref.BINARY_OPS), n=sizes, seed=seeds)
def test_zip_binary_matches_ref(op, n, seed):
    a = _vec(n, seed)
    b = _vec(n, seed + 1, positive=op == "div")
    got = K.zip_binary(op, jnp.array(a), jnp.array(b))
    want = ref.zip_binary(op, jnp.array(a), jnp.array(b))
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# axpy (foreach)
# ---------------------------------------------------------------------------

@given(n=sizes, block=blocks, seed=seeds, alpha=st.floats(-8, 8, allow_nan=False))
def test_axpy_matches_ref(n, block, seed, alpha):
    hypothesis.assume(_blk_ok(n, block))
    x, y = _vec(n, seed), _vec(n, seed + 1)
    got = K.axpy(jnp.float32(alpha), jnp.array(x), jnp.array(y), block=block)
    want = ref.axpy(np.float32(alpha), x, y)
    np.testing.assert_allclose(np.array(got), want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# filter
# ---------------------------------------------------------------------------

@given(n=sizes, seed=seeds, t=st.floats(-2, 2, allow_nan=False))
def test_filter_mask_matches_ref(n, seed, t):
    x = _vec(n, seed)
    kept, count = K.filter_mask(jnp.array(x), jnp.float32(t))
    rkept, rcount = ref.filter_mask(jnp.array(x), jnp.float32(t))
    np.testing.assert_allclose(np.array(kept), np.array(rkept), rtol=1e-6)
    assert int(count) == int(rcount)


@given(n=sizes, block=blocks, seed=seeds, t=st.floats(-2, 2, allow_nan=False))
def test_filter_reduce_matches_ref(n, block, seed, t):
    hypothesis.assume(_blk_ok(n, block))
    x = _vec(n, seed)
    got = K.filter_reduce(jnp.array(x), jnp.float32(t), block=block)
    want = ref.filter_reduce(jnp.array(x), jnp.float32(t))
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5, atol=1e-4)


def test_filter_reduce_all_pass_equals_sum():
    x = np.abs(_vec(256, 5)) + 1.0
    got = K.filter_reduce(jnp.array(x), jnp.float32(0.0))
    np.testing.assert_allclose(float(got), float(np.sum(x)), rtol=1e-5)


def test_filter_reduce_none_pass_is_zero():
    x = -np.abs(_vec(256, 5)) - 1.0
    assert float(K.filter_reduce(jnp.array(x), jnp.float32(0.0))) == 0.0


# ---------------------------------------------------------------------------
# branch_map (speculative if-then-else)
# ---------------------------------------------------------------------------

@given(
    n=sizes,
    seed=seeds,
    t=st.floats(-1, 1, allow_nan=False),
    then_op=st.sampled_from(("neg", "square", "relu")),
    else_op=st.sampled_from(("abs", "neg", "square")),
)
def test_branch_map_matches_ref(n, seed, t, then_op, else_op):
    x = _vec(n, seed)
    got = K.branch_map(jnp.float32(t), jnp.array(x), then_op, else_op)
    want = ref.branch_map(jnp.float32(t), jnp.array(x), then_op, else_op)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-5, atol=1e-6)


def test_branch_map_degenerate_same_op():
    """then == else must equal a plain map regardless of the predicate."""
    x = _vec(128, 2)
    got = K.branch_map(jnp.float32(0.0), jnp.array(x), "square", "square")
    np.testing.assert_allclose(np.array(got), x * x, rtol=1e-6)
