"""L2 model/variant catalogue checks: shapes, naming, and AOT lowering."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_catalogue_nonempty_and_unique():
    names = [v.name for v in model.VARIANTS.values()]
    assert len(names) == len(set(names))
    assert len(names) >= 25


def test_headline_present():
    assert model.HEADLINE in model.VARIANTS
    v = model.VARIANTS[model.HEADLINE]
    assert v.pattern == "vmul_reduce"
    assert v.params["n"] == model.PAPER_N


def test_paper_workload_is_16kb():
    """16 KB per operand at f32 = 4096 elements — the Fig. 3 data size."""
    assert model.PAPER_N * 4 == 16 * 1024


@pytest.mark.parametrize("name", sorted(model.VARIANTS))
def test_variant_traces_with_declared_specs(name):
    """Every variant must trace (abstract-eval) at its declared input specs
    and produce exactly its declared outputs."""
    v = model.VARIANTS[name]
    out = jax.eval_shape(v.fn, *v.specs)
    assert isinstance(out, tuple) and len(out) == len(v.outputs)
    for got, (shape, dtype) in zip(out, v.outputs):
        assert tuple(got.shape) == tuple(shape)
        assert {"f32": jnp.float32, "i32": jnp.int32}[dtype] == got.dtype


def test_variant_names_parseable():
    for v in model.VARIANTS.values():
        assert v.name.split("_n")[-1].isdigit(), v.name


@pytest.mark.parametrize(
    "name",
    [model.HEADLINE, f"map_sqrt_n{model.PAPER_N}", f"axpy_n{model.PAPER_N}"],
)
def test_lowering_produces_hlo_text(name):
    v = model.VARIANTS[name]
    text = aot.lower_variant(v)
    assert "HloModule" in text
    assert "ROOT" in text


def test_headline_lowered_numerics_roundtrip():
    """Execute the jitted headline function and compare against numpy."""
    v = model.VARIANTS[model.HEADLINE]
    rng = np.random.default_rng(0)
    a = rng.standard_normal(model.PAPER_N).astype(np.float32)
    b = rng.standard_normal(model.PAPER_N).astype(np.float32)
    (out,) = jax.jit(v.fn)(jnp.array(a), jnp.array(b))
    np.testing.assert_allclose(
        float(out[0]), float(np.sum(a.astype(np.float64) * b)), rtol=1e-4
    )


def test_manifest_entry_schema():
    v = model.VARIANTS[model.HEADLINE]
    e = aot.manifest_entry(v, "x.hlo.txt", "HloModule fake")
    for key in ("name", "pattern", "params", "inputs", "outputs", "file", "sha256"):
        assert key in e
    assert e["inputs"][0]["shape"] == [model.PAPER_N]
    assert e["outputs"][0]["dtype"] == "f32"
    json.dumps(e)  # must be JSON-serializable


def test_pad_to_block():
    x = jnp.arange(10, dtype=jnp.float32)
    padded = model.pad_to_block(x, 8)
    assert padded.shape == (16,)
    assert float(padded.sum()) == float(x.sum())  # zero padding is sum-safe
    same = model.pad_to_block(x, 5)
    assert same.shape == (10,)
