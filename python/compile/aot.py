"""AOT lowering: every pattern variant → HLO *text* + a manifest.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``: jax
≥ 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs, under ``--outdir`` (default ``../artifacts``):

  <variant>.hlo.txt   one per entry in model.VARIANTS
  model.hlo.txt       alias of the headline variant (Makefile sentinel)
  manifest.json       machine-readable catalogue the Rust runtime loads

Run as ``python -m compile.aot`` from the ``python/`` directory. Runs once at
build time; Python is never on the request path.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import shutil

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(v: model.Variant) -> str:
    return to_hlo_text(jax.jit(v.fn).lower(*v.specs))


_DTYPE_SHORT = {"float32": "f32", "int32": "i32", "bfloat16": "bf16", "float64": "f64"}


def _short_dtype(name: str) -> str:
    return _DTYPE_SHORT.get(name, name)


def manifest_entry(v: model.Variant, filename: str, hlo_text: str) -> dict:
    return {
        "name": v.name,
        "pattern": v.pattern,
        "params": v.params,
        "inputs": [
            {"shape": list(s.shape), "dtype": _short_dtype(s.dtype.name)}
            for s in v.specs
        ],
        "outputs": [
            {"shape": list(shape), "dtype": dtype} for shape, dtype in v.outputs
        ],
        "file": filename,
        "sha256": hashlib.sha256(hlo_text.encode()).hexdigest(),
        "return_tuple": True,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument(
        "--out",
        default=None,
        help="also write the headline variant's HLO to this exact path",
    )
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated variant names to (re)build; default: all",
    )
    args = ap.parse_args()

    os.makedirs(args.outdir, exist_ok=True)
    names = set(args.only.split(",")) if args.only else None

    entries = []
    for name, v in model.VARIANTS.items():
        if names is not None and name not in names:
            continue
        filename = f"{name}.hlo.txt"
        path = os.path.join(args.outdir, filename)
        text = lower_variant(v)
        with open(path, "w") as f:
            f.write(text)
        entries.append(manifest_entry(v, filename, text))
        print(f"  {name:40s} {len(text):>9d} chars")

    manifest = {
        "schema": 1,
        "headline": model.HEADLINE,
        "paper_n": model.PAPER_N,
        "variants": entries,
    }
    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)

    # TSV twin of the manifest — the Rust runtime parses this one (it builds
    # offline without a JSON dependency). Keep the two in lockstep.
    def spec_list(specs):
        return ";".join(
            "x".join(str(d) for d in s["shape"]) + ":" + s["dtype"] for s in specs
        )

    with open(os.path.join(args.outdir, "manifest.tsv"), "w") as f:
        f.write("# jit-overlay artifact manifest v1\n")
        f.write(f"headline\t{model.HEADLINE}\n")
        f.write(f"paper_n\t{model.PAPER_N}\n")
        for e in entries:
            f.write(
                "variant\t{name}\t{pattern}\t{file}\t{ins}\t{outs}\t{sha}\n".format(
                    name=e["name"],
                    pattern=e["pattern"],
                    file=e["file"],
                    ins=spec_list(e["inputs"]),
                    outs=spec_list(e["outputs"]),
                    sha=e["sha256"],
                )
            )

    headline_src = os.path.join(args.outdir, f"{model.HEADLINE}.hlo.txt")
    alias = args.out or os.path.join(args.outdir, "model.hlo.txt")
    if os.path.exists(headline_src):
        shutil.copyfile(headline_src, alias)
    print(f"wrote {len(entries)} variants + manifest to {args.outdir}")


if __name__ == "__main__":
    main()
