"""L2: JAX compute-graph definitions for every AOT-compiled pattern variant.

Each entry in :data:`VARIANTS` is one accelerator "bitstream" the Rust
runtime can load: a jittable function plus its example input specs. The JIT
coordinator composes *which* variant to run and the overlay simulator prices
it; the HLO artifact supplies the values.

Scalar results are shaped ``(1,)`` — the controller reads them out of a
result register; rank-0 would complicate the Rust literal plumbing for no
benefit.

Variant naming is load-bearing: ``<pattern>[_<op...>]_n<N>`` — the Rust
``runtime::manifest`` module parses it back into a typed key.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import (
    axpy,
    branch_map,
    filter_reduce,
    map_chain,
    map_unary,
    reduce_sum,
    vmul_reduce,
    zip_binary,
)
from .kernels import ref

#: vector lengths we AOT (elements of f32). 4096 = the paper's 16 KB
#: experiment (16 KB per operand vector at 4 B/element). The sweep sizes
#: feed the PR-overhead-amortization bench (T-PR).
SIZES = (1024, 4096, 16384, 65536, 262144)

#: the paper's experiment size: 16 KB of f32.
PAPER_N = 4096

_f32 = lambda n: jax.ShapeDtypeStruct((n,), jnp.float32)  # noqa: E731


@dataclasses.dataclass(frozen=True)
class Variant:
    """One AOT compilation unit."""

    name: str
    pattern: str                      # pattern family (vmul_reduce, map, ...)
    fn: Callable                      # jittable; returns a tuple of arrays
    specs: tuple                      # example ShapeDtypeStructs
    params: dict                      # pattern parameters for the manifest
    outputs: tuple                    # (shape, dtype-name) pairs


def _v(name, pattern, fn, specs, params, outputs):
    return Variant(name, pattern, fn, tuple(specs), dict(params), tuple(outputs))


def _scalar_out(f):
    """Wrap a scalar-returning pattern to emit a (1,) array in a 1-tuple."""

    def wrapped(*args):
        return (f(*args).reshape((1,)),)

    return wrapped


def _vec_out(f):
    def wrapped(*args):
        return (f(*args),)

    return wrapped


def build_variants() -> list[Variant]:
    """The full AOT variant catalogue."""
    out: list[Variant] = []

    # --- headline pattern: VMUL & Reduce, fused (dynamic-overlay dataflow) --
    for n in SIZES:
        out.append(
            _v(
                f"vmul_reduce_n{n}",
                "vmul_reduce",
                _scalar_out(vmul_reduce),
                [_f32(n), _f32(n)],
                {"n": n},
                [((1,), "f32")],
            )
        )

    # Unfused reference dataflow (static-overlay scenario: product vector is
    # materialized and transits pass-through tiles before the reduce).
    out.append(
        _v(
            f"vmul_reduce_unfused_n{PAPER_N}",
            "vmul_reduce_unfused",
            _scalar_out(ref.vmul_reduce),
            [_f32(PAPER_N), _f32(PAPER_N)],
            {"n": PAPER_N},
            [((1,), "f32")],
        )
    )

    # --- bare reduce ------------------------------------------------------
    for n in (PAPER_N, 65536):
        out.append(
            _v(
                f"reduce_sum_n{n}",
                "reduce",
                _scalar_out(reduce_sum),
                [_f32(n)],
                {"n": n},
                [((1,), "f32")],
            )
        )

    # --- map: one unary operator tile ------------------------------------
    for op in ("sqrt", "sin", "cos", "log", "exp", "abs", "neg", "square", "relu"):
        out.append(
            _v(
                f"map_{op}_n{PAPER_N}",
                "map",
                _vec_out(lambda x, _op=op: map_unary(_op, x)),
                [_f32(PAPER_N)],
                {"op": op, "n": PAPER_N},
                [((PAPER_N,), "f32")],
            )
        )

    # --- map chains: contiguous unary pipelines --------------------------
    for ops in (("square", "neg"), ("abs", "sqrt", "log"), ("square", "exp", "recip")):
        tag = "_".join(ops)
        out.append(
            _v(
                f"chain_{tag}_n{PAPER_N}",
                "chain",
                _vec_out(lambda x, _ops=ops: map_chain(_ops, x)),
                [_f32(PAPER_N)],
                {"ops": list(ops), "n": PAPER_N},
                [((PAPER_N,), "f32")],
            )
        )

    # --- zip: one binary operator tile ------------------------------------
    for op in ("add", "sub", "mul", "div", "max", "min"):
        out.append(
            _v(
                f"zip_{op}_n{PAPER_N}",
                "zip",
                _vec_out(lambda a, b, _op=op: zip_binary(_op, a, b)),
                [_f32(PAPER_N), _f32(PAPER_N)],
                {"op": op, "n": PAPER_N},
                [((PAPER_N,), "f32")],
            )
        )

    # --- foreach (AXPY) ----------------------------------------------------
    out.append(
        _v(
            f"axpy_n{PAPER_N}",
            "foreach",
            _vec_out(axpy),
            [_f32(1), _f32(PAPER_N), _f32(PAPER_N)],
            {"n": PAPER_N},
            [((PAPER_N,), "f32")],
        )
    )

    # --- filter → reduce ----------------------------------------------------
    for n in (PAPER_N, 65536):
        out.append(
            _v(
                f"filter_reduce_n{n}",
                "filter_reduce",
                _scalar_out(lambda x, t: filter_reduce(x, t)),
                [_f32(n), _f32(1)],
                {"n": n},
                [((1,), "f32")],
            )
        )

    # --- speculative branch map --------------------------------------------
    for then_op, else_op in (("sqrt", "square"), ("log", "neg")):
        out.append(
            _v(
                f"branch_{then_op}_{else_op}_n{PAPER_N}",
                "branch",
                _vec_out(
                    lambda t, x, _t=then_op, _e=else_op: branch_map(t, x, _t, _e)
                ),
                [_f32(1), _f32(PAPER_N)],
                {"then": then_op, "else": else_op, "n": PAPER_N},
                [((PAPER_N,), "f32")],
            )
        )

    return out


VARIANTS: dict[str, Variant] = {v.name: v for v in build_variants()}

#: the artifact `make artifacts`' sentinel target points at (the headline).
HEADLINE = f"vmul_reduce_n{PAPER_N}"


def pad_to_block(x: jnp.ndarray, block: int) -> jnp.ndarray:
    """Zero-pad a rank-1 array up to a block multiple (sum-safe padding)."""
    n = x.shape[0]
    rem = n % block
    if rem == 0:
        return x
    return jnp.pad(x, (0, block - rem))
