"""Shared plumbing for the Pallas kernels.

Blocking discipline
-------------------
Every kernel streams its operands in fixed-size blocks, mirroring the
overlay's execution model: a tile's two data BRAMs hold one chunk of each
operand while the PR operator streams through it. The Pallas analogue is a
1-D grid over chunks with BlockSpec-managed HBM→VMEM movement.

The paper's tile BRAMs are 18/36 Kb; our default block of 1024 f32 lanes
(4 KiB per operand) keeps the per-tile working set inside a 36 Kb BRAM pair
exactly as the hardware would. Callers may widen blocks for throughput —
`pick_block` clamps to the vector length and enforces divisibility (model.py
pads to a block multiple before calling in).

All kernels run with ``interpret=True``: the CPU PJRT client cannot execute
Mosaic custom-calls, and correctness — not wallclock — is what the Python
layer certifies. TPU efficiency is *estimated* in DESIGN.md §Perf from the
VMEM footprint these BlockSpecs imply.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: default elements per block: 1024 f32 = 4 KiB/operand, one BRAM-sized chunk.
DEFAULT_BLOCK = 1024

#: pallas interpret mode is mandatory on this (CPU PJRT) substrate.
INTERPRET = True


def pick_block(n: int, block: int | None = None) -> int:
    """Choose a block size for a length-``n`` vector.

    ``n`` must be a positive multiple of the chosen block; model.py pads
    inputs so this always holds for AOT variants, and tests exercise the
    error path.
    """
    b = min(block or DEFAULT_BLOCK, n)
    if n <= 0:
        raise ValueError(f"vector length must be positive, got {n}")
    if n % b != 0:
        raise ValueError(f"length {n} is not a multiple of block {b}")
    return b


def stream_spec(block: int):
    """BlockSpec for a streamed 1-D operand: grid step i reads chunk i."""
    return pl.BlockSpec((block,), lambda i: (i,))


def scalar_spec():
    """BlockSpec for a (1,)-shaped broadcast scalar pinned to chunk 0."""
    return pl.BlockSpec((1,), lambda i: (0,))


def accum_spec():
    """BlockSpec for a (1,)-shaped accumulator written by every grid step."""
    return pl.BlockSpec((1,), lambda i: (0,))


@functools.cache
def unary_fn(op: str):
    """jnp implementation of a tile unary operator (shared with ref.py)."""
    from . import ref

    return ref._UNARY[op]


@functools.cache
def binary_fn(op: str):
    """jnp implementation of a tile binary operator (shared with ref.py)."""
    from . import ref

    return ref._BINARY[op]


def f32(x):
    """Cast to the accumulator dtype (DSP48-style wide accumulation)."""
    return x.astype(jnp.float32)


__all__ = [
    "DEFAULT_BLOCK",
    "INTERPRET",
    "pick_block",
    "stream_spec",
    "scalar_spec",
    "accum_spec",
    "unary_fn",
    "binary_fn",
    "f32",
    "jax",
    "jnp",
    "pl",
]
