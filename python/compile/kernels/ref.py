"""Pure-jnp reference oracle for every Pallas kernel in this package.

Each function here is the semantic ground truth the corresponding Pallas
kernel is tested against (pytest + hypothesis in python/tests/). They are
also used by model.py when a composition is lowered in "reference" mode for
A/B HLO artifacts.

The ops mirror the paper's pre-synthesized operator library: the parallel
patterns (map / reduce / foreach / filter) and the operator set the overlay's
large and small PR tiles host (mul, add, sub, div, sqrtf, sin, cos, log, ...).
"""

from __future__ import annotations

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Operator library (what a single PR tile computes on a streamed element)
# ---------------------------------------------------------------------------

#: unary operators that fit the paper's *large* PR regions (8 DSP / 964 FF /
#: 1228 LUT): transcendental / iterative datapaths.
UNARY_LARGE = ("sqrt", "sin", "cos", "log", "exp", "tanh")

#: unary operators that fit the *small* PR regions (4 DSP / 156 FF / 270 LUT).
UNARY_SMALL = ("neg", "abs", "recip", "square", "relu")

#: binary operators (all fit small regions except div).
BINARY_OPS = ("add", "sub", "mul", "div", "max", "min")

_UNARY = {
    "sqrt": jnp.sqrt,
    "sin": jnp.sin,
    "cos": jnp.cos,
    "log": jnp.log,
    "exp": jnp.exp,
    "tanh": jnp.tanh,
    "neg": lambda x: -x,
    "abs": jnp.abs,
    "recip": lambda x: 1.0 / x,
    "square": lambda x: x * x,
    "relu": lambda x: jnp.maximum(x, 0.0),
}

_BINARY = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "div": lambda a, b: a / b,
    "max": jnp.maximum,
    "min": jnp.minimum,
}


def unary(op: str, x):
    """Apply one unary operator from the tile library element-wise."""
    return _UNARY[op](x)


def binary(op: str, a, b):
    """Apply one binary operator from the tile library element-wise."""
    return _BINARY[op](a, b)


# ---------------------------------------------------------------------------
# Parallel patterns (what the JIT composes out of tiles)
# ---------------------------------------------------------------------------

def vmul_reduce(a, b):
    """The paper's headline pattern: ``sum = Σ A⃗ × B⃗`` (VMUL then Reduce).

    Accumulation is performed in float32 regardless of input dtype, matching
    the kernel (and a DSP48 accumulator, which is wider than its operands).
    """
    prod = a.astype(jnp.float32) * b.astype(jnp.float32)
    return jnp.sum(prod, dtype=jnp.float32)


def reduce_sum(x):
    """Reduce pattern alone: sum of a vector (float32 accumulation)."""
    return jnp.sum(x.astype(jnp.float32), dtype=jnp.float32)


def map_unary(op: str, x):
    """Map pattern: one unary operator over a vector."""
    return unary(op, x)


def map_chain(ops, x):
    """A pipeline of map stages — operators in contiguous tiles."""
    for op in ops:
        x = unary(op, x)
    return x


def zip_binary(op: str, a, b):
    """ZipWith pattern (the paper's VMUL is ``zip_binary("mul", ...)``)."""
    return binary(op, a, b)


def axpy(alpha, x, y):
    """Foreach pattern: ``y[i] = alpha * x[i] + y[i]`` (scaled update)."""
    return alpha * x + y


def filter_mask(x, threshold):
    """Filter pattern with static shapes.

    FPGAs stream; a filter tile forwards only passing elements. With static
    tensor shapes we express filter as (masked values, survivor count):
    values failing ``x > threshold`` are zeroed and the count of survivors is
    returned so downstream reduce stages see identical semantics.
    """
    mask = x > threshold
    kept = jnp.where(mask, x, jnp.zeros_like(x))
    count = jnp.sum(mask.astype(jnp.int32))
    return kept, count


def filter_reduce(x, threshold):
    """Filter → Reduce composition: sum of elements above threshold."""
    kept, _ = filter_mask(x, threshold)
    return jnp.sum(kept.astype(jnp.float32), dtype=jnp.float32)


def branch_map(pred_threshold, x, then_op: str, else_op: str):
    """Conditional map — the dynamic overlay's if-then-else with speculation.

    Both branch operators run (speculatively, as in contiguous overlay tiles)
    and the interconnect selects per element: ``x > t ? then(x) : else(x)``.
    """
    t = unary(then_op, x)
    e = unary(else_op, x)
    return jnp.where(x > pred_threshold, t, e)
