"""Pallas kernel for the paper's headline pattern: ``sum = Σ A⃗ × B⃗``.

This is the fused VMUL→Reduce pipeline of the *dynamic* overlay: the
multiplier tile and the adder (reduce) tile are contiguous, so products are
consumed the cycle they are produced and never materialized. The kernel
mirrors that: each grid step streams one BRAM-sized chunk of A and B into
VMEM, multiplies, and folds the partial sum into a single f32 accumulator —
no intermediate product vector ever hits HBM.

Compare ``ref.vmul_reduce`` (the oracle) which materializes the product —
that is the *static-overlay scenario-3* dataflow, where the product must
transit pass-through tiles before reaching the adder.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, accum_spec, f32, pick_block, stream_spec


def _kernel(a_ref, b_ref, o_ref):
    """One grid step: fold chunk i's product-sum into the accumulator."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    partial = jnp.sum(f32(a_ref[...]) * f32(b_ref[...]))
    o_ref[...] += partial.reshape(o_ref.shape)


def vmul_reduce(a: jax.Array, b: jax.Array, *, block: int | None = None) -> jax.Array:
    """Fused multiply-reduce: returns scalar ``sum(a * b)`` in float32.

    Args:
      a, b: rank-1 arrays of equal length (length must be a block multiple).
      block: elements per streamed chunk; defaults to one BRAM-sized chunk.
    """
    if a.shape != b.shape or a.ndim != 1:
        raise ValueError(f"expected equal rank-1 shapes, got {a.shape} vs {b.shape}")
    n = a.shape[0]
    blk = pick_block(n, block)
    out = pl.pallas_call(
        _kernel,
        grid=(n // blk,),
        in_specs=[stream_spec(blk), stream_spec(blk)],
        out_specs=accum_spec(),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=INTERPRET,
    )(a, b)
    return out[0]
