"""Pallas kernels for the Map / ZipWith patterns.

``map_unary``  — one operator tile streaming a vector (paper: sqrtf, sin,
                 cos, log live in the large PR regions; neg/abs/... in small).
``map_chain``  — a pipeline of unary tiles in *contiguous* overlay positions:
                 all stages fuse into one pass over each VMEM-resident chunk,
                 exactly the dynamic overlay's pipelined dataflow.
``zip_binary`` — one binary operator tile consuming two streams (VMUL is
                 ``zip_binary("mul", ...)``).
``branch_map`` — if-then-else with speculation: both branch operators execute
                 (they occupy contiguous tiles) and the interconnect selects
                 per element. This is the dynamic overlay's answer to the
                 original design's "cannot compose simple conditionals"
                 limitation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import (
    INTERPRET,
    binary_fn,
    pick_block,
    scalar_spec,
    stream_spec,
    unary_fn,
)


def _unary_kernel(op, x_ref, o_ref):
    o_ref[...] = unary_fn(op)(x_ref[...])


def map_unary(op: str, x: jax.Array, *, block: int | None = None) -> jax.Array:
    """Element-wise unary operator over a rank-1 array, streamed in blocks."""
    if x.ndim != 1:
        raise ValueError(f"expected rank-1 input, got shape {x.shape}")
    n = x.shape[0]
    blk = pick_block(n, block)
    return pl.pallas_call(
        functools.partial(_unary_kernel, op),
        grid=(n // blk,),
        in_specs=[stream_spec(blk)],
        out_specs=stream_spec(blk),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=INTERPRET,
    )(x)


def _chain_kernel(ops, x_ref, o_ref):
    v = x_ref[...]
    for op in ops:
        v = unary_fn(op)(v)
    o_ref[...] = v


def map_chain(ops: tuple[str, ...], x: jax.Array, *, block: int | None = None) -> jax.Array:
    """A fused pipeline of unary operators (contiguous tiles, one pass)."""
    if not ops:
        raise ValueError("map_chain requires at least one operator")
    if x.ndim != 1:
        raise ValueError(f"expected rank-1 input, got shape {x.shape}")
    n = x.shape[0]
    blk = pick_block(n, block)
    return pl.pallas_call(
        functools.partial(_chain_kernel, tuple(ops)),
        grid=(n // blk,),
        in_specs=[stream_spec(blk)],
        out_specs=stream_spec(blk),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=INTERPRET,
    )(x)


def _binary_kernel(op, a_ref, b_ref, o_ref):
    o_ref[...] = binary_fn(op)(a_ref[...], b_ref[...])


def zip_binary(op: str, a: jax.Array, b: jax.Array, *, block: int | None = None) -> jax.Array:
    """Element-wise binary operator over two equal-shape rank-1 arrays."""
    if a.shape != b.shape or a.ndim != 1:
        raise ValueError(f"expected equal rank-1 shapes, got {a.shape} vs {b.shape}")
    n = a.shape[0]
    blk = pick_block(n, block)
    return pl.pallas_call(
        functools.partial(_binary_kernel, op),
        grid=(n // blk,),
        in_specs=[stream_spec(blk), stream_spec(blk)],
        out_specs=stream_spec(blk),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=INTERPRET,
    )(a, b)


def _branch_kernel(then_op, else_op, t_ref, x_ref, o_ref):
    x = x_ref[...]
    taken = unary_fn(then_op)(x)       # speculated THEN tile
    not_taken = unary_fn(else_op)(x)   # speculated ELSE tile
    o_ref[...] = jnp.where(x > t_ref[0], taken, not_taken)


def branch_map(
    threshold: jax.Array,
    x: jax.Array,
    then_op: str,
    else_op: str,
    *,
    block: int | None = None,
) -> jax.Array:
    """Speculative if-then-else map: ``x > t ? then_op(x) : else_op(x)``.

    ``threshold`` is a (1,)-shaped array (a controller register in hardware).
    """
    threshold = jnp.asarray(threshold).reshape((1,))
    if x.ndim != 1:
        raise ValueError(f"expected rank-1 input, got shape {x.shape}")
    n = x.shape[0]
    blk = pick_block(n, block)
    return pl.pallas_call(
        functools.partial(_branch_kernel, then_op, else_op),
        grid=(n // blk,),
        in_specs=[scalar_spec(), stream_spec(blk)],
        out_specs=stream_spec(blk),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=INTERPRET,
    )(threshold.astype(x.dtype), x)
