"""Pallas kernel for the Reduce pattern alone: ``sum(x)``.

One adder tile with a feedback accumulator register; chunks stream from the
data BRAM and fold into the running sum. Used by the JIT when a composition
ends in a bare reduce (e.g. filter → reduce with the filter fused upstream).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, accum_spec, f32, pick_block, stream_spec


def _kernel(x_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(f32(x_ref[...])).reshape(o_ref.shape)


def reduce_sum(x: jax.Array, *, block: int | None = None) -> jax.Array:
    """Scalar float32 sum of a rank-1 array, streamed in blocks."""
    if x.ndim != 1:
        raise ValueError(f"expected rank-1 input, got shape {x.shape}")
    n = x.shape[0]
    blk = pick_block(n, block)
    out = pl.pallas_call(
        _kernel,
        grid=(n // blk,),
        in_specs=[stream_spec(blk)],
        out_specs=accum_spec(),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=INTERPRET,
    )(x)
    return out[0]
