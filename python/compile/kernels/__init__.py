"""L1: Pallas kernels for the overlay's parallel-pattern library.

Each kernel is the TPU-idiom rethinking of one pre-synthesized overlay
pattern (see DESIGN.md §Hardware-Adaptation): BlockSpec chunks stand in for
tile BRAMs, the grid for the chunk stream, and kernel fusion for contiguous
tile pipelines. All kernels are interpret-mode (CPU PJRT substrate) and are
verified against the pure-jnp oracle in :mod:`ref`.
"""

from . import ref  # noqa: F401
from .axpy import axpy  # noqa: F401
from .common import DEFAULT_BLOCK, pick_block  # noqa: F401
from .filter import filter_mask, filter_reduce  # noqa: F401
from .map_ops import branch_map, map_chain, map_unary, zip_binary  # noqa: F401
from .reduce import reduce_sum  # noqa: F401
from .vmul_reduce import vmul_reduce  # noqa: F401

__all__ = [
    "axpy",
    "branch_map",
    "filter_mask",
    "filter_reduce",
    "map_chain",
    "map_unary",
    "reduce_sum",
    "vmul_reduce",
    "zip_binary",
    "ref",
    "DEFAULT_BLOCK",
    "pick_block",
]
