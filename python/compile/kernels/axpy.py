"""Pallas kernel for the Foreach pattern: ``y ← α·x + y`` (AXPY).

Foreach updates each element in place; the overlay realizes it as a
multiplier tile (α from a controller register) feeding an adder tile that
also consumes the y stream — two contiguous tiles, fully pipelined. The
kernel fuses both stages over each VMEM-resident chunk.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_block, scalar_spec, stream_spec


def _kernel(alpha_ref, x_ref, y_ref, o_ref):
    o_ref[...] = alpha_ref[0] * x_ref[...] + y_ref[...]


def axpy(
    alpha: jax.Array, x: jax.Array, y: jax.Array, *, block: int | None = None
) -> jax.Array:
    """Element-wise ``alpha * x + y`` over equal-length rank-1 arrays."""
    alpha = jnp.asarray(alpha).reshape((1,))
    if x.shape != y.shape or x.ndim != 1:
        raise ValueError(f"expected equal rank-1 shapes, got {x.shape} vs {y.shape}")
    n = x.shape[0]
    blk = pick_block(n, block)
    return pl.pallas_call(
        _kernel,
        grid=(n // blk,),
        in_specs=[scalar_spec(), stream_spec(blk), stream_spec(blk)],
        out_specs=stream_spec(blk),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=INTERPRET,
    )(alpha.astype(x.dtype), x, y)
