"""Pallas kernels for the Filter pattern (static-shape streaming filter).

A hardware filter tile forwards only passing elements downstream. Static
tensor shapes force a mask encoding instead: failing lanes are zeroed and a
survivor count is accumulated, so a downstream Reduce observes identical
semantics to the hardware stream (zeros are additive identity).

``filter_reduce`` fuses Filter→Reduce into one pass — the contiguous-tile
composition the dynamic overlay assembles for "sum of elements above t".
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, accum_spec, f32, pick_block, scalar_spec, stream_spec


def _filter_kernel(t_ref, x_ref, kept_ref, count_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        count_ref[...] = jnp.zeros_like(count_ref)

    x = x_ref[...]
    mask = x > t_ref[0]
    kept_ref[...] = jnp.where(mask, x, jnp.zeros_like(x))
    count_ref[...] += jnp.sum(mask.astype(jnp.int32)).reshape(count_ref.shape)


def filter_mask(
    x: jax.Array, threshold: jax.Array, *, block: int | None = None
) -> tuple[jax.Array, jax.Array]:
    """Mask-encoded filter: returns (kept values with zeros, survivor count)."""
    threshold = jnp.asarray(threshold).reshape((1,))
    if x.ndim != 1:
        raise ValueError(f"expected rank-1 input, got shape {x.shape}")
    n = x.shape[0]
    blk = pick_block(n, block)
    kept, count = pl.pallas_call(
        _filter_kernel,
        grid=(n // blk,),
        in_specs=[scalar_spec(), stream_spec(blk)],
        out_specs=[stream_spec(blk), accum_spec()],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=INTERPRET,
    )(threshold.astype(x.dtype), x)
    return kept, count[0]


def _filter_reduce_kernel(t_ref, x_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    kept = jnp.where(x > t_ref[0], f32(x), jnp.zeros((), jnp.float32))
    o_ref[...] += jnp.sum(kept).reshape(o_ref.shape)


def filter_reduce(
    x: jax.Array, threshold: jax.Array, *, block: int | None = None
) -> jax.Array:
    """Fused Filter→Reduce: float32 sum of elements above ``threshold``."""
    threshold = jnp.asarray(threshold).reshape((1,))
    if x.ndim != 1:
        raise ValueError(f"expected rank-1 input, got shape {x.shape}")
    n = x.shape[0]
    blk = pick_block(n, block)
    out = pl.pallas_call(
        _filter_reduce_kernel,
        grid=(n // blk,),
        in_specs=[scalar_spec(), stream_spec(blk)],
        out_specs=accum_spec(),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=INTERPRET,
    )(threshold.astype(x.dtype), x)
    return out[0]
