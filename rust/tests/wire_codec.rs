//! Deterministic wire-codec tests: every byte path is exercised on byte
//! slices — no sockets. Partial delivery is simulated by pushing a frame
//! into the [`FrameDecoder`] one byte (or one odd-sized chunk) at a time,
//! and mid-frame disconnects by cutting the byte stream at every possible
//! offset.

use std::io::Cursor;

use jit_overlay::coordinator::wire::{
    read_frame, ClientMsg, FrameDecoder, ServerMsg, DEFAULT_MAX_FRAME,
};
use jit_overlay::exec::cpu::Value;

fn sample_client_msgs() -> Vec<ClientMsg> {
    vec![
        ClientMsg::Request { id: 0, n: 0, seed: 0, pattern: String::new() },
        ClientMsg::Request {
            id: u64::MAX,
            n: 1 << 20,
            seed: 0xDEAD_BEEF,
            pattern: "chain:abs,neg,square".into(),
        },
        ClientMsg::Request { id: 7, n: 256, seed: 42, pattern: "vmul-reduce".into() },
        ClientMsg::Shutdown,
    ]
}

fn sample_server_msgs() -> Vec<ServerMsg> {
    vec![
        ServerMsg::Ok { id: 1, cached: false, jit_nanos: 12_345, value: Value::Scalar(3.25) },
        ServerMsg::Ok {
            id: 2,
            cached: true,
            jit_nanos: 0,
            value: Value::Vector(vec![0.0, -1.5, f32::MAX, 1e-20]),
        },
        ServerMsg::Ok { id: 3, cached: true, jit_nanos: 1, value: Value::Vector(vec![]) },
        ServerMsg::Err { id: u64::MAX, message: "capacité dépassée ✗".into() },
        ServerMsg::Busy { id: 99 },
    ]
}

#[test]
fn client_messages_roundtrip() {
    for msg in sample_client_msgs() {
        let frame = msg.to_frame();
        let mut dec = FrameDecoder::new(0);
        dec.push(&frame);
        let payload = dec.next_frame().unwrap().expect("one whole frame");
        assert_eq!(ClientMsg::decode(&payload).unwrap(), msg);
        assert!(!dec.is_mid_frame(), "frame fully consumed");
    }
}

#[test]
fn server_messages_roundtrip() {
    for msg in sample_server_msgs() {
        let frame = msg.to_frame();
        let mut dec = FrameDecoder::new(0);
        dec.push(&frame);
        let payload = dec.next_frame().unwrap().expect("one whole frame");
        assert_eq!(ServerMsg::decode(&payload).unwrap(), msg);
    }
}

/// Frames reassemble from arbitrary chunking: byte-at-a-time, and every
/// split point of a two-frame stream.
#[test]
fn partial_reads_reassemble_across_frame_boundaries() {
    let a = ClientMsg::Request { id: 5, n: 64, seed: 9, pattern: "map:relu".into() };
    let b = ClientMsg::Shutdown;
    let mut stream = a.to_frame();
    stream.extend_from_slice(&b.to_frame());

    // byte at a time: exactly two frames pop out, in order
    let mut dec = FrameDecoder::new(0);
    let mut got = Vec::new();
    for &byte in &stream {
        dec.push(&[byte]);
        while let Some(p) = dec.next_frame().unwrap() {
            got.push(ClientMsg::decode(&p).unwrap());
        }
    }
    assert_eq!(got, vec![a.clone(), b.clone()]);
    assert!(!dec.is_mid_frame());

    // every split point of the stream, two pushes
    for cut in 0..=stream.len() {
        let mut dec = FrameDecoder::new(0);
        let mut got = Vec::new();
        dec.push(&stream[..cut]);
        while let Some(p) = dec.next_frame().unwrap() {
            got.push(ClientMsg::decode(&p).unwrap());
        }
        dec.push(&stream[cut..]);
        while let Some(p) = dec.next_frame().unwrap() {
            got.push(ClientMsg::decode(&p).unwrap());
        }
        assert_eq!(got, vec![a.clone(), b.clone()], "split at {cut}");
    }
}

/// An oversized length prefix is rejected from the prefix alone — before
/// any payload arrives — and the decoder stays poisoned afterwards.
#[test]
fn oversized_length_prefix_is_rejected_before_buffering() {
    let mut dec = FrameDecoder::new(1024);
    dec.push(&2048u32.to_le_bytes());
    assert!(dec.next_frame().is_err(), "oversized prefix must be rejected");
    dec.push(&[0u8; 8]); // stream keeps talking: still broken
    assert!(dec.next_frame().is_err(), "framing violations are sticky");

    // a frame exactly at the cap is fine
    let mut dec = FrameDecoder::new(1024);
    let payload = vec![0x42u8; 1024];
    dec.push(&1024u32.to_le_bytes());
    dec.push(&payload);
    assert_eq!(dec.next_frame().unwrap().unwrap(), payload);
}

/// Malformed payloads: unknown tags, bad flags, non-UTF-8 strings,
/// truncations and trailing bytes all decode to errors, never panics.
#[test]
fn malformed_payloads_error_cleanly() {
    assert!(ClientMsg::decode(&[]).is_err(), "empty payload");
    assert!(ClientMsg::decode(&[0x7F]).is_err(), "unknown client tag");
    assert!(ServerMsg::decode(&[0x01]).is_err(), "client tag on the server side");
    assert!(ClientMsg::decode(&[0x81]).is_err(), "server tag on the client side");

    // REQUEST with a string length pointing past the payload end
    let mut p = vec![0x01];
    p.extend_from_slice(&1u64.to_le_bytes()); // id
    p.extend_from_slice(&8u32.to_le_bytes()); // n
    p.extend_from_slice(&2u64.to_le_bytes()); // seed
    p.extend_from_slice(&100u32.to_le_bytes()); // pattern len: 100, but...
    p.extend_from_slice(b"short"); // ...only 5 bytes follow
    assert!(ClientMsg::decode(&p).is_err(), "string length past payload end");

    // REQUEST whose pattern bytes are not UTF-8
    let mut p = vec![0x01];
    p.extend_from_slice(&1u64.to_le_bytes());
    p.extend_from_slice(&8u32.to_le_bytes());
    p.extend_from_slice(&2u64.to_le_bytes());
    p.extend_from_slice(&2u32.to_le_bytes());
    p.extend_from_slice(&[0xFF, 0xFE]);
    assert!(ClientMsg::decode(&p).is_err(), "non-UTF-8 pattern");

    // OK with a bad cached flag, then with a bad value kind
    let mut p = vec![0x81];
    p.extend_from_slice(&1u64.to_le_bytes());
    p.push(2); // cached must be 0 or 1
    p.extend_from_slice(&0u64.to_le_bytes());
    p.push(0);
    p.extend_from_slice(&1.0f32.to_le_bytes());
    assert!(ServerMsg::decode(&p).is_err(), "bad cached flag");
    let mut p = vec![0x81];
    p.extend_from_slice(&1u64.to_le_bytes());
    p.push(0);
    p.extend_from_slice(&0u64.to_le_bytes());
    p.push(9); // value kind must be 0 or 1
    assert!(ServerMsg::decode(&p).is_err(), "bad value kind");

    // BUSY with trailing bytes
    let mut p = vec![0x83];
    p.extend_from_slice(&1u64.to_le_bytes());
    p.push(0);
    assert!(ServerMsg::decode(&p).is_err(), "trailing bytes");

    // vector whose declared count exceeds the remaining bytes
    let mut p = vec![0x81];
    p.extend_from_slice(&1u64.to_le_bytes());
    p.push(1);
    p.extend_from_slice(&0u64.to_le_bytes());
    p.push(1); // vector
    p.extend_from_slice(&1000u32.to_le_bytes()); // count 1000, zero floats follow
    assert!(ServerMsg::decode(&p).is_err(), "vector count past payload end");
}

/// Mid-frame disconnects: cut the byte stream at every offset. A cut at a
/// frame boundary is clean; anywhere else the decoder reports a partial
/// frame buffered ([`FrameDecoder::is_mid_frame`]), which is how the
/// serving tier distinguishes a polite hangup from a broken peer.
#[test]
fn mid_frame_disconnects_are_detectable_at_every_cut() {
    let msg = ServerMsg::Ok {
        id: 11,
        cached: true,
        jit_nanos: 500,
        value: Value::Vector(vec![1.0, 2.0, 3.0]),
    };
    let stream = msg.to_frame();
    for cut in 0..=stream.len() {
        let mut dec = FrameDecoder::new(0);
        dec.push(&stream[..cut]);
        let complete = dec.next_frame().unwrap();
        if cut == stream.len() {
            assert!(complete.is_some(), "full stream must decode");
            assert!(!dec.is_mid_frame(), "boundary cut is clean");
        } else {
            assert!(complete.is_none(), "cut at {cut} must not yield a frame");
            assert_eq!(dec.is_mid_frame(), cut > 0, "cut at {cut}");
            assert_eq!(dec.buffered(), cut);
        }
    }
}

/// The blocking-stream helpers agree with the incremental decoder: clean
/// EOF at a boundary is `None`, EOF inside a frame is `UnexpectedEof`,
/// and an oversized prefix is `InvalidData` before the payload is read.
#[test]
fn blocking_read_frame_matches_the_decoder_semantics() {
    let msg = ClientMsg::Request { id: 3, n: 128, seed: 77, pattern: "axpy:2.5".into() };
    let frame = msg.to_frame();

    // two frames back to back, then clean EOF
    let mut stream = frame.clone();
    stream.extend_from_slice(&frame);
    let mut cur = Cursor::new(stream);
    for _ in 0..2 {
        let p = read_frame(&mut cur, 0).unwrap().expect("whole frame");
        assert_eq!(ClientMsg::decode(&p).unwrap(), msg);
    }
    assert!(read_frame(&mut cur, 0).unwrap().is_none(), "clean EOF at boundary");

    // EOF inside the prefix and inside the payload
    for cut in [2usize, frame.len() - 1] {
        let mut cur = Cursor::new(frame[..cut].to_vec());
        let err = read_frame(&mut cur, 0).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof, "cut at {cut}");
    }

    // oversized prefix: InvalidData, without consuming the payload
    let mut bytes = (DEFAULT_MAX_FRAME as u32 + 1).to_le_bytes().to_vec();
    bytes.extend_from_slice(&[0u8; 16]);
    let mut cur = Cursor::new(bytes);
    let err = read_frame(&mut cur, 0).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    assert_eq!(cur.position(), 4, "payload must not be read after a hostile prefix");
}
