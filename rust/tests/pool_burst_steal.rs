//! Pool-level burst draining, work-stealing, backpressure, and routing-cap
//! tests (ISSUE 3 acceptance criteria).
//!
//! Determinism technique: `WorkerPool::new_paused` holds every worker at a
//! start gate, so a full backlog can be enqueued before any serving starts
//! — the drain order is then a pure function of the configuration, not of
//! submit/serve timing. The steal test additionally releases only the
//! thief (`start_worker`) so the victim's queue is provably untouched
//! while the steal happens.

use std::sync::Arc;
use std::time::Duration;

use jit_overlay::bitstream::OperatorKind;
use jit_overlay::coordinator::{Metrics, Request, WorkerPool};
use jit_overlay::exec::cpu::{self, Value};
use jit_overlay::patterns::Composition;
use jit_overlay::{workload, Error, OverlayConfig, ServiceConfig};

/// A,B,A,B,… requests with per-request distinct inputs.
fn interleaved_requests(a: &Composition, b: &Composition, rounds: usize) -> Vec<Request> {
    workload::interleaved_stream(&[a.clone(), b.clone()], rounds)
        .into_iter()
        .enumerate()
        .map(|(i, comp)| {
            let inputs = workload::request_inputs(&comp, i as u64);
            Request::dynamic(comp, inputs)
        })
        .collect()
}

/// Enqueue the whole backlog on a paused pool, release it, drain replies.
fn drain_paused(service: ServiceConfig, reqs: &[Request]) -> Metrics {
    let pool = WorkerPool::new_paused(OverlayConfig::default(), service).expect("pool spawn");
    let pending: Vec<_> = reqs.iter().map(|r| pool.submit(r.clone()).expect("submit")).collect();
    pool.start();
    for rx in pending {
        rx.recv().expect("worker alive").expect("request served");
    }
    pool.shutdown().aggregate
}

/// ISSUE 3 acceptance: on the interleaved conflicting-chain workload at 4
/// workers, burst draining shows strictly fewer PR downloads per request
/// than the PR 1 FIFO drain (the pool-level mirror of the coordinator's
/// `batched_order_reduces_pr_downloads`).
#[test]
fn burst_drain_beats_fifo_on_interleaved_conflicts() {
    const WORKERS: usize = 4;
    const ROUNDS: usize = 4;
    let Some((a, b)) = workload::home_aligned_conflicting_pair(WORKERS as u64) else {
        eprintln!("skipping: no home-aligned chain pair under this hasher");
        return;
    };
    let reqs = interleaved_requests(&a, &b, ROUNDS);
    let service = |drain_window: usize| {
        ServiceConfig {
            drain_window,
            queue_capacity: reqs.len(),
            max_queue_skew: 1_000_000, // affinity only: the stream stays on one fabric
            ..ServiceConfig::with_workers(WORKERS)
        }
        .without_stealing()
    };

    let fifo = drain_paused(service(1), &reqs);
    let burst = drain_paused(service(reqs.len()), &reqs);

    assert_eq!(fifo.requests, reqs.len() as u64);
    assert_eq!(burst.requests, reqs.len() as u64);
    // FIFO: one burst per job, never a within-burst switch, PR thrash on
    // every A↔B alternation
    assert_eq!(fifo.bursts, reqs.len() as u64);
    assert_eq!(fifo.burst_group_switches, 0);
    assert!(fifo.evictions >= 1, "the FIFO baseline must actually thrash");
    // burst: the whole backlog drains as one window, regrouped to A…A B…B
    assert_eq!(burst.bursts, 1);
    assert_eq!(burst.burst_group_switches, 1);
    assert!(
        burst.pr_downloads < fifo.pr_downloads,
        "burst {} !< fifo {} PR downloads",
        burst.pr_downloads,
        fifo.pr_downloads
    );
    let per_req = |m: &Metrics| m.pr_downloads as f64 / m.requests as f64;
    assert!(per_req(&burst) < per_req(&fifo));
}

/// ISSUE 3 acceptance: with one worker's queue force-loaded deep, an idle
/// worker steals a whole composition group (never splitting it), the route
/// table repoints to the thief, and aggregate metrics still equal the
/// per-worker sum.
#[test]
fn idle_worker_steals_whole_group_and_repoints_route() {
    const K: usize = 4; // jobs per composition group
    let (a, b) = workload::home_aligned_conflicting_pair(2).expect("pigeonhole over three keys");
    let home = (a.cache_key() % 2) as usize;
    let thief = 1 - home;
    let service = ServiceConfig {
        queue_capacity: 2 * K,
        max_queue_skew: 1_000_000, // no spills: the backlog queues at home
        steal_min_depth: K + 1,    // exactly one steal: 2K ≥ K+1 > K
        ..ServiceConfig::with_workers(2)
    };
    let pool = WorkerPool::new_paused(OverlayConfig::default(), service).unwrap();
    let reqs = interleaved_requests(&a, &b, K);
    let pending: Vec<_> = reqs.iter().map(|r| pool.submit(r.clone()).unwrap()).collect();
    assert_eq!(pool.queue_depth(home), 2 * K);
    assert_eq!(pool.queue_depth(thief), 0);

    // release only the thief: it must find its own queue empty, steal the
    // tail group — every queued `b` job, interleaved or not — and serve it
    pool.start_worker(thief);
    let mut waited = 0;
    while pool.snapshot().requests < K as u64 {
        std::thread::sleep(Duration::from_millis(1));
        waited += 1;
        assert!(waited < 10_000, "thief never served the stolen group");
    }
    assert_eq!(pool.snapshot().steals, 1);
    assert_eq!(
        pool.queue_depth(home),
        K,
        "only the tail group may be taken — groups are never split"
    );
    assert_eq!(
        pool.planned_worker(b.cache_key()),
        thief,
        "route must repoint so repeats follow the stolen residency"
    );
    assert_eq!(pool.planned_worker(a.cache_key()), home);

    pool.start_worker(home);
    for rx in pending {
        rx.recv().unwrap().unwrap();
    }
    let report = pool.shutdown();

    // the thief served exactly the stolen group, the victim the rest
    assert_eq!(report.per_worker[thief].requests, K as u64);
    assert_eq!(report.per_worker[thief].steals, 1);
    assert_eq!(report.per_worker[home].requests, K as u64);
    assert_eq!(report.per_worker[home].steals, 0);
    // each fabric served one single-composition burst: no switches, no
    // cross-composition thrash anywhere
    assert_eq!(report.aggregate.bursts, 2);
    assert_eq!(report.aggregate.burst_group_switches, 0);
    assert_eq!(report.aggregate.pr_replaced, 0);
    assert_eq!(report.aggregate.evictions, 0);
    // aggregate equals the per-worker sum
    let sum = report.worker_sum();
    assert_eq!(sum.requests, report.aggregate.requests);
    assert_eq!(sum.jit_compiles, report.aggregate.jit_compiles);
    assert_eq!(sum.cache_hits, report.aggregate.cache_hits);
    assert_eq!(sum.pr_downloads, report.aggregate.pr_downloads);
    assert_eq!(sum.pr_region_hits, report.aggregate.pr_region_hits);
    assert_eq!(sum.bursts, report.aggregate.bursts);
    assert_eq!(sum.burst_group_switches, report.aggregate.burst_group_switches);
    assert_eq!(sum.steals, report.aggregate.steals);
    assert_eq!(sum.lru_evictions, report.aggregate.lru_evictions);
    assert!(report.panicked_workers.is_empty());
}

/// Backpressure: a full bounded queue rejects `try_submit` with `PoolBusy`
/// and counts it, while blocking `submit` waits for room instead.
#[test]
fn backpressure_rejects_then_recovers() {
    let service = ServiceConfig {
        queue_capacity: 3,
        ..ServiceConfig::with_workers(1).without_stealing()
    };
    let pool = WorkerPool::new_paused(OverlayConfig::default(), service).unwrap();
    let comp = Composition::map(OperatorKind::Sqrt, 128);
    let req = |k: u64| Request::dynamic(comp.clone(), workload::request_inputs(&comp, k));
    let mut pending = Vec::new();
    for k in 0..3 {
        pending.push(pool.try_submit(req(k)).unwrap());
    }
    for k in 3..5 {
        match pool.try_submit(req(k)) {
            Err(Error::PoolBusy { worker: 0, capacity: 3 }) => {}
            other => panic!("expected PoolBusy, got {other:?}"),
        }
    }
    assert_eq!(pool.snapshot().rejected, 2);
    pool.start();
    for rx in pending {
        rx.recv().unwrap().unwrap();
    }
    // started pool: blocking submits ride the backpressure without errors
    for k in 5..25 {
        pool.submit_wait(req(k)).unwrap();
    }
    let report = pool.shutdown();
    assert_eq!(report.aggregate.requests, 23);
    assert_eq!(report.aggregate.rejected, 2);
    // rejections are pool-level accounting, not any worker's
    assert_eq!(report.worker_sum().rejected, 0);
}

/// Satellite: contended-submit regression. Many client threads pipeline
/// blocking submits of one hot composition through tiny bounded queues;
/// every request must be served exactly once and the counters conserve.
#[test]
fn contended_pipelined_submitters_conserve_requests() {
    const CLIENTS: usize = 8;
    const PER_CLIENT: usize = 40;
    let service = ServiceConfig {
        queue_capacity: 4,
        drain_window: 4,
        ..ServiceConfig::with_workers(2)
    };
    let pool = Arc::new(WorkerPool::new(OverlayConfig::default(), service).unwrap());
    let mut joins = Vec::new();
    for c in 0..CLIENTS as u64 {
        let p = pool.clone();
        joins.push(std::thread::spawn(move || {
            let comp = Composition::vmul_reduce(256);
            let mut rxs = Vec::new();
            for i in 0..PER_CLIENT as u64 {
                let inputs = workload::request_inputs(&comp, c * 1000 + i);
                rxs.push(p.submit(Request::dynamic(comp.clone(), inputs)).unwrap());
            }
            for rx in rxs {
                rx.recv().expect("worker alive").expect("request served");
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let report = Arc::try_unwrap(pool).ok().expect("clients done").shutdown();
    assert_eq!(report.aggregate.requests, (CLIENTS * PER_CLIENT) as u64);
    assert_eq!(report.aggregate.rejected, 0, "blocking submit never rejects");
    let sum = report.worker_sum();
    assert_eq!(sum.requests, report.aggregate.requests);
    assert_eq!(sum.jit_compiles, report.aggregate.jit_compiles);
    assert_eq!(sum.cache_hits, report.aggregate.cache_hits);
    assert_eq!(sum.pr_downloads, report.aggregate.pr_downloads);
    assert_eq!(sum.bursts, report.aggregate.bursts);
    assert!(report.panicked_workers.is_empty());
}

fn agree(a: &Value, b: &Value) -> bool {
    const TOL: f32 = 1e-3;
    match (a, b) {
        (Value::Scalar(x), Value::Scalar(y)) => (x - y).abs() <= TOL * (1.0 + y.abs()),
        (Value::Vector(x), Value::Vector(y)) => {
            x.len() == y.len()
                && x.iter().zip(y).all(|(p, q)| (p - q).abs() <= TOL * (1.0 + q.abs()))
        }
        _ => false,
    }
}

/// Satellite: property-style reply integrity. Random interleaved streams
/// with aggressive bursting and stealing — every reply must carry the
/// value of *its own* request (distinct inputs per request make the value
/// a fingerprint of the pairing) and per-client recv order must hold.
#[test]
fn random_interleaved_streams_preserve_reply_integrity() {
    const CLIENTS: u64 = 3;
    const PER_CLIENT: usize = 30;
    let service = ServiceConfig {
        queue_capacity: 64,
        drain_window: 8,
        steal_min_depth: 1, // steal at any depth: maximize migrations
        max_queue_skew: 2,  // spill eagerly too
        ..ServiceConfig::with_workers(3)
    };
    let pool = Arc::new(WorkerPool::new(OverlayConfig::default(), service).unwrap());
    let mut joins = Vec::new();
    for client in 0..CLIENTS {
        let p = pool.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = workload::Rng::new(0xC0FFEE + client);
            let chains = workload::conflicting_chains(256);
            let reqs: Vec<Request> = (0..PER_CLIENT as u64)
                .map(|i| {
                    let comp = match rng.below(5) {
                        0 => chains[0].clone(),
                        1 => chains[1].clone(),
                        2 => chains[2].clone(),
                        3 => Composition::map(OperatorKind::Sqrt, 256),
                        _ => Composition::vmul_reduce(256),
                    };
                    let inputs = workload::request_inputs(&comp, client * 10_000 + i);
                    Request::dynamic(comp, inputs)
                })
                .collect();
            let expected: Vec<Value> =
                reqs.iter().map(|r| cpu::eval(&r.comp, &r.inputs).unwrap()).collect();
            let rxs: Vec<_> = reqs.iter().map(|r| p.submit(r.clone()).unwrap()).collect();
            for (i, rx) in rxs.into_iter().enumerate() {
                let resp = rx.recv().expect("worker hung up").expect("request failed");
                assert!(
                    agree(&resp.run.output, &expected[i]),
                    "client {client} reply {i} cross-wired: {:?} vs {:?}",
                    resp.run.output,
                    expected[i]
                );
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let report = Arc::try_unwrap(pool).ok().expect("clients done").shutdown();
    assert_eq!(report.aggregate.requests, CLIENTS * PER_CLIENT as u64);
    let sum = report.worker_sum();
    assert_eq!(sum.requests, report.aggregate.requests);
    assert_eq!(sum.pr_downloads, report.aggregate.pr_downloads);
    assert_eq!(sum.steals, report.aggregate.steals);
    assert!(report.panicked_workers.is_empty());
}

/// Satellite: the routing table honors its LRU cap under K+N distinct
/// compositions.
#[test]
fn route_table_honors_lru_cap() {
    const CAP: usize = 8;
    let service = ServiceConfig { route_capacity: CAP, ..ServiceConfig::with_workers(2) };
    let pool = WorkerPool::new(OverlayConfig::default(), service).unwrap();
    for i in 0..CAP + 6 {
        let comp = Composition::vmul_reduce(64 + 64 * i); // distinct keys
        let inputs = workload::request_inputs(&comp, i as u64);
        pool.submit_wait(Request::dynamic(comp, inputs)).unwrap();
        assert!(
            pool.routed_compositions() <= CAP,
            "route cap {CAP} violated: {}",
            pool.routed_compositions()
        );
    }
    assert_eq!(pool.routed_compositions(), CAP);
    let report = pool.shutdown();
    assert_eq!(report.aggregate.requests, (CAP + 6) as u64);
}
