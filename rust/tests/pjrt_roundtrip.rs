//! Integration across the language boundary: the L1/L2 artifacts (JAX +
//! Pallas, AOT-lowered to HLO text) executed through the Rust PJRT runtime
//! must agree with the overlay interpreter and the CPU reference.
//!
//! Without `artifacts/` these tests skip — *loudly*: each prints an
//! explicit `skipped:` marker (visible with `--nocapture`), and the CI
//! `pjrt-skip-visibility` job asserts the marker so a silently-missing
//! artifact build can never masquerade as a passing roundtrip suite.
//! Build the artifacts with `make artifacts` (repo root) to run them for
//! real.

use jit_overlay::bitstream::OperatorKind;
use jit_overlay::exec::{cpu, Engine};
use jit_overlay::jit::Jit;
use jit_overlay::patterns::Composition;
use jit_overlay::runtime::{default_artifacts_dir, Runtime};
use jit_overlay::timing::Target;
use jit_overlay::{workload, OverlayConfig};

fn runtime() -> Option<Runtime> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.tsv").exists() {
        // keep this string in sync with .github/workflows/ci.yml, which
        // greps for it to prove the skip is visible, not silent
        println!("skipped: artifacts missing (run make artifacts)");
        return None;
    }
    Some(Runtime::new(dir).unwrap())
}

#[test]
fn manifest_covers_the_paper_workload() {
    let Some(rt) = runtime() else { return };
    assert_eq!(rt.manifest().paper_n, 4096); // 16 KB of f32
    assert!(rt.manifest().get("vmul_reduce_n4096").is_ok());
}

#[test]
fn three_way_agreement_vmul_reduce_all_sizes() {
    let Some(rt) = runtime() else { return };
    let mut engine = Engine::new(OverlayConfig::default()).unwrap();
    for n in [1024usize, 4096, 16384] {
        let name = format!("vmul_reduce_n{n}");
        if rt.manifest().get(&name).is_err() {
            continue;
        }
        let comp = Composition::vmul_reduce(n);
        let acc = Jit.compile(&engine.fabric, &engine.lib, &comp).unwrap();
        let a = workload::vector(n, 100 + n as u64, -2.0, 2.0);
        let b = workload::vector(n, 200 + n as u64, -2.0, 2.0);

        let overlay = engine
            .run(&acc, &[a.clone(), b.clone()], Target::DynamicOverlay)
            .unwrap()
            .output
            .as_scalar()
            .unwrap();
        let reference = cpu::eval(&comp, &[a.clone(), b.clone()])
            .unwrap()
            .as_scalar()
            .unwrap();
        let pjrt = rt.execute_scalar(&name, &[a, b]).unwrap();

        let tol = 1e-2_f32.max(pjrt.abs() * 1e-4);
        assert!((overlay - pjrt).abs() < tol, "n={n}: overlay {overlay} vs pjrt {pjrt}");
        assert!((reference - pjrt).abs() < tol, "n={n}: cpu {reference} vs pjrt {pjrt}");
        engine.fabric.reset_full();
    }
}

#[test]
fn pallas_map_kernels_match_overlay() {
    let Some(rt) = runtime() else { return };
    let mut engine = Engine::new(OverlayConfig::default()).unwrap();
    let n = 4096;
    for op in [OperatorKind::Sqrt, OperatorKind::Exp, OperatorKind::Abs, OperatorKind::Neg] {
        let name = format!("map_{}_n{n}", op.name());
        if rt.manifest().get(&name).is_err() {
            continue;
        }
        let x = workload::vector(n, 7, 0.1, 3.0);
        let pjrt = rt.execute(&name, &[x.clone()]).unwrap();
        let comp = Composition::map(op, n);
        let acc = Jit.compile(&engine.fabric, &engine.lib, &comp).unwrap();
        let overlay = engine
            .run(&acc, &[x], Target::DynamicOverlay)
            .unwrap()
            .output;
        let ov = overlay.as_vector().unwrap();
        for i in 0..n {
            assert!(
                (ov[i] - pjrt[0][i]).abs() < 1e-3 * (1.0 + pjrt[0][i].abs()),
                "{name} i={i}: {} vs {}",
                ov[i],
                pjrt[0][i]
            );
        }
        engine.fabric.reset_full();
    }
}

#[test]
fn pallas_filter_reduce_matches_overlay() {
    let Some(rt) = runtime() else { return };
    let n = 4096;
    let name = format!("filter_reduce_n{n}");
    if rt.manifest().get(&name).is_err() {
        return;
    }
    let mut engine = Engine::new(OverlayConfig::default()).unwrap();
    let x = workload::vector(n, 31, -2.0, 2.0);
    let t = 0.25f32;
    let pjrt = rt.execute_scalar(&name, &[x.clone(), vec![t]]).unwrap();
    let comp = Composition::filter_reduce(t, n);
    let acc = Jit.compile(&engine.fabric, &engine.lib, &comp).unwrap();
    let overlay = engine
        .run(&acc, &[x], Target::DynamicOverlay)
        .unwrap()
        .output
        .as_scalar()
        .unwrap();
    assert!(
        (overlay - pjrt).abs() < 1e-2 + pjrt.abs() * 1e-4,
        "overlay {overlay} vs pjrt {pjrt}"
    );
}

#[test]
fn pallas_branch_kernel_matches_overlay() {
    let Some(rt) = runtime() else { return };
    let n = 4096;
    let name = "branch_sqrt_square_n4096";
    if rt.manifest().get(name).is_err() {
        return;
    }
    let mut engine = Engine::new(OverlayConfig::default()).unwrap();
    let x = workload::vector(n, 41, 0.05, 2.0);
    let t = 0.8f32;
    let pjrt = rt.execute(name, &[vec![t], x.clone()]).unwrap();
    let comp = Composition::branch(t, OperatorKind::Sqrt, OperatorKind::Square, n);
    let acc = Jit.compile(&engine.fabric, &engine.lib, &comp).unwrap();
    let overlay = engine
        .run(&acc, &[x], Target::DynamicOverlay)
        .unwrap()
        .output;
    let ov = overlay.as_vector().unwrap();
    for i in 0..n {
        assert!(
            (ov[i] - pjrt[0][i]).abs() < 1e-3 * (1.0 + pjrt[0][i].abs()),
            "i={i}: {} vs {}",
            ov[i],
            pjrt[0][i]
        );
    }
}

#[test]
fn executable_cache_amortizes_compilation() {
    let Some(rt) = runtime() else { return };
    let n = rt.manifest().paper_n;
    let name = rt.manifest().headline.clone();
    let z = vec![0.5f32; n];

    let t0 = std::time::Instant::now();
    rt.execute_scalar(&name, &[z.clone(), z.clone()]).unwrap();
    let cold = t0.elapsed();

    let t1 = std::time::Instant::now();
    for _ in 0..5 {
        rt.execute_scalar(&name, &[z.clone(), z.clone()]).unwrap();
    }
    let warm_each = t1.elapsed() / 5;
    assert!(
        warm_each < cold,
        "warm path ({warm_each:?}) should beat cold compile ({cold:?})"
    );
}
