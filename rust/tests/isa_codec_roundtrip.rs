//! Property-style roundtrip tests for the 42-instruction controller ISA
//! codec: `decode(encode(i)) == i` for every opcode under randomized
//! operands, and malformed words surface structured `Error`s, never panics.
//!
//! Randomness comes from the in-tree deterministic [`jit_overlay::workload::Rng`]
//! (fixed seeds — failures reproduce exactly).

use jit_overlay::isa::{encode, Category, Instr, Opcode};
use jit_overlay::workload::Rng;

const CASES_PER_OPCODE: usize = 64;

/// Random in-range operand set for any opcode.
fn random_instr(op: Opcode, rng: &mut Rng) -> Instr {
    Instr {
        op,
        tile: rng.below(64) as u8,
        a: rng.below(32) as u8,
        b: rng.below(32) as u8,
        imm: (rng.below(1024) as i16) - 512,
    }
}

#[test]
fn every_opcode_roundtrips_with_random_operands() {
    let mut rng = Rng::new(0x15A_C0DE);
    let mut covered = 0;
    for op in Opcode::all() {
        for _ in 0..CASES_PER_OPCODE {
            let i = random_instr(op, &mut rng);
            let w = encode::encode(&i).expect("in-range instr must encode");
            let back = encode::decode(w).expect("encoded word must decode");
            assert_eq!(back, i, "opcode {:?} word {w:#010x}", op);
        }
        covered += 1;
    }
    assert_eq!(covered, 42, "the paper's ISA has exactly 42 instructions");
}

#[test]
fn category_budgets_hold_under_roundtrip() {
    // the roundtrip must preserve the paper's 22/6/2/12 category split
    let mut rng = Rng::new(0xCA7_E60);
    let mut counts = std::collections::HashMap::new();
    for op in Opcode::all() {
        let i = random_instr(op, &mut rng);
        let back = encode::decode(encode::encode(&i).unwrap()).unwrap();
        *counts.entry(back.op.category()).or_insert(0usize) += 1;
    }
    assert_eq!(counts[&Category::Interconnect], 22);
    assert_eq!(counts[&Category::Branch], 6);
    assert_eq!(counts[&Category::Vector], 2);
    assert_eq!(counts[&Category::MemReg], 12);
}

#[test]
fn operand_field_extremes_roundtrip() {
    for op in Opcode::all() {
        for (tile, a, b, imm) in [
            (0u8, 0u8, 0u8, 0i16),
            (63, 31, 31, 511),
            (63, 0, 31, -512),
            (0, 31, 0, -1),
        ] {
            let i = Instr { op, tile, a, b, imm };
            let w = encode::encode(&i).unwrap();
            assert_eq!(encode::decode(w).unwrap(), i);
        }
    }
}

#[test]
fn malformed_words_error_instead_of_panicking() {
    // opcodes 42..64 are unassigned: every word carrying one must decode to
    // a structured error (the 6-bit opcode field is the top of the word)
    let mut rng = Rng::new(0xDEAD_C0DE);
    for bad_op in 42u32..64 {
        for _ in 0..CASES_PER_OPCODE {
            let w = (bad_op << 26) | (rng.next_u64() as u32 & 0x03FF_FFFF);
            let err = encode::decode(w).expect_err("unassigned opcode must not decode");
            assert!(
                matches!(err, jit_overlay::Error::Program(_)),
                "want Program error, got {err:?}"
            );
        }
    }
}

#[test]
fn arbitrary_words_decode_or_error_but_reencode_faithfully() {
    // fuzz the full 32-bit space: decoding either fails cleanly or yields
    // an instruction that re-encodes to the exact same word
    let mut rng = Rng::new(0xF022);
    for _ in 0..5_000 {
        let w = rng.next_u64() as u32;
        match encode::decode(w) {
            Err(_) => {} // bad opcode — structured rejection is legal
            Ok(i) => assert_eq!(encode::encode(&i).unwrap(), w, "word {w:#010x}"),
        }
    }
}

#[test]
fn out_of_range_operands_rejected_for_every_opcode() {
    for op in Opcode::all() {
        let base = Instr { op, tile: 0, a: 0, b: 0, imm: 0 };
        assert!(encode::encode(&Instr { tile: 64, ..base }).is_err(), "{op:?} tile");
        assert!(encode::encode(&Instr { a: 32, ..base }).is_err(), "{op:?} reg a");
        assert!(encode::encode(&Instr { b: 32, ..base }).is_err(), "{op:?} reg b");
        assert!(encode::encode(&Instr { imm: 512, ..base }).is_err(), "{op:?} imm hi");
        assert!(encode::encode(&Instr { imm: -513, ..base }).is_err(), "{op:?} imm lo");
    }
}

#[test]
fn batch_codec_roundtrips_random_programs() {
    let mut rng = Rng::new(0xBA7C4);
    for _ in 0..50 {
        let len = 1 + rng.below(64);
        let prog: Vec<Instr> = (0..len)
            .map(|_| {
                let op = Opcode::from_u8(rng.below(42) as u8).unwrap();
                random_instr(op, &mut rng)
            })
            .collect();
        let words = encode::encode_all(&prog).unwrap();
        assert_eq!(words.len(), prog.len());
        assert_eq!(encode::decode_all(&words).unwrap(), prog);
    }
}
