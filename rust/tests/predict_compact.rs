//! Predictive reconfiguration + online defragmentation, end to end.
//!
//! The contract, proven deterministically where the layer allows it:
//!
//! * **flags off is the paper's baseline, bit for bit** — a coordinator
//!   with `predict`/`compact` off, even one whose idle loop hammers
//!   `maintain()`, produces byte-identical outputs and identical metrics
//!   to one that never heard of maintenance;
//! * **acceptance**: on a seeded repeated-composition stream (a cycle of
//!   four 3-stage chains that cannot all co-reside), `--predict on` scores
//!   `prefetch_hits > 0` and pays *strictly fewer* critical-path PR
//!   downloads than `--predict off`, with bit-identical outputs;
//! * **compaction** strictly reduces live mean internal fragmentation or
//!   does nothing, and a compacted fabric still serves full cache hits;
//! * the pool wires the flags through: a real `WorkerPool` with
//!   `predict: true` prefetches in its drain-window idle gaps and folds
//!   the speculative counters into the shutdown aggregate.

use jit_overlay::coordinator::{Coordinator, Request};
use jit_overlay::patterns::Composition;
use jit_overlay::testkit::fingerprint;
use jit_overlay::workload;
use jit_overlay::{OverlayConfig, ServiceConfig};

/// The seeded acceptance stream: a cycle of four distinct 3-stage
/// small-operator chains. Three of them fill the 9-tile fabric; the fourth
/// forces the whole-fabric eviction, so the reactive baseline settles into
/// a steady state that re-downloads two of the chains every cycle.
fn cycle_compositions() -> Vec<Composition> {
    use jit_overlay::bitstream::OperatorKind::*;
    vec![
        Composition::chain(&[Neg, Abs, Square], 256).unwrap(),
        Composition::chain(&[Abs, Neg, Relu], 256).unwrap(),
        Composition::chain(&[Square, Relu, Neg], 256).unwrap(),
        Composition::chain(&[Relu, Square, Abs], 256).unwrap(),
    ]
}

fn cycle_request(comp: &Composition, seed: u64) -> Request {
    let inputs = (0..comp.inputs)
        .map(|c| workload::vector(256, seed + c as u64, -2.0, 2.0))
        .collect();
    Request::dynamic(comp.clone(), inputs)
}

/// Serve `cycles` passes over the cycle stream, running maintenance to
/// quiescence before every submit — exactly what the pool's idle loop does
/// between arrivals. Returns the coordinator and every output fingerprint.
fn run_cycles(predict: bool, cycles: usize) -> (Coordinator, Vec<Vec<u32>>) {
    let comps = cycle_compositions();
    let mut c = Coordinator::new(OverlayConfig::default()).unwrap();
    c.set_predict(predict);
    let mut outs = Vec::new();
    for cycle in 0..cycles {
        for comp in &comps {
            while c.maintain() {}
            let resp = c.submit(&cycle_request(comp, cycle as u64)).unwrap();
            outs.push(fingerprint(&resp.run.output));
        }
    }
    (c, outs)
}

/// Satellite: with both flags off (the default), a maintenance-hammering
/// run is bit-identical — outputs and the full metrics record — to a run
/// that never calls `maintain()` at all, over a seeded mixed stream.
#[test]
fn flags_off_maintenance_is_bit_identical_to_baseline() {
    let comps = workload::mixed_compositions(24, 512, 0xBEEF);
    let reqs: Vec<Request> = comps
        .into_iter()
        .enumerate()
        .map(|(k, comp)| {
            let inputs = workload::request_inputs(&comp, k as u64);
            Request::dynamic(comp, inputs)
        })
        .collect();
    let mut baseline = Coordinator::new(OverlayConfig::default()).unwrap();
    let mut hammered = Coordinator::new(OverlayConfig::default()).unwrap();
    for r in &reqs {
        let a = baseline.submit(r).unwrap();
        assert!(!hammered.maintain());
        let b = hammered.submit(r).unwrap();
        assert!(!hammered.maintain());
        assert_eq!(fingerprint(&a.run.output), fingerprint(&b.run.output));
    }
    assert_eq!(baseline.metrics, hammered.metrics, "flags off: not one counter moves");
    assert_eq!(hammered.metrics.prefetch_hits, 0);
    assert_eq!(hammered.metrics.migrations, 0);
}

/// Acceptance: on the seeded repeated-composition cycle, prediction scores
/// hits and strictly cuts critical-path PR downloads — without changing a
/// single output bit.
#[test]
fn predict_on_cuts_critical_path_downloads_on_the_cycle_stream() {
    let (off, outs_off) = run_cycles(false, 6);
    let (on, outs_on) = run_cycles(true, 6);
    assert_eq!(outs_off, outs_on, "speculation never changes results");
    assert_eq!(off.metrics.requests, on.metrics.requests);
    assert!(on.metrics.prefetch_hits > 0, "the cycle is learnable");
    assert!(
        on.metrics.pr_downloads < off.metrics.pr_downloads,
        "prefetch must shorten the critical path: on={} off={}",
        on.metrics.pr_downloads,
        off.metrics.pr_downloads
    );
    assert_eq!(on.metrics.prefetch_wasted, 0, "a deterministic cycle never mispredicts");
    // the conservation law survives speculation: prefetch bills no
    // request-path counter
    for m in [&off.metrics, &on.metrics] {
        assert_eq!(
            m.cache_hits + m.placement_respecializations + m.jit_compiles,
            m.requests
        );
    }
}

/// Compaction on the cycle's warmup state is either a strict improvement
/// or a no-op — never a lateral move — and always settles.
#[test]
fn compaction_strictly_improves_or_does_nothing() {
    // 6-stage chain: last stage spills onto Large tile 3 → improvement
    use jit_overlay::bitstream::OperatorKind::*;
    let mut c = Coordinator::new(OverlayConfig::default()).unwrap();
    c.set_compact(true);
    let spill = Composition::chain(&[Neg, Abs, Square, Relu, Neg, Abs], 256).unwrap();
    c.submit(&cycle_request(&spill, 1)).unwrap();
    let (before, after) = c.compact_once().expect("oversized resident must migrate");
    assert!(after < before, "compaction must strictly reduce mean_internal");
    assert!(c.compact_once().is_none(), "and then settle");

    // 3-stage chain: all residents already on Small tiles → no-op
    let mut tidy = Coordinator::new(OverlayConfig::default()).unwrap();
    tidy.set_compact(true);
    tidy.submit(&cycle_request(&cycle_compositions()[0], 1)).unwrap();
    assert!(tidy.compact_once().is_none());
    assert_eq!(tidy.metrics.migrations, 0);
}

/// The pool plumbing: a 1-worker `WorkerPool` with `predict: true` learns a
/// strict alternation in its idle windows and folds `prefetch_hits` into
/// the shutdown aggregate. (Idle windows are wall-clock here, so the test
/// only asserts that hits happened, not how many.)
#[test]
fn pool_prefetches_in_idle_windows_and_aggregates_hits() {
    use jit_overlay::coordinator::WorkerPool;
    let service = ServiceConfig {
        predict: true,
        ..ServiceConfig::with_workers(1).without_stealing()
    };
    let pool = WorkerPool::new(OverlayConfig::default(), service).unwrap();
    let comps = cycle_compositions();
    let (a, b) = (&comps[0], &comps[1]);
    // closed-loop warmup: both transitions seen twice
    for k in 0..3u64 {
        for comp in [a, b] {
            let rx = pool.submit(cycle_request(comp, k)).unwrap();
            rx.recv().unwrap().unwrap();
        }
    }
    // now every pause is a quiet window with a confident prediction
    let mut hit_window = false;
    for k in 0..10u64 {
        std::thread::sleep(std::time::Duration::from_millis(30));
        for comp in [a, b] {
            let rx = pool.submit(cycle_request(comp, 100 + k)).unwrap();
            rx.recv().unwrap().unwrap();
        }
        if pool.metrics.snapshot().prefetch_hits > 0 {
            hit_window = true;
            break;
        }
    }
    let report = pool.shutdown();
    assert!(
        hit_window || report.aggregate.prefetch_hits > 0,
        "an idle 1-worker pool with predict on must score prefetch hits"
    );
    assert_eq!(report.aggregate.prefetch_wasted + report.aggregate.migrations, 0);
}
