//! Quarantine-aware placement properties, seeded by `$JIT_OVERLAY_SEED`
//! (the CI seed matrix — see [`jit_overlay::workload::env_seed`]).
//!
//! Two invariants ride every seed:
//!
//! * the dynamic placer never lands an assignment on a quarantined tile —
//!   for any quarantined subset, a compilation either places entirely on
//!   live tiles or fails with a capacity-class error (never a wrong
//!   placement, never a crash);
//! * quarantining k distinct tiles degrades fabric capacity by exactly k
//!   free tiles, and a full power-cycle reset does not heal dead silicon.

use jit_overlay::bitstream::OperatorKind;
use jit_overlay::exec::Engine;
use jit_overlay::jit::Jit;
use jit_overlay::patterns::Composition;
use jit_overlay::workload::{env_seed, Rng};
use jit_overlay::OverlayConfig;

/// A quarantined random subset never hosts an assignment: whatever the
/// placer can still place lands entirely on live tiles, and what it
/// cannot place fails with a capacity error the recovery ladder can act
/// on (re-place / CPU floor) — never a plan touching dead silicon.
#[test]
fn placement_never_lands_on_a_quarantined_tile() {
    let mut rng = Rng::new(env_seed(0xDEAD) ^ 0x51CA);
    let comps = [
        Composition::map(OperatorKind::Abs, 128),
        Composition::vmul_reduce(128),
        Composition::map(OperatorKind::Sqrt, 128),
    ];
    for _trial in 0..20 {
        let mut engine = Engine::new(OverlayConfig::default()).unwrap();
        let tiles = engine.fabric.tiles.len();
        let k = 1 + rng.below(4);
        let mut dead = std::collections::HashSet::new();
        while dead.len() < k {
            let t = rng.below(tiles);
            if dead.insert(t) {
                assert!(engine.fabric.quarantine(t), "first quarantine of {t} must bill");
            }
        }
        for comp in &comps {
            match Jit.compile(&engine.fabric, &engine.lib, comp) {
                Ok(acc) => {
                    for a in &acc.placement().assignments {
                        assert!(
                            !dead.contains(&a.tile),
                            "stage placed on quarantined tile {} (dead set {dead:?})",
                            a.tile
                        );
                    }
                }
                Err(e) => assert!(
                    e.is_capacity(),
                    "infeasible placement must be a capacity error, got {e}"
                ),
            }
        }
    }
}

/// Quarantining k distinct tiles removes exactly k tiles from the free
/// pool — no more (no collateral eviction of live tiles), no less (the
/// dead tile really is withdrawn) — and re-quarantining is idempotent.
#[test]
fn quarantine_degrades_capacity_by_exactly_k() {
    let mut rng = Rng::new(env_seed(0xDEAD) ^ 0xCAFE);
    let mut engine = Engine::new(OverlayConfig::default()).unwrap();
    let tiles = engine.fabric.tiles.len();
    assert_eq!(engine.fabric.free_tiles().len(), tiles, "fresh fabric is fully free");
    let mut dead = Vec::new();
    for k in 1..=4usize {
        let t = loop {
            let t = rng.below(tiles);
            if !dead.contains(&t) {
                break t;
            }
        };
        assert!(engine.fabric.quarantine(t));
        assert!(!engine.fabric.quarantine(t), "re-quarantine must not double-bill");
        dead.push(t);
        assert_eq!(engine.fabric.quarantined_tiles(), k);
        assert_eq!(engine.fabric.free_tiles().len(), tiles - k, "capacity down by exactly k");
    }
    // a power cycle clears residency, not quarantine: dead silicon stays dead
    engine.fabric.reset_full();
    assert_eq!(engine.fabric.quarantined_tiles(), 4);
    assert_eq!(engine.fabric.free_tiles().len(), tiles - 4);
    // out-of-range quarantine is a no-op, not a panic
    assert!(!engine.fabric.quarantine(tiles + 1));
}
