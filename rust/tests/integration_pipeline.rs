//! Integration: full JIT → PR → controller execution across the pattern
//! library, cross-checked against the scalar CPU reference.

use jit_overlay::bitstream::OperatorKind;
use jit_overlay::coordinator::{Coordinator, Request};
use jit_overlay::exec::{cpu, Engine, Value};
use jit_overlay::jit::Jit;
use jit_overlay::patterns::Composition;
use jit_overlay::place::StaticScenario;
use jit_overlay::timing::Target;
use jit_overlay::{workload, OverlayConfig};

fn engine() -> Engine {
    Engine::new(OverlayConfig::default()).unwrap()
}

fn agree(a: &Value, b: &Value, tol: f32) -> bool {
    match (a, b) {
        (Value::Scalar(x), Value::Scalar(y)) => {
            (x - y).abs() <= tol * (1.0 + y.abs())
        }
        (Value::Vector(x), Value::Vector(y)) => {
            x.len() == y.len()
                && x.iter()
                    .zip(y)
                    .all(|(p, q)| (p - q).abs() <= tol * (1.0 + q.abs()))
        }
        _ => false,
    }
}

fn check_overlay_matches_cpu(comp: Composition, seeds: &[u64]) {
    let mut e = engine();
    let acc = Jit.compile(&e.fabric, &e.lib, &comp).unwrap();
    for &seed in seeds {
        let inputs: Vec<Vec<f32>> = (0..comp.inputs)
            .map(|k| workload::vector(comp.n, seed + k as u64, 0.1, 2.0))
            .collect();
        let overlay = e
            .run(&acc, &inputs, Target::DynamicOverlay)
            .unwrap()
            .output;
        let reference = cpu::eval(&comp, &inputs).unwrap();
        assert!(
            agree(&overlay, &reference, 1e-4),
            "mismatch for {comp:?} seed {seed}"
        );
    }
}

#[test]
fn vmul_reduce_matches_cpu_across_sizes() {
    for n in [256, 1024, 4096, 8192] {
        check_overlay_matches_cpu(Composition::vmul_reduce(n), &[1, 2]);
    }
}

#[test]
fn map_every_unary_op_matches_cpu() {
    use OperatorKind::*;
    for op in [Neg, Abs, Square, Relu, Sqrt, Sin, Cos, Log, Exp, Tanh, Recip] {
        check_overlay_matches_cpu(Composition::map(op, 512), &[3]);
    }
}

#[test]
fn chains_match_cpu() {
    use OperatorKind::*;
    for ops in [vec![Abs, Sqrt], vec![Square, Neg, Abs], vec![Relu, Sqrt, Log]] {
        check_overlay_matches_cpu(Composition::chain(&ops, 1024).unwrap(), &[5, 6]);
    }
}

#[test]
fn filter_reduce_matches_cpu() {
    for t in [-0.5, 0.5, 1.0, 5.0] {
        check_overlay_matches_cpu(Composition::filter_reduce(t, 2048), &[7]);
    }
}

#[test]
fn axpy_matches_cpu() {
    for alpha in [-1.5, 0.0, 2.0] {
        check_overlay_matches_cpu(Composition::axpy(alpha, 1024), &[9]);
    }
}

#[test]
fn branch_matches_cpu() {
    use OperatorKind::*;
    for (t, a, b) in [(0.5, Sqrt, Square), (1.0, Relu, Neg), (0.2, Log, Abs)] {
        check_overlay_matches_cpu(Composition::branch(t, a, b, 512), &[11]);
    }
}

#[test]
fn all_targets_agree_on_values() {
    // static overlay / ARM / HLS report different *times* but must produce
    // the same numbers.
    let comp = Composition::vmul_reduce(1024);
    let mut e = engine();
    let acc = Jit.compile(&e.fabric, &e.lib, &comp).unwrap();
    let a = workload::vector(1024, 21, -1.0, 1.0);
    let b = workload::vector(1024, 22, -1.0, 1.0);
    let mut values = Vec::new();
    for t in Target::ALL {
        let v = e
            .run(&acc, &[a.clone(), b.clone()], t)
            .unwrap()
            .output
            .as_scalar()
            .unwrap();
        values.push((t.name(), v));
    }
    let base = values[0].1;
    for (name, v) in &values {
        assert!(
            (v - base).abs() <= 1e-2 + base.abs() * 1e-4,
            "{name}: {v} vs {base}"
        );
    }
}

#[test]
fn fig2_shape_pass_through_monotone() {
    let comp = Composition::vmul_reduce(4096);
    let mut e = engine();
    let acc = Jit.compile(&e.fabric, &e.lib, &comp).unwrap();
    let a = workload::vector(4096, 1, -1.0, 1.0);
    let b = workload::vector(4096, 2, -1.0, 1.0);
    let mut last = 0.0;
    for s in StaticScenario::ALL {
        let t = e
            .run(&acc, &[a.clone(), b.clone()], Target::StaticOverlay(s))
            .unwrap()
            .timing
            .total();
        assert!(t > last, "{s:?} not slower than previous");
        last = t;
    }
}

#[test]
fn fig3_shape_full_ordering() {
    let comp = Composition::vmul_reduce(4096);
    let mut e = engine();
    let acc = Jit.compile(&e.fabric, &e.lib, &comp).unwrap();
    let a = workload::vector(4096, 1, -1.0, 1.0);
    let b = workload::vector(4096, 2, -1.0, 1.0);
    let time = |e: &mut Engine, t| {
        e.run(&acc, &[a.clone(), b.clone()], t).unwrap().timing.total()
    };
    let dynamic = time(&mut e, Target::DynamicOverlay);
    let s1 = time(&mut e, Target::StaticOverlay(StaticScenario::S1));
    let s3 = time(&mut e, Target::StaticOverlay(StaticScenario::S3));
    let arm = time(&mut e, Target::ArmSoftware);
    let hls = time(&mut e, Target::HlsCustom);
    // paper shape: dynamic ≤ s1 < s3 < arm; hls within 2× of dynamic
    assert!(dynamic <= s1 * 1.05);
    assert!(s1 < s3);
    assert!(s3 < arm);
    assert!(hls / dynamic < 2.0 && dynamic / hls < 2.0);
}

#[test]
fn pr_overhead_amortizes_with_repeat_requests() {
    let mut c = Coordinator::new(OverlayConfig::default()).unwrap();
    let n = 1024;
    let req = Request::dynamic(
        Composition::vmul_reduce(n),
        vec![workload::vector(n, 1, 0.0, 1.0), workload::vector(n, 2, 0.0, 1.0)],
    );
    let first = c.submit(&req).unwrap();
    assert!(first.run.reconfig.unwrap().seconds > 0.0);
    for _ in 0..5 {
        let r = c.submit(&req).unwrap();
        assert_eq!(r.run.reconfig.unwrap().downloads, 0, "residency cache must hit");
    }
    assert_eq!(c.metrics.pr_downloads, 2);
}

#[test]
fn controller_program_uses_all_isa_categories() {
    let e = engine();
    let acc = Jit
        .compile(&e.fabric, &e.lib, &Composition::vmul_reduce(4096))
        .unwrap();
    let mix = acc.program().category_mix();
    assert!(mix.interconnect > 0);
    assert!(mix.branch > 0);
    assert!(mix.vector > 0);
    assert!(mix.mem_reg > 0);
}

#[test]
fn stats_count_expected_dma_words() {
    let n = 2048;
    let mut e = engine();
    let acc = Jit.compile(&e.fabric, &e.lib, &Composition::vmul_reduce(n)).unwrap();
    let a = workload::vector(n, 1, 0.0, 1.0);
    let b = workload::vector(n, 2, 0.0, 1.0);
    let stats = e
        .run(&acc, &[a, b], Target::DynamicOverlay)
        .unwrap()
        .stats
        .unwrap();
    // 2n words in + 1 word (scalar result) out
    assert_eq!(stats.dma_words, 2 * n as u64 + 1);
    // every element passes the mul tile and the acc tile
    assert_eq!(stats.elements, 2 * n as u64);
}

// ---------------------------------------------------------------------------
// Scaling beyond the paper's 3×3: "the number of tiles can be set based on
// the resource capabilities of each FPGA".
// ---------------------------------------------------------------------------

fn engine_with_mesh(rows: usize, cols: usize) -> Engine {
    let mut cfg = OverlayConfig::default();
    cfg.rows = rows;
    cfg.cols = cols;
    Engine::new(cfg).unwrap()
}

#[test]
fn five_by_five_fabric_hosts_deep_pipelines() {
    use OperatorKind::*;
    let mut e = engine_with_mesh(5, 5);
    // 8-stage pipeline — impossible on 3×3 once large-class stages are
    // interleaved, comfortable on 5×5 (6 large tiles at 1/4 sizing).
    let ops = [Abs, Square, Sqrt, Relu, Exp, Neg, Abs, Square];
    let comp = Composition::chain(&ops, 2048).unwrap();
    let acc = Jit.compile(&e.fabric, &e.lib, &comp).unwrap();
    assert!(acc.placement().is_injective());
    let x = workload::vector(2048, 3, 0.1, 1.5);
    let got = e.run(&acc, &[x.clone()], Target::DynamicOverlay).unwrap().output;
    let want = cpu::eval(&comp, &[x]).unwrap();
    let (g, w) = (got.as_vector().unwrap(), want.as_vector().unwrap());
    for i in 0..2048 {
        assert!((g[i] - w[i]).abs() < 1e-3 * (1.0 + w[i].abs()), "i={i}");
    }
}

#[test]
fn bigger_fabric_shrinks_per_pipeline_reconfig_share() {
    // more tiles ⇒ more co-resident accelerators ⇒ fewer capacity evictions.
    let mut big = Coordinator::new({
        let mut c = OverlayConfig::default();
        c.rows = 4;
        c.cols = 4;
        c
    })
    .unwrap();
    let n = 512;
    use OperatorKind::*;
    let reqs = [
        Composition::vmul_reduce(n),
        Composition::chain(&[Abs, Sqrt], n).unwrap(),
        Composition::filter_reduce(0.5, n),
        Composition::axpy(2.0, n),
    ];
    for _ in 0..3 {
        for comp in &reqs {
            let inputs: Vec<Vec<f32>> = (0..comp.inputs)
                .map(|k| workload::vector(n, k as u64, 0.1, 1.0))
                .collect();
            big.submit(&Request::dynamic(comp.clone(), inputs)).unwrap();
        }
    }
    // on 16 tiles all four accelerators co-reside: downloads happen once.
    assert_eq!(big.metrics.evictions, 0);
    assert_eq!(big.metrics.pr_downloads, 2 + 2 + 2 + 2);
}

#[test]
fn wide_mesh_routes_long_pipelines_contiguously() {
    use OperatorKind::*;
    let e = engine_with_mesh(2, 8);
    let ops = vec![Abs, Neg, Square, Relu, Abs, Neg];
    let comp = Composition::chain(&ops, 256).unwrap();
    let acc = Jit.compile(&e.fabric, &e.lib, &comp).unwrap();
    assert_eq!(acc.total_hops(), 0, "snake placement must stay contiguous");
}

#[test]
fn one_by_n_mesh_still_works() {
    use OperatorKind::*;
    let mut e = engine_with_mesh(1, 6);
    let comp = Composition::chain(&[Abs, Square, Neg], 128).unwrap();
    let acc = Jit.compile(&e.fabric, &e.lib, &comp).unwrap();
    let x = workload::vector(128, 9, -1.0, 1.0);
    let got = e.run(&acc, &[x.clone()], Target::DynamicOverlay).unwrap().output;
    let want = cpu::eval(&comp, &[x]).unwrap();
    assert_eq!(
        got.as_vector().unwrap().len(),
        want.as_vector().unwrap().len()
    );
}
