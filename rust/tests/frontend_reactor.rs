//! Deterministic front-end tests: the reactor session layer proven under
//! virtual time (no sleeps, no wall clock).
//!
//! Technique: `testkit::ScriptedEngine` replaces the worker pool with a
//! virtual-clock backend whose completion *order* is an exact function of
//! a latency script, and `Reactor::poll_once` is stepped by the test
//! thread itself — the whole pipeline is single-threaded, so ordering,
//! fairness and starvation-freedom are checked as exact assertions, not
//! sampled from one lucky scheduling. `testkit::drive` bounds liveness:
//! a starved session fails the poll budget instead of hanging CI.
//!
//! The last two tests run the same invariants against the real
//! `WorkerPool` — through the reactor front end and through the
//! thread-per-client path (`--frontend reactor|threads`) — where
//! completion order is genuinely nondeterministic but the properties
//! (exactly one reply, in-session FIFO, nothing after close) must hold
//! for every interleaving.

use std::sync::Arc;

use jit_overlay::coordinator::frontend::{Frontend, Reactor, SessionHandle, SessionState};
use jit_overlay::coordinator::{AtomicMetrics, Request, WorkerPool};
use jit_overlay::exec::cpu::{self, Value};
use jit_overlay::patterns::Composition;
use jit_overlay::testkit::{drive, ScriptedEngine};
use jit_overlay::workload::{self, Rng};
use jit_overlay::{FrontendConfig, OverlayConfig, ServiceConfig};

/// A vmul request whose scalar result fingerprints `seed` — reply/request
/// pairing is then value-checkable.
fn vmul_req(n: usize, seed: u64) -> Request {
    Request::dynamic(
        Composition::vmul_reduce(n),
        vec![workload::vector(n, seed, 0.1, 1.0), workload::vector(n, seed + 1, 0.1, 1.0)],
    )
}

fn expected(req: &Request) -> Value {
    cpu::eval(&req.comp, &req.inputs).unwrap()
}

fn agree(a: &Value, b: &Value) -> bool {
    const TOL: f32 = 1e-3;
    match (a, b) {
        (Value::Scalar(x), Value::Scalar(y)) => (x - y).abs() <= TOL * (1.0 + y.abs()),
        (Value::Vector(x), Value::Vector(y)) => {
            x.len() == y.len()
                && x.iter().zip(y).all(|(p, q)| (p - q).abs() <= TOL * (1.0 + q.abs()))
        }
        _ => false,
    }
}

type ScriptedFront = (
    Frontend<ScriptedEngine>,
    Reactor<ScriptedEngine>,
    Arc<ScriptedEngine>,
    Arc<AtomicMetrics>,
);

fn scripted_front(
    capacity: usize,
    cfg: FrontendConfig,
    latency: impl FnMut(u64, &Request) -> u64 + Send + 'static,
) -> ScriptedFront {
    let engine =
        Arc::new(ScriptedEngine::new(OverlayConfig::default(), capacity, latency).unwrap());
    let metrics = Arc::new(AtomicMetrics::default());
    let fe = Frontend::new(engine.clone(), cfg, metrics.clone()).unwrap();
    let reactor = fe.reactor(0);
    (fe, reactor, engine, metrics)
}

/// The session walks Accepting → Queued → Dispatched → Replying-implied →
/// Accepting → Closed, one observable transition per step.
#[test]
fn session_walks_the_state_machine() {
    let cfg = FrontendConfig::default();
    let (fe, reactor, engine, metrics) = scripted_front(8, cfg, |_, _| 10);
    let s = fe.open_session();
    assert_eq!(s.state(), SessionState::Accepting);

    let req = vmul_req(128, 7);
    let want = expected(&req);
    s.submit(req).unwrap();
    assert_eq!(s.state(), SessionState::Queued);

    let stats = reactor.poll_once();
    assert_eq!(stats.admitted, 1);
    assert_eq!(s.state(), SessionState::Dispatched);
    assert_eq!(engine.in_service(), 1);

    assert!(engine.advance_next());
    assert_eq!(engine.now(), 10, "virtual time, not wall time");
    let stats = reactor.poll_once();
    assert_eq!((stats.completions, stats.delivered), (1, 1));
    // gap-free completion delivers immediately: Replying collapses back to
    // Accepting within the same poll
    assert_eq!(s.state(), SessionState::Accepting);
    let got = s.recv().unwrap();
    assert!(agree(&got.run.output, &want));

    s.close();
    assert_eq!(s.state(), SessionState::Closed);
    let m = metrics.snapshot();
    assert_eq!((m.sessions, m.completions), (1, 1));
    assert!(m.reactor_polls >= 2);
}

/// Completions scripted in *reverse* submission order must still be
/// delivered to the client in submission order — the reorder buffer at
/// work, observable only because completion order is deterministic.
#[test]
fn in_session_fifo_holds_under_reversed_completions() {
    const K: u64 = 4;
    let cfg = FrontendConfig {
        inflight_per_session: K as usize,
        ..FrontendConfig::default()
    };
    // dispatch i completes at tick 100 - 20*i: strictly reversed
    let (fe, reactor, engine, _) = scripted_front(8, cfg, |i, _| 100 - 20 * i);
    let s = fe.open_session();
    let wants: Vec<Value> = (0..K)
        .map(|k| {
            let req = vmul_req(128, 1000 + k);
            let want = expected(&req);
            s.submit(req).unwrap();
            want
        })
        .collect();

    let stats = reactor.poll_once();
    assert_eq!(stats.admitted, K as usize, "all K fit the in-flight budget");
    // complete everything (reverse order), then poll once: the reactor must
    // hold the early completions until the gap (seq 0, slowest) fills
    for _ in 0..K {
        assert!(engine.advance_next());
    }
    let stats = reactor.poll_once();
    assert_eq!(stats.completions, K as usize);
    assert_eq!(stats.delivered, K as usize, "gap filled: everything flushes in order");
    for want in &wants {
        let got = s.recv().unwrap();
        assert!(agree(&got.run.output, want), "replies out of submission order");
    }
    assert!(s.try_recv().is_none());
    assert!(reactor.poll_once().idle());
}

/// A partially-completed window stays buffered: with the *first* request
/// slowest, nothing is deliverable until it lands, and the session reads
/// `Replying` while the buffer holds out-of-order completions.
#[test]
fn replying_state_buffers_until_the_gap_fills() {
    let cfg = FrontendConfig { inflight_per_session: 3, ..FrontendConfig::default() };
    let (fe, reactor, engine, _) = scripted_front(8, cfg, |i, _| if i == 0 { 50 } else { i });
    let s = fe.open_session();
    for k in 0..3 {
        s.submit(vmul_req(128, 2000 + k)).unwrap();
    }
    assert_eq!(reactor.poll_once().admitted, 3);
    // two fast completions land; the slow head (seq 0) is still in service
    assert!(engine.advance_next());
    assert!(engine.advance_next());
    let stats = reactor.poll_once();
    assert_eq!((stats.completions, stats.delivered), (2, 0));
    assert_eq!(s.state(), SessionState::Replying);
    assert!(s.try_recv().is_none(), "no out-of-order delivery, ever");
    // the gap fills: all three flush, in order
    assert!(engine.advance_next());
    let stats = reactor.poll_once();
    assert_eq!((stats.completions, stats.delivered), (1, 3));
    assert_eq!(s.state(), SessionState::Accepting);
}

/// Starvation-freedom under an adversarial session mix: one flooding
/// session vs. two light ones, with the backend capacity *and* the
/// front-end budget far below the flood. Fairness rotation must finish
/// the light sessions long before the flood drains, and every session
/// completes within the liveness budget.
#[test]
fn starvation_freedom_under_adversarial_mix() {
    const HEAVY: u64 = 40;
    const LIGHT: u64 = 3;
    let cfg = FrontendConfig {
        inflight_per_session: 2,
        max_inflight: 4,
        ..FrontendConfig::default()
    };
    let (fe, reactor, engine, metrics) = scripted_front(4, cfg, |_, _| 3);
    let heavy = fe.open_session();
    let light_a = fe.open_session();
    let light_b = fe.open_session();
    // the flood is fully queued before the light sessions even submit —
    // the worst arrival order for them
    for k in 0..HEAVY {
        heavy.submit(vmul_req(128, 10_000 + k)).unwrap();
    }
    for k in 0..LIGHT {
        light_a.submit(vmul_req(128, 20_000 + k)).unwrap();
        light_b.submit(vmul_req(128, 30_000 + k)).unwrap();
    }

    let mut polls = 0usize;
    let mut heavy_done = None;
    let mut light_done = None;
    let (mut got_heavy, mut got_light) = (0u64, 0u64);
    while heavy_done.is_none() || light_done.is_none() {
        reactor.poll_once();
        polls += 1;
        assert!(polls < 2_000, "front end failed to drain the adversarial mix");
        engine.advance_next();
        while heavy.try_recv().is_some() {
            got_heavy += 1;
        }
        while light_a.try_recv().is_some() || light_b.try_recv().is_some() {
            got_light += 1;
        }
        if got_light == 2 * LIGHT && light_done.is_none() {
            light_done = Some(polls);
        }
        if got_heavy == HEAVY && heavy_done.is_none() {
            heavy_done = Some(polls);
        }
    }
    let (light_done, heavy_done) = (light_done.unwrap(), heavy_done.unwrap());
    assert!(
        light_done < heavy_done / 2,
        "light sessions starved: done at poll {light_done} vs heavy at {heavy_done}"
    );
    // the caps were genuinely binding: admission pressure was recorded and
    // the backend never saw more than the front-end-wide budget
    assert!(metrics.snapshot().admission_rejections > 0);
    assert!(engine.high_water() <= 4);
}

/// Seeded property, ≥ 4 seeds per run: every submitted request gets
/// exactly one reply, in-session FIFO order holds, and no reply is
/// delivered after session close. `$JIT_OVERLAY_SEED` shifts the seed
/// universe (the CI matrix); each universe is fully deterministic.
#[test]
fn exactly_one_reply_in_order_over_seeds() {
    let base = workload::env_seed(0);
    for round in 0..4u64 {
        let mut rng = Rng::new(0xF0_0D ^ base.wrapping_mul(0x9E37).wrapping_add(round));
        let capacity = 2 + rng.below(5);
        let cfg = FrontendConfig {
            inflight_per_session: 1 + rng.below(4),
            max_inflight: 2 + rng.below(8),
            ..FrontendConfig::default()
        };
        let max_lat = 1 + rng.below(20) as u64;
        let mut lat_rng = Rng::new(rng.next_u64());
        let (fe, reactor, engine, metrics) = scripted_front(capacity, cfg, move |_, _| {
            lat_rng.below(max_lat as usize) as u64
        });

        let n_sessions = 2 + rng.below(4);
        struct Script {
            handle: SessionHandle,
            wants: Vec<Option<Value>>, // None = request built to fail
            /// Close once at least this many replies were received and the
            /// reply buffer is drained (None = drain everything).
            close_cue: Option<usize>,
            /// Replies received when the close actually fired.
            closed_at: Option<usize>,
            received: usize,
        }
        let mut scripts: Vec<Script> = (0..n_sessions)
            .map(|si| {
                let handle = fe.open_session();
                let count = rng.below(10);
                let wants = (0..count)
                    .map(|k| {
                        if rng.below(12) == 0 {
                            // malformed: wrong channel count → its one
                            // reply is an error, still in order
                            let comp = Composition::vmul_reduce(64);
                            handle
                                .submit(Request::dynamic(comp, vec![vec![0.0; 64]]))
                                .unwrap();
                            None
                        } else {
                            let req = vmul_req(64, (si as u64) * 1000 + k as u64);
                            let want = expected(&req);
                            handle.submit(req).unwrap();
                            Some(want)
                        }
                    })
                    .collect::<Vec<_>>();
                let close_cue = (rng.below(4) == 0 && count > 0).then(|| rng.below(count));
                Script { handle, wants, close_cue, closed_at: None, received: 0 }
            })
            .collect();

        // drive to quiescence, executing each script's close at its cue.
        // Between polls nothing runs concurrently, so a close always
        // happens with the reply buffer drained — the cut is exact.
        let mut steps = 0usize;
        loop {
            let stats = reactor.poll_once();
            steps += 1;
            assert!(steps < 10_000, "round {round}: failed to quiesce");
            for s in scripts.iter_mut() {
                while let Some(got) = s.handle.try_recv() {
                    assert!(
                        s.closed_at.is_none(),
                        "round {round}: reply delivered after session close"
                    );
                    let want = &s.wants[s.received];
                    match (got, want) {
                        (Ok(resp), Some(w)) => assert!(
                            agree(&resp.run.output, w),
                            "round {round}: reply out of order or cross-wired"
                        ),
                        (Err(_), None) => {}
                        (got, want) => panic!(
                            "round {round}: reply {} kind mismatch: got ok={} want ok={}",
                            s.received,
                            got.is_ok(),
                            want.is_some()
                        ),
                    }
                    s.received += 1;
                }
                if let Some(cut) = s.close_cue {
                    if s.closed_at.is_none() && s.received >= cut {
                        s.handle.close();
                        s.closed_at = Some(s.received);
                    }
                }
            }
            if engine.advance_next() {
                continue;
            }
            if stats.idle() {
                break;
            }
        }

        // exactly one reply per request on every session left open; closed
        // sessions received exactly their pre-close prefix and then the
        // stream disconnected with nothing in between
        let mut expected_total = 0u64;
        for s in &mut scripts {
            match s.closed_at {
                None => {
                    assert_eq!(
                        s.received,
                        s.wants.len(),
                        "round {round}: open session missing replies"
                    );
                    s.handle.close();
                }
                Some(at) => {
                    assert_eq!(
                        s.received, at,
                        "round {round}: reply delivered after session close"
                    );
                }
            }
            assert!(s.handle.try_recv().is_none());
            assert_eq!(s.handle.state(), SessionState::Closed);
            expected_total += s.received as u64;
        }
        // conservation: the reactor drained exactly what the backend
        // completed, and undelivered completions are all accounted as late
        let m = metrics.snapshot();
        assert_eq!(m.sessions, n_sessions as u64);
        assert_eq!(m.completions, engine.dispatched());
        assert_eq!(
            expected_total + fe.late_replies(),
            m.completions,
            "round {round}: a reply was lost or duplicated"
        );
    }
}

/// The admission caps actually bound backend concurrency, and a backend
/// answering Busy (capacity below the front-end budget) only defers —
/// never drops — work.
#[test]
fn admission_caps_bound_the_backend() {
    // caps bind: high-water never exceeds min(frontend budget, capacity)
    let cfg = FrontendConfig {
        inflight_per_session: 2,
        max_inflight: 3,
        ..FrontendConfig::default()
    };
    let (fe, reactor, engine, _) = scripted_front(100, cfg, |_, _| 2);
    let sessions: Vec<_> = (0..4).map(|_| fe.open_session()).collect();
    for (i, s) in sessions.iter().enumerate() {
        for k in 0..5u64 {
            s.submit(vmul_req(64, (i as u64) * 100 + k)).unwrap();
        }
    }
    drive(&reactor, &engine, 10_000);
    assert!(engine.high_water() <= 3, "front-end budget exceeded: {}", engine.high_water());
    for s in &sessions {
        for _ in 0..5 {
            s.recv().unwrap();
        }
    }

    // backend capacity below the budget: Busy path defers, all complete
    let cfg = FrontendConfig {
        inflight_per_session: 4,
        max_inflight: 64,
        ..FrontendConfig::default()
    };
    let (fe, reactor, engine, metrics) = scripted_front(2, cfg, |_, _| 1);
    let s = fe.open_session();
    for k in 0..12u64 {
        s.submit(vmul_req(64, 500 + k)).unwrap();
    }
    drive(&reactor, &engine, 10_000);
    assert!(metrics.snapshot().admission_rejections > 0, "Busy path never exercised");
    assert!(engine.high_water() <= 2);
    for _ in 0..12 {
        s.recv().unwrap();
    }
}

/// Satellite (ISSUE 6): the close-vs-completion race, cut exactly. A
/// session closes while its completion sits in the shared queue, already
/// pushed by the backend but not yet drained by a poll. The completion
/// must be accounted exactly once — late, never delivered, never lost —
/// and the closed session must not linger in the table.
#[test]
fn close_while_completion_queued_accounts_late_exactly_once() {
    let (fe, reactor, engine, metrics) = scripted_front(8, FrontendConfig::default(), |_, _| 5);
    let s = fe.open_session();
    s.submit(vmul_req(64, 42)).unwrap();
    assert_eq!(reactor.poll_once().admitted, 1);
    // the backend completes: the reply is now queued in the completion
    // queue — and the client closes before the reactor drains it
    assert!(engine.advance_next());
    s.close();
    assert_eq!(s.state(), SessionState::Closed);
    assert_eq!(reactor.session_count(), 1, "in-flight work pins the closed session");

    let stats = reactor.poll_once();
    assert_eq!((stats.completions, stats.delivered), (1, 0), "closed: nothing delivered");
    assert!(s.try_recv().is_none(), "no reply after close, ever");
    assert_eq!(reactor.session_count(), 0, "last completion releases the session");
    // exactly-one-accounting: the completion is late XOR delivered
    let m = metrics.snapshot();
    assert_eq!(m.completions, 1);
    assert_eq!(fe.late_replies(), 1);
}

/// Satellite (ISSUE 6): close with the reorder buffer non-empty — two
/// fast completions gap-buffered behind a slow head when the close lands.
/// Every completion (buffered at close time or arriving after) must be
/// counted late exactly once: `delivered + late == completions` with zero
/// delivered.
#[test]
fn close_with_gap_buffered_replies_accounts_each_exactly_once() {
    let cfg = FrontendConfig { inflight_per_session: 3, ..FrontendConfig::default() };
    let (fe, reactor, engine, metrics) =
        scripted_front(8, cfg, |i, _| if i == 0 { 50 } else { i });
    let s = fe.open_session();
    for k in 0..3 {
        s.submit(vmul_req(64, 300 + k)).unwrap();
    }
    assert_eq!(reactor.poll_once().admitted, 3);
    // the two fast completions land and buffer behind the slow seq 0
    assert!(engine.advance_next());
    assert!(engine.advance_next());
    let stats = reactor.poll_once();
    assert_eq!((stats.completions, stats.delivered), (2, 0));
    assert_eq!(s.state(), SessionState::Replying);
    // close clears the buffer (2 late); the slow head is still in flight
    s.close();
    assert_eq!(fe.late_replies(), 2, "gap-buffered replies die with the close");
    assert_eq!(reactor.session_count(), 1);
    // the head completes into a closed session: late, and the table frees
    assert!(engine.advance_next());
    let stats = reactor.poll_once();
    assert_eq!((stats.completions, stats.delivered), (1, 0));
    assert_eq!(reactor.session_count(), 0);
    assert!(s.try_recv().is_none());
    let m = metrics.snapshot();
    assert_eq!(m.completions, 3);
    assert_eq!(fe.late_replies(), 3, "each completion late exactly once, none lost");
}

/// Satellite (ISSUE 6): a handle dropped without `close()` must release
/// its session — before the fix it leaked in the reactor table forever,
/// "delivering" every future completion into a disconnected channel. Both
/// drop timings: quiescent, and with a request still in flight (where the
/// straggler must be counted late, not lost).
#[test]
fn dropping_a_handle_without_close_releases_the_session() {
    let (fe, reactor, engine, metrics) = scripted_front(8, FrontendConfig::default(), |_, _| 5);
    // quiescent drop: served to completion, then the client walks away
    let a = fe.open_session();
    a.submit(vmul_req(64, 7)).unwrap();
    reactor.poll_once();
    assert!(engine.advance_next());
    assert_eq!(reactor.poll_once().delivered, 1);
    a.recv().unwrap();
    drop(a);
    assert_eq!(reactor.session_count(), 0, "dropped handle leaked its session");
    assert_eq!(fe.late_replies(), 0);

    // mid-flight drop: the straggling completion is late, exactly once
    let b = fe.open_session();
    b.submit(vmul_req(64, 8)).unwrap();
    reactor.poll_once();
    drop(b);
    assert_eq!(reactor.session_count(), 1, "in-flight work pins the dropped session");
    assert!(engine.advance_next());
    let stats = reactor.poll_once();
    assert_eq!((stats.completions, stats.delivered), (1, 0));
    assert_eq!(reactor.session_count(), 0);
    let m = metrics.snapshot();
    assert_eq!(m.completions, 2);
    assert_eq!(fe.late_replies(), 1, "delivered (1) + late (1) == completions (2)");
}

/// The split-handle API: the submit and reply halves work from different
/// threads (the socket tier's reader/writer shape), and dropping the
/// submit half closes the session and disconnects the reply half.
#[test]
fn split_handle_halves_work_independently_and_drop_closes() {
    let (fe, reactor, engine, _) = scripted_front(8, FrontendConfig::default(), |_, _| 2);
    let (sub, replies) = fe.open_session().split();
    let req = vmul_req(64, 99);
    let want = expected(&req);
    sub.submit(req).unwrap();
    assert_eq!(sub.state(), SessionState::Queued);
    reactor.poll_once();
    assert!(engine.advance_next());
    reactor.poll_once();
    let got = replies.recv().unwrap();
    assert!(agree(&got.run.output, &want));
    assert!(replies.try_recv().is_none());
    drop(sub);
    assert_eq!(reactor.session_count(), 0, "dropping the submit half closes the session");
    assert!(replies.recv().is_err(), "reply stream disconnects with the session");
}

/// The reactor front end over the *real* worker pool (threaded, scheduling
/// nondeterministic): the invariants — exactly one reply per request, in
/// submission order, correct values — must hold for every interleaving.
/// CI smoke-runs the same path via `repro serve --frontend reactor`.
#[test]
fn reactor_over_real_pool_preserves_reply_integrity() {
    const SESSIONS: u64 = 6;
    const PER_SESSION: u64 = 8;
    let service = ServiceConfig { queue_capacity: 64, ..ServiceConfig::with_workers(2) };
    let pool = Arc::new(WorkerPool::new(OverlayConfig::default(), service).unwrap());
    let fe = Frontend::new(
        pool.clone(),
        FrontendConfig { inflight_per_session: 4, max_inflight: 32, ..Default::default() },
        pool.metrics.clone(),
    )
    .unwrap();
    let threads = fe.spawn().unwrap();

    let handles: Vec<_> = (0..SESSIONS).map(|_| fe.open_session()).collect();
    let mut wants: Vec<Vec<Value>> = Vec::new();
    for (i, h) in handles.iter().enumerate() {
        let mut w = Vec::new();
        for k in 0..PER_SESSION {
            let req = vmul_req(256, (i as u64) * 1000 + k);
            w.push(expected(&req));
            h.submit(req).unwrap();
        }
        wants.push(w);
    }
    for (h, w) in handles.iter().zip(&wants) {
        for want in w {
            let got = h.recv().expect("request served");
            assert!(agree(&got.run.output, want), "reply out of order or cross-wired");
        }
        assert!(h.try_recv().is_none());
        h.close();
    }
    threads.shutdown();
    assert_eq!(fe.late_replies(), 0);
    drop(fe); // releases the front end's Arc on the pool
    let report = Arc::try_unwrap(pool).ok().expect("front end gone").shutdown();
    assert_eq!(report.aggregate.requests, SESSIONS * PER_SESSION);
    assert_eq!(report.aggregate.completions, SESSIONS * PER_SESSION);
    assert_eq!(report.aggregate.sessions, SESSIONS);
    assert!(report.panicked_workers.is_empty());
}

/// The same invariants through the thread-per-client mode (`--frontend
/// threads`): one client thread per session over the blocking channel
/// path. The two modes must agree on every observable property.
#[test]
fn thread_per_client_mode_preserves_reply_integrity() {
    const SESSIONS: u64 = 6;
    const PER_SESSION: u64 = 8;
    let base = workload::env_seed(0);
    let service = ServiceConfig { queue_capacity: 64, ..ServiceConfig::with_workers(2) };
    let pool = Arc::new(WorkerPool::new(OverlayConfig::default(), service).unwrap());
    let mut joins = Vec::new();
    for i in 0..SESSIONS {
        let p = pool.clone();
        joins.push(std::thread::spawn(move || {
            let reqs: Vec<Request> = (0..PER_SESSION)
                .map(|k| vmul_req(256, base.wrapping_mul(77) + i * 1000 + k))
                .collect();
            let wants: Vec<Value> = reqs.iter().map(expected).collect();
            let rxs: Vec<_> = reqs.into_iter().map(|r| p.submit(r).unwrap()).collect();
            for (rx, want) in rxs.into_iter().zip(&wants) {
                let got = rx.recv().expect("worker alive").expect("request served");
                assert!(agree(&got.run.output, want), "reply out of order or cross-wired");
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let report = Arc::try_unwrap(pool).ok().expect("clients done").shutdown();
    assert_eq!(report.aggregate.requests, SESSIONS * PER_SESSION);
    // the channel path never touches the reactor counters
    assert_eq!(report.aggregate.completions, 0);
    assert_eq!(report.aggregate.sessions, 0);
}
