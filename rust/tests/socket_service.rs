//! Serving-tier tests in two registers.
//!
//! The lifecycle properties — slow-client shedding, pending-cap
//! backpressure — are proven deterministically: [`ConnDriver`] is a pure
//! state machine over injected milliseconds, and the reactor is stepped
//! manually against a `ScriptedEngine`, so "a half-dead client must not
//! stall its neighbors" is an exact assertion, not a sampled race.
//!
//! The socket shell itself (accept loop, reader/writer pair, FIFO reply
//! pairing, metrics, remote shutdown) is then exercised end-to-end over
//! real localhost TCP with the real worker pool.

use std::io::Read;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use jit_overlay::coordinator::net::{ConnDriver, NetServer, WireStep};
use jit_overlay::coordinator::wire::{read_frame, write_frame, ClientMsg, FrameDecoder, ServerMsg};
use jit_overlay::coordinator::{AtomicMetrics, Frontend, Metrics, WorkerPool};
use jit_overlay::exec::cpu::{self, Value};
use jit_overlay::patterns::Composition;
use jit_overlay::testkit::ScriptedEngine;
use jit_overlay::workload;
use jit_overlay::{FrontendConfig, NetConfig, OverlayConfig, ServiceConfig};

fn agree(a: &Value, b: &Value) -> bool {
    const TOL: f32 = 1e-3;
    match (a, b) {
        (Value::Scalar(x), Value::Scalar(y)) => (x - y).abs() <= TOL * (1.0 + y.abs()),
        (Value::Vector(x), Value::Vector(y)) => {
            x.len() == y.len()
                && x.iter().zip(y).all(|(p, q)| (p - q).abs() <= TOL * (1.0 + q.abs()))
        }
        _ => false,
    }
}

/// A REQUEST frame's payload, as the decoder hands it to the driver.
fn req_payload(id: u64, n: u32, seed: u64, pattern: &str) -> Vec<u8> {
    ClientMsg::Request { id, n, seed, pattern: pattern.into() }.to_frame()[4..].to_vec()
}

/// The value the server must compute for a wire request: inputs are
/// synthesized from `(n, seed)` exactly as the serving tier does.
fn expected_for(n: usize, seed: u64, pattern: &str) -> Value {
    let comp = jit_overlay::patterns::parse_pattern(pattern, n).unwrap();
    let inputs: Vec<Vec<f32>> = (0..comp.inputs)
        .map(|c| workload::vector(n, seed.wrapping_add(c as u64), 0.1, 2.0))
        .collect();
    cpu::eval(&comp, &inputs).unwrap()
}

/// A slow (half-dead) client is shed on the idle deadline while a healthy
/// session on the same reactor keeps flowing; the shed session's in-flight
/// completion is accounted late, never delivered, never lost:
/// `delivered + late == completions` across both connections.
#[test]
fn slow_client_is_shed_while_healthy_sessions_proceed() {
    let net = NetConfig { idle_timeout_ms: 100, ..NetConfig::default() };
    // A's request (n=48) never completes within the test; B's (n=64) are
    // one-tick — keyed on the request so dispatch order cannot matter
    let engine = Arc::new(
        ScriptedEngine::new(OverlayConfig::default(), 16, |_, r| {
            if r.comp.n == 48 {
                1_000_000
            } else {
                1
            }
        })
        .unwrap(),
    );
    let metrics = Arc::new(AtomicMetrics::default());
    let cfg = FrontendConfig { reactors: 1, inflight_per_session: 4, max_inflight: 64 };
    let fe = Frontend::new(engine.clone(), cfg, metrics.clone()).unwrap();
    let reactor = fe.reactor(0);

    // connection A: one request, then silence
    let (sub_a, replies_a) = fe.open_session().split();
    let mut driver_a = ConnDriver::new(net.clone(), 0);
    match driver_a.on_frame(&req_payload(0, 48, 1, "vmul-reduce"), 0, 0) {
        WireStep::Submit { id: 0, request } => sub_a.submit(request).unwrap(),
        other => panic!("expected Submit, got {other:?}"),
    }

    // connection B: a healthy client, one frame every 10 ms
    let (sub_b, replies_b) = fe.open_session().split();
    let mut driver_b = ConnDriver::new(net.clone(), 0);
    let mut reqs_b = Vec::new();
    for k in 0..10u64 {
        let now = k * 10;
        assert!(!driver_b.idle_exceeded(now), "B's frames keep resetting its idle clock");
        match driver_b.on_frame(&req_payload(k, 64, 100 + k, "vmul-reduce"), now, 0) {
            WireStep::Submit { id, request } => {
                assert_eq!(id, k);
                reqs_b.push(request.clone());
                sub_b.submit(request).unwrap();
            }
            other => panic!("expected Submit, got {other:?}"),
        }
    }

    // drive until B's ten replies are delivered — A's stuck request must
    // not stall them
    let (mut completions, mut delivered) = (0usize, 0usize);
    let mut got = Vec::new();
    for _ in 0..200 {
        let stats = reactor.poll_once();
        completions += stats.completions;
        delivered += stats.delivered;
        while let Some(r) = replies_b.try_recv() {
            got.push(r.unwrap().run.output);
        }
        if got.len() == 10 {
            break;
        }
        engine.advance_next();
    }
    assert_eq!(got.len(), 10, "healthy session starved behind a slow peer");
    for (req, v) in reqs_b.iter().zip(&got) {
        assert!(agree(&cpu::eval(&req.comp, &req.inputs).unwrap(), v), "reply pairing broke");
    }

    // A has been silent past the idle deadline: the shell sheds it (B,
    // whose last frame landed at t=90, is nowhere near its deadline)
    assert!(driver_a.idle_exceeded(150));
    assert!(!driver_b.idle_exceeded(150));
    metrics.record(&Metrics { conns_shed: 1, ..Default::default() });
    drop(sub_a); // close-on-drop: the session ends with work in flight
    assert!(replies_a.recv().is_err(), "shed reply stream disconnects");
    assert_eq!(reactor.session_count(), 2, "in-flight work pins the shed session");

    // A's completion finally lands — on a closed session: late, not lost
    assert!(engine.advance_next());
    let stats = reactor.poll_once();
    completions += stats.completions;
    delivered += stats.delivered;
    assert_eq!(reactor.session_count(), 1, "only B's session remains");
    assert_eq!((completions, delivered), (11, 10));
    assert_eq!(fe.late_replies(), 1);
    assert_eq!(metrics.snapshot().conns_shed, 1);
    sub_b.close();
}

/// Overload on one connection degrades to `BUSY` frames at the pending
/// cap — deterministically, straight from wire bytes — and capacity
/// freed by replies re-admits new requests.
#[test]
fn wire_pending_cap_turns_overload_into_busy_frames() {
    let net = NetConfig { max_pending_per_conn: 2, ..NetConfig::default() };
    let metrics = AtomicMetrics::default();
    let mut driver = ConnDriver::new(net.clone(), 0);
    let mut dec = FrameDecoder::new(net.max_frame);
    for id in 0..4u64 {
        let msg = ClientMsg::Request { id, n: 32, seed: id, pattern: "vmul-reduce".into() };
        dec.push(&msg.to_frame());
    }

    let mut pending = 0usize;
    let (mut submitted, mut busy) = (Vec::new(), Vec::new());
    while let Some(p) = dec.next_frame().unwrap() {
        match driver.on_frame(&p, 0, pending) {
            WireStep::Submit { id, .. } => {
                pending += 1;
                submitted.push(id);
            }
            WireStep::Reject(ServerMsg::Busy { id }) => {
                metrics.record(&Metrics { net_rejections: 1, ..Default::default() });
                busy.push(id);
            }
            other => panic!("unexpected {other:?}"),
        }
    }
    assert_eq!(submitted, vec![0, 1]);
    assert_eq!(busy, vec![2, 3]);
    assert_eq!(metrics.snapshot().net_rejections, 2);

    // one reply drains: the next frame submits again
    pending -= 1;
    let msg = ClientMsg::Request { id: 9, n: 32, seed: 9, pattern: "vmul-reduce".into() };
    dec.push(&msg.to_frame());
    let p = dec.next_frame().unwrap().unwrap();
    assert!(matches!(driver.on_frame(&p, 0, pending), WireStep::Submit { id: 9, .. }));
}

/// End-to-end over real localhost TCP: pipelined requests come back in
/// submission order with correct values (in-session FIFO holds across the
/// socket), a clean EOF is a polite hangup, a malformed frame is shed, and
/// teardown returns the pool intact.
#[test]
fn tcp_round_trip_pipelines_in_order_with_clean_teardown() {
    let pool =
        Arc::new(WorkerPool::new(OverlayConfig::default(), ServiceConfig::with_workers(2)).unwrap());
    let fcfg = FrontendConfig { reactors: 2, inflight_per_session: 4, max_inflight: 64 };
    let front = Arc::new(Frontend::new(pool.clone(), fcfg, pool.metrics.clone()).unwrap());
    let threads = front.spawn().unwrap();
    let server =
        NetServer::bind("127.0.0.1:0", front.clone(), NetConfig::default(), pool.metrics.clone())
            .unwrap();
    let addr = server.local_addr().to_string();

    let n = 64u32;
    let mut s = TcpStream::connect(&addr).unwrap();
    for id in 0..3u64 {
        let msg = ClientMsg::Request { id, n, seed: 40 + id, pattern: "vmul-reduce".into() };
        write_frame(&mut s, &msg.to_frame()).unwrap();
    }
    for id in 0..3u64 {
        let payload = read_frame(&mut s, 0).unwrap().expect("a reply per request");
        match ServerMsg::decode(&payload).unwrap() {
            ServerMsg::Ok { id: got, value, .. } => {
                assert_eq!(got, id, "replies must come back in submission order");
                let want = expected_for(n as usize, 40 + id, "vmul-reduce");
                assert!(agree(&want, &value), "request {id}: wrong value");
            }
            other => panic!("expected Ok, got {other:?}"),
        }
    }
    drop(s); // clean EOF at a frame boundary: not a shed

    // a malformed frame on a second connection is shed (connection closed)
    let mut bad = TcpStream::connect(&addr).unwrap();
    let mut frame = 3u32.to_le_bytes().to_vec();
    frame.extend_from_slice(&[0x7F, 0, 1]); // unknown tag
    write_frame(&mut bad, &frame).unwrap();
    let mut rest = Vec::new();
    let _ = bad.read_to_end(&mut rest); // server hangs up on us
    assert!(rest.is_empty(), "no reply to a malformed frame");

    // both lifecycle outcomes are observable in the metrics
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let m = pool.metrics.snapshot();
        if m.connections == 2 && m.conns_shed == 1 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "lifecycle counters never settled: connections={} shed={}",
            m.connections,
            m.conns_shed
        );
        std::thread::sleep(Duration::from_millis(10));
    }

    server.stop();
    threads.shutdown();
    drop(front);
    let report = Arc::try_unwrap(pool).ok().expect("serving tier leaked the pool").shutdown();
    let m = &report.aggregate;
    assert_eq!((m.connections, m.conns_shed), (2, 1));
    assert_eq!(m.completions, 3, "three served requests drained exactly once");
}

/// `SHUTDOWN` is honored only with `allow_remote_shutdown`: an
/// unauthorized sender is shed and the server keeps serving; an authorized
/// one flips the stop flag and `join` returns.
#[test]
fn remote_shutdown_is_honored_only_when_enabled() {
    let pool =
        Arc::new(WorkerPool::new(OverlayConfig::default(), ServiceConfig::with_workers(1)).unwrap());
    let front = Arc::new(
        Frontend::new(pool.clone(), FrontendConfig::default(), pool.metrics.clone()).unwrap(),
    );
    let threads = front.spawn().unwrap();

    // phase 1: shutdown NOT allowed — the sender is shed, service continues
    let server =
        NetServer::bind("127.0.0.1:0", front.clone(), NetConfig::default(), pool.metrics.clone())
            .unwrap();
    let addr = server.local_addr().to_string();
    let mut s = TcpStream::connect(&addr).unwrap();
    write_frame(&mut s, &ClientMsg::Shutdown.to_frame()).unwrap();
    let mut rest = Vec::new();
    let _ = s.read_to_end(&mut rest); // shed: EOF, no reply
    assert!(!server.stop_requested(), "unauthorized SHUTDOWN must not stop the server");
    let mut ok = TcpStream::connect(&addr).unwrap();
    let msg = ClientMsg::Request { id: 1, n: 32, seed: 5, pattern: "vmul-reduce".into() };
    write_frame(&mut ok, &msg.to_frame()).unwrap();
    let payload = read_frame(&mut ok, 0).unwrap().expect("still serving after shed SHUTDOWN");
    assert!(matches!(ServerMsg::decode(&payload).unwrap(), ServerMsg::Ok { id: 1, .. }));
    drop(ok);
    server.stop();

    // phase 2: shutdown allowed — the flag flips and join returns
    let net = NetConfig { allow_remote_shutdown: true, ..NetConfig::default() };
    let server = NetServer::bind("127.0.0.1:0", front.clone(), net, pool.metrics.clone()).unwrap();
    let addr = server.local_addr().to_string();
    let mut s = TcpStream::connect(&addr).unwrap();
    write_frame(&mut s, &ClientMsg::Shutdown.to_frame()).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    while !server.stop_requested() {
        assert!(Instant::now() < deadline, "authorized SHUTDOWN never honored");
        std::thread::sleep(Duration::from_millis(10));
    }
    server.join();

    threads.shutdown();
    drop(front);
    Arc::try_unwrap(pool).ok().expect("serving tier leaked the pool").shutdown();
}
