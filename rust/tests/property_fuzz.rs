//! Property-style randomized tests over the coordinator-side invariants
//! (placement, routing, codec, codegen), using the in-tree deterministic
//! PRNG — the offline stand-in for proptest, with fixed seeds so failures
//! reproduce exactly. `$JIT_OVERLAY_SEED` (the CI `test-seeds` matrix)
//! shifts every stream into a distinct — still fully deterministic —
//! universe; re-run with the same value to reproduce a failure.

use jit_overlay::bitstream::{BitstreamLibrary, OperatorKind};
use jit_overlay::exec::{cpu, Engine};
use jit_overlay::isa::{encode, Instr, Opcode};
use jit_overlay::jit::Jit;
use jit_overlay::overlay::{Fabric, Mesh};
use jit_overlay::patterns::Composition;
use jit_overlay::place::DynamicPlacer;
use jit_overlay::route::shortest_route;
use jit_overlay::timing::Target;
use jit_overlay::workload::Rng;
use jit_overlay::OverlayConfig;

const CASES: usize = 200;

/// A test's fixed stream seed, shifted by the CI seed matrix.
fn seed(base: u64) -> u64 {
    base ^ jit_overlay::workload::env_seed(0).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

// ---------------------------------------------------------------------------
// ISA codec: encode∘decode = id for every valid field combination
// ---------------------------------------------------------------------------

#[test]
fn prop_codec_roundtrip_random_instrs() {
    let mut rng = Rng::new(seed(0xC0DEC));
    for _ in 0..CASES * 5 {
        let i = Instr {
            op: Opcode::from_u8(rng.below(42) as u8).unwrap(),
            tile: rng.below(64) as u8,
            a: rng.below(32) as u8,
            b: rng.below(32) as u8,
            imm: (rng.below(1024) as i16) - 512,
        };
        let w = encode::encode(&i).unwrap();
        assert_eq!(encode::decode(w).unwrap(), i);
    }
}

#[test]
fn prop_codec_rejects_or_roundtrips_any_word() {
    // decoding an arbitrary word either fails (bad opcode) or yields an
    // instruction that re-encodes to the same word.
    let mut rng = Rng::new(seed(0xBAD5EED));
    for _ in 0..CASES * 5 {
        let w = rng.next_u64() as u32;
        if let Ok(i) = encode::decode(w) {
            assert_eq!(encode::encode(&i).unwrap(), w, "word {w:#010x}");
        }
    }
}

// ---------------------------------------------------------------------------
// Router: legal shortest paths on random meshes with random blockages
// ---------------------------------------------------------------------------

#[test]
fn prop_routes_are_legal_and_minimal() {
    let mut rng = Rng::new(seed(0x7777));
    for _ in 0..CASES {
        let rows = 2 + rng.below(4);
        let cols = 2 + rng.below(4);
        let mesh = Mesh::new(rows, cols);
        let tiles = mesh.tiles();
        let from = rng.below(tiles);
        let to = rng.below(tiles);
        if from == to {
            continue;
        }
        let mut blocked = vec![false; tiles];
        for _ in 0..rng.below(tiles / 2 + 1) {
            let t = rng.below(tiles);
            if t != from && t != to {
                blocked[t] = true;
            }
        }
        match shortest_route(&mesh, from, to, &blocked) {
            Err(_) => {} // disconnection is legal under blockage
            Ok(r) => {
                // chain is adjacent, avoids blocked tiles, no repeats
                let mut chain = vec![from];
                chain.extend(&r.via);
                chain.push(to);
                for w in chain.windows(2) {
                    assert_eq!(mesh.manhattan(w[0], w[1]), 1, "{chain:?}");
                }
                for &v in &r.via {
                    assert!(!blocked[v], "route through blocked tile {v}");
                }
                let distinct: std::collections::HashSet<_> = chain.iter().collect();
                assert_eq!(distinct.len(), chain.len(), "cycle in {chain:?}");
                // no blockage ⇒ manhattan-minimal
                if blocked.iter().all(|&b| !b) {
                    assert_eq!(r.hops() + 1, mesh.manhattan(from, to));
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Placer: injectivity, class-compatibility, contiguity on random pipelines
// ---------------------------------------------------------------------------

#[test]
fn prop_placements_injective_and_class_compatible() {
    use OperatorKind::*;
    let small_ops = [Add, Sub, Mul, Max, Min, Neg, Abs, Square, Relu, AccSum, FilterGt];
    let large_ops = [Sqrt, Sin, Cos, Log, Exp, Tanh, Div];
    let cfg = OverlayConfig::default();
    let lib = BitstreamLibrary::standard(&cfg);
    let fabric = Fabric::new(cfg).unwrap();
    let mut rng = Rng::new(seed(0x91ACE));
    for _ in 0..CASES {
        let len = 1 + rng.below(6);
        let mut ops = Vec::new();
        let mut larges = 0;
        for _ in 0..len {
            if rng.below(4) == 0 && larges < 2 {
                ops.push(large_ops[rng.below(large_ops.len())]);
                larges += 1;
            } else {
                ops.push(small_ops[rng.below(small_ops.len())]);
            }
        }
        let p = match DynamicPlacer.place(&fabric, &lib, &ops) {
            Ok(p) => p,
            Err(e) => {
                assert!(e.is_capacity(), "unexpected error kind: {e}");
                continue;
            }
        };
        assert!(p.is_injective());
        for (a, &op) in p.assignments.iter().zip(&ops) {
            assert_eq!(a.op, op);
            let fp = jit_overlay::bitstream::Footprint::for_operator(op);
            assert!(fp.fits(&a.class.budget()), "{op:?} in {:?}", a.class);
        }
        // all-small pipelines must be perfectly contiguous
        if ops.iter().all(|o| {
            jit_overlay::bitstream::Footprint::for_operator(*o)
                .fits(&jit_overlay::bitstream::RegionClass::Small.budget())
        }) {
            assert!(p.is_contiguous(&fabric.mesh), "{ops:?} -> {:?}", p.assignments);
        }
    }
}

// ---------------------------------------------------------------------------
// Codegen + controller vs CPU reference on random compositions
// ---------------------------------------------------------------------------

#[test]
fn prop_random_chains_execute_correctly() {
    use OperatorKind::*;
    // domain-safe unary ops over positive inputs
    let ops_pool = [Abs, Neg, Square, Relu, Sqrt, Exp, Tanh];
    let mut rng = Rng::new(seed(0xE2E));
    let mut engine = Engine::new(OverlayConfig::default()).unwrap();
    for case in 0..40 {
        let len = 1 + rng.below(4);
        let ops: Vec<OperatorKind> =
            (0..len).map(|_| ops_pool[rng.below(ops_pool.len())]).collect();
        // at most 2 large-region ops fit the fabric
        let larges = ops
            .iter()
            .filter(|o| {
                !jit_overlay::bitstream::Footprint::for_operator(**o)
                    .fits(&jit_overlay::bitstream::RegionClass::Small.budget())
            })
            .count();
        if larges > 2 {
            continue;
        }
        let n = [64usize, 256, 1024, 2048][rng.below(4)];
        let comp = Composition::chain(&ops, n).unwrap();
        let acc = match Jit.compile(&engine.fabric, &engine.lib, &comp) {
            Ok(a) => a,
            Err(e) => {
                assert!(e.is_capacity());
                continue;
            }
        };
        let x: Vec<f32> = (0..n).map(|_| rng.range(0.05, 1.5)).collect();
        let got = engine
            .run(&acc, &[x.clone()], Target::DynamicOverlay)
            .unwrap()
            .output;
        let want = cpu::eval(&comp, &[x]).unwrap();
        let (g, w) = (got.as_vector().unwrap(), want.as_vector().unwrap());
        for i in 0..n {
            // NaN on both sides counts as agreement (e.g. sqrt of a
            // negative intermediate — both planes produce the same NaN).
            let same_nan = g[i].is_nan() && w[i].is_nan();
            assert!(
                same_nan || (g[i] - w[i]).abs() <= 1e-4 * (1.0 + w[i].abs()),
                "case {case} {ops:?} i={i}: {} vs {}",
                g[i],
                w[i]
            );
        }
        engine.fabric.reset_full();
    }
}

#[test]
fn prop_random_scalar_patterns_execute_correctly() {
    let mut rng = Rng::new(seed(0x5CA1A7));
    let mut engine = Engine::new(OverlayConfig::default()).unwrap();
    for _ in 0..30 {
        let n = [128usize, 512, 1024][rng.below(3)];
        let t = rng.range(-1.0, 1.0);
        let comp = if rng.below(2) == 0 {
            Composition::vmul_reduce(n)
        } else {
            Composition::filter_reduce(t, n)
        };
        let acc = Jit.compile(&engine.fabric, &engine.lib, &comp).unwrap();
        let inputs: Vec<Vec<f32>> = (0..comp.inputs)
            .map(|_| (0..n).map(|_| rng.range(-2.0, 2.0)).collect())
            .collect();
        let got = engine
            .run(&acc, &inputs, Target::DynamicOverlay)
            .unwrap()
            .output
            .as_scalar()
            .unwrap();
        let want = cpu::eval(&comp, &inputs).unwrap().as_scalar().unwrap();
        assert!(
            (got - want).abs() <= 1e-3 + want.abs() * 1e-4,
            "{got} vs {want}"
        );
        engine.fabric.reset_full();
    }
}

// ---------------------------------------------------------------------------
// Placement specialization: spills never clobber avoidably, and per-fabric
// occupancy accounting never double-books a tile (ISSUE 4)
// ---------------------------------------------------------------------------

#[test]
fn prop_spills_never_clobber_when_free_tiles_suffice() {
    use jit_overlay::coordinator::{AcceleratorCache, Coordinator, Request};
    use std::sync::Arc;

    // small all-small-class compositions: `free tiles ≥ stages` is then a
    // sufficient feasibility condition, so any eviction under it is a bug
    let small = [OperatorKind::Abs, OperatorKind::Neg, OperatorKind::Square, OperatorKind::Relu];
    for &fabrics in &[2usize, 3, 4] {
        let cache = Arc::new(AcceleratorCache::new(4));
        let mut coords: Vec<Coordinator> = (0..fabrics)
            .map(|_| {
                Coordinator::with_cache(jit_overlay::OverlayConfig::default(), cache.clone())
                    .unwrap()
            })
            .collect();
        let mut rng = Rng::new(seed(0x5B111 + fabrics as u64));
        for step in 0..120 {
            let len = 1 + rng.below(3);
            let ops: Vec<OperatorKind> = (0..len).map(|_| small[rng.below(small.len())]).collect();
            let n = [64usize, 128, 256][rng.below(3)];
            let comp = Composition::chain(&ops, n).unwrap();
            // every landing after the first on a different fabric is a
            // "spill": the composition's program is already cached
            let w = rng.below(fabrics);
            let c = &mut coords[w];
            let free_before = c.engine.fabric.free_tiles().len();
            let stages = comp.stages().len();
            let before = c.metrics;
            let inputs = jit_overlay::workload::request_inputs(&comp, step as u64);
            c.submit(&Request::dynamic(comp.clone(), inputs)).unwrap();
            let d = c.metrics.delta_since(&before);
            if free_before >= stages {
                // enough free tiles for the incoming placement: no resident
                // may be evicted or overwritten, on any fabric, ever
                assert_eq!(
                    d.pr_replaced, 0,
                    "step {step}: fabric {w} overwrote a resident with {free_before} free \
                     tiles for {stages} stages ({ops:?})"
                );
                assert_eq!(
                    d.evictions, 0,
                    "step {step}: fabric {w} evicted with {free_before} free tiles"
                );
            }
            // the plan served for this fabric is specialized to it and
            // never double-books a tile
            let (acc, _, _) = c.accelerator(&comp).unwrap();
            assert_eq!(acc.plan.fabric, c.engine.fabric.id);
            assert!(acc.placement().is_injective(), "step {step}: tile double-booked");
            // occupancy accounting is consistent with the tile states
            let (resident, total) = c.engine.residency();
            let manual =
                c.engine.fabric.tiles.iter().filter(|t| t.resident.is_some()).count();
            assert_eq!(resident, manual);
            assert!(resident <= total);
        }
        // conservation across the whole run, per fabric and in aggregate:
        // each iteration produced exactly two accelerator events — one
        // inside submit (counted as a request) and one post-run probe (a
        // guaranteed full hit: the just-executed plan matches residency)
        let mut total = jit_overlay::coordinator::Metrics::default();
        for c in &coords {
            assert_eq!(
                c.metrics.cache_hits
                    + c.metrics.placement_respecializations
                    + c.metrics.jit_compiles,
                2 * c.metrics.requests,
                "conservation must hold per fabric"
            );
            total.merge(&c.metrics);
        }
        assert_eq!(total.requests, 120);
    }
}

// ---------------------------------------------------------------------------
// Composition cache keys: random equal compositions hash equal, mutants differ
// ---------------------------------------------------------------------------

#[test]
fn prop_fusion_is_bit_identical_to_unfused_and_cpu() {
    use jit_overlay::coordinator::{Coordinator, Request};
    use jit_overlay::exec::Value;

    // every stream shape the service benches throw at the pool: the
    // mixed 80/20 skew, the spill-heavy distinct-key churn, the
    // adversarial conflicting-chain interleave — plus the map∘reduce
    // patterns whose fused datapath reassociates nothing by construction
    let mut comps: Vec<Composition> = Vec::new();
    comps.extend(jit_overlay::workload::mixed_compositions(24, 256, seed(0xF05E)));
    comps.extend(jit_overlay::workload::spill_heavy_compositions(24, 12, seed(0xD1FF)));
    let [a, b, c] = jit_overlay::workload::conflicting_chains(512);
    comps.extend(jit_overlay::workload::interleaved_stream(&[a, b, c], 4));
    comps.push(Composition::vmul_reduce(2048));
    comps.push(Composition::filter_reduce(0.25, 1024));

    let mut fused = Coordinator::new(OverlayConfig::default()).unwrap();
    fused.set_fusion(true);
    let mut plain = Coordinator::new(OverlayConfig::default()).unwrap();
    for (k, comp) in comps.into_iter().enumerate() {
        let inputs = jit_overlay::workload::request_inputs(&comp, seed(k as u64));
        let want = cpu::eval(&comp, &inputs).unwrap();
        let rf = fused
            .submit(&Request::dynamic(comp.clone(), inputs.clone()))
            .unwrap();
        let ru = plain.submit(&Request::dynamic(comp, inputs)).unwrap();
        for (label, got) in [("fused", &rf.run.output), ("unfused", &ru.run.output)] {
            match (got, &want) {
                (Value::Scalar(g), Value::Scalar(w)) => {
                    assert_eq!(g.to_bits(), w.to_bits(), "case {k} {label}");
                }
                (Value::Vector(g), Value::Vector(w)) => {
                    assert_eq!(g.len(), w.len(), "case {k} {label}");
                    for i in 0..g.len() {
                        assert_eq!(g[i].to_bits(), w[i].to_bits(), "case {k} {label} i={i}");
                    }
                }
                _ => panic!("case {k} {label}: output shape mismatch"),
            }
        }
    }
    assert!(fused.metrics.stages_fused > 0, "stream must exercise the fusion pass");
    assert_eq!(plain.metrics.stages_fused, 0, "fusion must stay off by default");
}

// ---------------------------------------------------------------------------
// Composition cache keys: random equal compositions hash equal, mutants differ
// ---------------------------------------------------------------------------

#[test]
fn prop_cache_key_stability() {
    use OperatorKind::*;
    let pool = [Abs, Neg, Square, Relu];
    let mut rng = Rng::new(seed(0xCACE));
    for _ in 0..CASES {
        let len = 1 + rng.below(3);
        let ops: Vec<OperatorKind> = (0..len).map(|_| pool[rng.below(pool.len())]).collect();
        let n = 64 << rng.below(4);
        let a = Composition::chain(&ops, n).unwrap();
        let b = Composition::chain(&ops, n).unwrap();
        assert_eq!(a.cache_key(), b.cache_key());
        let c = Composition::chain(&ops, n * 2).unwrap();
        assert_ne!(a.cache_key(), c.cache_key());
    }
}
