//! Per-fabric placement specialization (ISSUE 4).
//!
//! The bug class under test: the pool-wide `AcceleratorCache` used to
//! freeze the *compiling* fabric's placement inside the cached accelerator
//! and replay it verbatim after an affinity spill — silently overwriting
//! another fabric's residents even when free tiles existed there. The
//! tentpole splits the accelerator into a fabric-independent program and a
//! per-fabric `PlacementPlan`, respecializing the placement (placement
//! phase only) the first time a cached accelerator lands on a new fabric.
//!
//! Determinism technique for the pool test: the shared cache is pre-warmed
//! by a standalone coordinator (`WorkerPool::with_cache_paused`), so the
//! thief's first stolen request is *provably* a respecialization — no race
//! against the home worker compiling the spec first.

use std::sync::Arc;
use std::time::Duration;

use jit_overlay::bitstream::OperatorKind;
use jit_overlay::coordinator::{AcceleratorCache, Coordinator, Request, WorkerPool};
use jit_overlay::patterns::Composition;
use jit_overlay::{workload, OverlayConfig, ServiceConfig};

fn vmul_req(n: usize, seed: u64) -> Request {
    let comp = Composition::vmul_reduce(n);
    let inputs = workload::request_inputs(&comp, seed);
    Request::dynamic(comp, inputs)
}

fn map_req(op: OperatorKind, n: usize, seed: u64) -> Request {
    let comp = Composition::map(op, n);
    let inputs = workload::request_inputs(&comp, seed);
    Request::dynamic(comp, inputs)
}

/// The regression the tentpole exists for, reproduced at the coordinator
/// level: compile a composition on fabric A, then "spill" it to fabric B
/// whose occupancy differs while free tiles abound. Fabric B's residents
/// must survive — on pre-ISSUE-4 main the replayed placement overwrote
/// them (this test fails there with `pr_replaced == 1` and the Abs
/// operator evicted).
#[test]
fn spilled_composition_respects_other_fabrics_residents() {
    let n = 256;
    let cache = Arc::new(AcceleratorCache::new(1));
    let mut a = Coordinator::with_cache(OverlayConfig::default(), cache.clone()).unwrap();
    let mut b = Coordinator::with_cache(OverlayConfig::default(), cache.clone()).unwrap();

    // fabric A compiles vmul-reduce; its placement reflects A's empty
    // occupancy (the first two snake tiles)
    a.submit(&vmul_req(n, 1)).unwrap();
    assert_eq!(a.metrics.jit_compiles, 1);

    // fabric B first hosts a different accelerator: map(Abs) lands on B's
    // first snake tile — exactly where A's frozen placement points
    b.submit(&map_req(OperatorKind::Abs, n, 2)).unwrap();
    let abs_tile = b
        .engine
        .fabric
        .tiles
        .iter()
        .position(|t| t.resident == Some(OperatorKind::Abs))
        .expect("Abs resident on fabric B");
    let free_before = b.engine.fabric.free_tiles().len();
    assert!(free_before >= 2, "free tiles must exist for the incoming placement");

    // the cached composition now lands on B (the affinity-spill replay)
    let resp = b.submit(&vmul_req(n, 3)).unwrap();
    assert!(resp.cached, "the shared program must come from the cache");
    // B's only full compile is its own map(Abs); the spilled vmul reuses
    // the shared front end
    assert_eq!(b.metrics.jit_compiles, 1, "no front-end recompile on a spill");

    // B's resident survived: the placement was respecialized against B's
    // occupancy instead of replayed verbatim
    assert_eq!(
        b.engine.fabric.tiles[abs_tile].resident,
        Some(OperatorKind::Abs),
        "spill replay clobbered fabric B's resident despite {free_before} free tiles"
    );
    assert_eq!(b.metrics.pr_replaced, 0, "no resident may be overwritten");
    assert_eq!(b.metrics.evictions, 0);
    assert_eq!(b.metrics.placement_respecializations, 1);
    assert_eq!(
        b.metrics.residency_clobbers_avoided, 1,
        "the foreign placement would have clobbered — that avoidance is counted"
    );

    // and the respecialized plan is now cached per (composition, fabric):
    // a repeat on B is a full hit with zero JIT time
    let again = b.submit(&vmul_req(n, 4)).unwrap();
    assert!(again.cached);
    assert_eq!(again.jit_seconds, 0.0);
    assert_eq!(b.metrics.cache_hits, 1);
    assert_eq!(b.metrics.placement_respecializations, 1);

    // fabric A kept its own plan: repeats there are hits too, and the two
    // fabrics hold *different* placements of one shared program
    let ra = a.submit(&vmul_req(n, 5)).unwrap();
    assert!(ra.cached);
    assert_eq!(a.metrics.cache_hits, 1);
    let mul_tile_a = a
        .engine
        .fabric
        .tiles
        .iter()
        .position(|t| t.resident == Some(OperatorKind::Mul))
        .unwrap();
    let mul_tile_b = b
        .engine
        .fabric
        .tiles
        .iter()
        .position(|t| t.resident == Some(OperatorKind::Mul))
        .unwrap();
    assert_ne!(mul_tile_a, mul_tile_b, "B's specialized placement avoids the occupied tile");
}

/// Deterministic pool test (PR 3 `new_paused`/`start_worker` gates): a
/// stolen composition group triggers at most one placement
/// respecialization on the thief and zero on the home worker, with the
/// conservation law `hits + respecializations + compiles == requests`
/// holding in the aggregate.
#[test]
fn stolen_group_respecializes_once_on_thief_only() {
    const K: usize = 4; // jobs per composition group
    let (a, b) = workload::home_aligned_conflicting_pair(2).expect("pigeonhole over three keys");

    // Pre-warm the shared cache from a standalone fabric: b's program (and
    // that fabric's plan) are cached before the pool exists, so whoever
    // serves b first pays exactly one placement respecialization — never a
    // full compile, and never a race over who compiles the spec.
    let cache = Arc::new(AcceleratorCache::new(4));
    let mut warm = Coordinator::with_cache(OverlayConfig::default(), cache.clone()).unwrap();
    warm.submit(&Request::dynamic(b.clone(), workload::request_inputs(&b, 99))).unwrap();
    assert_eq!(warm.metrics.jit_compiles, 1);

    let home = (a.cache_key() % 2) as usize;
    let thief = 1 - home;
    let service = ServiceConfig {
        queue_capacity: 2 * K,
        max_queue_skew: 1_000_000, // no spills: the backlog queues at home
        steal_min_depth: K + 1,    // exactly one steal: 2K ≥ K+1 > K
        ..ServiceConfig::with_workers(2)
    };
    let pool = WorkerPool::with_cache_paused(OverlayConfig::default(), service, cache).unwrap();

    // interleave a,b,a,b,… so the tail group is b's (the pre-warmed key)
    let reqs: Vec<Request> = workload::interleaved_stream(&[a.clone(), b.clone()], K)
        .into_iter()
        .enumerate()
        .map(|(i, comp)| {
            let inputs = workload::request_inputs(&comp, i as u64);
            Request::dynamic(comp, inputs)
        })
        .collect();
    let pending: Vec<_> = reqs.iter().map(|r| pool.submit(r.clone()).unwrap()).collect();
    assert_eq!(pool.queue_depth(home), 2 * K);
    assert_eq!(pool.queue_depth(thief), 0);

    // release only the thief: it steals the whole b group and serves it
    pool.start_worker(thief);
    let mut waited = 0;
    while pool.snapshot().requests < K as u64 {
        std::thread::sleep(Duration::from_millis(1));
        waited += 1;
        assert!(waited < 10_000, "thief never served the stolen group");
    }
    assert_eq!(pool.snapshot().steals, 1);
    assert_eq!(pool.queue_depth(home), K, "whole-group steal must leave a's jobs");

    pool.start_worker(home);
    for rx in pending {
        rx.recv().unwrap().unwrap();
    }
    let report = pool.shutdown();

    // the thief served the stolen b group: one respecialization (the spec
    // was cached, its plan was foreign), then hits
    assert_eq!(report.per_worker[thief].requests, K as u64);
    assert_eq!(report.per_worker[thief].placement_respecializations, 1);
    assert_eq!(report.per_worker[thief].jit_compiles, 0);
    assert_eq!(report.per_worker[thief].cache_hits, (K - 1) as u64);
    // the home worker compiled its own composition and respecialized nothing
    assert_eq!(report.per_worker[home].requests, K as u64);
    assert_eq!(report.per_worker[home].placement_respecializations, 0);
    assert_eq!(report.per_worker[home].jit_compiles, 1);
    // conservation: every pool request is exactly one of hit / respec / compile
    let m = &report.aggregate;
    assert_eq!(
        m.cache_hits + m.placement_respecializations + m.jit_compiles,
        m.requests,
        "hits + respecializations + compiles must equal requests"
    );
    // nothing was clobbered anywhere: each fabric hosted one group
    assert_eq!(m.pr_replaced, 0);
    assert_eq!(m.evictions, 0);
    assert!(report.panicked_workers.is_empty());
}
