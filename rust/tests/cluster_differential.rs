//! Cluster sharding, end to end: the seeded differential proof.
//!
//! The contract: a P-pool cluster is an *invisible* scale-out of one
//! coordinator. Concretely, over a seeded churn stream that includes one
//! mid-stream pool join and one pool death (retire and detected death
//! share the evacuation path):
//!
//! * **bit-identical outputs** — every response fingerprint equals the
//!   1-pool reference coordinator's, whichever pool served it, through
//!   the join and through the death;
//! * **conservation** — cluster-wide (members + the retired pool),
//!   `cache_hits + placement_respecializations + jit_compiles ==
//!   requests`: no request is lost, duplicated, or double-billed by
//!   evacuation or warm-start;
//! * **warm-start** — the joining pool receives the cached
//!   fabric-independent programs, scores `warm_start_hits > 0`, and
//!   pays *strictly fewer* JIT compiles than the same join with
//!   warm-start off (the cold control);
//! * **ring stability** — growing P→P+1 pools re-homes at most
//!   2/(P+1) of ≥64 distinct composition keys, every moved key landing
//!   on the new pool.

use jit_overlay::coordinator::{Cluster, ClusterReport, Coordinator, HashRing, Request};
use jit_overlay::patterns::Composition;
use jit_overlay::testkit::fingerprint;
use jit_overlay::workload;
use jit_overlay::{ClusterConfig, OverlayConfig, ServiceConfig};

fn request(comp: &Composition, k: u64) -> Request {
    Request::dynamic(comp.clone(), workload::request_inputs(comp, k))
}

/// Phase boundaries of the churn scenario, as indices into [`stream`]:
/// the extra pool joins before request `JOIN_AT`, the first pool dies
/// before request `RETIRE_AT`.
const JOIN_AT: usize = 112;
const RETIRE_AT: usize = 184;

/// The seeded churn stream: a mixed prefix, then a 48-key wide cohort
/// (all compiled — and so all shipped at the join), the same cohort
/// replayed *after* the join (the joiner's owned share claims its
/// shipped programs), more churn across the pool death, and a tail.
/// The cohort and the hot mix are seed-independent, so the warm-start
/// assertions hold for every `$JIT_OVERLAY_SEED`; only the cold tail of
/// the churn segments varies.
fn stream() -> Vec<(Composition, u64)> {
    let seed = workload::env_seed(0xD1FF);
    let mut comps = Vec::new();
    comps.extend(workload::churn_compositions(64, 256, seed));
    comps.extend(workload::wide_cohort(48));
    debug_assert_eq!(comps.len(), JOIN_AT);
    comps.extend(workload::wide_cohort(48));
    comps.extend(workload::churn_compositions(24, 256, seed ^ 0x5EED));
    debug_assert_eq!(comps.len(), RETIRE_AT);
    comps.extend(workload::churn_compositions(16, 256, seed ^ 0xFEED));
    comps.into_iter().enumerate().map(|(k, c)| (c, k as u64)).collect()
}

/// Drive the full churn scenario through a 2-pool cluster: join a third
/// pool before `JOIN_AT`, retire the first pool before `RETIRE_AT`.
/// Returns every output fingerprint, the final report, and the joined
/// pool's id.
fn drive(reqs: &[(Composition, u64)], warm_start: bool) -> (Vec<Vec<u32>>, ClusterReport, u64) {
    let ccfg = ClusterConfig { warm_start, ..ClusterConfig::default() };
    let service = ServiceConfig::with_workers(2);
    let cluster =
        Cluster::homogeneous(OverlayConfig::default(), service.clone(), ccfg, 2).unwrap();
    let first = cluster.pool_ids()[0];
    let mut joined = 0;
    let mut outs = Vec::with_capacity(reqs.len());
    for (i, (comp, k)) in reqs.iter().enumerate() {
        if i == JOIN_AT {
            joined = cluster.join(OverlayConfig::default(), service.clone()).unwrap();
        }
        if i == RETIRE_AT {
            cluster.retire(first).unwrap();
        }
        let resp = cluster.submit_wait(request(comp, *k)).unwrap();
        outs.push(fingerprint(&resp.run.output));
    }
    (outs, cluster.shutdown(), joined)
}

#[test]
fn cluster_with_join_and_death_is_bit_identical_to_one_coordinator() {
    let reqs = stream();
    let total = reqs.len() as u64;

    // the 1-pool reference: one coordinator, strictly sequential
    let mut coord = Coordinator::new(OverlayConfig::default()).unwrap();
    let reference: Vec<Vec<u32>> = reqs
        .iter()
        .map(|(comp, k)| fingerprint(&coord.submit(&request(comp, *k)).unwrap().run.output))
        .collect();

    let (outs_warm, warm, joined_warm) = drive(&reqs, true);
    let (outs_cold, cold, joined_cold) = drive(&reqs, false);

    assert_eq!(outs_warm, reference, "warm cluster must match the reference bit for bit");
    assert_eq!(outs_cold, reference, "cold cluster must match the reference bit for bit");

    for (name, report) in [("warm", &warm), ("cold", &cold)] {
        let m = &report.aggregate;
        assert_eq!(m.requests, total, "{name}: every request served exactly once");
        assert_eq!(
            m.cache_hits + m.placement_respecializations + m.jit_compiles,
            total,
            "{name}: conservation across join, death, and warm-start"
        );
        assert_eq!(m.pool_joins, 3, "{name}: two founders + one mid-stream join");
        assert_eq!(m.pool_evacuations, 1, "{name}: one pool death");
        assert_eq!(report.retired.len(), 1);
        assert_eq!(report.per_pool.len(), 2, "{name}: the survivor and the joiner remain");
    }

    assert!(warm.aggregate.warm_start_hits > 0, "the joiner must claim shipped programs");
    assert_eq!(cold.aggregate.warm_start_hits, 0, "nothing is shipped with warm-start off");

    // the joined pool itself: warm-start converts its compiles into
    // placement-only respecializations. Ring geometry is identical in
    // both runs (same member ids, same vnodes), so the cold joiner's
    // extra compiles are exactly the claims the warm joiner got shipped.
    let joined_metrics = |report: &ClusterReport, id: u64| {
        report.per_pool.iter().find(|(pid, _)| *pid == id).map(|(_, m)| *m).unwrap()
    };
    let jw = joined_metrics(&warm, joined_warm);
    let jc = joined_metrics(&cold, joined_cold);
    assert!(jc.jit_compiles > 0, "the cold joiner must compile its owned keys");
    assert!(
        jw.jit_compiles < jc.jit_compiles,
        "warm-start must strictly cut the joiner's compiles: warm={} cold={}",
        jw.jit_compiles,
        jc.jit_compiles
    );
}

#[test]
fn pool_join_rehomes_at_most_two_over_p_plus_one_of_composition_keys() {
    // ≥64 distinct real composition keys; the ring sees them exactly as
    // the cluster router does (fusion off ⇒ unsalted cache keys)
    let keys: Vec<u64> = workload::wide_cohort(96).iter().map(|c| c.cache_key()).collect();
    let vnodes = ClusterConfig::default().vnodes;
    for p in [2usize, 3, 4] {
        // member ids are join-ordered, exactly as Cluster assigns them
        let seeds: Vec<u64> = (0..p as u64).collect();
        let mut grown = seeds.clone();
        grown.push(p as u64);
        let before = HashRing::new(&seeds, vnodes);
        let after = HashRing::new(&grown, vnodes);
        let mut moved = 0usize;
        for &key in &keys {
            let (a, b) = (before.owner(key), after.owner(key));
            if a != b {
                assert_eq!(b, p, "a re-homed key must land on the joined pool");
                moved += 1;
            }
        }
        let bound = 2.0 / (p as f64 + 1.0);
        let frac = moved as f64 / keys.len() as f64;
        assert!(frac <= bound, "{p}→{} pools re-homed {frac:.3} > {bound:.3}", p + 1);
        assert!(moved > 0, "the joined pool must take some arc");
    }
}

/// The reactor front end serves through a cluster exactly as through a
/// pool — the `Dispatch` seam the socket tier rides on.
#[test]
fn reactor_frontend_dispatches_through_the_cluster() {
    use jit_overlay::coordinator::Frontend;
    use jit_overlay::FrontendConfig;
    use std::sync::Arc;

    let cluster = Arc::new(
        Cluster::homogeneous(
            OverlayConfig::default(),
            ServiceConfig::with_workers(1),
            ClusterConfig::default(),
            2,
        )
        .unwrap(),
    );
    let front =
        Frontend::new(cluster.clone(), FrontendConfig::default(), cluster.metrics.clone())
            .unwrap();
    let threads = front.spawn().unwrap();
    let handle = front.open_session();
    let cohort = workload::wide_cohort(8);
    for (k, comp) in cohort.iter().enumerate() {
        handle.submit(request(comp, k as u64)).unwrap();
    }
    for _ in 0..cohort.len() {
        handle.recv().unwrap();
    }
    handle.close();
    drop(handle);
    threads.shutdown();
    drop(front);
    let Ok(cluster) = Arc::try_unwrap(cluster) else {
        panic!("front end leaked the cluster");
    };
    let report = cluster.shutdown();
    assert_eq!(report.aggregate.requests, 8);
    assert_eq!(
        report.aggregate.cache_hits
            + report.aggregate.placement_respecializations
            + report.aggregate.jit_compiles,
        8
    );
}
