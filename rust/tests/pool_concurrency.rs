//! Pool concurrency smoke: M client threads × N workers submitting
//! interleaved compositions. Checks the three service-layer invariants:
//!
//! 1. **per-client ordering** — each client drains its replies in submit
//!    order and every value matches the CPU reference;
//! 2. **metric conservation** — the pool's atomic aggregate equals the sum
//!    of the per-worker records;
//! 3. **affinity wins** — on a repeated-composition stream the pool's
//!    residency hit-rate strictly exceeds the single-worker baseline
//!    (conflicting accelerators stop thrashing one fabric), and the shared
//!    JIT cache keeps the accelerator hit-rate at least as high.

use std::sync::Arc;

use jit_overlay::bitstream::OperatorKind;
use jit_overlay::coordinator::{Coordinator, Request, WorkerPool};
use jit_overlay::exec::cpu::{self, Value};
use jit_overlay::patterns::Composition;
use jit_overlay::{workload, OverlayConfig, ServiceConfig};

fn pool(workers: usize) -> WorkerPool {
    WorkerPool::new(OverlayConfig::default(), ServiceConfig::with_workers(workers)).unwrap()
}

/// A pool whose scheduler never spills or steals: pure home/sticky
/// affinity. The deep pipelined queues of the ordering test would
/// otherwise make the spill/steal decisions (and thus compile counts)
/// timing-dependent.
fn affinity_only_pool(workers: usize) -> WorkerPool {
    let service =
        ServiceConfig { max_queue_skew: 1_000_000, ..ServiceConfig::with_workers(workers) }
            .without_stealing();
    WorkerPool::new(OverlayConfig::default(), service).unwrap()
}

fn agree(a: &Value, b: &Value) -> bool {
    const TOL: f32 = 1e-3;
    match (a, b) {
        (Value::Scalar(x), Value::Scalar(y)) => (x - y).abs() <= TOL * (1.0 + y.abs()),
        (Value::Vector(x), Value::Vector(y)) => {
            x.len() == y.len()
                && x.iter().zip(y).all(|(p, q)| (p - q).abs() <= TOL * (1.0 + q.abs()))
        }
        _ => false,
    }
}

/// One client's interleaved request sequence (4 compositions cycling).
fn client_stream(client: u64, count: usize, n: usize) -> Vec<Request> {
    let comps = [
        Composition::vmul_reduce(n),
        Composition::map(OperatorKind::Abs, n),
        Composition::filter_reduce(0.25, n),
        Composition::axpy(1.5, n),
    ];
    (0..count)
        .map(|i| {
            let comp = comps[i % comps.len()].clone();
            let inputs = workload::request_inputs(&comp, client * 1_000 + i as u64);
            Request::dynamic(comp, inputs)
        })
        .collect()
}

#[test]
fn clients_times_workers_preserve_ordering_and_metrics_conserve() {
    const CLIENTS: u64 = 4;
    const PER_CLIENT: usize = 12;
    let pool = Arc::new(affinity_only_pool(3));

    let mut joins = Vec::new();
    for c in 0..CLIENTS {
        let p = pool.clone();
        joins.push(std::thread::spawn(move || {
            let reqs = client_stream(c, PER_CLIENT, 256);
            let expected: Vec<Value> =
                reqs.iter().map(|r| cpu::eval(&r.comp, &r.inputs).unwrap()).collect();
            // pipelined submission: keep reply channels in submit order
            let replies: Vec<_> =
                reqs.iter().map(|r| p.submit(r.clone()).unwrap()).collect();
            for (i, rx) in replies.into_iter().enumerate() {
                let resp = rx.recv().expect("worker hung up").expect("request failed");
                assert!(
                    agree(&resp.run.output, &expected[i]),
                    "client {c} response {i} out of order or wrong: {:?} vs {:?}",
                    resp.run.output,
                    expected[i]
                );
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }

    let live = pool.snapshot();
    assert_eq!(live.requests, CLIENTS * PER_CLIENT as u64);

    let report = Arc::try_unwrap(pool).ok().expect("clients done").shutdown();
    assert_eq!(report.aggregate.requests, CLIENTS * PER_CLIENT as u64);

    // pool aggregate must equal the sum of worker records (counters exactly,
    // seconds up to the aggregate's nanosecond rounding)
    let sum = report.worker_sum();
    assert_eq!(sum.requests, report.aggregate.requests);
    assert_eq!(sum.jit_compiles, report.aggregate.jit_compiles);
    assert_eq!(sum.cache_hits, report.aggregate.cache_hits);
    assert_eq!(sum.pr_downloads, report.aggregate.pr_downloads);
    assert_eq!(sum.pr_region_hits, report.aggregate.pr_region_hits);
    assert_eq!(sum.pr_replaced, report.aggregate.pr_replaced);
    assert_eq!(sum.evictions, report.aggregate.evictions);
    assert!(report.panicked_workers.is_empty());
    assert!((sum.jit_seconds - report.aggregate.jit_seconds).abs() < 1e-3);
    assert!((sum.busy_seconds - report.aggregate.busy_seconds).abs() < 1e-3);

    // 4 distinct compositions, each JIT-compiled exactly once pool-wide
    // (affinity pins a composition to one worker; the cache is shared)
    assert_eq!(report.cached_accelerators, 4);
    assert_eq!(report.aggregate.jit_compiles, 4);
    assert_eq!(
        report.aggregate.cache_hits,
        CLIENTS * PER_CLIENT as u64 - 4
    );
}

/// Two 5-stage chains that cannot co-reside on one 9-tile fabric: serving
/// them interleaved from a single worker thrashes the PR regions on every
/// switch (the contention of the coordinator's batching tests).
fn chain_a(n: usize) -> Composition {
    use OperatorKind::*;
    Composition::chain(&[Neg, Abs, Square, Relu, Neg], n).unwrap()
}

fn chain_b(n: usize) -> Composition {
    use OperatorKind::*;
    Composition::chain(&[Abs, Neg, Relu, Square, Abs], n).unwrap()
}

/// Find a vector length whose two chain compositions hash to *different*
/// home workers, so the affinity win is deterministic for this process.
fn conflicting_pair(workers: u64) -> Option<(Composition, Composition)> {
    for n in [512usize, 640, 768, 896, 1024, 1152, 1280, 1408, 1536, 1664] {
        let (a, b) = (chain_a(n), chain_b(n));
        if a.cache_key() % workers != b.cache_key() % workers {
            return Some((a, b));
        }
    }
    None
}

#[test]
fn affinity_residency_beats_single_worker_baseline() {
    const ROUNDS: usize = 8;
    let Some((a, b)) = conflicting_pair(2) else {
        // hash layout put every candidate on one worker — astronomically
        // unlikely (2^-10); bail out rather than flake
        eprintln!("skipping: no conflicting pair under this hasher");
        return;
    };
    let reqs: Vec<Request> = (0..2 * ROUNDS)
        .map(|i| {
            let comp = if i % 2 == 0 { a.clone() } else { b.clone() };
            let inputs = workload::request_inputs(&comp, i as u64);
            Request::dynamic(comp, inputs)
        })
        .collect();

    // single-worker baseline: naive interleaved serving on one fabric
    let mut single = Coordinator::new(OverlayConfig::default()).unwrap();
    for r in &reqs {
        single.submit(r).unwrap();
    }
    let single_m = single.metrics;
    assert!(single_m.evictions >= 1, "baseline must actually thrash");

    // pool: the two chains live on different fabrics and stay resident
    let pool = pool(2);
    for r in &reqs {
        pool.submit_wait(r.clone()).unwrap();
    }
    let report = pool.shutdown();
    let pool_m = report.aggregate;

    assert_eq!(pool_m.requests, single_m.requests);
    assert!(
        pool_m.pr_downloads < single_m.pr_downloads,
        "pool {} !< single {}",
        pool_m.pr_downloads,
        single_m.pr_downloads
    );
    assert!(
        pool_m.pr_hit_rate() > single_m.pr_hit_rate(),
        "pool residency hit-rate {:.2} must exceed single-worker {:.2}",
        pool_m.pr_hit_rate(),
        single_m.pr_hit_rate()
    );
    assert!(pool_m.hit_rate() >= single_m.hit_rate());
    assert_eq!(pool_m.evictions, 0, "affinity must prevent capacity thrash");
    // the thrash signal: every post-warmup single-worker download overwrote
    // the other chain's operators; pool fabrics never overwrite anything
    assert_eq!(pool_m.pr_replaced, 0);
    assert!(single_m.pr_replaced > 0);
    // both workers served (the pair hashed apart) and each fabric ended
    // with its chain's 5 stages resident
    let active = report.per_worker.iter().filter(|m| m.requests > 0).count();
    assert_eq!(active, 2);
    for (resident, total) in report.per_worker_residency {
        assert_eq!((resident, total), (5, 9));
    }
}
