//! Regression goldens pinned to the paper's published numbers, so timing
//! model refactors cannot silently drift from the reproduction targets:
//!
//! * **Fig. 3** — the dynamic overlay's "only penalty": a full 3×3 PR
//!   download costs ≈ 1.250 ms through the ICAP, large regions ≈ 0.1775 ms
//!   apiece, and the cost is incurred once (residency amortizes repeats);
//! * **Fig. 2** — static-overlay scheduling wastes pass-through tiles
//!   (utilization 1.0 / 0.67 / 0.5 for S1/S2/S3 on the two-stage
//!   VMUL&Reduce) while dynamic placement is always contiguous.

use jit_overlay::bitstream::OperatorKind;
use jit_overlay::exec::Engine;
use jit_overlay::jit::Jit;
use jit_overlay::overlay::Mesh;
use jit_overlay::patterns::Composition;
use jit_overlay::place::{StaticPlacer, StaticScenario};
use jit_overlay::timing::Target;
use jit_overlay::{workload, OverlayConfig};

/// Paper: "around 1.250 ms" to populate the whole 3×3 overlay.
const FULL_RECONFIG_MS: f64 = 1.250;
/// Large-region frame bytes over ICAP bandwidth (67 456 B / 380 MB/s).
const LARGE_REGION_MS: f64 = 0.1775;
/// Small-region frame bytes over ICAP bandwidth (48 640 B / 380 MB/s).
const SMALL_REGION_MS: f64 = 0.1280;

#[test]
fn fig3_full_overlay_pr_download_is_1_25_ms() {
    let s = OverlayConfig::default().full_reconfig_seconds() * 1e3;
    assert!(
        (s - FULL_RECONFIG_MS).abs() < 0.05,
        "full-overlay PR download drifted from the paper: {s:.4} ms"
    );
}

#[test]
fn fig3_per_region_download_goldens() {
    let cfg = OverlayConfig::default();
    let large_ms = cfg.large_bitstream_bytes as f64 / cfg.clocks.icap_bytes_per_sec * 1e3;
    let small_ms = cfg.small_bitstream_bytes as f64 / cfg.clocks.icap_bytes_per_sec * 1e3;
    assert!(
        (large_ms - LARGE_REGION_MS).abs() / LARGE_REGION_MS < 0.02,
        "large-region download drifted: {large_ms:.4} ms"
    );
    assert!(
        (small_ms - SMALL_REGION_MS).abs() / SMALL_REGION_MS < 0.02,
        "small-region download drifted: {small_ms:.4} ms"
    );
    // region mix: 2 large + 7 small regions must reassemble the 1.25 ms
    let total = 2.0 * large_ms + 7.0 * small_ms;
    assert!((total - FULL_RECONFIG_MS).abs() < 0.05, "mix drifted: {total:.4} ms");
}

#[test]
fn fig3_pr_cost_is_incurred_once_then_amortized() {
    let mut e = Engine::new(OverlayConfig::default()).unwrap();
    let comp = Composition::vmul_reduce(4096);
    let acc = Jit.compile(&e.fabric, &e.lib, &comp).unwrap();
    let (a, b) = workload::paper_16kb(1);
    let first = e.run(&acc, &[a.clone(), b.clone()], Target::DynamicOverlay).unwrap();
    let r1 = first.reconfig.unwrap();
    // two small-region downloads (Mul + AccSum) priced through the ICAP
    assert_eq!(r1.downloads, 2);
    let want_ms = 2.0 * SMALL_REGION_MS;
    assert!(
        (r1.seconds * 1e3 - want_ms).abs() / want_ms < 0.05,
        "2-stage PR cost drifted: {:.4} ms",
        r1.seconds * 1e3
    );
    // repeat request: residency cache, zero PR time (the amortization claim)
    let second = e.run(&acc, &[a, b], Target::DynamicOverlay).unwrap();
    let r2 = second.reconfig.unwrap();
    assert_eq!(r2.downloads, 0);
    assert_eq!(r2.seconds, 0.0);
    assert_eq!(r2.hit_rate(), 1.0);
}

/// Tile utilization of a two-stage pipeline placement: useful stages over
/// stages + pass-through tiles.
fn utilization(stages: usize, pass_throughs: usize) -> f64 {
    stages as f64 / (stages + pass_throughs) as f64
}

#[test]
fn fig2_static_scenarios_waste_pass_through_tiles() {
    let mesh = Mesh::new(3, 3);
    let goldens = [
        (StaticScenario::S1, 0usize, 1.0f64),
        (StaticScenario::S2, 1, 2.0 / 3.0),
        (StaticScenario::S3, 2, 0.5),
    ];
    for (s, pass, util) in goldens {
        assert_eq!(s.pass_throughs(), pass, "{s:?} pass-through count drifted");
        let p = StaticPlacer::new(s)
            .place_pair(&mesh, OperatorKind::Mul, OperatorKind::AccSum)
            .unwrap();
        let gap = mesh.manhattan(p.assignments[0].tile, p.assignments[1].tile) - 1;
        assert_eq!(gap, pass, "{s:?} placement does not realize its scenario");
        let u = utilization(2, gap);
        assert!((u - util).abs() < 1e-12, "{s:?} utilization {u} != golden {util}");
    }
}

#[test]
fn fig2_dynamic_placement_is_fully_utilized() {
    let e = Engine::new(OverlayConfig::default()).unwrap();
    let acc = Jit.compile(&e.fabric, &e.lib, &Composition::vmul_reduce(4096)).unwrap();
    // the dynamic overlay's contiguity invariant: zero pass-through tiles
    assert_eq!(acc.total_hops(), 0);
    assert_eq!(utilization(acc.stages().len(), acc.total_hops()), 1.0);
}

#[test]
fn fig2_hop_cost_scales_with_pass_throughs() {
    let mut e = Engine::new(OverlayConfig::default()).unwrap();
    let comp = Composition::vmul_reduce(4096);
    let acc = Jit.compile(&e.fabric, &e.lib, &comp).unwrap();
    let (a, b) = workload::paper_16kb(2);
    let hop = |e: &mut Engine, s: StaticScenario| {
        e.run(&acc, &[a.clone(), b.clone()], Target::StaticOverlay(s))
            .unwrap()
            .timing
            .hop_s
    };
    let h1 = hop(&mut e, StaticScenario::S1);
    let h2 = hop(&mut e, StaticScenario::S2);
    let h3 = hop(&mut e, StaticScenario::S3);
    assert_eq!(h1, 0.0, "adjacent producer/consumer pays no hop cost");
    assert!(h2 > 0.0);
    let ratio = h3 / h2;
    assert!(
        (1.5..=2.5).contains(&ratio),
        "store-and-forward cost must scale ~linearly in pass-throughs, got {ratio}"
    );
}
