//! Chaos soak: the deterministic fault plane driven through every layer of
//! the serving stack, asserting the recovery ladder's contract end to end.
//!
//! Every schedule here is an **explicit ordinal list** (`transient_downloads:
//! vec![2, 5]`), never a seeded permille rate: the injected faults land at
//! exact, reproducible points in the stream, so the assertions are exact
//! counts, not statistical expectations. The properties:
//!
//! * transient download faults are retried invisibly — results stay
//!   **bit-identical** to a fault-free run (fingerprint-compared);
//! * a permanent region fault quarantines the tile and re-places the
//!   accelerator elsewhere — correct values, no CPU fallback;
//! * an injected worker panic is supervised: the coordinator is rebuilt in
//!   place and the staged burst replayed — no thread dies, no reply is
//!   lost (the injected panic does print to stderr via the default hook);
//! * under all three fault kinds at once, over real localhost TCP, every
//!   request gets **exactly one** reply with the correct value.

use std::net::TcpStream;
use std::sync::Arc;

use jit_overlay::coordinator::net::NetServer;
use jit_overlay::coordinator::wire::{read_frame, write_frame, ClientMsg, ServerMsg};
use jit_overlay::coordinator::{Coordinator, Frontend, Metrics, Request, WorkerPool};
use jit_overlay::exec::cpu::{self, Value};
use jit_overlay::patterns::Composition;
use jit_overlay::testkit::fingerprint;
use jit_overlay::workload;
use jit_overlay::{
    FaultPlane, FaultSpec, FrontendConfig, NetConfig, OverlayConfig, ServiceConfig,
};

fn agree(a: &Value, b: &Value) -> bool {
    const TOL: f32 = 1e-3;
    match (a, b) {
        (Value::Scalar(x), Value::Scalar(y)) => (x - y).abs() <= TOL * (1.0 + y.abs()),
        (Value::Vector(x), Value::Vector(y)) => {
            x.len() == y.len()
                && x.iter().zip(y).all(|(p, q)| (p - q).abs() <= TOL * (1.0 + q.abs()))
        }
        _ => false,
    }
}

/// The value the server must compute for a wire request: inputs are
/// synthesized from `(n, seed)` exactly as the serving tier does.
fn expected_for(n: usize, seed: u64, pattern: &str) -> Value {
    let comp = jit_overlay::patterns::parse_pattern(pattern, n).unwrap();
    let inputs: Vec<Vec<f32>> = (0..comp.inputs)
        .map(|c| workload::vector(n, seed.wrapping_add(c as u64), 0.1, 2.0))
        .collect();
    cpu::eval(&comp, &inputs).unwrap()
}

fn one_worker(spec: FaultSpec) -> ServiceConfig {
    ServiceConfig { faults: spec, ..ServiceConfig::with_workers(1).without_stealing() }
}

/// Transient download faults are absorbed by the retry budget: the served
/// values are bit-for-bit identical to a fault-free run of the same
/// stream, and only the `download_retries` counter betrays the injection.
#[test]
fn transient_download_faults_leave_results_bit_identical() {
    let reqs: Vec<Request> = workload::soak_compositions(12, 256)
        .into_iter()
        .enumerate()
        .map(|(k, comp)| {
            let inputs = workload::request_inputs(&comp, k as u64);
            Request::dynamic(comp, inputs)
        })
        .collect();
    let run = |spec: FaultSpec| -> (Vec<Vec<u32>>, Metrics) {
        let pool = WorkerPool::new(OverlayConfig::default(), one_worker(spec)).unwrap();
        let mut prints = Vec::new();
        for r in &reqs {
            let resp = pool.submit_wait(r.clone()).unwrap();
            prints.push(fingerprint(&resp.run.output));
        }
        (prints, pool.shutdown().aggregate)
    };

    let (clean, m_clean) = run(FaultSpec::default());
    let spec = FaultSpec { transient_downloads: vec![2, 5], ..FaultSpec::default() };
    let (faulted, m_faulted) = run(spec);

    assert_eq!(clean, faulted, "transient faults must not perturb a single result bit");
    assert_eq!((m_clean.requests, m_faulted.requests), (12, 12));
    assert_eq!(m_clean.download_retries, 0);
    assert!(m_faulted.download_retries >= 1, "the download schedule must actually fire");
    assert_eq!(m_faulted.tiles_quarantined, 0, "transient severity never quarantines");
    assert_eq!(m_faulted.workers_restarted, 0);
}

/// A permanent region fault walks the quarantine rung: the tile is marked
/// dead, the accelerator re-places around it on the same fabric, and the
/// repeat request full-hits the re-placed plan — the CPU floor is never
/// needed for a single dead tile.
#[test]
fn permanent_exec_fault_quarantines_and_re_places() {
    let mut coord = Coordinator::new(OverlayConfig::default()).unwrap();
    let spec = FaultSpec { region_dead: vec![1], ..FaultSpec::default() };
    coord.set_faults(FaultPlane::from_spec(spec), 3);

    let comp = Composition::vmul_reduce(256);
    let inputs = workload::request_inputs(&comp, 1);
    let want = cpu::eval(&comp, &inputs).unwrap();

    let resp = coord.submit(&Request::dynamic(comp.clone(), inputs.clone())).unwrap();
    assert!(agree(&want, &resp.run.output), "re-placed run must still be correct");
    assert_eq!(coord.metrics.tiles_quarantined, 1, "the dead region is quarantined");
    assert_eq!(coord.metrics.cpu_fallbacks, 0, "one dead tile must not force the CPU floor");

    let again = coord.submit(&Request::dynamic(comp, inputs)).unwrap();
    assert!(agree(&want, &again.run.output));
    assert_eq!(coord.metrics.tiles_quarantined, 1, "quarantine is billed once, not per run");
    assert_eq!(coord.metrics.requests, 2);
}

/// An injected worker panic is supervised, not fatal: the burst was staged
/// before the panic fired, so the rebuilt coordinator replays it in full —
/// every queued client still gets its (correct) reply, the thread never
/// dies, and both restart counters appear in the worker's own record.
#[test]
fn injected_worker_panic_is_supervised_and_the_burst_replayed() {
    let spec = FaultSpec { worker_panics: vec![1], ..FaultSpec::default() };
    let pool = WorkerPool::new_paused(OverlayConfig::default(), one_worker(spec)).unwrap();
    let mut pending = Vec::new();
    for k in 0..4u64 {
        let comp = Composition::vmul_reduce(128);
        let inputs = workload::request_inputs(&comp, k);
        let want = cpu::eval(&comp, &inputs).unwrap();
        pending.push((want, pool.submit(Request::dynamic(comp, inputs)).unwrap()));
    }
    pool.start(); // the whole backlog drains as one burst — which panics

    for (want, rx) in pending {
        let resp = rx.recv().expect("worker survived").expect("served after the replay");
        assert!(agree(&want, &resp.run.output));
    }
    let report = pool.shutdown();
    assert!(report.panicked_workers.is_empty(), "supervision keeps the thread alive");
    assert_eq!(report.aggregate.workers_restarted, 1);
    assert_eq!(report.aggregate.jobs_replayed, 4, "the whole staged burst replays");
    assert_eq!(report.aggregate.requests, 4);
    let sum = report.worker_sum();
    assert_eq!(sum.workers_restarted, 1, "the restart rides the worker's own record");
    assert_eq!(sum.jobs_replayed, 4);
    assert_eq!(sum.requests, report.aggregate.requests);
}

/// The full stack under all three fault kinds at once — transient
/// downloads, one dead region, one worker panic — over real localhost TCP:
/// a pipelined client gets exactly one `OK` per request id, every value
/// correct, and the fault counters record each scheduled injection.
#[test]
fn chaos_soak_over_the_socket_conserves_exactly_one_reply_per_request() {
    let spec = FaultSpec {
        transient_downloads: vec![2, 5],
        region_dead: vec![2],
        worker_panics: vec![1],
        ..FaultSpec::default()
    };
    let service = ServiceConfig { faults: spec, ..ServiceConfig::with_workers(2) };
    let pool = Arc::new(WorkerPool::new(OverlayConfig::default(), service).unwrap());
    let fcfg = FrontendConfig { reactors: 2, inflight_per_session: 4, max_inflight: 64 };
    let front = Arc::new(Frontend::new(pool.clone(), fcfg, pool.metrics.clone()).unwrap());
    let threads = front.spawn().unwrap();
    let server =
        NetServer::bind("127.0.0.1:0", front.clone(), NetConfig::default(), pool.metrics.clone())
            .unwrap();
    let addr = server.local_addr().to_string();

    const REQUESTS: u64 = 16;
    let n = 64u32;
    let mut s = TcpStream::connect(&addr).unwrap();
    for id in 0..REQUESTS {
        let msg = ClientMsg::Request { id, n, seed: 70 + id, pattern: "vmul-reduce".into() };
        write_frame(&mut s, &msg.to_frame()).unwrap();
    }
    let mut seen = std::collections::HashSet::new();
    for _ in 0..REQUESTS {
        let payload = read_frame(&mut s, 0).unwrap().expect("a reply per request");
        match ServerMsg::decode(&payload).unwrap() {
            ServerMsg::Ok { id, value, .. } => {
                assert!(seen.insert(id), "request {id} answered twice");
                let want = expected_for(n as usize, 70 + id, "vmul-reduce");
                assert!(agree(&want, &value), "request {id}: wrong value under faults");
            }
            other => panic!("the recovery ladder must serve every request, got {other:?}"),
        }
    }
    assert_eq!(seen.len(), REQUESTS as usize, "every id answered exactly once");
    drop(s); // clean EOF at a frame boundary

    server.stop();
    threads.shutdown();
    drop(front);
    let report = Arc::try_unwrap(pool).ok().expect("serving tier leaked the pool").shutdown();
    let m = &report.aggregate;
    assert_eq!(m.requests, REQUESTS, "every request served exactly once");
    assert_eq!(m.completions, REQUESTS, "every reply drained exactly once");
    assert_eq!(m.tiles_quarantined, 1, "the one scheduled dead region");
    assert!(m.workers_restarted >= 1, "the scheduled worker panic was supervised");
    assert!(m.jobs_replayed >= 1, "the panicked burst was replayed, not dropped");
    assert!(m.download_retries >= 1, "the transient download schedule fired");
    assert!(report.panicked_workers.is_empty(), "no worker thread was actually lost");
}
