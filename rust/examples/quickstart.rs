//! Quickstart: JIT-assemble the paper's VMUL&Reduce accelerator and run it.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the whole public API surface once: build a pattern composition,
//! JIT it onto the overlay, inspect placement, download bitstreams, execute,
//! and read the result + timing back.

use jit_overlay::exec::Engine;
use jit_overlay::jit::Jit;
use jit_overlay::patterns::Composition;
use jit_overlay::timing::Target;
use jit_overlay::{workload, OverlayConfig};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. a 3×3 dynamic overlay with the paper's PR sizing mix
    let cfg = OverlayConfig::default();
    println!(
        "fabric: {}×{} tiles ({} large PR regions), full reconfig ≈ {:.3} ms",
        cfg.rows,
        cfg.cols,
        cfg.large_tiles(),
        cfg.full_reconfig_seconds() * 1e3
    );
    let mut engine = Engine::new(cfg)?;

    // 2. the composition: sum = Σ A⃗ × B⃗ over 16 KB of data
    let n = 4096;
    let comp = Composition::vmul_reduce(n);

    // 3. JIT: compilation instead of synthesis
    let acc = Jit.compile(&engine.fabric, &engine.lib, &comp)?;
    println!(
        "jit: {} stages, {} pass-through hops, {}-instr program, chunk {}",
        acc.stages().len(),
        acc.total_hops(),
        acc.program().len(),
        acc.chunk()
    );
    for (s, a) in acc.stages().iter().zip(&acc.placement().assignments) {
        println!("  {:8} -> tile {} ({:?})", s.op.name(), a.tile, a.class);
    }

    // 4. execute on the dynamic overlay
    let (a, b) = workload::paper_16kb(7);
    let want = workload::dot_f64(&a, &b);
    let run = engine.run(&acc, &[a, b], Target::DynamicOverlay)?;
    let got = run.output.as_scalar().expect("scalar result");

    println!("result: {got} (reference {want:.3})");
    println!(
        "time: {:.4} ms total ({:.4} ms transfer), PR download {:.4} ms (amortized)",
        run.timing.total() * 1e3,
        run.timing.transfer_s * 1e3,
        run.reconfig.map_or(0.0, |r| r.seconds) * 1e3,
    );
    assert!(((got as f64 - want) / want).abs() < 1e-4);
    println!("quickstart OK");
    Ok(())
}
