//! A realistic multi-request workload through the coordinator: a sensor
//! analytics pipeline composing several of the paper's patterns, with the
//! reconfiguration-aware batcher amortizing PR downloads.
//!
//! ```bash
//! cargo run --release --example pattern_pipeline
//! ```
//!
//! Scenario (the kind of streaming workload the paper's intro motivates):
//! for each sensor frame,
//!   * energy    = Σ x·x               (vmul_reduce on x,x)
//!   * loudness  = abs → sqrt → log    (map chain; needs both large tiles)
//!   * events    = Σ x where x > θ     (filter → reduce)
//!   * compand   = x>1 ? sqrt : square (speculative branch; needs a large
//!                                      tile — contends with `loudness`)
//! Frames arrive interleaved; `loudness` and `compand` cannot co-reside
//! (two large PR regions total), so naive serving thrashes the fabric while
//! the batcher regroups frames per accelerator.

use jit_overlay::bitstream::OperatorKind;
use jit_overlay::coordinator::{Coordinator, Request};
use jit_overlay::patterns::Composition;
use jit_overlay::report::Table;
use jit_overlay::{workload, OverlayConfig};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n = 1024;
    let frames = 12;

    // interleaved request stream: energy, loudness, events, compand, ...
    let mut reqs = Vec::new();
    for f in 0..frames {
        let x = workload::vector(n, 100 + f as u64, 0.1, 3.0);
        match f % 4 {
            0 => reqs.push(Request::dynamic(
                Composition::vmul_reduce(n),
                vec![x.clone(), x],
            )),
            1 => reqs.push(Request::dynamic(
                Composition::chain(&[OperatorKind::Abs, OperatorKind::Sqrt, OperatorKind::Log], n)?,
                vec![x],
            )),
            2 => reqs.push(Request::dynamic(
                Composition::filter_reduce(1.5, n),
                vec![x],
            )),
            _ => reqs.push(Request::dynamic(
                Composition::branch(1.0, OperatorKind::Sqrt, OperatorKind::Square, n),
                vec![x],
            )),
        }
    }

    // naive serving: reconfigure on every accelerator switch
    let mut naive = Coordinator::new(OverlayConfig::default())?;
    for r in &reqs {
        naive.submit(r)?;
    }

    // batched serving: group by composition, reconfigure once per group
    let mut batched = Coordinator::new(OverlayConfig::default())?;
    let responses = batched.submit_batch(&reqs)?;

    let mut t = Table::new(
        "reconfiguration-aware batching",
        &["policy", "PR downloads", "PR time (ms)", "jit compiles", "cache hit rate"],
    );
    for (name, m) in
        [("naive (arrival order)", &naive.metrics), ("batched (grouped)", &batched.metrics)]
    {
        t.row(&[
            name.into(),
            m.pr_downloads.to_string(),
            format!("{:.4}", m.pr_seconds * 1e3),
            m.jit_compiles.to_string(),
            format!("{:.0}%", m.hit_rate() * 100.0),
        ]);
    }
    print!("{}", t.render());

    assert!(batched.metrics.pr_downloads < naive.metrics.pr_downloads);
    assert_eq!(responses.len(), frames);

    // spot-check one energy result
    let x0 = workload::vector(n, 100, 0.1, 3.0);
    let want: f64 = x0.iter().map(|v| (*v as f64) * (*v as f64)).sum();
    let got = responses[0].run.output.as_scalar().unwrap() as f64;
    assert!(((got - want) / want).abs() < 1e-4, "{got} vs {want}");
    println!(
        "energy(frame0) = {got:.3} (reference {want:.3}); \
         batched saved {} PR downloads",
        naive.metrics.pr_downloads - batched.metrics.pr_downloads
    );
    println!("pattern_pipeline OK");
    Ok(())
}
