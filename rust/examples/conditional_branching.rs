//! Conditional branching with speculation — the dynamic overlay's answer to
//! the static design's second limitation ("cannot compose simple
//! conditionals with pre-synthesized programming patterns").
//!
//! ```bash
//! cargo run --release --example conditional_branching
//! ```
//!
//! The JIT expands `x > t ? sqrt(x) : square(x)` into a *diamond*: a
//! predicate tile (Sub), two speculated operator tiles executing both arms,
//! and a Select tile committing per element — all placed in contiguous
//! tiles around a hub, exactly the paper's "if-then-else operators placed
//! within contiguous tiles".

use jit_overlay::bitstream::OperatorKind;
use jit_overlay::exec::{cpu, Engine};
use jit_overlay::jit::Jit;
use jit_overlay::patterns::Composition;
use jit_overlay::report::Table;
use jit_overlay::timing::Target;
use jit_overlay::{workload, OverlayConfig};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n = 2048;
    let mut engine = Engine::new(OverlayConfig::default())?;

    let comp = Composition::branch(0.5, OperatorKind::Sqrt, OperatorKind::Square, n);
    let acc = Jit.compile(&engine.fabric, &engine.lib, &comp)?;

    println!("speculative diamond ({} stages):", acc.stages().len());
    for (s, a) in acc.stages().iter().zip(&acc.placement().assignments) {
        println!("  {:9} -> tile {} ({:?})", s.op.name(), a.tile, a.class);
    }
    println!("pass-through hops: {} (contiguous ⇒ 0)", acc.total_hops());
    assert_eq!(acc.total_hops(), 0);

    let x = workload::vector(n, 5, 0.0, 4.0);
    let run = engine.run(&acc, &[x.clone()], Target::DynamicOverlay)?;
    let got = run.output.as_vector().expect("vector").to_vec();
    let want = cpu::eval(&comp, &[x.clone()])?;
    let want = want.as_vector().unwrap();

    let mut worst = 0.0f32;
    for i in 0..n {
        worst = worst.max((got[i] - want[i]).abs());
    }
    println!("max |overlay - reference| = {worst:e}");
    assert!(worst < 1e-4);

    // Cost of speculation: both arms always execute. Compare against the
    // hypothetical taken-arm-only map at the same length.
    let mut t = Table::new(
        "speculation cost (modeled)",
        &["pipeline", "tiles", "total (ms)"],
    );
    t.row(&[
        "branch diamond (speculative)".into(),
        "4".into(),
        format!("{:.4}", run.timing.total() * 1e3),
    ]);
    let map_only = Composition::map(OperatorKind::Sqrt, n);
    let acc2 = Jit.compile(&engine.fabric, &engine.lib, &map_only)?;
    let run2 = engine.run(&acc2, &[x], Target::DynamicOverlay)?;
    t.row(&[
        "unconditional map (lower bound)".into(),
        "1".into(),
        format!("{:.4}", run2.timing.total() * 1e3),
    ]);
    print!("{}", t.render());
    println!("conditional_branching OK");
    Ok(())
}
