//! End-to-end driver: the paper's full evaluation on the real small
//! workload (16 KB VMUL&Reduce), proving all layers compose.
//!
//! ```bash
//! make artifacts && cargo run --release --example vmul_reduce_e2e
//! ```
//!
//! Pipeline exercised, in order:
//!   1. L2/L1 artifact (JAX + Pallas, AOT-lowered HLO) loaded via PJRT;
//!   2. the JIT compiles the composition to a controller program;
//!   3. the PR manager downloads bitstreams (the 1.25 ms of Fig. 3);
//!   4. the fabric simulator executes the program — values must agree
//!      three ways (overlay == CPU reference == PJRT artifact);
//!   5. Fig. 2 and Fig. 3 tables are regenerated and printed.
//!
//! This is the run recorded in EXPERIMENTS.md.

use jit_overlay::exec::{cpu, Engine};
use jit_overlay::jit::Jit;
use jit_overlay::patterns::Composition;
use jit_overlay::place::StaticScenario;
use jit_overlay::report::{ms, speedup, Table};
use jit_overlay::runtime::{default_artifacts_dir, Runtime};
use jit_overlay::timing::Target;
use jit_overlay::{workload, OverlayConfig};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n = 4096; // 16 KB per operand — the paper's Fig. 3 data size
    let cfg = OverlayConfig::default();
    let mut engine = Engine::new(cfg.clone())?;
    let comp = Composition::vmul_reduce(n);
    let acc = Jit.compile(&engine.fabric, &engine.lib, &comp)?;
    let (a, b) = workload::paper_16kb(2024);

    // ---- three-way value agreement ---------------------------------------
    let overlay_run = engine.run(&acc, &[a.clone(), b.clone()], Target::DynamicOverlay)?;
    let overlay_val = overlay_run.output.as_scalar().expect("scalar");
    let cpu_val = cpu::eval(&comp, &[a.clone(), b.clone()])?.as_scalar().expect("scalar");
    let f64_ref = workload::dot_f64(&a, &b);

    println!("== value agreement (n = {n}) ==");
    println!("overlay interpreter : {overlay_val}");
    println!("cpu reference       : {cpu_val}");
    println!("f64 ground truth    : {f64_ref:.4}");

    let dir = default_artifacts_dir();
    let pjrt_val = if dir.join("manifest.tsv").exists() {
        let rt = Runtime::new(&dir)?;
        let v = rt.execute_scalar(&format!("vmul_reduce_n{n}"), &[a.clone(), b.clone()])?;
        println!("pjrt (pallas kernel): {v}   [platform {}]", rt.platform());
        Some(v)
    } else {
        println!("pjrt: SKIPPED — run `make artifacts` first");
        None
    };
    let tol = (f64_ref.abs() * 1e-4).max(1e-2);
    assert!((overlay_val as f64 - f64_ref).abs() < tol, "overlay deviates");
    assert!((cpu_val as f64 - f64_ref).abs() < tol, "cpu deviates");
    if let Some(p) = pjrt_val {
        assert!((p as f64 - f64_ref).abs() < tol, "pjrt deviates");
        println!("three-way agreement : OK (tol {tol:.3e})");
    }

    // ---- Fig. 2 ------------------------------------------------------------
    let mut fig2 = Table::new(
        "Fig. 2 — mapping VMUL&Reduce onto the static overlay",
        &["scenario", "pass-throughs", "total (ms)", "hop cost (ms)"],
    );
    for s in StaticScenario::ALL {
        let r = engine.run(&acc, &[a.clone(), b.clone()], Target::StaticOverlay(s))?;
        fig2.row(&[
            s.name().into(),
            s.pass_throughs().to_string(),
            ms(r.timing.total()),
            ms(r.timing.hop_s),
        ]);
    }
    print!("\n{}", fig2.render());

    // ---- Fig. 3 ------------------------------------------------------------
    let mut fig3 = Table::new(
        "Fig. 3 — total execution time, five hardware targets + ARM",
        &["target", "total (ms)", "vs dynamic"],
    );
    let dyn_total = overlay_run.timing.total();
    let mut winners: Vec<(String, f64)> = Vec::new();
    for t in Target::ALL {
        let r = engine.run(&acc, &[a.clone(), b.clone()], t)?;
        winners.push((t.name(), r.timing.total()));
        fig3.row(&[t.name(), ms(r.timing.total()), speedup(r.timing.total(), dyn_total)]);
    }
    print!("\n{}", fig3.render());
    println!(
        "PR overhead (startup only, excluded from graph per the paper): {:.3} ms",
        cfg.full_reconfig_seconds() * 1e3
    );

    // ---- shape assertions (the paper's qualitative claims) -----------------
    let t = |name: &str| winners.iter().find(|(n, _)| n == name).unwrap().1;
    assert!(t("dynamic-overlay") <= t("static-s1") * 1.05, "dynamic must win");
    assert!(t("static-s1") < t("static-s2") && t("static-s2") < t("static-s3"));
    assert!(t("arm-660mhz") > t("static-s3"), "ARM is the slow reference");
    let pr_ms = cfg.full_reconfig_seconds() * 1e3;
    assert!((pr_ms - 1.25).abs() < 0.1, "PR overhead ≈ 1.25 ms, got {pr_ms}");
    println!("\nend-to-end: all paper-shape assertions hold ✓");
    Ok(())
}
