//! Bench: cluster sharding — the `--pools P` tier (ISSUE 10).
//!
//! Three dimensions:
//!
//! * **churn sweep** — the same seeded churn stream (hot/cold mix with a
//!   recurring wide cohort) through 1/2/4-pool clusters, each with one
//!   mid-stream pool join and one pool death, pipelined submits with a
//!   rebalance probe per request: req/s next to the join / evacuation /
//!   cross-steal / warm-start counters;
//! * **join cost** — the identical 2-pool churn run with warm-start on
//!   vs off: the joining pool's JIT compiles are the price of a cold
//!   join, and `warm_start_hits` is how much of it shipping the cached
//!   programs bought back;
//! * **ring re-homing** — for P→P+1 at each P, the fraction of 128
//!   distinct composition keys that change owning pool (consistent
//!   hashing promises ~1/(P+1); acceptance allows 2/(P+1)).
//!
//! Acceptance: every P→P+1 re-homing fraction ≤ 2/(P+1), and the warm
//! joiner pays strictly fewer compiles than the cold one.

use jit_overlay::benchkit::{write_bench_json, JsonArray, JsonObject};
use jit_overlay::coordinator::{Cluster, HashRing, Metrics, Request};
use jit_overlay::report::Table;
use jit_overlay::{workload, ClusterConfig, OverlayConfig, ServiceConfig};

const WORKERS: usize = 2;

fn churn_stream(requests: usize, n: usize) -> Vec<Request> {
    workload::churn_compositions(requests, n, 0xC7A5)
        .into_iter()
        .enumerate()
        .map(|(k, comp)| {
            let inputs = workload::request_inputs(&comp, k as u64);
            Request::dynamic(comp, inputs)
        })
        .collect()
}

struct ChurnOutcome {
    wall_s: f64,
    aggregate: Metrics,
    /// The mid-stream joiner's own counters.
    joiner: Metrics,
}

/// Pipelined churn run: submit each request without waiting, join one
/// pool at the half-way mark, retire the first pool at the 3/4 mark,
/// probe `rebalance_once` every request, then drain every reply.
fn run_churn(pools: usize, reqs: &[Request], warm_start: bool) -> ChurnOutcome {
    let ccfg = ClusterConfig { warm_start, ..ClusterConfig::default() };
    let service = ServiceConfig {
        queue_capacity: reqs.len().max(1),
        ..ServiceConfig::with_workers(WORKERS)
    };
    let cluster = Cluster::homogeneous(OverlayConfig::default(), service.clone(), ccfg, pools)
        .expect("cluster spawn");
    let first = cluster.pool_ids()[0];
    let (join_at, retire_at) = (reqs.len() / 2, reqs.len() * 3 / 4);
    let mut joined = 0;
    let t0 = std::time::Instant::now();
    let mut pending = Vec::with_capacity(reqs.len());
    for (i, r) in reqs.iter().enumerate() {
        if i == join_at {
            joined = cluster
                .join(OverlayConfig::default(), service.clone())
                .expect("pool join");
        }
        if i == retire_at {
            cluster.retire(first).expect("pool retire");
        }
        pending.push(cluster.submit(r.clone()).expect("submit"));
        cluster.rebalance_once();
    }
    for rx in pending {
        rx.recv().expect("pool alive").expect("request served");
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let report = cluster.shutdown();
    let joiner = report
        .per_pool
        .iter()
        .find(|(id, _)| *id == joined)
        .map(|(_, m)| *m)
        .expect("joiner survived");
    ChurnOutcome { wall_s, aggregate: report.aggregate, joiner }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let requests = if quick { 48 } else { 240 };
    let n = 1024;
    let reqs = churn_stream(requests, n);

    // churn sweep: P pools, one join, one death, every P
    let mut t = Table::new(
        "cluster churn — P pools, one mid-stream join + one pool death",
        &["pools", "wall (ms)", "req/s", "joins", "evac", "x-steals", "warm hits", "compiles"],
    );
    let mut sweep = Vec::new();
    for pools in [1usize, 2, 4] {
        let out = run_churn(pools, &reqs, true);
        let m = &out.aggregate;
        assert_eq!(m.requests, requests as u64, "every request must be served once");
        assert_eq!(
            m.cache_hits + m.placement_respecializations + m.jit_compiles,
            m.requests,
            "cluster-wide conservation"
        );
        t.row(&[
            pools.to_string(),
            format!("{:.1}", out.wall_s * 1e3),
            format!("{:.0}", requests as f64 / out.wall_s),
            m.pool_joins.to_string(),
            m.pool_evacuations.to_string(),
            m.cross_pool_steals.to_string(),
            m.warm_start_hits.to_string(),
            m.jit_compiles.to_string(),
        ]);
        sweep.push((pools, out));
    }
    print!("{}", t.render());

    // join cost: identical 2-pool churn, warm-start on vs off
    let warm = &sweep.iter().find(|(p, _)| *p == 2).expect("2-pool cell").1;
    let cold = run_churn(2, &reqs, false);
    let mut t = Table::new(
        "join cost — the mid-stream joiner, warm-start on vs off (2 pools)",
        &["warm-start", "joiner compiles", "joiner respecs", "warm hits (cluster)"],
    );
    for (label, out) in [("on", warm), ("off", &cold)] {
        t.row(&[
            label.into(),
            out.joiner.jit_compiles.to_string(),
            out.joiner.placement_respecializations.to_string(),
            out.aggregate.warm_start_hits.to_string(),
        ]);
    }
    print!("{}", t.render());
    let ok_join = warm.joiner.jit_compiles < cold.joiner.jit_compiles;
    println!(
        "join acceptance: warm joiner {} compiles vs cold {} (strictly fewer: {}), {} warm-start hits",
        warm.joiner.jit_compiles,
        cold.joiner.jit_compiles,
        if ok_join { "PASS" } else { "MISS" },
        warm.aggregate.warm_start_hits,
    );

    // ring re-homing: P→P+1 over 128 distinct composition keys
    let keys: Vec<u64> = workload::wide_cohort(128).iter().map(|c| c.cache_key()).collect();
    let vnodes = ClusterConfig::default().vnodes;
    let mut t = Table::new(
        "ring re-homing — keys moved on a P→P+1 pool join (128 keys)",
        &["P", "moved", "fraction", "ideal 1/(P+1)", "bound 2/(P+1)"],
    );
    let mut ring_cells = Vec::new();
    let mut ok_ring = true;
    for p in 1usize..=8 {
        let seeds: Vec<u64> = (0..p as u64).collect();
        let mut grown = seeds.clone();
        grown.push(p as u64);
        let before = HashRing::new(&seeds, vnodes);
        let after = HashRing::new(&grown, vnodes);
        let moved = keys.iter().filter(|&&k| before.owner(k) != after.owner(k)).count();
        let frac = moved as f64 / keys.len() as f64;
        let bound = 2.0 / (p as f64 + 1.0);
        ok_ring &= frac <= bound;
        t.row(&[
            p.to_string(),
            moved.to_string(),
            format!("{frac:.3}"),
            format!("{:.3}", 1.0 / (p as f64 + 1.0)),
            format!("{bound:.3}"),
        ]);
        ring_cells.push((p, moved, frac, bound));
    }
    print!("{}", t.render());
    println!(
        "ring acceptance: every P→P+1 re-homing within 2/(P+1): {}",
        if ok_ring { "PASS" } else { "MISS" }
    );

    // BENCH_cluster.json — machine-readable companion
    let mut churn = JsonArray::new();
    for (pools, out) in &sweep {
        let m = &out.aggregate;
        let mut o = JsonObject::new();
        o.int("pools", *pools as u64)
            .num("wall_s", out.wall_s)
            .num("req_per_s", requests as f64 / out.wall_s)
            .int("pool_joins", m.pool_joins)
            .int("pool_evacuations", m.pool_evacuations)
            .int("cross_pool_steals", m.cross_pool_steals)
            .int("warm_start_hits", m.warm_start_hits)
            .int("jit_compiles", m.jit_compiles)
            .int("cache_hits", m.cache_hits)
            .int("placement_respecializations", m.placement_respecializations);
        churn.raw(&o.finish());
    }
    let mut join = JsonArray::new();
    for (label, out) in [("on", warm), ("off", &cold)] {
        let mut o = JsonObject::new();
        o.str("warm_start", label)
            .int("joiner_jit_compiles", out.joiner.jit_compiles)
            .int("joiner_respecializations", out.joiner.placement_respecializations)
            .int("warm_start_hits", out.aggregate.warm_start_hits);
        join.raw(&o.finish());
    }
    let mut ring = JsonArray::new();
    for (p, moved, frac, bound) in &ring_cells {
        let mut o = JsonObject::new();
        o.int("pools_before", *p as u64)
            .int("moved", *moved as u64)
            .num("fraction", *frac)
            .num("bound", *bound);
        ring.raw(&o.finish());
    }
    let mut accept = JsonObject::new();
    accept
        .str("ring_rehoming", if ok_ring { "PASS" } else { "MISS" })
        .str("warm_join", if ok_join { "PASS" } else { "MISS" });
    let mut root = JsonObject::new();
    root.str("group", "cluster")
        .int("requests", requests as u64)
        .int("workers_per_pool", WORKERS as u64)
        .raw("churn", &churn.finish())
        .raw("join", &join.finish())
        .raw("ring", &ring.finish())
        .raw("acceptance", &accept.finish());
    match write_bench_json("cluster", &root.finish()) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("BENCH json not written: {e}"),
    }
}
