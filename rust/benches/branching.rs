//! Bench T-BR: conditional branching with speculation.
//!
//! Compares the dynamic overlay's speculative diamond (both arms resident
//! in contiguous tiles, per-element select) against (a) an unconditional
//! map lower bound and (b) the ARM software branch loop, over several
//! taken-probabilities (speculation cost is data-independent — that is the
//! point of the measurement).

use jit_overlay::benchkit::Bench;
use jit_overlay::bitstream::OperatorKind;
use jit_overlay::exec::Engine;
use jit_overlay::jit::Jit;
use jit_overlay::patterns::Composition;
use jit_overlay::report::{ms, Table};
use jit_overlay::timing::Target;
use jit_overlay::{workload, OverlayConfig};

fn main() {
    let n = 2048;
    let mut engine = Engine::new(OverlayConfig::default()).unwrap();
    let branch = Composition::branch(0.5, OperatorKind::Sqrt, OperatorKind::Square, n);
    let acc = Jit.compile(&engine.fabric, &engine.lib, &branch).unwrap();

    // modeled table across taken-rates (values change; time must not)
    let mut t = Table::new(
        "T-BR — speculative branch, modeled time vs taken-rate",
        &["taken-rate", "overlay (ms)", "arm (ms)"],
    );
    for rate in [0.1f32, 0.5, 0.9] {
        let x = workload::vector(n, (rate * 100.0) as u64, 0.5 - rate, 1.5 - rate);
        let ov = engine.run(&acc, &[x.clone()], Target::DynamicOverlay).unwrap();
        let arm = engine.run(&acc, &[x], Target::ArmSoftware).unwrap();
        t.row(&[format!("{rate:.1}"), ms(ov.timing.total()), ms(arm.timing.total())]);
    }
    println!("{}", t.render());

    let x = workload::vector(n, 7, 0.0, 1.0);
    let mut bench = Bench::new("branching");
    bench.bench("speculative_diamond", || {
        engine
            .run(&acc, &[x.clone()], Target::DynamicOverlay)
            .unwrap()
            .timing
            .total()
    });
    let map_only = Composition::map(OperatorKind::Sqrt, n);
    let acc2 = Jit.compile(&engine.fabric, &engine.lib, &map_only).unwrap();
    bench.bench("unconditional_map", || {
        engine
            .run(&acc2, &[x.clone()], Target::DynamicOverlay)
            .unwrap()
            .timing
            .total()
    });
    bench.bench("arm_software", || {
        engine
            .run(&acc, &[x.clone()], Target::ArmSoftware)
            .unwrap()
            .timing
            .total()
    });
    bench.finish();
}
