//! Bench T-JIT: JIT assembly latency — "assemble gates through compilation
//! instead of synthesis".
//!
//! The paper's pitch is removing synthesis/place/route (minutes to hours)
//! from the programmer's path. This bench measures what replaces it: the
//! full JIT pipeline (linearize → select → place → route → codegen) per
//! composition, plus the coordinator's cache-hit path.

use jit_overlay::benchkit::Bench;
use jit_overlay::bitstream::{BitstreamLibrary, OperatorKind};
use jit_overlay::coordinator::{Coordinator, Request};
use jit_overlay::jit::Jit;
use jit_overlay::overlay::Fabric;
use jit_overlay::patterns::Composition;
use jit_overlay::OverlayConfig;

fn suite(n: usize) -> Vec<(&'static str, Composition)> {
    use OperatorKind::*;
    vec![
        ("vmul_reduce", Composition::vmul_reduce(n)),
        ("chain3", Composition::chain(&[Abs, Sqrt, Log], n).unwrap()),
        ("filter_reduce", Composition::filter_reduce(0.5, n)),
        ("branch_diamond", Composition::branch(0.0, Sqrt, Square, n)),
        ("axpy", Composition::axpy(2.0, n)),
    ]
}

fn main() {
    let cfg = OverlayConfig::default();
    let lib = BitstreamLibrary::standard(&cfg);
    let fabric = Fabric::new(cfg.clone()).unwrap();

    let mut bench = Bench::new("jit_compile");
    for (name, comp) in suite(4096) {
        bench.bench(name, || Jit.compile(&fabric, &lib, &comp).unwrap().program().len());
    }

    // coordinator cache-hit path (what repeat requests pay)
    let mut coord = Coordinator::new(cfg).unwrap();
    let n = 1024;
    let req = Request::dynamic(
        Composition::vmul_reduce(n),
        vec![vec![1.0; n], vec![2.0; n]],
    );
    coord.submit(&req).unwrap(); // warm
    bench.bench("cache_hit_lookup", || coord.accelerator(&req.comp).unwrap().2);
        bench.finish();
    match bench.write_json() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("BENCH json not written: {e}"),
    }
}
