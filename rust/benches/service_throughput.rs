//! Bench: worker-pool service throughput and PR-download amortization.
//!
//! Drives the same mixed composition stream (80% hot / 20% cold,
//! `workload::mixed_compositions`) through pools of 1/2/4/8 workers and
//! reports wall-clock req/s, speedup over one worker, PR downloads per
//! request, and the residency hit rate. The single-worker *batched*
//! coordinator (reconfiguration-aware reordering) is printed as the
//! PR-downloads baseline the pool has to beat without reordering.
//!
//! Acceptance targets (ISSUE 1): ≥ 2× req/s at 4 workers vs 1, and PR
//! downloads per request no worse than the batched single-worker baseline.

use jit_overlay::coordinator::{Coordinator, Metrics, Request, WorkerPool};
use jit_overlay::report::Table;
use jit_overlay::{workload, OverlayConfig, ServiceConfig};

fn stream(requests: usize, n: usize) -> Vec<Request> {
    workload::mixed_compositions(requests, n, 0xF00D)
        .into_iter()
        .enumerate()
        .map(|(k, comp)| {
            let inputs = workload::request_inputs(&comp, k as u64);
            Request::dynamic(comp, inputs)
        })
        .collect()
}

/// Serve the whole stream through a pool; returns wall seconds + metrics.
fn run_pool(workers: usize, reqs: &[Request]) -> (f64, Metrics) {
    let pool = WorkerPool::new(OverlayConfig::default(), ServiceConfig::with_workers(workers))
        .expect("pool spawn");
    let t0 = std::time::Instant::now();
    let pending: Vec<_> = reqs
        .iter()
        .map(|r| pool.submit(r.clone()).expect("submit"))
        .collect();
    for rx in pending {
        rx.recv().expect("worker alive").expect("request served");
    }
    let dt = t0.elapsed().as_secs_f64();
    (dt, pool.shutdown().aggregate)
}

/// Single-worker reconfiguration-aware batching — the paper-style baseline
/// for PR downloads per request.
fn run_batched_baseline(reqs: &[Request]) -> (f64, Metrics) {
    let mut coord = Coordinator::new(OverlayConfig::default()).expect("coordinator");
    let t0 = std::time::Instant::now();
    coord.submit_batch(reqs).expect("batch served");
    (t0.elapsed().as_secs_f64(), coord.metrics)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let requests = if quick { 64 } else { 256 };
    let n = 1024;
    let reqs = stream(requests, n);
    let distinct: std::collections::HashSet<u64> =
        reqs.iter().map(|r| r.comp.cache_key()).collect();
    println!(
        "mixed stream: {requests} requests over {} distinct compositions (n={n})",
        distinct.len()
    );

    let (base_dt, base_m) = run_batched_baseline(&reqs);
    let base_dpr = base_m.pr_downloads as f64 / requests as f64;

    let mut t = Table::new(
        "service throughput — mixed stream, 1/2/4/8 workers",
        &[
            "workers",
            "wall (ms)",
            "req/s",
            "speedup vs 1",
            "PR dl/req",
            "PR hit rate",
            "jit compiles",
        ],
    );
    t.row(&[
        "1 (batched)".into(),
        format!("{:.1}", base_dt * 1e3),
        format!("{:.0}", requests as f64 / base_dt),
        "-".into(),
        format!("{base_dpr:.3}"),
        format!("{:.0}%", base_m.pr_hit_rate() * 100.0),
        base_m.jit_compiles.to_string(),
    ]);

    let mut single_rate = 0.0;
    for workers in [1usize, 2, 4, 8] {
        let (dt, m) = run_pool(workers, &reqs);
        let rate = requests as f64 / dt;
        if workers == 1 {
            single_rate = rate;
        }
        let dpr = m.pr_downloads as f64 / requests as f64;
        t.row(&[
            workers.to_string(),
            format!("{:.1}", dt * 1e3),
            format!("{rate:.0}"),
            format!("{:.2}x", rate / single_rate),
            format!("{dpr:.3}"),
            format!("{:.0}%", m.pr_hit_rate() * 100.0),
            m.jit_compiles.to_string(),
        ]);
        if workers == 4 {
            let ok_speed = rate / single_rate >= 2.0;
            let ok_dpr = dpr <= base_dpr + 1e-9;
            println!(
                "4-worker acceptance: speedup {:.2}x (target ≥2x: {}), PR dl/req {:.3} vs batched {:.3} (target ≤: {})",
                rate / single_rate,
                if ok_speed { "PASS" } else { "MISS" },
                dpr,
                base_dpr,
                if ok_dpr { "PASS" } else { "MISS" },
            );
        }
    }
    print!("{}", t.render());
}
