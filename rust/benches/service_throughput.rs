//! Bench: worker-pool throughput — FIFO drain vs burst drain vs
//! burst+steal at 1/2/4/8 workers.
//!
//! Three streams drive every (workers × mode) cell:
//!
//! * **mixed** — the 80% hot / 20% cold skew of
//!   `workload::mixed_compositions` (req/s focus: burst draining must not
//!   cost throughput where there is little to regroup);
//! * **adversarial** — `workload::interleaved_stream` over a home-aligned
//!   pair of conflicting 5-stage chains, the PR-thrash worst case
//!   (PR-downloads/request focus: burst draining must collapse the
//!   per-switch re-download);
//! * **spill-heavy** — `workload::spill_heavy_compositions`: many distinct
//!   keys under `max_queue_skew = 0`, so affinity routing migrates
//!   compositions between fabrics constantly. This is the stream that
//!   makes the cost of placement-only respecialization — and the resident
//!   clobbers it avoids (ISSUE 4) — visible in the `respec` / `clob-avoid`
//!   columns next to the download counts.
//!
//! Methodology: pools start **paused**, the whole backlog is enqueued,
//! then the workers are released and the wall clock measures the pure
//! drain — so every mode sees the same queue depths and the drain window
//! actually has something to regroup (matching a loaded service, not an
//! idle one). The single-worker `submit_batch` coordinator is printed as
//! the offline scheduling bound.
//!
//! Acceptance (ISSUE 3): at 4 workers, burst req/s no worse than FIFO on
//! the mixed stream, and strictly fewer PR downloads/request than FIFO on
//! the adversarial stream.
//!
//! A second dimension (ISSUE 5) compares the serving layer itself at
//! 64/256/1024 sessions over a fixed 4-worker pool: thread-per-client
//! (one OS thread + per-request channels per session) vs the reactor
//! front end (one reactor thread multiplexing every session over a shared
//! completion queue). Acceptance: reactor throughput no worse than
//! thread-per-client at 256 sessions.

use std::sync::Arc;

use jit_overlay::benchkit::{write_bench_json, JsonArray, JsonObject};
use jit_overlay::coordinator::{Coordinator, Frontend, Metrics, Request, WorkerPool};
use jit_overlay::patterns::Composition;
use jit_overlay::report::Table;
use jit_overlay::{workload, FrontendConfig, OverlayConfig, ServiceConfig};

fn mixed_stream(requests: usize, n: usize) -> Vec<Request> {
    workload::mixed_compositions(requests, n, 0xF00D)
        .into_iter()
        .enumerate()
        .map(|(k, comp)| {
            let inputs = workload::request_inputs(&comp, k as u64);
            Request::dynamic(comp, inputs)
        })
        .collect()
}

/// Two conflicting 5-stage chains whose composition keys are congruent
/// mod 8 — and therefore share a home worker at every bench pool width
/// (1/2/4/8) — so the adversarial stream actually contends for one fabric
/// instead of hashing apart. Falls back to looser alignment if the hash
/// layout refuses (astronomically unlikely).
fn aligned_conflicting_pair() -> (Composition, Composition) {
    [8u64, 4, 2]
        .iter()
        .find_map(|&m| workload::home_aligned_conflicting_pair(m))
        .unwrap_or_else(|| {
            let [a, b, _] = workload::conflicting_chains(1024);
            (a, b)
        })
}

fn adversarial_stream(requests: usize) -> Vec<Request> {
    let (a, b) = aligned_conflicting_pair();
    workload::interleaved_stream(&[a, b], requests / 2)
        .into_iter()
        .enumerate()
        .map(|(k, comp)| {
            let inputs = workload::request_inputs(&comp, k as u64);
            Request::dynamic(comp, inputs)
        })
        .collect()
}

fn spill_heavy_stream(requests: usize) -> Vec<Request> {
    workload::spill_heavy_compositions(requests, 24, 0x5B111)
        .into_iter()
        .enumerate()
        .map(|(k, comp)| {
            let inputs = workload::request_inputs(&comp, k as u64);
            Request::dynamic(comp, inputs)
        })
        .collect()
}

#[derive(Clone, Copy)]
enum Mode {
    Fifo,
    Burst,
    BurstSteal,
}

impl Mode {
    const ALL: [Mode; 3] = [Mode::Fifo, Mode::Burst, Mode::BurstSteal];

    fn name(self) -> &'static str {
        match self {
            Mode::Fifo => "fifo",
            Mode::Burst => "burst",
            Mode::BurstSteal => "burst+steal",
        }
    }

    fn service(self, workers: usize, backlog: usize, skew: usize) -> ServiceConfig {
        let base = ServiceConfig {
            // the whole backlog is enqueued while paused; blocking submit
            // must never wait on a gated worker
            queue_capacity: backlog.max(1),
            max_queue_skew: skew,
            ..ServiceConfig::with_workers(workers)
        };
        match self {
            Mode::Fifo => base.fifo_drain().without_stealing(),
            Mode::Burst => base.without_stealing(),
            Mode::BurstSteal => base,
        }
    }
}

/// Enqueue the full stream on a paused pool, release it, drain replies;
/// returns wall seconds and the aggregate.
fn run_pool(workers: usize, mode: Mode, reqs: &[Request], skew: usize) -> (f64, Metrics) {
    let service = mode.service(workers, reqs.len(), skew);
    let pool = WorkerPool::new_paused(OverlayConfig::default(), service).expect("pool spawn");
    let pending: Vec<_> = reqs
        .iter()
        .map(|r| pool.submit(r.clone()).expect("submit"))
        .collect();
    let t0 = std::time::Instant::now();
    pool.start();
    for rx in pending {
        rx.recv().expect("worker alive").expect("request served");
    }
    let dt = t0.elapsed().as_secs_f64();
    (dt, pool.shutdown().aggregate)
}

/// Single-worker reconfiguration-aware batching — the offline scheduling
/// bound for PR downloads per request.
fn run_batched_baseline(reqs: &[Request]) -> (f64, Metrics) {
    let mut coord = Coordinator::new(OverlayConfig::default()).expect("coordinator");
    let t0 = std::time::Instant::now();
    coord.submit_batch(reqs).expect("batch served");
    (t0.elapsed().as_secs_f64(), coord.metrics)
}

fn bench_stream(
    label: &str,
    reqs: &[Request],
    skew: usize,
) -> Vec<(usize, &'static str, f64, Metrics)> {
    let requests = reqs.len();
    let distinct: std::collections::HashSet<u64> =
        reqs.iter().map(|r| r.comp.cache_key()).collect();
    println!(
        "{label}: {requests} requests over {} distinct compositions",
        distinct.len()
    );

    let (base_dt, base_m) = run_batched_baseline(reqs);
    let mut t = Table::new(
        &format!("service throughput — {label} stream"),
        &[
            "workers",
            "mode",
            "wall (ms)",
            "req/s",
            "PR dl/req",
            "PR hit rate",
            "switches",
            "steals",
            "respec",
            "clob-avoid",
        ],
    );
    t.row(&[
        "1".into(),
        "batched (offline)".into(),
        format!("{:.1}", base_dt * 1e3),
        format!("{:.0}", requests as f64 / base_dt),
        format!("{:.3}", base_m.pr_downloads as f64 / requests as f64),
        format!("{:.0}%", base_m.pr_hit_rate() * 100.0),
        "-".into(),
        "-".into(),
        base_m.placement_respecializations.to_string(),
        base_m.residency_clobbers_avoided.to_string(),
    ]);

    let mut cells = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        for mode in Mode::ALL {
            let (dt, m) = run_pool(workers, mode, reqs, skew);
            t.row(&[
                workers.to_string(),
                mode.name().into(),
                format!("{:.1}", dt * 1e3),
                format!("{:.0}", requests as f64 / dt),
                format!("{:.3}", m.pr_downloads as f64 / requests as f64),
                format!("{:.0}%", m.pr_hit_rate() * 100.0),
                m.burst_group_switches.to_string(),
                m.steals.to_string(),
                m.placement_respecializations.to_string(),
                m.residency_clobbers_avoided.to_string(),
            ]);
            cells.push((workers, mode.name(), dt, m));
        }
    }
    print!("{}", t.render());
    cells
}

/// Render one stream's (workers × mode) cells as a JSON array for the
/// machine-readable `BENCH_*.json` companion to the printed table.
fn stream_cells_json(requests: usize, cells: &[(usize, &'static str, f64, Metrics)]) -> String {
    let mut arr = JsonArray::new();
    for (workers, mode, dt, m) in cells {
        let mut o = JsonObject::new();
        o.int("workers", *workers as u64)
            .str("mode", mode)
            .num("wall_s", *dt)
            .num("req_per_s", requests as f64 / dt)
            .num("pr_dl_per_req", m.pr_downloads as f64 / requests as f64)
            .num("pr_hit_rate", m.pr_hit_rate())
            .int("burst_group_switches", m.burst_group_switches)
            .int("steals", m.steals)
            .int("placement_respecializations", m.placement_respecializations)
            .int("residency_clobbers_avoided", m.residency_clobbers_avoided);
        arr.raw(&o.finish());
    }
    arr.finish()
}

fn cell<'a>(
    cells: &'a [(usize, &'static str, f64, Metrics)],
    workers: usize,
    mode: &str,
) -> &'a (usize, &'static str, f64, Metrics) {
    cells
        .iter()
        .find(|(w, m, _, _)| *w == workers && *m == mode)
        .expect("cell present")
}

// ---------------------------------------------------------------------------
// Fusion dimension (ISSUE 7): the same 4-worker burst pool with the JIT
// fusion pass off vs on. The chain-heavy stream is the interleaved
// conflicting-chain pair — every composition is a 5-stage map chain whose
// adjacent pairs fuse 5 → 3 tiles, so the pass directly removes PR
// downloads; the mixed stream shows it does no harm where there is little
// to fuse.
// ---------------------------------------------------------------------------

/// Mean JIT front-end stage count (= tiles requested per composition)
/// across the stream's distinct compositions, under one fusion policy.
fn tiles_per_composition(reqs: &[Request], fuse: bool) -> f64 {
    let cfg = OverlayConfig::default();
    let lib = jit_overlay::bitstream::BitstreamLibrary::standard(&cfg);
    let mut seen = std::collections::HashSet::new();
    let (mut tiles, mut comps) = (0usize, 0usize);
    for r in reqs {
        if seen.insert(r.comp.cache_key()) {
            let spec = jit_overlay::jit::Jit
                .frontend_with(&lib, &r.comp, fuse)
                .expect("frontend");
            tiles += spec.stages.len();
            comps += 1;
        }
    }
    tiles as f64 / comps as f64
}

/// Burst-drain pool with an explicit fusion policy (same paused-backlog
/// methodology as [`run_pool`]).
fn run_fusion_pool(workers: usize, fuse: bool, reqs: &[Request]) -> (f64, Metrics) {
    let mut service =
        Mode::Burst.service(workers, reqs.len(), ServiceConfig::default().max_queue_skew);
    service.fuse = fuse;
    let pool = WorkerPool::new_paused(OverlayConfig::default(), service).expect("pool spawn");
    let pending: Vec<_> = reqs
        .iter()
        .map(|r| pool.submit(r.clone()).expect("submit"))
        .collect();
    let t0 = std::time::Instant::now();
    pool.start();
    for rx in pending {
        rx.recv().expect("worker alive").expect("request served");
    }
    let dt = t0.elapsed().as_secs_f64();
    (dt, pool.shutdown().aggregate)
}

fn bench_fusion(
    streams: &[(&'static str, &[Request])],
) -> Vec<(&'static str, &'static str, f64, Metrics, f64)> {
    const WORKERS: usize = 4;
    let mut t = Table::new(
        "fusion — unfused vs fused (4 workers, burst drain)",
        &[
            "stream",
            "fusion",
            "tiles/comp",
            "wall (ms)",
            "req/s",
            "PR dl/req",
            "fused",
            "dl-avoid",
            "fuse-fb",
            "cpu-fb",
        ],
    );
    let mut cells = Vec::new();
    for &(label, reqs) in streams {
        for fuse in [false, true] {
            let tpc = tiles_per_composition(reqs, fuse);
            let (dt, m) = run_fusion_pool(WORKERS, fuse, reqs);
            t.row(&[
                label.into(),
                if fuse { "on" } else { "off" }.into(),
                format!("{tpc:.2}"),
                format!("{:.1}", dt * 1e3),
                format!("{:.0}", reqs.len() as f64 / dt),
                format!("{:.3}", m.pr_downloads as f64 / reqs.len() as f64),
                m.stages_fused.to_string(),
                m.downloads_avoided.to_string(),
                m.fusion_fallbacks.to_string(),
                m.cpu_fallbacks.to_string(),
            ]);
            cells.push((label, if fuse { "on" } else { "off" }, dt, m, tpc));
        }
    }
    print!("{}", t.render());
    cells
}

// ---------------------------------------------------------------------------
// Front-end dimension (ISSUE 5): reactor vs thread-per-client by session
// count. Same 4-worker pool, same per-session stream; what varies is the
// serving layer — S client threads each with per-request channels, or a
// single reactor thread multiplexing all S sessions over one completion
// queue.
// ---------------------------------------------------------------------------

/// Thread-per-client: one OS thread per session submits its bucket through
/// the blocking channel path and drains its own replies.
fn run_thread_per_client(workers: usize, buckets: Vec<Vec<Request>>) -> (f64, Metrics) {
    let service = ServiceConfig { queue_capacity: 1024, ..ServiceConfig::with_workers(workers) };
    let pool =
        Arc::new(WorkerPool::new(OverlayConfig::default(), service).expect("pool spawn"));
    let t0 = std::time::Instant::now();
    let joins: Vec<_> = buckets
        .into_iter()
        .map(|bucket| {
            let p = pool.clone();
            std::thread::spawn(move || {
                let pending: Vec<_> =
                    bucket.into_iter().map(|r| p.submit(r).expect("submit")).collect();
                for rx in pending {
                    rx.recv().expect("worker alive").expect("request served");
                }
            })
        })
        .collect();
    for j in joins {
        j.join().expect("client thread");
    }
    let dt = t0.elapsed().as_secs_f64();
    (dt, Arc::try_unwrap(pool).ok().expect("clients done").shutdown().aggregate)
}

/// Reactor: one acceptor thread fans the same buckets into S multiplexed
/// sessions; a single reactor thread serves them all.
fn run_reactor(workers: usize, buckets: Vec<Vec<Request>>) -> (f64, Metrics) {
    let sessions = buckets.len();
    let service = ServiceConfig { queue_capacity: 1024, ..ServiceConfig::with_workers(workers) };
    let pool =
        Arc::new(WorkerPool::new(OverlayConfig::default(), service).expect("pool spawn"));
    let fcfg = FrontendConfig {
        reactors: 1,
        inflight_per_session: 4,
        max_inflight: (sessions * 4).max(64),
    };
    let front =
        Frontend::new(pool.clone(), fcfg, pool.metrics.clone()).expect("front end config");
    let threads = front.spawn().expect("reactor spawn");
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..sessions).map(|_| front.open_session()).collect();
    // interleave submissions round-robin across sessions (concurrent
    // arrivals), then drain each session's in-order reply stream
    let mut counts = vec![0usize; sessions];
    let mut buckets: Vec<std::vec::IntoIter<Request>> =
        buckets.into_iter().map(Vec::into_iter).collect();
    let mut any = true;
    while any {
        any = false;
        for (s, b) in buckets.iter_mut().enumerate() {
            if let Some(r) = b.next() {
                handles[s].submit(r).expect("session open");
                counts[s] += 1;
                any = true;
            }
        }
    }
    for (h, count) in handles.iter().zip(&counts) {
        for _ in 0..*count {
            h.recv().expect("request served");
        }
        h.close();
    }
    let dt = t0.elapsed().as_secs_f64();
    threads.shutdown();
    drop(front);
    (dt, Arc::try_unwrap(pool).ok().expect("front end done").shutdown().aggregate)
}

/// One bucket of the mixed stream per session.
fn session_buckets(sessions: usize, per_session: usize, n: usize) -> Vec<Vec<Request>> {
    let reqs = mixed_stream(sessions * per_session, n);
    let mut buckets: Vec<Vec<Request>> = (0..sessions).map(|_| Vec::new()).collect();
    for (k, r) in reqs.into_iter().enumerate() {
        buckets[k % sessions].push(r);
    }
    buckets
}

fn bench_frontends(
    session_counts: &[usize],
    per_session: usize,
) -> Vec<(usize, &'static str, f64, u64)> {
    const WORKERS: usize = 4;
    let mut t = Table::new(
        "front-end throughput — reactor vs thread-per-client (4 workers, mixed stream)",
        &["sessions", "front end", "threads", "wall (ms)", "req/s", "adm_rej", "polls"],
    );
    let mut cells = Vec::new();
    for &sessions in session_counts {
        let requests = sessions * per_session;
        for mode in ["threads", "reactor"] {
            let buckets = session_buckets(sessions, per_session, 1024);
            let (dt, m) = match mode {
                "threads" => run_thread_per_client(WORKERS, buckets),
                _ => run_reactor(WORKERS, buckets),
            };
            let serving_threads = match mode {
                // S clients + 4 workers vs 1 acceptor + 1 reactor + 4 workers
                "threads" => sessions + WORKERS,
                _ => 2 + WORKERS,
            };
            t.row(&[
                sessions.to_string(),
                mode.into(),
                serving_threads.to_string(),
                format!("{:.1}", dt * 1e3),
                format!("{:.0}", requests as f64 / dt),
                m.admission_rejections.to_string(),
                m.reactor_polls.to_string(),
            ]);
            cells.push((sessions, mode, dt, m.requests));
        }
    }
    print!("{}", t.render());
    cells
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let requests = if quick { 48 } else { 240 };
    let n = 1024;

    // mixed: spills on (default skew) — the live scheduler as deployed.
    // adversarial: affinity only, so the home-aligned pair provably
    // contends for one fabric and the modes differ only in drain policy.
    // spill-heavy: skew 0 — any imbalance migrates a composition, so
    // placement respecialization runs constantly and its cost shows up
    // next to the download counts.
    let default_skew = ServiceConfig::default().max_queue_skew;
    let mixed = bench_stream("mixed", &mixed_stream(requests, n), default_skew);
    let adversarial = bench_stream("adversarial", &adversarial_stream(requests), 1_000_000);
    let spill = bench_stream("spill-heavy", &spill_heavy_stream(requests), 0);

    // ISSUE 3 acceptance, evaluated at 4 workers
    let requests = requests as f64;
    let (_, _, fifo_dt, _) = cell(&mixed, 4, "fifo");
    let (_, _, burst_dt, _) = cell(&mixed, 4, "burst");
    let (_, _, _, fifo_m) = cell(&adversarial, 4, "fifo");
    let (_, _, _, burst_m) = cell(&adversarial, 4, "burst");
    let fifo_rate = requests / fifo_dt;
    let burst_rate = requests / burst_dt;
    let ok_rate = burst_rate >= fifo_rate * 0.95; // ±5% wall-clock noise floor
    let fifo_dpr = fifo_m.pr_downloads as f64 / requests;
    let burst_dpr = burst_m.pr_downloads as f64 / requests;
    let ok_dpr = burst_dpr < fifo_dpr;
    println!(
        "4-worker acceptance: mixed req/s burst {burst_rate:.0} vs fifo {fifo_rate:.0} (no worse: {}), adversarial PR dl/req burst {burst_dpr:.3} vs fifo {fifo_dpr:.3} (strictly fewer: {})",
        if ok_rate { "PASS" } else { "MISS" },
        if ok_dpr { "PASS" } else { "MISS" },
    );

    // ISSUE 4: on the spill-heavy stream at 4 workers, migrations pay
    // placement-only respecializations instead of clobbering residents —
    // both counters must be visible (nonzero) in the series
    let (_, _, _, spill_m) = cell(&spill, 4, "burst+steal");
    println!(
        "4-worker spill-heavy: {} respecializations, {} clobbers avoided, {} requests (visible: {})",
        spill_m.placement_respecializations,
        spill_m.residency_clobbers_avoided,
        spill_m.requests,
        if spill_m.placement_respecializations > 0 { "PASS" } else { "MISS" },
    );

    // ISSUE 7: fusion off vs on over a chain-heavy stream (the adversarial
    // conflicting-chain interleave — every composition fuses 5 → 3 tiles)
    // and the mixed stream. Acceptance: on the chain-heavy stream, fusion
    // must request strictly fewer tiles per composition and issue no more
    // PR downloads than the unfused baseline.
    let chain_reqs = adversarial_stream(requests as usize);
    let mixed_reqs = mixed_stream(requests as usize, n);
    let fusion_cells =
        bench_fusion(&[("chain-heavy", &chain_reqs), ("mixed", &mixed_reqs)]);
    let fusion_cell = |stream: &str, fuse: &str| {
        fusion_cells
            .iter()
            .find(|(s, f, _, _, _)| *s == stream && *f == fuse)
            .expect("fusion cell present")
    };
    let (_, _, _, fuse_off_m, fuse_off_tpc) = fusion_cell("chain-heavy", "off");
    let (_, _, _, fuse_on_m, fuse_on_tpc) = fusion_cell("chain-heavy", "on");
    let ok_fuse_tiles = fuse_on_tpc < fuse_off_tpc;
    let ok_fuse_dl = fuse_on_m.pr_downloads <= fuse_off_m.pr_downloads;
    println!(
        "chain-heavy fusion acceptance: tiles/comp {fuse_on_tpc:.2} vs {fuse_off_tpc:.2} (strictly fewer: {}), PR downloads {} vs {} (no more: {})",
        if ok_fuse_tiles { "PASS" } else { "MISS" },
        fuse_on_m.pr_downloads,
        fuse_off_m.pr_downloads,
        if ok_fuse_dl { "PASS" } else { "MISS" },
    );

    // ISSUE 5: session-count dimension — the reactor front end must match
    // or beat thread-per-client at 256 sessions (64/256/1024 full sweep)
    let (session_counts, per_session, accept_at): (&[usize], usize, usize) =
        if quick { (&[16, 64], 4, 64) } else { (&[64, 256, 1024], 8, 256) };
    let fcells = bench_frontends(session_counts, per_session);
    let fcell = |mode: &str| {
        fcells
            .iter()
            .find(|(s, m, _, _)| *s == accept_at && *m == mode)
            .expect("front-end cell present")
    };
    let (_, _, threads_dt, threads_served) = fcell("threads");
    let (_, _, reactor_dt, reactor_served) = fcell("reactor");
    assert_eq!(threads_served, reactor_served, "both modes must serve the whole stream");
    let threads_rate = *threads_served as f64 / threads_dt;
    let reactor_rate = *reactor_served as f64 / reactor_dt;
    let ok_reactor = reactor_rate >= threads_rate * 0.95;
    println!(
        "{accept_at}-session acceptance: reactor {reactor_rate:.0} req/s vs thread-per-client {threads_rate:.0} req/s (reactor no worse: {})",
        if ok_reactor { "PASS" } else { "MISS" },
    );

    // Machine-readable companion to the tables above, per the repo's
    // `BENCH_*.json` convention ($BENCH_JSON_DIR or the CWD).
    let stream_reqs = requests as usize;
    let mut streams = JsonObject::new();
    streams
        .raw("mixed", &stream_cells_json(stream_reqs, &mixed))
        .raw("adversarial", &stream_cells_json(stream_reqs, &adversarial))
        .raw("spill_heavy", &stream_cells_json(stream_reqs, &spill));
    let mut fronts = JsonArray::new();
    for (sessions, mode, dt, served) in &fcells {
        let mut o = JsonObject::new();
        o.int("sessions", *sessions as u64)
            .str("front_end", mode)
            .num("wall_s", *dt)
            .int("requests", *served)
            .num("req_per_s", *served as f64 / dt);
        fronts.raw(&o.finish());
    }
    let mut fusion = JsonArray::new();
    for (stream, fuse, dt, m, tpc) in &fusion_cells {
        let mut o = JsonObject::new();
        o.str("stream", stream)
            .str("fusion", fuse)
            .num("tiles_per_comp", *tpc)
            .num("wall_s", *dt)
            .num("req_per_s", stream_reqs as f64 / dt)
            .num("pr_dl_per_req", m.pr_downloads as f64 / stream_reqs as f64)
            .int("stages_fused", m.stages_fused)
            .int("downloads_avoided", m.downloads_avoided)
            .int("fusion_fallbacks", m.fusion_fallbacks)
            .int("cpu_fallbacks", m.cpu_fallbacks);
        fusion.raw(&o.finish());
    }
    let mut accept = JsonObject::new();
    accept
        .str("mixed_rate", if ok_rate { "PASS" } else { "MISS" })
        .str("adversarial_downloads", if ok_dpr { "PASS" } else { "MISS" })
        .str(
            "spill_respecializations",
            if spill_m.placement_respecializations > 0 { "PASS" } else { "MISS" },
        )
        .str("reactor_rate", if ok_reactor { "PASS" } else { "MISS" })
        .str("fusion_tiles", if ok_fuse_tiles { "PASS" } else { "MISS" })
        .str("fusion_downloads", if ok_fuse_dl { "PASS" } else { "MISS" });
    let mut root = JsonObject::new();
    root.str("group", "service_throughput")
        .int("requests_per_stream", requests as u64)
        .raw("streams", &streams.finish())
        .raw("fusion", &fusion.finish())
        .raw("frontends", &fronts.finish())
        .raw("acceptance", &accept.finish());
    match write_bench_json("service_throughput", &root.finish()) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("BENCH json not written: {e}"),
    }
}
