//! Bench FIG3: total execution time across the paper's evaluation targets
//! (static S1–S3, dynamic overlay, custom HLS; ARM software reference).
//!
//! Prints the modeled figure series, then times the real engine execution
//! per target.

use jit_overlay::benchkit::Bench;
use jit_overlay::exec::Engine;
use jit_overlay::jit::Jit;
use jit_overlay::patterns::Composition;
use jit_overlay::report::{ms, speedup, Table};
use jit_overlay::timing::Target;
use jit_overlay::{workload, OverlayConfig};

fn main() {
    let n = 4096; // the paper's 16 KB
    let mut engine = Engine::new(OverlayConfig::default()).unwrap();
    let comp = Composition::vmul_reduce(n);
    let acc = Jit.compile(&engine.fabric, &engine.lib, &comp).unwrap();
    let a = workload::vector(n, 1, -2.0, 2.0);
    let b = workload::vector(n, 2, -2.0, 2.0);

    // modeled series (the regenerated figure)
    let mut t = Table::new(
        &format!("FIG3 model series (n={n}, {} KB)", n * 4 / 1024),
        &["target", "total (ms)", "vs dynamic"],
    );
    let dyn_total = engine
        .run(&acc, &[a.clone(), b.clone()], Target::DynamicOverlay)
        .unwrap()
        .timing
        .total();
    for tgt in Target::ALL {
        let r = engine.run(&acc, &[a.clone(), b.clone()], tgt).unwrap();
        t.row(&[tgt.name(), ms(r.timing.total()), speedup(r.timing.total(), dyn_total)]);
    }
    println!("{}", t.render());
    println!(
        "PR overhead (startup): {:.3} ms\n",
        engine.fabric.cfg.full_reconfig_seconds() * 1e3
    );

    let mut bench = Bench::new("fig3_targets");
    for tgt in Target::ALL {
        bench.bench(&tgt.name(), || {
            engine
                .run(&acc, &[a.clone(), b.clone()], tgt)
                .unwrap()
                .timing
                .total()
        });
    }
    bench.finish();
}
