//! Bench T-ISA: controller interpreter throughput — the L3 hot loop.
//!
//! The whole request path funnels through `Controller::run`; this bench
//! isolates it: (a) control-only scalar loops (branch/cmp/inc pressure),
//! (b) the full VMUL&Reduce program at the paper's 16 KB, and (c) codec
//! round-trips (encode/decode of instruction BRAM images).

use jit_overlay::benchkit::Bench;
use jit_overlay::exec::Engine;
use jit_overlay::isa::{encode, Instr, Opcode, Program};
use jit_overlay::jit::Jit;
use jit_overlay::overlay::{Controller, ExternalIo, Fabric};
use jit_overlay::patterns::Composition;
use jit_overlay::timing::Target;
use jit_overlay::{workload, OverlayConfig};

fn scalar_loop_program(cfg: &OverlayConfig, iters: i16) -> Program {
    Program::new(
        vec![
            Instr::ldi(0, 0, 0),
            Instr::ldi(0, 1, iters),
            Instr::op_a(Opcode::IncR, 0, 0),
            Instr { op: Opcode::CmpR, tile: 0, a: 0, b: 1, imm: 0 },
            Instr { op: Opcode::Bne, tile: 0, a: 0, b: 0, imm: -3 },
            Instr::halt(),
        ],
        cfg,
    )
    .unwrap()
}

fn main() {
    let cfg = OverlayConfig::default();
    let mut bench = Bench::new("isa_interpret");

    // (a) control-only interpreter loop
    let prog = scalar_loop_program(&cfg, 500);
    let mut fabric = Fabric::new(cfg.clone()).unwrap();
    let ctl = Controller::default();
    bench.bench("scalar_loop_500", || {
        fabric.reset_data();
        let mut io = ExternalIo::default();
        ctl.run(&mut fabric, &prog, &mut io).unwrap().instrs
    });

    // (b) full 16 KB VMUL&Reduce end to end
    let n = 4096;
    let mut engine = Engine::new(cfg.clone()).unwrap();
    let acc = Jit
        .compile(&engine.fabric, &engine.lib, &Composition::vmul_reduce(n))
        .unwrap();
    let a = workload::vector(n, 1, -1.0, 1.0);
    let b2 = workload::vector(n, 2, -1.0, 1.0);
    bench.bench("vmul_reduce_16kb", || {
        engine
            .run(&acc, &[a.clone(), b2.clone()], Target::DynamicOverlay)
            .unwrap()
            .stats
            .unwrap()
            .instrs
    });

    // (c) codec round-trip of the compiled program image
    let words = acc.program().to_words();
    bench.bench("decode_program", || encode::decode_all(&words).unwrap().len());
    bench.bench("encode_program", || {
        encode::encode_all(acc.program().instrs()).unwrap().len()
    });
    bench.finish();
}
