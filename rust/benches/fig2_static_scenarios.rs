//! Bench FIG2: VMUL&Reduce on the static overlay, three scheduling
//! scenarios (paper Fig. 2).
//!
//! Times the *actual* end-to-end engine execution (JIT output running on
//! the fabric simulator); the modeled Fig. 2 table (the paper's
//! milliseconds) is printed first so the bench output regenerates the
//! figure's series.

use jit_overlay::benchkit::Bench;
use jit_overlay::exec::Engine;
use jit_overlay::jit::Jit;
use jit_overlay::patterns::Composition;
use jit_overlay::place::StaticScenario;
use jit_overlay::report::{ms, Table};
use jit_overlay::timing::Target;
use jit_overlay::{workload, OverlayConfig};

fn main() {
    let n = 4096;
    let mut engine = Engine::new(OverlayConfig::default()).unwrap();
    let comp = Composition::vmul_reduce(n);
    let acc = Jit.compile(&engine.fabric, &engine.lib, &comp).unwrap();
    let a = workload::vector(n, 1, -2.0, 2.0);
    let b = workload::vector(n, 2, -2.0, 2.0);

    // --- regenerated figure series (modeled milliseconds) -----------------
    let mut t = Table::new(
        &format!("FIG2 model series (n={n})"),
        &["scenario", "pass-throughs", "total (ms)"],
    );
    for s in StaticScenario::ALL {
        let r = engine
            .run(&acc, &[a.clone(), b.clone()], Target::StaticOverlay(s))
            .unwrap();
        t.row(&[s.name().into(), s.pass_throughs().to_string(), ms(r.timing.total())]);
    }
    println!("{}", t.render());

    // --- harness wall-time of the real execution path ---------------------
    let mut bench = Bench::new("fig2_static_scenarios");
    for s in StaticScenario::ALL {
        bench.bench(s.name(), || {
            engine
                .run(&acc, &[a.clone(), b.clone()], Target::StaticOverlay(s))
                .unwrap()
                .timing
                .total()
        });
    }
    bench.finish();
}
