//! Bench T-BITS: bitstream-count reduction — the paper's first static-flow
//! limitation ("all variants of programming patterns must be synthesized").
//!
//! Dynamic overlay: one bitstream per (operator × region class).
//! Static flow: one per (operator × tile position), because PR bitstreams
//! are location-specific. The table quantifies the reduction for the
//! pattern library; the bench times library construction + counting.

use jit_overlay::benchkit::Bench;
use jit_overlay::bitstream::{BitstreamLibrary, OperatorKind};
use jit_overlay::patterns::Composition;
use jit_overlay::report::Table;
use jit_overlay::OverlayConfig;

fn pattern_suite(n: usize) -> Vec<(&'static str, Composition)> {
    use OperatorKind::*;
    vec![
        ("vmul_reduce", Composition::vmul_reduce(n)),
        ("axpy", Composition::axpy(2.0, n)),
        ("filter_reduce", Composition::filter_reduce(0.5, n)),
        ("norm_chain", Composition::chain(&[Abs, Sqrt, Log], n).unwrap()),
        ("branch", Composition::branch(0.0, Sqrt, Square, n)),
    ]
}

fn main() {
    let cfg = OverlayConfig::default();
    let lib = BitstreamLibrary::standard(&cfg);
    let positions = cfg.tiles();
    let mut t = Table::new(
        "T-BITS — bitstreams required: dynamic vs static flow",
        &["pattern", "dynamic", "static (×9 positions)", "reduction"],
    );
    let mut static_total = 0usize;
    for (name, comp) in pattern_suite(1024) {
        let ops = comp.ops();
        let d = lib.dynamic_variants_for(&ops);
        let s = lib.static_variants_for(&ops, positions);
        static_total += s;
        t.row(&[
            name.into(),
            d.to_string(),
            s.to_string(),
            format!("{:.1}x", s as f64 / d.max(1) as f64),
        ]);
    }
    t.row(&[
        "WHOLE LIBRARY".into(),
        lib.len().to_string(),
        static_total.to_string(),
        format!("{:.1}x", static_total as f64 / lib.len() as f64),
    ]);
    println!("{}", t.render());

    let mut bench = Bench::new("bitstream_count");
    bench.bench("library_build", || BitstreamLibrary::standard(&cfg).len());
    let ops = Composition::branch(0.0, OperatorKind::Sqrt, OperatorKind::Square, 1024).ops();
    bench.bench("variant_counting", || {
        (lib.dynamic_variants_for(&ops), lib.static_variants_for(&ops, 9))
    });
        bench.finish();
    match bench.write_json() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("BENCH json not written: {e}"),
    }
}
