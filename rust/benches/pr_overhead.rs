//! Bench T-PR: partial-reconfiguration overhead and its amortization.
//!
//! The paper: PR ≈ 1.250 ms, "only incurred at startup or initial
//! configuration". This bench (a) validates the modeled full-fabric
//! download time, (b) sweeps data sizes to find where dynamic-including-PR
//! beats the static overlay, and (c) times the PR manager's hot path
//! (apply with cold vs warm residency cache).

use jit_overlay::benchkit::Bench;
use jit_overlay::exec::Engine;
use jit_overlay::jit::Jit;
use jit_overlay::patterns::Composition;
use jit_overlay::place::StaticScenario;
use jit_overlay::report::{ms, Table};
use jit_overlay::timing::Target;
use jit_overlay::{workload, OverlayConfig};

fn print_sweep() {
    let mut engine = Engine::new(OverlayConfig::default()).unwrap();
    let mut t = Table::new(
        "T-PR amortization sweep (VMUL&Reduce)",
        &["bytes/op", "dynamic (ms)", "dynamic+PR (ms)", "static-s3 (ms)", "crossover"],
    );
    for &bytes in &workload::SWEEP_SIZES {
        let n = bytes / 4;
        let comp = Composition::vmul_reduce(n);
        let acc = Jit.compile(&engine.fabric, &engine.lib, &comp).unwrap();
        let a = workload::vector(n, 3, -1.0, 1.0);
        let b = workload::vector(n, 4, -1.0, 1.0);
        engine.fabric.reset_full();
        let d = engine
            .run(&acc, &[a.clone(), b.clone()], Target::DynamicOverlay)
            .unwrap();
        let s3 = engine
            .run(&acc, &[a, b], Target::StaticOverlay(StaticScenario::S3))
            .unwrap();
        t.row(&[
            bytes.to_string(),
            ms(d.timing.total()),
            ms(d.total_with_reconfig()),
            ms(s3.timing.total()),
            (d.total_with_reconfig() < s3.timing.total()).to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "full-fabric reconfig (model): {:.4} ms (paper: ~1.250 ms)\n",
        OverlayConfig::default().full_reconfig_seconds() * 1e3
    );
}

fn main() {
    print_sweep();

    let mut engine = Engine::new(OverlayConfig::default()).unwrap();
    let comp = Composition::vmul_reduce(1024);
    let acc = Jit.compile(&engine.fabric, &engine.lib, &comp).unwrap();

    let mut bench = Bench::new("pr_overhead");
    bench.bench("apply_cold", || {
        engine.fabric.reset_full();
        engine
            .pr
            .apply(&mut engine.fabric, &engine.lib, acc.placement())
            .unwrap()
            .downloads
    });
    engine
        .pr
        .apply(&mut engine.fabric, &engine.lib, acc.placement())
        .unwrap();
    bench.bench("apply_warm", || {
        engine
            .pr
            .apply(&mut engine.fabric, &engine.lib, acc.placement())
            .unwrap()
            .cache_hits
    });
        bench.finish();
    match bench.write_json() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("BENCH json not written: {e}"),
    }
}
