//! Bench T-FRAG: internal fragmentation of the paper's non-uniform PR
//! sizing (1/4 large, 3/4 small) versus a uniform all-large fabric.
//!
//! Prints the fragmentation study table for representative operator mixes,
//! then times the placer+fragmentation accounting hot path.

use jit_overlay::benchkit::{write_bench_json, Bench, JsonObject};
use jit_overlay::bitstream::{BitstreamLibrary, OperatorKind};
use jit_overlay::coordinator::{Coordinator, Request};
use jit_overlay::overlay::Fabric;
use jit_overlay::patterns::Composition;
use jit_overlay::place::{frag, DynamicPlacer};
use jit_overlay::report::Table;
use jit_overlay::OverlayConfig;

fn mixes() -> Vec<(&'static str, Vec<OperatorKind>)> {
    use OperatorKind::*;
    vec![
        ("vmul_reduce (all small)", vec![Mul, AccSum]),
        ("axpy (all small)", vec![Mul, Add]),
        ("norm chain (mixed)", vec![Abs, Sqrt, AccSum]),
        ("transcendental (large)", vec![Sqrt, Log]),
        ("5-stage mixed", vec![Abs, Square, Mul, Sqrt, AccSum]),
    ]
}

/// The paper's trade-off study: non-uniform sizing (1/4 large) cuts
/// fragmentation but costs *mapping flexibility* — pipelines with many
/// large-region operators stop fitting. Sample random pipelines on both
/// fabrics and report placeability vs mean fragmentation.
fn mappability_study() {
    use jit_overlay::workload::Rng;
    use OperatorKind::*;
    let small_pool = [Add, Sub, Mul, Max, Min, Neg, Abs, Square, Relu, AccSum];
    let large_pool = [Sqrt, Sin, Cos, Log, Exp, Tanh];

    let mut uniform_cfg = OverlayConfig::default();
    uniform_cfg.sizing.large_every = 1; // every tile large
    let configs = [
        ("non-uniform (paper, 1/4 large)", OverlayConfig::default()),
        ("uniform all-large", uniform_cfg),
    ];

    let mut t = Table::new(
        "T-FRAG ablation — mapping flexibility vs fragmentation (500 random pipelines)",
        &["fabric sizing", "placeable", "mean frag (placed)"],
    );
    for (name, cfg) in configs {
        let lib = BitstreamLibrary::standard(&cfg);
        let fabric = Fabric::new(cfg).unwrap();
        let mut rng = Rng::new(0xF2A6);
        let (mut placed, mut total, mut frag_sum) = (0usize, 0usize, 0.0f64);
        for _ in 0..500 {
            let len = 1 + rng.below(6);
            let ops: Vec<OperatorKind> = (0..len)
                .map(|_| {
                    if rng.below(3) == 0 {
                        large_pool[rng.below(large_pool.len())]
                    } else {
                        small_pool[rng.below(small_pool.len())]
                    }
                })
                .collect();
            total += 1;
            if let Ok(p) = DynamicPlacer.place(&fabric, &lib, &ops) {
                placed += 1;
                frag_sum += frag::fragmentation(&p).mean_internal;
            }
        }
        t.row(&[
            name.into(),
            format!("{:.0}%", 100.0 * placed as f64 / total as f64),
            format!("{:.3}", frag_sum / placed.max(1) as f64),
        ]);
    }
    println!("{}", t.render());
}

fn main() {
    let cfg = OverlayConfig::default();
    let lib = BitstreamLibrary::standard(&cfg);
    let fabric = Fabric::new(cfg).unwrap();
    mappability_study();

    let mut t = Table::new(
        "T-FRAG — internal fragmentation: non-uniform vs uniform-large sizing",
        &["operator mix", "non-uniform frag", "uniform-large frag", "oversized tiles"],
    );
    for (name, ops) in mixes() {
        let p = DynamicPlacer.place(&fabric, &lib, &ops).unwrap();
        let (nu, u) = frag::vs_uniform_large(&p);
        let r = frag::fragmentation(&p);
        t.row(&[
            name.into(),
            format!("{nu:.3}"),
            format!("{u:.3}"),
            r.oversized_tiles.to_string(),
        ]);
    }
    println!("{}", t.render());

    let mut bench = Bench::new("fragmentation");
    for (name, ops) in mixes() {
        bench.bench(name, || {
            let p = DynamicPlacer.place(&fabric, &lib, &ops).unwrap();
            frag::fragmentation(&p).mean_internal
        });
    }
    bench.finish();

    // Online defragmentation demo: a 6-stage small-op chain spills its
    // last stage onto Large tile 3 (snake order); one compaction pass
    // migrates it to a free Small tile and strictly reduces the live mean
    // internal fragmentation. Emitted as BENCH_fragmentation.json.
    use OperatorKind::*;
    let mut c = Coordinator::new(OverlayConfig::default()).unwrap();
    c.set_compact(true);
    let comp = Composition::chain(&[Neg, Abs, Square, Relu, Neg, Abs], 1024).unwrap();
    c.submit(&Request::dynamic(comp, vec![vec![1.5f32; 1024]])).unwrap();
    let (frag_before, frag_after) = c.compact_once().expect("oversized resident compacts");
    println!(
        "\ncompaction: mean_internal {frag_before:.3} -> {frag_after:.3} ({} migrations)",
        c.metrics.migrations
    );
    let mut o = JsonObject::new();
    o.str("group", "fragmentation")
        .num("frag_before", frag_before)
        .num("frag_after", frag_after)
        .int("migrations", c.metrics.migrations);
    match write_bench_json("fragmentation", &o.finish()) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => println!("bench json skipped: {e}"),
    }
}
