//! In-tree micro-benchmark harness (criterion-style, offline build).
//!
//! Usage mirrors criterion closely enough that the bench sources read the
//! same way:
//!
//! ```no_run
//! use jit_overlay::benchkit::Bench;
//! let mut b = Bench::new("my_bench");
//! b.bench("fast_path", || 2 + 2);
//! b.finish();
//! ```
//!
//! Method: warm up for `warmup_iters`, then run batches until
//! `measure_time` elapses (≥ `min_samples` samples), reporting mean, p50,
//! p95 and throughput-friendly ns/iter. `black_box` prevents the optimizer
//! from deleting measured work.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

/// Re-export of `std::hint::black_box` under the criterion-familiar name.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Statistics of one benchmark.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub samples: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

/// A group of related benchmarks, printed as one table.
pub struct Bench {
    group: String,
    warmup_iters: u32,
    measure_time: Duration,
    min_samples: usize,
    results: Vec<(String, Stats)>,
}

impl Bench {
    pub fn new(group: &str) -> Bench {
        // honor `--quick` on the command line (cargo bench -- --quick)
        let quick = std::env::args().any(|a| a == "--quick");
        Bench {
            group: group.to_string(),
            warmup_iters: if quick { 3 } else { 20 },
            measure_time: if quick {
                Duration::from_millis(200)
            } else {
                Duration::from_millis(1500)
            },
            min_samples: if quick { 10 } else { 30 },
            results: Vec::new(),
        }
    }

    /// Time `f` and record the result under `name`.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> Stats {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples_ns: Vec<f64> = Vec::with_capacity(self.min_samples * 2);
        let t_start = Instant::now();
        while t_start.elapsed() < self.measure_time || samples_ns.len() < self.min_samples {
            let t0 = Instant::now();
            black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
            if samples_ns.len() >= 1_000_000 {
                break;
            }
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples_ns.len();
        let stats = Stats {
            samples: n,
            mean_ns: samples_ns.iter().sum::<f64>() / n as f64,
            p50_ns: samples_ns[n / 2],
            p95_ns: samples_ns[(n as f64 * 0.95) as usize % n],
            min_ns: samples_ns[0],
        };
        self.results.push((name.to_string(), stats));
        stats
    }

    /// Render the group's results as one JSON object, following the
    /// repo's `BENCH_*.json` convention (see `write_json`).
    pub fn to_json(&self) -> String {
        let mut arr = JsonArray::new();
        for (name, s) in &self.results {
            let mut r = JsonObject::new();
            r.str("name", name)
                .int("samples", s.samples as u64)
                .num("mean_ns", s.mean_ns)
                .num("p50_ns", s.p50_ns)
                .num("p95_ns", s.p95_ns)
                .num("min_ns", s.min_ns);
            arr.raw(&r.finish());
        }
        let mut o = JsonObject::new();
        o.str("group", &self.group).raw("results", &arr.finish());
        o.finish()
    }

    /// Write `BENCH_<group>.json` into `$BENCH_JSON_DIR` (or the CWD), so
    /// CI can harvest machine-readable results next to the printed table.
    pub fn write_json(&self) -> std::io::Result<std::path::PathBuf> {
        write_bench_json(&self.group, &self.to_json())
    }

    /// Print the group's results table. Call once per group.
    pub fn finish(&self) {
        println!("\n== bench group: {} ==", self.group);
        println!(
            "{:<42} {:>10} {:>12} {:>12} {:>12}",
            "benchmark", "samples", "mean", "p50", "p95"
        );
        for (name, s) in &self.results {
            println!(
                "{:<42} {:>10} {:>12} {:>12} {:>12}",
                format!("{}/{}", self.group, name),
                s.samples,
                fmt_ns(s.mean_ns),
                fmt_ns(s.p50_ns),
                fmt_ns(s.p95_ns),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Minimal JSON emitter (the crate is dependency-free by design)
// ---------------------------------------------------------------------------

/// Incremental JSON object builder. Only what the bench convention needs:
/// strings, integers, floats (non-finite values become `null` — JSON has
/// no NaN/inf), and pre-rendered nested values via [`JsonObject::raw`].
#[derive(Default)]
pub struct JsonObject {
    buf: String,
    any: bool,
}

impl JsonObject {
    pub fn new() -> JsonObject {
        JsonObject::default()
    }

    fn key(&mut self, k: &str) -> &mut Self {
        if self.any {
            self.buf.push(',');
        }
        self.any = true;
        self.buf.push('"');
        self.buf.push_str(&json_escape(k));
        self.buf.push_str("\":");
        self
    }

    pub fn str(&mut self, k: &str, v: &str) -> &mut Self {
        self.key(k);
        self.buf.push('"');
        self.buf.push_str(&json_escape(v));
        self.buf.push('"');
        self
    }

    pub fn int(&mut self, k: &str, v: u64) -> &mut Self {
        self.key(k);
        self.buf.push_str(&v.to_string());
        self
    }

    /// Finite floats render as numbers; NaN and ±inf render as `null`.
    pub fn num(&mut self, k: &str, v: f64) -> &mut Self {
        self.key(k);
        if v.is_finite() {
            self.buf.push_str(&format!("{v}"));
        } else {
            self.buf.push_str("null");
        }
        self
    }

    /// Splice an already-rendered JSON value (object or array) under `k`.
    pub fn raw(&mut self, k: &str, json: &str) -> &mut Self {
        self.key(k);
        self.buf.push_str(json);
        self
    }

    pub fn finish(&self) -> String {
        format!("{{{}}}", self.buf)
    }
}

/// Incremental JSON array of pre-rendered values.
#[derive(Default)]
pub struct JsonArray {
    buf: String,
    any: bool,
}

impl JsonArray {
    pub fn new() -> JsonArray {
        JsonArray::default()
    }

    pub fn raw(&mut self, json: &str) -> &mut Self {
        if self.any {
            self.buf.push(',');
        }
        self.any = true;
        self.buf.push_str(json);
        self
    }

    pub fn finish(&self) -> String {
        format!("[{}]", self.buf)
    }
}

/// Escape a string for embedding in a JSON string literal.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Where `BENCH_<name>.json` lands: `$BENCH_JSON_DIR` when set, else CWD.
pub fn bench_json_path(name: &str) -> std::path::PathBuf {
    let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
    std::path::Path::new(&dir).join(format!("BENCH_{name}.json"))
}

/// Write one machine-readable result file per the `BENCH_*.json`
/// convention and return its path.
pub fn write_bench_json(name: &str, json: &str) -> std::io::Result<std::path::PathBuf> {
    let path = bench_json_path(name);
    std::fs::write(&path, format!("{json}\n"))?;
    Ok(path)
}

/// Human-format nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut b = Bench::new("test");
        b.measure_time = Duration::from_millis(20);
        b.min_samples = 5;
        b.warmup_iters = 1;
        let s = b.bench("noop", || 1 + 1);
        assert!(s.samples >= 5);
        assert!(s.min_ns <= s.p50_ns);
        assert!(s.p50_ns <= s.p95_ns.max(s.p50_ns));
        assert!(s.mean_ns > 0.0);
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.500 ms");
        assert_eq!(fmt_ns(3.2e9), "3.200 s");
    }

    #[test]
    fn black_box_passes_through() {
        assert_eq!(black_box(42), 42);
    }

    #[test]
    fn json_objects_escape_and_null_non_finite() {
        let mut o = JsonObject::new();
        o.str("name", "a\"b\\c\nd\u{1}")
            .int("count", 3)
            .num("p50", 1.5)
            .num("bad", f64::NAN)
            .num("worse", f64::INFINITY);
        assert_eq!(
            o.finish(),
            r#"{"name":"a\"b\\c\nd\u0001","count":3,"p50":1.5,"bad":null,"worse":null}"#
        );
    }

    #[test]
    fn json_arrays_nest_in_objects() {
        let mut arr = JsonArray::new();
        arr.raw("1").raw(r#"{"x":2}"#);
        let mut o = JsonObject::new();
        o.raw("items", &arr.finish());
        assert_eq!(o.finish(), r#"{"items":[1,{"x":2}]}"#);
        assert_eq!(JsonArray::new().finish(), "[]");
        assert_eq!(JsonObject::new().finish(), "{}");
    }

    #[test]
    fn bench_to_json_lists_every_result() {
        let mut b = Bench::new("unit");
        b.measure_time = Duration::from_millis(1);
        b.min_samples = 2;
        b.warmup_iters = 0;
        b.bench("one", || 1);
        b.bench("two", || 2);
        let j = b.to_json();
        assert!(j.starts_with(r#"{"group":"unit","results":["#), "{j}");
        assert!(j.contains(r#""name":"one""#) && j.contains(r#""name":"two""#), "{j}");
        assert!(j.contains(r#""p50_ns":"#), "{j}");
    }

    #[test]
    fn bench_json_path_defaults_to_cwd() {
        if std::env::var("BENCH_JSON_DIR").is_err() {
            assert_eq!(
                bench_json_path("service"),
                std::path::Path::new(".").join("BENCH_service.json")
            );
        }
    }
}
