//! In-tree micro-benchmark harness (criterion-style, offline build).
//!
//! Usage mirrors criterion closely enough that the bench sources read the
//! same way:
//!
//! ```no_run
//! use jit_overlay::benchkit::Bench;
//! let mut b = Bench::new("my_bench");
//! b.bench("fast_path", || 2 + 2);
//! b.finish();
//! ```
//!
//! Method: warm up for `warmup_iters`, then run batches until
//! `measure_time` elapses (≥ `min_samples` samples), reporting mean, p50,
//! p95 and throughput-friendly ns/iter. `black_box` prevents the optimizer
//! from deleting measured work.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

/// Re-export of `std::hint::black_box` under the criterion-familiar name.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Statistics of one benchmark.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub samples: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

/// A group of related benchmarks, printed as one table.
pub struct Bench {
    group: String,
    warmup_iters: u32,
    measure_time: Duration,
    min_samples: usize,
    results: Vec<(String, Stats)>,
}

impl Bench {
    pub fn new(group: &str) -> Bench {
        // honor `--quick` on the command line (cargo bench -- --quick)
        let quick = std::env::args().any(|a| a == "--quick");
        Bench {
            group: group.to_string(),
            warmup_iters: if quick { 3 } else { 20 },
            measure_time: if quick {
                Duration::from_millis(200)
            } else {
                Duration::from_millis(1500)
            },
            min_samples: if quick { 10 } else { 30 },
            results: Vec::new(),
        }
    }

    /// Time `f` and record the result under `name`.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> Stats {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples_ns: Vec<f64> = Vec::with_capacity(self.min_samples * 2);
        let t_start = Instant::now();
        while t_start.elapsed() < self.measure_time || samples_ns.len() < self.min_samples {
            let t0 = Instant::now();
            black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
            if samples_ns.len() >= 1_000_000 {
                break;
            }
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples_ns.len();
        let stats = Stats {
            samples: n,
            mean_ns: samples_ns.iter().sum::<f64>() / n as f64,
            p50_ns: samples_ns[n / 2],
            p95_ns: samples_ns[(n as f64 * 0.95) as usize % n],
            min_ns: samples_ns[0],
        };
        self.results.push((name.to_string(), stats));
        stats
    }

    /// Print the group's results table. Call once per group.
    pub fn finish(&self) {
        println!("\n== bench group: {} ==", self.group);
        println!(
            "{:<42} {:>10} {:>12} {:>12} {:>12}",
            "benchmark", "samples", "mean", "p50", "p95"
        );
        for (name, s) in &self.results {
            println!(
                "{:<42} {:>10} {:>12} {:>12} {:>12}",
                format!("{}/{}", self.group, name),
                s.samples,
                fmt_ns(s.mean_ns),
                fmt_ns(s.p50_ns),
                fmt_ns(s.p95_ns),
            );
        }
    }
}

/// Human-format nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut b = Bench::new("test");
        b.measure_time = Duration::from_millis(20);
        b.min_samples = 5;
        b.warmup_iters = 1;
        let s = b.bench("noop", || 1 + 1);
        assert!(s.samples >= 5);
        assert!(s.min_ns <= s.p50_ns);
        assert!(s.p50_ns <= s.p95_ns.max(s.p50_ns));
        assert!(s.mean_ns > 0.0);
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.500 ms");
        assert_eq!(fmt_ns(3.2e9), "3.200 s");
    }

    #[test]
    fn black_box_passes_through() {
        assert_eq!(black_box(42), 42);
    }
}
