//! Online defragmentation: migrate residents to undo internal fragmentation.
//!
//! The T-FRAG study ([`super::frag`]) measures the cost of the paper's
//! non-uniform region sizing: a small-footprint operator parked in one of
//! the two Large regions wastes most of that region's budget **and**
//! starves the next large-region stage (`sqrt`, `sin`, fused pairs) of the
//! only tiles it can use. This module plans the cure: during quiet drain
//! windows the coordinator migrates such residents onto free healthy Small
//! tiles. Every planned move strictly reduces that tile's internal
//! fragmentation (the same footprint in a strictly smaller budget leaves
//! strictly less slack), so a non-empty plan strictly reduces
//! [`FragReport::mean_internal`] — and an empty plan is a guaranteed no-op.
//!
//! Planning is pure (no fabric mutation) and deterministic: sources and
//! targets are scanned in tile-index order. Execution lives in the
//! coordinator, which downloads each resident into its new tile, clears
//! the old region, and republishes any cached placement plans that touched
//! the moved tiles (see `Coordinator::compact_once`).

use crate::bitstream::{OperatorKind, RegionClass};
use crate::overlay::Fabric;

use super::frag::{assignment_footprint, fragmentation, FragReport};
use super::{Assignment, Placement};

/// One planned migration: the resident of `from` moves to `to`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileMove {
    /// Source tile (currently holds the resident).
    pub from: usize,
    /// Destination tile (free and healthy at planning time).
    pub to: usize,
    /// The resident being moved.
    pub op: OperatorKind,
    /// Its fused tail, when the tile hosts a fused pair.
    pub tail: Option<OperatorKind>,
}

/// A compaction plan with its predicted fragmentation improvement.
#[derive(Debug, Clone, Default)]
pub struct CompactionPlan {
    /// Migrations in execution order.
    pub moves: Vec<TileMove>,
    /// Fragmentation of the live residency before any move.
    pub before: FragReport,
    /// Predicted fragmentation after all moves complete.
    pub after: FragReport,
}

impl CompactionPlan {
    /// True when compaction has nothing to do.
    pub fn is_noop(&self) -> bool {
        self.moves.is_empty()
    }
}

/// The fabric's live residency as a placement (one assignment per occupied
/// tile, in tile-index order) — the input the frag report scores.
pub fn live_placement(fabric: &Fabric) -> Placement {
    Placement {
        assignments: fabric
            .tiles
            .iter()
            .enumerate()
            .filter_map(|(t, tile)| {
                tile.resident.map(|op| Assignment {
                    op,
                    tile: t,
                    class: tile.class,
                    tail: tile.resident_tail,
                })
            })
            .collect(),
    }
}

/// Plan migrations against `fabric`'s current occupancy.
///
/// A tile is a migration source when it is a Large region whose resident's
/// full footprint (head plus fused tail, per [`assignment_footprint`])
/// would fit the Small budget — the "oversized" tiles of the frag report.
/// Targets are free, healthy Small tiles, consumed in index order; each is
/// used at most once. Residents that genuinely need their Large region are
/// never touched, and occupied or quarantined tiles are never targets, so
/// executing the plan can never clobber a resident in use.
pub fn plan_compaction(fabric: &Fabric) -> CompactionPlan {
    let live = live_placement(fabric);
    let before = fragmentation(&live);

    let mut targets = fabric
        .free_tiles_iter()
        .filter(|&t| fabric.tiles[t].class == RegionClass::Small);
    let small_budget = RegionClass::Small.budget();

    let mut moves = Vec::new();
    let mut relocated = live.assignments.clone();
    for a in &mut relocated {
        if a.class != RegionClass::Large || !assignment_footprint(a).fits(&small_budget) {
            continue;
        }
        let Some(to) = targets.next() else { break };
        moves.push(TileMove { from: a.tile, to, op: a.op, tail: a.tail });
        a.tile = to;
        a.class = RegionClass::Small;
    }

    let after = if moves.is_empty() { before } else { fragmentation(&Placement { assignments: relocated }) };
    CompactionPlan { moves, before, after }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitstream::BitstreamLibrary;
    use crate::config::OverlayConfig;

    fn setup() -> (Fabric, BitstreamLibrary) {
        let cfg = OverlayConfig::default();
        let lib = BitstreamLibrary::standard(&cfg);
        (Fabric::new(cfg).unwrap(), lib)
    }

    fn load(f: &mut Fabric, lib: &BitstreamLibrary, tile: usize, op: OperatorKind) {
        let bs = lib.get(op, f.tiles[tile].class).unwrap().clone();
        f.load_bitstream(tile, &bs).unwrap();
    }

    #[test]
    fn empty_fabric_is_a_noop() {
        let (f, _) = setup();
        let p = plan_compaction(&f);
        assert!(p.is_noop());
        assert_eq!(p.before, p.after);
        assert_eq!(p.before.tiles, 0);
    }

    #[test]
    fn small_resident_on_large_tile_is_migrated() {
        let (mut f, lib) = setup();
        load(&mut f, &lib, 3, OperatorKind::Add); // Large tile, Small-footprint op
        let p = plan_compaction(&f);
        assert_eq!(p.moves.len(), 1);
        assert_eq!(p.moves[0].from, 3);
        assert_eq!(p.moves[0].op, OperatorKind::Add);
        assert_eq!(f.tiles[p.moves[0].to].class, RegionClass::Small);
        assert!(f.tile_is_free(p.moves[0].to));
        // the move strictly tightens the budget around the same footprint
        assert!(p.after.mean_internal < p.before.mean_internal);
        assert_eq!(p.before.oversized_tiles, 1);
        assert_eq!(p.after.oversized_tiles, 0);
    }

    #[test]
    fn genuinely_large_residents_stay_put() {
        let (mut f, lib) = setup();
        load(&mut f, &lib, 3, OperatorKind::Sqrt); // needs the Large budget
        load(&mut f, &lib, 7, OperatorKind::Sin);
        let p = plan_compaction(&f);
        assert!(p.is_noop());
        assert_eq!(p.before.mean_internal, p.after.mean_internal);
    }

    #[test]
    fn no_free_small_tiles_means_noop() {
        let (mut f, lib) = setup();
        load(&mut f, &lib, 3, OperatorKind::Add);
        // occupy every small tile so the planner has nowhere to move it
        for t in 0..f.tiles.len() {
            if f.tiles[t].class == RegionClass::Small {
                load(&mut f, &lib, t, OperatorKind::Mul);
            }
        }
        assert!(plan_compaction(&f).is_noop());
    }

    #[test]
    fn quarantined_tiles_are_never_targets() {
        let (mut f, lib) = setup();
        load(&mut f, &lib, 3, OperatorKind::Add);
        // quarantine every small tile except tile 6
        for t in [0usize, 1, 2, 4, 5, 8] {
            assert!(f.quarantine(t));
        }
        let p = plan_compaction(&f);
        assert_eq!(p.moves.len(), 1);
        assert_eq!(p.moves[0].to, 6, "only healthy free small tile");
    }

    #[test]
    fn both_large_tiles_compact_in_index_order() {
        let (mut f, lib) = setup();
        load(&mut f, &lib, 3, OperatorKind::Add);
        load(&mut f, &lib, 7, OperatorKind::Mul);
        let p = plan_compaction(&f);
        assert_eq!(p.moves.len(), 2);
        assert_eq!((p.moves[0].from, p.moves[0].to), (3, 0));
        assert_eq!((p.moves[1].from, p.moves[1].to), (7, 1));
        assert!(p.after.mean_internal < p.before.mean_internal);
        // planning is pure: the fabric is untouched and replanning agrees
        assert_eq!(plan_compaction(&f).moves, p.moves);
        assert_eq!(f.tiles[3].resident, Some(OperatorKind::Add));
    }

    #[test]
    fn live_placement_reflects_fused_residency() {
        let (mut f, lib) = setup();
        let fused = crate::bitstream::Bitstream::synthesize_fused(
            OperatorKind::Mul,
            OperatorKind::AccSum,
            RegionClass::Large,
            &f.cfg,
        );
        f.load_bitstream(3, &fused).unwrap();
        load(&mut f, &lib, 0, OperatorKind::Abs);
        let live = live_placement(&f);
        assert_eq!(live.assignments.len(), 2);
        let a3 = live.assignments.iter().find(|a| a.tile == 3).unwrap();
        assert_eq!(a3.tail, Some(OperatorKind::AccSum));
        // mul+acc_sum overflows the Small budget: not a migration source
        assert!(plan_compaction(&f).is_noop());
    }
}
