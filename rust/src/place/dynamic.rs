//! The dynamic placer: contiguous placement via the mesh's snake path.
//!
//! Because bitstreams can be downloaded into any class-compatible PR region
//! at run time, the placer walks the snake order (a Hamiltonian path whose
//! consecutive tiles are always adjacent) and greedily assigns pipeline
//! stages to consecutive *compatible* tiles. A stage needing a large region
//! may have to skip small tiles — the skipped tiles become pass-through
//! hops, which the placer minimizes by scoring all snake windows.

use crate::bitstream::{BitstreamLibrary, OperatorKind, RegionClass};
use crate::error::{Error, Result};
use crate::overlay::{Fabric, Mesh};

use super::{Assignment, Placement};

/// Contiguity-first placer for the dynamic overlay.
#[derive(Debug, Clone, Default)]
pub struct DynamicPlacer;

impl DynamicPlacer {
    /// Place a stage pipeline `ops` onto free tiles of `fabric`.
    ///
    /// Strategy: slide a window along the snake order over *free* tiles;
    /// within a window, stages take the next class-compatible tile (small
    /// ops accept large tiles; large ops require large tiles). The window
    /// with the fewest skipped tiles wins; ties prefer the earliest window
    /// (deterministic).
    pub fn place(
        &self,
        fabric: &Fabric,
        lib: &BitstreamLibrary,
        ops: &[OperatorKind],
    ) -> Result<Placement> {
        // required class per stage
        let needs: Vec<RegionClass> =
            ops.iter().map(|&op| lib.preferred_class(op)).collect::<Result<_>>()?;
        self.place_with_needs(fabric, ops, &needs)
    }

    /// Would [`DynamicPlacer::place_with_needs`] succeed against `fabric`'s
    /// current occupancy? This *is* the placer's own feasibility — a greedy
    /// earliest-compatible assignment over the free tiles in snake order
    /// ([`try_window`] from the first window), which succeeds iff some
    /// window does — shared so the engine's residency guard can never
    /// disagree with the placer about what fits.
    pub fn feasible(fabric: &Fabric, needs: &[RegionClass]) -> bool {
        if needs.is_empty() {
            return false;
        }
        let free: Vec<usize> = fabric
            .mesh
            .snake_order()
            .into_iter()
            .filter(|&t| fabric.tile_is_free(t))
            .collect();
        try_window(fabric, &free, needs).is_some()
    }

    /// Like [`DynamicPlacer::place`], but with the per-stage region classes
    /// already selected — the placement-only recompile path, where the JIT
    /// front end ran once (on some other fabric) and only the placement
    /// must be redone against this fabric's occupancy.
    pub fn place_with_needs(
        &self,
        fabric: &Fabric,
        ops: &[OperatorKind],
        needs: &[RegionClass],
    ) -> Result<Placement> {
        if ops.is_empty() {
            return Err(Error::Placement("empty pipeline".into()));
        }
        debug_assert_eq!(ops.len(), needs.len());
        let snake = fabric.mesh.snake_order();
        let free: Vec<usize> = snake
            .iter()
            .copied()
            .filter(|&t| fabric.tile_is_free(t))
            .collect();
        if free.len() < ops.len() {
            return Err(Error::Placement(format!(
                "{} stages but only {} free tiles",
                ops.len(),
                free.len()
            )));
        }

        let mut best: Option<(usize, Vec<usize>)> = None; // (skips, tiles)
        for start in 0..free.len() {
            if let Some(tiles) = try_window(fabric, &free[start..], needs) {
                let skips = window_skips(&fabric.mesh, &tiles);
                if best.as_ref().map_or(true, |(s, _)| skips < *s) {
                    best = Some((skips, tiles));
                    if skips == 0 {
                        break; // cannot do better
                    }
                }
            }
        }

        let (_, tiles) = best.ok_or_else(|| {
            Error::Placement(format!(
                "no feasible placement for {} stages (large-region stages may exceed the {} large tiles)",
                ops.len(),
                fabric.cfg.large_tiles()
            ))
        })?;

        Ok(Placement {
            assignments: ops
                .iter()
                .zip(&tiles)
                .map(|(&op, &tile)| Assignment {
                    op,
                    tile,
                    class: fabric.tiles[tile].class,
                    tail: None,
                })
                .collect(),
        })
    }
}

/// Assign stages to the earliest class-compatible tiles of `window`,
/// preserving order. Returns the chosen tiles or None if infeasible.
fn try_window(fabric: &Fabric, window: &[usize], needs: &[RegionClass]) -> Option<Vec<usize>> {
    let mut tiles = Vec::with_capacity(needs.len());
    let mut w = window.iter().copied();
    for &need in needs {
        loop {
            let t = w.next()?;
            let class = fabric.tiles[t].class;
            let ok = match need {
                RegionClass::Small => true, // small ops run in either class
                RegionClass::Large => class == RegionClass::Large,
            };
            if ok {
                tiles.push(t);
                break;
            }
        }
    }
    Some(tiles)
}

/// Total tiles skipped between consecutive chosen stages (pass-throughs).
fn window_skips(mesh: &Mesh, tiles: &[usize]) -> usize {
    tiles
        .windows(2)
        .map(|w| mesh.manhattan(w[0], w[1]).saturating_sub(1))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OverlayConfig;

    fn setup() -> (Fabric, BitstreamLibrary) {
        let cfg = OverlayConfig::default();
        let lib = BitstreamLibrary::standard(&cfg);
        (Fabric::new(cfg).unwrap(), lib)
    }

    #[test]
    fn vmul_reduce_places_contiguously() {
        let (f, lib) = setup();
        let p = DynamicPlacer
            .place(&f, &lib, &[OperatorKind::Mul, OperatorKind::AccSum])
            .unwrap();
        assert!(p.is_contiguous(&f.mesh));
        assert!(p.is_injective());
    }

    #[test]
    fn long_pipeline_follows_snake() {
        let (f, lib) = setup();
        let ops = [
            OperatorKind::Abs,
            OperatorKind::Square,
            OperatorKind::Add,
            OperatorKind::Mul,
            OperatorKind::AccSum,
        ];
        let p = DynamicPlacer.place(&f, &lib, &ops).unwrap();
        assert!(p.is_contiguous(&f.mesh), "{:?}", p.assignments);
        assert!(p.is_injective());
    }

    #[test]
    fn large_op_lands_on_large_tile() {
        let (f, lib) = setup();
        let p = DynamicPlacer
            .place(&f, &lib, &[OperatorKind::Sqrt])
            .unwrap();
        assert_eq!(p.assignments[0].class, RegionClass::Large);
        assert!(f.cfg.is_large_tile(p.assignments[0].tile));
    }

    #[test]
    fn mixed_pipeline_minimizes_skips() {
        let (f, lib) = setup();
        // sqrt requires a large tile (3 or 7 on the default fabric); the
        // placer should pick a window around it with minimal gaps.
        let p = DynamicPlacer
            .place(&f, &lib, &[OperatorKind::Mul, OperatorKind::Sqrt, OperatorKind::AccSum])
            .unwrap();
        assert!(p.is_injective());
        assert!(
            p.max_stage_gap(&f.mesh) <= 1,
            "gap too large: {:?}",
            p.assignments
        );
    }

    #[test]
    fn too_many_stages_fail() {
        let (f, lib) = setup();
        let ops = vec![OperatorKind::Add; 10]; // 10 stages, 9 tiles
        let err = DynamicPlacer.place(&f, &lib, &ops).unwrap_err();
        assert!(err.is_capacity());
    }

    #[test]
    fn too_many_large_stages_fail() {
        let (f, lib) = setup();
        let ops = vec![OperatorKind::Sin; 3]; // only 2 large tiles
        assert!(DynamicPlacer.place(&f, &lib, &ops).is_err());
    }

    #[test]
    fn occupied_tiles_are_skipped() {
        let (mut f, lib) = setup();
        // occupy the first three snake tiles
        let bs = lib.get(OperatorKind::Add, RegionClass::Small).unwrap().clone();
        for t in [0usize, 1, 2] {
            f.load_bitstream(t, &bs).unwrap();
        }
        let p = DynamicPlacer
            .place(&f, &lib, &[OperatorKind::Mul, OperatorKind::AccSum])
            .unwrap();
        for a in &p.assignments {
            assert!(![0, 1, 2].contains(&a.tile));
        }
        assert!(p.is_contiguous(&f.mesh));
    }

    #[test]
    fn quarantined_tiles_are_avoided() {
        let (mut f, lib) = setup();
        assert!(f.quarantine(0));
        assert!(f.quarantine(4));
        let p = DynamicPlacer
            .place(&f, &lib, &[OperatorKind::Mul, OperatorKind::AccSum])
            .unwrap();
        for a in &p.assignments {
            assert!(![0, 4].contains(&a.tile), "landed on quarantined tile: {a:?}");
        }
        // quarantining both large tiles starves large-region stages
        assert!(f.quarantine(3));
        assert!(f.quarantine(7));
        assert!(!DynamicPlacer::feasible(&f, &[RegionClass::Large]));
        assert!(DynamicPlacer.place(&f, &lib, &[OperatorKind::Sqrt]).is_err());
    }

    #[test]
    fn empty_pipeline_rejected() {
        let (f, lib) = setup();
        assert!(DynamicPlacer.place(&f, &lib, &[]).is_err());
    }

    /// `feasible` agrees with `place_with_needs` — success and failure.
    #[test]
    fn feasibility_matches_placement_outcome() {
        let (mut f, lib) = setup();
        let small = vec![RegionClass::Small; 2];
        let larges = vec![RegionClass::Large; 3];
        assert!(DynamicPlacer::feasible(&f, &small));
        assert!(!DynamicPlacer::feasible(&f, &larges), "only 2 large tiles exist");
        assert!(!DynamicPlacer::feasible(&f, &[]));
        // occupy all but one tile: a 2-stage pipeline no longer fits
        let bs = lib.get(OperatorKind::Add, RegionClass::Small).unwrap().clone();
        let bl = lib.get(OperatorKind::Add, RegionClass::Large).unwrap().clone();
        for t in 0..8 {
            let b = if f.cfg.is_large_tile(t) { &bl } else { &bs };
            f.load_bitstream(t, b).unwrap();
        }
        assert!(DynamicPlacer::feasible(&f, &small[..1]));
        assert!(!DynamicPlacer::feasible(&f, &small));
        assert_eq!(
            DynamicPlacer::feasible(&f, &small),
            DynamicPlacer
                .place_with_needs(&f, &[OperatorKind::Add, OperatorKind::Add], &small)
                .is_ok()
        );
    }
}
