//! Operator placement onto PR tiles.
//!
//! [`dynamic`] is the paper's contribution: because any bitstream can be
//! downloaded into any (class-compatible) tile at run time, the placer can
//! always choose **contiguous** tiles, keeping pipelines fused and
//! pass-through penalties at zero. [`static_`] models the original/static
//! overlay where operator positions are frozen at synthesis time — the
//! three Fig. 2 scheduling scenarios differ precisely in how many
//! pass-through tiles separate producer from consumer. [`frag`] measures
//! the internal fragmentation of a placement (the T-FRAG study), and
//! [`compact`] plans the migrations that undo it online.

pub mod compact;
pub mod dynamic;
pub mod frag;
pub mod static_;

pub use compact::CompactionPlan;
pub use dynamic::DynamicPlacer;
pub use static_::{StaticScenario, StaticPlacer};

use crate::bitstream::{OperatorKind, RegionClass};

/// One operator assigned to one tile.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Assignment {
    pub op: OperatorKind,
    pub tile: usize,
    pub class: RegionClass,
    /// Fused tail operator resident in the same tile (fusion pass): the
    /// tile computes `tail(op(..))` element-wise. `None` for plain stages.
    pub tail: Option<OperatorKind>,
}

/// A complete placement: assignments in dataflow (stage) order.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Placement {
    pub assignments: Vec<Assignment>,
}

impl Placement {
    /// Tile of stage `i`.
    pub fn tile_of(&self, stage: usize) -> Option<usize> {
        self.assignments.get(stage).map(|a| a.tile)
    }

    /// Max pass-through distance between consecutive stages (0 = fully
    /// contiguous, the dynamic overlay's invariant).
    pub fn max_stage_gap(&self, mesh: &crate::overlay::Mesh) -> usize {
        self.assignments
            .windows(2)
            .map(|w| mesh.manhattan(w[0].tile, w[1].tile).saturating_sub(1))
            .max()
            .unwrap_or(0)
    }

    /// Are all consecutive stages mesh-adjacent?
    pub fn is_contiguous(&self, mesh: &crate::overlay::Mesh) -> bool {
        self.max_stage_gap(mesh) == 0
    }

    /// No two stages share a tile.
    pub fn is_injective(&self) -> bool {
        let mut seen = std::collections::HashSet::new();
        self.assignments.iter().all(|a| seen.insert(a.tile))
    }
}
