//! Static-overlay placement: the Fig. 2 scheduling scenarios.
//!
//! In the original (static) overlay, operator positions are fixed when the
//! overlay is synthesized; the scheduler can only choose *which* fixed
//! instance to use. Figure 2 maps VMUL&Reduce onto a 3×3 static overlay in
//! three scenarios that differ in the number of pass-through tiles between
//! the multiplier and the adder:
//!
//! * **S1** — producer and consumer adjacent (0 pass-through): the lucky
//!   schedule, equal in dataflow to the dynamic overlay's placement;
//! * **S2** — one pass-through tile between them;
//! * **S3** — two pass-through tiles (opposite corners of the mesh region).
//!
//! The static overlay also pays store-and-forward forwarding at each
//! pass-through tile (only border tiles had stream BRAMs in the original
//! design), which is what makes Fig. 3's static series degrade with hop
//! count.

use crate::bitstream::OperatorKind;
use crate::error::{Error, Result};
use crate::overlay::Mesh;

use super::{Assignment, Placement};
use crate::bitstream::RegionClass;

/// The three Fig. 2 scheduling scenarios.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StaticScenario {
    /// Adjacent producer/consumer — 0 pass-through tiles.
    S1,
    /// 1 pass-through tile.
    S2,
    /// 2 pass-through tiles.
    S3,
}

impl StaticScenario {
    pub const ALL: [StaticScenario; 3] =
        [StaticScenario::S1, StaticScenario::S2, StaticScenario::S3];

    /// Pass-through tiles between producer and consumer in this scenario.
    pub fn pass_throughs(self) -> usize {
        match self {
            StaticScenario::S1 => 0,
            StaticScenario::S2 => 1,
            StaticScenario::S3 => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            StaticScenario::S1 => "static-s1",
            StaticScenario::S2 => "static-s2",
            StaticScenario::S3 => "static-s3",
        }
    }
}

/// Placer for the static overlay: positions are frozen; the scenario picks
/// which frozen instances serve a 2-stage producer→consumer pattern.
#[derive(Debug, Clone)]
pub struct StaticPlacer {
    pub scenario: StaticScenario,
}

impl StaticPlacer {
    pub fn new(scenario: StaticScenario) -> StaticPlacer {
        StaticPlacer { scenario }
    }

    /// Fixed operator positions for a producer/consumer pair on a 3×3 (or
    /// larger) mesh, reproducing Fig. 2's organization:
    ///
    /// * S1: tiles (0, 1) — adjacent;
    /// * S2: tiles (0, 2) — tile 1 passes through;
    /// * S3: tiles (0, 6) on the snake — tiles 1, 2 (S-corner) pass through
    ///   via the east edge, i.e. two pass-through tiles on the route.
    pub fn place_pair(
        &self,
        mesh: &Mesh,
        producer: OperatorKind,
        consumer: OperatorKind,
    ) -> Result<Placement> {
        if mesh.rows < 3 || mesh.cols < 3 {
            return Err(Error::Placement(
                "static scenarios are defined on ≥3×3 meshes".into(),
            ));
        }
        let (p, c) = match self.scenario {
            StaticScenario::S1 => (mesh.index(0, 0), mesh.index(0, 1)),
            StaticScenario::S2 => (mesh.index(0, 0), mesh.index(0, 2)),
            StaticScenario::S3 => (mesh.index(0, 0), mesh.index(1, 2)),
        };
        Ok(Placement {
            assignments: vec![
                Assignment { op: producer, tile: p, class: RegionClass::Small, tail: None },
                Assignment { op: consumer, tile: c, class: RegionClass::Small, tail: None },
            ],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mesh() -> Mesh {
        Mesh::new(3, 3)
    }

    #[test]
    fn scenario_pass_through_counts() {
        assert_eq!(StaticScenario::S1.pass_throughs(), 0);
        assert_eq!(StaticScenario::S2.pass_throughs(), 1);
        assert_eq!(StaticScenario::S3.pass_throughs(), 2);
    }

    #[test]
    fn placements_realize_declared_pass_throughs() {
        for s in StaticScenario::ALL {
            let p = StaticPlacer::new(s)
                .place_pair(&mesh(), OperatorKind::Mul, OperatorKind::AccSum)
                .unwrap();
            let gap = mesh().manhattan(p.assignments[0].tile, p.assignments[1].tile) - 1;
            assert_eq!(gap, s.pass_throughs(), "{s:?}");
        }
    }

    #[test]
    fn s1_matches_dynamic_contiguity() {
        let p = StaticPlacer::new(StaticScenario::S1)
            .place_pair(&mesh(), OperatorKind::Mul, OperatorKind::AccSum)
            .unwrap();
        assert!(p.is_contiguous(&mesh()));
    }

    #[test]
    fn small_mesh_rejected() {
        let m = Mesh::new(2, 2);
        assert!(StaticPlacer::new(StaticScenario::S1)
            .place_pair(&m, OperatorKind::Mul, OperatorKind::AccSum)
            .is_err());
    }
}
