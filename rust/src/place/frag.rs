//! Internal-fragmentation accounting (the T-FRAG study).
//!
//! The paper: *"We are using this configuration to study how such
//! non-uniform organizations can reduce the internal fragmentation within
//! the PR regions versus flexibility of mapping and performance."* Given a
//! placement, these metrics quantify how much of each PR region's resource
//! budget its resident operator leaves idle, and compare sizing policies.

use crate::bitstream::{Footprint, RegionClass};

use super::{Assignment, Placement};

/// Resources an assignment actually consumes in its tile: the head
/// operator's footprint plus the fused tail's, when one shares the region.
/// Head-only accounting overstates fused tiles' slack (and can claim a
/// genuinely Large-requiring fused pair "would have fit Small"). Shared
/// with the compaction planner so "would fit Small" means the same thing
/// in the report and in the migration decision.
pub fn assignment_footprint(a: &Assignment) -> Footprint {
    let head = Footprint::for_operator(a.op);
    match a.tail {
        Some(tail) => head.plus(&Footprint::for_operator(tail)),
        None => head,
    }
}

/// Fragmentation summary of one placement.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FragReport {
    /// Mean fraction of region budget left unused, over placed tiles.
    pub mean_internal: f64,
    /// Worst single-tile fragmentation.
    pub worst_internal: f64,
    /// Placed tiles whose operator would have fit a Small region but
    /// occupies a Large one (flexibility cost of non-uniform sizing).
    pub oversized_tiles: usize,
    /// Number of placed tiles.
    pub tiles: usize,
}

/// Compute fragmentation of `placement` under the paper's class budgets.
pub fn fragmentation(placement: &Placement) -> FragReport {
    let mut report = FragReport::default();
    let mut total = 0.0;
    for a in &placement.assignments {
        let fp = assignment_footprint(a);
        let budget = a.class.budget();
        let f = fp.fragmentation_in(&budget);
        total += f;
        report.worst_internal = report.worst_internal.max(f);
        if a.class == RegionClass::Large && fp.fits(&RegionClass::Small.budget()) {
            report.oversized_tiles += 1;
        }
        report.tiles += 1;
    }
    if report.tiles > 0 {
        report.mean_internal = total / report.tiles as f64;
    }
    report
}

/// Compare a placement's fragmentation under the paper's **non-uniform**
/// sizing against a hypothetical **uniform all-large** fabric (the naïve
/// alternative the paper argues against): returns `(non_uniform, uniform)`.
pub fn vs_uniform_large(placement: &Placement) -> (f64, f64) {
    let non_uniform = fragmentation(placement).mean_internal;
    let uniform: f64 = if placement.assignments.is_empty() {
        0.0
    } else {
        placement
            .assignments
            .iter()
            .map(|a| assignment_footprint(a).fragmentation_in(&RegionClass::Large.budget()))
            .sum::<f64>()
            / placement.assignments.len() as f64
    };
    (non_uniform, uniform)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitstream::OperatorKind;
    use crate::place::Assignment;

    fn place(ops: &[(OperatorKind, RegionClass)]) -> Placement {
        Placement {
            assignments: ops
                .iter()
                .enumerate()
                .map(|(i, &(op, class))| Assignment { op, tile: i, class, tail: None })
                .collect(),
        }
    }

    #[test]
    fn empty_placement_zero_frag() {
        let r = fragmentation(&Placement::default());
        assert_eq!(r.tiles, 0);
        assert_eq!(r.mean_internal, 0.0);
    }

    #[test]
    fn small_ops_in_small_regions_fragment_less_than_in_large() {
        let tight = place(&[(OperatorKind::Mul, RegionClass::Small)]);
        let loose = place(&[(OperatorKind::Mul, RegionClass::Large)]);
        assert!(
            fragmentation(&tight).mean_internal < fragmentation(&loose).mean_internal
        );
        assert_eq!(fragmentation(&loose).oversized_tiles, 1);
        assert_eq!(fragmentation(&tight).oversized_tiles, 0);
    }

    #[test]
    fn non_uniform_beats_uniform_for_mixed_pipelines() {
        // the paper's configuration argument: mixed pipelines fragment less
        // when small ops live in small regions.
        let p = place(&[
            (OperatorKind::Mul, RegionClass::Small),
            (OperatorKind::AccSum, RegionClass::Small),
            (OperatorKind::Sqrt, RegionClass::Large),
        ]);
        let (non_uniform, uniform) = vs_uniform_large(&p);
        assert!(non_uniform < uniform, "{non_uniform} !< {uniform}");
    }

    /// Regression: fused tiles must fold the tail footprint. Head-only
    /// accounting claimed a fused mul+acc_sum Large tile was "oversized"
    /// (mul alone fits Small; the fused pair does not) and overstated its
    /// slack relative to the unfused two-tile placement.
    #[test]
    fn fused_tail_counts_toward_tile_footprint() {
        let fused = Placement {
            assignments: vec![Assignment {
                op: OperatorKind::Mul,
                tile: 3,
                class: RegionClass::Large,
                tail: Some(OperatorKind::AccSum),
            }],
        };
        let unfused_head_only = place(&[(OperatorKind::Mul, RegionClass::Large)]);
        let fused_r = fragmentation(&fused);
        let head_r = fragmentation(&unfused_head_only);
        // mul+acc_sum together overflow the Small budget, so the Large tile
        // is required, not oversized...
        assert_eq!(fused_r.oversized_tiles, 0, "fused pair needs the Large region");
        assert_eq!(head_r.oversized_tiles, 1, "mul alone would have fit Small");
        // ...and the fused tile wastes strictly less of the region than the
        // head alone would (the tail consumes real resources).
        assert!(
            fused_r.mean_internal < head_r.mean_internal,
            "fused {} !< head-only {}",
            fused_r.mean_internal,
            head_r.mean_internal
        );
        // the uniform-large comparison folds tails the same way
        let (nu, _) = vs_uniform_large(&fused);
        assert!((nu - fused_r.mean_internal).abs() < 1e-12);
    }

    #[test]
    fn transcendental_in_large_region_is_snug() {
        let p = place(&[(OperatorKind::Log, RegionClass::Large)]);
        let r = fragmentation(&p);
        assert!(r.mean_internal < 0.15, "log should nearly fill a large region");
    }
}
