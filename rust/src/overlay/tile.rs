//! Tile and fabric state.
//!
//! A [`Tile`] is the paper's unit of composition: one PR-region slot (which
//! class depends on its mesh position), a small scalar register file, two
//! data BRAMs, an accumulator, the interconnect switch, and per-direction
//! inboxes modelling streams parked on input ports. The instruction BRAM is
//! held by the controller (it sequences all tiles from one image).

use super::interconnect::SwitchState;
use super::mesh::Mesh;
use crate::bitstream::{Bitstream, OperatorKind, RegionClass};
use crate::config::OverlayConfig;
use crate::error::{Error, Result};
use crate::isa::Dir;

/// A stream parked on a tile's input port, tagged with the operand slot the
/// producer addressed it to (VecRun's imm bits — the hardware equivalent is
/// the stream header the interconnect carries).
#[derive(Debug, Clone, PartialEq)]
pub struct ParkedStream {
    pub slot: u8,
    pub from: Dir,
    pub data: Vec<f32>,
}

/// One overlay tile.
#[derive(Debug, Clone)]
pub struct Tile {
    /// Which PR-region class this position is provisioned as.
    pub class: RegionClass,
    /// The operator currently resident in the PR region, if any.
    pub resident: Option<OperatorKind>,
    /// Fused tail operator sharing the PR region (set only when a fused
    /// bitstream was downloaded; the tile then computes `tail(resident(..))`
    /// element-wise).
    pub resident_tail: Option<OperatorKind>,
    /// Scalar register file (controller-visible; f64 so it can carry both
    /// loop counters and operand scalars like filter thresholds).
    pub regs: Vec<f64>,
    /// Two data BRAMs of `bram_words` f32 each.
    pub bram: [Vec<f32>; 2],
    /// Reduce accumulator (the AccSum feedback register).
    pub acc: f32,
    /// Interconnect switch.
    pub switch: SwitchState,
    /// Streams parked on input ports (at most one per port).
    pub inbox: Vec<ParkedStream>,
    /// True once the PR region has suffered a permanent fault. A
    /// quarantined tile never hosts an operator again: the placer routes
    /// around it and `load_bitstream` rejects it. Survives `reset_full`
    /// — a power cycle does not heal dead silicon.
    pub quarantined: bool,
}

impl Tile {
    fn new(class: RegionClass, cfg: &OverlayConfig) -> Tile {
        Tile {
            class,
            resident: None,
            resident_tail: None,
            regs: vec![0.0; cfg.regs_per_tile],
            bram: [Vec::new(), Vec::new()],
            acc: 0.0,
            switch: SwitchState::default(),
            inbox: Vec::new(),
            quarantined: false,
        }
    }

    /// Take the stream parked on port `d`, if any.
    pub fn take_inbox(&mut self, d: Dir) -> Option<Vec<f32>> {
        let pos = self.inbox.iter().position(|p| p.from == d)?;
        Some(self.inbox.remove(pos).data)
    }

    /// Take the parked stream addressed to operand slot `slot`, if any.
    pub fn take_slot(&mut self, slot: u8) -> Option<ParkedStream> {
        let pos = self.inbox.iter().position(|p| p.slot == slot)?;
        Some(self.inbox.remove(pos))
    }

    /// Park a stream on port `d` (replacing any previous one on that port).
    pub fn park(&mut self, d: Dir, slot: u8, data: Vec<f32>) {
        self.inbox.retain(|p| p.from != d);
        self.inbox.push(ParkedStream { slot, from: d, data });
    }

    /// Parked streams sorted by operand slot (the VecRun gather order).
    pub fn drain_inbox_by_slot(&mut self) -> Vec<ParkedStream> {
        let mut all = std::mem::take(&mut self.inbox);
        all.sort_by_key(|p| p.slot);
        all
    }

    /// Clear all volatile state (registers, BRAMs, streams, accumulator)
    /// but keep the resident operator and switch config.
    pub fn reset_data(&mut self) {
        for r in &mut self.regs {
            *r = 0.0;
        }
        self.bram = [Vec::new(), Vec::new()];
        self.acc = 0.0;
        self.inbox.clear();
    }
}

/// The whole fabric: mesh geometry + tile state + config.
#[derive(Debug, Clone)]
pub struct Fabric {
    /// Process-unique fabric identity, minted at construction. Placement
    /// plans are specialized *per fabric* (a placement is only valid
    /// against the occupancy it was compiled for), so the plan cache keys
    /// on this id. A `clone()` deliberately keeps the id: it duplicates
    /// this fabric's state, occupancy included.
    pub id: u64,
    pub mesh: Mesh,
    pub cfg: OverlayConfig,
    pub tiles: Vec<Tile>,
}

/// Mints [`Fabric::id`]s.
static NEXT_FABRIC_ID: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

impl Fabric {
    /// Build a powered-on, empty fabric from a config.
    pub fn new(cfg: OverlayConfig) -> Result<Fabric> {
        cfg.validate()?;
        let mesh = Mesh::new(cfg.rows, cfg.cols);
        let tiles = (0..mesh.tiles())
            .map(|i| {
                let class = if cfg.is_large_tile(i) {
                    RegionClass::Large
                } else {
                    RegionClass::Small
                };
                Tile::new(class, &cfg)
            })
            .collect();
        let id = NEXT_FABRIC_ID.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(Fabric { id, mesh, cfg, tiles })
    }

    /// Load a bitstream into tile `idx`'s PR region.
    ///
    /// Fails if the bitstream was synthesized for a different region class —
    /// partial bitstreams are region-specific in the PR flow.
    pub fn load_bitstream(&mut self, idx: usize, bs: &Bitstream) -> Result<()> {
        let tile = self
            .tiles
            .get_mut(idx)
            .ok_or_else(|| Error::Reconfig(format!("tile {idx} out of range")))?;
        if tile.quarantined {
            return Err(Error::TileFault { tile: idx, permanent: true });
        }
        if bs.class != tile.class {
            return Err(Error::Reconfig(format!(
                "bitstream for {:?} region cannot load into {:?} tile {idx}",
                bs.class, tile.class
            )));
        }
        if !bs.footprint.fits(&tile.class.budget()) {
            return Err(Error::Reconfig(format!(
                "operator {} overflows {:?} region budget",
                bs.op.name(),
                tile.class
            )));
        }
        tile.resident = Some(bs.op);
        tile.resident_tail = bs.tail;
        tile.acc = 0.0;
        Ok(())
    }

    /// Clear a tile's PR region (resident operator removed).
    pub fn clear_region(&mut self, idx: usize) -> Result<()> {
        let tile = self
            .tiles
            .get_mut(idx)
            .ok_or_else(|| Error::Reconfig(format!("tile {idx} out of range")))?;
        tile.resident = None;
        tile.resident_tail = None;
        Ok(())
    }

    /// Reset all volatile data state (between requests; residents persist —
    /// that is the point of the residency cache).
    pub fn reset_data(&mut self) {
        for t in &mut self.tiles {
            t.reset_data();
        }
    }

    /// Clear every tile's interconnect switch (between accelerators: the
    /// next program reconfigures routing from scratch in its prologue).
    pub fn reset_switches(&mut self) {
        for t in &mut self.tiles {
            t.switch.clear();
        }
    }

    /// Full reset including switches and residents (power cycle).
    pub fn reset_full(&mut self) {
        for t in &mut self.tiles {
            t.reset_data();
            t.switch.clear();
            t.resident = None;
            t.resident_tail = None;
        }
    }

    /// Is tile `idx` empty and healthy (placeable)? Quarantined regions are
    /// never free — they can no longer host anything. Out-of-range indices
    /// are not free.
    pub fn tile_is_free(&self, idx: usize) -> bool {
        self.tiles
            .get(idx)
            .map_or(false, |t| t.resident.is_none() && !t.quarantined)
    }

    /// Indices of currently-empty, healthy tiles, in index order, without
    /// allocating — the predictor polls this every idle tick.
    pub fn free_tiles_iter(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.tiles.len()).filter(move |&i| self.tile_is_free(i))
    }

    /// Number of currently-empty, healthy tiles (allocation-free).
    pub fn free_tile_count(&self) -> usize {
        self.free_tiles_iter().count()
    }

    /// Indices of currently-empty, healthy tiles as a `Vec` (callers that
    /// need random access; hot paths use [`Fabric::free_tiles_iter`]).
    pub fn free_tiles(&self) -> Vec<usize> {
        self.free_tiles_iter().collect()
    }

    /// Quarantine tile `idx` after a permanent region fault: any resident
    /// is evicted (its output can no longer be trusted) and the tile is
    /// withdrawn from placement forever. Returns `true` when the tile was
    /// newly quarantined, `false` when it already was (or is out of
    /// range), so callers can count `tiles_quarantined` without
    /// double-billing repeated faults on the same region.
    pub fn quarantine(&mut self, idx: usize) -> bool {
        match self.tiles.get_mut(idx) {
            Some(t) if !t.quarantined => {
                t.quarantined = true;
                t.resident = None;
                t.resident_tail = None;
                true
            }
            _ => false,
        }
    }

    /// Number of quarantined tiles (capacity permanently lost).
    pub fn quarantined_tiles(&self) -> usize {
        self.tiles.iter().filter(|t| t.quarantined).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitstream::BitstreamLibrary;

    fn fabric() -> Fabric {
        Fabric::new(OverlayConfig::default()).unwrap()
    }

    #[test]
    fn new_fabric_has_paper_class_mix() {
        let f = fabric();
        let large = f.tiles.iter().filter(|t| t.class == RegionClass::Large).count();
        assert_eq!(large, 2); // ≈1/4 of 9
        assert_eq!(f.tiles.len(), 9);
    }

    #[test]
    fn load_bitstream_into_matching_class() {
        let mut f = fabric();
        let lib = BitstreamLibrary::standard(&f.cfg);
        let bs = lib.get(OperatorKind::Mul, RegionClass::Small).unwrap().clone();
        f.load_bitstream(0, &bs).unwrap();
        assert_eq!(f.tiles[0].resident, Some(OperatorKind::Mul));
    }

    #[test]
    fn class_mismatch_rejected() {
        let mut f = fabric();
        let lib = BitstreamLibrary::standard(&f.cfg);
        let large_bs = lib.get(OperatorKind::Sin, RegionClass::Large).unwrap().clone();
        // tile 0 is small; sin's bitstream targets large regions.
        assert!(f.load_bitstream(0, &large_bs).is_err());
        // tile 3 is large.
        f.load_bitstream(3, &large_bs).unwrap();
        assert_eq!(f.tiles[3].resident, Some(OperatorKind::Sin));
    }

    #[test]
    fn out_of_range_tile_rejected() {
        let mut f = fabric();
        let lib = BitstreamLibrary::standard(&f.cfg);
        let bs = lib.get(OperatorKind::Add, RegionClass::Small).unwrap().clone();
        assert!(f.load_bitstream(99, &bs).is_err());
    }

    #[test]
    fn reset_data_keeps_residents() {
        let mut f = fabric();
        let lib = BitstreamLibrary::standard(&f.cfg);
        let bs = lib.get(OperatorKind::Add, RegionClass::Small).unwrap().clone();
        f.load_bitstream(1, &bs).unwrap();
        f.tiles[1].regs[0] = 42.0;
        f.tiles[1].bram[0] = vec![1.0; 8];
        f.reset_data();
        assert_eq!(f.tiles[1].resident, Some(OperatorKind::Add));
        assert_eq!(f.tiles[1].regs[0], 0.0);
        assert!(f.tiles[1].bram[0].is_empty());
    }

    #[test]
    fn fabric_ids_are_distinct() {
        let a = fabric();
        let b = fabric();
        assert_ne!(a.id, b.id, "each constructed fabric gets its own identity");
        // a clone is the same fabric (same occupancy), so it keeps the id
        assert_eq!(a.clone().id, a.id);
    }

    #[test]
    fn free_tiles_tracks_residency() {
        let mut f = fabric();
        assert_eq!(f.free_tiles().len(), 9);
        let lib = BitstreamLibrary::standard(&f.cfg);
        let bs = lib.get(OperatorKind::Add, RegionClass::Small).unwrap().clone();
        f.load_bitstream(2, &bs).unwrap();
        assert_eq!(f.free_tiles().len(), 8);
        f.clear_region(2).unwrap();
        assert_eq!(f.free_tiles().len(), 9);
    }

    #[test]
    fn free_tile_accessors_agree() {
        let mut f = fabric();
        let lib = BitstreamLibrary::standard(&f.cfg);
        let bs = lib.get(OperatorKind::Add, RegionClass::Small).unwrap().clone();
        f.load_bitstream(2, &bs).unwrap();
        assert!(f.quarantine(5));
        assert_eq!(f.free_tile_count(), 7);
        assert_eq!(f.free_tiles_iter().collect::<Vec<_>>(), f.free_tiles());
        assert!(!f.tile_is_free(2), "resident tile is not free");
        assert!(!f.tile_is_free(5), "quarantined tile is not free");
        assert!(!f.tile_is_free(99), "out of range is not free");
        assert!(f.tile_is_free(0));
    }

    #[test]
    fn quarantine_evicts_and_withdraws_the_tile() {
        let mut f = fabric();
        let lib = BitstreamLibrary::standard(&f.cfg);
        let bs = lib.get(OperatorKind::Add, RegionClass::Small).unwrap().clone();
        f.load_bitstream(2, &bs).unwrap();
        assert!(f.quarantine(2), "first quarantine is new");
        assert!(!f.quarantine(2), "repeat quarantine is not counted again");
        assert!(!f.quarantine(99), "out of range is a no-op");
        assert_eq!(f.quarantined_tiles(), 1);
        assert_eq!(f.tiles[2].resident, None, "resident evicted");
        assert!(!f.free_tiles().contains(&2), "quarantined tile is never free");
        assert_eq!(f.free_tiles().len(), 8);
        match f.load_bitstream(2, &bs) {
            Err(Error::TileFault { tile: 2, permanent: true }) => {}
            other => panic!("expected permanent tile fault, got {other:?}"),
        }
    }

    #[test]
    fn quarantine_survives_full_reset() {
        let mut f = fabric();
        assert!(f.quarantine(5));
        f.reset_full();
        assert_eq!(f.quarantined_tiles(), 1, "power cycling does not heal dead silicon");
        assert!(!f.free_tiles().contains(&5));
    }

    #[test]
    fn inbox_take_and_park() {
        let mut f = fabric();
        f.tiles[4].park(Dir::W, 0, vec![1.0, 2.0]);
        assert_eq!(f.tiles[4].take_inbox(Dir::W), Some(vec![1.0, 2.0]));
        assert_eq!(f.tiles[4].take_inbox(Dir::W), None);
    }

    #[test]
    fn park_replaces_same_port_and_drain_sorts_by_slot() {
        let mut f = fabric();
        f.tiles[4].park(Dir::W, 1, vec![1.0]);
        f.tiles[4].park(Dir::W, 2, vec![2.0]); // replaces slot-1 stream on W
        f.tiles[4].park(Dir::N, 0, vec![3.0]);
        let drained = f.tiles[4].drain_inbox_by_slot();
        assert_eq!(drained.len(), 2);
        assert_eq!(drained[0].slot, 0);
        assert_eq!(drained[0].data, vec![3.0]);
        assert_eq!(drained[1].slot, 2);
        assert!(f.tiles[4].inbox.is_empty());
    }
}
