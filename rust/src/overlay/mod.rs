//! The overlay fabric simulator.
//!
//! A cycle-approximate model of the paper's dynamic overlay: a 2-D mesh of
//! tiles ([`mesh`]), each with a PR-region slot, a register file, two data
//! BRAMs and an instruction BRAM ([`tile`]), joined by a programmable
//! N-E-S-W interconnect that can *consume* or *bypass* streams
//! ([`interconnect`]), all sequenced by a centralized controller that
//! interprets the 42-instruction ISA ([`controller`]).
//!
//! The simulator executes controller programs **semantically** (real f32
//! data moves through BRAMs and streams — this is what the integration
//! tests cross-check against the PJRT artifacts and the scalar reference)
//! and **temporally** (every instruction, DMA beat, stream element, stage
//! fill and pass-through hop is priced in fabric cycles).

pub mod controller;
pub mod interconnect;
pub mod mesh;
pub mod tile;

pub use controller::{Controller, ExecStats, ExternalIo};
pub use interconnect::SwitchState;
pub use mesh::Mesh;
pub use tile::{Fabric, Tile};
