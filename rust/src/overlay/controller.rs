//! The overlay controller: the run-time interpreter of the 42-instruction
//! ISA.
//!
//! The controller executes a validated [`Program`] against a [`Fabric`],
//! moving real `f32` data (semantic plane) while accounting every fabric
//! cycle (temporal plane):
//!
//! * control instructions cost 1 cycle (taken branches 2);
//! * DMA moves cost `ceil(words × 4 B / DMA-bytes-per-cycle)` cycles;
//! * vector operations cost `stage latency + len·II` cycles;
//! * stream deliveries record both *hop fills* (pipelined forwarding: 1
//!   cycle per pass-through tile) and *hop elements* (store-and-forward
//!   re-staging: `len` cycles per hop) so the two overlay generations can
//!   be priced from one execution (see `timing::overlay`).
//!
//! Chunk-at-a-time streaming: a `vec.run` processes its whole chunk and
//! parks the result on the consumer's port. This is steady-state-equivalent
//! to element streaming for feed-forward pipelines, which is exactly the
//! class of dataflow the JIT emits.

use super::tile::Fabric;
use crate::bitstream::OperatorKind;
use crate::error::{Error, Result};
use crate::isa::{Instr, Opcode, Program};

/// External stream channels (DDR-side buffers the DMA engine touches).
///
/// Input channels are *borrowed* — the DMA engine only reads DDR, so the
/// request path never copies operand vectors into the IO block (perf pass
/// §Perf-2: saves one full operand copy per request).
#[derive(Debug, Clone, Default)]
pub struct ExternalIo<'a> {
    /// `dma.in` sources, by channel id.
    pub inputs: Vec<&'a [f32]>,
    /// `dma.out` destinations, by channel id (filled by execution).
    pub outputs: Vec<Vec<f32>>,
}

impl<'a> ExternalIo<'a> {
    /// Borrow each vector in `inputs` as one input channel.
    pub fn with_inputs(inputs: &'a [Vec<f32>]) -> ExternalIo<'a> {
        ExternalIo {
            inputs: inputs.iter().map(|v| v.as_slice()).collect(),
            outputs: Vec::new(),
        }
    }

    /// Build from explicit channel slices.
    pub fn from_slices(inputs: Vec<&'a [f32]>) -> ExternalIo<'a> {
        ExternalIo { inputs, outputs: Vec::new() }
    }
}

/// Cycle/event accounting of one program execution.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ExecStats {
    /// Instructions retired.
    pub instrs: u64,
    /// Cycles spent on control (non-vector, non-DMA) instructions.
    pub control_cycles: u64,
    /// Cycles spent in vector operations (fill + streaming).
    pub vector_cycles: u64,
    /// Cycles spent in DMA transfers.
    pub dma_cycles: u64,
    /// Words moved by DMA.
    pub dma_words: u64,
    /// Elements that passed through any operator.
    pub elements: u64,
    /// Pass-through tiles traversed by deliveries (fills — pipelined cost).
    pub hop_fills: u64,
    /// Σ (hops × chunk length) — store-and-forward re-staging cost.
    pub hop_elements: u64,
    /// Taken branches.
    pub branches_taken: u64,
}

impl ExecStats {
    /// Total cycles under the **dynamic** (pipelined) overlay model:
    /// pass-through tiles only add fill cycles.
    pub fn cycles_pipelined(&self) -> u64 {
        self.control_cycles + self.vector_cycles + self.dma_cycles + self.hop_fills
    }

    /// Total cycles under the **static store-and-forward** model: every hop
    /// re-stages the whole chunk (the original overlay's non-contiguous
    /// penalty — Fig. 2/3).
    pub fn cycles_store_forward(&self) -> u64 {
        self.control_cycles + self.vector_cycles + self.dma_cycles + self.hop_elements
    }

    /// Seconds at a fabric clock.
    pub fn seconds(&self, fabric_hz: f64, pipelined: bool) -> f64 {
        let c = if pipelined { self.cycles_pipelined() } else { self.cycles_store_forward() };
        c as f64 / fabric_hz
    }
}

/// Controller flag register.
#[derive(Debug, Clone, Copy, Default)]
struct Flags {
    eq: bool,
    lt: bool,
}

/// The controller itself. Stateless between runs except for fuel limits.
#[derive(Debug, Clone)]
pub struct Controller {
    /// Instruction budget per run (infinite loops trap instead of hanging).
    pub max_instrs: u64,
}

impl Default for Controller {
    fn default() -> Self {
        Controller { max_instrs: 1_000_000 }
    }
}

impl Controller {
    /// Execute `program` on `fabric` with external channels `io`.
    pub fn run(
        &self,
        fabric: &mut Fabric,
        program: &Program,
        io: &mut ExternalIo<'_>,
    ) -> Result<ExecStats> {
        let mut stats = ExecStats::default();
        let mut flags = Flags::default();
        let mut pc: usize = 0;
        let instrs = program.instrs();

        let dma_cycles_per_word = {
            let c = &fabric.cfg.clocks;
            (4.0 * c.fabric_hz / c.dma_bytes_per_sec).max(f64::MIN_POSITIVE)
        };

        while pc < instrs.len() {
            if stats.instrs >= self.max_instrs {
                return Err(Error::Trap { pc, reason: "instruction budget exhausted".into() });
            }
            let i = instrs[pc];
            stats.instrs += 1;
            let mut next = pc + 1;

            match i.op {
                // ---- interconnect -------------------------------------------------
                op if op.port_dir().is_some() => {
                    let (is_in, d) = op.port_dir().unwrap();
                    let sw = &mut fabric.tiles[i.tile as usize].switch;
                    if is_in {
                        sw.set_in(d);
                    } else {
                        sw.out_port = Some(d);
                    }
                    stats.control_cycles += 1;
                }
                op if op.bypass_dirs().is_some() => {
                    let (from, to) = op.bypass_dirs().unwrap();
                    fabric.tiles[i.tile as usize].switch.set_bypass(from, to);
                    stats.control_cycles += 1;
                }
                Opcode::ConnectPr => {
                    fabric.tiles[i.tile as usize].switch.pr_connected = true;
                    stats.control_cycles += 1;
                }
                Opcode::DisconnectPr => {
                    fabric.tiles[i.tile as usize].switch.pr_connected = false;
                    stats.control_cycles += 1;
                }

                // ---- branching ----------------------------------------------------
                Opcode::Beq | Opcode::Bne | Opcode::Blt | Opcode::Bge | Opcode::Jmp => {
                    let take = match i.op {
                        Opcode::Beq => flags.eq,
                        Opcode::Bne => !flags.eq,
                        Opcode::Blt => flags.lt,
                        Opcode::Bge => !flags.lt,
                        _ => true,
                    };
                    stats.control_cycles += 1;
                    if take {
                        stats.control_cycles += 1; // pipeline bubble
                        stats.branches_taken += 1;
                        next = (pc as i64 + 1 + i.imm as i64) as usize;
                    }
                }
                Opcode::SpecSel => {
                    // Commit control-level speculation: keep the parked
                    // stream tagged slot `a` if flags.eq else slot `b`;
                    // retag the survivor to slot 0, drop the loser.
                    let tile = &mut fabric.tiles[i.tile as usize];
                    let (keep, drop_) = if flags.eq { (i.a, i.b) } else { (i.b, i.a) };
                    tile.take_slot(drop_);
                    if let Some(mut s) = tile.take_slot(keep) {
                        s.slot = 0;
                        let from = s.from;
                        tile.park(from, 0, s.data);
                    }
                    stats.control_cycles += 1;
                }

                // ---- vector operations --------------------------------------------
                Opcode::VecRun | Opcode::VecAcc => {
                    self.vec_op(fabric, &i, &mut stats)?;
                }

                // ---- memory & register --------------------------------------------
                Opcode::Ldi => {
                    fabric.tiles[i.tile as usize].regs[i.a as usize] = i.imm as f64;
                    stats.control_cycles += 1;
                }
                Opcode::Mov => {
                    let t = &mut fabric.tiles[i.tile as usize];
                    t.regs[i.a as usize] = t.regs[i.b as usize];
                    stats.control_cycles += 1;
                }
                Opcode::Ld => {
                    let t = &mut fabric.tiles[i.tile as usize];
                    let addr = t.regs[i.b as usize] as usize;
                    let bram = &t.bram[(i.imm & 1) as usize];
                    let v = *bram.get(addr).ok_or_else(|| Error::Trap {
                        pc,
                        reason: format!("ld: address {addr} beyond BRAM ({} words)", bram.len()),
                    })?;
                    t.regs[i.a as usize] = v as f64;
                    stats.control_cycles += 1;
                }
                Opcode::St => {
                    let words = fabric.cfg.bram_words();
                    let t = &mut fabric.tiles[i.tile as usize];
                    let addr = t.regs[i.b as usize] as usize;
                    if addr >= words {
                        return Err(Error::Trap {
                            pc,
                            reason: format!("st: address {addr} beyond BRAM capacity {words}"),
                        });
                    }
                    let bram = &mut t.bram[(i.imm & 1) as usize];
                    if bram.len() <= addr {
                        bram.resize(addr + 1, 0.0);
                    }
                    bram[addr] = t.regs[i.a as usize] as f32;
                    stats.control_cycles += 1;
                }
                Opcode::AddR | Opcode::SubR => {
                    let t = &mut fabric.tiles[i.tile as usize];
                    let b = t.regs[i.b as usize];
                    if i.op == Opcode::AddR {
                        t.regs[i.a as usize] += b;
                    } else {
                        t.regs[i.a as usize] -= b;
                    }
                    stats.control_cycles += 1;
                }
                Opcode::IncR | Opcode::DecR => {
                    let t = &mut fabric.tiles[i.tile as usize];
                    t.regs[i.a as usize] += if i.op == Opcode::IncR { 1.0 } else { -1.0 };
                    stats.control_cycles += 1;
                }
                Opcode::CmpR => {
                    let t = &fabric.tiles[i.tile as usize];
                    let (a, b) = (t.regs[i.a as usize], t.regs[i.b as usize]);
                    flags.eq = a == b;
                    flags.lt = a < b;
                    stats.control_cycles += 1;
                }
                Opcode::DmaIn => {
                    // len = R[a]; DDR word offset = R[b]; imm: bit0 = BRAM
                    // select, bits[15:1] = channel id.
                    let t = &fabric.tiles[i.tile as usize];
                    let len = t.regs[i.a as usize] as usize;
                    let off = t.regs[i.b as usize] as usize;
                    let chan = (i.imm >> 1) as usize;
                    let bram_sel = (i.imm & 1) as usize;
                    let src = io.inputs.get(chan).ok_or_else(|| Error::Trap {
                        pc,
                        reason: format!("dma.in: no input channel {chan}"),
                    })?;
                    if src.len() < off + len {
                        return Err(Error::Trap {
                            pc,
                            reason: format!(
                                "dma.in: channel {chan} holds {} < {off}+{len} words",
                                src.len()
                            ),
                        });
                    }
                    if len > fabric.cfg.bram_words() {
                        return Err(Error::Trap {
                            pc,
                            reason: format!(
                                "dma.in: {len} words exceed data BRAM capacity {}",
                                fabric.cfg.bram_words()
                            ),
                        });
                    }
                    {
                        // reuse the BRAM buffer's capacity (perf §Perf-3)
                        let src = &src[off..off + len];
                        let bram = &mut fabric.tiles[i.tile as usize].bram[bram_sel];
                        bram.clear();
                        bram.extend_from_slice(src);
                    }
                    stats.dma_words += len as u64;
                    stats.dma_cycles += (len as f64 * dma_cycles_per_word).ceil() as u64;
                    stats.control_cycles += 1; // descriptor issue
                }
                Opcode::DmaOut => {
                    // len = R[a]; DDR word offset = R[b]; imm as dma.in.
                    let t = &fabric.tiles[i.tile as usize];
                    let len = t.regs[i.a as usize] as usize;
                    let off = t.regs[i.b as usize] as usize;
                    let bram_sel = (i.imm & 1) as usize;
                    let chan = (i.imm >> 1) as usize;
                    let bram = &t.bram[bram_sel];
                    if bram.len() < len {
                        return Err(Error::Trap {
                            pc,
                            reason: format!(
                                "dma.out: BRAM{bram_sel} holds {} < {len} words",
                                bram.len()
                            ),
                        });
                    }
                    let data = bram[..len].to_vec();
                    if io.outputs.len() <= chan {
                        io.outputs.resize(chan + 1, Vec::new());
                    }
                    let out = &mut io.outputs[chan];
                    if out.len() < off + len {
                        out.resize(off + len, 0.0);
                    }
                    out[off..off + len].copy_from_slice(&data);
                    stats.dma_words += len as u64;
                    stats.dma_cycles += (len as f64 * dma_cycles_per_word).ceil() as u64;
                    stats.control_cycles += 1;
                }
                Opcode::Halt => break,
                other => {
                    return Err(Error::Trap {
                        pc,
                        reason: format!("unhandled opcode {other:?}"),
                    })
                }
            }
            pc = next;
        }
        Ok(stats)
    }

    /// Execute `vec.run` / `vec.acc` on one tile.
    fn vec_op(&self, fabric: &mut Fabric, i: &Instr, stats: &mut ExecStats) -> Result<()> {
        let idx = i.tile as usize;
        // a quarantined region must never compute: its output cannot be
        // trusted, so the fault surfaces before any element is touched
        if fabric.tiles[idx].quarantined {
            return Err(Error::TileFault { tile: idx, permanent: true });
        }
        let len = fabric.tiles[idx].regs[i.a as usize] as usize;
        let op = fabric.tiles[idx].resident.ok_or_else(|| Error::Trap {
            pc: 0,
            reason: format!("vec op on tile {idx} with no resident operator"),
        })?;
        // fused datapath: the tail operator applies to the head's output
        // inside the same tile (no extra stream, no extra hop).
        let tail = fabric.tiles[idx].resident_tail;

        // ---- gather operand streams: parked inboxes by slot, then BRAMs --
        let parked = fabric.tiles[idx].drain_inbox_by_slot();
        let mut operands: Vec<Vec<f32>> = parked.into_iter().map(|p| p.data).collect();
        // a fused vec.acc streams the *head*'s operands (e.g. mul+acc_sum
        // reads two vectors); a plain vec.acc folds one stream.
        let arity = if i.op == Opcode::VecAcc && tail.is_none() { 1 } else { op.arity() };
        // remember which operand came out of which BRAM so buffers can be
        // handed back afterwards, preserving their capacity across the
        // chunk loop (perf §Perf-3: no per-chunk reallocation).
        let mut bram_src: Vec<Option<usize>> = vec![None; operands.len()];
        let mut bram_i = 0;
        while operands.len() < arity && bram_i < 2 {
            let b = std::mem::take(&mut fabric.tiles[idx].bram[bram_i]);
            if !b.is_empty() {
                operands.push(b);
                bram_src.push(Some(bram_i));
            }
            bram_i += 1;
        }
        if operands.len() < arity {
            return Err(Error::Trap {
                pc: 0,
                reason: format!(
                    "tile {idx} op {} needs {arity} operand streams, found {}",
                    op.name(),
                    operands.len()
                ),
            });
        }
        operands.truncate(arity);

        // ---- broadcast scalars, validate lengths ---------------------------
        for o in operands.iter_mut() {
            if o.len() == 1 && len > 1 {
                o.resize(len, o[0]); // hardware: register-held scalar operand
            } else if o.len() < len {
                return Err(Error::Trap {
                    pc: 0,
                    reason: format!(
                        "tile {idx}: operand stream of {} < vector length {len}",
                        o.len()
                    ),
                });
            }
        }

        // ---- cycle accounting -------------------------------------------------
        stats.elements += len as u64;
        stats.vector_cycles += op.latency_cycles() + (len as u64) * op.initiation_interval();
        if let Some(t) = tail {
            // the fused tail deepens the pipeline by its own fill latency;
            // streaming still overlaps (II stays 1), so no extra len·II.
            stats.vector_cycles += t.latency_cycles();
        }

        let mut state = fabric.tiles[idx].acc;

        // ---- reduce: vec.acc folds without materializing a result vector
        // (perf §Perf-1) and leaves the scalar in R[b] and BRAM[imm&1][0] ----
        if i.op == Opcode::VecAcc {
            let mut fold = 0.0f32;
            if let Some(t) = tail {
                // fused map∘reduce: the head computes each element, the
                // stateful tail (acc_sum) folds it — sequentially, the same
                // association as the unfused two-tile path (bit-identical).
                let mut head_state = 0.0f32;
                for k in 0..len {
                    let a = operands[0][k];
                    let b = operands.get(1).map_or(0.0, |o| o[k]);
                    let hv = op.apply(a, b, &mut head_state);
                    fold = t.apply(hv, 0.0, &mut state);
                }
            } else if op == OperatorKind::AccSum {
                // hot reduce path: plain sequential accumulate (same
                // association as the generic path — bit-identical)
                for &v in &operands[0][..len] {
                    state += v;
                }
            } else {
                for k in 0..len {
                    let a = operands[0][k];
                    let b = operands.get(1).map_or(0.0, |o| o[k]);
                    fold += op.apply(a, b, &mut state);
                }
            }
            let scalar = if tail.map_or(op.is_stateful(), OperatorKind::is_stateful) {
                // stateful ops (AccSum, fused or not) carry the fold in
                // their feedback reg
                state
            } else {
                // stateless op output folded by the adder feedback
                fold
            };
            fabric.tiles[idx].acc = state;
            // hand consumed BRAM buffers back (capacity reuse)
            for (o, src) in operands.iter_mut().zip(&bram_src) {
                if let Some(j) = src {
                    o.clear();
                    fabric.tiles[idx].bram[*j] = std::mem::take(o);
                }
            }
            let t = &mut fabric.tiles[idx];
            t.regs[i.b as usize] = scalar as f64;
            let out = &mut t.bram[(i.imm & 1) as usize];
            out.clear();
            out.push(scalar);
            return Ok(());
        }

        // ---- apply, in place over operand 0's buffer (perf §Perf-1) ---------
        let mut result = std::mem::take(&mut operands[0]);
        result.truncate(len);
        if op == OperatorKind::Select {
            let (a, b) = (&operands[1], &operands[2]);
            for k in 0..len {
                // result[k] still holds pred[k] at this point
                result[k] = if result[k] > 0.0 { a[k] } else { b[k] };
            }
        } else if let Some(b) = operands.get(1) {
            // binary: hoist the opcode match out of the element loop so the
            // common tile datapaths autovectorize (perf §Perf-4).
            let b = &b[..len];
            match op {
                OperatorKind::Mul => {
                    for (r, &bv) in result.iter_mut().zip(b) {
                        *r *= bv;
                    }
                }
                OperatorKind::Add => {
                    for (r, &bv) in result.iter_mut().zip(b) {
                        *r += bv;
                    }
                }
                OperatorKind::Sub => {
                    for (r, &bv) in result.iter_mut().zip(b) {
                        *r -= bv;
                    }
                }
                _ => {
                    for (r, &bv) in result.iter_mut().zip(b) {
                        *r = op.apply(*r, bv, &mut state);
                    }
                }
            }
        } else {
            for r in result.iter_mut().take(len) {
                *r = op.apply(*r, 0.0, &mut state);
            }
        }
        if let Some(t) = tail {
            // fused map∘map: the unary stateless tail transforms the head's
            // output element-wise before delivery.
            for r in result.iter_mut() {
                *r = t.apply(*r, 0.0, &mut state);
            }
        }
        fabric.tiles[idx].acc = state;

        // hand non-result BRAM buffers back (capacity reuse, perf §Perf-3);
        // operand 0's buffer travels onward as the result stream.
        for (k, src) in bram_src.iter().enumerate().skip(1) {
            if let Some(j) = src {
                if let Some(o) = operands.get_mut(k) {
                    o.clear();
                    fabric.tiles[idx].bram[*j] = std::mem::take(o);
                }
            }
        }

        // ---- deliver: follow out_port through bypass tiles to a consumer ----
        let out = fabric.tiles[idx].switch.out_port;
        match out {
            None => {
                // park the result in BRAM[imm&1]
                fabric.tiles[idx].bram[(i.imm & 1) as usize] = result;
            }
            Some(mut dir) => {
                let slot = ((i.imm >> 1) & 0x3) as u8;
                let mut cur = idx;
                let mut hops = 0u64;
                loop {
                    let nxt = fabric.mesh.neighbor(cur, dir).ok_or(Error::Routing {
                        from: idx,
                        to: cur,
                    })?;
                    let arrival = dir.opposite();
                    let t = &fabric.tiles[nxt];
                    if t.switch.consumes(arrival) {
                        fabric.tiles[nxt].park(arrival, slot, result);
                        break;
                    }
                    if let Some(fwd) = t.switch.bypass_to(arrival) {
                        hops += 1;
                        cur = nxt;
                        dir = fwd;
                        if hops as usize > fabric.mesh.tiles() {
                            return Err(Error::Routing { from: idx, to: nxt });
                        }
                        continue;
                    }
                    return Err(Error::Routing { from: idx, to: nxt });
                }
                stats.hop_fills += hops;
                stats.hop_elements += hops * len as u64;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitstream::{BitstreamLibrary, RegionClass};
    use crate::isa::Dir;
    use crate::config::OverlayConfig;
    use crate::isa::Instr;

    fn setup(ops: &[(usize, OperatorKind)]) -> Fabric {
        let mut f = Fabric::new(OverlayConfig::default()).unwrap();
        let lib = BitstreamLibrary::standard(&f.cfg);
        for &(idx, op) in ops {
            let class = f.tiles[idx].class;
            let bs = lib
                .get(op, class)
                .or_else(|| lib.get(op, RegionClass::Large))
                .unwrap()
                .clone();
            f.load_bitstream(idx, &bs).unwrap();
        }
        f
    }

    fn prog(cfg: &OverlayConfig, instrs: Vec<Instr>) -> Program {
        Program::new(instrs, cfg).unwrap()
    }

    /// The paper's headline accelerator, hand-assembled: tile0 multiplies two
    /// DMA'd vectors, streams the product east into tile1's accumulator.
    fn vmul_reduce_program(cfg: &OverlayConfig, n: i16) -> Program {
        use Opcode::*;
        prog(
            cfg,
            vec![
                Instr::ldi(0, 1, n),
                Instr::ldi(1, 1, n),
                // interconnect: t0 → E, t1 consumes on W
                Instr::op(SetOutE, 0),
                Instr::op(SetInW, 1),
                Instr::op(ConnectPr, 0),
                Instr::op(ConnectPr, 1),
                // data in
                Instr { op: DmaIn, tile: 0, a: 1, b: 0, imm: 0 },      // chan0 → bram0
                Instr { op: DmaIn, tile: 0, a: 1, b: 0, imm: 0b11 },   // chan1 → bram1
                // compute
                Instr { op: VecRun, tile: 0, a: 1, b: 0, imm: 0 },
                Instr { op: VecAcc, tile: 1, a: 1, b: 2, imm: 0 },
                // result out: 1 word from t1.bram0 → chan0
                Instr::ldi(1, 3, 1),
                Instr { op: DmaOut, tile: 1, a: 3, b: 0, imm: 0 },
                Instr::halt(),
            ],
        )
    }

    #[test]
    fn vmul_reduce_end_to_end() {
        let mut f = setup(&[(0, OperatorKind::Mul), (1, OperatorKind::AccSum)]);
        let n = 256;
        let a: Vec<f32> = (0..n).map(|i| i as f32 / 16.0).collect();
        let b: Vec<f32> = (0..n).map(|i| 0.5 + (i % 7) as f32).collect();
        let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();

        let p = vmul_reduce_program(&f.cfg, n as i16);
        let chans = vec![a, b];
        let mut io = ExternalIo::with_inputs(&chans);
        let stats = Controller::default().run(&mut f, &p, &mut io).unwrap();

        let got = io.outputs[0][0];
        assert!((got - want).abs() / want.abs() < 1e-5, "got {got}, want {want}");
        assert_eq!(stats.elements, 2 * n as u64); // mul stream + acc stream
        assert!(stats.dma_words >= 2 * n as u64);
        assert!(stats.cycles_pipelined() > 0);
    }

    #[test]
    fn pass_through_tiles_add_hop_cost() {
        // t0 (mul) → E, t1 bypasses W→E, t2 consumes on W (acc).
        let mut f = setup(&[
            (0, OperatorKind::Mul),
            (1, OperatorKind::Route),
            (2, OperatorKind::AccSum),
        ]);
        use Opcode::*;
        let n = 128;
        let p = prog(
            &f.cfg,
            vec![
                Instr::ldi(0, 1, n),
                Instr::ldi(2, 1, n),
                Instr::op(SetOutE, 0),
                Instr::op(BypassWE, 1),
                Instr::op(SetInW, 2),
                Instr::op(ConnectPr, 0),
                Instr::op(ConnectPr, 2),
                Instr { op: DmaIn, tile: 0, a: 1, b: 0, imm: 0 },
                Instr { op: DmaIn, tile: 0, a: 1, b: 0, imm: 0b11 },
                Instr { op: VecRun, tile: 0, a: 1, b: 0, imm: 0 },
                Instr { op: VecAcc, tile: 2, a: 1, b: 2, imm: 0 },
                Instr::ldi(2, 3, 1),
                Instr { op: DmaOut, tile: 2, a: 3, b: 0, imm: 0 },
                Instr::halt(),
            ],
        );
        let a = vec![1.0f32; n as usize];
        let b = vec![2.0f32; n as usize];
        let chans = vec![a, b];
        let mut io = ExternalIo::with_inputs(&chans);
        let stats = Controller::default().run(&mut f, &p, &mut io).unwrap();
        assert_eq!(io.outputs[0][0], 256.0);
        assert_eq!(stats.hop_fills, 1);
        assert_eq!(stats.hop_elements, n as u64);
        // store-and-forward prices the hop per element; pipelined per fill.
        assert_eq!(
            stats.cycles_store_forward() - stats.cycles_pipelined(),
            (n - 1) as u64
        );
    }

    #[test]
    fn fused_vmul_reduce_on_one_tile() {
        // mul+acc_sum fused into large tile 3: two DMA'd vectors, one
        // vec.acc, dot product out — no inter-tile stream at all.
        let mut f = setup(&[]);
        let bs = crate::bitstream::Bitstream::synthesize_fused(
            OperatorKind::Mul,
            OperatorKind::AccSum,
            RegionClass::Large,
            &f.cfg,
        );
        f.load_bitstream(3, &bs).unwrap();
        use Opcode::*;
        let n = 256;
        let p = prog(
            &f.cfg,
            vec![
                Instr::ldi(3, 1, n),
                Instr::op(ConnectPr, 3),
                Instr { op: DmaIn, tile: 3, a: 1, b: 0, imm: 0 },
                Instr { op: DmaIn, tile: 3, a: 1, b: 0, imm: 0b11 },
                Instr { op: VecAcc, tile: 3, a: 1, b: 2, imm: 0 },
                Instr::ldi(3, 3, 1),
                Instr { op: DmaOut, tile: 3, a: 3, b: 0, imm: 0 },
                Instr::halt(),
            ],
        );
        let a: Vec<f32> = (0..n).map(|i| i as f32 / 16.0).collect();
        let b: Vec<f32> = (0..n).map(|i| 0.5 + (i % 7) as f32).collect();
        // reference association: sequential sum of products, like the
        // unfused mul-tile → acc-tile pipeline
        let mut want = 0.0f32;
        for (x, y) in a.iter().zip(&b) {
            want += x * y;
        }
        let chans = vec![a, b];
        let mut io = ExternalIo::with_inputs(&chans);
        Controller::default().run(&mut f, &p, &mut io).unwrap();
        assert_eq!(io.outputs[0][0].to_bits(), want.to_bits());
    }

    #[test]
    fn fused_map_applies_tail_elementwise() {
        // neg+abs fused: abs(neg(x)) == abs(x)
        let mut f = setup(&[]);
        let bs = crate::bitstream::Bitstream::synthesize_fused(
            OperatorKind::Neg,
            OperatorKind::Abs,
            RegionClass::Small,
            &f.cfg,
        );
        f.load_bitstream(0, &bs).unwrap();
        use Opcode::*;
        let n = 4;
        let p = prog(
            &f.cfg,
            vec![
                Instr::ldi(0, 1, n),
                Instr { op: DmaIn, tile: 0, a: 1, b: 0, imm: 0 },
                Instr { op: VecRun, tile: 0, a: 1, b: 0, imm: 0 },
                Instr { op: DmaOut, tile: 0, a: 1, b: 0, imm: 0 },
                Instr::halt(),
            ],
        );
        let chans = vec![vec![-1.5f32, 2.0, -0.25, 0.0]];
        let mut io = ExternalIo::with_inputs(&chans);
        let stats = Controller::default().run(&mut f, &p, &mut io).unwrap();
        assert_eq!(io.outputs[0], vec![1.5, 2.0, 0.25, 0.0]);
        // the tail adds its fill latency to the vector account
        assert_eq!(
            stats.vector_cycles,
            OperatorKind::Neg.latency_cycles()
                + OperatorKind::Abs.latency_cycles()
                + n as u64
        );
    }

    #[test]
    fn scalar_loop_with_branches() {
        use Opcode::*;
        let f_cfg = OverlayConfig::default();
        let mut f = setup(&[]);
        // r0 = 0; r1 = 10; loop: inc r0; cmp r0,r1; bne loop; halt
        let p = prog(
            &f_cfg,
            vec![
                Instr::ldi(0, 0, 0),
                Instr::ldi(0, 1, 10),
                Instr::op_a(IncR, 0, 0),
                Instr { op: CmpR, tile: 0, a: 0, b: 1, imm: 0 },
                Instr { op: Bne, tile: 0, a: 0, b: 0, imm: -3 },
                Instr::halt(),
            ],
        );
        let mut io = ExternalIo::default();
        let stats = Controller::default().run(&mut f, &p, &mut io).unwrap();
        assert_eq!(f.tiles[0].regs[0], 10.0);
        assert_eq!(stats.branches_taken, 9);
    }

    #[test]
    fn infinite_loop_traps_on_fuel() {
        let cfg = OverlayConfig::default();
        let mut f = setup(&[]);
        let p = prog(
            &cfg,
            vec![
                Instr { op: Opcode::Jmp, tile: 0, a: 0, b: 0, imm: -1 },
                Instr::halt(),
            ],
        );
        let ctl = Controller { max_instrs: 1000 };
        let err = ctl.run(&mut f, &p, &mut ExternalIo::default()).unwrap_err();
        assert!(matches!(err, Error::Trap { .. }));
    }

    #[test]
    fn vec_on_empty_tile_traps() {
        let cfg = OverlayConfig::default();
        let mut f = setup(&[]);
        let p = prog(
            &cfg,
            vec![
                Instr::ldi(0, 1, 4),
                Instr { op: Opcode::VecRun, tile: 0, a: 1, b: 0, imm: 0 },
                Instr::halt(),
            ],
        );
        assert!(Controller::default()
            .run(&mut f, &p, &mut ExternalIo::default())
            .is_err());
    }

    #[test]
    fn dma_overflow_traps() {
        let cfg = OverlayConfig::default();
        let mut f = setup(&[]);
        // ask for more words than the channel holds
        let p = prog(
            &cfg,
            vec![
                Instr::ldi(0, 1, 100),
                Instr { op: Opcode::DmaIn, tile: 0, a: 1, b: 0, imm: 0 },
                Instr::halt(),
            ],
        );
        let chans = vec![vec![0.0; 10]];
        let mut io = ExternalIo::with_inputs(&chans);
        assert!(Controller::default().run(&mut f, &p, &mut io).is_err());
    }

    #[test]
    fn broadcast_scalar_operand() {
        // filter_gt with a broadcast threshold in bram1
        let cfg = OverlayConfig::default();
        let mut f = setup(&[(0, OperatorKind::FilterGt)]);
        use Opcode::*;
        let n = 8;
        let p = prog(
            &cfg,
            vec![
                Instr::ldi(0, 1, n),
                Instr { op: DmaIn, tile: 0, a: 1, b: 0, imm: 0 },      // values
                Instr::ldi(0, 2, 1),
                Instr { op: DmaIn, tile: 0, a: 2, b: 0, imm: 0b11 },   // threshold (1 word)
                Instr { op: VecRun, tile: 0, a: 1, b: 0, imm: 0 },     // result → bram0
                Instr { op: DmaOut, tile: 0, a: 1, b: 0, imm: 0 },
                Instr::halt(),
            ],
        );
        let vals = vec![-1.0, 2.0, 0.5, 3.0, -2.0, 4.0, 1.0, 0.0];
        let chans = vec![vals, vec![0.9]];
        let mut io = ExternalIo::with_inputs(&chans);
        Controller::default().run(&mut f, &p, &mut io).unwrap();
        assert_eq!(io.outputs[0], vec![0.0, 2.0, 0.0, 3.0, 0.0, 4.0, 1.0, 0.0]);
    }

    #[test]
    fn spec_sel_commits_by_flags() {
        let cfg = OverlayConfig::default();
        let mut f = setup(&[]);
        f.tiles[4].park(Dir::W, 1, vec![1.0, 1.0]);
        f.tiles[4].park(Dir::N, 2, vec![2.0, 2.0]);
        use Opcode::*;
        // cmp r0,r0 sets eq → keep slot a=1, drop slot b=2
        let p = prog(
            &cfg,
            vec![
                Instr { op: CmpR, tile: 4, a: 0, b: 0, imm: 0 },
                Instr { op: SpecSel, tile: 4, a: 1, b: 2, imm: 0 },
                Instr::halt(),
            ],
        );
        Controller::default().run(&mut f, &p, &mut ExternalIo::default()).unwrap();
        assert_eq!(f.tiles[4].inbox.len(), 1);
        assert_eq!(f.tiles[4].inbox[0].data, vec![1.0, 1.0]);
        assert_eq!(f.tiles[4].inbox[0].slot, 0);
    }
}
