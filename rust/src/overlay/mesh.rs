//! Mesh geometry: row-major tile indexing and N-E-S-W neighbourhood.

use crate::isa::Dir;

/// A rows×cols 2-D mesh (pure geometry; no state).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Mesh {
    pub rows: usize,
    pub cols: usize,
}

impl Mesh {
    pub fn new(rows: usize, cols: usize) -> Mesh {
        Mesh { rows, cols }
    }

    pub fn tiles(&self) -> usize {
        self.rows * self.cols
    }

    /// (row, col) of a row-major tile index.
    pub fn coords(&self, idx: usize) -> (usize, usize) {
        (idx / self.cols, idx % self.cols)
    }

    /// Row-major index of (row, col).
    pub fn index(&self, row: usize, col: usize) -> usize {
        row * self.cols + col
    }

    /// Neighbour of `idx` in direction `d`, if inside the mesh.
    pub fn neighbor(&self, idx: usize, d: Dir) -> Option<usize> {
        let (r, c) = self.coords(idx);
        let (nr, nc) = match d {
            Dir::N => (r.checked_sub(1)?, c),
            Dir::S => (r + 1, c),
            Dir::W => (r, c.checked_sub(1)?),
            Dir::E => (r, c + 1),
        };
        (nr < self.rows && nc < self.cols).then(|| self.index(nr, nc))
    }

    /// Direction from tile `a` to an adjacent tile `b`, if adjacent.
    pub fn direction(&self, a: usize, b: usize) -> Option<Dir> {
        Dir::ALL.into_iter().find(|&d| self.neighbor(a, d) == Some(b))
    }

    /// Manhattan distance between two tiles.
    pub fn manhattan(&self, a: usize, b: usize) -> usize {
        let (ar, ac) = self.coords(a);
        let (br, bc) = self.coords(b);
        ar.abs_diff(br) + ac.abs_diff(bc)
    }

    /// Is the tile on the mesh border (the original overlay put data BRAMs
    /// only on border tiles)?
    pub fn is_border(&self, idx: usize) -> bool {
        let (r, c) = self.coords(idx);
        r == 0 || c == 0 || r + 1 == self.rows || c + 1 == self.cols
    }

    /// Snake (boustrophedon) order: a Hamiltonian path where consecutive
    /// tiles are always mesh-adjacent — the dynamic placer's canvas for
    /// contiguous pipelines.
    pub fn snake_order(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.tiles());
        for r in 0..self.rows {
            if r % 2 == 0 {
                for c in 0..self.cols {
                    out.push(self.index(r, c));
                }
            } else {
                for c in (0..self.cols).rev() {
                    out.push(self.index(r, c));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn neighbors_3x3() {
        let m = Mesh::new(3, 3);
        // center tile 4 has all four neighbors
        assert_eq!(m.neighbor(4, Dir::N), Some(1));
        assert_eq!(m.neighbor(4, Dir::S), Some(7));
        assert_eq!(m.neighbor(4, Dir::E), Some(5));
        assert_eq!(m.neighbor(4, Dir::W), Some(3));
        // corner tile 0
        assert_eq!(m.neighbor(0, Dir::N), None);
        assert_eq!(m.neighbor(0, Dir::W), None);
        assert_eq!(m.neighbor(0, Dir::E), Some(1));
        assert_eq!(m.neighbor(0, Dir::S), Some(3));
    }

    #[test]
    fn direction_inverse_of_neighbor() {
        let m = Mesh::new(3, 4);
        for idx in 0..m.tiles() {
            for d in Dir::ALL {
                if let Some(n) = m.neighbor(idx, d) {
                    assert_eq!(m.direction(idx, n), Some(d));
                    assert_eq!(m.direction(n, idx), Some(d.opposite()));
                }
            }
        }
    }

    #[test]
    fn manhattan_distance() {
        let m = Mesh::new(3, 3);
        assert_eq!(m.manhattan(0, 8), 4);
        assert_eq!(m.manhattan(4, 4), 0);
        assert_eq!(m.manhattan(0, 2), 2);
    }

    #[test]
    fn snake_order_is_contiguous_hamiltonian() {
        for (r, c) in [(3, 3), (2, 5), (4, 4), (1, 7)] {
            let m = Mesh::new(r, c);
            let order = m.snake_order();
            assert_eq!(order.len(), m.tiles());
            let mut seen = std::collections::HashSet::new();
            for w in order.windows(2) {
                assert_eq!(m.manhattan(w[0], w[1]), 1, "{r}x{c}: {w:?} not adjacent");
                seen.insert(w[0]);
            }
            seen.insert(*order.last().unwrap());
            assert_eq!(seen.len(), m.tiles());
        }
    }

    #[test]
    fn border_detection_3x3() {
        let m = Mesh::new(3, 3);
        let borders: Vec<usize> = (0..9).filter(|&i| m.is_border(i)).collect();
        assert_eq!(borders, vec![0, 1, 2, 3, 5, 6, 7, 8]); // all but center
    }
}
