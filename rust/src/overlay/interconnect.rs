//! Per-tile interconnect switch state.
//!
//! Each tile's switch decides, per port, whether arriving data is
//! **consumed** by the resident operator (`set.in.*` marks a port as
//! consuming — cumulative, so a Select tile can consume on three ports),
//! forwarded onward without consumption (**bypass** — how Fig. 2's
//! pass-through tiles work), or dropped. The operator's result leaves on
//! the single `out_port`. All of this is configured by the controller's 22
//! interconnect instructions.

use crate::isa::Dir;

/// Switch configuration of one tile.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SwitchState {
    /// Ports whose arrivals feed the resident operator (N,E,S,W mask).
    in_ports: [bool; 4],
    /// Port the operator's output stream leaves on.
    pub out_port: Option<Dir>,
    /// `bypass[from] = Some(to)`: arrivals on `from` are forwarded to `to`
    /// without consumption. Indexed by `Dir as usize` (N,E,S,W → 0..4).
    bypass: [Option<Dir>; 4],
    /// Is the PR operator tapped into the stream? (`pr.connect`)
    pub pr_connected: bool,
}

fn di(d: Dir) -> usize {
    match d {
        Dir::N => 0,
        Dir::E => 1,
        Dir::S => 2,
        Dir::W => 3,
    }
}

impl SwitchState {
    /// Mark port `d` as consuming (cumulative — `set.in.*`).
    pub fn set_in(&mut self, d: Dir) {
        self.in_ports[di(d)] = true;
    }

    /// Is port `d` marked consuming (regardless of PR connection)?
    pub fn in_port_set(&self, d: Dir) -> bool {
        self.in_ports[di(d)]
    }

    /// Configure a bypass lane `from → to`.
    pub fn set_bypass(&mut self, from: Dir, to: Dir) {
        self.bypass[di(from)] = Some(to);
    }

    /// Remove a bypass lane.
    pub fn clear_bypass(&mut self, from: Dir) {
        self.bypass[di(from)] = None;
    }

    /// Where arrivals on `from` are forwarded, if bypassed.
    pub fn bypass_to(&self, from: Dir) -> Option<Dir> {
        self.bypass[di(from)]
    }

    /// Does the tile consume arrivals on `d` into its operator?
    pub fn consumes(&self, d: Dir) -> bool {
        self.pr_connected && self.in_ports[di(d)]
    }

    /// Number of configured bypass lanes (resource/penalty metric).
    pub fn bypass_count(&self) -> usize {
        self.bypass.iter().filter(|b| b.is_some()).count()
    }

    /// Reset to the power-on state.
    pub fn clear(&mut self) {
        *self = SwitchState::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_switch_is_inert() {
        let s = SwitchState::default();
        for d in Dir::ALL {
            assert!(!s.consumes(d));
            assert_eq!(s.bypass_to(d), None);
        }
        assert_eq!(s.bypass_count(), 0);
    }

    #[test]
    fn consume_requires_pr_connected() {
        let mut s = SwitchState::default();
        s.set_in(Dir::W);
        assert!(!s.consumes(Dir::W), "not connected yet");
        s.pr_connected = true;
        assert!(s.consumes(Dir::W));
        assert!(!s.consumes(Dir::E));
    }

    #[test]
    fn set_in_is_cumulative_for_multi_port_consumers() {
        // a Select tile consumes predicate + two speculated streams
        let mut s = SwitchState::default();
        s.pr_connected = true;
        s.set_in(Dir::N);
        s.set_in(Dir::W);
        s.set_in(Dir::E);
        assert!(s.consumes(Dir::N) && s.consumes(Dir::W) && s.consumes(Dir::E));
        assert!(!s.consumes(Dir::S));
    }

    #[test]
    fn bypass_set_clear() {
        let mut s = SwitchState::default();
        s.set_bypass(Dir::W, Dir::E);
        s.set_bypass(Dir::N, Dir::S);
        assert_eq!(s.bypass_to(Dir::W), Some(Dir::E));
        assert_eq!(s.bypass_count(), 2);
        s.clear_bypass(Dir::W);
        assert_eq!(s.bypass_to(Dir::W), None);
        assert_eq!(s.bypass_count(), 1);
    }

    #[test]
    fn clear_resets_everything() {
        let mut s = SwitchState::default();
        s.set_in(Dir::N);
        s.out_port = Some(Dir::S);
        s.pr_connected = true;
        s.set_bypass(Dir::E, Dir::W);
        s.clear();
        assert_eq!(s, SwitchState::default());
    }
}
