//! Table/series emitters for the benchmark harnesses.
//!
//! Every bench and example renders its results through these helpers so
//! regenerated tables and figure series look the same everywhere (and can
//! be diffed against EXPERIMENTS.md).

use std::fmt::Write as _;

/// A simple right-padded ASCII table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Render the table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let _ = writeln!(
            out,
            "{}",
            "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1))
        );
        for r in &self.rows {
            let _ = writeln!(out, "{}", line(r, &widths));
        }
        out
    }

    /// Render as CSV (for EXPERIMENTS.md appendices / plotting).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ =
            writeln!(out, "{}", self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }
}

/// Format seconds as the paper's millisecond axis.
pub fn ms(seconds: f64) -> String {
    format!("{:.4}", seconds * 1e3)
}

/// Format a speedup factor.
pub fn speedup(base: f64, other: f64) -> String {
    if other == 0.0 {
        "inf".into()
    } else {
        format!("{:.2}x", base / other)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["target", "ms"]);
        t.row(&["dynamic-overlay".into(), "0.125".into()]);
        t.row(&["arm".into(), "1.5".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("dynamic-overlay"));
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines.len() >= 4);
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("", &["a"]);
        t.row(&["x,y".into()]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    fn ms_formats() {
        assert_eq!(ms(1.25e-3), "1.2500");
        assert_eq!(speedup(2.0, 1.0), "2.00x");
    }
}
