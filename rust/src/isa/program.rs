//! Controller program container: validation, category statistics, and the
//! per-tile instruction-BRAM footprint check.

use std::collections::HashMap;

use super::{encode, Category, Instr, Opcode};
use crate::config::OverlayConfig;
use crate::error::{Error, Result};

/// A validated controller program.
#[derive(Debug, Clone, PartialEq)]
pub struct Program {
    instrs: Vec<Instr>,
}

/// Per-category instruction counts of one program (T-ISA reporting).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CategoryMix {
    pub interconnect: usize,
    pub branch: usize,
    pub vector: usize,
    pub mem_reg: usize,
}

impl CategoryMix {
    pub fn total(&self) -> usize {
        self.interconnect + self.branch + self.vector + self.mem_reg
    }
}

impl Program {
    /// Wrap and validate an instruction sequence.
    ///
    /// Rules:
    /// * non-empty, ends with `halt`;
    /// * every instruction encodes (field ranges);
    /// * every branch target lands inside the program;
    /// * tile indices fit the given fabric.
    pub fn new(instrs: Vec<Instr>, cfg: &OverlayConfig) -> Result<Program> {
        if instrs.is_empty() {
            return Err(Error::Program("empty program".into()));
        }
        if instrs.last().map(|i| i.op) != Some(Opcode::Halt) {
            return Err(Error::Program("program must end with halt".into()));
        }
        let len = instrs.len() as i64;
        for (pc, i) in instrs.iter().enumerate() {
            encode::encode(i)?; // field range check
            if (i.tile as usize) >= cfg.tiles() {
                return Err(Error::Program(format!(
                    "pc={pc}: tile {} outside {}x{} fabric",
                    i.tile, cfg.rows, cfg.cols
                )));
            }
            if matches!(
                i.op,
                Opcode::Beq | Opcode::Bne | Opcode::Blt | Opcode::Bge | Opcode::Jmp
            ) {
                let tgt = pc as i64 + 1 + i.imm as i64;
                if tgt < 0 || tgt >= len {
                    return Err(Error::Program(format!(
                        "pc={pc}: branch target {tgt} outside program (len {len})"
                    )));
                }
            }
            if i.a as usize >= cfg.regs_per_tile || i.b as usize >= cfg.regs_per_tile {
                return Err(Error::Program(format!(
                    "pc={pc}: register operand exceeds {} regs/tile",
                    cfg.regs_per_tile
                )));
            }
        }
        Ok(Program { instrs })
    }

    pub fn instrs(&self) -> &[Instr] {
        &self.instrs
    }

    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Per-category counts — how the program spends the 42-opcode ISA.
    pub fn category_mix(&self) -> CategoryMix {
        let mut mix = CategoryMix::default();
        for i in &self.instrs {
            match i.op.category() {
                Category::Interconnect => mix.interconnect += 1,
                Category::Branch => mix.branch += 1,
                Category::Vector => mix.vector += 1,
                Category::MemReg => mix.mem_reg += 1,
            }
        }
        mix
    }

    /// Number of distinct opcodes used (≤ 42).
    pub fn distinct_opcodes(&self) -> usize {
        self.instrs
            .iter()
            .map(|i| i.op as u8)
            .collect::<std::collections::HashSet<_>>()
            .len()
    }

    /// Instructions destined for each tile — must fit its instruction BRAM.
    pub fn per_tile_footprint(&self) -> HashMap<u8, usize> {
        let mut m: HashMap<u8, usize> = HashMap::new();
        for i in &self.instrs {
            *m.entry(i.tile).or_default() += 1;
        }
        m
    }

    /// Check the program fits the fabric's per-tile instruction BRAMs.
    pub fn check_bram_fit(&self, cfg: &OverlayConfig) -> Result<()> {
        for (tile, n) in self.per_tile_footprint() {
            if n > cfg.instr_bram_words {
                return Err(Error::Program(format!(
                    "tile {tile}: {n} instructions exceed instruction BRAM of {} words",
                    cfg.instr_bram_words
                )));
            }
        }
        Ok(())
    }

    /// Binary image (what the controller writes into instruction BRAMs).
    pub fn to_words(&self) -> Vec<u32> {
        encode::encode_all(&self.instrs).expect("validated at construction")
    }

    /// Reconstruct from a binary image (re-validates).
    pub fn from_words(words: &[u32], cfg: &OverlayConfig) -> Result<Program> {
        Program::new(encode::decode_all(words)?, cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Instr;

    fn cfg() -> OverlayConfig {
        OverlayConfig::default()
    }

    fn valid_prog() -> Vec<Instr> {
        vec![
            Instr::ldi(0, 1, 256),
            Instr { op: Opcode::DmaIn, tile: 0, a: 1, b: 0, imm: 0 },
            Instr { op: Opcode::SetOutE, tile: 0, a: 0, b: 0, imm: 0 },
            Instr { op: Opcode::VecRun, tile: 0, a: 1, b: 0, imm: 0 },
            Instr::halt(),
        ]
    }

    #[test]
    fn accepts_valid_program() {
        let p = Program::new(valid_prog(), &cfg()).unwrap();
        assert_eq!(p.len(), 5);
    }

    #[test]
    fn rejects_empty() {
        assert!(Program::new(vec![], &cfg()).is_err());
    }

    #[test]
    fn rejects_missing_halt() {
        let mut p = valid_prog();
        p.pop();
        assert!(Program::new(p, &cfg()).is_err());
    }

    #[test]
    fn rejects_tile_outside_fabric() {
        let mut p = valid_prog();
        p[0].tile = 9; // 3x3 fabric has tiles 0..9
        assert!(Program::new(p, &cfg()).is_err());
    }

    #[test]
    fn rejects_branch_out_of_range() {
        let p = vec![
            Instr { op: Opcode::Jmp, tile: 0, a: 0, b: 0, imm: 10 },
            Instr::halt(),
        ];
        assert!(Program::new(p, &cfg()).is_err());
    }

    #[test]
    fn rejects_register_beyond_config() {
        let mut c = cfg();
        c.regs_per_tile = 4;
        let p = vec![Instr::ldi(0, 7, 1), Instr::halt()];
        assert!(Program::new(p, &c).is_err());
    }

    #[test]
    fn category_mix_counts() {
        let p = Program::new(valid_prog(), &cfg()).unwrap();
        let mix = p.category_mix();
        assert_eq!(mix.interconnect, 1);
        assert_eq!(mix.vector, 1);
        assert_eq!(mix.mem_reg, 3); // ldi, dma.in, halt
        assert_eq!(mix.total(), p.len());
    }

    #[test]
    fn words_roundtrip() {
        let p = Program::new(valid_prog(), &cfg()).unwrap();
        let q = Program::from_words(&p.to_words(), &cfg()).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn bram_fit_enforced() {
        let mut c = cfg();
        c.instr_bram_words = 8;
        let mut instrs: Vec<Instr> = (0..20).map(|_| Instr::op(Opcode::IncR, 0)).collect();
        instrs.push(Instr::halt());
        // Program itself is valid (halt tile 0 also counts toward tile 0)…
        let p = Program::new(instrs, &c).unwrap();
        // …but it cannot be loaded into an 8-word instruction BRAM.
        assert!(p.check_bram_fit(&c).is_err());
    }
}
