//! Dense 32-bit binary encoding of controller instructions.
//!
//! Word layout (msb → lsb):
//!
//! ```text
//!   [31:26] opcode   (6 bits, 0..42)
//!   [25:20] tile     (6 bits, 0..64)
//!   [19:15] a        (5 bits, register 0..32)
//!   [14:10] b        (5 bits, register 0..32)
//!   [ 9: 0] imm      (10 bits, two's-complement, -512..=511)
//! ```
//!
//! This is what a tile's instruction BRAM holds; `instr_bram_words` in the
//! config is denominated in these words.

use super::{Instr, Opcode};
use crate::error::{Error, Result};

const IMM_MIN: i16 = -512;
const IMM_MAX: i16 = 511;

/// Encode one instruction to its 32-bit word.
///
/// Fails if any field is out of range for the layout.
pub fn encode(i: &Instr) -> Result<u32> {
    if i.tile >= 64 {
        return Err(Error::Program(format!("tile {} out of range (<64)", i.tile)));
    }
    if i.a >= 32 || i.b >= 32 {
        return Err(Error::Program(format!(
            "register operand out of range (<32): a={} b={}",
            i.a, i.b
        )));
    }
    if i.imm < IMM_MIN || i.imm > IMM_MAX {
        return Err(Error::Program(format!(
            "immediate {} out of range ({IMM_MIN}..={IMM_MAX})",
            i.imm
        )));
    }
    let imm10 = (i.imm as u32) & 0x3ff;
    Ok(((i.op as u32) << 26)
        | ((i.tile as u32) << 20)
        | ((i.a as u32) << 15)
        | ((i.b as u32) << 10)
        | imm10)
}

/// Decode one 32-bit word back into an instruction.
pub fn decode(w: u32) -> Result<Instr> {
    let opv = (w >> 26) as u8;
    let op = Opcode::from_u8(opv)
        .ok_or_else(|| Error::Program(format!("bad opcode {opv:#x} in word {w:#010x}")))?;
    // sign-extend the 10-bit immediate
    let raw = (w & 0x3ff) as i16;
    let imm = if raw & 0x200 != 0 { raw | !0x3ff } else { raw };
    Ok(Instr {
        op,
        tile: ((w >> 20) & 0x3f) as u8,
        a: ((w >> 15) & 0x1f) as u8,
        b: ((w >> 10) & 0x1f) as u8,
        imm,
    })
}

/// Encode a whole instruction sequence.
pub fn encode_all(instrs: &[Instr]) -> Result<Vec<u32>> {
    instrs.iter().map(encode).collect()
}

/// Decode a whole word sequence.
pub fn decode_all(words: &[u32]) -> Result<Vec<Instr>> {
    words.iter().copied().map(decode).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Opcode;

    fn roundtrip(i: Instr) {
        let w = encode(&i).unwrap();
        assert_eq!(decode(w).unwrap(), i, "word {w:#010x}");
    }

    #[test]
    fn roundtrip_every_opcode() {
        for op in Opcode::all() {
            roundtrip(Instr { op, tile: 5, a: 3, b: 7, imm: -3 });
        }
    }

    #[test]
    fn roundtrip_imm_extremes() {
        for imm in [IMM_MIN, -1, 0, 1, IMM_MAX] {
            roundtrip(Instr { op: Opcode::Jmp, tile: 0, a: 0, b: 0, imm });
        }
    }

    #[test]
    fn roundtrip_field_extremes() {
        roundtrip(Instr { op: Opcode::Ldi, tile: 63, a: 31, b: 31, imm: 0 });
    }

    #[test]
    fn rejects_out_of_range_tile() {
        let i = Instr { op: Opcode::Halt, tile: 64, a: 0, b: 0, imm: 0 };
        assert!(encode(&i).is_err());
    }

    #[test]
    fn rejects_out_of_range_reg() {
        let i = Instr { op: Opcode::Mov, tile: 0, a: 32, b: 0, imm: 0 };
        assert!(encode(&i).is_err());
    }

    #[test]
    fn rejects_out_of_range_imm() {
        for imm in [IMM_MIN - 1, IMM_MAX + 1] {
            let i = Instr { op: Opcode::Jmp, tile: 0, a: 0, b: 0, imm };
            assert!(encode(&i).is_err());
        }
    }

    #[test]
    fn rejects_bad_opcode_word() {
        assert!(decode(0xffff_ffff).is_err());
    }

    #[test]
    fn encode_all_decode_all_roundtrip() {
        let prog: Vec<Instr> = Opcode::all()
            .enumerate()
            .map(|(k, op)| Instr { op, tile: (k % 9) as u8, a: 1, b: 2, imm: k as i16 - 21 })
            .collect();
        let words = encode_all(&prog).unwrap();
        assert_eq!(decode_all(&words).unwrap(), prog);
    }
}
