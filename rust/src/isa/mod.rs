//! The overlay controller's instruction set.
//!
//! The paper: *"The new controller currently interprets 42 different
//! instructions (interconnect: 22 instructions, branching: 6 instructions,
//! vector operations: 2 instructions, Memory & Register operations: 12
//! instructions)."* This module defines exactly those 42 opcodes, grouped into
//! the same four categories, with a dense 32-bit encoding ([`encode`]), a
//! two-way text assembler ([`asm`]) and program container ([`program`]).
//!
//! Instruction model: the controller is centralized (one program counter,
//! one flag register) but every instruction names a *target tile*; register
//! and BRAM operands resolve against that tile's local state. This mirrors
//! the paper's design where the controller writes each tile's instruction
//! BRAM and sequences the fabric.

pub mod asm;
pub mod encode;
pub mod program;

pub use program::Program;

/// Mesh port direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dir {
    N,
    E,
    S,
    W,
}

impl Dir {
    pub const ALL: [Dir; 4] = [Dir::N, Dir::E, Dir::S, Dir::W];

    /// The opposite port (data leaving `E` arrives on the neighbour's `W`).
    pub fn opposite(self) -> Dir {
        match self {
            Dir::N => Dir::S,
            Dir::S => Dir::N,
            Dir::E => Dir::W,
            Dir::W => Dir::E,
        }
    }
}

/// Instruction category, with the paper's per-category opcode budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    /// Interconnect configuration (22 opcodes).
    Interconnect,
    /// Branching (6 opcodes).
    Branch,
    /// Vector operations (2 opcodes).
    Vector,
    /// Memory & register operations (12 opcodes).
    MemReg,
}

impl Category {
    /// The paper's opcode budget for this category.
    pub fn budget(self) -> usize {
        match self {
            Category::Interconnect => 22,
            Category::Branch => 6,
            Category::Vector => 2,
            Category::MemReg => 12,
        }
    }
}

/// The 42 controller opcodes.
///
/// Discriminants are the binary opcode values (stable — artifacts embed
/// them); the order groups the categories contiguously:
/// `0..22` interconnect, `22..28` branch, `28..30` vector, `30..42` mem/reg.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Opcode {
    // ---- interconnect (22) ------------------------------------------------
    /// Operator input port ⇐ North.
    SetInN = 0,
    SetInE = 1,
    SetInS = 2,
    SetInW = 3,
    /// Operator output port ⇒ North.
    SetOutN = 4,
    SetOutE = 5,
    SetOutS = 6,
    SetOutW = 7,
    /// Pass-through: forward N→S without consuming (branch bypass).
    BypassNS = 8,
    BypassSN = 9,
    BypassEW = 10,
    BypassWE = 11,
    BypassNE = 12,
    BypassEN = 13,
    BypassNW = 14,
    BypassWN = 15,
    BypassSE = 16,
    BypassES = 17,
    BypassSW = 18,
    BypassWS = 19,
    /// Tap the resident PR operator into the configured stream.
    ConnectPr = 20,
    /// Detach the PR operator (tile becomes pure routing).
    DisconnectPr = 21,

    // ---- branching (6) -----------------------------------------------------
    /// Branch if flags.eq (pc-relative imm).
    Beq = 22,
    Bne = 23,
    /// Branch if flags.lt.
    Blt = 24,
    Bge = 25,
    /// Unconditional jump (pc-relative imm).
    Jmp = 26,
    /// Speculative select: commit one of two speculated tile streams based
    /// on the flag register — the dynamic overlay's if-then-else support.
    SpecSel = 27,

    // ---- vector operations (2) ---------------------------------------------
    /// Stream `len = R[a]` elements through the tile's resident operator.
    VecRun = 28,
    /// As `VecRun`, folding the stream into the tile accumulator (reduce).
    VecAcc = 29,

    // ---- memory & register operations (12) ----------------------------------
    /// R[a] ⇐ sign-extended imm.
    Ldi = 30,
    /// R[a] ⇐ R[b].
    Mov = 31,
    /// R[a] ⇐ dataBRAM[imm&1][ R[b] ].
    Ld = 32,
    /// dataBRAM[imm&1][ R[b] ] ⇐ R[a].
    St = 33,
    /// R[a] ⇐ R[a] + R[b].
    AddR = 34,
    /// R[a] ⇐ R[a] − R[b].
    SubR = 35,
    /// R[a] ⇐ R[a] + 1.
    IncR = 36,
    /// R[a] ⇐ R[a] − 1.
    DecR = 37,
    /// Compare R[a] ? R[b] → controller flags.
    CmpR = 38,
    /// DMA `len = R[a]` words from external channel `imm>>1` into
    /// dataBRAM[imm&1] of the target tile.
    DmaIn = 39,
    /// DMA out of dataBRAM[imm&1] to external channel `imm>>1`.
    DmaOut = 40,
    /// Stop the controller.
    Halt = 41,
}

impl Opcode {
    /// Total number of opcodes — the paper's 42.
    pub const COUNT: usize = 42;

    /// All opcodes in discriminant order.
    pub fn all() -> impl Iterator<Item = Opcode> {
        (0..Self::COUNT as u8).map(|v| Opcode::from_u8(v).unwrap())
    }

    /// Decode a raw opcode byte.
    pub fn from_u8(v: u8) -> Option<Opcode> {
        if (v as usize) < Self::COUNT {
            // SAFETY: repr(u8) with dense discriminants 0..42, checked above.
            Some(unsafe { std::mem::transmute::<u8, Opcode>(v) })
        } else {
            None
        }
    }

    /// The category this opcode belongs to.
    pub fn category(self) -> Category {
        match self as u8 {
            0..=21 => Category::Interconnect,
            22..=27 => Category::Branch,
            28..=29 => Category::Vector,
            _ => Category::MemReg,
        }
    }

    /// Lower-case mnemonic used by the assembler/disassembler.
    pub fn mnemonic(self) -> &'static str {
        use Opcode::*;
        match self {
            SetInN => "set.in.n",
            SetInE => "set.in.e",
            SetInS => "set.in.s",
            SetInW => "set.in.w",
            SetOutN => "set.out.n",
            SetOutE => "set.out.e",
            SetOutS => "set.out.s",
            SetOutW => "set.out.w",
            BypassNS => "bypass.ns",
            BypassSN => "bypass.sn",
            BypassEW => "bypass.ew",
            BypassWE => "bypass.we",
            BypassNE => "bypass.ne",
            BypassEN => "bypass.en",
            BypassNW => "bypass.nw",
            BypassWN => "bypass.wn",
            BypassSE => "bypass.se",
            BypassES => "bypass.es",
            BypassSW => "bypass.sw",
            BypassWS => "bypass.ws",
            ConnectPr => "pr.connect",
            DisconnectPr => "pr.disconnect",
            Beq => "beq",
            Bne => "bne",
            Blt => "blt",
            Bge => "bge",
            Jmp => "jmp",
            SpecSel => "spec.sel",
            VecRun => "vec.run",
            VecAcc => "vec.acc",
            Ldi => "ldi",
            Mov => "mov",
            Ld => "ld",
            St => "st",
            AddR => "add",
            SubR => "sub",
            IncR => "inc",
            DecR => "dec",
            CmpR => "cmp",
            DmaIn => "dma.in",
            DmaOut => "dma.out",
            Halt => "halt",
        }
    }

    /// `set.in.*` / `set.out.*` direction, if this is a port-set opcode.
    pub fn port_dir(self) -> Option<(bool, Dir)> {
        use Opcode::*;
        Some(match self {
            SetInN => (true, Dir::N),
            SetInE => (true, Dir::E),
            SetInS => (true, Dir::S),
            SetInW => (true, Dir::W),
            SetOutN => (false, Dir::N),
            SetOutE => (false, Dir::E),
            SetOutS => (false, Dir::S),
            SetOutW => (false, Dir::W),
            _ => return None,
        })
    }

    /// `(from, to)` ports for a bypass opcode.
    pub fn bypass_dirs(self) -> Option<(Dir, Dir)> {
        use Opcode::*;
        Some(match self {
            BypassNS => (Dir::N, Dir::S),
            BypassSN => (Dir::S, Dir::N),
            BypassEW => (Dir::E, Dir::W),
            BypassWE => (Dir::W, Dir::E),
            BypassNE => (Dir::N, Dir::E),
            BypassEN => (Dir::E, Dir::N),
            BypassNW => (Dir::N, Dir::W),
            BypassWN => (Dir::W, Dir::N),
            BypassSE => (Dir::S, Dir::E),
            BypassES => (Dir::E, Dir::S),
            BypassSW => (Dir::S, Dir::W),
            BypassWS => (Dir::W, Dir::S),
            _ => return None,
        })
    }

    /// Bypass opcode for a `(from, to)` port pair, if one exists (from≠to).
    pub fn bypass_for(from: Dir, to: Dir) -> Option<Opcode> {
        Opcode::all().find(|o| o.bypass_dirs() == Some((from, to)))
    }
}

/// One decoded controller instruction.
///
/// Fields not used by an opcode must be zero (enforced by
/// [`program::Program::validate`], preserved by the codec).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Instr {
    pub op: Opcode,
    /// Target tile (row-major index), `< 64`.
    pub tile: u8,
    /// First register operand, `< 32`.
    pub a: u8,
    /// Second register operand, `< 32`.
    pub b: u8,
    /// Signed immediate, `-512..=511` (branch offsets, BRAM selects, ...).
    pub imm: i16,
}

impl Instr {
    /// A fully-zero-operand instruction for `op` on `tile`.
    pub fn op(op: Opcode, tile: u8) -> Instr {
        Instr { op, tile, a: 0, b: 0, imm: 0 }
    }

    /// Convenience constructors used throughout the JIT code generator.
    pub fn ldi(tile: u8, r: u8, imm: i16) -> Instr {
        Instr { op: Opcode::Ldi, tile, a: r, b: 0, imm }
    }
    /// `op` on `tile` with a single register operand `a`.
    pub fn op_a(op: Opcode, tile: u8, a: u8) -> Instr {
        Instr { op, tile, a, b: 0, imm: 0 }
    }
    pub fn halt() -> Instr {
        Instr::op(Opcode::Halt, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn exactly_42_opcodes() {
        assert_eq!(Opcode::all().count(), 42);
        assert_eq!(Opcode::COUNT, 42);
    }

    #[test]
    fn category_budgets_match_paper() {
        // paper: interconnect 22, branching 6, vector 2, mem/reg 12.
        let mut counts: HashMap<Category, usize> = HashMap::new();
        for op in Opcode::all() {
            *counts.entry(op.category()).or_default() += 1;
        }
        for cat in [
            Category::Interconnect,
            Category::Branch,
            Category::Vector,
            Category::MemReg,
        ] {
            assert_eq!(counts[&cat], cat.budget(), "{cat:?}");
        }
        assert_eq!(counts.values().sum::<usize>(), 42);
    }

    #[test]
    fn from_u8_roundtrips_all() {
        for op in Opcode::all() {
            assert_eq!(Opcode::from_u8(op as u8), Some(op));
        }
        assert_eq!(Opcode::from_u8(42), None);
        assert_eq!(Opcode::from_u8(255), None);
    }

    #[test]
    fn mnemonics_unique() {
        let mut seen = std::collections::HashSet::new();
        for op in Opcode::all() {
            assert!(seen.insert(op.mnemonic()), "dup mnemonic {}", op.mnemonic());
        }
    }

    #[test]
    fn bypass_table_complete_and_consistent() {
        // 12 ordered (from, to) pairs with from != to on 4 ports.
        let mut n = 0;
        for from in Dir::ALL {
            for to in Dir::ALL {
                if from == to {
                    assert_eq!(Opcode::bypass_for(from, to), None);
                } else {
                    let op = Opcode::bypass_for(from, to).unwrap();
                    assert_eq!(op.bypass_dirs(), Some((from, to)));
                    n += 1;
                }
            }
        }
        assert_eq!(n, 12);
    }

    #[test]
    fn dir_opposite_is_involution() {
        for d in Dir::ALL {
            assert_eq!(d.opposite().opposite(), d);
        }
    }

    #[test]
    fn port_dir_covers_exactly_eight() {
        assert_eq!(Opcode::all().filter(|o| o.port_dir().is_some()).count(), 8);
    }
}
