//! Two-way text assembler for controller programs.
//!
//! Format, one instruction per line:
//!
//! ```text
//!   <mnemonic> t<tile> [r<a>] [r<b>] [#<imm>]   ; comment
//! ```
//!
//! e.g. `ldi t0 r1 #4096`, `vec.acc t4 r1`, `bypass.we t1`. Operands may be
//! omitted when zero. `;` starts a comment; blank lines are ignored. Used by
//! the CLI `inspect` subcommand and by tests to write programs legibly.

use std::collections::HashMap;
use std::sync::OnceLock;

use super::{Instr, Opcode};
use crate::error::{Error, Result};

/// Render one instruction.
pub fn format_instr(i: &Instr) -> String {
    let mut s = format!("{} t{}", i.op.mnemonic(), i.tile);
    if i.a != 0 || i.b != 0 {
        s.push_str(&format!(" r{}", i.a));
    }
    if i.b != 0 {
        s.push_str(&format!(" r{}", i.b));
    }
    if i.imm != 0 {
        s.push_str(&format!(" #{}", i.imm));
    }
    s
}

/// Render a whole program.
pub fn format_program(instrs: &[Instr]) -> String {
    let mut out = String::new();
    for (pc, i) in instrs.iter().enumerate() {
        out.push_str(&format!("{pc:4}:  {}\n", format_instr(i)));
    }
    out
}

fn mnemonic_table() -> &'static HashMap<&'static str, Opcode> {
    static TABLE: OnceLock<HashMap<&'static str, Opcode>> = OnceLock::new();
    TABLE.get_or_init(|| Opcode::all().map(|o| (o.mnemonic(), o)).collect())
}

/// Parse one line (without comments) into an instruction.
pub fn parse_instr(line: &str) -> Result<Instr> {
    let mut parts = line.split_whitespace();
    let mn = parts
        .next()
        .ok_or_else(|| Error::Program("empty instruction".into()))?;
    let op = *mnemonic_table()
        .get(mn)
        .ok_or_else(|| Error::Program(format!("unknown mnemonic `{mn}`")))?;
    let mut instr = Instr::op(op, 0);
    let mut regs_seen = 0u8;
    for tok in parts {
        if let Some(t) = tok.strip_prefix('t') {
            instr.tile = t
                .parse()
                .map_err(|_| Error::Program(format!("bad tile `{tok}`")))?;
        } else if let Some(r) = tok.strip_prefix('r') {
            let v: u8 = r
                .parse()
                .map_err(|_| Error::Program(format!("bad register `{tok}`")))?;
            match regs_seen {
                0 => instr.a = v,
                1 => instr.b = v,
                _ => return Err(Error::Program(format!("too many registers at `{tok}`"))),
            }
            regs_seen += 1;
        } else if let Some(m) = tok.strip_prefix('#') {
            instr.imm = m
                .parse()
                .map_err(|_| Error::Program(format!("bad immediate `{tok}`")))?;
        } else {
            return Err(Error::Program(format!("unrecognized token `{tok}`")));
        }
    }
    Ok(instr)
}

/// Parse a whole program text (strips comments / pc prefixes / blank lines).
pub fn parse_program(text: &str) -> Result<Vec<Instr>> {
    let mut out = Vec::new();
    for raw in text.lines() {
        let line = raw.split(';').next().unwrap_or("");
        // tolerate the `  12:  ` pc prefix emitted by format_program
        let line = match line.split_once(':') {
            Some((pc, rest)) if pc.trim().chars().all(|c| c.is_ascii_digit()) => rest,
            _ => line,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        out.push(parse_instr(line)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Opcode;

    #[test]
    fn format_parse_roundtrip_every_opcode() {
        for op in Opcode::all() {
            let i = Instr { op, tile: 3, a: 2, b: 1, imm: -7 };
            let text = format_instr(&i);
            assert_eq!(parse_instr(&text).unwrap(), i, "{text}");
        }
    }

    #[test]
    fn parse_tolerates_comments_and_pc() {
        let text = "  0:  ldi t0 r1 #4096 ; vector length\n\n  1:  halt t0\n";
        let prog = parse_program(text).unwrap();
        assert_eq!(prog.len(), 2);
        assert_eq!(prog[0], Instr::ldi(0, 1, 4096));
        assert_eq!(prog[1].op, Opcode::Halt);
    }

    #[test]
    fn parse_rejects_unknown_mnemonic() {
        assert!(parse_instr("frobnicate t0").is_err());
    }

    #[test]
    fn parse_rejects_garbage_operand() {
        assert!(parse_instr("ldi t0 q9").is_err());
    }

    #[test]
    fn program_roundtrip() {
        let prog = vec![
            Instr::ldi(0, 1, 256),
            Instr { op: Opcode::DmaIn, tile: 0, a: 1, b: 0, imm: 0 },
            Instr { op: Opcode::VecAcc, tile: 4, a: 1, b: 2, imm: 0 },
            Instr::halt(),
        ];
        let text = format_program(&prog);
        assert_eq!(parse_program(&text).unwrap(), prog);
    }
}
