//! Crate-wide error type.
//!
//! Every fallible public API in the library returns [`Result<T>`]. Errors are
//! structured (not stringly-typed) so callers — the coordinator in
//! particular — can distinguish recoverable conditions (e.g. a pattern that
//! does not fit the fabric) from hard faults (a corrupt artifact).

use std::fmt;

/// Library-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// All error conditions surfaced by the JIT overlay runtime.
///
/// `Display`/`Error` are implemented by hand (not derived via `thiserror`)
/// so the crate builds with zero dependencies, fully offline.
#[derive(Debug)]
pub enum Error {
    /// A pattern expression failed shape/type checking.
    Pattern(String),

    /// The JIT could not select an operator implementation.
    NoBitstream { op: String, class: crate::bitstream::RegionClass },

    /// Placement failed: not enough free tiles (or no contiguous run).
    Placement(String),

    /// Routing failed between two placed tiles.
    Routing { from: usize, to: usize },

    /// A controller program is malformed (bad operands, missing halt, ...).
    Program(String),

    /// The controller trapped at runtime (bad address, div-by-zero, ...).
    Trap { pc: usize, reason: String },

    /// Reconfiguration error (bitstream does not fit the PR region, ...).
    Reconfig(String),

    /// A cached placement plan no longer matches the occupancy of the
    /// fabric it is about to be replayed on: it would overwrite residents
    /// of other accelerators even though the fabric has enough free tiles
    /// to host the pipeline cleanly. Run a placement-only recompile
    /// against the live occupancy instead of replaying.
    StalePlan { fabric: u64, free_tiles: usize },

    /// Artifact manifest / HLO loading problems.
    Artifact(String),

    /// The PJRT runtime rejected or failed an operation.
    Runtime(String),

    /// Configuration rejected at validation time.
    Config(String),

    /// Backpressure: the chosen pool worker's bounded queue is full.
    /// Retry later, drain replies, or use the blocking submit path.
    PoolBusy { worker: usize, capacity: usize },

    /// A fabric tile faulted. `permanent: false` means the tile's
    /// configuration was corrupted but the region is healthy (recovery:
    /// clear and re-download); `permanent: true` means the region is dead
    /// and has been quarantined (recovery: re-place elsewhere). The
    /// coordinator's recovery ladder retries both before falling back to
    /// CPU interpretation.
    TileFault { tile: usize, permanent: bool },

    /// Underlying I/O failure.
    Io(std::io::Error),

    /// Manifest / program-text parse failure.
    Parse(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Pattern(m) => write!(f, "pattern error: {m}"),
            Error::NoBitstream { op, class } => {
                write!(f, "no bitstream for operator `{op}` fitting region class {class:?}")
            }
            Error::Placement(m) => write!(f, "placement failed: {m}"),
            Error::Routing { from, to } => {
                write!(f, "routing failed: no path from tile {from} to tile {to}")
            }
            Error::Program(m) => write!(f, "program error: {m}"),
            Error::Trap { pc, reason } => write!(f, "controller trap at pc={pc}: {reason}"),
            Error::Reconfig(m) => write!(f, "reconfiguration error: {m}"),
            Error::StalePlan { fabric, free_tiles } => write!(
                f,
                "stale placement plan for fabric {fabric}: replay would overwrite residents while {free_tiles} tiles are free"
            ),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::PoolBusy { worker, capacity } => {
                write!(f, "pool busy: worker {worker} queue at capacity {capacity}")
            }
            Error::TileFault { tile, permanent } => write!(
                f,
                "tile fault at tile {tile} ({})",
                if *permanent { "permanent: region quarantined" } else { "transient: wrong bits" }
            ),
            // transparent: I/O errors surface their own message
            Error::Io(e) => fmt::Display::fmt(e, f),
            Error::Parse(m) => write!(f, "parse error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::Io(e)
    }
}

impl Error {
    /// True when retrying with a different placement/fabric may succeed.
    pub fn is_capacity(&self) -> bool {
        matches!(
            self,
            Error::Placement(_) | Error::Routing { .. } | Error::NoBitstream { .. }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_renders_structured_messages() {
        assert_eq!(Error::Pattern("x".into()).to_string(), "pattern error: x");
        assert_eq!(
            Error::Routing { from: 1, to: 2 }.to_string(),
            "routing failed: no path from tile 1 to tile 2"
        );
        assert_eq!(
            Error::Trap { pc: 7, reason: "div0".into() }.to_string(),
            "controller trap at pc=7: div0"
        );
    }

    #[test]
    fn io_errors_are_transparent_and_sourced() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "boom");
        let msg = io.to_string();
        let e: Error = io.into();
        assert_eq!(e.to_string(), msg);
        assert!(std::error::Error::source(&e).is_some());
        assert!(std::error::Error::source(&Error::Parse("p".into())).is_none());
    }

    #[test]
    fn capacity_classification() {
        assert!(Error::Placement("full".into()).is_capacity());
        assert!(Error::Routing { from: 0, to: 1 }.is_capacity());
        assert!(!Error::Runtime("x".into()).is_capacity());
        // backpressure is a service condition, not a placement-capacity miss
        assert!(!Error::PoolBusy { worker: 0, capacity: 8 }.is_capacity());
        // a stale plan wants respecialization, not a bigger fabric
        assert!(!Error::StalePlan { fabric: 1, free_tiles: 4 }.is_capacity());
        // tile faults ride their own recovery ladder, not the capacity one
        assert!(!Error::TileFault { tile: 3, permanent: true }.is_capacity());
    }

    #[test]
    fn tile_fault_renders_both_severities() {
        assert_eq!(
            Error::TileFault { tile: 4, permanent: false }.to_string(),
            "tile fault at tile 4 (transient: wrong bits)"
        );
        assert_eq!(
            Error::TileFault { tile: 7, permanent: true }.to_string(),
            "tile fault at tile 7 (permanent: region quarantined)"
        );
    }

    #[test]
    fn stale_plan_renders() {
        assert_eq!(
            Error::StalePlan { fabric: 3, free_tiles: 5 }.to_string(),
            "stale placement plan for fabric 3: replay would overwrite residents while 5 tiles are free"
        );
    }

    #[test]
    fn pool_busy_renders() {
        assert_eq!(
            Error::PoolBusy { worker: 2, capacity: 64 }.to_string(),
            "pool busy: worker 2 queue at capacity 64"
        );
    }
}
