//! Crate-wide error type.
//!
//! Every fallible public API in the library returns [`Result<T>`]. Errors are
//! structured (not stringly-typed) so callers — the coordinator in
//! particular — can distinguish recoverable conditions (e.g. a pattern that
//! does not fit the fabric) from hard faults (a corrupt artifact).

use thiserror::Error;

/// Library-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// All error conditions surfaced by the JIT overlay runtime.
#[derive(Debug, Error)]
pub enum Error {
    /// A pattern expression failed shape/type checking.
    #[error("pattern error: {0}")]
    Pattern(String),

    /// The JIT could not select an operator implementation.
    #[error("no bitstream for operator `{op}` fitting region class {class:?}")]
    NoBitstream { op: String, class: crate::bitstream::RegionClass },

    /// Placement failed: not enough free tiles (or no contiguous run).
    #[error("placement failed: {0}")]
    Placement(String),

    /// Routing failed between two placed tiles.
    #[error("routing failed: no path from tile {from} to tile {to}")]
    Routing { from: usize, to: usize },

    /// A controller program is malformed (bad operands, missing halt, ...).
    #[error("program error: {0}")]
    Program(String),

    /// The controller trapped at runtime (bad address, div-by-zero, ...).
    #[error("controller trap at pc={pc}: {reason}")]
    Trap { pc: usize, reason: String },

    /// Reconfiguration error (bitstream does not fit the PR region, ...).
    #[error("reconfiguration error: {0}")]
    Reconfig(String),

    /// Artifact manifest / HLO loading problems.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// The PJRT runtime rejected or failed an operation.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Configuration rejected at validation time.
    #[error("config error: {0}")]
    Config(String),

    /// Underlying I/O failure.
    #[error(transparent)]
    Io(#[from] std::io::Error),

    /// Manifest / program-text parse failure.
    #[error("parse error: {0}")]
    Parse(String),
}

impl Error {
    /// True when retrying with a different placement/fabric may succeed.
    pub fn is_capacity(&self) -> bool {
        matches!(
            self,
            Error::Placement(_) | Error::Routing { .. } | Error::NoBitstream { .. }
        )
    }
}
