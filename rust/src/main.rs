//! `repro` — the JIT-overlay leader binary.
//!
//! Subcommands map one-to-one onto the paper's experiments (see DESIGN.md
//! §Experiment-index) plus operational utilities:
//!
//! ```text
//!   repro fig2 [--n N]          reproduce Fig. 2 (static scenarios)
//!   repro fig3 [--n N]          reproduce Fig. 3 (five targets + ARM)
//!   repro sweep                 PR-overhead amortization sweep (T-PR)
//!   repro run --pattern P ...   JIT + run one composition
//!   repro verify [--n N]        three-way value agreement (overlay/CPU/PJRT)
//!   repro isa                   print the 42-instruction opcode table
//!   repro inspect --pattern P   show placement + disassembled program
//!   repro serve --requests K --workers N   multi-fabric pool service demo
//!   repro serve --pools P ...              cluster sharding across P pools
//!   repro serve --listen ADDR --reactors N socket serving tier (wire protocol)
//!   repro loadgen --addr ADDR --conns C    closed/open-loop load + BENCH JSON
//! ```
//!
//! Arg parsing is hand-rolled (`--flag value` pairs) and errors ride a
//! boxed-error shim — the workspace builds offline without clap or anyhow.

use std::io::{Read, Write};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use jit_overlay::benchkit::{write_bench_json, JsonObject};
use jit_overlay::coordinator::wire::{read_frame, write_frame, ClientMsg, ServerMsg};
use jit_overlay::coordinator::{
    AtomicMetrics, Cluster, Coordinator, Dispatch, Frontend, Metrics, NetServer, Request,
    WorkerPool,
};
use jit_overlay::exec::Engine;
use jit_overlay::isa::{asm, Category, Opcode};
use jit_overlay::jit::Jit;
use jit_overlay::patterns::{parse_pattern, Composition};
use jit_overlay::place::StaticScenario;
use jit_overlay::report::{ms, speedup, Table};
use jit_overlay::runtime::{default_artifacts_dir, Runtime};
use jit_overlay::timing::Target;
use jit_overlay::{
    workload, ClusterConfig, FaultSpec, FrontendConfig, NetConfig, OverlayConfig, ServiceConfig,
};

/// CLI-local result over a boxed error (the anyhow stand-in).
type Result<T, E = Box<dyn std::error::Error>> = std::result::Result<T, E>;

/// Build a boxed error from a format string.
macro_rules! anyhow {
    ($($arg:tt)*) => { Box::<dyn std::error::Error>::from(format!($($arg)*)) };
}

/// Early-return with a formatted boxed error.
macro_rules! bail {
    ($($arg:tt)*) => { return Err(anyhow!($($arg)*)) };
}

/// `.context(..)` / `.with_context(..)` on any displayable error.
trait Context<T> {
    fn context(self, msg: &'static str) -> Result<T>;
    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T>;
}

impl<T, E: std::fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: &'static str) -> Result<T> {
        self.map_err(|e| anyhow!("{msg}: {e}"))
    }

    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.map_err(|e| anyhow!("{}: {e}", f()))
    }
}

/// Minimal `--key value` argument map.
struct Args {
    map: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut map = std::collections::HashMap::new();
        let mut it = argv.iter();
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got `{a}`"))?;
            let val = it
                .next()
                .ok_or_else(|| anyhow!("--{key} needs a value"))?;
            map.insert(key.to_string(), val.clone());
        }
        Ok(Args { map })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
        }
    }

    fn u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
        }
    }

    fn str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }
}

/// Minimal SIGINT/SIGTERM latch for graceful shutdown, hand-rolled so the
/// crate stays dependency-free. The handler only sets an atomic flag; the
/// serve loop polls it and winds the tier down in order (stop accepting,
/// drain connections, shut the pool down, print the metrics summary).
#[cfg(unix)]
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    static TERM: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_signal(_signum: i32) {
        TERM.store(true, Ordering::SeqCst);
    }

    /// Install the handler for SIGINT (2) and SIGTERM (15). `signal(2)`
    /// semantics are enough here: the handler is one async-signal-safe
    /// atomic store, and a re-delivered signal before the poll loop
    /// notices is harmless (the flag is already set).
    pub fn install() {
        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
        }
        unsafe {
            signal(2, on_signal); // SIGINT
            signal(15, on_signal); // SIGTERM
        }
    }

    /// True once SIGINT or SIGTERM was delivered.
    pub fn requested() -> bool {
        TERM.load(Ordering::SeqCst)
    }
}

/// Non-unix stand-in: no signals to latch, the serve loop only stops on an
/// authorized remote `SHUTDOWN` frame.
#[cfg(not(unix))]
mod sig {
    pub fn install() {}

    pub fn requested() -> bool {
        false
    }
}

fn parse_target(s: &str) -> Result<Target> {
    Ok(match s {
        "dynamic" => Target::DynamicOverlay,
        "static-s1" => Target::StaticOverlay(StaticScenario::S1),
        "static-s2" => Target::StaticOverlay(StaticScenario::S2),
        "static-s3" => Target::StaticOverlay(StaticScenario::S3),
        "arm" => Target::ArmSoftware,
        "hls" => Target::HlsCustom,
        other => bail!("unknown target `{other}`"),
    })
}

fn parse_fuse(s: &str) -> Result<bool> {
    parse_switch("fuse", s)
}

/// `on`/`off` toggles (`--fuse`, `--predict`, `--compact`).
fn parse_switch(flag: &str, s: &str) -> Result<bool> {
    Ok(match s {
        "on" => true,
        "off" => false,
        other => bail!("--{flag} takes `on` or `off`, got `{other}`"),
    })
}

/// Parse the fault-injection flags shared by both serve modes into the
/// service config: `--faults off|transient-downloads|chaos` selects a
/// preset, `--fault-seed` / `--fault-permille` tune it, and
/// `--download-retries` bounds the transient-download retry budget.
fn parse_faults(args: &Args, service: &mut ServiceConfig) -> Result<()> {
    let seed = args.u64("fault-seed", 0xFA117)?;
    let permille = args.usize("fault-permille", 100)? as u32;
    service.faults = match args.str("faults", "off").as_str() {
        "off" => FaultSpec::default(),
        "transient-downloads" => FaultSpec::transient(seed, permille),
        "chaos" => FaultSpec::chaos(seed),
        other => bail!("--faults takes off|transient-downloads|chaos, got `{other}`"),
    };
    service.download_retries =
        args.usize("download-retries", service.download_retries as usize)? as u32;
    Ok(())
}

/// Parse the cluster-tier flags shared by both serve modes:
/// `--vnodes V`, `--warm-start on|off`, `--cross-steal-depth D` (0 = off).
/// The fusion salt mirrors the pools' own `--fuse` so routing keys and
/// cache keys agree.
fn parse_cluster(args: &Args, fuse: bool) -> Result<ClusterConfig> {
    let defaults = ClusterConfig::default();
    Ok(ClusterConfig {
        vnodes: args.usize("vnodes", defaults.vnodes)?.max(1),
        warm_start: parse_switch("warm-start", &args.str("warm-start", "on"))?,
        cross_steal_depth: match args.usize("cross-steal-depth", defaults.cross_steal_depth)? {
            0 => usize::MAX,
            d => d,
        },
        fuse,
    })
}

fn cmd_fig2(n: usize) -> Result<()> {
    let mut engine = Engine::new(OverlayConfig::default())?;
    let comp = Composition::vmul_reduce(n);
    let acc = Jit.compile(&engine.fabric, &engine.lib, &comp)?;
    let a = workload::vector(n, 1, -2.0, 2.0);
    let b = workload::vector(n, 2, -2.0, 2.0);
    let mut t = Table::new(
        "Fig. 2 — static-overlay scheduling scenarios (VMUL&Reduce)",
        &["scenario", "pass-through tiles", "total (ms)", "hop cost (ms)"],
    );
    for s in StaticScenario::ALL {
        let r = engine.run(&acc, &[a.clone(), b.clone()], Target::StaticOverlay(s))?;
        t.row(&[
            s.name().into(),
            s.pass_throughs().to_string(),
            ms(r.timing.total()),
            ms(r.timing.hop_s),
        ]);
    }
    let rd = engine.run(&acc, &[a, b], Target::DynamicOverlay)?;
    t.row(&[
        "dynamic (ref)".into(),
        acc.total_hops().to_string(),
        ms(rd.timing.total()),
        ms(rd.timing.hop_s),
    ]);
    print!("{}", t.render());
    Ok(())
}

fn cmd_fig3(n: usize) -> Result<()> {
    let mut engine = Engine::new(OverlayConfig::default())?;
    let comp = Composition::vmul_reduce(n);
    let acc = Jit.compile(&engine.fabric, &engine.lib, &comp)?;
    let a = workload::vector(n, 1, -2.0, 2.0);
    let b = workload::vector(n, 2, -2.0, 2.0);

    let mut table = Table::new(
        &format!("Fig. 3 — VMUL&Reduce total execution time, {} KB", n * 4 / 1024),
        &["target", "total (ms)", "transfer (ms)", "compute (ms)", "vs dynamic"],
    );
    let mut dyn_total = 0.0;
    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    for t in Target::ALL {
        let r = engine.run(&acc, &[a.clone(), b.clone()], t)?;
        let total = r.timing.total();
        if t == Target::DynamicOverlay {
            dyn_total = total;
        }
        rows.push((t.name(), total, r.timing.transfer_s));
    }
    for (name, total, tx) in rows {
        table.row(&[name, ms(total), ms(tx), ms(total - tx), speedup(total, dyn_total)]);
    }
    print!("{}", table.render());
    println!(
        "PR overhead (startup, amortized): {:.3} ms",
        OverlayConfig::default().full_reconfig_seconds() * 1e3
    );
    Ok(())
}

fn cmd_sweep() -> Result<()> {
    let mut engine = Engine::new(OverlayConfig::default())?;
    let mut t = Table::new(
        "T-PR — PR overhead amortization vs data size",
        &["bytes/operand", "dynamic (ms)", "dynamic+PR (ms)", "static-s3 (ms)", "PR amortized?"],
    );
    for &bytes in &workload::SWEEP_SIZES {
        let n = bytes / 4;
        let comp = Composition::vmul_reduce(n);
        let acc = Jit.compile(&engine.fabric, &engine.lib, &comp)?;
        let a = workload::vector(n, 3, -1.0, 1.0);
        let b = workload::vector(n, 4, -1.0, 1.0);
        engine.fabric.reset_full(); // force fresh PR download
        let dyn_run = engine.run(&acc, &[a.clone(), b.clone()], Target::DynamicOverlay)?;
        let st3 = engine.run(&acc, &[a, b], Target::StaticOverlay(StaticScenario::S3))?;
        let d = dyn_run.timing.total();
        let dpr = dyn_run.total_with_reconfig();
        t.row(&[
            bytes.to_string(),
            ms(d),
            ms(dpr),
            ms(st3.timing.total()),
            (dpr < st3.timing.total()).to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let n = args.usize("n", 4096)?;
    let comp = parse_pattern(&args.str("pattern", "vmul-reduce"), n)?;
    let target = parse_target(&args.str("target", "dynamic"))?;
    let seed = args.u64("seed", 42)?;
    let mut coord = Coordinator::new(OverlayConfig::default())?;
    coord.set_fusion(parse_fuse(&args.str("fuse", "off"))?);
    let inputs: Vec<Vec<f32>> = (0..comp.inputs)
        .map(|k| workload::vector(n, seed + k as u64, -2.0, 2.0))
        .collect();
    let resp = coord.submit(&Request { comp, inputs, target })?;
    match resp.run.output {
        jit_overlay::exec::Value::Scalar(s) => println!("result: {s}"),
        jit_overlay::exec::Value::Vector(ref v) => println!(
            "result: vector[{}] = [{:.4}, {:.4}, ... , {:.4}]",
            v.len(),
            v[0],
            v.get(1).copied().unwrap_or(0.0),
            v[v.len() - 1]
        ),
    }
    println!(
        "time: {} ms ({}); jit: {:.3} ms; {}",
        ms(resp.run.timing.total()),
        resp.run.target.name(),
        resp.jit_seconds * 1e3,
        coord.metrics.summary()
    );
    Ok(())
}

fn cmd_verify(n: usize) -> Result<()> {
    let mut engine = Engine::new(OverlayConfig::default())?;
    let comp = Composition::vmul_reduce(n);
    let acc = Jit.compile(&engine.fabric, &engine.lib, &comp)?;
    let (a, b) = (workload::vector(n, 9, -2.0, 2.0), workload::vector(n, 10, -2.0, 2.0));
    let overlay = engine
        .run(&acc, &[a.clone(), b.clone()], Target::DynamicOverlay)?
        .output
        .as_scalar()
        .ok_or_else(|| anyhow!("no scalar"))?;
    let cpu = jit_overlay::exec::cpu::eval(&comp, &[a.clone(), b.clone()])?
        .as_scalar()
        .ok_or_else(|| anyhow!("no scalar"))?;
    println!("overlay interpreter : {overlay}");
    println!("cpu reference       : {cpu}");
    let dir = default_artifacts_dir();
    if dir.join("manifest.tsv").exists() {
        let rt = Runtime::new(&dir).context("loading artifacts")?;
        let name = format!("vmul_reduce_n{n}");
        match rt.execute_scalar(&name, &[a, b]) {
            Ok(p) => {
                println!("pjrt ({name:>18}): {p}");
                let worst = (overlay - p).abs().max((cpu - p).abs());
                println!("max abs deviation   : {worst:e}");
                if worst > (p.abs() * 1e-4).max(1e-2) {
                    bail!("three-way agreement FAILED");
                }
                println!("three-way agreement : OK");
            }
            Err(e) => println!("pjrt: skipped ({e})"),
        }
    } else {
        println!("pjrt: skipped (run `make artifacts`)");
    }
    Ok(())
}

fn cmd_isa() {
    let mut t = Table::new(
        "controller ISA — 42 instructions",
        &["opcode", "mnemonic", "category"],
    );
    for op in Opcode::all() {
        t.row(&[
            format!("{:#04x}", op as u8),
            op.mnemonic().into(),
            format!("{:?}", op.category()),
        ]);
    }
    print!("{}", t.render());
    for c in [Category::Interconnect, Category::Branch, Category::Vector, Category::MemReg] {
        println!("{c:?}: {} opcodes", c.budget());
    }
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let n = args.usize("n", 2048)?;
    let engine = Engine::new(OverlayConfig::default())?;
    let comp = parse_pattern(&args.str("pattern", "vmul-reduce"), n)?;
    let acc = Jit.compile(&engine.fabric, &engine.lib, &comp)?;
    println!("stages: {}", acc.stages().len());
    for (i, (s, a)) in acc.stages().iter().zip(&acc.placement().assignments).enumerate() {
        println!("  stage {i}: {:10} -> tile {} ({:?})", s.op.name(), a.tile, a.class);
    }
    for r in acc.routes() {
        println!("  route: {} -> {} via {:?} ({} hops)", r.from, r.to, r.via, r.hops());
    }
    println!("chunk: {} words; scalar channels: {:?}", acc.chunk(), acc.scalar_channels());
    println!("\nprogram ({} instrs):", acc.program().len());
    print!("{}", asm::format_program(acc.program().instrs()));
    println!("category mix: {:?}", acc.program().category_mix());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    if let Some(addr) = args.get("listen") {
        return cmd_serve_listen(args, &addr.to_string());
    }
    let requests = args.usize("requests", 64)?;
    let n = args.usize("n", 1024)?;
    let workers = args.usize("workers", 1)?;
    let seed = args.u64("seed", 0xF00D)?;
    let mut service = ServiceConfig::with_workers(workers);
    service.queue_capacity = args.usize("queue-capacity", service.queue_capacity)?;
    service.drain_window = args.usize("drain-window", service.drain_window)?;
    service.max_queue_skew = args.usize("skew", service.max_queue_skew)?;
    // --steal-depth 0 disables stealing entirely
    service.steal_min_depth = match args.usize("steal-depth", service.steal_min_depth)? {
        0 => usize::MAX,
        d => d,
    };
    service.fuse = parse_fuse(&args.str("fuse", "off"))?;
    service.predict = parse_switch("predict", &args.str("predict", "off"))?;
    service.compact = parse_switch("compact", &args.str("compact", "off"))?;
    parse_faults(args, &mut service)?;
    let pools = args.usize("pools", 1)?;
    if pools > 1 {
        return cmd_serve_cluster(args, pools, service, requests, n, seed);
    }
    let frontend = args.str("frontend", "direct");
    let sessions = args.usize("sessions", 8)?.max(1);
    let inflight =
        args.usize("inflight", FrontendConfig::default().inflight_per_session)?.max(1);
    let reactors = args.usize("reactors", 1)?.max(1);
    let pool = WorkerPool::new(OverlayConfig::default(), service)?;
    let comps = workload::mixed_compositions(requests, n, seed);
    let reqs: Vec<Request> = comps
        .into_iter()
        .enumerate()
        .map(|(k, comp)| {
            let inputs = workload::request_inputs(&comp, k as u64);
            Request::dynamic(comp, inputs)
        })
        .collect();

    // each arm measures its own wall window: submission through the last
    // drained reply, excluding pool/front-end teardown
    let t0 = std::time::Instant::now();
    let (report, dt) = match frontend.as_str() {
        // legacy single pipelined submitter straight into the pool
        "direct" => {
            let mut pending = Vec::with_capacity(requests);
            for r in reqs {
                pending.push(pool.submit(r)?);
            }
            for rx in pending {
                rx.recv().context("pool worker dropped a reply")??;
            }
            let dt = t0.elapsed().as_secs_f64();
            (pool.shutdown(), dt)
        }
        // thread-per-client: one OS thread + one channel per session
        "threads" => {
            let pool = std::sync::Arc::new(pool);
            let mut buckets: Vec<Vec<Request>> = (0..sessions).map(|_| Vec::new()).collect();
            for (k, r) in reqs.into_iter().enumerate() {
                buckets[k % sessions].push(r);
            }
            let mut joins = Vec::with_capacity(sessions);
            for bucket in buckets {
                let p = pool.clone();
                joins.push(std::thread::spawn(move || -> Result<(), String> {
                    let pending: Vec<_> = bucket
                        .into_iter()
                        .map(|r| p.submit(r).map_err(|e| e.to_string()))
                        .collect::<Result<_, _>>()?;
                    for rx in pending {
                        rx.recv()
                            .map_err(|_| "pool worker dropped a reply".to_string())?
                            .map_err(|e| e.to_string())?;
                    }
                    Ok(())
                }));
            }
            for j in joins {
                let served = j.join().map_err(|_| anyhow!("client thread panicked"))?;
                served.map_err(|e| anyhow!("{e}"))?;
            }
            let dt = t0.elapsed().as_secs_f64();
            let report = std::sync::Arc::try_unwrap(pool)
                .map_err(|_| anyhow!("client thread leaked the pool"))?
                .shutdown();
            (report, dt)
        }
        // reactor: a fixed set of reactor threads multiplexes all sessions
        "reactor" => {
            let pool = std::sync::Arc::new(pool);
            let fcfg = FrontendConfig {
                reactors,
                inflight_per_session: inflight,
                max_inflight: (sessions * inflight).max(1),
            };
            let front = Frontend::new(pool.clone(), fcfg, pool.metrics.clone())
                .map_err(|e| anyhow!("{e}"))?;
            let threads = front.spawn().map_err(|e| anyhow!("{e}"))?;
            let handles: Vec<_> = (0..sessions).map(|_| front.open_session()).collect();
            let mut counts = vec![0usize; sessions];
            for (k, r) in reqs.into_iter().enumerate() {
                handles[k % sessions].submit(r).map_err(|e| anyhow!("{e}"))?;
                counts[k % sessions] += 1;
            }
            for (h, count) in handles.iter().zip(&counts) {
                for _ in 0..*count {
                    h.recv().map_err(|e| anyhow!("{e}"))?;
                }
                h.close();
            }
            let dt = t0.elapsed().as_secs_f64();
            threads.shutdown();
            drop(front);
            let report = std::sync::Arc::try_unwrap(pool)
                .map_err(|_| anyhow!("front end leaked the pool"))?
                .shutdown();
            (report, dt)
        }
        other => bail!("unknown --frontend `{other}` (direct, threads, reactor)"),
    };

    println!(
        "front end: {frontend} (sessions={sessions} inflight/session={inflight} reactors={reactors})"
    );
    for (w, (m, (res, total))) in report
        .per_worker
        .iter()
        .zip(&report.per_worker_residency)
        .enumerate()
    {
        println!("worker {w}: {} residency={res}/{total}", m.summary());
    }
    println!("pool ({workers} workers): {}", report.aggregate.summary());
    println!(
        "served {requests} requests in {:.1} ms ({:.0} req/s wall), {} cached accelerators, {:.2} PR downloads/request",
        dt * 1e3,
        requests as f64 / dt,
        report.cached_accelerators,
        report.aggregate.pr_downloads as f64 / requests.max(1) as f64,
    );
    Ok(())
}

/// `repro serve --pools P` (P > 1): the cluster demo. P identically
/// configured pools behind the consistent-hash router serve the
/// pool-churn stream; with `--churn on` (the default) one extra pool
/// joins warm mid-stream and the first member retires shortly after, so
/// every cluster counter — joins, evacuations, cross-pool steals,
/// warm-start hits — moves in a single run.
fn cmd_serve_cluster(
    args: &Args,
    pools: usize,
    service: ServiceConfig,
    requests: usize,
    n: usize,
    seed: u64,
) -> Result<()> {
    let ccfg = parse_cluster(args, service.fuse)?;
    let churn = parse_switch("churn", &args.str("churn", "on"))?;
    let workers = service.workers;
    let cluster = Cluster::homogeneous(OverlayConfig::default(), service.clone(), ccfg, pools)?;
    let first = cluster.pool_ids()[0];
    let comps = workload::churn_compositions(requests, n, seed);
    let (join_at, retire_at) = (requests / 2, (requests * 3) / 4);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::with_capacity(requests);
    for (k, comp) in comps.iter().enumerate() {
        if churn && k == join_at {
            cluster.join(OverlayConfig::default(), service.clone())?;
        }
        if churn && k == retire_at {
            cluster.retire(first)?;
        }
        let inputs = workload::request_inputs(comp, k as u64);
        pending.push(cluster.submit(Request::dynamic(comp.clone(), inputs))?);
        // opportunistic last-resort rebalance: moves whole tail groups
        // from a deep member to an idle one (usually a no-op)
        cluster.rebalance_once();
    }
    for rx in pending {
        rx.recv().context("cluster pool dropped a reply")??;
    }
    let dt = t0.elapsed().as_secs_f64();
    let report = cluster.shutdown();
    for (id, m) in &report.per_pool {
        println!("pool {id}: {}", m.summary());
    }
    for (i, m) in report.retired.iter().enumerate() {
        println!("retired pool #{i}: {}", m.summary());
    }
    let m = &report.aggregate;
    println!("cluster ({pools} pools x {workers} workers): {}", m.summary());
    println!(
        "served {requests} requests in {:.1} ms ({:.0} req/s wall), {} cached accelerators; \
         joins={} evacuations={} cross-steals={} warm-start-hits={}",
        dt * 1e3,
        requests as f64 / dt,
        report.cached_accelerators,
        m.pool_joins,
        m.pool_evacuations,
        m.cross_pool_steals,
        m.warm_start_hits,
    );
    Ok(())
}

/// `repro serve --listen ADDR`: the socket serving tier. Runs until an
/// authorized remote `SHUTDOWN` frame arrives (`--allow-remote-shutdown 1`
/// — which `repro loadgen --stop-server 1` sends when it is done) or
/// SIGINT/SIGTERM is delivered, then stops accepting, drains open
/// connections within `--drain-ms`, and prints the metrics summary either
/// way.
fn cmd_serve_listen(args: &Args, addr: &str) -> Result<()> {
    let workers = args.usize("workers", 2)?.max(1);
    let reactors = args.usize("reactors", 2)?.max(1);
    let inflight = args.usize("inflight", FrontendConfig::default().inflight_per_session)?.max(1);
    let max_inflight = args.usize("max-inflight", 1024)?.max(1);
    let drain_ms = args.u64("drain-ms", 5000)?;
    let bench = args.get("bench").map(str::to_string);
    let mut service = ServiceConfig::with_workers(workers);
    service.queue_capacity = args.usize("queue-capacity", service.queue_capacity)?;
    service.fuse = parse_fuse(&args.str("fuse", "off"))?;
    service.predict = parse_switch("predict", &args.str("predict", "off"))?;
    service.compact = parse_switch("compact", &args.str("compact", "off"))?;
    parse_faults(args, &mut service)?;
    let defaults = NetConfig::default();
    let net = NetConfig {
        idle_timeout_ms: args.u64("idle-timeout-ms", defaults.idle_timeout_ms)?,
        max_pending_per_conn: args.usize("max-pending", defaults.max_pending_per_conn)?,
        max_n: args.usize("max-n", defaults.max_n)?,
        allow_remote_shutdown: args.str("allow-remote-shutdown", "0") == "1",
        ..defaults
    };

    if !service.faults.is_off() {
        println!("fault injection ACTIVE: {}", args.str("faults", "off"));
    }
    let fcfg = FrontendConfig { reactors, inflight_per_session: inflight, max_inflight };
    let pools = args.usize("pools", 1)?;

    let (aggregate, banner) = if pools > 1 {
        // cluster tier: sessions dispatch through the consistent-hash
        // router instead of a single pool — same Dispatch seam
        let ccfg = parse_cluster(args, service.fuse)?;
        let cluster = std::sync::Arc::new(Cluster::homogeneous(
            OverlayConfig::default(),
            service,
            ccfg,
            pools,
        )?);
        let metrics = cluster.metrics.clone();
        let live = {
            let weak = std::sync::Arc::downgrade(&cluster);
            let fallback = metrics.clone();
            move || {
                weak.upgrade().map(|c| c.snapshot()).unwrap_or_else(|| fallback.snapshot())
            }
        };
        let banner = format!("{pools} pools x {workers} workers");
        let agg = run_listen_tier(
            addr,
            cluster,
            fcfg,
            net,
            metrics,
            &banner,
            drain_ms,
            live,
            |cluster| {
                std::sync::Arc::try_unwrap(cluster)
                    .map(|c| {
                        let report = c.shutdown();
                        for (id, m) in &report.per_pool {
                            println!("pool {id}: {}", m.summary());
                        }
                        for (i, m) in report.retired.iter().enumerate() {
                            println!("retired pool #{i}: {}", m.summary());
                        }
                        report.aggregate
                    })
                    .map_err(|_| "serving tier leaked the cluster".to_string())
            },
        )?;
        (agg, banner)
    } else {
        let pool = std::sync::Arc::new(WorkerPool::new(OverlayConfig::default(), service)?);
        let metrics = pool.metrics.clone();
        let live = {
            let m = metrics.clone();
            move || m.snapshot()
        };
        let banner = format!("{workers} workers");
        let agg = run_listen_tier(
            addr,
            pool,
            fcfg,
            net,
            metrics,
            &banner,
            drain_ms,
            live,
            |pool| {
                std::sync::Arc::try_unwrap(pool)
                    .map(|p| {
                        let report = p.shutdown();
                        if !report.panicked_workers.is_empty() {
                            println!("workers lost to panics: {:?}", report.panicked_workers);
                        }
                        report.aggregate
                    })
                    .map_err(|_| "serving tier leaked the pool".to_string())
            },
        )?;
        (agg, banner)
    };

    let m = &aggregate;
    println!(
        "served {} connections ({} shed, {} wire rejections)",
        m.connections, m.conns_shed, m.net_rejections
    );
    println!("pool ({banner}): {}", m.summary());
    if let Some(name) = bench {
        let mut o = JsonObject::new();
        o.str("group", "serve")
            .int("pools", pools as u64)
            .int("workers", workers as u64)
            .int("reactors", reactors as u64)
            .int("requests", m.requests)
            .int("connections", m.connections)
            .int("rejected", m.rejected)
            .int("cpu_fallbacks", m.cpu_fallbacks)
            .int("pr_downloads", m.pr_downloads)
            .int("prefetch_hits", m.prefetch_hits)
            .int("prefetch_wasted", m.prefetch_wasted)
            .int("migrations", m.migrations)
            .int("download_retries", m.download_retries)
            .int("tiles_quarantined", m.tiles_quarantined)
            .int("workers_restarted", m.workers_restarted)
            .int("jobs_replayed", m.jobs_replayed)
            .int("pool_joins", m.pool_joins)
            .int("pool_evacuations", m.pool_evacuations)
            .int("cross_pool_steals", m.cross_pool_steals)
            .int("warm_start_hits", m.warm_start_hits);
        let path = write_bench_json(&name, &o.finish()).context("writing bench json")?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

/// Shared tail of `serve --listen`, generic over the dispatch backend
/// (one pool, or a cluster of pools): spawn the reactors, bind the
/// socket tier, run until a stop arrives, then drain within the window
/// and hand the backend to `finish` for its final aggregate. On a drain
/// timeout, `live` supplies the best available counters instead.
#[allow(clippy::too_many_arguments)]
fn run_listen_tier<B: Dispatch + Send + Sync + 'static>(
    addr: &str,
    backend: std::sync::Arc<B>,
    fcfg: FrontendConfig,
    net: NetConfig,
    metrics: std::sync::Arc<AtomicMetrics>,
    banner: &str,
    drain_ms: u64,
    live: impl Fn() -> Metrics,
    finish: impl FnOnce(std::sync::Arc<B>) -> Result<Metrics, String> + Send + 'static,
) -> Result<Metrics> {
    let reactors = fcfg.reactors;
    let front = std::sync::Arc::new(
        Frontend::new(backend.clone(), fcfg, metrics.clone()).map_err(|e| anyhow!("{e}"))?,
    );
    let threads = front.spawn().map_err(|e| anyhow!("{e}"))?;
    let server =
        NetServer::bind(addr, front.clone(), net.clone(), metrics).map_err(|e| anyhow!("{e}"))?;
    println!(
        "listening on {} ({reactors} reactors, {banner}, max {} pending/conn)",
        server.local_addr(),
        net.max_pending_per_conn
    );
    if !net.allow_remote_shutdown {
        println!("remote shutdown disabled; stop with Ctrl-C (--allow-remote-shutdown 1 to enable)");
    }

    // run until a stop arrives: an authorized remote SHUTDOWN frame flips
    // the server's stop flag, SIGINT/SIGTERM flips the process-local latch
    sig::install();
    while !sig::requested() && !server.stop_requested() {
        std::thread::sleep(Duration::from_millis(50));
    }
    server.request_stop();
    println!("stop requested; draining (up to {drain_ms} ms) ...");

    // bounded drain: join the server and shut the backend down on a helper
    // thread so one wedged connection cannot hang the process past the
    // drain window. On timeout the live aggregate is still reported.
    let (tx, rx) = std::sync::mpsc::channel();
    let drainer = std::thread::spawn(move || {
        server.join();
        threads.shutdown();
        drop(front);
        let _ = tx.send(finish(backend));
    });
    match rx.recv_timeout(Duration::from_millis(drain_ms)) {
        Ok(report) => {
            let _ = drainer.join();
            report.map_err(|e| anyhow!("{e}"))
        }
        Err(_) => {
            println!("drain window elapsed with connections still open; reporting live counters");
            Ok(live())
        }
    }
}

/// A loadgen client connection: TCP, or a Unix socket via `unix:<path>`.
enum ClientStream {
    Tcp(std::net::TcpStream),
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixStream),
}

impl ClientStream {
    fn connect(addr: &str) -> std::io::Result<ClientStream> {
        if let Some(path) = addr.strip_prefix("unix:") {
            #[cfg(unix)]
            return std::os::unix::net::UnixStream::connect(path).map(ClientStream::Unix);
            #[cfg(not(unix))]
            return Err(std::io::Error::new(
                std::io::ErrorKind::Unsupported,
                "unix sockets are unavailable on this platform",
            ));
        }
        std::net::TcpStream::connect(addr).map(ClientStream::Tcp)
    }

    fn try_clone(&self) -> std::io::Result<ClientStream> {
        match self {
            ClientStream::Tcp(s) => s.try_clone().map(ClientStream::Tcp),
            #[cfg(unix)]
            ClientStream::Unix(s) => s.try_clone().map(ClientStream::Unix),
        }
    }
}

impl Read for ClientStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            ClientStream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            ClientStream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for ClientStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            ClientStream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            ClientStream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            ClientStream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            ClientStream::Unix(s) => s.flush(),
        }
    }
}

/// Per-connection loadgen outcome.
#[derive(Default)]
struct ConnResult {
    latencies_ns: Vec<u64>,
    ok: u64,
    busy: u64,
    err: u64,
}

/// One closed-loop connection: send, await the reply, repeat.
fn loadgen_closed(
    addr: &str,
    conn_id: u64,
    requests: usize,
    n: u32,
    pattern: &str,
    max_frame: usize,
) -> Result<ConnResult, String> {
    let mut stream = ClientStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    let mut out = ConnResult::default();
    for k in 0..requests as u64 {
        let req = ClientMsg::Request {
            id: k,
            n,
            seed: conn_id * 10_000 + k,
            pattern: pattern.to_string(),
        };
        let t0 = Instant::now();
        write_frame(&mut stream, &req.to_frame()).map_err(|e| format!("send: {e}"))?;
        let payload = read_frame(&mut stream, max_frame)
            .map_err(|e| format!("recv: {e}"))?
            .ok_or("server closed mid-run")?;
        out.latencies_ns.push(t0.elapsed().as_nanos() as u64);
        match ServerMsg::decode(&payload).map_err(|e| format!("decode: {e}"))? {
            ServerMsg::Ok { id, .. } if id == k => out.ok += 1,
            ServerMsg::Ok { id, .. } => return Err(format!("reply id {id} for request {k}")),
            ServerMsg::Busy { .. } => out.busy += 1,
            ServerMsg::Err { .. } => out.err += 1,
        }
    }
    Ok(out)
}

/// One open-loop connection: a writer fires at a fixed interval without
/// waiting, a reader pairs replies to send times by wire id. The reader
/// only blocks on the socket while `answered < sent` — the server answers
/// every complete frame exactly once, so a reply is then guaranteed in
/// flight and the blocking read always returns.
fn loadgen_open(
    addr: &str,
    conn_id: u64,
    interval: Duration,
    duration: Duration,
    n: u32,
    pattern: &str,
    max_frame: usize,
) -> Result<ConnResult, String> {
    let reader_stream = ClientStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    let mut writer_stream = reader_stream.try_clone().map_err(|e| format!("clone: {e}"))?;
    let sent: Arc<Mutex<std::collections::HashMap<u64, Instant>>> =
        Arc::new(Mutex::new(std::collections::HashMap::new()));
    let sent_total = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let pattern = pattern.to_string();

    let writer = {
        let (sent, sent_total) = (sent.clone(), sent_total.clone());
        std::thread::spawn(move || -> Result<(), String> {
            let t0 = Instant::now();
            let mut id = 0u64;
            while t0.elapsed() < duration {
                let req = ClientMsg::Request {
                    id,
                    n,
                    seed: conn_id * 10_000 + id,
                    pattern: pattern.clone(),
                };
                sent.lock().unwrap().insert(id, Instant::now());
                write_frame(&mut writer_stream, &req.to_frame())
                    .map_err(|e| format!("send: {e}"))?;
                // counted only after the frame is fully on the wire: the
                // reader treats every counted send as an owed reply
                sent_total.fetch_add(1, std::sync::atomic::Ordering::Release);
                id += 1;
                std::thread::sleep(interval);
            }
            Ok(())
        })
    };

    let mut out = ConnResult::default();
    let mut stream = reader_stream;
    let mut answered = 0u64;
    loop {
        if answered < sent_total.load(std::sync::atomic::Ordering::Acquire) {
            match read_frame(&mut stream, max_frame).map_err(|e| format!("recv: {e}"))? {
                Some(p) => {
                    record_open_reply(&p, &sent, &mut out)?;
                    answered += 1;
                }
                None => return Err("server closed mid-run".into()),
            }
        } else if writer.is_finished() {
            writer.join().map_err(|_| "writer panicked".to_string())??;
            // the writer may have sent one last frame between the two
            // checks above; the total is final now, so drain to it
            while answered < sent_total.load(std::sync::atomic::Ordering::Acquire) {
                match read_frame(&mut stream, max_frame).map_err(|e| format!("recv: {e}"))? {
                    Some(p) => {
                        record_open_reply(&p, &sent, &mut out)?;
                        answered += 1;
                    }
                    None => return Err("server closed before draining replies".into()),
                }
            }
            break;
        } else {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    Ok(out)
}

fn record_open_reply(
    payload: &[u8],
    sent: &Mutex<std::collections::HashMap<u64, Instant>>,
    out: &mut ConnResult,
) -> Result<(), String> {
    let msg = ServerMsg::decode(payload).map_err(|e| format!("decode: {e}"))?;
    let id = match &msg {
        ServerMsg::Ok { id, .. } | ServerMsg::Err { id, .. } | ServerMsg::Busy { id } => *id,
    };
    let t0 = sent
        .lock()
        .unwrap()
        .remove(&id)
        .ok_or_else(|| format!("reply for unknown id {id}"))?;
    out.latencies_ns.push(t0.elapsed().as_nanos() as u64);
    match msg {
        ServerMsg::Ok { .. } => out.ok += 1,
        ServerMsg::Busy { .. } => out.busy += 1,
        ServerMsg::Err { .. } => out.err += 1,
    }
    Ok(())
}

fn percentile(sorted_ns: &[u64], p: f64) -> u64 {
    if sorted_ns.is_empty() {
        return 0;
    }
    let idx = ((sorted_ns.len() - 1) as f64 * p).round() as usize;
    sorted_ns[idx.min(sorted_ns.len() - 1)]
}

/// `repro loadgen`: closed- or open-loop socket load against
/// `repro serve --listen`, reporting p50/p95/p99 and writing
/// `BENCH_<name>.json` per the repo convention.
fn cmd_loadgen(args: &Args) -> Result<()> {
    let addr = args.str("addr", "127.0.0.1:7700");
    let conns = args.usize("conns", 64)?.max(1);
    let requests = args.usize("requests", 32)?.max(1);
    let n = args.usize("n", 1024)? as u32;
    let pattern = args.str("pattern", "vmul-reduce");
    let mode = args.str("mode", "closed");
    let rate = args.usize("rate", 200)?.max(1); // open loop: req/s per conn
    let duration = Duration::from_millis(args.u64("duration-ms", 2000)?);
    let bench = args.str("bench", "service");
    // vector replies carry n floats; keep the client cap comfortably above
    let max_frame = (n as usize * 4 + 4096).max(1 << 20);

    let t_wall = Instant::now();
    let mut joins = Vec::with_capacity(conns);
    for c in 0..conns as u64 {
        let (addr, pattern, mode) = (addr.clone(), pattern.clone(), mode.clone());
        joins.push(std::thread::Builder::new().stack_size(256 * 1024).spawn(
            move || -> Result<ConnResult, String> {
                match mode.as_str() {
                    "closed" => loadgen_closed(&addr, c, requests, n, &pattern, max_frame),
                    "open" => {
                        let interval = Duration::from_nanos(1_000_000_000 / rate as u64);
                        loadgen_open(&addr, c, interval, duration, n, &pattern, max_frame)
                    }
                    other => Err(format!("unknown --mode `{other}` (closed, open)")),
                }
            },
        )?);
    }
    let mut all = ConnResult::default();
    let mut conn_errors = 0usize;
    let mut first_error = String::new();
    for j in joins {
        match j.join().map_err(|_| anyhow!("loadgen connection thread panicked"))? {
            Ok(r) => {
                all.latencies_ns.extend(r.latencies_ns);
                all.ok += r.ok;
                all.busy += r.busy;
                all.err += r.err;
            }
            Err(e) => {
                conn_errors += 1;
                if first_error.is_empty() {
                    first_error = e;
                }
            }
        }
    }
    let wall_s = t_wall.elapsed().as_secs_f64();

    if args.str("stop-server", "0") == "1" {
        let mut s = ClientStream::connect(&addr).context("connect for shutdown")?;
        write_frame(&mut s, &ClientMsg::Shutdown.to_frame()).context("send shutdown")?;
    }

    all.latencies_ns.sort_unstable();
    let total = all.ok + all.busy + all.err;
    let (p50, p95, p99) = (
        percentile(&all.latencies_ns, 0.50),
        percentile(&all.latencies_ns, 0.95),
        percentile(&all.latencies_ns, 0.99),
    );
    let mean = if all.latencies_ns.is_empty() {
        0.0
    } else {
        all.latencies_ns.iter().sum::<u64>() as f64 / all.latencies_ns.len() as f64
    };
    println!("loadgen: mode={mode} conns={conns} pattern={pattern} n={n} addr={addr}");
    println!(
        "replies: {total} ({} ok, {} busy, {} err) in {:.2} s ({:.0} req/s); conn errors: {conn_errors}",
        all.ok, all.busy, all.err, wall_s, total as f64 / wall_s
    );
    println!(
        "latency: p50 {} p95 {} p99 {} mean {}",
        jit_overlay::benchkit::fmt_ns(p50 as f64),
        jit_overlay::benchkit::fmt_ns(p95 as f64),
        jit_overlay::benchkit::fmt_ns(p99 as f64),
        jit_overlay::benchkit::fmt_ns(mean),
    );
    if conn_errors > 0 {
        println!("first connection error: {first_error}");
    }

    let mut o = JsonObject::new();
    o.str("group", "loadgen")
        .str("mode", &mode)
        .str("pattern", &pattern)
        .str("addr", &addr)
        .int("conns", conns as u64)
        .int("n", n as u64)
        .int("replies", total)
        .int("ok", all.ok)
        .int("busy", all.busy)
        .int("err", all.err)
        .int("conn_errors", conn_errors as u64)
        .num("wall_s", wall_s)
        .num("req_per_s", total as f64 / wall_s)
        .int("p50_ns", p50)
        .int("p95_ns", p95)
        .int("p99_ns", p99)
        .num("mean_ns", mean);
    let path = write_bench_json(&bench, &o.finish()).context("writing bench json")?;
    println!("wrote {}", path.display());
    if total == 0 {
        bail!("loadgen received no replies ({conn_errors} connection errors: {first_error})");
    }
    Ok(())
}

const USAGE: &str = "usage: repro <fig2|fig3|sweep|run|verify|isa|inspect|serve|loadgen> [--flag value ...]
  run:   --pattern P --n LEN --target dynamic|static|arm --fuse on|off
  serve: --requests K --workers N --n LEN --seed S (multi-fabric pool)
         --fuse on|off (JIT fusion pass + fallback ladder; default off)
         --predict on|off (speculative prefetch of the predicted next
           accelerator in idle windows; default off)
         --compact on|off (online defragmentation in idle windows; default off)
         --drain-window W (burst size; 1 = FIFO)  --queue-capacity C (backpressure)
         --steal-depth D (work-stealing threshold; 0 = off)  --skew S (spill threshold)
         --frontend direct|threads|reactor (session layer; default direct)
         --sessions S --inflight I --reactors R (threads/reactor front ends)
         --faults off|transient-downloads|chaos (fault injection; default off)
           with --fault-seed S --fault-permille M --download-retries R
         --pools P (P > 1: cluster of P pools behind a consistent-hash ring)
           with --vnodes V (ring points per pool) --warm-start on|off
           --cross-steal-depth D (cross-pool steal threshold; 0 = off)
           --churn on|off (mid-stream pool join + retire; blocking mode only)
         --listen ADDR (socket tier; ADDR is ip:port or unix:/path)
           with --reactors R --workers N --max-pending P --idle-timeout-ms T
           --max-n N --allow-remote-shutdown 0|1
           --drain-ms D (bounded drain on SIGINT/SIGTERM/shutdown; default 5000)
           --bench NAME (write BENCH_<NAME>.json with the final counters)
  loadgen: --addr ADDR --conns C --mode closed|open --pattern P --n LEN
           closed: --requests K (per connection, one outstanding)
           open:   --rate R (req/s per conn) --duration-ms D
           --bench NAME (BENCH_<NAME>.json; $BENCH_JSON_DIR or CWD)
           --stop-server 1 (send SHUTDOWN when done)
  see crate docs / README for per-command flags";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        println!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "fig2" => cmd_fig2(args.usize("n", 4096)?)?,
        "fig3" => cmd_fig3(args.usize("n", 4096)?)?,
        "sweep" => cmd_sweep()?,
        "run" => cmd_run(&args)?,
        "verify" => cmd_verify(args.usize("n", 4096)?)?,
        "isa" => cmd_isa(),
        "inspect" => cmd_inspect(&args)?,
        "serve" => cmd_serve(&args)?,
        "loadgen" => cmd_loadgen(&args)?,
        other => bail!("unknown command `{other}`\n{USAGE}"),
    }
    Ok(())
}
