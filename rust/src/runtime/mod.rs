//! The PJRT runtime: loads AOT-compiled HLO artifacts and executes them on
//! the request path.
//!
//! Python (JAX + Pallas) runs **once** at build time (`make artifacts`);
//! this module is the only thing that touches the results. HLO *text* is
//! the interchange format (jax ≥ 0.5 emits 64-bit instruction ids in its
//! protos, which xla_extension 0.5.1 rejects; the text parser reassigns
//! ids — see /opt/xla-example/README.md).
//!
//! Executables are compiled lazily and cached per variant name; the cache
//! is the Rust analogue of the overlay's bitstream residency — compiling an
//! HLO module is our "synthesis", running it is "execution", and the cache
//! is what makes JIT assembly cheap on repeat requests.

pub mod manifest;

pub use manifest::{Manifest, TensorSpec, VariantEntry};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use crate::error::{Error, Result};

/// A loaded PJRT runtime bound to one artifacts directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest in `dir`.
    pub fn new(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PJRT CPU client: {e}")))?;
        Ok(Runtime { client, dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Compile (or fetch from cache) the executable for `name`.
    fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let path = self.manifest.hlo_path(&self.dir, name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Artifact(format!("non-UTF8 path {path:?}")))?,
        )
        .map_err(|e| Error::Artifact(format!("parse {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {name}: {e}")))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute variant `name` on f32 input vectors.
    ///
    /// Inputs must match the manifest's declared shapes (rank-1 f32).
    /// Returns the artifact's outputs as f32 vectors.
    pub fn execute(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let entry = self.manifest.get(name)?.clone();
        if inputs.len() != entry.inputs.len() {
            return Err(Error::Runtime(format!(
                "{name}: expected {} inputs, got {}",
                entry.inputs.len(),
                inputs.len()
            )));
        }
        for (k, (spec, v)) in entry.inputs.iter().zip(inputs).enumerate() {
            if spec.elements() != v.len() {
                return Err(Error::Runtime(format!(
                    "{name}: input {k} needs {} elements, got {}",
                    spec.elements(),
                    v.len()
                )));
            }
            if spec.dtype != "f32" {
                return Err(Error::Runtime(format!(
                    "{name}: input {k} dtype {} unsupported by the f32 host path",
                    spec.dtype
                )));
            }
        }

        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|v| xla::Literal::vec1(v))
            .collect();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Runtime(format!("execute {name}: {e}")))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("readback {name}: {e}")))?;

        // artifacts are lowered with return_tuple=True
        let parts: Vec<xla::Literal> = if entry.outputs.len() == 1 {
            vec![lit
                .to_tuple1()
                .map_err(|e| Error::Runtime(format!("untuple {name}: {e}")))?]
        } else {
            lit.to_tuple()
                .map_err(|e| Error::Runtime(format!("untuple {name}: {e}")))?
        };
        parts
            .into_iter()
            .map(|p| {
                p.to_vec::<f32>()
                    .map_err(|e| Error::Runtime(format!("readback {name}: {e}")))
            })
            .collect()
    }

    /// Execute and return the single scalar a reduce-style variant yields.
    pub fn execute_scalar(&self, name: &str, inputs: &[Vec<f32>]) -> Result<f32> {
        let outs = self.execute(name, inputs)?;
        outs.first()
            .and_then(|v| v.first().copied())
            .ok_or_else(|| Error::Runtime(format!("{name}: empty output")))
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("dir", &self.dir)
            .field("variants", &self.manifest.variants.len())
            .field("cached", &self.cached())
            .finish()
    }
}

/// Default artifacts directory (crate-root `artifacts/`, overridable with
/// `$JIT_OVERLAY_ARTIFACTS`).
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("JIT_OVERLAY_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = default_artifacts_dir();
        if dir.join("manifest.tsv").exists() {
            Some(Runtime::new(dir).unwrap())
        } else {
            // same loud marker as tests/pjrt_roundtrip.rs: a skip must be
            // visible, never silent
            println!("skipped: artifacts missing (run make artifacts)");
            None
        }
    }

    #[test]
    fn headline_artifact_computes_dot_product() {
        let Some(rt) = runtime() else { return };
        let n = rt.manifest().paper_n;
        let a: Vec<f32> = (0..n).map(|i| (i % 37) as f32 / 7.0).collect();
        let b: Vec<f32> = (0..n).map(|i| 0.25 + (i % 11) as f32).collect();
        let want: f64 = a.iter().zip(&b).map(|(x, y)| (*x as f64) * (*y as f64)).sum();
        let name = rt.manifest().headline.clone();
        let got = rt.execute_scalar(&name, &[a, b]).unwrap();
        assert!(
            ((got as f64 - want) / want).abs() < 1e-5,
            "got {got}, want {want}"
        );
    }

    #[test]
    fn executable_cache_hits_on_second_call() {
        let Some(rt) = runtime() else { return };
        let name = rt.manifest().headline.clone();
        let n = rt.manifest().paper_n;
        let z = vec![0.0f32; n];
        rt.execute_scalar(&name, &[z.clone(), z.clone()]).unwrap();
        assert_eq!(rt.cached(), 1);
        rt.execute_scalar(&name, &[z.clone(), z]).unwrap();
        assert_eq!(rt.cached(), 1);
    }

    #[test]
    fn wrong_arity_rejected() {
        let Some(rt) = runtime() else { return };
        let name = rt.manifest().headline.clone();
        assert!(rt.execute(&name, &[vec![0.0; 8]]).is_err());
    }

    #[test]
    fn wrong_shape_rejected() {
        let Some(rt) = runtime() else { return };
        let name = rt.manifest().headline.clone();
        assert!(rt
            .execute(&name, &[vec![0.0; 8], vec![0.0; 8]])
            .is_err());
    }

    #[test]
    fn map_variant_roundtrip() {
        let Some(rt) = runtime() else { return };
        if rt.manifest().get("map_sqrt_n4096").is_err() {
            return;
        }
        let x: Vec<f32> = (0..4096).map(|i| i as f32).collect();
        let out = rt.execute("map_sqrt_n4096", &[x.clone()]).unwrap();
        assert_eq!(out[0].len(), 4096);
        for (i, (got, want)) in out[0].iter().zip(x.iter().map(|v| v.sqrt())).enumerate() {
            assert!((got - want).abs() < 1e-4, "i={i}: {got} vs {want}");
        }
    }
}
