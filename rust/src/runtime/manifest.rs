//! The AOT artifact manifest (written by `python/compile/aot.py`).
//!
//! Two files are emitted at build time: `manifest.json` (human/tooling) and
//! `manifest.tsv`, the line-oriented form this module parses — the runtime
//! builds fully offline and carries no JSON dependency. Format:
//!
//! ```text
//! # jit-overlay artifact manifest v1
//! headline<TAB>vmul_reduce_n4096
//! paper_n<TAB>4096
//! variant<TAB><name>\t<pattern>\t<file>\t<in specs>\t<out specs>\t<sha256>
//! ```
//!
//! where a spec list is `;`-separated `shape:dtype` entries, shapes being
//! `x`-separated dims (`4096:f32`, `2x8:f32`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// Tensor shape + dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(s: &str) -> Result<TensorSpec> {
        let (shape_s, dtype) = s
            .split_once(':')
            .ok_or_else(|| Error::Parse(format!("bad tensor spec `{s}`")))?;
        let shape = shape_s
            .split('x')
            .map(|d| {
                d.parse::<usize>()
                    .map_err(|_| Error::Parse(format!("bad dim `{d}` in `{s}`")))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec { shape, dtype: dtype.to_string() })
    }

    fn parse_list(s: &str) -> Result<Vec<TensorSpec>> {
        if s.is_empty() {
            return Ok(Vec::new());
        }
        s.split(';').map(TensorSpec::parse).collect()
    }
}

/// One AOT-compiled variant.
#[derive(Debug, Clone)]
pub struct VariantEntry {
    pub name: String,
    pub pattern: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub file: String,
    pub sha256: String,
}

/// The manifest document.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub headline: String,
    pub paper_n: usize,
    pub variants: Vec<VariantEntry>,
}

impl Manifest {
    /// Parse the TSV manifest text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut headline = String::new();
        let mut paper_n = 0usize;
        let mut variants = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim_end();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split('\t').collect();
            match fields[0] {
                "headline" if fields.len() == 2 => headline = fields[1].to_string(),
                "paper_n" if fields.len() == 2 => {
                    paper_n = fields[1]
                        .parse()
                        .map_err(|_| Error::Parse(format!("line {}: bad paper_n", lineno + 1)))?
                }
                "variant" if fields.len() == 7 => variants.push(VariantEntry {
                    name: fields[1].to_string(),
                    pattern: fields[2].to_string(),
                    file: fields[3].to_string(),
                    inputs: TensorSpec::parse_list(fields[4])?,
                    outputs: TensorSpec::parse_list(fields[5])?,
                    sha256: fields[6].to_string(),
                }),
                other => {
                    return Err(Error::Parse(format!(
                        "line {}: unrecognized record `{other}` ({} fields)",
                        lineno + 1,
                        fields.len()
                    )))
                }
            }
        }
        if headline.is_empty() || variants.is_empty() {
            return Err(Error::Parse("manifest missing headline or variants".into()));
        }
        Ok(Manifest { headline, paper_n, variants })
    }

    /// Load `manifest.tsv` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Self::parse(&text)
    }

    /// Index variants by name.
    pub fn by_name(&self) -> HashMap<&str, &VariantEntry> {
        self.variants.iter().map(|v| (v.name.as_str(), v)).collect()
    }

    /// Find a variant by name.
    pub fn get(&self, name: &str) -> Result<&VariantEntry> {
        self.variants
            .iter()
            .find(|v| v.name == name)
            .ok_or_else(|| Error::Artifact(format!("no variant `{name}` in manifest")))
    }

    /// Absolute path of a variant's HLO file.
    pub fn hlo_path(&self, dir: &Path, name: &str) -> Result<PathBuf> {
        Ok(dir.join(&self.get(name)?.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# jit-overlay artifact manifest v1
headline\tvmul_reduce_n4096
paper_n\t4096
variant\tvmul_reduce_n4096\tvmul_reduce\tvmul_reduce_n4096.hlo.txt\t4096:f32;4096:f32\t1:f32\tdeadbeef
variant\tmap_sqrt_n4096\tmap\tmap_sqrt_n4096.hlo.txt\t4096:f32\t4096:f32\tcafe
";

    #[test]
    fn parse_and_lookup() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.headline, "vmul_reduce_n4096");
        assert_eq!(m.paper_n, 4096);
        assert_eq!(m.variants.len(), 2);
        let v = m.get("vmul_reduce_n4096").unwrap();
        assert_eq!(v.inputs.len(), 2);
        assert_eq!(v.inputs[0].elements(), 4096);
        assert_eq!(v.outputs[0].elements(), 1);
        assert!(m.get("nope").is_err());
        assert_eq!(m.by_name().len(), 2);
    }

    #[test]
    fn multidim_spec() {
        let t = TensorSpec::parse("2x8:f32").unwrap();
        assert_eq!(t.shape, vec![2, 8]);
        assert_eq!(t.elements(), 16);
    }

    #[test]
    fn bad_records_rejected() {
        assert!(Manifest::parse("headline\tx\nvariant\tonly\tthree\tfields\n").is_err());
        assert!(Manifest::parse("").is_err());
        assert!(Manifest::parse("paper_n\tnotanumber\nheadline\tx\n").is_err());
        assert!(TensorSpec::parse("nodtype").is_err());
        assert!(TensorSpec::parse("ax2:f32").is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // integration sanity: if artifacts/ exists, it must parse.
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.tsv").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.variants.len() >= 10);
            let headline = m.headline.clone();
            assert!(m.get(&headline).is_ok());
        }
    }
}
