//! The bitstream library: every operator pre-synthesized for every region
//! class it fits, plus the variant-counting study (T-BITS).
//!
//! The paper's first limitation of the *static* approach is that "all
//! variants of programming patterns must be synthesized": a static overlay
//! needs one bitstream per (pattern × placement) because operator positions
//! are frozen, while the dynamic overlay needs only one bitstream per
//! (operator × region class) and composes placements at run time.
//! [`BitstreamLibrary::static_variants_for`] vs
//! [`BitstreamLibrary::dynamic_variants_for`] quantify that reduction.

use std::collections::HashMap;

use super::{Bitstream, Footprint, OperatorKind, RegionClass};
use crate::config::OverlayConfig;
use crate::error::{Error, Result};

/// Immutable registry of pre-synthesized bitstreams.
#[derive(Debug, Clone)]
pub struct BitstreamLibrary {
    by_key: HashMap<(OperatorKind, RegionClass), Bitstream>,
}

impl BitstreamLibrary {
    /// "Synthesize" the full catalogue: each operator in each class whose
    /// budget holds it. (Large regions can host small operators too — that
    /// flexibility is exactly what the fragmentation study prices.)
    pub fn standard(cfg: &OverlayConfig) -> BitstreamLibrary {
        let mut by_key = HashMap::new();
        for op in OperatorKind::ALL {
            let fp = Footprint::for_operator(op);
            for class in [RegionClass::Small, RegionClass::Large] {
                if fp.fits(&class.budget()) {
                    by_key.insert((op, class), Bitstream::synthesize(op, class, cfg));
                }
            }
        }
        BitstreamLibrary { by_key }
    }

    /// Number of distinct bitstreams in the library.
    pub fn len(&self) -> usize {
        self.by_key.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_key.is_empty()
    }

    /// Exact lookup.
    pub fn get(&self, op: OperatorKind, class: RegionClass) -> Option<&Bitstream> {
        self.by_key.get(&(op, class))
    }

    /// The bitstream for `op` in the *smallest* class available, or in
    /// `class` exactly when `exact` is set.
    pub fn select(&self, op: OperatorKind, class: RegionClass) -> Result<&Bitstream> {
        self.get(op, class).ok_or_else(|| Error::NoBitstream {
            op: op.name().to_string(),
            class,
        })
    }

    /// Smallest region class that can host `op` (library-backed).
    pub fn preferred_class(&self, op: OperatorKind) -> Result<RegionClass> {
        for class in [RegionClass::Small, RegionClass::Large] {
            if self.by_key.contains_key(&(op, class)) {
                return Ok(class);
            }
        }
        Err(Error::NoBitstream { op: op.name().to_string(), class: RegionClass::Large })
    }

    /// Operators hosted only by large regions.
    pub fn large_only_ops(&self) -> Vec<OperatorKind> {
        OperatorKind::ALL
            .iter()
            .copied()
            .filter(|&op| {
                !self.by_key.contains_key(&(op, RegionClass::Small))
                    && self.by_key.contains_key(&(op, RegionClass::Large))
            })
            .collect()
    }

    // ---- T-BITS: bitstream-count study ------------------------------------

    /// Bitstreams a **dynamic** overlay needs for a pattern using `ops`:
    /// one per distinct (operator, preferred class) — placement is decided
    /// at run time, so position does not multiply the count.
    pub fn dynamic_variants_for(&self, ops: &[OperatorKind]) -> usize {
        let mut distinct = std::collections::HashSet::new();
        for &op in ops {
            if let Ok(class) = self.preferred_class(op) {
                distinct.insert((op, class));
            }
        }
        distinct.len()
    }

    /// Bitstreams a **static** flow needs: every operator pre-placed at
    /// every tile position it might occupy — `|ops| × positions` (one
    /// partial bitstream per PR region per operator, since PR bitstreams
    /// are location-specific in the Xilinx flow).
    pub fn static_variants_for(&self, ops: &[OperatorKind], positions: usize) -> usize {
        let mut distinct = std::collections::HashSet::new();
        for &op in ops {
            if self.preferred_class(op).is_ok() {
                distinct.insert(op);
            }
        }
        distinct.len() * positions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lib() -> BitstreamLibrary {
        BitstreamLibrary::standard(&OverlayConfig::default())
    }

    #[test]
    fn standard_library_covers_all_ops() {
        let l = lib();
        for op in OperatorKind::ALL {
            assert!(l.preferred_class(op).is_ok(), "{op:?} missing");
        }
    }

    #[test]
    fn small_ops_present_in_both_classes() {
        let l = lib();
        assert!(l.get(OperatorKind::Mul, RegionClass::Small).is_some());
        assert!(l.get(OperatorKind::Mul, RegionClass::Large).is_some());
    }

    #[test]
    fn transcendentals_are_large_only() {
        let l = lib();
        let large_only = l.large_only_ops();
        for op in [OperatorKind::Sqrt, OperatorKind::Sin, OperatorKind::Log] {
            assert!(large_only.contains(&op), "{op:?}");
            assert!(l.get(op, RegionClass::Small).is_none());
        }
    }

    #[test]
    fn select_reports_structured_error() {
        let l = lib();
        let err = l.select(OperatorKind::Sin, RegionClass::Small).unwrap_err();
        assert!(err.is_capacity());
    }

    #[test]
    fn dynamic_beats_static_variant_count() {
        let l = lib();
        let ops = [OperatorKind::Mul, OperatorKind::AccSum];
        let dynamic = l.dynamic_variants_for(&ops);
        let static_ = l.static_variants_for(&ops, 9); // 3×3 overlay positions
        assert_eq!(dynamic, 2);
        assert_eq!(static_, 18);
        assert!(dynamic < static_);
    }

    #[test]
    fn duplicate_ops_counted_once() {
        let l = lib();
        let ops = [OperatorKind::Mul, OperatorKind::Mul, OperatorKind::Mul];
        assert_eq!(l.dynamic_variants_for(&ops), 1);
        assert_eq!(l.static_variants_for(&ops, 4), 4);
    }

    #[test]
    fn library_size_is_ops_plus_small_duplicates() {
        let l = lib();
        // every op fits Large; small ops additionally fit Small.
        let large_count = OperatorKind::ALL.len();
        let small_count = OperatorKind::ALL
            .iter()
            .filter(|&&op| Footprint::for_operator(op).fits(&RegionClass::Small.budget()))
            .count();
        assert_eq!(l.len(), large_count + small_count);
    }
}
