//! Resource footprints and PR-region classes.
//!
//! The paper sizes 1/4 of PR regions at **8 DSP / 964 FF / 1228 LUT** (for
//! sqrtf, sin, cos, log, ...) and the rest at **4 DSP / 156 FF / 270 LUT**.
//! A bitstream fits a region iff its footprint fits the region's budget;
//! the slack is *internal fragmentation* — the T-FRAG study quantifies it.

use super::OperatorKind;

/// FPGA resource triple.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct Footprint {
    pub dsp: u32,
    pub ff: u32,
    pub lut: u32,
}

impl Footprint {
    pub const fn new(dsp: u32, ff: u32, lut: u32) -> Footprint {
        Footprint { dsp, ff, lut }
    }

    /// Component-wise `self ≤ other`.
    pub fn fits(&self, budget: &Footprint) -> bool {
        self.dsp <= budget.dsp && self.ff <= budget.ff && self.lut <= budget.lut
    }

    /// Component-wise sum — the footprint of two operators sharing one
    /// region (the fusion pass: head + tail datapaths side by side).
    pub fn plus(&self, other: &Footprint) -> Footprint {
        Footprint::new(self.dsp + other.dsp, self.ff + other.ff, self.lut + other.lut)
    }

    /// Fraction of the budget left unused, averaged over the three resource
    /// kinds — the internal-fragmentation metric of the T-FRAG study.
    pub fn fragmentation_in(&self, budget: &Footprint) -> f64 {
        fn slack(used: u32, cap: u32) -> f64 {
            if cap == 0 {
                0.0
            } else {
                1.0 - (used.min(cap) as f64 / cap as f64)
            }
        }
        (slack(self.dsp, budget.dsp) + slack(self.ff, budget.ff) + slack(self.lut, budget.lut))
            / 3.0
    }

    /// Per-operator footprint, from Xilinx floating-point operator LogiCORE
    /// resource tables (Virtex-7 speedgrade-2 orders of magnitude).
    pub fn for_operator(op: OperatorKind) -> Footprint {
        use OperatorKind::*;
        match op {
            // small-region residents
            Add | Sub => Footprint::new(2, 120, 200),
            Mul => Footprint::new(3, 140, 130),
            Max | Min | Relu => Footprint::new(0, 60, 110),
            Neg | Abs => Footprint::new(0, 30, 40),
            Square => Footprint::new(3, 140, 130),
            FilterGt => Footprint::new(0, 90, 160),
            Select => Footprint::new(0, 70, 120),
            AccSum => Footprint::new(2, 130, 210),
            Route => Footprint::new(0, 8, 12),
            // large-region residents (iterative / polynomial datapaths)
            Div | Recip => Footprint::new(4, 520, 800),
            Sqrt => Footprint::new(4, 540, 760),
            Sin | Cos => Footprint::new(8, 900, 1100),
            Log | Exp | Tanh => Footprint::new(7, 930, 1180),
        }
    }
}

/// The two PR-region provisioning classes of the paper's overlay.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RegionClass {
    /// 4 DSP / 156 FF / 270 LUT.
    Small,
    /// 8 DSP / 964 FF / 1228 LUT.
    Large,
}

impl RegionClass {
    /// The paper's published budget for this class.
    pub fn budget(self) -> Footprint {
        match self {
            RegionClass::Small => Footprint::new(4, 156, 270),
            RegionClass::Large => Footprint::new(8, 964, 1228),
        }
    }

    /// The smallest class whose budget holds `fp`, if any.
    pub fn smallest_fitting(fp: &Footprint) -> Option<RegionClass> {
        if fp.fits(&RegionClass::Small.budget()) {
            Some(RegionClass::Small)
        } else if fp.fits(&RegionClass::Large.budget()) {
            Some(RegionClass::Large)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_budgets() {
        assert_eq!(RegionClass::Small.budget(), Footprint::new(4, 156, 270));
        assert_eq!(RegionClass::Large.budget(), Footprint::new(8, 964, 1228));
    }

    #[test]
    fn transcendentals_need_large_regions() {
        for op in [
            OperatorKind::Sqrt,
            OperatorKind::Sin,
            OperatorKind::Cos,
            OperatorKind::Log,
        ] {
            let fp = Footprint::for_operator(op);
            assert_eq!(RegionClass::smallest_fitting(&fp), Some(RegionClass::Large), "{op:?}");
        }
    }

    #[test]
    fn arithmetic_fits_small_regions() {
        for op in [
            OperatorKind::Add,
            OperatorKind::Mul,
            OperatorKind::AccSum,
            OperatorKind::Route,
        ] {
            let fp = Footprint::for_operator(op);
            assert_eq!(RegionClass::smallest_fitting(&fp), Some(RegionClass::Small), "{op:?}");
        }
    }

    #[test]
    fn every_operator_fits_somewhere() {
        for op in OperatorKind::ALL {
            assert!(
                RegionClass::smallest_fitting(&Footprint::for_operator(op)).is_some(),
                "{op:?} fits no region class"
            );
        }
    }

    #[test]
    fn fragmentation_bounds() {
        let b = RegionClass::Large.budget();
        assert_eq!(Footprint::new(8, 964, 1228).fragmentation_in(&b), 0.0);
        let tiny = Footprint::new(0, 0, 0).fragmentation_in(&b);
        assert!((tiny - 1.0).abs() < 1e-12);
        // small op in a large region wastes most of it — the paper's
        // motivation for non-uniform sizing.
        let abs_in_large = Footprint::for_operator(OperatorKind::Abs).fragmentation_in(&b);
        let abs_in_small =
            Footprint::for_operator(OperatorKind::Abs)
                .fragmentation_in(&RegionClass::Small.budget());
        assert!(abs_in_large > abs_in_small);
    }

    #[test]
    fn fits_is_componentwise() {
        let budget = Footprint::new(4, 156, 270);
        assert!(!Footprint::new(5, 1, 1).fits(&budget)); // dsp over
        assert!(!Footprint::new(1, 200, 1).fits(&budget)); // ff over
        assert!(!Footprint::new(1, 1, 300).fits(&budget)); // lut over
        assert!(Footprint::new(4, 156, 270).fits(&budget)); // exact
    }
}
