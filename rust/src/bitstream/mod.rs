//! Pre-synthesized operator bitstreams and the library that manages them.
//!
//! In the paper, operators (mul, add, sqrtf, sin, ...) are synthesized once
//! per PR-region class and stored as partial bitstreams; the runtime
//! downloads them into tiles. Here a [`Bitstream`] is a descriptor carrying
//! the operator semantics, its resource [`Footprint`], its latency/II
//! pipeline characteristics, and a deterministic pseudo-payload standing in
//! for the configuration frames (its length drives the ICAP timing model).

pub mod footprint;
pub mod library;

pub use footprint::{Footprint, RegionClass};
pub use library::BitstreamLibrary;

/// Operator semantics a PR tile can host.
///
/// `Route` is the "empty" configuration: the tile only forwards data
/// (a pass-through tile in Fig. 2's static scenarios).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OperatorKind {
    // binary stream operators
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Min,
    // unary stream operators
    Neg,
    Abs,
    Recip,
    Square,
    Relu,
    Sqrt,
    Sin,
    Cos,
    Log,
    Exp,
    Tanh,
    // stateful stream operators
    /// Running-sum accumulator (the Reduce pattern's adder with feedback).
    AccSum,
    /// Threshold filter: forwards x (or 0) based on `x > t`.
    FilterGt,
    /// Two-input select driven by a predicate stream (branch commit).
    Select,
    /// Pure routing / pass-through (no operator resident).
    Route,
}

impl OperatorKind {
    /// All real operators (everything but `Route`).
    pub const ALL: [OperatorKind; 21] = [
        OperatorKind::Add,
        OperatorKind::Sub,
        OperatorKind::Mul,
        OperatorKind::Div,
        OperatorKind::Max,
        OperatorKind::Min,
        OperatorKind::Neg,
        OperatorKind::Abs,
        OperatorKind::Recip,
        OperatorKind::Square,
        OperatorKind::Relu,
        OperatorKind::Sqrt,
        OperatorKind::Sin,
        OperatorKind::Cos,
        OperatorKind::Log,
        OperatorKind::Exp,
        OperatorKind::Tanh,
        OperatorKind::AccSum,
        OperatorKind::FilterGt,
        OperatorKind::Select,
        OperatorKind::Route,
    ];

    /// Number of data inputs the operator consumes per element.
    pub fn arity(self) -> usize {
        use OperatorKind::*;
        match self {
            Add | Sub | Mul | Div | Max | Min => 2,
            FilterGt => 2, // value stream + (usually broadcast) threshold
            Select => 3,   // predicate + two speculated streams
            AccSum => 1,
            Route => 1,
            _ => 1,
        }
    }

    /// Does the operator carry state across elements (reduce-style)?
    pub fn is_stateful(self) -> bool {
        matches!(self, OperatorKind::AccSum)
    }

    /// Library name (matches the Python kernel op names where applicable).
    pub fn name(self) -> &'static str {
        use OperatorKind::*;
        match self {
            Add => "add",
            Sub => "sub",
            Mul => "mul",
            Div => "div",
            Max => "max",
            Min => "min",
            Neg => "neg",
            Abs => "abs",
            Recip => "recip",
            Square => "square",
            Relu => "relu",
            Sqrt => "sqrt",
            Sin => "sin",
            Cos => "cos",
            Log => "log",
            Exp => "exp",
            Tanh => "tanh",
            AccSum => "acc_sum",
            FilterGt => "filter_gt",
            Select => "select",
            Route => "route",
        }
    }

    /// Parse a library name back into an operator.
    pub fn from_name(s: &str) -> Option<OperatorKind> {
        OperatorKind::ALL.iter().copied().find(|o| o.name() == s)
    }

    /// Apply the operator to one streamed element (simulation semantics).
    ///
    /// `state` is the tile accumulator for stateful ops. Binary ops take
    /// `(a, b)`; unary ops ignore `b`; `Select` is handled by the
    /// interconnect (it needs three streams) and must not be applied here.
    pub fn apply(self, a: f32, b: f32, state: &mut f32) -> f32 {
        use OperatorKind::*;
        match self {
            Add => a + b,
            Sub => a - b,
            Mul => a * b,
            Div => a / b,
            Max => a.max(b),
            Min => a.min(b),
            Neg => -a,
            Abs => a.abs(),
            Recip => 1.0 / a,
            Square => a * a,
            Relu => a.max(0.0),
            Sqrt => a.sqrt(),
            Sin => a.sin(),
            Cos => a.cos(),
            Log => a.ln(),
            Exp => a.exp(),
            Tanh => a.tanh(),
            AccSum => {
                *state += a;
                *state
            }
            FilterGt => {
                if a > b {
                    a
                } else {
                    0.0
                }
            }
            Select | Route => a,
        }
    }

    /// Pipeline latency in fabric cycles (fill cost of the tile stage).
    ///
    /// Small arithmetic closes in a few stages; the iterative/CORDIC-style
    /// transcendentals the large regions host are deep pipelines. Values
    /// follow Xilinx LogiCORE floating-point operator datasheet orders.
    pub fn latency_cycles(self) -> u64 {
        use OperatorKind::*;
        match self {
            Add | Sub | Max | Min => 3,
            Mul => 4,
            Div | Recip => 14,
            Neg | Abs | Relu | Route => 1,
            Square => 4,
            Sqrt => 16,
            Sin | Cos => 20,
            Log | Exp | Tanh => 22,
            AccSum => 3,
            FilterGt => 2,
            Select => 1,
        }
    }

    /// Initiation interval (elements accepted per cycle is 1/II).
    /// All library operators are fully pipelined (II=1).
    pub fn initiation_interval(self) -> u64 {
        1
    }
}

/// A pre-synthesized partial bitstream for one operator in one region class.
#[derive(Debug, Clone, PartialEq)]
pub struct Bitstream {
    pub op: OperatorKind,
    pub class: RegionClass,
    /// Fused tail operator sharing the region (`None` for the standard
    /// library; `Some` only for on-demand fused descriptors).
    pub tail: Option<OperatorKind>,
    pub footprint: Footprint,
    /// Configuration-frame byte count (drives ICAP download time).
    pub frame_bytes: usize,
    /// Stable content hash (identity for the residency cache).
    pub id: u64,
}

impl Bitstream {
    /// Deterministically derive the descriptor for (op, class).
    pub fn synthesize(
        op: OperatorKind,
        class: RegionClass,
        cfg: &crate::config::OverlayConfig,
    ) -> Bitstream {
        let footprint = Footprint::for_operator(op);
        Bitstream {
            op,
            class,
            tail: None,
            footprint,
            frame_bytes: Self::frame_bytes_for(class, cfg),
            id: Self::content_hash(op.name(), class),
        }
    }

    /// Derive the descriptor for a fused `tail(op(..))` pair in one region.
    ///
    /// Fused descriptors are synthesized on demand (the PR manager asks for
    /// them when a fused assignment misses residency) and never enter the
    /// standard library catalogue: the fusion pass only produces a pair
    /// after checking the combined footprint fits `class`, so the catalogue
    /// stays the paper's per-(operator × class) inventory.
    pub fn synthesize_fused(
        op: OperatorKind,
        tail: OperatorKind,
        class: RegionClass,
        cfg: &crate::config::OverlayConfig,
    ) -> Bitstream {
        let footprint = Footprint::for_operator(op).plus(&Footprint::for_operator(tail));
        Bitstream {
            op,
            class,
            tail: Some(tail),
            footprint,
            frame_bytes: Self::frame_bytes_for(class, cfg),
            id: Self::content_hash(&format!("{}+{}", op.name(), tail.name()), class),
        }
    }

    fn frame_bytes_for(class: RegionClass, cfg: &crate::config::OverlayConfig) -> usize {
        match class {
            RegionClass::Small => cfg.small_bitstream_bytes,
            RegionClass::Large => cfg.large_bitstream_bytes,
        }
    }

    /// FNV-1a over (name, class) — stable across runs, collision-free for
    /// the 21×2 catalogue plus the fused "head+tail" names.
    fn content_hash(name: &str, class: RegionClass) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for byte in name.bytes().chain(std::iter::once(match class {
            RegionClass::Small => b's',
            RegionClass::Large => b'l',
        })) {
            h ^= byte as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OverlayConfig;

    #[test]
    fn names_roundtrip() {
        for op in OperatorKind::ALL {
            assert_eq!(OperatorKind::from_name(op.name()), Some(op));
        }
        assert_eq!(OperatorKind::from_name("bogus"), None);
    }

    #[test]
    fn apply_matches_float_semantics() {
        let mut s = 0.0;
        assert_eq!(OperatorKind::Mul.apply(3.0, 4.0, &mut s), 12.0);
        assert_eq!(OperatorKind::Relu.apply(-2.0, 0.0, &mut s), 0.0);
        assert_eq!(OperatorKind::FilterGt.apply(5.0, 3.0, &mut s), 5.0);
        assert_eq!(OperatorKind::FilterGt.apply(2.0, 3.0, &mut s), 0.0);
    }

    #[test]
    fn acc_sum_accumulates_across_elements() {
        let mut s = 0.0;
        for v in [1.0, 2.0, 3.0] {
            OperatorKind::AccSum.apply(v, 0.0, &mut s);
        }
        assert_eq!(s, 6.0);
    }

    #[test]
    fn transcendentals_are_deep_pipelines() {
        assert!(OperatorKind::Sqrt.latency_cycles() > OperatorKind::Mul.latency_cycles());
        assert!(OperatorKind::Log.latency_cycles() >= OperatorKind::Sin.latency_cycles());
    }

    #[test]
    fn all_operators_fully_pipelined() {
        for op in OperatorKind::ALL {
            assert_eq!(op.initiation_interval(), 1);
        }
    }

    #[test]
    fn synthesize_is_deterministic_and_distinct() {
        let cfg = OverlayConfig::default();
        let a = Bitstream::synthesize(OperatorKind::Mul, RegionClass::Small, &cfg);
        let b = Bitstream::synthesize(OperatorKind::Mul, RegionClass::Small, &cfg);
        let c = Bitstream::synthesize(OperatorKind::Mul, RegionClass::Large, &cfg);
        assert_eq!(a, b);
        assert_ne!(a.id, c.id);
        assert!(c.frame_bytes > a.frame_bytes);
    }

    #[test]
    fn synthesize_fused_sums_footprints_and_hashes_distinctly() {
        let cfg = OverlayConfig::default();
        let f = Bitstream::synthesize_fused(
            OperatorKind::Neg,
            OperatorKind::Abs,
            RegionClass::Small,
            &cfg,
        );
        assert_eq!(f.tail, Some(OperatorKind::Abs));
        assert_eq!(f.footprint, Footprint::new(0, 60, 80));
        let plain = Bitstream::synthesize(OperatorKind::Neg, RegionClass::Small, &cfg);
        assert_ne!(f.id, plain.id);
        // order matters: neg∘abs and abs∘neg are different datapaths
        let swapped = Bitstream::synthesize_fused(
            OperatorKind::Abs,
            OperatorKind::Neg,
            RegionClass::Small,
            &cfg,
        );
        assert_ne!(f.id, swapped.id);
    }
}
