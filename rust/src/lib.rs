//! # jit-overlay
//!
//! A full-system reproduction of **“A Dynamic Overlay Supporting Just-In-Time
//! Assembly to Construct Customized Hardware Accelerators”** (Aklah, Ma,
//! Andrews — 2016).
//!
//! The paper replaces the FPGA CAD path (synthesis, place & route) with
//! run-time composition: pre-synthesized operator bitstreams are downloaded
//! into partially-reconfigurable tiles embedded in a programmable mesh
//! overlay, and a 42-instruction controller assembles them into custom
//! accelerators *just in time*. This crate implements that system end to
//! end:
//!
//! * [`isa`] — the 42-instruction controller ISA (22 interconnect, 6
//!   branch, 2 vector, 12 mem/reg), with binary codec and assembler;
//! * [`overlay`] — the cycle-approximate fabric simulator (tiles, BRAMs,
//!   N-E-S-W interconnect, controller interpreter);
//! * [`bitstream`] — the pre-synthesized operator library with the paper's
//!   published large/small PR-region footprints;
//! * [`place`] / [`route`] — dynamic contiguous placement vs. the static
//!   scenarios of Fig. 2, and mesh stream routing;
//! * [`reconfig`] — the PR download model (ICAP bandwidth, residency cache)
//!   reproducing the ~1.25 ms overhead of Fig. 3;
//! * [`patterns`] / [`jit`] — the programmer-facing parallel-pattern API
//!   and the JIT compiler that turns compositions into controller programs;
//! * [`timing`] — analytic models for the four evaluation targets (dynamic
//!   overlay, static overlay, custom HLS, ARM software);
//! * [`exec`] — the execution engine joining simulator timing with PJRT
//!   numerics;
//! * [`runtime`] — the PJRT/XLA artifact loader (AOT-compiled JAX/Pallas
//!   kernels; Python never runs at request time);
//! * [`coordinator`] — the run-time service: bounded request queues, an
//!   LRU-capped sharded accelerator cache, reconfiguration-aware batching,
//!   metrics — scaled out by [`coordinator::pool`], a multi-fabric worker
//!   pool whose affinity scheduler routes each composition to the worker
//!   where its accelerator is already compiled and resident, whose workers
//!   drain their queues in scheduler-reordered bursts, and whose idle
//!   workers steal whole composition groups from the deepest queue
//!   (`repro serve --workers N --drain-window W --steal-depth D`), and
//!   fronted by [`coordinator::frontend`], an event-driven session layer
//!   multiplexing many clients over a shared completion queue
//!   (`repro serve --frontend reactor --sessions S --inflight I`), and
//!   exposed over TCP/Unix sockets by [`coordinator::net`], a socket
//!   serving tier speaking the length-prefixed [`coordinator::wire`]
//!   protocol with per-connection backpressure and idle shedding
//!   (`repro serve --listen ADDR --reactors N`, load-driven by
//!   `repro loadgen`), and sharded across many pools by
//!   [`coordinator::cluster`], a consistent-hash ring router (splitmix64
//!   virtual nodes, so a pool join/leave re-homes only ~1/N of keys) with
//!   warm-start program shipping to joining pools, backlog evacuation on
//!   retire, and cross-pool group migration as the last steal tier
//!   (`repro serve --pools P`);
//! * [`testkit`] — deterministic service-layer test harness: a virtual
//!   clock plus a scripted-latency engine shim, so ordering, fairness and
//!   starvation properties are proven without sleeps;
//! * [`faults`] — the deterministic fault-injection plane (seeded,
//!   schedule-driven PR-download / tile-execution / worker-panic faults)
//!   behind the self-healing recovery ladder: download retry, tile
//!   quarantine + re-placement, worker supervision with burst replay
//!   (`repro serve --faults transient-downloads|chaos`);
//! * [`predict`] / [`place::compact`] — speculative maintenance run in
//!   quiet drain windows: a per-worker Markov predictor prefetches the
//!   likely next accelerator's bitstreams into idle healthy tiles, and an
//!   online defragmenter migrates small-footprint residents off the scarce
//!   Large regions (`repro serve --predict on --compact on`).
//!
//! The crate is dependency-free by design: PRNG ([`workload`]), bench
//! harness ([`benchkit`]), error type ([`error`]) and CLI parsing are all
//! in-tree, so `cargo build` works fully offline.

pub mod benchkit;
pub mod bitstream;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod exec;
pub mod faults;
pub mod isa;
pub mod jit;
pub mod overlay;
pub mod patterns;
pub mod place;
pub mod predict;
pub mod reconfig;
pub mod report;
pub mod route;
pub mod runtime;
pub mod testkit;
pub mod timing;
pub mod workload;

pub use config::{ClusterConfig, FrontendConfig, NetConfig, OverlayConfig, ServiceConfig};
pub use error::{Error, Result};
pub use faults::{DownloadFault, ExecFault, FaultPlane, FaultSpec};
