//! Partial-reconfiguration management: the ICAP download model and the
//! operator residency cache.
//!
//! The dynamic overlay's "only penalty" (Fig. 3) is PR time — ~1.250 ms to
//! populate the 3×3 fabric, incurred at startup or when the JIT assembles a
//! *different* accelerator. The [`PrManager`] prices downloads through the
//! configured ICAP bandwidth and skips tiles whose resident operator
//! already matches (residency caching) — the mechanism that amortizes JIT
//! assembly across repeated requests.

use crate::bitstream::BitstreamLibrary;
use crate::error::{Error, Result};
use crate::faults::{DownloadFault, FaultPlane};
use crate::overlay::Fabric;
use crate::place::Placement;

/// Outcome of applying a reconfiguration plan.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ReconfigStats {
    /// Tiles whose PR region was written.
    pub downloads: usize,
    /// Downloads that overwrote a *different* resident operator — the
    /// residency-thrash signal per fabric (cold loads write empty regions
    /// and do not count).
    pub replaced: usize,
    /// Tiles skipped because the right operator was already resident.
    pub cache_hits: usize,
    /// Configuration bytes moved through the ICAP.
    pub bytes: usize,
    /// Wall-clock seconds spent reconfiguring.
    pub seconds: f64,
    /// Transfers re-armed after a transient download fault (each aborted
    /// attempt re-pays its frame bytes through the ICAP — the physical
    /// cost of the retry rung).
    pub retries: usize,
}

impl ReconfigStats {
    /// Residency hit rate in [0, 1] for this plan application.
    pub fn hit_rate(&self) -> f64 {
        let total = self.downloads + self.cache_hits;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }
}

/// The PR download engine + residency cache.
#[derive(Debug, Clone, Default)]
pub struct PrManager {
    /// Cumulative stats across the manager's lifetime (metrics surface).
    pub lifetime: ReconfigStats,
}

impl PrManager {
    /// Realize `placement` on `fabric`: download every stage's bitstream
    /// into its assigned tile, skipping already-resident operators.
    ///
    /// Returns per-call stats; accumulates lifetime stats.
    pub fn apply(
        &mut self,
        fabric: &mut Fabric,
        lib: &BitstreamLibrary,
        placement: &Placement,
    ) -> Result<ReconfigStats> {
        self.apply_with(fabric, lib, placement, &FaultPlane::NoFaults, 0)
    }

    /// Like [`PrManager::apply`], but every ICAP transfer is arbitrated by
    /// the fault plane. A [`DownloadFault::Transient`] aborts one attempt
    /// (the frame bytes are re-paid and the transfer re-armed, up to
    /// `retry_budget` re-arms per assignment before giving up); a
    /// [`DownloadFault::Permanent`] quarantines the target tile and
    /// surfaces [`Error::TileFault`] so the coordinator re-places
    /// elsewhere. With [`FaultPlane::NoFaults`] this is byte-identical to
    /// the plain path.
    pub fn apply_with(
        &mut self,
        fabric: &mut Fabric,
        lib: &BitstreamLibrary,
        placement: &Placement,
        faults: &FaultPlane,
        retry_budget: u32,
    ) -> Result<ReconfigStats> {
        let mut stats = ReconfigStats::default();
        let outcome = Self::transfer_all(fabric, lib, placement, faults, retry_budget, &mut stats);
        // bytes already moved through the ICAP (including aborted attempts
        // on a faulted run) are billed to lifetime whether or not the plan
        // completed — the hardware cost was paid either way
        stats.seconds = stats.bytes as f64 / fabric.cfg.clocks.icap_bytes_per_sec;
        self.lifetime.downloads += stats.downloads;
        self.lifetime.replaced += stats.replaced;
        self.lifetime.cache_hits += stats.cache_hits;
        self.lifetime.bytes += stats.bytes;
        self.lifetime.seconds += stats.seconds;
        self.lifetime.retries += stats.retries;
        outcome.map(|()| stats)
    }

    /// The per-assignment download loop, accumulating into `stats` so the
    /// caller can bill lifetime counters even when a fault aborts the plan.
    fn transfer_all(
        fabric: &mut Fabric,
        lib: &BitstreamLibrary,
        placement: &Placement,
        faults: &FaultPlane,
        retry_budget: u32,
        stats: &mut ReconfigStats,
    ) -> Result<()> {
        for a in &placement.assignments {
            let tile = &fabric.tiles[a.tile];
            // a residency hit needs the whole fused pair to match: a plain
            // `mul` resident cannot stand in for `mul+acc_sum` (or vice
            // versa) — they are different datapaths.
            if tile.resident == Some(a.op) && tile.resident_tail == a.tail {
                stats.cache_hits += 1;
                continue;
            }
            let replacing = tile.resident.is_some();
            // fused pairs are synthesized on demand (they never enter the
            // standard catalogue); plain assignments come from the library
            let owned;
            let bs = match a.tail {
                None => lib.select(a.op, tile.class)?,
                Some(t) => {
                    owned = crate::bitstream::Bitstream::synthesize_fused(
                        a.op,
                        t,
                        tile.class,
                        &fabric.cfg,
                    );
                    &owned
                }
            };
            let mut rearms: u32 = 0;
            loop {
                match faults.next_download() {
                    Some(DownloadFault::Permanent) => {
                        fabric.quarantine(a.tile);
                        return Err(Error::TileFault { tile: a.tile, permanent: true });
                    }
                    Some(DownloadFault::Transient) => {
                        // the aborted transfer still moved its frame
                        // through the ICAP before failing CRC
                        stats.bytes += bs.frame_bytes;
                        stats.retries += 1;
                        if rearms >= retry_budget {
                            return Err(Error::Reconfig(format!(
                                "tile {}: transient download fault persisted past {retry_budget} retries",
                                a.tile
                            )));
                        }
                        rearms += 1;
                    }
                    None => {
                        fabric.load_bitstream(a.tile, bs)?;
                        if replacing {
                            stats.replaced += 1;
                        }
                        stats.downloads += 1;
                        stats.bytes += bs.frame_bytes;
                        break;
                    }
                }
            }
        }
        Ok(())
    }

    /// Execute one compaction move: download the resident (head plus fused
    /// tail) into the destination tile, then clear the source region. The
    /// download is priced like any other ICAP transfer — and arbitrated by
    /// the fault plane, so a compaction in a chaos run retries and
    /// quarantines exactly like the request path. On fault the source is
    /// left intact (the resident was never lost; at worst the destination
    /// holds a redundant copy that eviction reclaims).
    pub fn migrate(
        &mut self,
        fabric: &mut Fabric,
        lib: &BitstreamLibrary,
        mv: &crate::place::compact::TileMove,
        faults: &FaultPlane,
        retry_budget: u32,
    ) -> Result<ReconfigStats> {
        let placement = Placement {
            assignments: vec![crate::place::Assignment {
                op: mv.op,
                tile: mv.to,
                class: fabric.tiles[mv.to].class,
                tail: mv.tail,
            }],
        };
        let stats = self.apply_with(fabric, lib, &placement, faults, retry_budget)?;
        fabric.clear_region(mv.from)?;
        Ok(stats)
    }

    /// Evict every resident operator not used by `placement` (frees tiles
    /// for the next accelerator; models the paper's "only active operators
    /// resident" density argument).
    pub fn evict_unused(&mut self, fabric: &mut Fabric, placement: &Placement) {
        let keep: std::collections::HashSet<usize> =
            placement.assignments.iter().map(|a| a.tile).collect();
        for t in 0..fabric.tiles.len() {
            if !keep.contains(&t) && fabric.tiles[t].resident.is_some() {
                fabric.tiles[t].resident = None;
                fabric.tiles[t].resident_tail = None;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitstream::OperatorKind;
    use crate::config::OverlayConfig;
    use crate::place::DynamicPlacer;

    fn setup() -> (Fabric, BitstreamLibrary, PrManager) {
        let cfg = OverlayConfig::default();
        let lib = BitstreamLibrary::standard(&cfg);
        (Fabric::new(cfg).unwrap(), lib, PrManager::default())
    }

    fn vmul_placement(f: &Fabric, lib: &BitstreamLibrary) -> Placement {
        DynamicPlacer
            .place(f, lib, &[OperatorKind::Mul, OperatorKind::AccSum])
            .unwrap()
    }

    #[test]
    fn first_apply_downloads_everything() {
        let (mut f, lib, mut pr) = setup();
        let p = vmul_placement(&f, &lib);
        let s = pr.apply(&mut f, &lib, &p).unwrap();
        assert_eq!(s.downloads, 2);
        assert_eq!(s.cache_hits, 0);
        assert!(s.seconds > 0.0);
    }

    #[test]
    fn second_apply_hits_cache() {
        let (mut f, lib, mut pr) = setup();
        let p = vmul_placement(&f, &lib);
        pr.apply(&mut f, &lib, &p).unwrap();
        let s2 = pr.apply(&mut f, &lib, &p).unwrap();
        assert_eq!(s2.downloads, 0);
        assert_eq!(s2.cache_hits, 2);
        assert_eq!(s2.seconds, 0.0);
    }

    #[test]
    fn full_fabric_reconfig_costs_about_1_25_ms() {
        let (mut f, lib, mut pr) = setup();
        // fill every tile with a fresh operator
        let ops: Vec<OperatorKind> = vec![
            OperatorKind::Add,
            OperatorKind::Sub,
            OperatorKind::Mul,
            OperatorKind::Max, // large tile 3 hosts a small op — still a large-frame download? no: frame size follows region class
            OperatorKind::Min,
            OperatorKind::Abs,
            OperatorKind::Neg,
            OperatorKind::Square,
            OperatorKind::Relu,
        ];
        let placement = crate::place::Placement {
            assignments: (0..9)
                .map(|t| crate::place::Assignment {
                    op: ops[t],
                    tile: t,
                    class: f.tiles[t].class,
                    tail: None,
                })
                .collect(),
        };
        let s = pr.apply(&mut f, &lib, &placement).unwrap();
        assert_eq!(s.downloads, 9);
        assert!((s.seconds - 1.25e-3).abs() < 0.1e-3, "got {}", s.seconds);
    }

    #[test]
    fn evict_unused_frees_other_tiles() {
        let (mut f, lib, mut pr) = setup();
        let p = vmul_placement(&f, &lib);
        pr.apply(&mut f, &lib, &p).unwrap();
        // occupy one more tile, then evict relative to p
        let extra = lib
            .get(OperatorKind::Abs, f.tiles[5].class)
            .unwrap()
            .clone();
        f.load_bitstream(5, &extra).unwrap();
        pr.evict_unused(&mut f, &p);
        assert!(f.tiles[5].resident.is_none());
        for a in &p.assignments {
            assert!(f.tiles[a.tile].resident.is_some());
        }
    }

    #[test]
    fn replacing_download_counts_as_thrash() {
        let (mut f, lib, mut pr) = setup();
        let p1 = vmul_placement(&f, &lib);
        let s1 = pr.apply(&mut f, &lib, &p1).unwrap();
        assert_eq!(s1.replaced, 0, "cold loads are not thrash");
        // force a different operator onto the same tiles
        let p2 = Placement {
            assignments: p1
                .assignments
                .iter()
                .map(|a| crate::place::Assignment { op: OperatorKind::Add, ..*a })
                .collect(),
        };
        let s2 = pr.apply(&mut f, &lib, &p2).unwrap();
        assert_eq!(s2.downloads, 2);
        assert_eq!(s2.replaced, 2);
        assert_eq!(pr.lifetime.replaced, 2);
    }

    #[test]
    fn fused_assignment_is_its_own_residency_entry() {
        let (mut f, lib, mut pr) = setup();
        let fused = Placement {
            assignments: vec![crate::place::Assignment {
                op: OperatorKind::Mul,
                tile: 3, // large tile: mul+acc_sum needs the large budget
                class: f.tiles[3].class,
                tail: Some(OperatorKind::AccSum),
            }],
        };
        let cold = pr.apply(&mut f, &lib, &fused).unwrap();
        assert_eq!(cold.downloads, 1);
        assert_eq!(f.tiles[3].resident, Some(OperatorKind::Mul));
        assert_eq!(f.tiles[3].resident_tail, Some(OperatorKind::AccSum));
        // same fused pair again: residency hit
        let warm = pr.apply(&mut f, &lib, &fused).unwrap();
        assert_eq!(warm.cache_hits, 1);
        assert_eq!(warm.downloads, 0);
        // a *plain* mul on the same tile is a different datapath: re-download
        let plain = Placement {
            assignments: vec![crate::place::Assignment {
                tail: None,
                ..fused.assignments[0]
            }],
        };
        let s = pr.apply(&mut f, &lib, &plain).unwrap();
        assert_eq!(s.downloads, 1);
        assert_eq!(s.replaced, 1);
        assert_eq!(f.tiles[3].resident_tail, None);
    }

    #[test]
    fn hit_rate_reflects_residency() {
        let (mut f, lib, mut pr) = setup();
        let p = vmul_placement(&f, &lib);
        let cold = pr.apply(&mut f, &lib, &p).unwrap();
        assert_eq!(cold.hit_rate(), 0.0);
        let warm = pr.apply(&mut f, &lib, &p).unwrap();
        assert_eq!(warm.hit_rate(), 1.0);
        assert_eq!(ReconfigStats::default().hit_rate(), 0.0);
    }

    #[test]
    fn transient_download_fault_retries_within_budget() {
        use crate::faults::{FaultPlane, FaultSpec};
        let (mut f, lib, mut pr) = setup();
        let p = vmul_placement(&f, &lib);
        // the first download aborts once, then succeeds on the re-arm
        let plane = FaultPlane::from_spec(FaultSpec {
            transient_downloads: vec![1],
            ..FaultSpec::default()
        });
        let s = pr.apply_with(&mut f, &lib, &p, &plane, 3).unwrap();
        assert_eq!(s.downloads, 2);
        assert_eq!(s.retries, 1);
        assert_eq!(pr.lifetime.retries, 1);
        // the aborted attempt re-paid its frame: 3 transfers' bytes for 2 downloads
        let clean = PrManager::default()
            .apply(&mut Fabric::new(f.cfg.clone()).unwrap(), &lib, &p)
            .unwrap();
        assert!(s.bytes > clean.bytes);
        assert_eq!(f.tiles[p.assignments[0].tile].resident, Some(OperatorKind::Mul));
    }

    #[test]
    fn transient_fault_past_budget_gives_up() {
        use crate::faults::{FaultPlane, FaultSpec};
        let (mut f, lib, mut pr) = setup();
        let p = vmul_placement(&f, &lib);
        // every attempt at the first assignment faults: ordinals 1..=3
        let plane = FaultPlane::from_spec(FaultSpec {
            transient_downloads: vec![1, 2, 3],
            ..FaultSpec::default()
        });
        let err = pr.apply_with(&mut f, &lib, &p, &plane, 2).unwrap_err();
        assert!(matches!(err, crate::error::Error::Reconfig(_)), "got {err:?}");
        assert_eq!(f.quarantined_tiles(), 0, "transient faults never quarantine");
    }

    #[test]
    fn permanent_download_fault_quarantines_the_tile() {
        use crate::faults::{FaultPlane, FaultSpec};
        let (mut f, lib, mut pr) = setup();
        let p = vmul_placement(&f, &lib);
        let plane = FaultPlane::from_spec(FaultSpec {
            permanent_downloads: vec![1],
            ..FaultSpec::default()
        });
        let err = pr.apply_with(&mut f, &lib, &p, &plane, 3).unwrap_err();
        let victim = p.assignments[0].tile;
        let hit = matches!(
            err,
            crate::error::Error::TileFault { tile, permanent: true } if tile == victim
        );
        assert!(hit, "got {err:?}");
        assert_eq!(f.quarantined_tiles(), 1);
        assert!(!f.free_tiles().contains(&victim));
    }

    #[test]
    fn lifetime_is_billed_even_when_the_plan_faults_out() {
        use crate::faults::{FaultPlane, FaultSpec};
        let (mut f, lib, mut pr) = setup();
        let p = vmul_placement(&f, &lib);
        let plane = FaultPlane::from_spec(FaultSpec {
            transient_downloads: vec![1, 2, 3],
            ..FaultSpec::default()
        });
        pr.apply_with(&mut f, &lib, &p, &plane, 2).unwrap_err();
        // budget 2 allows 3 attempts; every aborted one re-paid its frame
        assert_eq!(pr.lifetime.retries, 3);
        assert!(pr.lifetime.bytes > 0);
        assert!(pr.lifetime.seconds > 0.0);
        assert_eq!(pr.lifetime.downloads, 0, "nothing completed");
    }

    #[test]
    fn migrate_moves_the_resident_and_clears_the_source() {
        let (mut f, lib, mut pr) = setup();
        // a small-footprint op parked on Large tile 3: the compactor's case
        let bs = lib.get(OperatorKind::Add, f.tiles[3].class).unwrap().clone();
        f.load_bitstream(3, &bs).unwrap();
        let mv = crate::place::compact::TileMove {
            from: 3,
            to: 0,
            op: OperatorKind::Add,
            tail: None,
        };
        let s = pr
            .migrate(&mut f, &lib, &mv, &FaultPlane::NoFaults, 0)
            .unwrap();
        assert_eq!(s.downloads, 1);
        assert_eq!(f.tiles[3].resident, None, "source cleared");
        assert_eq!(f.tiles[0].resident, Some(OperatorKind::Add));
        // a faulted migration must leave the source resident intact
        let mv_back = crate::place::compact::TileMove { from: 0, to: 2, ..mv };
        let plane = crate::faults::FaultPlane::from_spec(crate::faults::FaultSpec {
            transient_downloads: vec![1, 2],
            ..crate::faults::FaultSpec::default()
        });
        pr.migrate(&mut f, &lib, &mv_back, &plane, 1).unwrap_err();
        assert_eq!(f.tiles[0].resident, Some(OperatorKind::Add), "source survives the fault");
    }

    #[test]
    fn lifetime_stats_accumulate() {
        let (mut f, lib, mut pr) = setup();
        let p = vmul_placement(&f, &lib);
        pr.apply(&mut f, &lib, &p).unwrap();
        pr.apply(&mut f, &lib, &p).unwrap();
        assert_eq!(pr.lifetime.downloads, 2);
        assert_eq!(pr.lifetime.cache_hits, 2);
    }
}
