//! System configuration: fabric geometry, tile sizing mix, clocks, and the
//! reconfiguration-cost model.
//!
//! Defaults reproduce the paper's testbed: a 3×3 overlay on a Virtex-7,
//! 1/4 of PR regions "large" (8 DSP / 964 FF / 1228 LUT), the rest "small"
//! (4 DSP / 156 FF / 270 LUT), ~1.250 ms full-overlay PR time, and a 660 MHz
//! ARM software reference (Zedboard).

use crate::error::{Error, Result};

/// Clock and bandwidth parameters of the modeled platform.
#[derive(Debug, Clone)]
pub struct ClockConfig {
    /// Overlay fabric clock (Hz). Virtex-7 overlays of this style close
    /// timing in the 100–250 MHz range; the paper's graphs are consistent
    /// with ~100 MHz, which we take as default.
    pub fabric_hz: f64,
    /// ARM software reference clock (Hz) — the paper's 660 MHz Zedboard.
    pub arm_hz: f64,
    /// DMA / AXI streaming bandwidth between DDR and the overlay (bytes/s).
    /// 32-bit AXI at fabric clock ⇒ 4 B/cycle.
    pub dma_bytes_per_sec: f64,
    /// ICAP configuration bandwidth (bytes/s). Virtex-7 ICAP: 32 bit @
    /// 100 MHz = 400 MB/s theoretical; real controllers reach ~380 MB/s.
    pub icap_bytes_per_sec: f64,
}

impl Default for ClockConfig {
    fn default() -> Self {
        Self {
            fabric_hz: 100.0e6,
            arm_hz: 660.0e6,
            dma_bytes_per_sec: 400.0e6,
            icap_bytes_per_sec: 380.0e6,
        }
    }
}

/// Fraction and shape of the two PR-region classes within the fabric.
#[derive(Debug, Clone)]
pub struct TileSizing {
    /// Every `large_every`-th tile is provisioned as a large region
    /// (the paper: 1/4 of regions). `large_every == 0` disables large tiles.
    pub large_every: usize,
}

impl Default for TileSizing {
    fn default() -> Self {
        Self { large_every: 4 }
    }
}

/// Service-layer (worker pool) configuration.
///
/// Separate from [`OverlayConfig`] because it describes the *deployment*
/// (how many fabrics, how requests are routed), not the modeled hardware.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Number of pool workers; each owns one overlay fabric.
    pub workers: usize,
    /// Lock shards of the pool-wide compiled-accelerator cache.
    pub cache_shards: usize,
    /// Affinity-scheduler spill threshold: a request leaves its home worker
    /// for the least-loaded one when the home queue is more than this many
    /// requests deeper. Low values favor load balance; high values favor
    /// residency (fewer PR downloads).
    pub max_queue_skew: usize,
    /// Bounded per-worker queue depth (≥ 1). A full queue exerts
    /// backpressure: `WorkerPool::try_submit` returns
    /// [`crate::Error::PoolBusy`], `WorkerPool::submit` blocks for space.
    pub queue_capacity: usize,
    /// Maximum jobs a worker pops per wakeup and reorders with the
    /// reconfiguration-aware scheduler before serving (≥ 1). `1` degenerates
    /// to the PR 1 FIFO drain: no reordering, one metrics fold per job.
    pub drain_window: usize,
    /// Work-stealing threshold: an idle worker steals the tail composition
    /// group of the deepest queue only when that queue holds at least this
    /// many jobs. [`usize::MAX`] disables stealing entirely.
    pub steal_min_depth: usize,
    /// LRU cap on the pool-wide compiled-accelerator cache (`0` =
    /// unbounded). Enforced per lock shard as `ceil(capacity /
    /// cache_shards)`, so the true bound is within one entry per shard of
    /// this value and a skewed key distribution can evict a hot shard
    /// before the nominal total is reached (set `cache_shards: 1` for an
    /// exact cap). Evictions count into `Metrics::lru_evictions`.
    pub cache_capacity: usize,
    /// LRU cap on the pool routing table (`0` = unbounded). Evicting a
    /// sticky route only forgets affinity: the composition falls back to
    /// its home-hash worker on its next request.
    pub route_capacity: usize,
    /// Fusion policy for every pool worker: compile compositions with the
    /// JIT fusion pass (adjacent map∘map / map∘reduce pairs share a tile),
    /// falling back to the unfused shape — and finally CPU interpretation —
    /// when placement runs out of room. Off by default: the paper's
    /// one-operator-per-tile baseline.
    pub fuse: bool,
    /// Deterministic fault-injection schedule shared by every worker (see
    /// [`crate::faults`]). The default (all-off) spec collapses to
    /// [`crate::faults::FaultPlane::NoFaults`], which costs nothing on the
    /// request path.
    pub faults: crate::faults::FaultSpec,
    /// Retry budget for transiently failed PR downloads: a faulted ICAP
    /// transfer is re-armed up to this many times (each retry re-pays the
    /// transfer bytes) before the request errors out. Counted in
    /// `Metrics::download_retries`.
    pub download_retries: u32,
    /// Predictive reconfiguration: each worker learns a first-order Markov
    /// chain over its request keys and, in quiet drain windows, prefetches
    /// the predicted next accelerator's bitstreams into idle healthy tiles
    /// so the following request pays residency hits instead of critical-path
    /// PR downloads. Off by default: the paper's purely reactive JIT.
    /// Counted in `Metrics::prefetch_hits` / `Metrics::prefetch_wasted`.
    pub predict: bool,
    /// Online defragmentation: in quiet drain windows each worker migrates
    /// residents whose footprint fits a Small region off Large tiles,
    /// freeing the scarce Large regions and strictly reducing internal
    /// fragmentation (see [`crate::place::compact`]). Off by default.
    /// Counted in `Metrics::migrations`.
    pub compact: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            workers: 1,
            cache_shards: 8,
            max_queue_skew: 4,
            queue_capacity: 256,
            drain_window: 32,
            steal_min_depth: 2,
            cache_capacity: 256,
            route_capacity: 1024,
            fuse: false,
            faults: crate::faults::FaultSpec::default(),
            download_retries: 3,
            predict: false,
            compact: false,
        }
    }
}

impl ServiceConfig {
    /// A default-tuned pool of `workers` fabrics.
    pub fn with_workers(workers: usize) -> Self {
        Self { workers, ..Self::default() }
    }

    /// Disable work-stealing (pure home/sticky affinity).
    pub fn without_stealing(mut self) -> Self {
        self.steal_min_depth = usize::MAX;
        self
    }

    /// Degenerate to the PR 1 FIFO drain: one job per wakeup, no burst
    /// reordering (baseline for the burst-draining benchmarks).
    pub fn fifo_drain(mut self) -> Self {
        self.drain_window = 1;
        self
    }

    /// Validate invariants. Call after deserializing user-supplied configs.
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            return Err(Error::Config("pool needs at least one worker".into()));
        }
        if self.cache_shards == 0 {
            return Err(Error::Config("cache needs at least one shard".into()));
        }
        if self.queue_capacity == 0 {
            return Err(Error::Config("worker queues need capacity for at least one job".into()));
        }
        if self.drain_window == 0 {
            return Err(Error::Config("drain window must admit at least one job".into()));
        }
        Ok(())
    }
}

/// Reactor front-end configuration (see [`crate::coordinator::frontend`]).
///
/// Separate from [`ServiceConfig`] because it describes the *session
/// layer* in front of the pool (how many reactor threads multiplex the
/// client sessions, how much work may be in flight), not the pool itself.
#[derive(Debug, Clone)]
pub struct FrontendConfig {
    /// Reactor threads (≥ 1). Sessions are partitioned across reactors by
    /// session id, so one reactor multiplexes many sessions; more reactors
    /// only help once a single poll loop saturates a core.
    pub reactors: usize,
    /// Maximum requests one session may have dispatched into the pool at
    /// once (≥ 1). Also bounds the per-session reorder buffer that restores
    /// in-session FIFO delivery from out-of-order completions.
    pub inflight_per_session: usize,
    /// Maximum requests the whole front end may have dispatched at once
    /// (≥ 1, shared across reactors). Admission beyond either cap — or past
    /// a pool answering `PoolBusy` — waits in the session's inbox and is
    /// counted in `Metrics::admission_rejections`.
    pub max_inflight: usize,
}

impl Default for FrontendConfig {
    fn default() -> Self {
        Self { reactors: 1, inflight_per_session: 4, max_inflight: 256 }
    }
}

impl FrontendConfig {
    /// Validate invariants. Call after deserializing user-supplied configs.
    pub fn validate(&self) -> Result<()> {
        if self.reactors == 0 {
            return Err(Error::Config("front end needs at least one reactor".into()));
        }
        if self.inflight_per_session == 0 {
            return Err(Error::Config(
                "sessions need an in-flight budget of at least one request".into(),
            ));
        }
        if self.max_inflight == 0 {
            return Err(Error::Config(
                "front end needs an in-flight budget of at least one request".into(),
            ));
        }
        Ok(())
    }
}

/// Cluster-layer configuration (see [`crate::coordinator::cluster`]).
///
/// Separate from [`ServiceConfig`] because it describes the tier *above*
/// the pools — how composition keys shard across pools on the consistent
/// ring, whether joining pools are warm-started, when whole queued groups
/// migrate between pools — not any single pool's internals.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Virtual nodes per pool on the consistent-hash ring (≥ 1). More
    /// vnodes smooth each pool's arc share toward 1/N at the cost of a
    /// larger (still tiny) sorted ring; 64 keeps per-pool load within a
    /// few percent of fair for single-digit pool counts.
    pub vnodes: usize,
    /// Warm-start joining pools: ship every cached fabric-independent
    /// `AcceleratorProgram` (with a donor placement) into the joiner's
    /// cache so its first request per shipped key pays a placement-only
    /// respecialization instead of a JIT compile. Counted in
    /// `Metrics::warm_start_hits` when a shipped key is first claimed.
    pub warm_start: bool,
    /// Cross-pool steal threshold: `Cluster::rebalance_once` migrates the
    /// tail composition group of the deepest pool to an idle pool only
    /// when the victim's total backlog is at least this deep (≥ 1). The
    /// last-resort tier above in-pool stealing; [`usize::MAX`] disables
    /// cross-pool migration entirely.
    pub cross_steal_depth: usize,
    /// Fusion policy mirrored from the member pools' [`ServiceConfig`]:
    /// the cluster salts routing keys for fused compositions so a fused
    /// and an unfused build of the same composition shard independently,
    /// matching the pool cache's keying.
    pub fuse: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self { vnodes: 64, warm_start: true, cross_steal_depth: 2, fuse: false }
    }
}

impl ClusterConfig {
    /// Validate invariants. Call after deserializing user-supplied configs.
    pub fn validate(&self) -> Result<()> {
        if self.vnodes == 0 {
            return Err(Error::Config("ring needs at least one vnode per pool".into()));
        }
        if self.cross_steal_depth == 0 {
            return Err(Error::Config(
                "cross-pool stealing needs a victim depth of at least one job".into(),
            ));
        }
        Ok(())
    }
}

/// Socket serving-tier configuration (see [`crate::coordinator::net`]).
///
/// Separate from [`FrontendConfig`] because it describes the *network
/// boundary* in front of the session layer — framing limits, timeouts,
/// per-connection pipelining — not the reactors behind it.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Cap on a single wire frame's payload, bytes (≥ 64). A hostile
    /// length prefix above this is rejected before any payload is
    /// buffered and the connection is closed.
    pub max_frame: usize,
    /// Cap on a request's vector length `n` (elements). Bounds the memory
    /// a single wire request can make the server synthesize.
    pub max_n: usize,
    /// Requests one connection may have outstanding (submitted, reply not
    /// yet written) before further frames answer `BUSY` (≥ 1) — the
    /// connection-level face of the admission caps.
    pub max_pending_per_conn: usize,
    /// Idle read timeout, milliseconds: a connection that sends no
    /// complete frame for this long is shed (`0` = never). Slow-loris
    /// partial frames count as idle — only a *complete* frame resets the
    /// clock.
    pub idle_timeout_ms: u64,
    /// Honor a wire `SHUTDOWN` message (loadgen-driven CI teardown).
    /// Off by default: a remote peer must not be able to stop the server
    /// unless the operator opted in.
    pub allow_remote_shutdown: bool,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            max_frame: 1 << 20,
            max_n: 1 << 20,
            max_pending_per_conn: 32,
            idle_timeout_ms: 30_000,
            allow_remote_shutdown: false,
        }
    }
}

impl NetConfig {
    /// Validate invariants. Call after deserializing user-supplied configs.
    pub fn validate(&self) -> Result<()> {
        if self.max_frame < 64 {
            return Err(Error::Config(
                "max_frame must hold at least one small message (64 bytes)".into(),
            ));
        }
        if self.max_n == 0 {
            return Err(Error::Config("max_n must admit at least one element".into()));
        }
        if self.max_pending_per_conn == 0 {
            return Err(Error::Config(
                "connections need a pending budget of at least one request".into(),
            ));
        }
        Ok(())
    }
}

/// Complete overlay configuration.
#[derive(Debug, Clone)]
pub struct OverlayConfig {
    /// Mesh rows (paper experiment: 3).
    pub rows: usize,
    /// Mesh columns (paper experiment: 3).
    pub cols: usize,
    /// Large/small PR sizing mix.
    pub sizing: TileSizing,
    /// Per-tile data BRAM capacity in bytes (two data BRAMs per tile; this
    /// is the capacity of each). 18 Kb BRAM ⇒ 2304 B; we default to a
    /// 36 Kb pair half, 4 KiB, matching the kernels' 1024-f32 chunks.
    pub data_bram_bytes: usize,
    /// Per-tile instruction BRAM capacity in *instructions* (32-bit words).
    pub instr_bram_words: usize,
    /// Number of controller-visible scalar registers per tile.
    pub regs_per_tile: usize,
    /// Clocks and bandwidths.
    pub clocks: ClockConfig,
    /// Approximate partial bitstream size for a small region (bytes). On a
    /// Virtex-7, a region of ~300 LUT + 4 DSP is on the order of 100–200 KB
    /// of frames; chosen so a full 3×3 reconfig ≈ the paper's 1.250 ms.
    pub small_bitstream_bytes: usize,
    /// Partial bitstream size for a large region (bytes).
    pub large_bitstream_bytes: usize,
}

impl Default for OverlayConfig {
    fn default() -> Self {
        Self {
            rows: 3,
            cols: 3,
            sizing: TileSizing::default(),
            data_bram_bytes: 4096,
            instr_bram_words: 256,
            regs_per_tile: 16,
            clocks: ClockConfig::default(),
            // 9 tiles: 7 small + 2 large ⇒ 7*48640 + 2*67456 ≈ 475 KB
            // ⇒ 475 KB / 380 MB/s ≈ 1.250 ms — the paper's PR overhead.
            small_bitstream_bytes: 48_640,
            large_bitstream_bytes: 67_456,
        }
    }
}

impl OverlayConfig {
    /// Total number of tiles in the mesh.
    pub fn tiles(&self) -> usize {
        self.rows * self.cols
    }

    /// Whether tile `idx` (row-major) is provisioned as a large PR region.
    ///
    /// With the default `large_every = 4` on a 3×3 mesh, tiles 0, 4 and 8
    /// would be large — slightly more than the paper's 1/4; we instead mark
    /// every 4th tile *starting at 3* (tiles 3, 7) so a 3×3 mesh gets 2/9 ≈
    /// 1/4 large regions, placed off the border as the PR flow prefers.
    pub fn is_large_tile(&self, idx: usize) -> bool {
        let e = self.sizing.large_every;
        e != 0 && idx % e == e - 1
    }

    /// Number of large tiles in the mesh.
    pub fn large_tiles(&self) -> usize {
        (0..self.tiles()).filter(|&i| self.is_large_tile(i)).count()
    }

    /// Seconds to reconfigure every PR region in the fabric once — the
    /// "PR overhead" of Fig. 3 (paper: ≈1.250 ms for the 3×3 overlay).
    pub fn full_reconfig_seconds(&self) -> f64 {
        let large = self.large_tiles();
        let small = self.tiles() - large;
        let bytes = large * self.large_bitstream_bytes + small * self.small_bitstream_bytes;
        bytes as f64 / self.clocks.icap_bytes_per_sec
    }

    /// Validate invariants. Call after deserializing user-supplied configs.
    pub fn validate(&self) -> Result<()> {
        if self.rows == 0 || self.cols == 0 {
            return Err(Error::Config("mesh must have at least one tile".into()));
        }
        if self.data_bram_bytes < 16 || self.data_bram_bytes % 4 != 0 {
            return Err(Error::Config(
                "data BRAM must hold at least 4 words and be word-aligned".into(),
            ));
        }
        if self.instr_bram_words < 8 {
            return Err(Error::Config("instruction BRAM too small".into()));
        }
        if self.regs_per_tile < 4 {
            return Err(Error::Config("need at least 4 registers per tile".into()));
        }
        let c = &self.clocks;
        for (name, v) in [
            ("fabric_hz", c.fabric_hz),
            ("arm_hz", c.arm_hz),
            ("dma_bytes_per_sec", c.dma_bytes_per_sec),
            ("icap_bytes_per_sec", c.icap_bytes_per_sec),
        ] {
            if !(v.is_finite() && v > 0.0) {
                return Err(Error::Config(format!("{name} must be positive, got {v}")));
            }
        }
        Ok(())
    }

    /// Words of f32 a single data BRAM holds.
    pub fn bram_words(&self) -> usize {
        self.data_bram_bytes / 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_valid() {
        OverlayConfig::default().validate().unwrap();
    }

    #[test]
    fn default_mesh_is_paper_3x3() {
        let c = OverlayConfig::default();
        assert_eq!(c.tiles(), 9);
        assert_eq!((c.rows, c.cols), (3, 3));
    }

    #[test]
    fn quarter_of_tiles_are_large() {
        let c = OverlayConfig::default();
        // 2 of 9 ≈ the paper's "1/4 of the PR regions".
        assert_eq!(c.large_tiles(), 2);
        assert!(c.is_large_tile(3));
        assert!(c.is_large_tile(7));
        assert!(!c.is_large_tile(0));
    }

    #[test]
    fn full_reconfig_matches_paper_pr_overhead() {
        let s = OverlayConfig::default().full_reconfig_seconds();
        // paper: "around 1.250 ms"
        assert!((s - 1.25e-3).abs() < 0.05e-3, "got {s}");
    }

    #[test]
    fn zero_rows_rejected() {
        let mut c = OverlayConfig::default();
        c.rows = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn bad_clock_rejected() {
        let mut c = OverlayConfig::default();
        c.clocks.fabric_hz = -1.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn bram_words_default_matches_kernel_block() {
        assert_eq!(OverlayConfig::default().bram_words(), 1024);
    }

    #[test]
    fn service_config_defaults_validate() {
        ServiceConfig::default().validate().unwrap();
        let s = ServiceConfig::with_workers(4);
        assert_eq!(s.workers, 4);
        s.validate().unwrap();
        // faults are off by default, with a small positive retry budget
        assert!(s.faults.is_off());
        assert!(s.download_retries > 0);
        // speculative maintenance is opt-in: the paper's baseline is
        // purely reactive
        assert!(!s.predict);
        assert!(!s.compact);
    }

    #[test]
    fn service_config_rejects_zero_workers_and_shards() {
        assert!(ServiceConfig { workers: 0, ..Default::default() }.validate().is_err());
        assert!(ServiceConfig { cache_shards: 0, ..Default::default() }.validate().is_err());
        assert!(ServiceConfig { queue_capacity: 0, ..Default::default() }.validate().is_err());
        assert!(ServiceConfig { drain_window: 0, ..Default::default() }.validate().is_err());
    }

    #[test]
    fn frontend_config_defaults_validate_and_zeroes_reject() {
        FrontendConfig::default().validate().unwrap();
        assert!(FrontendConfig { reactors: 0, ..Default::default() }.validate().is_err());
        assert!(FrontendConfig { inflight_per_session: 0, ..Default::default() }
            .validate()
            .is_err());
        assert!(FrontendConfig { max_inflight: 0, ..Default::default() }.validate().is_err());
    }

    #[test]
    fn net_config_defaults_validate_and_zeroes_reject() {
        NetConfig::default().validate().unwrap();
        assert!(NetConfig { max_frame: 0, ..Default::default() }.validate().is_err());
        assert!(NetConfig { max_frame: 63, ..Default::default() }.validate().is_err());
        assert!(NetConfig { max_n: 0, ..Default::default() }.validate().is_err());
        assert!(NetConfig { max_pending_per_conn: 0, ..Default::default() }
            .validate()
            .is_err());
        // idle_timeout_ms = 0 (never shed) is a valid operator choice
        NetConfig { idle_timeout_ms: 0, ..Default::default() }.validate().unwrap();
    }

    #[test]
    fn cluster_config_defaults_validate_and_zeroes_reject() {
        let c = ClusterConfig::default();
        c.validate().unwrap();
        assert_eq!(c.vnodes, 64);
        assert!(c.warm_start);
        assert_eq!(c.cross_steal_depth, 2);
        assert!(!c.fuse);
        assert!(ClusterConfig { vnodes: 0, ..Default::default() }.validate().is_err());
        assert!(ClusterConfig { cross_steal_depth: 0, ..Default::default() }
            .validate()
            .is_err());
        // usize::MAX disables cross-pool stealing but stays valid
        ClusterConfig { cross_steal_depth: usize::MAX, ..Default::default() }
            .validate()
            .unwrap();
    }

    #[test]
    fn service_config_builders() {
        let s = ServiceConfig::with_workers(4).without_stealing().fifo_drain();
        assert_eq!(s.steal_min_depth, usize::MAX);
        assert_eq!(s.drain_window, 1);
        s.validate().unwrap();
    }
}
