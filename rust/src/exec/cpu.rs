//! Scalar CPU evaluation of pattern compositions.
//!
//! Two roles: (1) the *values* behind the ARM-software Fig. 3 series, and
//! (2) an independent reference the integration tests triangulate against —
//! overlay-interpreter result == PJRT artifact result == this evaluator.

use crate::bitstream::OperatorKind;
use crate::error::{Error, Result};
use crate::patterns::{Composition, Expr};

/// Result of evaluating a composition.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Scalar(f32),
    Vector(Vec<f32>),
}

impl Value {
    pub fn as_scalar(&self) -> Option<f32> {
        match self {
            Value::Scalar(s) => Some(*s),
            Value::Vector(v) if v.len() == 1 => Some(v[0]),
            _ => None,
        }
    }
    pub fn as_vector(&self) -> Option<&[f32]> {
        match self {
            Value::Vector(v) => Some(v),
            Value::Scalar(_) => None,
        }
    }
}

/// Evaluate `comp` over `inputs` (one vector per external channel).
pub fn eval(comp: &Composition, inputs: &[Vec<f32>]) -> Result<Value> {
    if inputs.len() < comp.inputs as usize {
        return Err(Error::Pattern(format!(
            "composition reads {} channels, got {}",
            comp.inputs,
            inputs.len()
        )));
    }
    for (k, v) in inputs.iter().enumerate().take(comp.inputs as usize) {
        if v.len() != comp.n {
            return Err(Error::Pattern(format!(
                "channel {k}: expected {} elements, got {}",
                comp.n,
                v.len()
            )));
        }
    }
    match eval_expr(&comp.expr, inputs, comp.n)? {
        EV::Vec(v) => Ok(Value::Vector(v)),
        EV::Scalar(s) => Ok(Value::Scalar(s)),
    }
}

enum EV {
    Vec(Vec<f32>),
    Scalar(f32),
}

fn unary(op: OperatorKind, v: &mut [f32]) {
    let mut state = 0.0;
    for x in v.iter_mut() {
        *x = op.apply(*x, 0.0, &mut state);
    }
}

fn eval_expr(e: &Expr, inputs: &[Vec<f32>], n: usize) -> Result<EV> {
    Ok(match e {
        Expr::Input(c) => EV::Vec(inputs[*c as usize].clone()),
        Expr::Scalar(v) => EV::Vec(vec![*v; n]),
        Expr::Map { op, x } => {
            let EV::Vec(mut v) = eval_expr(x, inputs, n)? else {
                return Err(Error::Pattern("map over scalar".into()));
            };
            unary(*op, &mut v);
            EV::Vec(v)
        }
        Expr::Zip { op, x, y } => {
            let EV::Vec(a) = eval_expr(x, inputs, n)? else {
                return Err(Error::Pattern("zip over scalar".into()));
            };
            let EV::Vec(b) = eval_expr(y, inputs, n)? else {
                return Err(Error::Pattern("zip over scalar".into()));
            };
            let mut state = 0.0;
            EV::Vec(
                a.iter()
                    .zip(&b)
                    .map(|(&p, &q)| op.apply(p, q, &mut state))
                    .collect(),
            )
        }
        Expr::Reduce { x } => {
            let EV::Vec(v) = eval_expr(x, inputs, n)? else {
                return Err(Error::Pattern("reduce over scalar".into()));
            };
            EV::Scalar(v.iter().sum())
        }
        Expr::FilterGt { t, x } => {
            let EV::Vec(v) = eval_expr(x, inputs, n)? else {
                return Err(Error::Pattern("filter over scalar".into()));
            };
            EV::Vec(v.into_iter().map(|x| if x > *t { x } else { 0.0 }).collect())
        }
        Expr::Branch { t, then_op, else_op, x } => {
            let EV::Vec(v) = eval_expr(x, inputs, n)? else {
                return Err(Error::Pattern("branch over scalar".into()));
            };
            let mut s1 = 0.0;
            let mut s2 = 0.0;
            EV::Vec(
                v.into_iter()
                    .map(|x| {
                        if x > *t {
                            then_op.apply(x, 0.0, &mut s1)
                        } else {
                            else_op.apply(x, 0.0, &mut s2)
                        }
                    })
                    .collect(),
            )
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(n: usize) -> Vec<f32> {
        (0..n).map(|i| i as f32 / 8.0 - 4.0).collect()
    }

    #[test]
    fn vmul_reduce_matches_dot() {
        let n = 64;
        let a = ramp(n);
        let b: Vec<f32> = (0..n).map(|i| (i % 5) as f32).collect();
        let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        let got = eval(&Composition::vmul_reduce(n), &[a, b]).unwrap();
        assert_eq!(got.as_scalar(), Some(want));
    }

    #[test]
    fn axpy_matches_formula() {
        let n = 32;
        let x = ramp(n);
        let y: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let got = eval(&Composition::axpy(2.0, n), &[x.clone(), y.clone()]).unwrap();
        let v = got.as_vector().unwrap();
        for i in 0..n {
            assert_eq!(v[i], 2.0 * x[i] + y[i]);
        }
    }

    #[test]
    fn filter_reduce_sums_survivors() {
        let n = 16;
        let x = ramp(n);
        let want: f32 = x.iter().filter(|&&v| v > 0.0).sum();
        let got = eval(&Composition::filter_reduce(0.0, n), &[x]).unwrap();
        assert_eq!(got.as_scalar(), Some(want));
    }

    #[test]
    fn branch_selects_per_element() {
        let n = 16;
        let x = ramp(n);
        let got = eval(
            &Composition::branch(0.0, OperatorKind::Square, OperatorKind::Neg, n),
            &[x.clone()],
        )
        .unwrap();
        let v = got.as_vector().unwrap();
        for i in 0..n {
            let want = if x[i] > 0.0 { x[i] * x[i] } else { -x[i] };
            assert_eq!(v[i], want);
        }
    }

    #[test]
    fn wrong_channel_length_rejected() {
        let c = Composition::vmul_reduce(64);
        assert!(eval(&c, &[vec![0.0; 64], vec![0.0; 32]]).is_err());
    }

    #[test]
    fn missing_channel_rejected() {
        let c = Composition::vmul_reduce(64);
        assert!(eval(&c, &[vec![0.0; 64]]).is_err());
    }
}
