//! The execution engine: one compiled accelerator, five evaluation targets.
//!
//! For the **dynamic overlay** the engine is fully mechanistic: download
//! bitstreams (PR manager), run the controller program on the fabric
//! simulator (semantic values + measured cycles). For the other Fig. 3
//! targets the values come from the same semantics (scalar CPU evaluation
//! or PJRT artifacts) and the time from the analytic models in
//! [`crate::timing`] — the static overlay costs store-and-forward hops, the
//! HLS module a fused II≈1.4 pipeline, the ARM a scalar loop at 660 MHz.

pub mod cpu;

pub use cpu::Value;

use crate::bitstream::{BitstreamLibrary, OperatorKind};
use crate::config::OverlayConfig;
use crate::error::{Error, Result};
use crate::faults::{ExecFault, FaultPlane};
use crate::jit::{AcceleratorProgram, CompiledAccelerator, PlacementPlan};
use crate::overlay::{Controller, ExecStats, ExternalIo, Fabric};
use crate::patterns::Composition;
use crate::place::{DynamicPlacer, StaticScenario};
use crate::reconfig::{PrManager, ReconfigStats};
use crate::timing::{arm::ArmModel, hls::HlsModel, overlay as otiming, Target, TimingBreakdown};

/// Everything one run produces.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub target: Target,
    pub output: Value,
    pub timing: TimingBreakdown,
    /// PR download cost (dynamic overlay only; the Fig. 3 "only penalty").
    pub reconfig: Option<ReconfigStats>,
    /// Raw interpreter stats (overlay targets only).
    pub stats: Option<ExecStats>,
}

impl RunResult {
    /// Total time including amortizable reconfiguration.
    pub fn total_with_reconfig(&self) -> f64 {
        self.timing.total() + self.reconfig.map_or(0.0, |r| r.seconds)
    }
}

/// The engine: owns the fabric + PR manager, borrows library and config.
#[derive(Debug)]
pub struct Engine {
    pub fabric: Fabric,
    pub lib: BitstreamLibrary,
    pub pr: PrManager,
    pub controller: Controller,
    pub arm: ArmModel,
    pub hls: HlsModel,
    /// Fault-injection plane arbitrating PR downloads and tile execution
    /// ([`FaultPlane::NoFaults`] by default — zero hot-path cost).
    pub faults: std::sync::Arc<FaultPlane>,
    /// Re-arms allowed per transient download fault before giving up
    /// ([`crate::config::ServiceConfig::download_retries`]).
    pub download_retries: u32,
}

impl Engine {
    pub fn new(cfg: OverlayConfig) -> Result<Engine> {
        let lib = BitstreamLibrary::standard(&cfg);
        Ok(Engine {
            fabric: Fabric::new(cfg)?,
            lib,
            pr: PrManager::default(),
            controller: Controller::default(),
            arm: ArmModel::default(),
            hls: HlsModel::default(),
            faults: FaultPlane::none(),
            download_retries: 3,
        })
    }

    /// Run `acc` on `target` with the user's input channels.
    pub fn run(
        &mut self,
        acc: &CompiledAccelerator,
        inputs: &[Vec<f32>],
        target: Target,
    ) -> Result<RunResult> {
        match target {
            Target::DynamicOverlay => self.run_dynamic(acc, inputs),
            Target::StaticOverlay(s) => self.run_static(acc, inputs, s),
            Target::ArmSoftware => self.run_arm(acc, inputs),
            Target::HlsCustom => self.run_hls(acc, inputs),
        }
    }

    /// Assemble + execute on the dynamic overlay (the paper's system).
    ///
    /// Values and event counts come from the controller interpreter; the
    /// reported *time* comes from the pipelined analytic model. The
    /// interpreter executes chunk-serially (stage i+1 runs after stage i),
    /// but the hardware overlaps stages — contiguous tiles stream
    /// element-by-element — so the analytic `pipeline_time` (fill = Σ stage
    /// latencies, steady state = one element per cycle) is the faithful
    /// price. `stats` carries the raw interpreter cycle counts for anyone
    /// who wants the unpipelined view.
    fn run_dynamic(
        &mut self,
        acc: &CompiledAccelerator,
        inputs: &[Vec<f32>],
    ) -> Result<RunResult> {
        // Residency guard: a placement plan is only valid against the
        // occupancy it was compiled for. Replaying one that would overwrite
        // other accelerators' residents *while free tiles could host it* is
        // always a stale plan (compiled on another fabric, or before this
        // fabric's occupancy moved) — refuse it so the caller respecializes
        // instead of silently clobbering. When the fabric genuinely lacks
        // room, overwriting is the legitimate capacity thrash the batcher
        // amortizes, and the plan passes.
        if self.plan_is_stale(acc) {
            return Err(Error::StalePlan {
                fabric: self.fabric.id,
                free_tiles: self.fabric.free_tile_count(),
            });
        }
        let reconfig = self.pr.apply_with(
            &mut self.fabric,
            &self.lib,
            acc.placement(),
            &self.faults,
            self.download_retries,
        )?;
        // Execution fault site: downloads landed, but the serving region
        // may hold wrong bits (clear it so the retry re-downloads clean)
        // or die outright (quarantine + re-place). Either way the run is
        // refused *before* the interpreter touches data, so no partial
        // output ever escapes a faulted tile.
        if let Some(fault) = self.faults.next_exec() {
            if let Some(a) = acc.placement().assignments.first() {
                match fault {
                    ExecFault::WrongBits => {
                        self.fabric.clear_region(a.tile)?;
                        return Err(Error::TileFault { tile: a.tile, permanent: false });
                    }
                    ExecFault::RegionDead => {
                        self.fabric.quarantine(a.tile);
                        return Err(Error::TileFault { tile: a.tile, permanent: true });
                    }
                }
            }
        }
        self.fabric.reset_data();
        self.fabric.reset_switches(); // stale routes must not leak between accelerators

        // Borrow user channels directly; only the (1-word) broadcast-scalar
        // channels are materialized (perf §Perf-2: no operand copies).
        self.validate_inputs(acc, inputs)?;
        let scalar_bufs: Vec<Vec<f32>> =
            acc.scalar_channels().iter().map(|&s| vec![s]).collect();
        let mut io = ExternalIo::from_slices(
            inputs
                .iter()
                .map(|v| v.as_slice())
                .chain(scalar_bufs.iter().map(|v| v.as_slice()))
                .collect(),
        );
        let stats = self
            .controller
            .run(&mut self.fabric, acc.program(), &mut io)?;

        let timing = otiming::pipeline_time(
            &self.fabric.cfg,
            &acc.composition().ops(),
            acc.composition().n,
            acc.total_hops(),
            acc.program().len(),
            acc.composition().inputs as usize,
            otiming::ForwardingMode::Pipelined,
        );
        let output = self.take_output(acc, io)?;
        Ok(RunResult {
            target: Target::DynamicOverlay,
            output,
            timing,
            reconfig: Some(reconfig),
            stats: Some(stats),
        })
    }

    /// Static overlay: same semantics, fixed placement with `scenario`'s
    /// pass-through count, store-and-forward forwarding.
    fn run_static(
        &mut self,
        acc: &CompiledAccelerator,
        inputs: &[Vec<f32>],
        scenario: StaticScenario,
    ) -> Result<RunResult> {
        // Values: execute the same program on the simulator (the dataflow
        // semantics of the static overlay are identical; only timing and
        // placement freedom differ).
        let mut run = self.run_dynamic(acc, inputs)?;
        let ops = acc.composition().ops();
        let timing = otiming::pipeline_time(
            &self.fabric.cfg,
            &ops,
            acc.composition().n,
            scenario.pass_throughs() + acc.total_hops(),
            acc.program().len(),
            acc.composition().inputs as usize,
            otiming::ForwardingMode::StoreAndForward,
        );
        run.target = Target::StaticOverlay(scenario);
        run.timing = timing;
        // the static overlay is synthesized once: no PR at run time,
        // but also no run-time flexibility (the paper's trade-off).
        run.reconfig = None;
        Ok(run)
    }

    fn run_arm(&self, acc: &CompiledAccelerator, inputs: &[Vec<f32>]) -> Result<RunResult> {
        self.run_cpu(acc.composition(), inputs)
    }

    /// Software (ARM-model) evaluation straight from the composition — no
    /// compiled accelerator, no placement, no fabric state. This is the
    /// floor of the resource-aware fallback ladder: when neither the fused
    /// nor the unfused shape places, the coordinator answers from here
    /// instead of surfacing a placement error.
    pub fn run_cpu(&self, comp: &Composition, inputs: &[Vec<f32>]) -> Result<RunResult> {
        let output = cpu::eval(comp, inputs)?;
        let timing = self
            .arm
            .pattern_time(&self.fabric.cfg.clocks, comp.stages().len(), comp.n);
        Ok(RunResult { target: Target::ArmSoftware, output, timing, reconfig: None, stats: None })
    }

    fn run_hls(&self, acc: &CompiledAccelerator, inputs: &[Vec<f32>]) -> Result<RunResult> {
        let output = cpu::eval(acc.composition(), inputs)?;
        let timing = self.hls.pattern_time(
            &self.fabric.cfg,
            acc.composition().inputs as usize,
            acc.composition().n,
        );
        Ok(RunResult { target: Target::HlsCustom, output, timing, reconfig: None, stats: None })
    }

    /// Fabric occupancy: `(tiles with a resident operator, total tiles)`.
    ///
    /// The pool reports this per worker — it is the residency the affinity
    /// scheduler is trying to protect.
    pub fn residency(&self) -> (usize, usize) {
        let total = self.fabric.tiles.len();
        (total - self.fabric.free_tile_count(), total)
    }

    /// Would replaying `plan` overwrite residents of *other* operators on
    /// this fabric? (Downloading into an empty tile, or re-downloading the
    /// operator already resident, is never a clobber.)
    pub fn plan_clobbers(&self, plan: &PlacementPlan) -> bool {
        plan.placement.assignments.iter().any(|a| {
            let t = &self.fabric.tiles[a.tile];
            // a fused pair and its bare head are different datapaths, so
            // the comparison covers the whole (head, tail) residency
            t.resident.map_or(false, |r| r != a.op || t.resident_tail != a.tail)
        })
    }

    /// Does `plan` assign any stage to a quarantined tile? Such a plan can
    /// never replay successfully (the download would be rejected), so the
    /// cache treats it like a miss and respecializes around the dead
    /// region instead of replaying into it forever.
    pub fn plan_touches_quarantine(&self, plan: &PlacementPlan) -> bool {
        plan.placement
            .assignments
            .iter()
            .any(|a| self.fabric.tiles.get(a.tile).map_or(true, |t| t.quarantined))
    }

    /// The residency-guard predicate: would replaying `acc`'s plan
    /// overwrite residents of *other* operators even though this fabric's
    /// free tiles could host the pipeline on untouched ones? True means
    /// the plan is stale for this fabric right now and should be
    /// respecialized, not replayed.
    ///
    /// Feasibility is [`DynamicPlacer::feasible`] — the placer's own
    /// condition, shared, so a refusal here guarantees a placement-only
    /// recompile will succeed. Branch diamonds (a Select hub needing free
    /// *adjacent* spokes) have a stricter shape the linear check cannot
    /// see, so the guard stays conservative there and lets the replay
    /// through — the coordinator covers diamonds by *attempting* the
    /// respecialization instead (see
    /// [`Coordinator::accelerator`](crate::coordinator::Coordinator)).
    pub fn plan_is_stale(&self, acc: &CompiledAccelerator) -> bool {
        if !self.plan_clobbers(&acc.plan) {
            return false;
        }
        let spec: &AcceleratorProgram = &acc.spec;
        if spec.stages.iter().any(|s| s.op == OperatorKind::Select) {
            return false;
        }
        DynamicPlacer::feasible(&self.fabric, &spec.classes)
    }

    /// Validate user channel count/lengths against the composition.
    fn validate_inputs(&self, acc: &CompiledAccelerator, inputs: &[Vec<f32>]) -> Result<()> {
        let want = acc.composition().inputs as usize;
        if inputs.len() != want {
            return Err(Error::Pattern(format!(
                "composition reads {want} channels, got {}",
                inputs.len()
            )));
        }
        for (k, v) in inputs.iter().enumerate() {
            if v.len() != acc.composition().n {
                return Err(Error::Pattern(format!(
                    "channel {k}: expected {} elements, got {}",
                    acc.composition().n,
                    v.len()
                )));
            }
        }
        Ok(())
    }

    fn take_output(&self, acc: &CompiledAccelerator, io: ExternalIo) -> Result<Value> {
        let out = io
            .outputs
            .first()
            .cloned()
            .ok_or_else(|| Error::Runtime("accelerator produced no output".into()))?;
        Ok(if acc.composition().scalar_result() {
            Value::Scalar(*out.first().ok_or_else(|| {
                Error::Runtime("empty scalar output channel".into())
            })?)
        } else {
            Value::Vector(out)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitstream::OperatorKind;
    use crate::jit::Jit;
    use crate::patterns::Composition;

    fn engine() -> Engine {
        Engine::new(OverlayConfig::default()).unwrap()
    }

    fn compile(e: &Engine, comp: &Composition) -> CompiledAccelerator {
        Jit.compile(&e.fabric, &e.lib, comp).unwrap()
    }

    fn ramp(n: usize, seed: u32) -> Vec<f32> {
        (0..n)
            .map(|i| {
                ((i as u32).wrapping_mul(2654435761).wrapping_add(seed) % 1000) as f32 / 250.0
                    - 2.0
            })
            .collect()
    }

    #[test]
    fn dynamic_overlay_matches_cpu_reference_vmul_reduce() {
        let mut e = engine();
        let n = 4096; // the paper's 16 KB
        let comp = Composition::vmul_reduce(n);
        let acc = compile(&e, &comp);
        let a = ramp(n, 1);
        let b = ramp(n, 2);
        let dyn_ = e.run(&acc, &[a.clone(), b.clone()], Target::DynamicOverlay).unwrap();
        let arm = e.run(&acc, &[a, b], Target::ArmSoftware).unwrap();
        let (d, r) = (dyn_.output.as_scalar().unwrap(), arm.output.as_scalar().unwrap());
        assert!((d - r).abs() <= 1e-2_f32.max(r.abs() * 1e-4), "{d} vs {r}");
        assert!(dyn_.reconfig.unwrap().downloads > 0);
    }

    #[test]
    fn chunked_execution_covers_large_vectors() {
        let mut e = engine();
        let n = 8192; // 8 chunks of 1024
        let comp = Composition::vmul_reduce(n);
        let acc = compile(&e, &comp);
        let a = vec![0.5f32; n];
        let b = vec![2.0f32; n];
        let out = e.run(&acc, &[a, b], Target::DynamicOverlay).unwrap();
        assert_eq!(out.output.as_scalar(), Some(n as f32));
    }

    #[test]
    fn map_pipeline_produces_vector() {
        let mut e = engine();
        let n = 2048;
        let comp = Composition::chain(&[OperatorKind::Abs, OperatorKind::Square], n).unwrap();
        let acc = compile(&e, &comp);
        let x = ramp(n, 3);
        let run = e.run(&acc, &[x.clone()], Target::DynamicOverlay).unwrap();
        let v = run.output.as_vector().unwrap();
        assert_eq!(v.len(), n);
        for i in 0..n {
            assert!((v[i] - x[i] * x[i]).abs() < 1e-4, "i={i}");
        }
    }

    #[test]
    fn filter_reduce_on_overlay() {
        let mut e = engine();
        let n = 1024;
        let comp = Composition::filter_reduce(0.5, n);
        let acc = compile(&e, &comp);
        let x = ramp(n, 7);
        let want: f32 = x.iter().filter(|&&v| v > 0.5).sum();
        let run = e.run(&acc, &[x], Target::DynamicOverlay).unwrap();
        let got = run.output.as_scalar().unwrap();
        assert!((got - want).abs() < want.abs().max(1.0) * 1e-4, "{got} vs {want}");
    }

    #[test]
    fn branch_diamond_on_overlay() {
        let mut e = engine();
        let n = 512;
        let comp = Composition::branch(0.0, OperatorKind::Relu, OperatorKind::Neg, n);
        let acc = compile(&e, &comp);
        let x = ramp(n, 11);
        let run = e.run(&acc, &[x.clone()], Target::DynamicOverlay).unwrap();
        let v = run.output.as_vector().unwrap();
        for i in 0..n {
            let want = if x[i] > 0.0 { x[i].max(0.0) } else { -x[i] };
            assert!((v[i] - want).abs() < 1e-5, "i={i}: {} vs {want}", v[i]);
        }
    }

    #[test]
    fn axpy_on_overlay() {
        let mut e = engine();
        let n = 1024;
        let comp = Composition::axpy(3.0, n);
        let acc = compile(&e, &comp);
        let x = ramp(n, 13);
        let y = ramp(n, 17);
        let run = e.run(&acc, &[x.clone(), y.clone()], Target::DynamicOverlay).unwrap();
        let v = run.output.as_vector().unwrap();
        for i in 0..n {
            assert!((v[i] - (3.0 * x[i] + y[i])).abs() < 1e-4, "i={i}");
        }
    }

    #[test]
    fn fig3_ordering_dynamic_beats_static_monotonically() {
        let mut e = engine();
        let n = 4096;
        let comp = Composition::vmul_reduce(n);
        let acc = compile(&e, &comp);
        let a = ramp(n, 1);
        let b = ramp(n, 2);

        let t_dyn = e
            .run(&acc, &[a.clone(), b.clone()], Target::DynamicOverlay)
            .unwrap()
            .timing
            .total();
        let mut statics = Vec::new();
        for s in StaticScenario::ALL {
            let t = e
                .run(&acc, &[a.clone(), b.clone()], Target::StaticOverlay(s))
                .unwrap()
                .timing
                .total();
            statics.push(t);
        }
        let t_arm =
            e.run(&acc, &[a.clone(), b.clone()], Target::ArmSoftware).unwrap().timing.total();

        // dynamic ≤ static-s1 < static-s2 < static-s3 (pass-through penalty)
        assert!(t_dyn <= statics[0] * 1.05, "dyn {t_dyn} vs s1 {}", statics[0]);
        assert!(statics[0] < statics[1] && statics[1] < statics[2]);
        // ARM slowest (the paper's software reference)
        assert!(t_arm > statics[2], "arm {t_arm} vs s3 {}", statics[2]);
    }

    #[test]
    fn second_run_amortizes_reconfig() {
        let mut e = engine();
        let n = 1024;
        let comp = Composition::vmul_reduce(n);
        let acc = compile(&e, &comp);
        let a = vec![1.0f32; n];
        let b = vec![1.0f32; n];
        let first = e.run(&acc, &[a.clone(), b.clone()], Target::DynamicOverlay).unwrap();
        let second = e.run(&acc, &[a, b], Target::DynamicOverlay).unwrap();
        assert!(first.reconfig.unwrap().seconds > 0.0);
        assert_eq!(second.reconfig.unwrap().seconds, 0.0); // residency cache
    }

    #[test]
    fn residency_tracks_downloads() {
        let mut e = engine();
        assert_eq!(e.residency(), (0, 9));
        let comp = Composition::vmul_reduce(256);
        let acc = compile(&e, &comp);
        e.run(&acc, &[vec![1.0; 256], vec![1.0; 256]], Target::DynamicOverlay).unwrap();
        assert_eq!(e.residency(), (2, 9));
        e.fabric.reset_full();
        assert_eq!(e.residency(), (0, 9));
    }

    /// The residency guard (ISSUE 4): a plan compiled against an occupancy
    /// that has since changed is refused when free tiles could host it, and
    /// a placement-only respecialization then runs clean without touching
    /// the residents the stale plan would have clobbered.
    #[test]
    fn stale_plan_refused_when_free_tiles_exist() {
        let mut e = engine();
        let n = 256;
        // both compiled against the *empty* fabric: their placements overlap
        let vmul = compile(&e, &Composition::vmul_reduce(n));
        let map = compile(&e, &Composition::chain(&[OperatorKind::Abs], n).unwrap());
        e.run(&vmul, &[vec![1.0; n], vec![1.0; n]], Target::DynamicOverlay).unwrap();
        assert!(e.plan_is_stale(&map), "overlapping plan with 7 free tiles must be stale");
        let err = e.run(&map, &[vec![-1.0; n]], Target::DynamicOverlay).unwrap_err();
        assert!(matches!(err, Error::StalePlan { .. }), "got: {err}");
        // respecialize placement-only against the live occupancy
        let plan = Jit.place_onto(&e.fabric, &map.spec).unwrap();
        let fresh = CompiledAccelerator { spec: map.spec.clone(), plan: plan.into() };
        assert!(!e.plan_is_stale(&fresh));
        let run = e.run(&fresh, &[vec![-1.0; n]], Target::DynamicOverlay).unwrap();
        assert_eq!(run.output.as_vector().map(|v| v[0]), Some(1.0));
        // the stale plan's victims survived
        assert_eq!(e.fabric.tiles[0].resident, Some(OperatorKind::Mul));
        // full fabric exception: when free tiles cannot host the pipeline,
        // overwriting is legitimate capacity thrash, not staleness
        let mut full = engine();
        let chain = Composition::chain(
            &[
                OperatorKind::Neg,
                OperatorKind::Abs,
                OperatorKind::Square,
                OperatorKind::Relu,
                OperatorKind::Neg,
            ],
            n,
        )
        .unwrap();
        let acc_a = compile(&full, &chain);
        full.run(&acc_a, &[vec![1.0; n]], Target::DynamicOverlay).unwrap();
        full.fabric.reset_full();
        let conflicting = Composition::chain(
            &[
                OperatorKind::Abs,
                OperatorKind::Neg,
                OperatorKind::Relu,
                OperatorKind::Square,
                OperatorKind::Abs,
            ],
            n,
        )
        .unwrap();
        let acc_b = compile(&full, &conflicting);
        full.run(&acc_b, &[vec![1.0; n]], Target::DynamicOverlay).unwrap();
        // acc_a's plan clobbers acc_b's residents, but only 4 tiles are
        // free for its 5 stages — allowed (and counted as pr_replaced)
        assert!(!full.plan_is_stale(&acc_a));
        full.run(&acc_a, &[vec![1.0; n]], Target::DynamicOverlay).unwrap();
    }

    /// Tentpole invariant: fused execution is bit-identical to unfused
    /// execution and to the CPU reference, on both map chains and reduces.
    #[test]
    fn fused_execution_matches_unfused_bitwise() {
        let n = 2048;
        let chain = Composition::chain(
            &[
                OperatorKind::Neg,
                OperatorKind::Abs,
                OperatorKind::Square,
                OperatorKind::Relu,
                OperatorKind::Neg,
            ],
            n,
        )
        .unwrap();
        for comp in [chain, Composition::vmul_reduce(n), Composition::filter_reduce(0.25, n)] {
            let inputs: Vec<Vec<f32>> =
                (0..comp.inputs).map(|k| ramp(n, 19 + k as u32)).collect();
            let mut plain = engine();
            let acc = compile(&plain, &comp);
            let unfused = plain.run(&acc, &inputs, Target::DynamicOverlay).unwrap();

            let mut fused_e = engine();
            let fused_acc =
                Jit.compile_with(&fused_e.fabric, &fused_e.lib, &comp, true).unwrap();
            assert!(fused_acc.spec.fused_pairs > 0, "{comp:?} should fuse");
            assert!(fused_acc.stages().len() < acc.stages().len());
            let fused = fused_e.run(&fused_acc, &inputs, Target::DynamicOverlay).unwrap();

            let cpu = plain.run_cpu(&comp, &inputs).unwrap();
            match (&unfused.output, &fused.output, &cpu.output) {
                (Value::Scalar(u), Value::Scalar(f), Value::Scalar(c)) => {
                    assert_eq!(u.to_bits(), f.to_bits(), "{comp:?}");
                    assert_eq!(u.to_bits(), c.to_bits(), "{comp:?}");
                }
                (Value::Vector(u), Value::Vector(f), Value::Vector(c)) => {
                    for i in 0..n {
                        assert_eq!(u[i].to_bits(), f[i].to_bits(), "{comp:?} i={i}");
                        assert_eq!(u[i].to_bits(), c[i].to_bits(), "{comp:?} i={i}");
                    }
                }
                _ => panic!("output shape mismatch for {comp:?}"),
            }
            // and the point of it all: fewer PR downloads
            assert!(
                fused.reconfig.unwrap().downloads < unfused.reconfig.unwrap().downloads,
                "{comp:?}"
            );
        }
    }

    /// Execution faults refuse the run before any output escapes: wrong
    /// bits clear the region (transient — a re-download heals it), a dead
    /// region is quarantined (permanent — the plan must move elsewhere).
    #[test]
    fn exec_faults_refuse_the_run_and_mark_the_tile() {
        use crate::faults::{FaultPlane, FaultSpec};
        let n = 256;
        let comp = Composition::vmul_reduce(n);
        let inputs = [vec![1.0f32; n], vec![1.0f32; n]];

        // wrong bits on exec 1: region cleared, tile stays healthy
        let mut e = engine();
        let acc = compile(&e, &comp);
        e.faults =
            FaultPlane::from_spec(FaultSpec { wrong_bits: vec![1], ..FaultSpec::default() });
        let victim = acc.placement().assignments[0].tile;
        let err = e.run(&acc, &inputs, Target::DynamicOverlay).unwrap_err();
        assert!(
            matches!(err, Error::TileFault { tile, permanent: false } if tile == victim),
            "got {err:?}"
        );
        assert_eq!(e.fabric.tiles[victim].resident, None, "corrupt region cleared");
        assert_eq!(e.fabric.quarantined_tiles(), 0);
        // exec 2 is clean: the retry re-downloads and serves
        let run = e.run(&acc, &inputs, Target::DynamicOverlay).unwrap();
        assert_eq!(run.output.as_scalar(), Some(n as f32));

        // region dead on exec 1: tile quarantined for good
        let mut e = engine();
        let acc = compile(&e, &comp);
        e.faults =
            FaultPlane::from_spec(FaultSpec { region_dead: vec![1], ..FaultSpec::default() });
        let victim = acc.placement().assignments[0].tile;
        let err = e.run(&acc, &inputs, Target::DynamicOverlay).unwrap_err();
        assert!(
            matches!(err, Error::TileFault { tile, permanent: true } if tile == victim),
            "got {err:?}"
        );
        assert_eq!(e.fabric.quarantined_tiles(), 1);
        assert!(e.plan_touches_quarantine(&acc.plan), "dead plan must read as a miss");
        // respecializing around the dead region still serves the request
        let plan = Jit.place_onto(&e.fabric, &acc.spec).unwrap();
        let moved = CompiledAccelerator { spec: acc.spec.clone(), plan: plan.into() };
        assert!(!e.plan_touches_quarantine(&moved.plan));
        let run = e.run(&moved, &inputs, Target::DynamicOverlay).unwrap();
        assert_eq!(run.output.as_scalar(), Some(n as f32));
    }

    #[test]
    fn wrong_input_count_rejected() {
        let mut e = engine();
        let comp = Composition::vmul_reduce(64);
        let acc = compile(&e, &comp);
        assert!(e.run(&acc, &[vec![0.0; 64]], Target::DynamicOverlay).is_err());
    }
}
