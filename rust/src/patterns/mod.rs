//! The programmer-facing parallel-pattern API.
//!
//! This is the paper's programming model: *"Programmers access libraries of
//! pre-synthesized parallel patterns such as map, reduce, foreach, and
//! filter"* and compose them symbolically; the JIT turns the composition
//! into controller instructions — compilation instead of synthesis.
//!
//! A [`Composition`] is a small dataflow expression over external input
//! vectors. [`Composition::stages`] linearizes it into the stage pipeline
//! the JIT places onto tiles; [`Composition::cache_key`] is the identity
//! the coordinator's accelerator cache uses.

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};

use crate::bitstream::OperatorKind;
use crate::error::{Error, Result};

/// A pattern expression (linear pipelines + the branch diamond).
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    /// External input vector, by channel index.
    Input(u8),
    /// Map one unary operator over the upstream stream.
    Map { op: OperatorKind, x: Box<Expr> },
    /// Element-wise binary operator; `y` must be `Input` or `Scalar`-like
    /// (linear pipelines: one flowing operand).
    Zip { op: OperatorKind, x: Box<Expr>, y: Box<Expr> },
    /// Broadcast scalar (thresholds, α) — materialized as a 1-word channel.
    Scalar(f32),
    /// Reduce the upstream stream to a scalar sum.
    Reduce { x: Box<Expr> },
    /// Mask-filter: forward x where `x > t`, else 0.
    FilterGt { t: f32, x: Box<Expr> },
    /// Speculative if-then-else map: `x > t ? then_op(x) : else_op(x)`.
    Branch { t: f32, then_op: OperatorKind, else_op: OperatorKind, x: Box<Expr> },
}

/// One linearized pipeline stage.
#[derive(Debug, Clone, PartialEq)]
pub struct Stage {
    pub op: OperatorKind,
    pub sources: Vec<Source>,
    /// True for the reduce stage (VecAcc instead of VecRun).
    pub is_reduce: bool,
    /// Fused tail operator: applied element-wise after `op` inside the
    /// same tile. `None` everywhere except stages produced by the JIT's
    /// fusion pass (`Jit::frontend_with`); linearization never sets it.
    pub fused: Option<OperatorKind>,
}

/// Where a stage operand comes from.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Source {
    /// DMA from external channel `chan`.
    External { chan: u8 },
    /// The output stream of a previous stage, delivered on-fabric.
    Stage { index: usize, slot: u8 },
    /// A broadcast scalar (materialized as a synthetic 1-word channel).
    Scalar { value_bits: u32 },
}

impl Source {
    pub fn scalar(v: f32) -> Source {
        Source::Scalar { value_bits: v.to_bits() }
    }
    pub fn scalar_value(&self) -> Option<f32> {
        match self {
            Source::Scalar { value_bits } => Some(f32::from_bits(*value_bits)),
            _ => None,
        }
    }
}

/// A validated composition: expression + workload length.
#[derive(Debug, Clone, PartialEq)]
pub struct Composition {
    pub expr: Expr,
    /// Elements per input vector.
    pub n: usize,
    /// Number of external input channels the expression references.
    pub inputs: u8,
}

impl Composition {
    /// Validate and wrap an expression for vectors of length `n`.
    pub fn new(expr: Expr, n: usize) -> Result<Composition> {
        if n == 0 {
            return Err(Error::Pattern("workload length must be positive".into()));
        }
        let mut max_input: i32 = -1;
        check(&expr, &mut max_input, false)?;
        Ok(Composition { expr, n, inputs: (max_input + 1) as u8 })
    }

    /// Does the composition end in a scalar (reduce) result?
    pub fn scalar_result(&self) -> bool {
        matches!(self.expr, Expr::Reduce { .. })
    }

    /// Linearize into the stage pipeline the placer/codegen consume.
    ///
    /// Stages are emitted leaves-first; stage *i*'s flowing operand is
    /// stage *i−1* (delivered on-fabric at slot 0) unless it reads directly
    /// from an external channel. The branch diamond expands to
    /// `[pred(Sub), then, else, Select]` with slot-tagged deliveries.
    pub fn stages(&self) -> Vec<Stage> {
        let mut out = Vec::new();
        linearize(&self.expr, &mut out);
        out
    }

    /// Operator multiset (for bitstream counting and placement).
    pub fn ops(&self) -> Vec<OperatorKind> {
        self.stages().iter().map(|s| s.op).collect()
    }

    /// Stable identity for the coordinator's accelerator cache.
    pub fn cache_key(&self) -> u64 {
        let mut h = DefaultHasher::new();
        format!("{:?}|{}", self.expr, self.n).hash(&mut h);
        h.finish()
    }

    // ---- convenience constructors (the "symbolic links" of the paper) ----

    /// `sum(Σ a[i] * b[i])` — the headline VMUL&Reduce.
    pub fn vmul_reduce(n: usize) -> Composition {
        Composition::new(
            Expr::Reduce {
                x: Box::new(Expr::Zip {
                    op: OperatorKind::Mul,
                    x: Box::new(Expr::Input(0)),
                    y: Box::new(Expr::Input(1)),
                }),
            },
            n,
        )
        .expect("static expr")
    }

    /// `map(op, x)`.
    pub fn map(op: OperatorKind, n: usize) -> Composition {
        Composition::new(Expr::Map { op, x: Box::new(Expr::Input(0)) }, n).expect("static expr")
    }

    /// A chain of unary maps.
    pub fn chain(ops: &[OperatorKind], n: usize) -> Result<Composition> {
        if ops.is_empty() {
            return Err(Error::Pattern("empty chain".into()));
        }
        let mut e = Expr::Input(0);
        for &op in ops {
            e = Expr::Map { op, x: Box::new(e) };
        }
        Composition::new(e, n)
    }

    /// `sum(x[i] where x[i] > t)` — filter → reduce.
    pub fn filter_reduce(t: f32, n: usize) -> Composition {
        Composition::new(
            Expr::Reduce { x: Box::new(Expr::FilterGt { t, x: Box::new(Expr::Input(0)) }) },
            n,
        )
        .expect("static expr")
    }

    /// `α·x + y` — the foreach/AXPY pattern.
    pub fn axpy(alpha: f32, n: usize) -> Composition {
        Composition::new(
            Expr::Zip {
                op: OperatorKind::Add,
                x: Box::new(Expr::Zip {
                    op: OperatorKind::Mul,
                    x: Box::new(Expr::Input(0)),
                    y: Box::new(Expr::Scalar(alpha)),
                }),
                y: Box::new(Expr::Input(1)),
            },
            n,
        )
        .expect("static expr")
    }

    /// Speculative conditional map.
    pub fn branch(t: f32, then_op: OperatorKind, else_op: OperatorKind, n: usize) -> Composition {
        Composition::new(
            Expr::Branch { t, then_op, else_op, x: Box::new(Expr::Input(0)) },
            n,
        )
        .expect("static expr")
    }
}

/// Parse the CLI/wire pattern grammar into a [`Composition`]:
///
/// ```text
/// vmul-reduce | map:OP | chain:OP,OP,.. | filter-reduce:T | axpy:A | branch:T,THEN,ELSE
/// ```
///
/// Shared by `repro run`/`repro inspect` and the socket serving tier,
/// where it is the *whole* untrusted-request surface: a hostile pattern
/// string must come back as an [`Error::Pattern`], never a panic.
pub fn parse_pattern(s: &str, n: usize) -> Result<Composition> {
    let parse_op = |name: &str| -> Result<OperatorKind> {
        OperatorKind::from_name(name)
            .ok_or_else(|| Error::Pattern(format!("unknown operator `{name}`")))
    };
    let parse_f32 = |v: &str, what: &str| -> Result<f32> {
        v.parse().map_err(|_| Error::Pattern(format!("{what}: bad number `{v}`")))
    };
    // the convenience constructors expect() their validation (their shapes
    // are static); parsed input goes through Composition::new so a bad op
    // arity or n == 0 surfaces as Err, not a panic
    if n == 0 {
        return Err(Error::Pattern("workload length must be positive".into()));
    }
    if s == "vmul-reduce" {
        return Ok(Composition::vmul_reduce(n));
    }
    if let Some(op) = s.strip_prefix("map:") {
        return Composition::new(
            Expr::Map { op: parse_op(op)?, x: Box::new(Expr::Input(0)) },
            n,
        );
    }
    if let Some(ops) = s.strip_prefix("chain:") {
        let ops: Vec<OperatorKind> = ops.split(',').map(parse_op).collect::<Result<_>>()?;
        return Composition::chain(&ops, n);
    }
    if let Some(t) = s.strip_prefix("filter-reduce:") {
        return Ok(Composition::filter_reduce(parse_f32(t, "filter-reduce")?, n));
    }
    if let Some(a) = s.strip_prefix("axpy:") {
        return Ok(Composition::axpy(parse_f32(a, "axpy")?, n));
    }
    if let Some(rest) = s.strip_prefix("branch:") {
        let parts: Vec<&str> = rest.split(',').collect();
        if parts.len() != 3 {
            return Err(Error::Pattern("branch needs <t>,<then>,<else>".into()));
        }
        return Composition::new(
            Expr::Branch {
                t: parse_f32(parts[0], "branch")?,
                then_op: parse_op(parts[1])?,
                else_op: parse_op(parts[2])?,
                x: Box::new(Expr::Input(0)),
            },
            n,
        );
    }
    Err(Error::Pattern(format!(
        "unknown pattern `{s}` (try vmul-reduce, map:sqrt, chain:abs,sqrt, \
         filter-reduce:0.5, axpy:2.0, branch:0.0,sqrt,square)"
    )))
}

fn check(e: &Expr, max_input: &mut i32, scalar_pos: bool) -> Result<()> {
    match e {
        Expr::Input(c) => {
            *max_input = (*max_input).max(*c as i32);
            Ok(())
        }
        Expr::Scalar(_) => {
            if scalar_pos {
                Ok(())
            } else {
                Err(Error::Pattern("scalar only allowed as a zip operand".into()))
            }
        }
        Expr::Map { op, x } => {
            if op.arity() != 1 {
                return Err(Error::Pattern(format!("map needs unary op, got {}", op.name())));
            }
            check(x, max_input, false)
        }
        Expr::Zip { op, x, y } => {
            if op.arity() != 2 {
                return Err(Error::Pattern(format!("zip needs binary op, got {}", op.name())));
            }
            // linear pipeline restriction: y must be a leaf
            match **y {
                Expr::Input(_) | Expr::Scalar(_) => {}
                _ => {
                    return Err(Error::Pattern(
                        "zip's second operand must be an input or scalar (linear pipelines)"
                            .into(),
                    ))
                }
            }
            check(x, max_input, false)?;
            check(y, max_input, true)
        }
        Expr::Reduce { x } | Expr::FilterGt { x, .. } => check(x, max_input, false),
        Expr::Branch { then_op, else_op, x, .. } => {
            for op in [then_op, else_op] {
                if op.arity() != 1 {
                    return Err(Error::Pattern(format!(
                        "branch arms must be unary, got {}",
                        op.name()
                    )));
                }
            }
            // branch input must be a leaf: the diamond fans the raw channel out
            match **x {
                Expr::Input(_) => check(x, max_input, false),
                _ => Err(Error::Pattern(
                    "branch input must be an external channel (diamond fan-out)".into(),
                )),
            }
        }
    }
}

/// Returns the index of the stage producing `e`'s stream.
fn linearize(e: &Expr, out: &mut Vec<Stage>) -> usize {
    match e {
        Expr::Input(c) => {
            // a bare input flowing into stage k is expressed as that
            // stage's External source; emit a pseudo Route stage only if the
            // whole expression is just an input (not a useful accelerator).
            out.push(Stage {
                op: OperatorKind::Route,
                sources: vec![Source::External { chan: *c }],
                is_reduce: false,
                fused: None,
            });
            out.len() - 1
        }
        Expr::Scalar(v) => {
            out.push(Stage {
                op: OperatorKind::Route,
                sources: vec![Source::scalar(*v)],
                is_reduce: false,
                fused: None,
            });
            out.len() - 1
        }
        Expr::Map { op, x } => {
            let src = flowing_source(x, out);
            out.push(Stage { op: *op, sources: vec![src], is_reduce: false, fused: None });
            out.len() - 1
        }
        Expr::Zip { op, x, y } => {
            let xs = flowing_source(x, out);
            let ys = leaf_source(y);
            out.push(Stage { op: *op, sources: vec![xs, ys], is_reduce: false, fused: None });
            out.len() - 1
        }
        Expr::Reduce { x } => {
            let src = flowing_source(x, out);
            out.push(Stage {
                op: OperatorKind::AccSum,
                sources: vec![src],
                is_reduce: true,
                fused: None,
            });
            out.len() - 1
        }
        Expr::FilterGt { t, x } => {
            let src = flowing_source(x, out);
            out.push(Stage {
                op: OperatorKind::FilterGt,
                sources: vec![src, Source::scalar(*t)],
                is_reduce: false,
                fused: None,
            });
            out.len() - 1
        }
        Expr::Branch { t, then_op, else_op, x } => {
            let chan = match **x {
                Expr::Input(c) => c,
                _ => unreachable!("validated: branch input is a channel"),
            };
            // pred = x - t  (pred > 0 ⇔ x > t)
            out.push(Stage {
                op: OperatorKind::Sub,
                sources: vec![Source::External { chan }, Source::scalar(*t)],
                is_reduce: false,
                fused: None,
            });
            let pred = out.len() - 1;
            out.push(Stage {
                op: *then_op,
                sources: vec![Source::External { chan }],
                is_reduce: false,
                fused: None,
            });
            let then_i = out.len() - 1;
            out.push(Stage {
                op: *else_op,
                sources: vec![Source::External { chan }],
                is_reduce: false,
                fused: None,
            });
            let else_i = out.len() - 1;
            out.push(Stage {
                op: OperatorKind::Select,
                sources: vec![
                    Source::Stage { index: pred, slot: 0 },
                    Source::Stage { index: then_i, slot: 1 },
                    Source::Stage { index: else_i, slot: 2 },
                ],
                is_reduce: false,
                fused: None,
            });
            out.len() - 1
        }
    }
}

/// Source for a stage whose flowing operand is `e`: either a direct
/// external/scalar leaf, or the on-fabric stream of the stage producing it.
fn flowing_source(e: &Expr, out: &mut Vec<Stage>) -> Source {
    match e {
        Expr::Input(c) => Source::External { chan: *c },
        Expr::Scalar(v) => Source::scalar(*v),
        other => {
            let idx = linearize(other, out);
            Source::Stage { index: idx, slot: 0 }
        }
    }
}

fn leaf_source(e: &Expr) -> Source {
    match e {
        Expr::Input(c) => Source::External { chan: *c },
        Expr::Scalar(v) => Source::scalar(*v),
        _ => unreachable!("validated: zip second operand is a leaf"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vmul_reduce_is_two_stages() {
        let c = Composition::vmul_reduce(4096);
        let stages = c.stages();
        assert_eq!(stages.len(), 2);
        assert_eq!(stages[0].op, OperatorKind::Mul);
        assert_eq!(
            stages[0].sources,
            vec![Source::External { chan: 0 }, Source::External { chan: 1 }]
        );
        assert_eq!(stages[1].op, OperatorKind::AccSum);
        assert!(stages[1].is_reduce);
        assert_eq!(stages[1].sources, vec![Source::Stage { index: 0, slot: 0 }]);
        assert!(c.scalar_result());
        assert_eq!(c.inputs, 2);
    }

    #[test]
    fn chain_linearizes_in_order() {
        let c = Composition::chain(
            &[OperatorKind::Abs, OperatorKind::Sqrt, OperatorKind::Log],
            1024,
        )
        .unwrap();
        let ops: Vec<_> = c.stages().iter().map(|s| s.op).collect();
        assert_eq!(ops, vec![OperatorKind::Abs, OperatorKind::Sqrt, OperatorKind::Log]);
        assert!(!c.scalar_result());
    }

    #[test]
    fn filter_reduce_stages() {
        let c = Composition::filter_reduce(0.5, 2048);
        let stages = c.stages();
        assert_eq!(stages.len(), 2);
        assert_eq!(stages[0].op, OperatorKind::FilterGt);
        assert_eq!(stages[0].sources[1].scalar_value(), Some(0.5));
        assert!(stages[1].is_reduce);
    }

    #[test]
    fn axpy_stages() {
        let c = Composition::axpy(2.5, 512);
        let stages = c.stages();
        assert_eq!(stages.len(), 2);
        assert_eq!(stages[0].op, OperatorKind::Mul);
        assert_eq!(stages[0].sources[1].scalar_value(), Some(2.5));
        assert_eq!(stages[1].op, OperatorKind::Add);
        assert_eq!(stages[1].sources[1], Source::External { chan: 1 });
        assert_eq!(c.inputs, 2);
    }

    #[test]
    fn branch_expands_to_diamond() {
        let c = Composition::branch(0.0, OperatorKind::Sqrt, OperatorKind::Square, 256);
        let stages = c.stages();
        assert_eq!(stages.len(), 4);
        assert_eq!(stages[0].op, OperatorKind::Sub); // predicate
        assert_eq!(stages[3].op, OperatorKind::Select);
        let slots: Vec<u8> = stages[3]
            .sources
            .iter()
            .map(|s| match s {
                Source::Stage { slot, .. } => *slot,
                _ => panic!("select sources must be stages"),
            })
            .collect();
        assert_eq!(slots, vec![0, 1, 2]);
    }

    #[test]
    fn nonlinear_zip_rejected() {
        // zip whose second operand is itself a map — not a linear pipeline
        let e = Expr::Zip {
            op: OperatorKind::Add,
            x: Box::new(Expr::Input(0)),
            y: Box::new(Expr::Map { op: OperatorKind::Abs, x: Box::new(Expr::Input(1)) }),
        };
        assert!(Composition::new(e, 64).is_err());
    }

    #[test]
    fn map_with_binary_op_rejected() {
        let e = Expr::Map { op: OperatorKind::Add, x: Box::new(Expr::Input(0)) };
        assert!(Composition::new(e, 64).is_err());
    }

    #[test]
    fn zero_length_rejected() {
        assert!(Composition::new(Expr::Input(0), 0).is_err());
    }

    #[test]
    fn bare_scalar_rejected() {
        assert!(Composition::new(Expr::Scalar(1.0), 64).is_err());
    }

    #[test]
    fn cache_key_distinguishes_compositions() {
        let a = Composition::vmul_reduce(4096);
        let b = Composition::vmul_reduce(1024);
        let c = Composition::filter_reduce(0.0, 4096);
        assert_ne!(a.cache_key(), b.cache_key());
        assert_ne!(a.cache_key(), c.cache_key());
        assert_eq!(a.cache_key(), Composition::vmul_reduce(4096).cache_key());
    }

    #[test]
    fn parse_pattern_covers_the_grammar() {
        assert!(parse_pattern("vmul-reduce", 64).unwrap().scalar_result());
        assert_eq!(parse_pattern("map:abs", 64).unwrap().ops(), vec![OperatorKind::Abs]);
        assert_eq!(
            parse_pattern("chain:abs,sqrt", 64).unwrap().ops(),
            vec![OperatorKind::Abs, OperatorKind::Sqrt]
        );
        assert!(parse_pattern("filter-reduce:0.5", 64).unwrap().scalar_result());
        assert_eq!(parse_pattern("axpy:2.0", 64).unwrap().inputs, 2);
        assert_eq!(parse_pattern("branch:0.0,sqrt,square", 64).unwrap().stages().len(), 4);
        // parsed == constructed: the wire path hits the same cache keys
        assert_eq!(
            parse_pattern("vmul-reduce", 256).unwrap().cache_key(),
            Composition::vmul_reduce(256).cache_key()
        );
    }

    /// Untrusted-surface property: every malformed pattern is an `Err`,
    /// never a panic — the serving tier feeds this straight from the wire.
    #[test]
    fn parse_pattern_rejects_hostile_input_without_panicking() {
        for s in [
            "",
            "nope",
            "map:",
            "map:nope",
            "map:add",                // binary op where unary is required
            "chain:",
            "chain:abs,nope",
            "filter-reduce:",
            "filter-reduce:xyz",
            "axpy:NaN-ish",
            "branch:0.0",
            "branch:0.0,sqrt",
            "branch:x,sqrt,square",
            "branch:0.0,add,mul",     // binary arms
        ] {
            assert!(parse_pattern(s, 64).is_err(), "`{s}` must not parse");
        }
        assert!(parse_pattern("vmul-reduce", 0).is_err(), "n = 0 must not panic");
    }

    #[test]
    fn input_count_tracks_max_channel() {
        let c = Composition::axpy(1.0, 8);
        assert_eq!(c.inputs, 2);
        let m = Composition::map(OperatorKind::Abs, 8);
        assert_eq!(m.inputs, 1);
    }
}
