//! One bounded LRU map, three users.
//!
//! [`ClockLru`] is the atomic-clock LRU that used to be spelled twice —
//! once inside `AcceleratorCache` (per shard) and once as the pool's
//! `RouteTable` — and now also backs the per-fabric placement-plan cache.
//! The design point all three share: the *hot* path (lookup, or in-place
//! update of a value with interior mutability) takes only the read lock,
//! because recency lives in a relaxed `AtomicU64` per entry and the clock
//! itself is a relaxed `fetch_add`. The write lock is taken once per
//! brand-new key, where eviction — a scan for the stalest entries — rides
//! on a path that already pays an insert.
//!
//! Eviction granularity is configurable: the accelerator cache evicts one
//! entry at a time (inserts there already pay a JIT compile), while the
//! route table amortizes its O(n) recency scan by dropping the stalest
//! ~1/8 of the table per pass (submitters wait behind its write lock).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{RwLock, RwLockReadGuard, RwLockWriteGuard};

/// A bounded `u64 → V` map with atomic-clock LRU eviction.
///
/// Locks recover from poisoning: every critical section leaves the map in
/// a consistent state (an insert/remove either completed or never
/// happened), so a panicking user cannot leave it logically corrupt.
#[derive(Debug)]
pub struct ClockLru<V> {
    map: RwLock<HashMap<u64, ClockEntry<V>>>,
    /// Monotonic recency clock; ticked under either lock.
    clock: AtomicU64,
    /// Max entries (`usize::MAX` = unbounded). Atomic so a cap can be
    /// raised on a live map ([`ClockLru::raise_capacity`]).
    capacity: AtomicUsize,
    /// Entries removed per eviction pass (≥ 1).
    evict_batch: usize,
}

#[derive(Debug)]
struct ClockEntry<V> {
    value: V,
    last_hit: AtomicU64,
}

impl<V> ClockLru<V> {
    /// A map capped at `capacity` entries (`0` = unbounded), evicting the
    /// single stalest entry when a new key needs room.
    pub fn new(capacity: usize) -> ClockLru<V> {
        Self::with_evict_batch(capacity, 1)
    }

    /// Like [`ClockLru::new`], but each eviction pass drops the stalest
    /// `evict_batch` entries in one scan (amortizes cold-key churn).
    pub fn with_evict_batch(capacity: usize, evict_batch: usize) -> ClockLru<V> {
        ClockLru {
            map: RwLock::new(HashMap::new()),
            clock: AtomicU64::new(0),
            capacity: AtomicUsize::new(if capacity == 0 { usize::MAX } else { capacity }),
            evict_batch: evict_batch.max(1),
        }
    }

    /// Raise the capacity to at least `capacity` (`0` = unbounded). Never
    /// shrinks — shrinking a live map would demand an eviction sweep here
    /// instead of on the insert path.
    pub fn raise_capacity(&self, capacity: usize) {
        let cap = if capacity == 0 { usize::MAX } else { capacity };
        self.capacity.fetch_max(cap, Ordering::Relaxed);
    }

    /// Visit every value under the read lock (no recency bump).
    pub fn for_each(&self, mut f: impl FnMut(&V)) {
        for e in self.read_map().values() {
            f(&e.value);
        }
    }

    /// Visit every `(key, value)` pair under the read lock (no recency
    /// bump). The compactor uses this to find which cached entries must be
    /// republished after residents migrate — it needs the keys to put the
    /// remapped values back.
    pub fn for_each_entry(&self, mut f: impl FnMut(u64, &V)) {
        for (k, e) in self.read_map().iter() {
            f(*k, &e.value);
        }
    }

    fn read_map(&self) -> RwLockReadGuard<'_, HashMap<u64, ClockEntry<V>>> {
        self.map.read().unwrap_or_else(|p| p.into_inner())
    }

    fn write_map(&self) -> RwLockWriteGuard<'_, HashMap<u64, ClockEntry<V>>> {
        self.map.write().unwrap_or_else(|p| p.into_inner())
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Look up `key`, refreshing its LRU recency; `read` runs on the value
    /// under the read lock.
    pub fn get<R>(&self, key: u64, read: impl FnOnce(&V) -> R) -> Option<R> {
        let map = self.read_map();
        map.get(&key).map(|e| {
            e.last_hit.store(self.tick(), Ordering::Relaxed);
            read(&e.value)
        })
    }

    /// Recency-neutral lookup: a probe (e.g. steal-victim scoring) must not
    /// distort the LRU order it is inspecting.
    pub fn peek<R>(&self, key: u64, read: impl FnOnce(&V) -> R) -> Option<R> {
        let map = self.read_map();
        map.get(&key).map(|e| read(&e.value))
    }

    /// Read the most-recently-hit entry without bumping anything (`None`
    /// when empty).
    pub fn most_recent<R>(&self, read: impl FnOnce(&V) -> R) -> Option<R> {
        let map = self.read_map();
        map.values()
            .max_by_key(|e| e.last_hit.load(Ordering::Relaxed))
            .map(|e| read(&e.value))
    }

    /// Insert unless already present — first writer wins, so concurrent
    /// builders of one key converge on a single value. `read` runs on the
    /// entry that ends up in the map (fresh or pre-existing). Returns the
    /// read result plus the number of stale entries evicted to make room.
    pub fn insert_if_absent<R>(
        &self,
        key: u64,
        value: V,
        read: impl FnOnce(&V) -> R,
    ) -> (R, usize) {
        let mut map = self.write_map();
        if let Some(e) = map.get(&key) {
            e.last_hit.store(self.tick(), Ordering::Relaxed);
            return (read(&e.value), 0);
        }
        let evicted = self.evict_for_insert(&mut map);
        map.insert(key, ClockEntry { value, last_hit: AtomicU64::new(self.tick()) });
        (read(&map[&key].value), evicted)
    }

    /// Overwrite-or-insert under the write lock (plan respecialization:
    /// a stale value must be *replaced*, not kept by first-writer-wins).
    /// Returns the number of stale entries evicted to make room.
    pub fn put(&self, key: u64, value: V) -> usize {
        let mut map = self.write_map();
        if let Some(e) = map.get_mut(&key) {
            e.value = value;
            e.last_hit.store(self.tick(), Ordering::Relaxed);
            return 0;
        }
        let evicted = self.evict_for_insert(&mut map);
        map.insert(key, ClockEntry { value, last_hit: AtomicU64::new(self.tick()) });
        evicted
    }

    /// Update an existing value in place — through `&V`, so `V` supplies
    /// interior mutability (the route table's `AtomicUsize`) — on the
    /// *read* lock, falling back to a write-locked insert of `make()` for
    /// a brand-new key. The steady state never serializes readers.
    pub fn update_or_insert(
        &self,
        key: u64,
        update: impl Fn(&V),
        make: impl FnOnce() -> V,
    ) -> usize {
        {
            let map = self.read_map();
            if let Some(e) = map.get(&key) {
                update(&e.value);
                e.last_hit.store(self.tick(), Ordering::Relaxed);
                return 0;
            }
        }
        let mut map = self.write_map();
        if let Some(e) = map.get(&key) {
            update(&e.value);
            e.last_hit.store(self.tick(), Ordering::Relaxed);
            return 0;
        }
        let evicted = self.evict_for_insert(&mut map);
        map.insert(key, ClockEntry { value: make(), last_hit: AtomicU64::new(self.tick()) });
        evicted
    }

    /// Make room for one incoming entry: when the map is at capacity, drop
    /// the stalest `max(evict_batch, overflow)` entries in a single
    /// `select_nth` pass. Returns how many were removed.
    fn evict_for_insert(&self, map: &mut HashMap<u64, ClockEntry<V>>) -> usize {
        let capacity = self.capacity.load(Ordering::Relaxed);
        if map.len() < capacity {
            return 0;
        }
        let overflow = map.len() + 1 - capacity;
        let batch = overflow.max(self.evict_batch).min(map.len());
        let mut entries: Vec<(u64, u64)> = map
            .iter()
            .map(|(k, e)| (e.last_hit.load(Ordering::Relaxed), *k))
            .collect();
        entries.select_nth_unstable(batch - 1);
        for (_, stale_key) in entries.into_iter().take(batch) {
            map.remove(&stale_key);
        }
        batch
    }

    /// Number of live entries.
    pub fn len(&self) -> usize {
        self.read_map().len()
    }

    /// True when nothing has been inserted (or everything was evicted).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_and_insert_roundtrip() {
        let m: ClockLru<u32> = ClockLru::new(0);
        assert!(m.is_empty());
        assert_eq!(m.get(1, |v| *v), None);
        let (winner, evicted) = m.insert_if_absent(1, 10, |v| *v);
        assert_eq!((winner, evicted), (10, 0));
        assert_eq!(m.get(1, |v| *v), Some(10));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn first_writer_wins() {
        let m: ClockLru<u32> = ClockLru::new(0);
        m.insert_if_absent(7, 1, |_| ());
        let (winner, evicted) = m.insert_if_absent(7, 2, |v| *v);
        assert_eq!(winner, 1, "second insert must observe the first value");
        assert_eq!(evicted, 0);
    }

    #[test]
    fn put_overwrites() {
        let m: ClockLru<u32> = ClockLru::new(0);
        assert_eq!(m.put(7, 1), 0);
        assert_eq!(m.put(7, 2), 0);
        assert_eq!(m.get(7, |v| *v), Some(2));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn cap_holds_and_evicts_stalest() {
        const K: usize = 4;
        let m: ClockLru<u64> = ClockLru::new(K);
        for key in 0..K as u64 {
            let (_, evicted) = m.insert_if_absent(key, key, |v| *v);
            assert_eq!(evicted, 0);
        }
        // touch key 0 so key 1 becomes the stalest
        assert!(m.get(0, |_| ()).is_some());
        let mut evictions = 0;
        for key in K as u64..(K + 3) as u64 {
            let (_, evicted) = m.insert_if_absent(key, key, |v| *v);
            evictions += evicted;
            assert!(m.len() <= K, "cap of {K} violated: {}", m.len());
        }
        assert_eq!(m.len(), K);
        assert_eq!(evictions, 3);
        assert!(m.get(0, |_| ()).is_some(), "recently-hit entry must survive");
        assert!(m.get(1, |_| ()).is_none(), "stalest entry must be evicted first");
    }

    #[test]
    fn batch_eviction_drops_a_batch_in_one_pass() {
        let m: ClockLru<u64> = ClockLru::with_evict_batch(16, 4);
        for key in 0..16u64 {
            m.insert_if_absent(key, key, |_| ());
        }
        let (_, evicted) = m.insert_if_absent(100, 100, |v| *v);
        assert_eq!(evicted, 4);
        assert_eq!(m.len(), 13);
        for key in 0..4u64 {
            assert!(m.peek(key, |_| ()).is_none(), "stalest 4 must be gone");
        }
    }

    #[test]
    fn update_or_insert_updates_in_place() {
        use std::sync::atomic::AtomicUsize;
        let m: ClockLru<AtomicUsize> = ClockLru::new(0);
        let evicted = m.update_or_insert(
            3,
            |w| w.store(1, Ordering::Relaxed),
            || AtomicUsize::new(1),
        );
        assert_eq!(evicted, 0);
        m.update_or_insert(3, |w| w.store(9, Ordering::Relaxed), || AtomicUsize::new(0));
        assert_eq!(m.get(3, |w| w.load(Ordering::Relaxed)), Some(9));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn peek_does_not_bump_recency() {
        let m: ClockLru<u64> = ClockLru::new(2);
        m.insert_if_absent(1, 1, |_| ());
        m.insert_if_absent(2, 2, |_| ());
        // peeks at 1 must not protect it: 1 is still the stalest
        for _ in 0..8 {
            assert!(m.peek(1, |_| ()).is_some());
        }
        assert!(m.get(2, |_| ()).is_some());
        m.insert_if_absent(3, 3, |_| ());
        assert!(m.peek(1, |_| ()).is_none(), "peeked-only entry must be evicted");
        assert!(m.peek(2, |_| ()).is_some());
    }

    #[test]
    fn raise_capacity_stops_eviction() {
        let m: ClockLru<u64> = ClockLru::new(2);
        m.insert_if_absent(1, 1, |_| ());
        m.insert_if_absent(2, 2, |_| ());
        m.raise_capacity(4);
        let (_, evicted) = m.insert_if_absent(3, 3, |v| *v);
        assert_eq!(evicted, 0, "raised cap must admit the third entry");
        assert_eq!(m.len(), 3);
        // raising never shrinks
        m.raise_capacity(1);
        let (_, evicted) = m.insert_if_absent(4, 4, |v| *v);
        assert_eq!(evicted, 0);
        assert_eq!(m.len(), 4);
        let mut sum = 0;
        m.for_each(|v| sum += *v);
        assert_eq!(sum, 1 + 2 + 3 + 4);
    }

    #[test]
    fn most_recent_tracks_hits() {
        let m: ClockLru<u64> = ClockLru::new(0);
        assert_eq!(m.most_recent(|v| *v), None);
        m.insert_if_absent(1, 10, |_| ());
        m.insert_if_absent(2, 20, |_| ());
        assert_eq!(m.most_recent(|v| *v), Some(20));
        m.get(1, |_| ());
        assert_eq!(m.most_recent(|v| *v), Some(10));
    }

    #[test]
    fn shareable_across_threads() {
        fn assert_sync<T: Sync + Send>() {}
        assert_sync::<ClockLru<u64>>();
    }

    /// Satellite (ISSUE 5): an eviction batch larger than the capacity must
    /// clamp to the map size — never panic, never evict the incoming entry,
    /// and still respect the cap.
    #[test]
    fn evict_batch_larger_than_capacity_clamps() {
        let m: ClockLru<u64> = ClockLru::with_evict_batch(2, 8);
        m.insert_if_absent(1, 1, |_| ());
        m.insert_if_absent(2, 2, |_| ());
        // at capacity with batch 8 > len 2: the pass clears the whole map,
        // then the new entry lands — it must never evict itself
        let (won, evicted) = m.insert_if_absent(3, 3, |v| *v);
        assert_eq!((won, evicted), (3, 2), "batch clamps to the 2 evictable entries");
        assert_eq!(m.len(), 1);
        assert_eq!(m.get(3, |v| *v), Some(3));
        // same clamp on the overwrite path
        let m: ClockLru<u64> = ClockLru::with_evict_batch(1, 100);
        assert_eq!(m.put(1, 1), 0);
        assert_eq!(m.put(2, 2), 1);
        assert_eq!(m.get(2, |v| *v), Some(2));
        assert_eq!(m.len(), 1);
    }

    /// Satellite (ISSUE 5): `insert_if_absent` under thread contention —
    /// exactly one value wins per key and every racer observes the winner
    /// (the shared-cache "racing compilers converge" guarantee).
    #[test]
    fn insert_if_absent_converges_under_contention() {
        const THREADS: usize = 8;
        const KEYS: u64 = 16;
        let m: ClockLru<u64> = ClockLru::new(0);
        let observed: Vec<Vec<u64>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..THREADS as u64)
                .map(|tid| {
                    let m = &m;
                    scope.spawn(move || {
                        (0..KEYS)
                            .map(|key| {
                                // each thread proposes its own value; the
                                // read sees whoever won
                                let (winner, _) = m.insert_if_absent(
                                    key,
                                    tid * 1000 + key,
                                    |v| *v,
                                );
                                winner
                            })
                            .collect()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("racer")).collect()
        });
        assert_eq!(m.len(), KEYS as usize);
        for key in 0..KEYS {
            let final_value = m.peek(key, |v| *v).expect("key present");
            for per_thread in &observed {
                assert_eq!(
                    per_thread[key as usize], final_value,
                    "a racer observed a value that did not win key {key}"
                );
            }
        }
    }

    /// Satellite (ISSUE 5): `most_recent` stays coherent after a full
    /// eviction cycle replaces every original entry.
    #[test]
    fn most_recent_after_a_full_eviction_cycle() {
        const K: u64 = 4;
        let m: ClockLru<u64> = ClockLru::new(K as usize);
        for key in 0..K {
            m.insert_if_absent(key, key * 10, |_| ());
        }
        assert_eq!(m.most_recent(|v| *v), Some((K - 1) * 10));
        // a full cycle: K fresh keys evict all K originals one by one
        for key in K..2 * K {
            m.insert_if_absent(key, key * 10, |_| ());
        }
        assert_eq!(m.len(), K as usize);
        for key in 0..K {
            assert!(m.peek(key, |_| ()).is_none(), "original {key} must be evicted");
        }
        // the newest insert is the most recent …
        assert_eq!(m.most_recent(|v| *v), Some((2 * K - 1) * 10));
        // … until a survivor is *hit*, which retakes the crown
        assert!(m.get(K, |_| ()).is_some());
        assert_eq!(m.most_recent(|v| *v), Some(K * 10));
    }
}
