//! The run-time coordinator: the paper's "run time interpreter" as a
//! service.
//!
//! Responsibilities:
//! * **accelerator cache** — compiled accelerators keyed by composition
//!   hash; a repeat request skips the JIT entirely;
//! * **reconfiguration-aware batching** — the scheduler reorders a batch to
//!   group requests that use the same accelerator, so the fabric is
//!   reconfigured once per *group* instead of once per request (the
//!   PR overhead is the dynamic overlay's only penalty — amortizing it is
//!   the whole game);
//! * **metrics** — counters a deployment would alarm on.
//!
//! [`Coordinator`] is the synchronous core; [`serve`]/[`spawn_service`]
//! wrap it in an mpsc request loop on a dedicated thread, and [`pool`]
//! scales it out to N workers — each owning its own fabric — behind an
//! affinity scheduler with bounded queues, reconfiguration-aware burst
//! draining ([`Coordinator::serve_burst`]) and work-stealing (used by
//! `repro serve --workers N`).

pub mod metrics;
pub mod pool;

pub use metrics::{AtomicMetrics, Metrics};
pub use pool::{PoolReport, WorkerPool};

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

use crate::config::{OverlayConfig, ServiceConfig};
use crate::error::Result;
use crate::exec::{Engine, RunResult};
use crate::jit::{CompiledAccelerator, Jit};
use crate::patterns::Composition;
use crate::timing::Target;

/// Sharded, read-mostly cache of compiled accelerators, keyed by
/// [`Composition::cache_key`].
///
/// Shared across every worker of a [`WorkerPool`]: a composition JIT-ed on
/// one fabric is immediately *usable* on all others — tile indices and
/// region classes are identical across fabrics of one config, and the PR
/// manager simply overwrites whatever is resident in the placement's
/// tiles. Note the placement reflects the *compiling* fabric's occupancy
/// at compile time: replayed on a different fabric it may overwrite
/// residents even when free tiles exist there. Affinity routing keeps that
/// rare (a composition normally stays on the fabric that compiled it);
/// per-fabric placement specialization is a ROADMAP item. Sharding keeps
/// writer stalls local to one key-slice while the hot path — repeat
/// compositions — takes only a read lock.
///
/// The cache is LRU-capped (satellite of ISSUE 3): `capacity` entries,
/// enforced per shard as `ceil(capacity / shards)` (`0` = unbounded) — so
/// the bound is approximate under skewed key distributions; one shard
/// gives an exact cap. Recency is tracked with a relaxed atomic clock so
/// `get` bumps an entry's timestamp under the *read* lock; eviction scans
/// its shard for the stalest entry at insert time, which is O(shard size)
/// on a path that already pays a JIT compile. Shard locks recover from
/// poisoning — an insert/remove either completed or never happened, so a
/// panicking worker cannot leave a shard logically corrupt, and must not
/// cascade its panic into every other worker sharing the cache.
#[derive(Debug)]
pub struct AcceleratorCache {
    shards: Vec<RwLock<HashMap<u64, CacheEntry>>>,
    /// Per-shard entry cap (`usize::MAX` = unbounded).
    shard_capacity: usize,
    /// Monotonic recency clock shared by every shard.
    clock: AtomicU64,
}

#[derive(Debug)]
struct CacheEntry {
    acc: Arc<CompiledAccelerator>,
    last_hit: AtomicU64,
}

impl AcceleratorCache {
    /// Build an unbounded cache with `shards` independent lock domains (≥ 1).
    pub fn new(shards: usize) -> AcceleratorCache {
        Self::bounded(shards, 0)
    }

    /// Build a cache capped at `capacity` total entries (`0` = unbounded),
    /// split evenly across `shards` lock domains (≥ 1).
    pub fn bounded(shards: usize, capacity: usize) -> AcceleratorCache {
        let shards = shards.max(1);
        let shard_capacity = if capacity == 0 {
            usize::MAX
        } else {
            // ceil(capacity / shards) — spelled without the (a + b - 1) / b
            // idiom because usize::div_ceil needs Rust 1.73 and the crate's
            // MSRV is 1.70 — so per-shard caps sum to ≥ capacity and a
            // single-shard cache caps at exactly `capacity`
            (capacity / shards + usize::from(capacity % shards != 0)).max(1)
        };
        AcceleratorCache {
            shards: (0..shards).map(|_| RwLock::new(HashMap::new())).collect(),
            shard_capacity,
            clock: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: u64) -> &RwLock<HashMap<u64, CacheEntry>> {
        &self.shards[(key % self.shards.len() as u64) as usize]
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Look up a compiled accelerator, refreshing its LRU recency.
    pub fn get(&self, key: u64) -> Option<Arc<CompiledAccelerator>> {
        let shard = self.shard(key).read().unwrap_or_else(|p| p.into_inner());
        shard.get(&key).map(|e| {
            e.last_hit.store(self.tick(), Ordering::Relaxed);
            e.acc.clone()
        })
    }

    /// Insert unless already present; returns the winning entry (first
    /// writer wins, so concurrent compilers converge on one accelerator)
    /// plus the number of least-recently-hit entries evicted to make room
    /// (0 or 1 today).
    pub fn insert(
        &self,
        key: u64,
        acc: Arc<CompiledAccelerator>,
    ) -> (Arc<CompiledAccelerator>, usize) {
        let mut shard = self.shard(key).write().unwrap_or_else(|p| p.into_inner());
        if let Some(existing) = shard.get(&key) {
            existing.last_hit.store(self.tick(), Ordering::Relaxed);
            return (existing.acc.clone(), 0);
        }
        let mut evicted = 0;
        while shard.len() >= self.shard_capacity {
            let stalest = shard
                .iter()
                .min_by_key(|(_, e)| e.last_hit.load(Ordering::Relaxed))
                .map(|(k, _)| *k)
                .expect("shard at capacity is nonempty");
            shard.remove(&stalest);
            evicted += 1;
        }
        let entry = CacheEntry { acc: acc.clone(), last_hit: AtomicU64::new(self.tick()) };
        shard.insert(key, entry);
        (acc, evicted)
    }

    /// Number of cached accelerators across all shards.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.read().unwrap_or_else(|p| p.into_inner()).len())
            .sum()
    }

    /// True when nothing has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One unit of work.
#[derive(Debug, Clone)]
pub struct Request {
    pub comp: Composition,
    pub inputs: Vec<Vec<f32>>,
    pub target: Target,
}

impl Request {
    pub fn dynamic(comp: Composition, inputs: Vec<Vec<f32>>) -> Request {
        Request { comp, inputs, target: Target::DynamicOverlay }
    }
}

/// A served response.
#[derive(Debug, Clone)]
pub struct Response {
    pub run: RunResult,
    /// JIT compile time for this request (0 on accelerator-cache hits).
    pub jit_seconds: f64,
    /// Did the accelerator cache hit?
    pub cached: bool,
}

/// The coordinator service core: one fabric, one JIT, one metrics record.
///
/// The accelerator cache is always an [`AcceleratorCache`] behind an `Arc`;
/// a standalone coordinator owns a private one, while pool workers share a
/// single instance (see [`Coordinator::with_cache`]).
pub struct Coordinator {
    pub engine: Engine,
    jit: Jit,
    cache: Arc<AcceleratorCache>,
    pub metrics: Metrics,
}

impl Coordinator {
    pub fn new(cfg: OverlayConfig) -> Result<Coordinator> {
        let service = ServiceConfig::default();
        let cache = AcceleratorCache::bounded(service.cache_shards, service.cache_capacity);
        Self::with_cache(cfg, Arc::new(cache))
    }

    /// Build a coordinator serving from a shared (pool-wide) cache.
    pub fn with_cache(cfg: OverlayConfig, cache: Arc<AcceleratorCache>) -> Result<Coordinator> {
        Ok(Coordinator { engine: Engine::new(cfg)?, jit: Jit, cache, metrics: Metrics::default() })
    }

    /// Compile (or fetch) the accelerator for a composition.
    ///
    /// Compilation sees the fabric's *current* occupancy, so co-residency
    /// is exploited when capacity allows (different accelerators land on
    /// disjoint tiles and never evict each other). When the placer runs out
    /// of tiles, the coordinator evicts all residents and recompiles against
    /// the empty fabric — the PR manager will re-download on demand (this is
    /// the thrash the batcher exists to amortize).
    pub fn accelerator(
        &mut self,
        comp: &Composition,
    ) -> Result<(Arc<CompiledAccelerator>, f64, bool)> {
        let key = comp.cache_key();
        if let Some(acc) = self.cache.get(key) {
            self.metrics.cache_hits += 1;
            return Ok((acc, 0.0, true));
        }
        let t0 = Instant::now();
        let compiled = match self.jit.compile(&self.engine.fabric, &self.engine.lib, comp) {
            Ok(acc) => acc,
            Err(e) if e.is_capacity() => {
                self.metrics.evictions += 1;
                self.engine.fabric.reset_full();
                self.jit.compile(&self.engine.fabric, &self.engine.lib, comp)?
            }
            Err(e) => return Err(e),
        };
        let dt = t0.elapsed().as_secs_f64();
        self.metrics.jit_compiles += 1;
        self.metrics.jit_seconds += dt;
        // first writer wins; a racing worker's duplicate compile converges
        let (acc, evicted) = self.cache.insert(key, Arc::new(compiled));
        self.metrics.lru_evictions += evicted as u64;
        Ok((acc, dt, false))
    }

    /// Serve one request.
    pub fn submit(&mut self, req: &Request) -> Result<Response> {
        let (acc, jit_seconds, cached) = self.accelerator(&req.comp)?;
        let run = self.engine.run(&acc, &req.inputs, req.target)?;
        self.metrics.requests += 1;
        if let Some(r) = run.reconfig {
            self.metrics.pr_downloads += r.downloads as u64;
            self.metrics.pr_region_hits += r.cache_hits as u64;
            self.metrics.pr_replaced += r.replaced as u64;
            self.metrics.pr_seconds += r.seconds;
        }
        self.metrics.busy_seconds += run.timing.total();
        Ok(Response { run, jit_seconds, cached })
    }

    /// Reconfiguration-aware batch schedule: stable-group requests by
    /// composition key. Returns the execution order (indices into `reqs`).
    pub fn schedule(reqs: &[Request]) -> Vec<usize> {
        let keys: Vec<u64> = reqs.iter().map(|r| r.comp.cache_key()).collect();
        Self::schedule_keys(&keys)
    }

    /// [`Coordinator::schedule`] over bare composition keys — the form the
    /// pool's drain loop uses, where requests arrive wrapped in [`Job`]s.
    /// Stable: groups are ordered by first arrival and arrival order is
    /// preserved within a group.
    pub fn schedule_keys(keys: &[u64]) -> Vec<usize> {
        let mut first_seen: HashMap<u64, usize> = HashMap::new();
        let mut order: Vec<(usize, usize)> = Vec::with_capacity(keys.len()); // (group, idx)
        for (i, &key) in keys.iter().enumerate() {
            let next_group = first_seen.len();
            let g = *first_seen.entry(key).or_insert(next_group);
            order.push((g, i));
        }
        order.sort(); // stable by (group, arrival)
        order.into_iter().map(|(_, i)| i).collect()
    }

    /// Serve a drained queue window in reconfiguration-minimizing order:
    /// stable-group the jobs by composition key, serve group by group, and
    /// account the burst counters (`bursts`, `burst_group_switches`).
    ///
    /// Replies are **returned, not sent**: each response is paired with its
    /// own request's reply channel (reordering can never cross-wire them),
    /// and the caller delivers after folding the burst's single metrics
    /// delta — so a client that has received a reply always observes that
    /// request in the pool aggregate. A per-request failure becomes that
    /// client's reply and does not abort the rest of the burst.
    pub fn serve_burst(&mut self, jobs: Vec<Job>) -> BurstReplies {
        if jobs.is_empty() {
            return Vec::new();
        }
        let keys: Vec<u64> = jobs.iter().map(|j| j.request.comp.cache_key()).collect();
        let order = Self::schedule_keys(&keys);
        let mut jobs: Vec<Option<Job>> = jobs.into_iter().map(Some).collect();
        let mut replies = Vec::with_capacity(jobs.len());
        let mut prev_key: Option<u64> = None;
        let mut switches = 0u64;
        for i in order {
            let job = jobs[i].take().expect("schedule visits each job once");
            if prev_key.is_some() && prev_key != Some(keys[i]) {
                switches += 1;
            }
            prev_key = Some(keys[i]);
            let resp = self.submit(&job.request);
            replies.push((job.reply, resp));
        }
        self.metrics.bursts += 1;
        self.metrics.burst_group_switches += switches;
        replies
    }

    /// Serve a batch in reconfiguration-minimizing order; returns responses
    /// in the *original* request order.
    pub fn submit_batch(&mut self, reqs: &[Request]) -> Result<Vec<Response>> {
        let order = Self::schedule(reqs);
        let mut out: Vec<Option<Response>> = (0..reqs.len()).map(|_| None).collect();
        for i in order {
            out[i] = Some(self.submit(&reqs[i])?);
        }
        Ok(out.into_iter().map(|r| r.expect("all served")).collect())
    }

    /// Number of cached accelerators.
    pub fn cached_accelerators(&self) -> usize {
        self.cache.len()
    }
}

/// A request plus its reply channel.
pub struct Job {
    pub request: Request,
    pub reply: std::sync::mpsc::Sender<Result<Response>>,
}

/// What [`Coordinator::serve_burst`] hands back: each served job's reply
/// channel with its response, in served (reordered) order, for the caller
/// to deliver after folding metrics.
pub type BurstReplies = Vec<(std::sync::mpsc::Sender<Result<Response>>, Result<Response>)>;

/// Request loop: drain jobs from `rx`, serve them on this thread, return
/// the final metrics when all senders hang up.
///
/// The coordinator is deliberately single-threaded (it owns one fabric, as
/// the controller owns one FPGA); concurrency lives in the callers — spawn
/// this on a dedicated thread and clone the job sender freely.
pub fn serve(mut coord: Coordinator, rx: std::sync::mpsc::Receiver<Job>) -> Metrics {
    while let Ok(job) = rx.recv() {
        let resp = coord.submit(&job.request);
        let _ = job.reply.send(resp);
    }
    coord.metrics
}

/// Spawn [`serve`] on a new thread; returns the job sender and the join
/// handle yielding final metrics.
pub fn spawn_service(
    coord: Coordinator,
) -> (std::sync::mpsc::Sender<Job>, std::thread::JoinHandle<Metrics>) {
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || serve(coord, rx));
    (tx, handle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitstream::OperatorKind;

    fn coord() -> Coordinator {
        Coordinator::new(OverlayConfig::default()).unwrap()
    }

    fn vmul_req(n: usize, seed: f32) -> Request {
        Request::dynamic(
            Composition::vmul_reduce(n),
            vec![vec![seed; n], vec![2.0; n]],
        )
    }

    fn map_req(n: usize) -> Request {
        Request::dynamic(Composition::map(OperatorKind::Abs, n), vec![vec![-1.0; n]])
    }

    #[test]
    fn repeat_requests_hit_accelerator_cache() {
        let mut c = coord();
        let r1 = c.submit(&vmul_req(1024, 1.0)).unwrap();
        let r2 = c.submit(&vmul_req(1024, 3.0)).unwrap();
        assert!(!r1.cached);
        assert!(r2.cached);
        assert_eq!(r2.jit_seconds, 0.0);
        assert_eq!(c.cached_accelerators(), 1);
        assert_eq!(r2.run.output.as_scalar(), Some(3.0 * 2.0 * 1024.0));
    }

    #[test]
    fn schedule_groups_same_composition() {
        let reqs = vec![
            vmul_req(512, 1.0), // A
            map_req(512),       // B
            vmul_req(512, 2.0), // A
            map_req(512),       // B
            vmul_req(512, 3.0), // A
        ];
        let order = Coordinator::schedule(&reqs);
        assert_eq!(order, vec![0, 2, 4, 1, 3]);
    }

    /// Two 5-stage chains cannot co-reside on a 9-tile fabric with the
    /// first one resident (only 4 tiles stay free), so switching between
    /// them forces whole-fabric eviction + re-download — the contention the
    /// batcher amortizes.
    fn chain_a_req(n: usize) -> Request {
        use OperatorKind::*;
        Request::dynamic(
            Composition::chain(&[Neg, Abs, Square, Relu, Neg], n).unwrap(),
            vec![vec![1.5; n]],
        )
    }

    fn chain_b_req(n: usize) -> Request {
        use OperatorKind::*;
        Request::dynamic(
            Composition::chain(&[Abs, Neg, Relu, Square, Abs], n).unwrap(),
            vec![vec![-2.0; n]],
        )
    }

    #[test]
    fn small_accelerators_co_reside_without_thrash() {
        // vmul (2 tiles) and map (1 tile) fit together: after warmup no
        // further downloads, no evictions.
        let mut c = coord();
        for _ in 0..3 {
            c.submit(&vmul_req(512, 1.0)).unwrap();
            c.submit(&map_req(512)).unwrap();
        }
        assert_eq!(c.metrics.evictions, 0);
        assert_eq!(c.metrics.pr_downloads, 3); // 2 (vmul) + 1 (map), once
    }

    #[test]
    fn batched_order_reduces_pr_downloads() {
        // interleaved A,B,A,B,A with conflicting 5-stage chains: naive
        // serving re-downloads on every switch; scheduled serving
        // reconfigures once per group.
        let reqs: Vec<Request> = vec![
            chain_a_req(512),
            chain_b_req(512),
            chain_a_req(512),
            chain_b_req(512),
            chain_a_req(512),
        ];

        let mut naive = coord();
        for r in &reqs {
            naive.submit(r).unwrap();
        }

        let mut batched = coord();
        batched.submit_batch(&reqs).unwrap();

        assert!(
            batched.metrics.pr_downloads < naive.metrics.pr_downloads,
            "batched {} !< naive {}",
            batched.metrics.pr_downloads,
            naive.metrics.pr_downloads
        );
        assert!(naive.metrics.evictions >= 1);
    }

    #[test]
    fn batch_responses_in_original_order() {
        let mut c = coord();
        let reqs = vec![vmul_req(512, 1.0), map_req(512), vmul_req(512, 2.0)];
        let resps = c.submit_batch(&reqs).unwrap();
        assert_eq!(resps.len(), 3);
        assert_eq!(resps[0].run.output.as_scalar(), Some(1024.0));
        assert!(resps[1].run.output.as_vector().is_some());
        assert_eq!(resps[2].run.output.as_scalar(), Some(2048.0));
    }

    #[test]
    fn metrics_accumulate() {
        let mut c = coord();
        c.submit(&vmul_req(512, 1.0)).unwrap();
        c.submit(&vmul_req(512, 1.0)).unwrap();
        assert_eq!(c.metrics.requests, 2);
        assert_eq!(c.metrics.jit_compiles, 1);
        assert_eq!(c.metrics.cache_hits, 1);
        assert!(c.metrics.busy_seconds > 0.0);
    }

    #[test]
    fn threaded_serve_loop_round_trips() {
        let (tx, handle) = spawn_service(coord());
        let (rtx, rrx) = std::sync::mpsc::channel();
        tx.send(Job { request: vmul_req(256, 1.0), reply: rtx }).unwrap();
        let resp = rrx.recv().unwrap().unwrap();
        assert_eq!(resp.run.output.as_scalar(), Some(512.0));
        drop(tx);
        let metrics = handle.join().unwrap();
        assert_eq!(metrics.requests, 1);
    }

    #[test]
    fn service_survives_request_errors() {
        let (tx, handle) = spawn_service(coord());
        // bad request: wrong channel count
        let (rtx, rrx) = std::sync::mpsc::channel();
        tx.send(Job {
            request: Request::dynamic(Composition::vmul_reduce(64), vec![vec![0.0; 64]]),
            reply: rtx,
        })
        .unwrap();
        assert!(rrx.recv().unwrap().is_err());
        // service still alive for a good request
        let (rtx2, rrx2) = std::sync::mpsc::channel();
        tx.send(Job { request: vmul_req(64, 1.0), reply: rtx2 }).unwrap();
        assert!(rrx2.recv().unwrap().is_ok());
        drop(tx);
        handle.join().unwrap();
    }

    #[test]
    fn shared_cache_skips_jit_on_second_coordinator() {
        let cache = Arc::new(AcceleratorCache::new(4));
        let mut a = Coordinator::with_cache(OverlayConfig::default(), cache.clone()).unwrap();
        let mut b = Coordinator::with_cache(OverlayConfig::default(), cache.clone()).unwrap();
        let ra = a.submit(&vmul_req(512, 1.0)).unwrap();
        let rb = b.submit(&vmul_req(512, 2.0)).unwrap();
        assert!(!ra.cached);
        assert!(rb.cached, "second fabric must reuse the shared compile");
        assert_eq!(b.metrics.jit_compiles, 0);
        // but b still pays its own PR downloads — residency is per fabric
        assert_eq!(b.metrics.pr_downloads, 2);
        assert_eq!(cache.len(), 1);
        assert!(!cache.is_empty());
    }

    #[test]
    fn sharded_cache_first_writer_wins() {
        let cache = AcceleratorCache::new(2);
        let e = Engine::new(OverlayConfig::default()).unwrap();
        let comp = Composition::vmul_reduce(128);
        let acc1 = Arc::new(Jit.compile(&e.fabric, &e.lib, &comp).unwrap());
        let acc2 = Arc::new(Jit.compile(&e.fabric, &e.lib, &comp).unwrap());
        let key = comp.cache_key();
        let (won, _) = cache.insert(key, acc1.clone());
        assert!(Arc::ptr_eq(&won, &acc1));
        let (lost, evicted) = cache.insert(key, acc2);
        assert!(Arc::ptr_eq(&lost, &acc1), "second insert must return the first entry");
        assert_eq!(evicted, 0);
        assert!(cache.get(key).is_some());
        assert!(cache.get(key ^ 1).is_none());
    }

    /// Satellite (ISSUE 3): a cap of K holds under K+N distinct
    /// compositions, and the evicted entry is the least-recently-hit one.
    #[test]
    fn lru_cap_holds_and_evicts_stalest() {
        const K: usize = 4;
        let e = Engine::new(OverlayConfig::default()).unwrap();
        let comp = Composition::vmul_reduce(128);
        let acc = Arc::new(Jit.compile(&e.fabric, &e.lib, &comp).unwrap());
        let cache = AcceleratorCache::bounded(1, K);
        for key in 0..K as u64 {
            let (_, evicted) = cache.insert(key, acc.clone());
            assert_eq!(evicted, 0);
            assert!(cache.len() <= K);
        }
        assert_eq!(cache.len(), K);
        // touch key 0 so key 1 becomes the stalest
        assert!(cache.get(0).is_some());
        let mut evictions = 0;
        for key in K as u64..(K + 3) as u64 {
            let (_, evicted) = cache.insert(key, acc.clone());
            evictions += evicted;
            assert!(cache.len() <= K, "cap of {K} violated: {}", cache.len());
        }
        assert_eq!(cache.len(), K);
        assert_eq!(evictions, 3);
        assert!(cache.get(0).is_some(), "recently-hit entry must survive");
        assert!(cache.get(1).is_none(), "least-recently-hit entry must be evicted first");
    }

    /// End-to-end: a capacity-1 coordinator cache recompiles on alternation
    /// and counts its LRU evictions.
    #[test]
    fn coordinator_counts_lru_evictions() {
        let service = ServiceConfig { cache_shards: 1, cache_capacity: 1, ..Default::default() };
        let cache = AcceleratorCache::bounded(service.cache_shards, service.cache_capacity);
        let mut c = Coordinator::with_cache(OverlayConfig::default(), Arc::new(cache)).unwrap();
        c.submit(&vmul_req(256, 1.0)).unwrap();
        c.submit(&map_req(256)).unwrap(); // evicts the vmul accelerator
        c.submit(&vmul_req(256, 1.0)).unwrap(); // recompile, evicts the map
        assert_eq!(c.metrics.jit_compiles, 3);
        assert_eq!(c.metrics.cache_hits, 0);
        assert_eq!(c.metrics.lru_evictions, 2);
        assert_eq!(c.cached_accelerators(), 1);
    }

    #[test]
    fn serve_burst_groups_and_replies_in_pair() {
        let mut c = coord();
        let reqs = vec![vmul_req(512, 1.0), map_req(512), vmul_req(512, 2.0), map_req(512)];
        let mut rxs = Vec::new();
        let jobs: Vec<Job> = reqs
            .into_iter()
            .map(|request| {
                let (rtx, rrx) = std::sync::mpsc::channel();
                rxs.push(rrx);
                Job { request, reply: rtx }
            })
            .collect();
        let replies = c.serve_burst(jobs);
        assert_eq!(replies.len(), 4);
        assert_eq!(c.metrics.bursts, 1);
        // [A, B, A, B] regroups to [A, A, B, B]: exactly one switch
        assert_eq!(c.metrics.burst_group_switches, 1);
        for (tx, resp) in replies {
            tx.send(resp).unwrap();
        }
        // replies pair with their own request channels despite reordering
        let r0 = rxs[0].recv().unwrap().unwrap();
        assert_eq!(r0.run.output.as_scalar(), Some(1024.0));
        let r2 = rxs[2].recv().unwrap().unwrap();
        assert_eq!(r2.run.output.as_scalar(), Some(2048.0));
        assert!(rxs[1].recv().unwrap().unwrap().run.output.as_vector().is_some());
        assert!(rxs[3].recv().unwrap().unwrap().run.output.as_vector().is_some());
    }
}
