//! The run-time coordinator: the paper's "run time interpreter" as a
//! service.
//!
//! Responsibilities:
//! * **accelerator cache** — compiled accelerators keyed by composition
//!   hash; a repeat request skips the JIT entirely;
//! * **reconfiguration-aware batching** — the scheduler reorders a batch to
//!   group requests that use the same accelerator, so the fabric is
//!   reconfigured once per *group* instead of once per request (the
//!   PR overhead is the dynamic overlay's only penalty — amortizing it is
//!   the whole game);
//! * **metrics** — counters a deployment would alarm on.
//!
//! [`Coordinator`] is the synchronous core; [`serve`]/[`spawn_service`]
//! wrap it in an mpsc request loop on a dedicated thread, and [`pool`]
//! scales it out to N workers — each owning its own fabric — behind an
//! affinity scheduler with bounded queues, reconfiguration-aware burst
//! draining ([`Coordinator::serve_burst`]) and work-stealing (used by
//! `repro serve --workers N`). In front of the pool, [`frontend`] is the
//! event-driven session layer: a fixed set of reactor threads multiplexes
//! many client sessions over a shared completion queue with admission
//! control and fairness rotation (`repro serve --frontend reactor`).

pub mod cluster;
pub mod frontend;
pub mod lru;
pub mod metrics;
pub mod net;
pub mod pool;
pub mod wire;

pub use cluster::{Cluster, ClusterReport, HashRing};
pub use frontend::{
    Dispatch, Frontend, FrontendThreads, Reactor, Rejected, SessionHandle, SessionRecv,
    SessionReplies, SessionState, SessionSubmitter,
};
pub use lru::ClockLru;
pub use metrics::{AtomicMetrics, Metrics};
pub use net::{ConnDriver, NetServer, ServerStats, WireStep};
pub use pool::{Completion, CompletionQueue, PoolReport, ReplySink, Ticket, WorkerPool};
pub use wire::{ClientMsg, FrameDecoder, ServerMsg};

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::config::{OverlayConfig, ServiceConfig};
use crate::error::{Error, Result};
use crate::exec::{Engine, RunResult};
use crate::faults::FaultPlane;
use crate::jit::{AcceleratorProgram, CompiledAccelerator, Jit, PlacementPlan, FUSED_KEY_SALT};
use crate::patterns::Composition;
use crate::predict::NextPredictor;
use crate::timing::Target;

/// Default placement plans retained per cached composition — one per
/// fabric that has executed it, LRU-capped so a long-lived cache shared by
/// many short-lived fabrics cannot grow without bound (an evicted plan
/// only costs a placement-only recompile on that fabric's next request).
/// A pool raises this to its worker count so a hot composition touched by
/// every fabric never cycles the plan LRU (see
/// [`AcceleratorCache::with_plan_capacity`]).
const DEFAULT_PLANS_PER_COMPOSITION: usize = 8;

/// Sharded, read-mostly cache of compiled accelerators, keyed by
/// [`Composition::cache_key`].
///
/// Shared across every worker of a [`WorkerPool`]. Each entry is split the
/// way the JIT is split: the fabric-independent
/// [`AcceleratorProgram`] (stages + bitstream class selection — valid on
/// every fabric of a config) plus a small per-fabric map of
/// [`PlacementPlan`]s, because a placement is only valid against the
/// occupancy of the fabric it was compiled for. A composition JIT-ed on
/// one fabric therefore skips the JIT *front end* everywhere, and pays at
/// most a placement-only respecialization the first time it lands on
/// another fabric — it never replays a foreign placement over that
/// fabric's residents (the pre-ISSUE-4 spill bug).
///
/// Structure: both levels — the sharded key map and each entry's plan map
/// — are [`ClockLru`]s, the crate's one bounded-map implementation. The
/// spec level is LRU-capped at `capacity` entries, enforced per shard as
/// `ceil(capacity / shards)` (`0` = unbounded), so the bound is
/// approximate under skewed key distributions; one shard gives an exact
/// cap. Lookups bump recency under the read lock; eviction scans ride the
/// insert path, which already pays a JIT compile. Shard locks recover from
/// poisoning, so a panicking worker cannot leave a shard logically corrupt
/// or cascade into peers sharing the cache.
#[derive(Debug)]
pub struct AcceleratorCache {
    shards: Vec<ClockLru<CachedAccelerator>>,
    /// Cap on each entry's per-fabric plan map (`usize::MAX` = unbounded).
    /// Atomic so [`AcceleratorCache::ensure_plan_capacity`] can raise it on
    /// a live (externally built) cache.
    plan_capacity: std::sync::atomic::AtomicUsize,
}

/// One cached composition: the shared program plus every fabric's
/// specialized placement plan, keyed by [`crate::overlay::Fabric::id`].
#[derive(Debug)]
struct CachedAccelerator {
    spec: Arc<AcceleratorProgram>,
    plans: ClockLru<Arc<PlacementPlan>>,
}

/// What [`AcceleratorCache::lookup`] returns on a spec hit.
pub struct CacheHit {
    /// The shared, fabric-independent program.
    pub spec: Arc<AcceleratorProgram>,
    /// The querying fabric's own specialized plan, if one is cached.
    pub plan: Option<Arc<PlacementPlan>>,
    /// When `plan` is `None`: the most-recently-used *other* fabric's plan
    /// — the placement the pre-split pool-wide cache would have replayed
    /// verbatim. Used to account `Metrics::residency_clobbers_avoided`.
    pub foreign_plan: Option<Arc<PlacementPlan>>,
}

impl AcceleratorCache {
    /// Build an unbounded cache with `shards` independent lock domains (≥ 1).
    pub fn new(shards: usize) -> AcceleratorCache {
        Self::bounded(shards, 0)
    }

    /// Build a cache capped at `capacity` total entries (`0` = unbounded),
    /// split evenly across `shards` lock domains (≥ 1).
    pub fn bounded(shards: usize, capacity: usize) -> AcceleratorCache {
        Self::with_plan_capacity(shards, capacity, DEFAULT_PLANS_PER_COMPOSITION)
    }

    /// [`AcceleratorCache::bounded`] with an explicit cap on each entry's
    /// per-fabric plan map. A pool sizes this to its worker count, so a
    /// composition hot on every fabric holds one plan per fabric without
    /// LRU cycling; `0` = unbounded.
    pub fn with_plan_capacity(
        shards: usize,
        capacity: usize,
        plan_capacity: usize,
    ) -> AcceleratorCache {
        let shards = shards.max(1);
        let shard_capacity = if capacity == 0 {
            0 // ClockLru's own "unbounded" sentinel
        } else {
            // ceil(capacity / shards) — spelled without the (a + b - 1) / b
            // idiom because usize::div_ceil needs Rust 1.73 and the crate's
            // MSRV is 1.70 — so per-shard caps sum to ≥ capacity and a
            // single-shard cache caps at exactly `capacity`
            (capacity / shards + usize::from(capacity % shards != 0)).max(1)
        };
        AcceleratorCache {
            shards: (0..shards).map(|_| ClockLru::new(shard_capacity)).collect(),
            plan_capacity: std::sync::atomic::AtomicUsize::new(if plan_capacity == 0 {
                usize::MAX
            } else {
                plan_capacity
            }),
        }
    }

    /// Raise the per-composition plan cap to at least `fabrics` — one slot
    /// per fabric that will share this cache — for future entries *and*
    /// every already-cached one. Pool construction calls this, so an
    /// externally supplied cache (built with the smaller default cap)
    /// cannot silently cycle a hot composition's plan LRU under a wide
    /// pool. Never shrinks.
    pub fn ensure_plan_capacity(&self, fabrics: usize) {
        self.plan_capacity
            .fetch_max(fabrics.max(1), std::sync::atomic::Ordering::Relaxed);
        for shard in &self.shards {
            shard.for_each(|e| e.plans.raise_capacity(fabrics));
        }
    }

    fn shard(&self, key: u64) -> &ClockLru<CachedAccelerator> {
        &self.shards[(key % self.shards.len() as u64) as usize]
    }

    /// Look up a composition for one fabric, refreshing LRU recency at
    /// both levels.
    pub fn lookup(&self, key: u64, fabric: u64) -> Option<CacheHit> {
        self.shard(key).get(key, |e| {
            let plan = e.plans.get(fabric, Arc::clone);
            let foreign_plan =
                if plan.is_none() { e.plans.most_recent(Arc::clone) } else { None };
            CacheHit { spec: e.spec.clone(), plan, foreign_plan }
        })
    }

    /// Insert a freshly compiled accelerator. First writer wins on the
    /// spec (concurrent compilers converge on one program), but the given
    /// plan always lands in the winner's per-fabric plan map — it was
    /// placed against the caller's live occupancy either way. Returns the
    /// winning accelerator for `plan.fabric` plus the number of LRU
    /// entries evicted (spec-level and plan-level combined).
    pub fn insert(
        &self,
        key: u64,
        spec: Arc<AcceleratorProgram>,
        plan: Arc<PlacementPlan>,
    ) -> (CompiledAccelerator, usize) {
        let fabric = plan.fabric;
        let entry = CachedAccelerator {
            spec,
            plans: ClockLru::new(
                self.plan_capacity.load(std::sync::atomic::Ordering::Relaxed),
            ),
        };
        let ((winner, plan_evicted), spec_evicted) =
            self.shard(key).insert_if_absent(key, entry, |e| {
                (e.spec.clone(), e.plans.put(fabric, plan.clone()))
            });
        (CompiledAccelerator { spec: winner, plan }, spec_evicted + plan_evicted)
    }

    /// Cache a respecialized plan for `plan.fabric` (overwriting any stale
    /// one). Returns plan-level LRU evictions; a no-op when the spec entry
    /// was itself evicted in the meantime.
    pub fn insert_plan(&self, key: u64, plan: Arc<PlacementPlan>) -> usize {
        self.shard(key)
            .get(key, |e| e.plans.put(plan.fabric, plan.clone()))
            .unwrap_or(0)
    }

    /// Recency-neutral probe: does `fabric` already hold a specialized
    /// plan for this composition? (Steal-victim scoring — a probe must not
    /// distort either LRU.)
    pub fn has_plan(&self, key: u64, fabric: u64) -> bool {
        self.shard(key)
            .peek(key, |e| e.plans.peek(fabric, |_| ()).is_some())
            .unwrap_or(false)
    }

    /// Snapshot every cached composition's plan for one fabric (recency
    /// neutral, sorted by key for determinism): `(key, spec, plan)`
    /// triples. The compactor scans these after a migration to republish
    /// the plans whose placements touched a moved tile.
    pub fn plans_for_fabric(
        &self,
        fabric: u64,
    ) -> Vec<(u64, Arc<AcceleratorProgram>, Arc<PlacementPlan>)> {
        let mut out = Vec::new();
        for shard in &self.shards {
            shard.for_each_entry(|key, e| {
                if let Some(plan) = e.plans.peek(fabric, Arc::clone) {
                    out.push((key, e.spec.clone(), plan));
                }
            });
        }
        out.sort_by_key(|&(key, _, _)| key);
        out
    }

    /// Number of cached compositions across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(ClockLru::len).sum()
    }

    /// True when nothing has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One unit of work.
#[derive(Debug, Clone)]
pub struct Request {
    pub comp: Composition,
    pub inputs: Vec<Vec<f32>>,
    pub target: Target,
}

impl Request {
    pub fn dynamic(comp: Composition, inputs: Vec<Vec<f32>>) -> Request {
        Request { comp, inputs, target: Target::DynamicOverlay }
    }
}

/// A served response.
#[derive(Debug, Clone)]
pub struct Response {
    pub run: RunResult,
    /// JIT time paid by this request: a full compile on a cold key, a
    /// placement-only respecialization when a cached accelerator first
    /// lands on this fabric (or its plan went stale), 0 on a full hit.
    pub jit_seconds: f64,
    /// Did the accelerator cache supply the (fabric-independent) program?
    /// True on both full hits and placement-only respecializations.
    pub cached: bool,
}

/// The coordinator service core: one fabric, one JIT, one metrics record.
///
/// The accelerator cache is always an [`AcceleratorCache`] behind an `Arc`;
/// a standalone coordinator owns a private one, while pool workers share a
/// single instance (see [`Coordinator::with_cache`]).
pub struct Coordinator {
    pub engine: Engine,
    jit: Jit,
    cache: Arc<AcceleratorCache>,
    pub metrics: Metrics,
    /// Fusion policy: compile with the fusion pass first, falling back to
    /// the unfused shape (and finally CPU interpretation) when placement
    /// runs out of room. Off by default — the paper's one-operator-per-tile
    /// baseline.
    fuse: bool,
    /// Quarantined-tile count already billed to `metrics.tiles_quarantined`
    /// (the fabric count is a level; the metric is its increments).
    quarantined_seen: usize,
    /// Predictive reconfiguration: learn the request stream's transitions
    /// and prefetch the predicted next accelerator in quiet windows. Off
    /// by default — with it off, no predictor state is touched and the
    /// serve path is bit-identical to the reactive baseline.
    predict: bool,
    /// Online defragmentation in quiet windows. Off by default.
    compact: bool,
    /// The Markov chain over this coordinator's effective cache keys.
    predictor: NextPredictor,
    /// Key staged by the last completed prefetch, not yet claimed by a
    /// request: the next submit scores it as a hit or a waste.
    last_prefetch: Option<u64>,
    /// Tiles of the most recently served accelerator — the "in use" set a
    /// prefetch must never clobber (everything else resident is idle and
    /// fair game for speculation; staleness guards re-place the losers).
    active: Vec<usize>,
}

impl Coordinator {
    pub fn new(cfg: OverlayConfig) -> Result<Coordinator> {
        let service = ServiceConfig::default();
        let cache = AcceleratorCache::bounded(service.cache_shards, service.cache_capacity);
        Self::with_cache(cfg, Arc::new(cache))
    }

    /// Build a coordinator serving from a shared (pool-wide) cache.
    pub fn with_cache(cfg: OverlayConfig, cache: Arc<AcceleratorCache>) -> Result<Coordinator> {
        Ok(Coordinator {
            engine: Engine::new(cfg)?,
            jit: Jit,
            cache,
            metrics: Metrics::default(),
            fuse: false,
            quarantined_seen: 0,
            predict: false,
            compact: false,
            predictor: NextPredictor::default(),
            last_prefetch: None,
            active: Vec::new(),
        })
    }

    /// Install a fault-injection plane (shared across the pool so every
    /// site draws ordinals from one schedule) and the transient-download
    /// retry budget. [`FaultPlane::NoFaults`] restores the zero-cost
    /// default.
    pub fn set_faults(&mut self, plane: Arc<FaultPlane>, download_retries: u32) {
        self.engine.faults = plane;
        self.engine.download_retries = download_retries;
    }

    /// Turn the fusion pass on or off for subsequent requests. Fused and
    /// unfused compiles live under different (salted) cache keys, so
    /// flipping the policy never serves the wrong shape from cache.
    pub fn set_fusion(&mut self, on: bool) {
        self.fuse = on;
    }

    /// Current fusion policy.
    pub fn fusion(&self) -> bool {
        self.fuse
    }

    /// Turn predictive reconfiguration on or off (see
    /// [`Coordinator::maintain`]). Off is the paper's reactive baseline.
    pub fn set_predict(&mut self, on: bool) {
        self.predict = on;
    }

    /// Current prediction policy.
    pub fn predicting(&self) -> bool {
        self.predict
    }

    /// Mark a stream discontinuity — a stolen composition group arriving
    /// on this worker, a supervised-restart replay — so the next
    /// observed key starts a fresh chain instead of learning a false
    /// successor edge across the boundary (see
    /// [`NextPredictor::break_chain`]). No-op with prediction off: the
    /// reactive baseline stays bit-identical.
    pub fn note_stream_break(&mut self) {
        if self.predict {
            self.predictor.break_chain();
        }
    }

    /// Hand the learned next-composition table to a successor
    /// coordinator — worker supervision rebuilds the `Coordinator` in
    /// place, and the prediction learned across restarts must not
    /// cold-start with it. Leaves a fresh default predictor behind.
    pub(crate) fn take_predictor(&mut self) -> NextPredictor {
        std::mem::take(&mut self.predictor)
    }

    /// Adopt a predecessor's learned table. The hand-off boundary is a
    /// stream discontinuity (the successor starts on a replayed burst),
    /// so the chain is broken on install: the edge counts survive, the
    /// dangling `last` state does not.
    pub(crate) fn install_predictor(&mut self, mut predictor: NextPredictor) {
        predictor.break_chain();
        self.predictor = predictor;
    }

    /// Turn online defragmentation on or off (see
    /// [`Coordinator::compact_once`]).
    pub fn set_compact(&mut self, on: bool) {
        self.compact = on;
    }

    /// Current compaction policy.
    pub fn compacting(&self) -> bool {
        self.compact
    }

    /// One quiet-window maintenance pass: defragment first (it frees the
    /// scarce Large tiles), then prefetch the predicted next accelerator
    /// into whatever is idle. Returns whether any speculative work was
    /// done — the pool's idle loop re-enters until this settles, then
    /// parks. A no-op (and bit-identical to not being called) when both
    /// policies are off.
    pub fn maintain(&mut self) -> bool {
        let mut worked = false;
        if self.compact {
            worked |= self.compact_once().is_some();
        }
        if self.predict {
            worked |= self.prefetch_predicted().is_some();
        }
        worked
    }

    /// Would realizing `plan` overwrite a resident of the most recently
    /// served accelerator? Those tiles are "in use": a prefetch must never
    /// steal them. Idle residents elsewhere are legitimate speculation
    /// targets — if the speculation is wrong their plans read as stale and
    /// respecialize, never silently corrupt.
    fn plan_disturbs_active(&self, plan: &PlacementPlan) -> bool {
        plan.placement.assignments.iter().any(|a| {
            self.active.contains(&a.tile) && {
                let t = &self.engine.fabric.tiles[a.tile];
                t.resident != Some(a.op) || t.resident_tail != a.tail
            }
        })
    }

    /// Prefetch the predicted next accelerator's bitstreams during a quiet
    /// window, so the predicted request pays residency hits instead of
    /// critical-path downloads. Returns the prefetched key, or `None` when
    /// there is nothing (safe) to do.
    ///
    /// The ladder: the predictor must clear its confidence gates; the key
    /// must still be cached (prediction is over cache keys — a prefetch
    /// never compiles); the fabric's cached plan is replayed if it touches
    /// no quarantined tile and no in-use resident, otherwise a fresh
    /// placement onto free healthy tiles is attempted (the placer cannot
    /// clobber anyone). The download itself is billed to the PR manager's
    /// lifetime stats but **not** to `Metrics::pr_downloads` — that
    /// counter measures the critical path this feature exists to shorten.
    /// Hits and mispredictions are scored by the next real submit into
    /// `prefetch_hits` / `prefetch_wasted`.
    pub fn prefetch_predicted(&mut self) -> Option<u64> {
        if !self.predict {
            return None;
        }
        let key = self.predictor.predict()?;
        if self.last_prefetch == Some(key) {
            return None; // already staged for the next request
        }
        let fabric = self.engine.fabric.id;
        let hit = self.cache.lookup(key, fabric)?;
        let plan = match hit.plan {
            Some(p)
                if !self.engine.plan_touches_quarantine(&p)
                    && !self.plan_disturbs_active(&p) =>
            {
                p
            }
            _ => {
                // no replayable plan: respecialize onto free healthy
                // tiles. Unbilled (no respecialization or JIT counters):
                // prefetch is not a request, and the conservation law
                // (hits + respecs + compiles == requests) must hold.
                let plan = Arc::new(self.jit.place_onto(&self.engine.fabric, &hit.spec).ok()?);
                self.metrics.lru_evictions +=
                    self.cache.insert_plan(key, Arc::clone(&plan)) as u64;
                plan
            }
        };
        let applied = self.engine.pr.apply_with(
            &mut self.engine.fabric,
            &self.engine.lib,
            &plan.placement,
            &self.engine.faults,
            self.engine.download_retries,
        );
        match applied {
            Ok(_) => {
                self.last_prefetch = Some(key);
                Some(key)
            }
            Err(_) => {
                // a faulted speculative download costs nothing on the
                // request path; account any quarantine it surfaced
                self.note_quarantines();
                None
            }
        }
    }

    /// One compaction pass: plan migrations against the live occupancy
    /// ([`crate::place::compact::plan_compaction`]), execute them through
    /// the PR manager, then **republish** every cached plan of this fabric
    /// that touched a moved tile — its assignments remapped through the
    /// move map and re-routed/re-codegenned via
    /// [`Jit::plan_for_placement`] — so later requests replay onto the
    /// tiles their residents now occupy instead of re-downloading into the
    /// vacated ones. A republish that fails (e.g. contiguity broken) keeps
    /// the old plan: the engine's staleness/clobber guards respecialize it
    /// on demand, so compaction can reduce efficiency of one plan but
    /// never its correctness. Returns `(mean_internal before, after)` of
    /// the live residency, or `None` when there was nothing to do.
    pub fn compact_once(&mut self) -> Option<(f64, f64)> {
        if !self.compact {
            return None;
        }
        let plan = crate::place::compact::plan_compaction(&self.engine.fabric);
        if plan.is_noop() {
            return None;
        }
        let mut moved: HashMap<usize, usize> = HashMap::new();
        for mv in &plan.moves {
            let migrated = self.engine.pr.migrate(
                &mut self.engine.fabric,
                &self.engine.lib,
                mv,
                &self.engine.faults,
                self.engine.download_retries,
            );
            match migrated {
                Ok(_) => {
                    self.metrics.migrations += 1;
                    moved.insert(mv.from, mv.to);
                }
                // the source resident survives a faulted migration; skip
                // this move and account any quarantine
                Err(_) => self.note_quarantines(),
            }
        }
        if moved.is_empty() {
            return None;
        }
        let fabric_id = self.engine.fabric.id;
        for (key, spec, old) in self.cache.plans_for_fabric(fabric_id) {
            if !old.placement.assignments.iter().any(|a| moved.contains_key(&a.tile)) {
                continue;
            }
            let mut placement = old.placement.clone();
            for a in &mut placement.assignments {
                if let Some(&to) = moved.get(&a.tile) {
                    a.tile = to;
                    a.class = self.engine.fabric.tiles[to].class;
                }
            }
            if let Ok(new_plan) = self.jit.plan_for_placement(&self.engine.fabric, &spec, placement)
            {
                self.metrics.lru_evictions +=
                    self.cache.insert_plan(key, Arc::new(new_plan)) as u64;
            }
        }
        // keep protecting the in-use residents at their new homes
        for t in self.active.iter_mut() {
            if let Some(&to) = moved.get(t) {
                *t = to;
            }
        }
        let live = crate::place::compact::live_placement(&self.engine.fabric);
        let after = crate::place::frag::fragmentation(&live).mean_internal;
        Some((plan.before.mean_internal, after))
    }

    /// Compile (or fetch) the accelerator for a composition, specialized to
    /// this coordinator's fabric.
    ///
    /// Three outcomes, in decreasing order of luck:
    ///
    /// * **full hit** — the shared cache holds the program *and* a live
    ///   plan for this fabric: nothing to compile;
    /// * **placement respecialization** — the program is cached but this
    ///   fabric has no plan (first landing after an affinity spill or
    ///   steal), or its cached plan went stale (replaying it would clobber
    ///   residents the fabric still has room to avoid): re-run only the
    ///   placement phase against the *current* occupancy and cache the
    ///   specialized plan per `(composition, fabric)`;
    /// * **full compile** — cold key: front end + placement, then publish
    ///   both (first writer wins on the program, so racing workers
    ///   converge).
    ///
    /// Placement always sees the fabric's *current* occupancy, so
    /// co-residency is exploited when capacity allows (different
    /// accelerators land on disjoint tiles and never evict each other).
    /// When the placer runs out of tiles, the coordinator evicts all
    /// residents and replaces against the empty fabric — the PR manager
    /// re-downloads on demand (the thrash the batcher exists to amortize).
    pub fn accelerator(
        &mut self,
        comp: &Composition,
    ) -> Result<(CompiledAccelerator, f64, bool)> {
        if self.fuse {
            // resource-aware ladder, rung 1: the fused shape. On a capacity
            // refusal, fall through to the unfused shape against the
            // *current* occupancy — less destructive than evicting the
            // whole fabric to force the fused one in.
            match self.accelerator_shaped(comp, true) {
                Err(e) if e.is_capacity() => self.metrics.fusion_fallbacks += 1,
                other => return other,
            }
        }
        self.accelerator_shaped(comp, false)
    }

    /// [`Coordinator::accelerator`] for one explicit shape (fused or not).
    ///
    /// The unfused shape is the last accelerator rung: on a capacity
    /// refusal it evicts the whole fabric and retries against empty tiles.
    /// The fused shape instead *returns* the capacity error so the ladder
    /// can try the (differently shaped) unfused pipeline first.
    fn accelerator_shaped(
        &mut self,
        comp: &Composition,
        fuse: bool,
    ) -> Result<(CompiledAccelerator, f64, bool)> {
        let key = comp.cache_key() ^ if fuse { FUSED_KEY_SALT } else { 0 };
        let fabric = self.engine.fabric.id;
        if let Some(hit) = self.cache.lookup(key, fabric) {
            if let Some(plan) = hit.plan {
                // a plan assigning a stage to a quarantined tile can never
                // replay (the download would be refused) — treat it like a
                // stale plan and respecialize around the dead region
                let dead = self.engine.plan_touches_quarantine(&plan);
                if !dead && !self.engine.plan_clobbers(&plan) {
                    self.metrics.cache_hits += 1;
                    return Ok((CompiledAccelerator { spec: hit.spec, plan }, 0.0, true));
                }
                // The occupancy drifted under this fabric's cached plan:
                // replaying it would overwrite residents. *Attempt* a
                // placement-only recompile against the live occupancy —
                // the attempt is the feasibility check, so this covers
                // every spec shape (branch diamonds included, which the
                // engine's predictive guard cannot judge). If the fabric
                // genuinely has no room, replaying the old plan is the
                // legitimate capacity thrash the batcher amortizes.
                return match self.place_fresh(&hit.spec) {
                    Ok((new_plan, dt)) => {
                        if !dead {
                            self.metrics.residency_clobbers_avoided += 1;
                        }
                        Ok(self.publish_plan(hit.spec, new_plan, dt))
                    }
                    // replaying a dead plan is pointless (the quarantined
                    // tile refuses the download): surface the capacity
                    // miss so the ladder degrades instead of spinning
                    Err(e) if e.is_capacity() && !dead => {
                        self.metrics.cache_hits += 1;
                        Ok((CompiledAccelerator { spec: hit.spec, plan }, 0.0, true))
                    }
                    Err(e) => Err(e),
                };
            }
            // First landing on this fabric: specialize the placement. The
            // pre-split behavior — replaying another fabric's frozen plan
            // over whatever lives here — is what the clobbers-avoided
            // counter measures.
            let foreign_would_clobber =
                hit.foreign_plan.is_some_and(|p| self.engine.plan_clobbers(&p));
            let (plan, dt) = match self.place_fresh(&hit.spec) {
                Ok((plan, dt)) => {
                    if foreign_would_clobber {
                        self.metrics.residency_clobbers_avoided += 1;
                    }
                    (plan, dt)
                }
                Err(e) if e.is_capacity() && !fuse => {
                    // no clean fit anywhere: evict everything and place on
                    // the empty fabric, as a full compile would
                    self.metrics.evictions += 1;
                    self.engine.fabric.reset_full();
                    self.place_fresh(&hit.spec)?
                }
                Err(e) => return Err(e),
            };
            return Ok(self.publish_plan(hit.spec, plan, dt));
        }
        let t0 = Instant::now();
        let compiled =
            match self.jit.compile_with(&self.engine.fabric, &self.engine.lib, comp, fuse) {
                Ok(acc) => acc,
                Err(e) if e.is_capacity() && !fuse => {
                    self.metrics.evictions += 1;
                    self.engine.fabric.reset_full();
                    self.jit.compile_with(&self.engine.fabric, &self.engine.lib, comp, fuse)?
                }
                Err(e) => return Err(e),
            };
        let dt = t0.elapsed().as_secs_f64();
        self.metrics.jit_compiles += 1;
        self.metrics.jit_seconds += dt;
        self.metrics.stages_fused += compiled.spec.fused_pairs as u64;
        // first writer wins; a racing worker's duplicate compile converges
        let (acc, evicted) = self.cache.insert(key, compiled.spec, compiled.plan);
        self.metrics.lru_evictions += evicted as u64;
        Ok((acc, dt, false))
    }

    /// One timed placement-only attempt against the live occupancy (no
    /// fallback — callers decide between eviction and replay on capacity).
    fn place_fresh(&mut self, spec: &Arc<AcceleratorProgram>) -> Result<(PlacementPlan, f64)> {
        let t0 = Instant::now();
        let plan = self.jit.place_onto(&self.engine.fabric, spec)?;
        Ok((plan, t0.elapsed().as_secs_f64()))
    }

    /// Account a placement respecialization and publish its plan to the
    /// per-fabric plan cache.
    fn publish_plan(
        &mut self,
        spec: Arc<AcceleratorProgram>,
        plan: PlacementPlan,
        dt: f64,
    ) -> (CompiledAccelerator, f64, bool) {
        self.metrics.placement_respecializations += 1;
        self.metrics.jit_seconds += dt;
        let plan = Arc::new(plan);
        self.metrics.lru_evictions += self.cache.insert_plan(spec.key, plan.clone()) as u64;
        (CompiledAccelerator { spec, plan }, dt, true)
    }

    /// Serve one request, riding the tile-fault recovery ladder.
    ///
    /// A transient [`Error::TileFault`] (wrong bits — the engine already
    /// cleared the corrupt region) re-submits, paying one clean
    /// re-download (`download_retries`). A permanent one (the engine
    /// quarantined the region) re-submits too: the plan now reads as dead,
    /// so the cache respecializes around the quarantined tile — the
    /// "re-place elsewhere" rung between the fused→unfused ladder and the
    /// CPU floor. Attempts are bounded by the tile count (each permanent
    /// fault consumes a tile, so the ladder cannot spin), after which the
    /// request degrades to CPU interpretation like any other capacity
    /// exhaustion.
    pub fn submit(&mut self, req: &Request) -> Result<Response> {
        if self.predict {
            // score the outstanding prefetch against what actually arrived,
            // then feed the predictor — once per request, outside the fault
            // ladder (a retried attempt is not a new observation)
            let key = req.comp.cache_key() ^ if self.fuse { FUSED_KEY_SALT } else { 0 };
            if let Some(staged) = self.last_prefetch.take() {
                if staged == key {
                    self.metrics.prefetch_hits += 1;
                } else {
                    self.metrics.prefetch_wasted += 1;
                }
            }
            self.predictor.observe(key);
        }
        let max_attempts = self.engine.fabric.tiles.len() + 1;
        let mut attempt = 0;
        loop {
            match self.submit_inner(req) {
                Err(Error::TileFault { permanent, .. }) => {
                    self.note_quarantines();
                    attempt += 1;
                    if attempt >= max_attempts {
                        return self.submit_cpu_fallback(req);
                    }
                    if !permanent {
                        // the cleared region re-downloads on the retry —
                        // bill the extra transfer like a download re-arm
                        self.metrics.download_retries += 1;
                    }
                }
                other => return other,
            }
        }
    }

    /// Account any tiles quarantined since the last fault (the fabric
    /// count is a level; `tiles_quarantined` bills its increments once).
    fn note_quarantines(&mut self) {
        let now = self.engine.fabric.quarantined_tiles();
        if now > self.quarantined_seen {
            self.metrics.tiles_quarantined += (now - self.quarantined_seen) as u64;
            self.quarantined_seen = now;
        }
    }

    /// One serving attempt (no tile-fault recovery — [`Coordinator::submit`]
    /// wraps this in the retry ladder).
    fn submit_inner(&mut self, req: &Request) -> Result<Response> {
        let (acc, jit_seconds, cached) = match self.accelerator(&req.comp) {
            Ok(triaged) => triaged,
            // The bottom rung of the resource-aware ladder: no shape of
            // this composition places on any occupancy (even an empty
            // fabric), so answer from the CPU reference instead of
            // surfacing a placement error to the client.
            Err(e) if e.is_capacity() => return self.submit_cpu_fallback(req),
            Err(e) => return Err(e),
        };
        let run = self.engine.run(&acc, &req.inputs, req.target)?;
        // these tiles now hold the most recently served accelerator: the
        // prefetcher must leave them alone until the next request lands
        self.active.clear();
        self.active.extend(acc.plan.placement.assignments.iter().map(|a| a.tile));
        self.metrics.requests += 1;
        if let Some(r) = run.reconfig {
            self.metrics.pr_downloads += r.downloads as u64;
            self.metrics.pr_region_hits += r.cache_hits as u64;
            self.metrics.pr_replaced += r.replaced as u64;
            self.metrics.pr_seconds += r.seconds;
            self.metrics.download_retries += r.retries as u64;
            if r.downloads > 0 {
                // each fused pair is one tile (hence one download) the
                // unfused shape would have paid on this reconfiguration —
                // an upper-bound indicator (residency hits discount it)
                self.metrics.downloads_avoided += acc.spec.fused_pairs as u64;
            }
        }
        self.metrics.busy_seconds += run.timing.total();
        Ok(Response { run, jit_seconds, cached })
    }

    /// Serve a request by CPU interpretation ([`Engine::run_cpu`]): no
    /// accelerator, no placement, no fabric state touched. Counted in
    /// `cpu_fallbacks`; `cached` is false and no JIT time is charged.
    fn submit_cpu_fallback(&mut self, req: &Request) -> Result<Response> {
        let run = self.engine.run_cpu(&req.comp, &req.inputs)?;
        // a CPU answer leaves no accelerator in use on the fabric
        self.active.clear();
        self.metrics.requests += 1;
        self.metrics.cpu_fallbacks += 1;
        self.metrics.busy_seconds += run.timing.total();
        Ok(Response { run, jit_seconds: 0.0, cached: false })
    }

    /// Reconfiguration-aware batch schedule: stable-group requests by
    /// composition key. Returns the execution order (indices into `reqs`).
    pub fn schedule(reqs: &[Request]) -> Vec<usize> {
        let keys: Vec<u64> = reqs.iter().map(|r| r.comp.cache_key()).collect();
        Self::schedule_keys(&keys)
    }

    /// [`Coordinator::schedule`] over bare composition keys — the form the
    /// pool's drain loop uses, where requests arrive wrapped in [`Job`]s.
    /// Stable: groups are ordered by first arrival and arrival order is
    /// preserved within a group.
    pub fn schedule_keys(keys: &[u64]) -> Vec<usize> {
        let mut first_seen: HashMap<u64, usize> = HashMap::new();
        let mut order: Vec<(usize, usize)> = Vec::with_capacity(keys.len()); // (group, idx)
        for (i, &key) in keys.iter().enumerate() {
            let next_group = first_seen.len();
            let g = *first_seen.entry(key).or_insert(next_group);
            order.push((g, i));
        }
        order.sort(); // stable by (group, arrival)
        order.into_iter().map(|(_, i)| i).collect()
    }

    /// Serve a drained queue window in reconfiguration-minimizing order:
    /// stable-group the jobs by composition key, serve group by group, and
    /// account the burst counters (`bursts`, `burst_group_switches`).
    ///
    /// Replies are **returned, not sent**: each response is paired with its
    /// own request's reply channel (reordering can never cross-wire them),
    /// and the caller delivers after folding the burst's single metrics
    /// delta — so a client that has received a reply always observes that
    /// request in the pool aggregate. A per-request failure becomes that
    /// client's reply and does not abort the rest of the burst.
    pub fn serve_burst(&mut self, jobs: Vec<Job>) -> BurstReplies {
        if jobs.is_empty() {
            return Vec::new();
        }
        let keys: Vec<u64> = jobs.iter().map(|j| j.request.comp.cache_key()).collect();
        let order = Self::schedule_keys(&keys);
        let mut jobs: Vec<Option<Job>> = jobs.into_iter().map(Some).collect();
        let mut replies = Vec::with_capacity(jobs.len());
        let mut prev_key: Option<u64> = None;
        let mut switches = 0u64;
        for i in order {
            let job = jobs[i].take().expect("schedule visits each job once");
            if prev_key.is_some() && prev_key != Some(keys[i]) {
                switches += 1;
            }
            prev_key = Some(keys[i]);
            let resp = self.submit(&job.request);
            replies.push((job.reply, resp));
        }
        self.metrics.bursts += 1;
        self.metrics.burst_group_switches += switches;
        replies
    }

    /// Serve a batch in reconfiguration-minimizing order; returns responses
    /// in the *original* request order.
    pub fn submit_batch(&mut self, reqs: &[Request]) -> Result<Vec<Response>> {
        let order = Self::schedule(reqs);
        let mut out: Vec<Option<Response>> = (0..reqs.len()).map(|_| None).collect();
        for i in order {
            out[i] = Some(self.submit(&reqs[i])?);
        }
        Ok(out.into_iter().map(|r| r.expect("all served")).collect())
    }

    /// Number of cached accelerators.
    pub fn cached_accelerators(&self) -> usize {
        self.cache.len()
    }
}

/// A request plus its reply sink (a per-request channel or a shared
/// completion queue — see [`pool::ReplySink`]).
pub struct Job {
    pub request: Request,
    pub reply: pool::ReplySink,
}

/// What [`Coordinator::serve_burst`] hands back: each served job's reply
/// sink with its response, in served (reordered) order, for the caller
/// to deliver after folding metrics.
pub type BurstReplies = Vec<(pool::ReplySink, Result<Response>)>;

/// Request loop: drain jobs from `rx`, serve them on this thread, return
/// the final metrics when all senders hang up.
///
/// The coordinator is deliberately single-threaded (it owns one fabric, as
/// the controller owns one FPGA); concurrency lives in the callers — spawn
/// this on a dedicated thread and clone the job sender freely.
pub fn serve(mut coord: Coordinator, rx: std::sync::mpsc::Receiver<Job>) -> Metrics {
    while let Ok(job) = rx.recv() {
        let resp = coord.submit(&job.request);
        job.reply.deliver(resp);
    }
    coord.metrics
}

/// Spawn [`serve`] on a new thread; returns the job sender and the join
/// handle yielding final metrics.
pub fn spawn_service(
    coord: Coordinator,
) -> (std::sync::mpsc::Sender<Job>, std::thread::JoinHandle<Metrics>) {
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || serve(coord, rx));
    (tx, handle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitstream::OperatorKind;

    fn coord() -> Coordinator {
        Coordinator::new(OverlayConfig::default()).unwrap()
    }

    fn vmul_req(n: usize, seed: f32) -> Request {
        Request::dynamic(
            Composition::vmul_reduce(n),
            vec![vec![seed; n], vec![2.0; n]],
        )
    }

    fn map_req(n: usize) -> Request {
        Request::dynamic(Composition::map(OperatorKind::Abs, n), vec![vec![-1.0; n]])
    }

    #[test]
    fn repeat_requests_hit_accelerator_cache() {
        let mut c = coord();
        let r1 = c.submit(&vmul_req(1024, 1.0)).unwrap();
        let r2 = c.submit(&vmul_req(1024, 3.0)).unwrap();
        assert!(!r1.cached);
        assert!(r2.cached);
        assert_eq!(r2.jit_seconds, 0.0);
        assert_eq!(c.cached_accelerators(), 1);
        assert_eq!(r2.run.output.as_scalar(), Some(3.0 * 2.0 * 1024.0));
    }

    #[test]
    fn schedule_groups_same_composition() {
        let reqs = vec![
            vmul_req(512, 1.0), // A
            map_req(512),       // B
            vmul_req(512, 2.0), // A
            map_req(512),       // B
            vmul_req(512, 3.0), // A
        ];
        let order = Coordinator::schedule(&reqs);
        assert_eq!(order, vec![0, 2, 4, 1, 3]);
    }

    /// A plan cap below the fabric count cycles the per-composition plan
    /// LRU (every landing respecializes); raising it — what pool
    /// construction does for externally supplied caches — restores the
    /// full-hit steady state.
    #[test]
    fn ensure_plan_capacity_prevents_plan_cycling() {
        let cache = Arc::new(AcceleratorCache::with_plan_capacity(1, 0, 1));
        let mut coords: Vec<Coordinator> = (0..3)
            .map(|_| Coordinator::with_cache(OverlayConfig::default(), cache.clone()).unwrap())
            .collect();
        for pass in 0..2 {
            for c in coords.iter_mut() {
                c.submit(&vmul_req(256, pass as f32 + 1.0)).unwrap();
            }
        }
        let cycled: u64 =
            coords.iter().map(|c| c.metrics.placement_respecializations).sum();
        assert_eq!(cycled, 5, "a single plan slot must cycle under 3 fabrics");
        assert_eq!(coords.iter().map(|c| c.metrics.cache_hits).sum::<u64>(), 0);

        cache.ensure_plan_capacity(3);
        for pass in 0..2 {
            for c in coords.iter_mut() {
                c.submit(&vmul_req(256, pass as f32 + 3.0)).unwrap();
            }
        }
        let respecs: u64 =
            coords.iter().map(|c| c.metrics.placement_respecializations).sum();
        let hits: u64 = coords.iter().map(|c| c.metrics.cache_hits).sum();
        assert_eq!(respecs - cycled, 2, "only the evicted fabrics respecialize once more");
        assert_eq!(hits, 4, "every later landing is a full hit");
    }

    /// Two 5-stage chains cannot co-reside on a 9-tile fabric with the
    /// first one resident (only 4 tiles stay free), so switching between
    /// them forces whole-fabric eviction + re-download — the contention the
    /// batcher amortizes.
    fn chain_a_req(n: usize) -> Request {
        use OperatorKind::*;
        Request::dynamic(
            Composition::chain(&[Neg, Abs, Square, Relu, Neg], n).unwrap(),
            vec![vec![1.5; n]],
        )
    }

    fn chain_b_req(n: usize) -> Request {
        use OperatorKind::*;
        Request::dynamic(
            Composition::chain(&[Abs, Neg, Relu, Square, Abs], n).unwrap(),
            vec![vec![-2.0; n]],
        )
    }

    #[test]
    fn small_accelerators_co_reside_without_thrash() {
        // vmul (2 tiles) and map (1 tile) fit together: after warmup no
        // further downloads, no evictions.
        let mut c = coord();
        for _ in 0..3 {
            c.submit(&vmul_req(512, 1.0)).unwrap();
            c.submit(&map_req(512)).unwrap();
        }
        assert_eq!(c.metrics.evictions, 0);
        assert_eq!(c.metrics.pr_downloads, 3); // 2 (vmul) + 1 (map), once
    }

    #[test]
    fn fusion_cuts_chain_tiles_and_downloads() {
        let mut plain = coord();
        let r_plain = plain.submit(&chain_a_req(512)).unwrap();
        let mut fused = coord();
        fused.set_fusion(true);
        let r_fused = fused.submit(&chain_a_req(512)).unwrap();
        // (neg+abs)(square+relu)(neg): 5 tiles → 3, 5 downloads → 3
        assert_eq!(plain.metrics.pr_downloads, 5);
        assert_eq!(fused.metrics.pr_downloads, 3);
        assert_eq!(fused.metrics.stages_fused, 2);
        assert_eq!(fused.metrics.downloads_avoided, 2);
        assert_eq!(fused.metrics.fusion_fallbacks, 0);
        assert_eq!(fused.metrics.cpu_fallbacks, 0);
        // same answers, bit for bit
        let (u, f) = (
            r_plain.run.output.as_vector().unwrap(),
            r_fused.run.output.as_vector().unwrap(),
        );
        assert_eq!(u.len(), f.len());
        for i in 0..u.len() {
            assert_eq!(u[i].to_bits(), f[i].to_bits(), "i={i}");
        }
    }

    #[test]
    fn fused_capacity_falls_back_to_unfused_shape() {
        // occupy both Large tiles: the fused vmul (mul+acc_sum needs a
        // Large region) cannot place, but the unfused 2×Small shape can —
        // the ladder must take it without evicting the Large residents.
        let mut c = coord();
        c.set_fusion(true);
        let bs = c
            .engine
            .lib
            .get(OperatorKind::Sin, crate::bitstream::RegionClass::Large)
            .unwrap()
            .clone();
        c.engine.fabric.load_bitstream(3, &bs).unwrap();
        c.engine.fabric.load_bitstream(7, &bs).unwrap();
        let r = c.submit(&vmul_req(512, 1.0)).unwrap();
        assert_eq!(r.run.output.as_scalar(), Some(1024.0));
        assert_eq!(c.metrics.fusion_fallbacks, 1);
        assert_eq!(c.metrics.cpu_fallbacks, 0);
        assert_eq!(c.metrics.evictions, 0);
        assert_eq!(c.engine.fabric.tiles[3].resident, Some(OperatorKind::Sin));
        assert_eq!(c.engine.fabric.tiles[7].resident, Some(OperatorKind::Sin));
    }

    #[test]
    fn unplaceable_composition_degrades_to_cpu() {
        // three Large-only operators on a fabric with two Large tiles: no
        // shape places even on an empty fabric. The ladder bottoms out at
        // CPU interpretation instead of surfacing a placement error.
        use OperatorKind::*;
        let mut c = coord();
        c.set_fusion(true);
        let n = 256;
        let comp = Composition::chain(&[Sin, Exp, Log], n).unwrap();
        let x = vec![0.5f32; n];
        let r = c.submit(&Request::dynamic(comp, vec![x.clone()])).unwrap();
        assert!(matches!(r.run.target, Target::ArmSoftware));
        assert!(!r.cached);
        assert_eq!(r.jit_seconds, 0.0);
        assert_eq!(c.metrics.cpu_fallbacks, 1);
        assert_eq!(c.metrics.fusion_fallbacks, 1);
        assert_eq!(c.metrics.requests, 1);
        let got = r.run.output.as_vector().unwrap();
        let want = 0.5f32.sin().exp().ln();
        assert_eq!(got[0].to_bits(), want.to_bits());
        // CPU fallbacks sit outside the hit/respec/compile conservation law
        assert_eq!(
            c.metrics.cache_hits
                + c.metrics.placement_respecializations
                + c.metrics.jit_compiles,
            0
        );
    }

    #[test]
    fn fusion_policies_do_not_share_cache_entries() {
        let cache = Arc::new(AcceleratorCache::new(2));
        let mut c = Coordinator::with_cache(OverlayConfig::default(), cache.clone()).unwrap();
        assert!(!c.fusion());
        c.submit(&vmul_req(512, 1.0)).unwrap();
        c.set_fusion(true);
        assert!(c.fusion());
        let r = c.submit(&vmul_req(512, 1.0)).unwrap();
        assert!(!r.cached, "fused compile must not reuse the unfused entry");
        assert_eq!(cache.len(), 2);
        assert_eq!(c.metrics.jit_compiles, 2);
        // a repeat under the same policy is a full hit
        let r2 = c.submit(&vmul_req(512, 2.0)).unwrap();
        assert!(r2.cached);
        assert_eq!(r2.jit_seconds, 0.0);
        assert_eq!(c.metrics.cache_hits, 1);
        assert_eq!(r2.run.output.as_scalar(), Some(2.0 * 2.0 * 512.0));
    }

    #[test]
    fn batched_order_reduces_pr_downloads() {
        // interleaved A,B,A,B,A with conflicting 5-stage chains: naive
        // serving re-downloads on every switch; scheduled serving
        // reconfigures once per group.
        let reqs: Vec<Request> = vec![
            chain_a_req(512),
            chain_b_req(512),
            chain_a_req(512),
            chain_b_req(512),
            chain_a_req(512),
        ];

        let mut naive = coord();
        for r in &reqs {
            naive.submit(r).unwrap();
        }

        let mut batched = coord();
        batched.submit_batch(&reqs).unwrap();

        assert!(
            batched.metrics.pr_downloads < naive.metrics.pr_downloads,
            "batched {} !< naive {}",
            batched.metrics.pr_downloads,
            naive.metrics.pr_downloads
        );
        assert!(naive.metrics.evictions >= 1);
    }

    #[test]
    fn batch_responses_in_original_order() {
        let mut c = coord();
        let reqs = vec![vmul_req(512, 1.0), map_req(512), vmul_req(512, 2.0)];
        let resps = c.submit_batch(&reqs).unwrap();
        assert_eq!(resps.len(), 3);
        assert_eq!(resps[0].run.output.as_scalar(), Some(1024.0));
        assert!(resps[1].run.output.as_vector().is_some());
        assert_eq!(resps[2].run.output.as_scalar(), Some(2048.0));
    }

    #[test]
    fn metrics_accumulate() {
        let mut c = coord();
        c.submit(&vmul_req(512, 1.0)).unwrap();
        c.submit(&vmul_req(512, 1.0)).unwrap();
        assert_eq!(c.metrics.requests, 2);
        assert_eq!(c.metrics.jit_compiles, 1);
        assert_eq!(c.metrics.cache_hits, 1);
        assert!(c.metrics.busy_seconds > 0.0);
    }

    /// Recovery ladder, transient rung: wrong bits clear the region, the
    /// re-submit re-downloads clean, and the client never sees the fault.
    #[test]
    fn transient_tile_fault_retries_and_serves() {
        use crate::faults::{FaultPlane, FaultSpec};
        let mut c = coord();
        c.set_faults(
            FaultPlane::from_spec(FaultSpec { wrong_bits: vec![1], ..FaultSpec::default() }),
            3,
        );
        let r = c.submit(&vmul_req(256, 1.0)).unwrap();
        assert_eq!(r.run.output.as_scalar(), Some(512.0));
        assert_eq!(c.metrics.requests, 1, "one reply per request despite the retry");
        assert_eq!(c.metrics.download_retries, 1);
        assert_eq!(c.metrics.tiles_quarantined, 0);
        assert_eq!(c.metrics.cpu_fallbacks, 0);
    }

    /// Recovery ladder, "re-place elsewhere" rung: a dead region is
    /// quarantined and the cached plan respecializes around it — still
    /// served on the fabric, not the CPU floor.
    #[test]
    fn permanent_tile_fault_re_places_elsewhere() {
        use crate::faults::{FaultPlane, FaultSpec};
        let mut c = coord();
        c.set_faults(
            FaultPlane::from_spec(FaultSpec { region_dead: vec![1], ..FaultSpec::default() }),
            3,
        );
        let r = c.submit(&vmul_req(256, 1.0)).unwrap();
        assert_eq!(r.run.output.as_scalar(), Some(512.0));
        assert!(matches!(r.run.target, Target::DynamicOverlay), "served on fabric, not CPU");
        assert_eq!(c.metrics.tiles_quarantined, 1);
        assert_eq!(c.engine.fabric.quarantined_tiles(), 1);
        assert_eq!(c.metrics.cpu_fallbacks, 0);
        // the moved plan is cached: the repeat is a clean full hit
        let r2 = c.submit(&vmul_req(256, 2.0)).unwrap();
        assert_eq!(r2.run.output.as_scalar(), Some(1024.0));
        assert!(r2.cached);
        assert_eq!(r2.jit_seconds, 0.0);
    }

    /// Recovery ladder, floor: cascading permanent faults eat the fabric
    /// tile by tile until placement is infeasible, then the request
    /// degrades to CPU interpretation instead of erroring or spinning.
    #[test]
    fn cascading_permanent_faults_bottom_out_at_cpu() {
        use crate::faults::{FaultPlane, FaultSpec};
        let mut c = coord();
        c.set_faults(
            FaultPlane::from_spec(FaultSpec {
                region_dead: (1..=20).collect(),
                ..FaultSpec::default()
            }),
            3,
        );
        let r = c.submit(&vmul_req(256, 1.0)).unwrap();
        assert_eq!(r.run.output.as_scalar(), Some(512.0));
        assert!(matches!(r.run.target, Target::ArmSoftware));
        assert_eq!(c.metrics.cpu_fallbacks, 1);
        assert_eq!(c.metrics.requests, 1);
        assert!(c.metrics.tiles_quarantined >= 1);
        assert_eq!(
            c.metrics.tiles_quarantined as usize,
            c.engine.fabric.quarantined_tiles(),
            "metric must mirror the fabric's quarantine level"
        );
    }

    #[test]
    fn threaded_serve_loop_round_trips() {
        let (tx, handle) = spawn_service(coord());
        let (rtx, rrx) = std::sync::mpsc::channel();
        tx.send(Job { request: vmul_req(256, 1.0), reply: pool::ReplySink::channel(rtx) })
            .unwrap();
        let resp = rrx.recv().unwrap().unwrap();
        assert_eq!(resp.run.output.as_scalar(), Some(512.0));
        drop(tx);
        let metrics = handle.join().unwrap();
        assert_eq!(metrics.requests, 1);
    }

    #[test]
    fn service_survives_request_errors() {
        let (tx, handle) = spawn_service(coord());
        // bad request: wrong channel count
        let (rtx, rrx) = std::sync::mpsc::channel();
        tx.send(Job {
            request: Request::dynamic(Composition::vmul_reduce(64), vec![vec![0.0; 64]]),
            reply: pool::ReplySink::channel(rtx),
        })
        .unwrap();
        assert!(rrx.recv().unwrap().is_err());
        // service still alive for a good request
        let (rtx2, rrx2) = std::sync::mpsc::channel();
        tx.send(Job { request: vmul_req(64, 1.0), reply: pool::ReplySink::channel(rtx2) })
            .unwrap();
        assert!(rrx2.recv().unwrap().is_ok());
        drop(tx);
        handle.join().unwrap();
    }

    #[test]
    fn shared_cache_skips_jit_on_second_coordinator() {
        let cache = Arc::new(AcceleratorCache::new(4));
        let mut a = Coordinator::with_cache(OverlayConfig::default(), cache.clone()).unwrap();
        let mut b = Coordinator::with_cache(OverlayConfig::default(), cache.clone()).unwrap();
        let ra = a.submit(&vmul_req(512, 1.0)).unwrap();
        let rb = b.submit(&vmul_req(512, 2.0)).unwrap();
        assert!(!ra.cached);
        assert!(rb.cached, "second fabric must reuse the shared compile");
        assert_eq!(b.metrics.jit_compiles, 0);
        // b's first landing is a placement-only respecialization …
        assert_eq!(b.metrics.placement_respecializations, 1);
        // … and b still pays its own PR downloads — residency is per fabric
        assert_eq!(b.metrics.pr_downloads, 2);
        // b's second request is then a full (spec + plan) hit
        let rb2 = b.submit(&vmul_req(512, 3.0)).unwrap();
        assert!(rb2.cached);
        assert_eq!(rb2.jit_seconds, 0.0);
        assert_eq!(b.metrics.cache_hits, 1);
        assert_eq!(b.metrics.placement_respecializations, 1);
        assert_eq!(cache.len(), 1);
        assert!(!cache.is_empty());
    }

    #[test]
    fn sharded_cache_first_writer_wins() {
        let cache = AcceleratorCache::new(2);
        let e = Engine::new(OverlayConfig::default()).unwrap();
        let comp = Composition::vmul_reduce(128);
        let acc1 = Jit.compile(&e.fabric, &e.lib, &comp).unwrap();
        let acc2 = Jit.compile(&e.fabric, &e.lib, &comp).unwrap();
        let key = comp.cache_key();
        let (won, _) = cache.insert(key, acc1.spec.clone(), acc1.plan.clone());
        assert!(Arc::ptr_eq(&won.spec, &acc1.spec));
        let (lost, evicted) = cache.insert(key, acc2.spec.clone(), acc2.plan.clone());
        assert!(Arc::ptr_eq(&lost.spec, &acc1.spec), "second insert must return the first spec");
        assert_eq!(evicted, 0);
        // both plans were placed against the same fabric: the loser's plan
        // (fresher) overwrites, and the lookup pairs it with the winning spec
        let hit = cache.lookup(key, e.fabric.id).expect("cached");
        assert!(Arc::ptr_eq(&hit.spec, &acc1.spec));
        assert!(Arc::ptr_eq(hit.plan.as_ref().unwrap(), &acc2.plan));
        assert!(cache.lookup(key ^ 1, e.fabric.id).is_none());
        assert!(cache.has_plan(key, e.fabric.id));
        assert!(!cache.has_plan(key, e.fabric.id + 1));
    }

    /// Satellite (ISSUE 3): a cap of K holds under K+N distinct
    /// compositions, and the evicted entry is the least-recently-hit one.
    #[test]
    fn lru_cap_holds_and_evicts_stalest() {
        const K: usize = 4;
        let e = Engine::new(OverlayConfig::default()).unwrap();
        let comp = Composition::vmul_reduce(128);
        let acc = Jit.compile(&e.fabric, &e.lib, &comp).unwrap();
        let fabric = e.fabric.id;
        let cache = AcceleratorCache::bounded(1, K);
        for key in 0..K as u64 {
            let (_, evicted) = cache.insert(key, acc.spec.clone(), acc.plan.clone());
            assert_eq!(evicted, 0);
            assert!(cache.len() <= K);
        }
        assert_eq!(cache.len(), K);
        // touch key 0 so key 1 becomes the stalest
        assert!(cache.lookup(0, fabric).is_some());
        let mut evictions = 0;
        for key in K as u64..(K + 3) as u64 {
            let (_, evicted) = cache.insert(key, acc.spec.clone(), acc.plan.clone());
            evictions += evicted;
            assert!(cache.len() <= K, "cap of {K} violated: {}", cache.len());
        }
        assert_eq!(cache.len(), K);
        assert_eq!(evictions, 3);
        assert!(cache.lookup(0, fabric).is_some(), "recently-hit entry must survive");
        assert!(
            cache.lookup(1, fabric).is_none(),
            "least-recently-hit entry must be evicted first"
        );
    }

    /// Tentpole (ISSUE 4): the per-key conservation law — every request is
    /// exactly one of full hit, placement respecialization, or full
    /// compile — across two fabrics sharing one cache.
    #[test]
    fn hits_plus_respecializations_plus_compiles_equal_requests() {
        let cache = Arc::new(AcceleratorCache::new(2));
        let mut a = Coordinator::with_cache(OverlayConfig::default(), cache.clone()).unwrap();
        let mut b = Coordinator::with_cache(OverlayConfig::default(), cache).unwrap();
        for k in 0..3 {
            a.submit(&vmul_req(256, k as f32 + 1.0)).unwrap();
            a.submit(&map_req(256)).unwrap();
            b.submit(&vmul_req(256, k as f32 + 1.0)).unwrap();
            b.submit(&map_req(256)).unwrap();
        }
        let mut total = a.metrics;
        total.merge(&b.metrics);
        assert_eq!(total.requests, 12);
        assert_eq!(total.jit_compiles, 2, "one full compile per composition");
        assert_eq!(
            total.placement_respecializations, 2,
            "one placement-only recompile per composition on the second fabric"
        );
        assert_eq!(
            total.cache_hits + total.placement_respecializations + total.jit_compiles,
            total.requests
        );
        // nothing ever clobbered: both fabrics had free tiles for both
        // small accelerators, so they co-reside everywhere
        assert_eq!(total.pr_replaced, 0);
        assert_eq!(total.evictions, 0);
    }

    /// End-to-end: a capacity-1 coordinator cache recompiles on alternation
    /// and counts its LRU evictions.
    #[test]
    fn coordinator_counts_lru_evictions() {
        let service = ServiceConfig { cache_shards: 1, cache_capacity: 1, ..Default::default() };
        let cache = AcceleratorCache::bounded(service.cache_shards, service.cache_capacity);
        let mut c = Coordinator::with_cache(OverlayConfig::default(), Arc::new(cache)).unwrap();
        c.submit(&vmul_req(256, 1.0)).unwrap();
        c.submit(&map_req(256)).unwrap(); // evicts the vmul accelerator
        c.submit(&vmul_req(256, 1.0)).unwrap(); // recompile, evicts the map
        assert_eq!(c.metrics.jit_compiles, 3);
        assert_eq!(c.metrics.cache_hits, 0);
        assert_eq!(c.metrics.lru_evictions, 2);
        assert_eq!(c.cached_accelerators(), 1);
    }

    #[test]
    fn serve_burst_groups_and_replies_in_pair() {
        let mut c = coord();
        let reqs = vec![vmul_req(512, 1.0), map_req(512), vmul_req(512, 2.0), map_req(512)];
        let mut rxs = Vec::new();
        let jobs: Vec<Job> = reqs
            .into_iter()
            .map(|request| {
                let (rtx, rrx) = std::sync::mpsc::channel();
                rxs.push(rrx);
                Job { request, reply: pool::ReplySink::channel(rtx) }
            })
            .collect();
        let replies = c.serve_burst(jobs);
        assert_eq!(replies.len(), 4);
        assert_eq!(c.metrics.bursts, 1);
        // [A, B, A, B] regroups to [A, A, B, B]: exactly one switch
        assert_eq!(c.metrics.burst_group_switches, 1);
        for (sink, resp) in replies {
            sink.deliver(resp);
        }
        // replies pair with their own request channels despite reordering
        let r0 = rxs[0].recv().unwrap().unwrap();
        assert_eq!(r0.run.output.as_scalar(), Some(1024.0));
        let r2 = rxs[2].recv().unwrap().unwrap();
        assert_eq!(r2.run.output.as_scalar(), Some(2048.0));
        assert!(rxs[1].recv().unwrap().unwrap().run.output.as_vector().is_some());
        assert!(rxs[3].recv().unwrap().unwrap().run.output.as_vector().is_some());
    }

    /// With both policies off (the default), maintenance is a guaranteed
    /// no-op: same requests → bit-identical outputs and metrics whether or
    /// not the idle loop ever calls it.
    #[test]
    fn maintain_is_inert_with_flags_off() {
        let mut plain = coord();
        let mut maintained = coord();
        for k in 0..3 {
            let a = plain.submit(&vmul_req(512, k as f32 + 1.0)).unwrap();
            assert!(!maintained.maintain());
            let b = maintained.submit(&vmul_req(512, k as f32 + 1.0)).unwrap();
            assert!(!maintained.maintain());
            assert_eq!(
                a.run.output.as_scalar().unwrap().to_bits(),
                b.run.output.as_scalar().unwrap().to_bits()
            );
        }
        assert_eq!(plain.metrics.pr_downloads, maintained.metrics.pr_downloads);
        assert_eq!(plain.metrics.cache_hits, maintained.metrics.cache_hits);
        assert_eq!(maintained.metrics.prefetch_hits, 0);
        assert_eq!(maintained.metrics.prefetch_wasted, 0);
        assert_eq!(maintained.metrics.migrations, 0);
    }

    /// The predictor warms on an alternating stream, stages the predicted
    /// next accelerator once per quiet window, and the next submit scores
    /// it: a correct guess is a `prefetch_hits`, a wrong one
    /// `prefetch_wasted`. Speculative downloads never touch the
    /// request-path `pr_downloads` counter.
    #[test]
    fn prefetch_stages_the_predicted_accelerator_and_is_scored() {
        let mut c = coord();
        c.set_predict(true);
        // warmup: vmul→map and map→vmul each seen twice (MIN_SAMPLES)
        for k in 0..2 {
            c.submit(&vmul_req(256, k as f32 + 1.0)).unwrap();
            c.submit(&map_req(256)).unwrap();
        }
        c.submit(&vmul_req(256, 9.0)).unwrap();
        let downloads = c.metrics.pr_downloads;
        assert!(c.prefetch_predicted().is_some(), "map is the confident next");
        assert!(c.prefetch_predicted().is_none(), "already staged: idle loop settles");
        assert_eq!(c.metrics.pr_downloads, downloads, "speculation is off the critical path");
        c.submit(&map_req(256)).unwrap(); // the prediction comes true
        assert_eq!(c.metrics.prefetch_hits, 1);
        assert_eq!(c.metrics.prefetch_wasted, 0);
        // now vmul is predicted; serving map instead scores a waste
        assert!(c.prefetch_predicted().is_some());
        c.submit(&map_req(256)).unwrap();
        assert_eq!(c.metrics.prefetch_hits, 1);
        assert_eq!(c.metrics.prefetch_wasted, 1);
        assert_eq!(c.metrics.pr_downloads, downloads, "co-residents replay for free");
    }

    /// Two 5-stage chains cannot co-reside, so the predicted chain's cached
    /// plan overlaps the in-use resident set and its fresh placement cannot
    /// fit the 4 free tiles: the prefetcher must decline rather than evict
    /// the accelerator just served.
    #[test]
    fn prefetch_never_evicts_the_in_use_accelerator() {
        let mut c = coord();
        c.set_predict(true);
        for _ in 0..2 {
            c.submit(&chain_a_req(256)).unwrap();
            c.submit(&chain_b_req(256)).unwrap();
        }
        c.submit(&chain_a_req(256)).unwrap();
        let residents: Vec<_> =
            c.engine.fabric.tiles.iter().map(|t| t.resident).collect();
        let downloads = c.metrics.pr_downloads;
        assert!(c.prefetch_predicted().is_none(), "no safe tiles for chain B");
        let after: Vec<_> = c.engine.fabric.tiles.iter().map(|t| t.resident).collect();
        assert_eq!(residents, after, "chain A stays resident untouched");
        assert_eq!(c.metrics.pr_downloads, downloads);
        // the declined speculation costs nothing at the next submit either
        c.submit(&chain_a_req(256)).unwrap();
        assert_eq!(c.metrics.prefetch_hits + c.metrics.prefetch_wasted, 0);
    }

    /// A cached plan pointing at a quarantined tile is never replayed by
    /// the prefetcher: it respecializes onto healthy free tiles instead,
    /// and the staged accelerator then serves with zero downloads.
    #[test]
    fn prefetch_respecializes_around_quarantine() {
        let mut c = coord();
        c.set_predict(true);
        for k in 0..2 {
            c.submit(&vmul_req(256, k as f32 + 1.0)).unwrap();
            c.submit(&map_req(256)).unwrap();
        }
        c.submit(&vmul_req(256, 9.0)).unwrap();
        // kill the tile holding map's resident (and its cached plan target)
        let map_tile = c
            .engine
            .fabric
            .tiles
            .iter()
            .position(|t| t.resident == Some(OperatorKind::Abs))
            .unwrap();
        assert!(c.engine.fabric.quarantine(map_tile));
        let downloads = c.metrics.pr_downloads;
        assert!(c.prefetch_predicted().is_some());
        let new_tile = c
            .engine
            .fabric
            .tiles
            .iter()
            .position(|t| t.resident == Some(OperatorKind::Abs))
            .unwrap();
        assert_ne!(new_tile, map_tile);
        assert!(!c.engine.fabric.tiles[new_tile].quarantined);
        assert_eq!(c.metrics.pr_downloads, downloads);
        let r = c.submit(&map_req(256)).unwrap();
        assert!(r.cached);
        assert_eq!(c.metrics.prefetch_hits, 1);
        assert_eq!(c.metrics.pr_downloads, downloads, "prefetched bits serve the hit");
    }

    /// End-to-end compaction: a 6-stage chain's last stage lands on Large
    /// tile 3 (snake order 0,1,2,5,4,3); compaction migrates it to a free
    /// Small tile, strictly reduces mean internal fragmentation, and
    /// republishes the cached plan so the next request replays the migrated
    /// placement with zero downloads.
    #[test]
    fn compact_once_migrates_and_republishes_the_cached_plan() {
        use OperatorKind::*;
        let mut c = coord();
        c.set_compact(true);
        let req = Request::dynamic(
            Composition::chain(&[Neg, Abs, Square, Relu, Neg, Abs], 256).unwrap(),
            vec![vec![1.5; 256]],
        );
        let r1 = c.submit(&req).unwrap();
        assert_eq!(c.metrics.pr_downloads, 6);
        assert_eq!(c.engine.fabric.tiles[3].resident, Some(Abs));
        let (before, after) = c.compact_once().unwrap();
        assert!(after < before, "migration strictly tightens the fit");
        assert_eq!(c.metrics.migrations, 1);
        assert!(c.engine.fabric.tiles[3].resident.is_none(), "Large tile vacated");
        assert_eq!(c.engine.fabric.tiles[6].resident, Some(Abs), "first free Small tile");
        assert!(c.compact_once().is_none(), "second pass settles");
        let r2 = c.submit(&req).unwrap();
        assert!(r2.cached, "republished plan replays as a full hit");
        assert_eq!(c.metrics.pr_downloads, 6, "no re-download after migration");
        assert_eq!(c.metrics.placement_respecializations, 0);
        assert_eq!(
            r1.run.output.as_vector().unwrap(),
            r2.run.output.as_vector().unwrap()
        );
    }
}
