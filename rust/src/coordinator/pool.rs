//! Multi-fabric worker pool: bounded queues, reconfiguration-aware burst
//! draining, and whole-group work-stealing.
//!
//! The paper's run-time system owns **one** overlay fabric; this module
//! scales it out the way a deployment would: N workers, each owning its own
//! [`crate::exec::Engine`] (fabric + PR manager + residency state), fed
//! through **bounded per-worker job queues** by an affinity scheduler:
//!
//! * **home routing** — each [`Request`]'s composition hashes to a home
//!   worker through the same splitmix64-mixed consistent-hash ring the
//!   cluster tier uses ([`crate::coordinator::cluster::HashRing`] over
//!   worker indices), so repeated compositions land where their
//!   accelerator is already compiled *and* its operators are already
//!   resident in the PR regions — skipping both the JIT and the ICAP
//!   download (the Fig. 3 amortization, multiplied across fabrics) — and
//!   growing the worker count moves only ~1/N of homes instead of the
//!   near-total remap the old `cache_key % workers` hash suffered;
//! * **sticky spill** — when the home queue runs deeper than the
//!   least-loaded worker by more than `max_queue_skew`, the request spills
//!   to the least-loaded worker and the routing table is updated so future
//!   repeats follow it (residency migrates once, not per request). The
//!   routing table is LRU-capped (`route_capacity`); evicting a route only
//!   forgets affinity — the key falls back to its home hash;
//! * **burst draining** — a worker pops up to `drain_window` queued jobs
//!   per wakeup and runs them through the coordinator's
//!   reconfiguration-aware scheduler ([`Coordinator::serve_burst`]):
//!   stable-grouped by composition key, the fabric reconfigures once per
//!   *group* instead of once per interleaved request, and the worker folds
//!   **one** metrics delta per burst. `drain_window = 1` degenerates to the
//!   PR 1 FIFO drain;
//! * **work-stealing** — an idle worker (empty queue) steals from a queue
//!   holding ≥ `steal_min_depth` jobs, **preferring victims whose tail
//!   composition already has a placement plan cached for the thief's
//!   fabric** (those steals skip the placement respecialization; scoring
//!   is lock-free via an atomic tail-key mirror), deepest-first otherwise.
//!   It takes the **whole tail composition group** (every queued job of
//!   the tail key — never a prefix), refuses a tail key that continues
//!   into the burst the victim is currently serving (so a same-key run cut
//!   by the drain window is not split across fabrics), and the route table
//!   is repointed so repeats follow the stolen residency to the thief's
//!   fabric;
//! * **backpressure** — queues are bounded at `queue_capacity`:
//!   [`WorkerPool::try_submit`] fails fast with [`Error::PoolBusy`] (and
//!   counts `Metrics::rejected`), [`WorkerPool::submit`] blocks until the
//!   chosen queue has room. The full-queue check reads an atomic depth
//!   mirror, so rejection never takes a lock, and acceptance takes one
//!   short per-worker lock — submitters to different workers never
//!   contend (the PR 1 `Mutex<mpsc::Sender>` wrapper is gone);
//! * **aggregate metrics** — workers fold per-burst deltas into one
//!   [`AtomicMetrics`] snapshot *before* delivering the burst's replies,
//!   so any client holding a response already sees it counted, and pool
//!   totals equal the sum of worker records (`rejected` excepted — it is
//!   pool-level, accounted by the submit path).
//!
//! * **two reply paths** — every job carries a [`ReplySink`]: the blocking
//!   `submit`/`submit_wait` API replies over a per-request channel, while
//!   [`WorkerPool::submit_async`] returns a [`Ticket`] and replies through
//!   a single shared [`CompletionQueue`] that one consumer (the reactor
//!   front end, [`crate::coordinator::frontend`]) drains for *all*
//!   in-flight requests — no per-request channel, no per-request blocked
//!   `recv`.
//!
//! * **worker supervision** — each burst is served under `catch_unwind`:
//!   a panicking serving path bills its metrics delta into the aggregate,
//!   rebuilds the worker's [`Coordinator`] in place on the same thread
//!   (fresh fabric, same shared cache), and either **replays** the staged
//!   burst (injected faults fire before the jobs are taken, so they never
//!   left the staging slot) or lets the consumed jobs' [`ReplySink`] drops
//!   fail safe — every request still gets exactly one reply. Counted in
//!   `Metrics::workers_restarted` / `Metrics::jobs_replayed`.
//!
//! For deterministic batching experiments, [`WorkerPool::new_paused`]
//! spawns workers held at a start gate: enqueue a full backlog, then
//! [`WorkerPool::start`] (or [`WorkerPool::start_worker`]) and measure the
//! pure drain. The benches and the burst/steal tests are built on this.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::cluster::HashRing;
use super::{
    AcceleratorCache, AtomicMetrics, ClockLru, Coordinator, Job, Metrics, Request, Response,
};
use crate::config::{OverlayConfig, ServiceConfig};
use crate::error::{Error, Result};
use crate::faults::FaultPlane;

/// Shortest idle-worker sleep between checking its own queue and the steal
/// candidates. Doubles up to [`IDLE_POLL_MAX`] while nothing arrives, so a
/// busy pool steals within ~0.5 ms but an idle pool settles at ~50
/// wakeups/s per worker instead of 2000.
const IDLE_POLL: Duration = Duration::from_micros(500);

/// Identifier pairing an async submission with its eventual [`Completion`].
/// Allocated by [`CompletionQueue::next_ticket`] — monotonic per queue, so
/// a ticket is unique within the queue its submission named.
pub type Ticket = u64;

/// One finished request, delivered through a [`CompletionQueue`].
#[derive(Debug)]
pub struct Completion {
    /// The ticket returned by the `submit_async` that started the request.
    pub ticket: Ticket,
    /// The request's outcome — a served response or its error.
    pub result: Result<Response>,
}

/// The pool's shared completion path: workers push every async reply here
/// and a single consumer (the reactor front end) drains them in batches —
/// the inversion of the one-`mpsc::Receiver`-per-request model, where each
/// pending request cost its own channel and its own blocked `recv`.
///
/// The queue doubles as the consumer's event source: [`CompletionQueue::wake`]
/// posts a bare wakeup (a client submitted, a session closed, shutdown), and
/// [`CompletionQueue::wait`] parks until a completion or a wakeup is pending.
#[derive(Debug)]
pub struct CompletionQueue {
    inner: Mutex<CqInner>,
    cv: Condvar,
    tickets: AtomicU64,
}

#[derive(Debug)]
struct CqInner {
    completions: VecDeque<Completion>,
    /// Pending bare wakeups, consumed by [`CompletionQueue::wait`].
    wakes: usize,
}

impl CompletionQueue {
    pub fn new() -> CompletionQueue {
        CompletionQueue {
            inner: Mutex::new(CqInner { completions: VecDeque::new(), wakes: 0 }),
            cv: Condvar::new(),
            tickets: AtomicU64::new(0),
        }
    }

    fn lock(&self) -> MutexGuard<'_, CqInner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Allocate the next ticket (1, 2, 3, …).
    pub fn next_ticket(&self) -> Ticket {
        self.tickets.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Push one completion and notify the consumer.
    pub fn push(&self, completion: Completion) {
        let mut g = self.lock();
        g.completions.push_back(completion);
        drop(g);
        self.cv.notify_one();
    }

    /// Take every queued completion (possibly none), without blocking.
    pub fn drain(&self) -> Vec<Completion> {
        let mut g = self.lock();
        g.completions.drain(..).collect()
    }

    /// Post a bare wakeup: [`CompletionQueue::wait`] returns even though no
    /// completion arrived (new client work, session close, shutdown).
    pub fn wake(&self) {
        let mut g = self.lock();
        g.wakes += 1;
        drop(g);
        self.cv.notify_one();
    }

    /// Park until a completion or a wakeup is pending, or `timeout` passes.
    /// Consumes every pending wakeup (a burst of submissions costs one
    /// extra poll, not one per submission); queued completions are left
    /// for [`CompletionQueue::drain`].
    ///
    /// The timeout is an **absolute deadline**: the remaining wait is
    /// recomputed on every loop iteration. Re-arming the full timeout per
    /// condvar wakeup — the previous behavior — let wakeup churn (spurious
    /// wakeups, or a completion observed by the notified waiter only after
    /// a racing `drain` emptied the queue) park the caller far beyond the
    /// timeout it asked for.
    pub fn wait(&self, timeout: Duration) {
        // `checked_add` guards pathological `Duration::MAX`-style timeouts;
        // an unrepresentable deadline degrades to hour-long re-arms.
        let deadline = Instant::now().checked_add(timeout);
        let mut g = self.lock();
        while g.completions.is_empty() && g.wakes == 0 {
            let remaining = match deadline {
                Some(d) => {
                    let r = d.saturating_duration_since(Instant::now());
                    if r.is_zero() {
                        return;
                    }
                    r
                }
                None => Duration::from_secs(3600),
            };
            let (woken, _) =
                self.cv.wait_timeout(g, remaining).unwrap_or_else(|p| p.into_inner());
            g = woken;
        }
        g.wakes = 0;
    }

    /// Completions currently queued.
    pub fn len(&self) -> usize {
        self.lock().completions.len()
    }

    /// True when no completion is queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for CompletionQueue {
    fn default() -> CompletionQueue {
        CompletionQueue::new()
    }
}

/// Where a [`Job`]'s reply goes: a per-request channel (the blocking
/// `submit`/`submit_wait` path) or a shared [`CompletionQueue`] tagged with
/// the request's [`Ticket`] (the async front-end path).
///
/// A sink dropped without delivering — a worker died with the job queued,
/// a panic unwound the serving path — fails safe: the queue variant pushes
/// an error completion so no session waits forever on a ticket that can no
/// longer complete, and the channel variant disconnects its receiver by
/// dropping the sender (the PR 3 behavior, unchanged).
#[derive(Debug)]
pub struct ReplySink {
    kind: Option<SinkKind>,
}

#[derive(Debug)]
enum SinkKind {
    Channel(mpsc::Sender<Result<Response>>),
    Queue { completions: Arc<CompletionQueue>, ticket: Ticket },
}

impl ReplySink {
    /// Reply through a dedicated per-request channel.
    pub fn channel(tx: mpsc::Sender<Result<Response>>) -> ReplySink {
        ReplySink { kind: Some(SinkKind::Channel(tx)) }
    }

    /// Reply through a shared completion queue under `ticket`.
    pub fn queue(completions: Arc<CompletionQueue>, ticket: Ticket) -> ReplySink {
        ReplySink { kind: Some(SinkKind::Queue { completions, ticket }) }
    }

    /// Deliver the reply. A hung-up channel receiver is not an error.
    pub fn deliver(mut self, result: Result<Response>) {
        self.send(result);
    }

    /// Disarm the sink without delivering anything: the submission failed
    /// and its error went back to the caller directly, so no completion
    /// must ever surface for this ticket.
    pub(crate) fn defuse(mut self) {
        self.kind = None;
    }

    fn send(&mut self, result: Result<Response>) {
        match self.kind.take() {
            Some(SinkKind::Channel(tx)) => {
                let _ = tx.send(result);
            }
            Some(SinkKind::Queue { completions, ticket }) => {
                completions.push(Completion { ticket, result });
            }
            None => {}
        }
    }
}

impl Drop for ReplySink {
    fn drop(&mut self) {
        if matches!(self.kind, Some(SinkKind::Queue { .. })) {
            self.send(Err(Error::Runtime("pool worker dropped the reply".into())));
        }
        // Channel: dropping the sender disconnects the receiver — exactly
        // the signal blocking clients already interpret as a dead worker.
    }
}

/// Idle-poll backoff ceiling (worst-case added steal latency).
const IDLE_POLL_MAX: Duration = Duration::from_millis(20);

/// Virtual nodes per worker on the in-pool home-hash ring. Pools are
/// narrow (a handful of workers), so fewer points than the cluster
/// default still spread homes well, and the ring is built once at pool
/// construction — lookup cost is a binary search either way.
const WORKER_VNODES: usize = 32;

/// What a worker thread leaves behind when the pool shuts down.
struct WorkerExit {
    metrics: Metrics,
    resident_tiles: usize,
    total_tiles: usize,
}

/// Everything a worker needs to rebuild its [`Coordinator`] in place after
/// a panic unwound the serving path — the supervision rung of the recovery
/// ladder. The fault plane is shared (an `Arc`), so a respawned worker
/// keeps consuming the same deterministic schedule.
struct RespawnSpec {
    cfg: OverlayConfig,
    fuse: bool,
    predict: bool,
    compact: bool,
    plane: Arc<FaultPlane>,
    download_retries: u32,
}

impl RespawnSpec {
    /// Build a fresh coordinator against the shared cache, wired exactly
    /// like the one it replaces.
    fn rebuild(&self, cache: &Arc<AcceleratorCache>) -> Result<Coordinator> {
        let mut c = Coordinator::with_cache(self.cfg.clone(), cache.clone())?;
        c.set_fusion(self.fuse);
        c.set_predict(self.predict);
        c.set_compact(self.compact);
        c.set_faults(self.plane.clone(), self.download_retries);
        Ok(c)
    }
}

/// A bounded MPMC job queue: submitters push, the owning worker drains in
/// bursts, idle peers steal whole composition groups from the tail.
struct JobQueue {
    inner: Mutex<QueueInner>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
    /// Mirror of `inner.jobs.len()`, readable without the lock: the
    /// lock-free full-queue fast-fail and the steal victim choice.
    depth: AtomicUsize,
    /// Queued + in-flight requests on this worker (the scheduler's load
    /// signal). Incremented at dispatch, decremented after serving.
    load: AtomicUsize,
    /// Composition key of the tail of the burst the owner is currently
    /// serving, valid while `inflight_valid`. Written only by the owning
    /// worker: under the queue lock at pop time, or (for a stolen group)
    /// inside `steal_into` before the route repoint publishes the thief.
    /// Thieves refuse to steal this key, so a same-key run cut by the
    /// drain window is not split across fabrics (the common straddle).
    /// Distinct groups interleaved across the window boundary can still
    /// migrate — bounded extra downloads, not a correctness issue.
    inflight_tail_key: AtomicU64,
    inflight_valid: AtomicBool,
    /// Composition key of the *queued* tail job, valid while `tail_valid`:
    /// an atomic mirror (maintained under the lock at every push/pop/steal,
    /// like `depth`) so steal-victim scoring reads it without contending on
    /// the mutex of a busy queue. Purely a scoring hint — the steal itself
    /// re-reads the real tail under the lock.
    tail_key: AtomicU64,
    tail_valid: AtomicBool,
}

struct QueueInner {
    jobs: VecDeque<Job>,
    closed: bool,
}

/// A failed push hands the job back so the caller can fail over or reject.
enum PushError {
    Full(Job),
    Closed(Job),
}

impl JobQueue {
    fn new(capacity: usize) -> JobQueue {
        JobQueue {
            inner: Mutex::new(QueueInner { jobs: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
            depth: AtomicUsize::new(0),
            load: AtomicUsize::new(0),
            inflight_tail_key: AtomicU64::new(0),
            inflight_valid: AtomicBool::new(false),
            tail_key: AtomicU64::new(0),
            tail_valid: AtomicBool::new(false),
        }
    }

    /// Refresh the queued-tail mirror from the deque (call with the lock
    /// held, after any mutation of `jobs`).
    fn sync_tail(&self, g: &QueueInner) {
        match g.jobs.back() {
            Some(j) => {
                self.tail_key.store(j.request.comp.cache_key(), Ordering::Relaxed);
                // Release pairs with the Acquire in `tail_hint`: a reader
                // that observes `valid` also observes the matching key
                self.tail_valid.store(true, Ordering::Release);
            }
            None => self.tail_valid.store(false, Ordering::Relaxed),
        }
    }

    /// Lock-free read of the queued-tail mirror (`None` = empty queue).
    fn tail_hint(&self) -> Option<u64> {
        if self.tail_valid.load(Ordering::Acquire) {
            Some(self.tail_key.load(Ordering::Relaxed))
        } else {
            None
        }
    }

    /// Lock the queue, recovering from poisoning: every critical section
    /// leaves the deque in a consistent state (a push/pop either completed
    /// or never happened), so a panicking peer cannot corrupt it.
    fn lock(&self) -> MutexGuard<'_, QueueInner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Non-blocking push. A full queue is detected from the atomic depth
    /// mirror before taking any lock, so the backpressure path is lock-free.
    fn try_push(&self, job: Job) -> std::result::Result<(), PushError> {
        if self.depth.load(Ordering::Relaxed) >= self.capacity {
            return Err(PushError::Full(job));
        }
        let mut g = self.lock();
        if g.closed {
            return Err(PushError::Closed(job));
        }
        if g.jobs.len() >= self.capacity {
            return Err(PushError::Full(job));
        }
        g.jobs.push_back(job);
        self.depth.store(g.jobs.len(), Ordering::Relaxed);
        self.sync_tail(&g);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking push: waits for room. `Err` returns the job when the queue
    /// closed while waiting (the worker is gone).
    fn push_blocking(&self, job: Job) -> std::result::Result<(), Job> {
        let mut g = self.lock();
        loop {
            if g.closed {
                return Err(job);
            }
            if g.jobs.len() < self.capacity {
                g.jobs.push_back(job);
                self.depth.store(g.jobs.len(), Ordering::Relaxed);
                self.sync_tail(&g);
                drop(g);
                self.not_empty.notify_one();
                return Ok(());
            }
            g = self.not_full.wait(g).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Pop up to `max` jobs in arrival order. `None` means closed *and*
    /// drained (the worker should exit); `Some(empty)` means currently
    /// empty but still open (try stealing, then wait).
    fn pop_burst(&self, max: usize) -> Option<Vec<Job>> {
        let mut g = self.lock();
        if !g.jobs.is_empty() {
            let take = max.min(g.jobs.len());
            let burst: Vec<Job> = g.jobs.drain(..take).collect();
            self.depth.store(g.jobs.len(), Ordering::Relaxed);
            self.sync_tail(&g);
            // mark the burst's tail group while still holding the lock, so
            // a thief can never observe the queue remainder without also
            // seeing that its head group is in flight here
            let tail = burst.last().expect("nonempty burst");
            self.mark_inflight(tail.request.comp.cache_key());
            drop(g);
            self.not_full.notify_all();
            Some(burst)
        } else if g.closed {
            None
        } else {
            Some(Vec::new())
        }
    }

    /// Park until the queue becomes nonempty or closes. With a timeout —
    /// the idle worker's steal-poll cadence — the wait wakes periodically
    /// to scan for steal victims; without one it sleeps until notified
    /// (stealing disabled: nothing else to watch).
    fn wait_nonempty(&self, timeout: Option<Duration>) {
        let g = self.lock();
        if !g.jobs.is_empty() || g.closed {
            return;
        }
        match timeout {
            Some(t) => {
                let (woken, _) =
                    self.not_empty.wait_timeout(g, t).unwrap_or_else(|p| p.into_inner());
                drop(woken);
            }
            None => {
                let woken = self.not_empty.wait(g).unwrap_or_else(|p| p.into_inner());
                drop(woken);
            }
        }
    }

    /// Close the queue: submitters fail over, the worker drains and exits.
    fn close(&self) {
        let mut g = self.lock();
        g.closed = true;
        drop(g);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Close the queue *and discard* anything still queued. Dropping the
    /// jobs fires each [`ReplySink`]'s fail-safe: channel clients blocked
    /// in `recv` observe a disconnect, and async submissions get an error
    /// completion pushed to their queue — nobody waits forever on a worker
    /// that died with their job queued. Zeroing the
    /// depth mirror also keeps [`JobQueue::try_push`]'s lock-free full
    /// check from reporting a dead-at-capacity queue as `Full` (which would
    /// surface as `PoolBusy` instead of failing over). The load counter is
    /// deliberately left inflated: a dead worker must not look attractive
    /// to the spill heuristic.
    fn close_and_discard(&self) {
        let mut g = self.lock();
        g.closed = true;
        g.jobs.clear();
        self.depth.store(0, Ordering::Relaxed);
        self.sync_tail(&g);
        drop(g);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Record the in-flight burst's tail composition key (see the field
    /// docs for the straddle-protection rationale).
    fn mark_inflight(&self, key: u64) {
        self.inflight_tail_key.store(key, Ordering::Relaxed);
        // Release pairs with the Acquire in the steal guard: a reader that
        // observes `valid` also observes the matching key
        self.inflight_valid.store(true, Ordering::Release);
    }

    /// The burst finished: its groups are fully served and stealable again.
    fn clear_inflight(&self) {
        self.inflight_valid.store(false, Ordering::Relaxed);
    }
}

/// A start gate: worker threads wait here so paused pools can accumulate a
/// backlog before serving (deterministic burst/steal experiments).
struct Gate {
    flag: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn new(open: bool) -> Gate {
        Gate { flag: Mutex::new(open), cv: Condvar::new() }
    }

    fn wait(&self) {
        let mut g = self.flag.lock().unwrap_or_else(|p| p.into_inner());
        while !*g {
            g = self.cv.wait(g).unwrap_or_else(|p| p.into_inner());
        }
    }

    fn open(&self) {
        let mut g = self.flag.lock().unwrap_or_else(|p| p.into_inner());
        *g = true;
        drop(g);
        self.cv.notify_all();
    }
}

/// Sticky composition→worker routing table: a [`ClockLru`] of
/// `AtomicUsize` worker indices.
///
/// The steady state — looking up or repointing an existing route — takes
/// only the read lock: the worker index lives in an atomic inside the
/// entry and recency in the LRU's atomic clock. The write lock is taken
/// once per brand-new composition, where the LRU amortizes its O(n)
/// recency scan by evicting the stalest ~1/8 of the table per pass
/// (submitters wait behind that exclusive lock).
struct RouteTable {
    map: ClockLru<AtomicUsize>,
}

impl RouteTable {
    fn new(capacity: usize) -> RouteTable {
        let batch = if capacity == 0 { 1 } else { (capacity / 8).max(1) };
        RouteTable { map: ClockLru::with_evict_batch(capacity, batch) }
    }

    fn get(&self, key: u64) -> Option<usize> {
        self.map.get(key, |w| w.load(Ordering::Relaxed))
    }

    /// Point `key` at `worker`, evicting the least-recently-hit routes when
    /// a brand-new key would exceed the cap.
    fn set(&self, key: u64, worker: usize) {
        self.map.update_or_insert(
            key,
            |w| w.store(worker, Ordering::Relaxed),
            || AtomicUsize::new(worker),
        );
    }

    fn len(&self) -> usize {
        self.map.len()
    }
}

/// State shared by submitters and every worker thread.
struct PoolShared {
    queues: Vec<JobQueue>,
    route: RouteTable,
    gates: Vec<Gate>,
    steal_min_depth: usize,
    max_queue_skew: usize,
    /// The pool-wide accelerator cache, consulted by steal-victim scoring.
    cache: Arc<AcceleratorCache>,
    /// Worker index → its fabric's id (plan-cache key).
    fabric_ids: Vec<u64>,
    /// Consistent-hash ring over worker indices: the home hash. Shares
    /// the cluster tier's splitmix64 mix, so key→worker homes are stable
    /// under worker-count changes (only ~1/N of keys re-home on growth).
    ring: HashRing,
}

impl PoolShared {
    /// Try to steal work for idle worker `thief`: among the other queues
    /// holding at least `steal_min_depth` jobs, **prefer a victim whose
    /// tail composition already has a placement plan cached for the
    /// thief's fabric** — that steal skips the placement respecialization
    /// entirely (the group ran here before) — falling back to the deepest
    /// queue. Extract **every** queued job of the chosen tail key — whole
    /// groups only, never splitting one — and repoint the route so repeats
    /// follow the stolen residency.
    fn steal_into(&self, thief: usize) -> Option<Vec<Job>> {
        if self.steal_min_depth == usize::MAX {
            return None;
        }
        // candidates at or above the steal threshold, deepest first
        // (ties broken toward the lowest index, as before)
        let mut candidates: Vec<(usize, usize)> = self
            .queues
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != thief)
            .filter_map(|(i, q)| {
                let d = q.depth.load(Ordering::Relaxed);
                (d >= self.steal_min_depth).then_some((d, i))
            })
            .collect();
        candidates.sort_unstable_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        if candidates.is_empty() {
            return None;
        }
        // order the attempts: plan-preferred victims first, the rest after,
        // both deepest-first — an inflight-blocked (or meanwhile emptied)
        // victim falls through to the next candidate instead of aborting
        // the whole steal and idling the thief
        let thief_fabric = self.fabric_ids[thief];
        let mut order = Vec::with_capacity(candidates.len());
        let mut rest = Vec::new();
        for &(_, i) in &candidates {
            // lock-free: the tail mirror plus a recency-neutral cache peek,
            // so scoring contends on neither busy-queue mutexes nor LRUs
            let preferred = self.queues[i]
                .tail_hint()
                .map_or(false, |key| self.cache.has_plan(key, thief_fabric));
            if preferred {
                order.push(i);
            } else {
                rest.push(i);
            }
        }
        order.extend(rest);
        order.into_iter().find_map(|v| self.try_steal_from(v, thief))
    }

    /// Take the whole tail composition group of `v`'s queue for `thief`,
    /// repointing the route so repeats follow the stolen residency. `None`
    /// when the queue emptied since scoring or its tail group continues
    /// into the burst the victim is serving right now (a same-key run cut
    /// by the drain window — stealing it would split the group across
    /// fabrics and thrash both).
    fn try_steal_from(&self, v: usize, thief: usize) -> Option<Vec<Job>> {
        let vq = &self.queues[v];
        let mut g = vq.lock();
        let key = g.jobs.back()?.request.comp.cache_key();
        if vq.inflight_valid.load(Ordering::Acquire)
            && vq.inflight_tail_key.load(Ordering::Relaxed) == key
        {
            return None;
        }
        let mut stolen = Vec::new();
        let mut kept = VecDeque::with_capacity(g.jobs.len());
        while let Some(job) = g.jobs.pop_front() {
            if job.request.comp.cache_key() == key {
                stolen.push(job);
            } else {
                kept.push_back(job);
            }
        }
        g.jobs = kept;
        self.queues[thief].load.fetch_add(stolen.len(), Ordering::SeqCst);
        vq.load.fetch_sub(stolen.len(), Ordering::SeqCst);
        vq.depth.store(g.jobs.len(), Ordering::Relaxed);
        vq.sync_tail(&g);
        drop(g);
        vq.not_full.notify_all();
        // guard the stolen group on the thief's marker BEFORE the route
        // repoint publishes the new destination: otherwise a same-key job
        // could route to the thief and a third worker could re-steal it
        // while this group is in flight
        self.queues[thief].mark_inflight(key);
        self.route.set(key, thief);
        Some(stolen)
    }
}

/// Final pool accounting returned by [`WorkerPool::shutdown`].
#[derive(Debug, Clone)]
pub struct PoolReport {
    /// The atomic aggregate's final snapshot.
    pub aggregate: Metrics,
    /// Each worker's own metrics record, in worker order.
    pub per_worker: Vec<Metrics>,
    /// Each worker's final fabric occupancy `(resident tiles, total tiles)`.
    pub per_worker_residency: Vec<(usize, usize)>,
    /// Compiled accelerators in the shared cache at shutdown.
    pub cached_accelerators: usize,
    /// Workers whose thread panicked (their per-worker record is zeroed, so
    /// [`PoolReport::worker_sum`] undercounts the aggregate when nonempty).
    pub panicked_workers: Vec<usize>,
}

impl PoolReport {
    /// Sum of the per-worker records. Equals [`PoolReport::aggregate`] on
    /// every worker-served counter (up to nanosecond rounding on the
    /// seconds fields) — provided [`PoolReport::panicked_workers`] is empty.
    /// The exception is `Metrics::rejected`: backpressure rejections are
    /// recorded by the submit path straight into the aggregate and appear
    /// in no worker's record.
    pub fn worker_sum(&self) -> Metrics {
        let mut sum = Metrics::default();
        for m in &self.per_worker {
            sum.merge(m);
        }
        sum
    }
}

/// A pool of N coordinator workers, each owning its own overlay fabric.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<WorkerExit>>,
    /// Live pool-level aggregate (see [`AtomicMetrics`]).
    pub metrics: Arc<AtomicMetrics>,
    cache: Arc<AcceleratorCache>,
    queue_capacity: usize,
}

impl WorkerPool {
    /// Spawn `service.workers` workers, each with a fabric built from
    /// `cfg`, serving immediately.
    pub fn new(cfg: OverlayConfig, service: ServiceConfig) -> Result<WorkerPool> {
        Self::build(cfg, service, true, None)
    }

    /// Like [`WorkerPool::new`], but workers are held at a start gate until
    /// [`WorkerPool::start`] / [`WorkerPool::start_worker`]: enqueue a full
    /// backlog first, then release the workers and measure the pure drain.
    ///
    /// While paused nothing drains, so blocking [`WorkerPool::submit`]
    /// calls beyond `queue_capacity` will wait until the pool starts;
    /// paused experiments should size `queue_capacity` to the backlog (or
    /// use [`WorkerPool::try_submit`]).
    pub fn new_paused(cfg: OverlayConfig, service: ServiceConfig) -> Result<WorkerPool> {
        Self::build(cfg, service, false, None)
    }

    /// Like [`WorkerPool::new`], but serving from a caller-supplied shared
    /// [`AcceleratorCache`] instead of building a private one
    /// (`service.cache_shards` / `cache_capacity` are then ignored). This
    /// is how accelerators pre-compiled elsewhere — another pool, a
    /// standalone [`Coordinator`] — flow into the pool: the program is
    /// reused as-is and each fabric specializes its own placement on first
    /// touch.
    pub fn with_cache(
        cfg: OverlayConfig,
        service: ServiceConfig,
        cache: Arc<AcceleratorCache>,
    ) -> Result<WorkerPool> {
        Self::build(cfg, service, true, Some(cache))
    }

    /// [`WorkerPool::with_cache`] with workers held at the start gate (see
    /// [`WorkerPool::new_paused`]).
    pub fn with_cache_paused(
        cfg: OverlayConfig,
        service: ServiceConfig,
        cache: Arc<AcceleratorCache>,
    ) -> Result<WorkerPool> {
        Self::build(cfg, service, false, Some(cache))
    }

    fn build(
        cfg: OverlayConfig,
        service: ServiceConfig,
        started: bool,
        cache: Option<Arc<AcceleratorCache>>,
    ) -> Result<WorkerPool> {
        service.validate()?;
        let cache = cache.unwrap_or_else(|| {
            Arc::new(AcceleratorCache::bounded(service.cache_shards, service.cache_capacity))
        });
        // one plan slot per fabric: a composition hot on every worker must
        // never cycle its per-fabric plan LRU — raised on externally
        // supplied caches too (their default cap may be below the width)
        cache.ensure_plan_capacity(service.workers);
        let metrics = Arc::new(AtomicMetrics::default());
        // build every coordinator before spawning anything: the shared
        // state carries each worker's fabric id (steal-victim scoring), so
        // the ids must all be known up front — and a failed fabric
        // construction then simply returns before any thread exists
        let plane = FaultPlane::from_spec(service.faults.clone());
        let mut coords = Vec::with_capacity(service.workers);
        for _ in 0..service.workers {
            let mut c = Coordinator::with_cache(cfg.clone(), cache.clone())?;
            c.set_fusion(service.fuse);
            c.set_predict(service.predict);
            c.set_compact(service.compact);
            c.set_faults(plane.clone(), service.download_retries);
            coords.push(c);
        }
        let shared = Arc::new(PoolShared {
            queues: (0..service.workers).map(|_| JobQueue::new(service.queue_capacity)).collect(),
            route: RouteTable::new(service.route_capacity),
            gates: (0..service.workers).map(|_| Gate::new(started)).collect(),
            steal_min_depth: service.steal_min_depth,
            max_queue_skew: service.max_queue_skew,
            cache: cache.clone(),
            fabric_ids: coords.iter().map(|c| c.engine.fabric.id).collect(),
            ring: HashRing::new(
                &(0..service.workers as u64).collect::<Vec<u64>>(),
                WORKER_VNODES,
            ),
        });
        let mut handles = Vec::with_capacity(service.workers);
        for (w, coord) in coords.into_iter().enumerate() {
            let shared_w = shared.clone();
            let agg = metrics.clone();
            let drain_window = service.drain_window;
            let respawn = RespawnSpec {
                cfg: cfg.clone(),
                fuse: service.fuse,
                predict: service.predict,
                compact: service.compact,
                plane: plane.clone(),
                download_retries: service.download_retries,
            };
            let spawned = std::thread::Builder::new()
                .name(format!("overlay-worker-{w}"))
                .spawn(move || worker_loop(coord, w, shared_w, agg, drain_window, respawn))
                .map_err(Error::from);
            match spawned {
                Ok(handle) => handles.push(handle),
                Err(e) => {
                    // release the workers already spawned so they exit
                    // instead of leaking at the gate
                    for q in &shared.queues {
                        q.close();
                    }
                    for g in &shared.gates {
                        g.open();
                    }
                    return Err(e);
                }
            }
        }
        Ok(WorkerPool {
            shared,
            handles,
            metrics,
            cache,
            queue_capacity: service.queue_capacity,
        })
    }

    /// Release every worker of a paused pool.
    pub fn start(&self) {
        for g in &self.shared.gates {
            g.open();
        }
    }

    /// Release a single worker of a paused pool (deterministic
    /// work-stealing experiments: start only the thief).
    pub fn start_worker(&self, w: usize) {
        self.shared.gates[w].open();
    }

    /// Number of workers in the pool.
    pub fn worker_count(&self) -> usize {
        self.shared.queues.len()
    }

    /// Compiled accelerators currently in the shared cache.
    pub fn cached_accelerators(&self) -> usize {
        self.cache.len()
    }

    /// Entries in the sticky routing table (LRU-capped at
    /// `ServiceConfig::route_capacity`).
    pub fn routed_compositions(&self) -> usize {
        self.shared.route.len()
    }

    /// Jobs currently queued (not in-flight) at worker `w`.
    pub fn queue_depth(&self, w: usize) -> usize {
        self.shared.queues[w].depth.load(Ordering::Relaxed)
    }

    /// Live aggregate metrics snapshot.
    pub fn snapshot(&self) -> Metrics {
        self.metrics.snapshot()
    }

    /// The worker the scheduler would pick for composition key `key` right
    /// now: the sticky/home worker unless its queue is `max_queue_skew`
    /// deeper than the least-loaded one.
    ///
    /// Read-only — the routing table is only updated by submission and
    /// stealing. Two racing submitters of a brand-new key may both compute
    /// the same home (deterministic hash), so the race at worst duplicates
    /// one JIT compile, which the shared cache converges.
    pub fn planned_worker(&self, key: u64) -> usize {
        self.route_decision(key).0
    }

    /// One route-table read: returns the chosen worker and whether the
    /// sticky entry must be updated to match it.
    fn route_decision(&self, key: u64) -> (usize, bool) {
        let sticky = self.shared.route.get(key);
        // home = ring owner, not `key % n`: the ring's splitmix64-mixed
        // virtual nodes keep homes stable when the worker count changes
        // (a grown pool re-homes only the new worker's arcs, ~1/N of
        // keys), and share one hash discipline with the cluster router
        let home = sticky.unwrap_or_else(|| self.shared.ring.owner(key));
        // single allocation-free pass over the load counters
        let mut home_load = 0;
        let mut least = home;
        let mut least_load = usize::MAX;
        for (i, q) in self.shared.queues.iter().enumerate() {
            // Relaxed: like the steal-victim tail mirror, the load
            // counters are scoring hints mirrored beside the queue lock —
            // routing tolerates a stale read (at worst one extra spill or
            // one deferred one), and the enqueue that follows synchronizes
            // on the chosen queue's own lock, which stays authoritative
            let l = q.load.load(Ordering::Relaxed);
            if i == home {
                home_load = l;
            }
            if l < least_load {
                least_load = l;
                least = i;
            }
        }
        let spill = home_load > least_load.saturating_add(self.shared.max_queue_skew);
        let chosen = if spill { least } else { home };
        (chosen, sticky != Some(chosen))
    }

    /// Enqueue a request; returns the reply channel immediately. Blocks
    /// while the chosen worker's bounded queue is full (backpressure by
    /// waiting — use [`WorkerPool::try_submit`] to fail fast instead).
    ///
    /// Submitting many requests before draining any replies is how callers
    /// express pipelining. Each worker serves its queue in drain bursts
    /// reordered per window by composition group, so replies always pair
    /// with their own request channel and per-client `recv` order is
    /// whatever submit/recv pairing the client chose; strict pool-wide
    /// per-key FIFO is not guaranteed once spills or steals migrate a
    /// composition (disable them via `max_queue_skew` / `steal_min_depth`
    /// if required).
    pub fn submit(&self, request: Request) -> Result<mpsc::Receiver<Result<Response>>> {
        self.submit_channel(request, true)
    }

    /// Enqueue a request without blocking: a full queue returns
    /// [`Error::PoolBusy`] (counted in `Metrics::rejected`) and the caller
    /// decides — retry, shed, or drain replies first.
    pub fn try_submit(&self, request: Request) -> Result<mpsc::Receiver<Result<Response>>> {
        self.submit_channel(request, false)
    }

    /// Async submission: enqueue a request whose reply is pushed onto the
    /// shared `completions` queue instead of a dedicated channel, and
    /// return the [`Ticket`] that names it there. Never blocks — a full
    /// queue returns [`Error::PoolBusy`] (counted in `Metrics::rejected`).
    /// On any error no completion is ever delivered for the (discarded)
    /// ticket: the submission simply did not happen.
    ///
    /// This is the pool half of the reactor front end
    /// ([`crate::coordinator::frontend`]): one consumer drains one queue
    /// for *all* in-flight requests, where `submit` costs one channel and
    /// one blocked `recv` per request.
    pub fn submit_async(
        &self,
        request: Request,
        completions: &Arc<CompletionQueue>,
    ) -> Result<Ticket> {
        self.submit_async_reclaim(request, completions).map_err(|(_request, e)| e)
    }

    /// [`WorkerPool::submit_async`] that hands the request back on failure
    /// — the reactor's retry path resubmits it without a clone. Keeps the
    /// ticket/defuse lifecycle in exactly one place: a failed submission
    /// must never surface a completion for its (discarded) ticket.
    pub(crate) fn submit_async_reclaim(
        &self,
        request: Request,
        completions: &Arc<CompletionQueue>,
    ) -> std::result::Result<Ticket, (Request, Error)> {
        let ticket = completions.next_ticket();
        let job = Job { request, reply: ReplySink::queue(completions.clone(), ticket) };
        match self.route_and_enqueue(job, false) {
            Ok(()) => Ok(ticket),
            Err((job, e)) => {
                // never let the sink's drop deliver an error completion for
                // a submission whose error the caller got synchronously
                let Job { request, reply } = job;
                reply.defuse();
                Err((request, e))
            }
        }
    }

    fn submit_channel(
        &self,
        request: Request,
        block: bool,
    ) -> Result<mpsc::Receiver<Result<Response>>> {
        let (rtx, rrx) = mpsc::channel();
        let job = Job { request, reply: ReplySink::channel(rtx) };
        // dropping the failed job drops the sender; the receiver is dropped
        // by the caller along with this error
        self.route_and_enqueue(job, block).map_err(|(_job, e)| e)?;
        Ok(rrx)
    }

    /// Route a job and enqueue it, failing over past dead workers. On
    /// failure the job is handed back intact (with its reply sink unfired)
    /// so the caller decides: surface the error, retry later, or both.
    pub(crate) fn route_and_enqueue(
        &self,
        mut job: Job,
        block: bool,
    ) -> std::result::Result<(), (Job, Error)> {
        let key = job.request.comp.cache_key();
        // the routing table is written only when the decision changed — the
        // steady state (repeat composition, stable route) stays on the read
        // path and never serializes submitters
        let (w, stale) = self.route_decision(key);
        if stale {
            self.shared.route.set(key, w);
        }
        match self.enqueue(w, job, block) {
            Ok(()) => return Ok(()),
            Err(PushError::Full(j)) => return Err((j, self.reject(w))),
            Err(PushError::Closed(j)) => job = j,
        }
        // worker `w` is gone (its queue closed, e.g. a panicked thread).
        // Fail over to the other workers — lowest load first so a dead
        // worker's frozen counter can't keep attracting traffic — and
        // repoint the sticky route at whoever accepted. A full candidate is
        // skipped, not fatal: another may still have room.
        let mut candidates: Vec<usize> =
            (0..self.shared.queues.len()).filter(|&i| i != w).collect();
        candidates.sort_by_key(|&i| self.shared.queues[i].load.load(Ordering::SeqCst));
        let mut full_candidate = None;
        for c in candidates {
            match self.enqueue(c, job, block) {
                Ok(()) => {
                    self.shared.route.set(key, c);
                    return Ok(());
                }
                Err(PushError::Full(j)) => {
                    full_candidate = Some(c);
                    job = j;
                }
                Err(PushError::Closed(j)) => job = j,
            }
        }
        match full_candidate {
            // at least one live worker exists, it is just saturated
            Some(c) => Err((job, self.reject(c))),
            None => Err((job, Error::Runtime("every pool worker is gone".into()))),
        }
    }

    /// Enqueue on worker `w`, keeping the load counter consistent.
    fn enqueue(&self, w: usize, job: Job, block: bool) -> std::result::Result<(), PushError> {
        let q = &self.shared.queues[w];
        // count the job before it becomes poppable so the worker's
        // post-serve decrement can never underflow the counter
        q.load.fetch_add(1, Ordering::SeqCst);
        let res = if block {
            q.push_blocking(job).map_err(PushError::Closed)
        } else {
            q.try_push(job)
        };
        if res.is_err() {
            q.load.fetch_sub(1, Ordering::SeqCst);
        }
        res
    }

    /// Account one backpressure rejection and build the error.
    fn reject(&self, worker: usize) -> Error {
        self.metrics.record(&Metrics { rejected: 1, ..Default::default() });
        Error::PoolBusy { worker, capacity: self.queue_capacity }
    }

    /// Enqueue a request and block for its response.
    pub fn submit_wait(&self, request: Request) -> Result<Response> {
        self.submit(request)?
            .recv()
            .map_err(|_| Error::Runtime("pool worker dropped the reply".into()))?
    }

    /// Close every queue and open every gate: workers drain what is
    /// already queued, reply, and exit. Idempotent.
    fn release_workers(&self) {
        for q in &self.shared.queues {
            q.close();
        }
        for g in &self.shared.gates {
            g.open();
        }
    }

    /// The pool-wide shared accelerator cache — the cluster tier's
    /// warm-start donor/recipient handle.
    pub(crate) fn cache(&self) -> &Arc<AcceleratorCache> {
        &self.cache
    }

    /// Each worker's fabric id, in worker order (plan-cache keys: the
    /// cluster ships one cached plan per donor fabric at warm-start).
    pub(crate) fn fabric_ids(&self) -> &[u64] {
        &self.shared.fabric_ids
    }

    /// Jobs currently queued (not in-flight) across every worker.
    pub(crate) fn total_queue_depth(&self) -> usize {
        self.shared.queues.iter().map(|q| q.depth.load(Ordering::Relaxed)).sum()
    }

    /// Graceful quiesce for a pool leaving a cluster: close every queue
    /// and open every gate, so workers drain what is already queued,
    /// reply, and exit on their own. Idempotent; never blocks. The
    /// handles are joined later by [`WorkerPool::shutdown`] (or the
    /// pool's drop).
    pub(crate) fn quiesce(&self) {
        self.release_workers();
    }

    /// Pull every queued (not yet in-flight) job out of the pool — the
    /// evacuation half of a cluster retire/death. Queue bookkeeping
    /// (depth, load, tail mirrors) is restored under each queue's lock,
    /// so workers still serving their in-flight bursts keep consistent
    /// counters for the jobs they already hold.
    pub(crate) fn extract_backlog(&self) -> Vec<Job> {
        let mut out = Vec::new();
        for q in &self.shared.queues {
            let mut g = q.lock();
            let taken: Vec<Job> = g.jobs.drain(..).collect();
            if taken.is_empty() {
                continue;
            }
            q.load.fetch_sub(taken.len(), Ordering::SeqCst);
            q.depth.store(0, Ordering::Relaxed);
            q.sync_tail(&g);
            drop(g);
            q.not_full.notify_all();
            out.extend(taken);
        }
        out
    }

    /// Export the whole tail composition group of the deepest queue
    /// holding at least `min_depth` jobs — the cross-pool rung of the
    /// steal ladder. Mirrors the in-pool steal (whole groups only; a
    /// tail key continuing into the victim's in-flight burst is
    /// refused), except the group leaves the pool entirely: no thief
    /// queue is credited here and no route is repointed — the cluster
    /// ring still owns the key, so the migration is transient load
    /// shedding, not an affinity change. Empty when nothing qualifies.
    pub(crate) fn export_tail_group(&self, min_depth: usize) -> Vec<Job> {
        let mut candidates: Vec<(usize, usize)> = self
            .shared
            .queues
            .iter()
            .enumerate()
            .filter_map(|(i, q)| {
                let d = q.depth.load(Ordering::Relaxed);
                (d >= min_depth.max(1)).then_some((d, i))
            })
            .collect();
        candidates.sort_unstable_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        for (_, v) in candidates {
            let vq = &self.shared.queues[v];
            let mut g = vq.lock();
            let key = match g.jobs.back() {
                Some(job) => job.request.comp.cache_key(),
                None => continue, // drained since scoring
            };
            if vq.inflight_valid.load(Ordering::Acquire)
                && vq.inflight_tail_key.load(Ordering::Relaxed) == key
            {
                continue;
            }
            let mut stolen = Vec::new();
            let mut kept = VecDeque::with_capacity(g.jobs.len());
            while let Some(job) = g.jobs.pop_front() {
                if job.request.comp.cache_key() == key {
                    stolen.push(job);
                } else {
                    kept.push_back(job);
                }
            }
            g.jobs = kept;
            vq.load.fetch_sub(stolen.len(), Ordering::SeqCst);
            vq.depth.store(g.jobs.len(), Ordering::Relaxed);
            vq.sync_tail(&g);
            drop(g);
            vq.not_full.notify_all();
            return stolen;
        }
        Vec::new()
    }

    /// Drain all queues, stop every worker, and return the final report.
    pub fn shutdown(mut self) -> PoolReport {
        // closing ends each worker's loop after it drains everything
        // already queued; opening the gates lets paused pools drain too
        self.release_workers();
        let handles = std::mem::take(&mut self.handles);
        let mut per_worker = Vec::with_capacity(handles.len());
        let mut per_worker_residency = Vec::with_capacity(handles.len());
        let mut panicked_workers = Vec::new();
        for (w, handle) in handles.into_iter().enumerate() {
            let exit = handle.join().unwrap_or_else(|_| {
                panicked_workers.push(w);
                WorkerExit { metrics: Metrics::default(), resident_tiles: 0, total_tiles: 0 }
            });
            per_worker.push(exit.metrics);
            per_worker_residency.push((exit.resident_tiles, exit.total_tiles));
        }
        PoolReport {
            aggregate: self.metrics.snapshot(),
            per_worker,
            per_worker_residency,
            cached_accelerators: self.cache.len(),
            panicked_workers,
        }
    }

    #[cfg(test)]
    fn force_load(&self, worker: usize, load: usize) {
        self.shared.queues[worker].load.store(load, Ordering::SeqCst);
    }
}

impl Drop for WorkerPool {
    /// Dropping the pool without [`WorkerPool::shutdown`] (early `?`
    /// return, caller panic) must not park the worker threads forever at a
    /// gate or an empty-queue wait: close the queues and open the gates so
    /// every worker drains its backlog, delivers the replies, and exits on
    /// its own — the drop itself never blocks. (PR 1 got this for free
    /// from dropping the `mpsc::Sender`s.)
    fn drop(&mut self) {
        self.release_workers();
    }
}

/// Closes and drains the worker's queue on the way out — normal exit *or*
/// a panic in the serving path — so submitters fail over instead of
/// feeding a dead worker, and already-queued clients get a disconnect
/// instead of an eternal `recv`. On the normal path the queue is already
/// closed and drained, so the discard is a no-op.
struct CloseOnExit<'a> {
    shared: &'a PoolShared,
    idx: usize,
}

impl Drop for CloseOnExit<'_> {
    fn drop(&mut self) {
        self.shared.queues[self.idx].close_and_discard();
    }
}

/// One worker's loop: drain bursts from the own queue, reorder each burst
/// with the reconfiguration-aware scheduler, steal whole composition groups
/// when idle, fold one metrics delta per burst (before delivering replies),
/// and report the final fabric occupancy on exit.
///
/// Every burst is served under `catch_unwind`. A panicking serving path is
/// **supervised**: the dead coordinator's metrics delta is billed, a fresh
/// coordinator is rebuilt in place on this same thread, and the burst is
/// replayed when its jobs survived (injected faults fire before the staging
/// slot is taken) or left to the [`ReplySink`] drop fail-safe when they did
/// not — exactly one reply per request either way.
fn worker_loop(
    mut coord: Coordinator,
    idx: usize,
    shared: Arc<PoolShared>,
    agg: Arc<AtomicMetrics>,
    drain_window: usize,
    respawn: RespawnSpec,
) -> WorkerExit {
    shared.gates[idx].wait();
    let queue = &shared.queues[idx];
    let _close_on_exit = CloseOnExit { shared: &shared, idx };
    // with stealing disabled there is nothing to poll for: sleep until
    // a submitter or shutdown notifies
    let polling = shared.steal_min_depth != usize::MAX;
    let mut idle_poll = IDLE_POLL;
    // a burst carried over from a supervised panic, with its pending
    // steal credit: replayed before the queue is polled again, so
    // recovery never reorders past it, and a steal whose burst panicked
    // before `steals` was billed is still counted on the replay
    let mut carry: Option<(Vec<Job>, bool)> = None;
    loop {
        let (burst, stole) = if let Some(replayed) = carry.take() {
            replayed
        } else {
            let popped = match queue.pop_burst(drain_window) {
                None => break, // closed and drained
                Some(popped) => popped,
            };
            if popped.is_empty() {
                match shared.steal_into(idx) {
                    // steal_into already marked this queue's inflight key,
                    // before publishing the route repoint
                    Some(stolen) => (stolen, true),
                    None => {
                        // quiet window: speculative maintenance (defragment,
                        // then prefetch the predicted next accelerator) runs
                        // while the queue is empty, billed per pass so its
                        // counters reach the pool aggregate. It settles to a
                        // no-op — staged prefetch, compacted fabric — and
                        // only then does the worker park as before.
                        if coord.predicting() || coord.compacting() {
                            let before = coord.metrics;
                            let worked = coord.maintain();
                            agg.record(&coord.metrics.delta_since(&before));
                            if worked {
                                continue; // re-check the queue between passes
                            }
                        }
                        queue.wait_nonempty(polling.then_some(idle_poll));
                        if polling {
                            idle_poll = (idle_poll * 2).min(IDLE_POLL_MAX);
                        }
                        continue;
                    }
                }
            } else {
                (popped, false)
            }
        };
        idle_poll = IDLE_POLL;
        let burst_len = burst.len();
        let before = coord.metrics;
        // stage the burst in a slot the panic path can inspect: an injected
        // worker fault fires before the slot is taken (the jobs survive for
        // replay), while a genuine mid-serve panic finds it already empty —
        // the consumed jobs' ReplySinks then fail safe from their drops
        let mut slot = Some(burst);
        let served = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            coord.engine.faults.maybe_worker_panic();
            let burst = slot.take().expect("burst staged for serving");
            if stole {
                coord.metrics.steals += 1;
                // a stolen group is adjacent in time but not in any
                // client's request order: break the predictor's chain so
                // the boundary never becomes a false successor edge
                coord.note_stream_break();
            }
            coord.serve_burst(burst)
        }));
        match served {
            Ok(replies) => {
                agg.record(&coord.metrics.delta_since(&before));
                queue.load.fetch_sub(replies.len(), Ordering::SeqCst);
                queue.clear_inflight();
                for (reply, resp) in replies {
                    // a hung-up client is not a worker error
                    reply.deliver(resp);
                }
            }
            Err(_) => {
                // supervision: bill what the dead coordinator managed to
                // count, then rebuild it in place on this same thread
                agg.record(&coord.metrics.delta_since(&before));
                let replay = slot.take();
                let replayed = replay.as_ref().map_or(0, Vec::len) as u64;
                if replay.is_none() {
                    // the jobs were consumed: their sinks already failed
                    // safe, so this burst is over — release its load
                    queue.load.fetch_sub(burst_len, Ordering::SeqCst);
                    queue.clear_inflight();
                }
                coord.metrics.workers_restarted += 1;
                coord.metrics.jobs_replayed += replayed;
                agg.record(&Metrics {
                    workers_restarted: 1,
                    jobs_replayed: replayed,
                    ..Metrics::default()
                });
                match respawn.rebuild(&shared.cache) {
                    Ok(mut fresh) => {
                        // the record travels with the worker, not the fabric:
                        // worker_sum == aggregate still holds after a restart
                        fresh.metrics = coord.metrics;
                        // ... and so does the learned next-composition
                        // table: a supervised restart must not cold-start
                        // prefetch. The replay boundary is a stream
                        // discontinuity, so the chain breaks on install.
                        fresh.install_predictor(coord.take_predictor());
                        coord = fresh;
                        carry = replay.map(|jobs| (jobs, stole));
                    }
                    // the fabric cannot be rebuilt: exit. CloseOnExit fails
                    // the queue over, and a carried burst's sinks fail safe
                    // when `replay` drops here.
                    Err(_) => break,
                }
            }
        }
    }
    let (resident_tiles, total_tiles) = coord.engine.residency();
    WorkerExit { metrics: coord.metrics, resident_tiles, total_tiles }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitstream::OperatorKind;
    use crate::patterns::Composition;
    use crate::workload;

    fn pool(workers: usize) -> WorkerPool {
        WorkerPool::new(OverlayConfig::default(), ServiceConfig::with_workers(workers)).unwrap()
    }

    fn vmul_req(n: usize, seed: u64) -> Request {
        Request::dynamic(
            Composition::vmul_reduce(n),
            vec![workload::vector(n, seed, 0.1, 1.0), workload::vector(n, seed + 1, 0.1, 1.0)],
        )
    }

    fn map_req(n: usize) -> Request {
        Request::dynamic(Composition::map(OperatorKind::Abs, n), vec![vec![-1.0; n]])
    }

    #[test]
    fn pool_round_trips_and_aggregates() {
        let pool = pool(2);
        let mut pending = Vec::new();
        for k in 0..4 {
            pending.push(pool.submit(vmul_req(256, k)).unwrap());
            pending.push(pool.submit(map_req(256)).unwrap());
        }
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        assert_eq!(pool.snapshot().requests, 8);
        let report = pool.shutdown();
        assert_eq!(report.aggregate.requests, 8);
        assert_eq!(report.per_worker.len(), 2);
        assert_eq!(report.cached_accelerators, 2);
        assert!(report.panicked_workers.is_empty());
        // pool aggregate == sum of worker records
        let sum = report.worker_sum();
        assert_eq!(sum.requests, report.aggregate.requests);
        assert_eq!(sum.jit_compiles, report.aggregate.jit_compiles);
        assert_eq!(sum.cache_hits, report.aggregate.cache_hits);
        assert_eq!(sum.pr_downloads, report.aggregate.pr_downloads);
        assert_eq!(sum.pr_region_hits, report.aggregate.pr_region_hits);
        assert_eq!(sum.bursts, report.aggregate.bursts);
        assert_eq!(sum.burst_group_switches, report.aggregate.burst_group_switches);
        assert_eq!(sum.steals, report.aggregate.steals);
        assert!(report.aggregate.bursts >= 1);
    }

    #[test]
    fn affinity_keeps_a_composition_on_one_worker() {
        let pool = pool(4);
        for k in 0..6 {
            pool.submit_wait(vmul_req(512, k)).unwrap();
        }
        let report = pool.shutdown();
        let serving: Vec<usize> = report
            .per_worker
            .iter()
            .enumerate()
            .filter(|(_, m)| m.requests > 0)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(serving.len(), 1, "one composition must stay on one worker");
        // all repeats after the first hit the shared JIT cache
        assert_eq!(report.aggregate.jit_compiles, 1);
        assert_eq!(report.aggregate.cache_hits, 5);
        // ... and the home fabric kept the operators resident
        assert_eq!(report.aggregate.pr_downloads, 2);
        assert_eq!(report.aggregate.pr_region_hits, 2 * 5);
        // serial submit_wait never builds a queue: every burst is one job
        // and a single-composition stream never switches groups
        assert_eq!(report.aggregate.bursts, 6);
        assert_eq!(report.aggregate.burst_group_switches, 0);
        assert_eq!(report.aggregate.steals, 0);
    }

    #[test]
    fn scheduler_spills_to_least_loaded_when_home_is_deep() {
        let pool = pool(2);
        let key = Composition::vmul_reduce(128).cache_key();
        // neutral loads, no sticky entry: the plan is the ring home
        let home = pool.planned_worker(key);
        let other = 1 - home;
        // same loads: stay home
        assert_eq!(pool.planned_worker(key), home);
        // overload home beyond the skew threshold: spill
        pool.force_load(home, ServiceConfig::default().max_queue_skew + 1);
        pool.force_load(other, 0);
        assert_eq!(pool.planned_worker(key), other);
        pool.force_load(home, 0);
        let report = pool.shutdown();
        assert_eq!(report.aggregate.requests, 0);
    }

    #[test]
    fn home_hash_survives_worker_growth() {
        // the satellite-1 regression: growing an N-worker pool to N+1
        // must re-home only the new worker's ring arcs (~1/N of keys),
        // not remap nearly everything the way `key % n` did. Asserted on
        // the pool's own planned_worker under neutral loads and no
        // sticky routes, over ≥64 distinct keys.
        for n in [2usize, 4] {
            let small = pool(n);
            let big = pool(n + 1);
            let total = 128u64;
            let mut moved = 0usize;
            for k in 0..total {
                // well-spread distinct keys (the ring mixes again anyway)
                let key = k.wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ 0x5eed;
                let (a, b) = (small.planned_worker(key), big.planned_worker(key));
                if a != b {
                    assert_eq!(b, n, "a re-homed key must land on the new worker");
                    moved += 1;
                }
            }
            let frac = moved as f64 / total as f64;
            assert!(
                frac <= 2.0 / (n as f64 + 1.0),
                "{n}→{} workers re-homed {frac:.3} of keys",
                n + 1
            );
            small.shutdown();
            big.shutdown();
        }
    }

    #[test]
    fn sticky_routing_follows_a_spill() {
        let pool = pool(2);
        let req = vmul_req(128, 1);
        let key = req.comp.cache_key();
        let home = pool.planned_worker(key);
        let other = 1 - home;
        pool.force_load(home, ServiceConfig::default().max_queue_skew + 1);
        pool.submit_wait(req).unwrap();
        pool.force_load(home, 0);
        // home is idle again, but the composition now lives on `other`
        assert_eq!(pool.planned_worker(key), other);
        pool.shutdown();
    }

    #[test]
    fn submit_wait_surfaces_request_errors_and_pool_survives() {
        let pool = pool(2);
        // wrong channel count → structured error, worker stays alive
        let bad = Request::dynamic(Composition::vmul_reduce(64), vec![vec![0.0; 64]]);
        assert!(pool.submit_wait(bad).is_err());
        pool.submit_wait(vmul_req(64, 3)).unwrap();
        let report = pool.shutdown();
        assert_eq!(report.aggregate.requests, 1); // failed request not counted
    }

    #[test]
    fn residency_reported_per_fabric() {
        let pool = pool(2);
        pool.submit_wait(vmul_req(128, 1)).unwrap();
        let report = pool.shutdown();
        // exactly one fabric hosts the two vmul stages; the other is empty
        let resident: usize = report.per_worker_residency.iter().map(|(r, _)| r).sum();
        assert_eq!(resident, 2);
        for (_, total) in report.per_worker_residency {
            assert_eq!(total, 9);
        }
    }

    #[test]
    fn try_submit_rejects_when_queue_full() {
        let service = ServiceConfig {
            queue_capacity: 2,
            ..ServiceConfig::with_workers(1).without_stealing()
        };
        let pool = WorkerPool::new_paused(OverlayConfig::default(), service).unwrap();
        let a = pool.try_submit(vmul_req(128, 1)).unwrap();
        let b = pool.try_submit(vmul_req(128, 2)).unwrap();
        match pool.try_submit(vmul_req(128, 3)) {
            Err(Error::PoolBusy { worker: 0, capacity: 2 }) => {}
            other => panic!("expected PoolBusy, got {other:?}"),
        }
        assert_eq!(pool.snapshot().rejected, 1);
        assert_eq!(pool.queue_depth(0), 2);
        // draining frees capacity again
        pool.start();
        a.recv().unwrap().unwrap();
        b.recv().unwrap().unwrap();
        let c = pool.try_submit(vmul_req(128, 4)).unwrap();
        c.recv().unwrap().unwrap();
        let report = pool.shutdown();
        assert_eq!(report.aggregate.requests, 3);
        assert_eq!(report.aggregate.rejected, 1);
        // rejected is pool-level: it appears in no worker record
        assert_eq!(report.worker_sum().rejected, 0);
    }

    #[test]
    fn submit_async_replies_through_the_shared_completion_queue() {
        let service = ServiceConfig::with_workers(2).without_stealing();
        let pool = WorkerPool::new_paused(OverlayConfig::default(), service).unwrap();
        let cq = Arc::new(CompletionQueue::new());
        let mut tickets = Vec::new();
        for k in 0..4 {
            tickets.push(pool.submit_async(vmul_req(256, k), &cq).unwrap());
        }
        assert_eq!(tickets, vec![1, 2, 3, 4], "tickets are monotonic per queue");
        assert!(cq.is_empty(), "paused pool must not have completed anything");
        pool.start();
        // drain until every ticket completed — the single consumer loop
        let mut seen = std::collections::HashSet::new();
        while seen.len() < tickets.len() {
            cq.wait(Duration::from_millis(50));
            for c in cq.drain() {
                assert!(seen.insert(c.ticket), "duplicate completion {}", c.ticket);
                c.result.expect("request served");
            }
        }
        assert!(tickets.iter().all(|t| seen.contains(t)));
        let report = pool.shutdown();
        assert_eq!(report.aggregate.requests, 4);
    }

    #[test]
    fn failed_submit_async_delivers_no_completion() {
        let service = ServiceConfig {
            queue_capacity: 1,
            ..ServiceConfig::with_workers(1).without_stealing()
        };
        let pool = WorkerPool::new_paused(OverlayConfig::default(), service).unwrap();
        let cq = Arc::new(CompletionQueue::new());
        let accepted = pool.submit_async(vmul_req(128, 1), &cq).unwrap();
        match pool.submit_async(vmul_req(128, 2), &cq) {
            Err(Error::PoolBusy { worker: 0, capacity: 1 }) => {}
            other => panic!("expected PoolBusy, got {other:?}"),
        }
        assert_eq!(pool.snapshot().rejected, 1);
        pool.start();
        cq.wait(Duration::from_millis(500));
        let mut done = cq.drain();
        while done.is_empty() {
            cq.wait(Duration::from_millis(50));
            done = cq.drain();
        }
        assert_eq!(done.len(), 1, "the rejected ticket must never complete");
        assert_eq!(done[0].ticket, accepted);
        let report = pool.shutdown();
        assert!(cq.is_empty(), "shutdown must not surface the defused sink");
        assert_eq!(report.aggregate.requests, 1);
    }

    #[test]
    fn dropped_async_job_fails_safe_with_an_error_completion() {
        let cq = Arc::new(CompletionQueue::new());
        let ticket = cq.next_ticket();
        let sink = ReplySink::queue(cq.clone(), ticket);
        drop(sink); // a worker died with the job queued
        let done = cq.drain();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].ticket, ticket);
        assert!(done[0].result.is_err(), "dropped sink must surface an error");
    }

    #[test]
    fn completion_queue_wake_unblocks_wait() {
        let cq = Arc::new(CompletionQueue::new());
        cq.wake();
        // a pending wakeup makes wait return immediately (consumed once)
        cq.wait(Duration::from_secs(5));
        assert!(cq.is_empty());
    }

    #[test]
    fn wait_returns_near_its_timeout_when_nothing_arrives() {
        let cq = CompletionQueue::new();
        let t0 = Instant::now();
        cq.wait(Duration::from_millis(50));
        let elapsed = t0.elapsed();
        // lower bound: the wait genuinely parked (allow coarse clocks)
        assert!(elapsed >= Duration::from_millis(40), "returned early: {elapsed:?}");
        // upper bound: generous slack for CI schedulers, but nowhere near
        // the unbounded park the re-armed timeout allowed
        assert!(elapsed <= Duration::from_secs(5), "overslept: {elapsed:?}");
    }

    #[test]
    fn wait_deadline_bounds_park_under_wakeup_churn() {
        // A churn thread pushes a completion and immediately drains it
        // back, so the waiter's condvar keeps firing while the predicate is
        // frequently already false again — the exact pattern that made the
        // re-armed timeout restart from zero on every wakeup. The absolute
        // deadline must bound the total park regardless.
        let cq = Arc::new(CompletionQueue::new());
        let stop = Arc::new(AtomicBool::new(false));
        let churn = {
            let cq = cq.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    cq.push(Completion {
                        ticket: cq.next_ticket(),
                        result: Err(Error::Runtime("churn".into())),
                    });
                    cq.drain();
                }
            })
        };
        let t0 = Instant::now();
        // several waits back-to-back: each must individually respect its
        // deadline (early returns on an observed completion are fine)
        for _ in 0..20 {
            cq.wait(Duration::from_millis(20));
        }
        let elapsed = t0.elapsed();
        stop.store(true, Ordering::Relaxed);
        churn.join().unwrap();
        assert!(
            elapsed <= Duration::from_secs(20),
            "wait parked {elapsed:?}: deadline not honored under churn"
        );
    }

    #[test]
    fn paused_pool_drains_one_burst_with_grouping() {
        let service = ServiceConfig {
            max_queue_skew: usize::MAX - 1, // affinity only, no spills
            ..ServiceConfig::with_workers(1).without_stealing()
        };
        let pool = WorkerPool::new_paused(OverlayConfig::default(), service).unwrap();
        // interleaved A,B,A,B — one drain window regroups to A,A,B,B
        let mut pending = Vec::new();
        for k in 0..2 {
            pending.push(pool.submit(vmul_req(256, k)).unwrap());
            pending.push(pool.submit(map_req(256)).unwrap());
        }
        assert_eq!(pool.queue_depth(0), 4);
        pool.start();
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let report = pool.shutdown();
        assert_eq!(report.aggregate.requests, 4);
        assert_eq!(report.aggregate.bursts, 1);
        assert_eq!(report.aggregate.burst_group_switches, 1);
    }
}
