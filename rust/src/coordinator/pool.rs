//! Multi-fabric worker pool with affinity scheduling.
//!
//! The paper's run-time system owns **one** overlay fabric; this module
//! scales it out the way a deployment would: N workers, each owning its own
//! [`crate::exec::Engine`] (fabric + PR manager + residency state), fed
//! through per-worker queues by an **affinity scheduler**:
//!
//! * **home routing** — each [`Request`]'s composition hashes to a home
//!   worker (`cache_key % workers`), so repeated compositions land where
//!   their accelerator is already compiled *and* its operators are already
//!   resident in the PR regions — skipping both the JIT and the ICAP
//!   download (the Fig. 3 amortization, multiplied across fabrics);
//! * **sticky spill** — when the home queue runs deeper than the
//!   least-loaded worker by more than `max_queue_skew`, the request spills
//!   to the least-loaded worker and the routing table is updated so future
//!   repeats follow it (residency migrates once, not per request);
//! * **shared JIT cache** — compiled accelerators live in the pool-wide
//!   sharded [`AcceleratorCache`], so a spill never recompiles, it only
//!   re-downloads bitstreams on the new fabric;
//! * **aggregate metrics** — workers fold per-request deltas into one
//!   [`AtomicMetrics`] snapshot, so pool totals are observable while the
//!   pool is live and provably equal to the sum of worker records.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::thread::JoinHandle;

use super::{AcceleratorCache, AtomicMetrics, Coordinator, Job, Metrics, Request, Response};
use crate::config::{OverlayConfig, ServiceConfig};
use crate::error::{Error, Result};

/// What a worker thread leaves behind when the pool shuts down.
struct WorkerExit {
    metrics: Metrics,
    resident_tiles: usize,
    total_tiles: usize,
}

struct WorkerHandle {
    /// `mpsc::Sender` is not `Sync` on older toolchains; the mutex is held
    /// only for the enqueue itself.
    tx: Mutex<mpsc::Sender<Job>>,
    handle: JoinHandle<WorkerExit>,
    /// Queued + in-flight requests on this worker (the scheduler's load
    /// signal). Incremented at dispatch, decremented after serving.
    load: Arc<AtomicUsize>,
}

/// Final pool accounting returned by [`WorkerPool::shutdown`].
#[derive(Debug, Clone)]
pub struct PoolReport {
    /// The atomic aggregate's final snapshot.
    pub aggregate: Metrics,
    /// Each worker's own metrics record, in worker order.
    pub per_worker: Vec<Metrics>,
    /// Each worker's final fabric occupancy `(resident tiles, total tiles)`.
    pub per_worker_residency: Vec<(usize, usize)>,
    /// Compiled accelerators in the shared cache at shutdown.
    pub cached_accelerators: usize,
    /// Workers whose thread panicked (their per-worker record is zeroed, so
    /// [`PoolReport::worker_sum`] undercounts the aggregate when nonempty).
    pub panicked_workers: Vec<usize>,
}

impl PoolReport {
    /// Sum of the per-worker records. Equals [`PoolReport::aggregate`] up
    /// to nanosecond rounding on the seconds fields — provided
    /// [`PoolReport::panicked_workers`] is empty (a panicked worker's
    /// record is lost while its already-folded deltas stay in the
    /// aggregate).
    pub fn worker_sum(&self) -> Metrics {
        let mut sum = Metrics::default();
        for m in &self.per_worker {
            sum.merge(m);
        }
        sum
    }
}

/// A pool of N coordinator workers, each owning its own overlay fabric.
pub struct WorkerPool {
    workers: Vec<WorkerHandle>,
    /// Composition key → worker that last served it (sticky affinity).
    route: RwLock<HashMap<u64, usize>>,
    /// Live pool-level aggregate (see [`AtomicMetrics`]).
    pub metrics: Arc<AtomicMetrics>,
    cache: Arc<AcceleratorCache>,
    max_queue_skew: usize,
}

impl WorkerPool {
    /// Spawn `service.workers` workers, each with a fabric built from `cfg`.
    pub fn new(cfg: OverlayConfig, service: ServiceConfig) -> Result<WorkerPool> {
        service.validate()?;
        let cache = Arc::new(AcceleratorCache::new(service.cache_shards));
        let metrics = Arc::new(AtomicMetrics::default());
        let mut workers = Vec::with_capacity(service.workers);
        for w in 0..service.workers {
            let coord = Coordinator::with_cache(cfg.clone(), cache.clone())?;
            let (tx, rx) = mpsc::channel::<Job>();
            let load = Arc::new(AtomicUsize::new(0));
            let worker_load = load.clone();
            let agg = metrics.clone();
            let handle = std::thread::Builder::new()
                .name(format!("overlay-worker-{w}"))
                .spawn(move || worker_loop(coord, rx, agg, worker_load))?;
            workers.push(WorkerHandle { tx: Mutex::new(tx), handle, load });
        }
        Ok(WorkerPool {
            workers,
            route: RwLock::new(HashMap::new()),
            metrics,
            cache,
            max_queue_skew: service.max_queue_skew,
        })
    }

    /// Number of workers in the pool.
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Compiled accelerators currently in the shared cache.
    pub fn cached_accelerators(&self) -> usize {
        self.cache.len()
    }

    /// Live aggregate metrics snapshot.
    pub fn snapshot(&self) -> Metrics {
        self.metrics.snapshot()
    }

    /// The worker the scheduler would pick for composition key `key` right
    /// now: the sticky/home worker unless its queue is `max_queue_skew`
    /// deeper than the least-loaded one.
    ///
    /// Read-only — the routing table is only updated by [`Self::submit`].
    /// Two racing submitters of a brand-new key may both compute the same
    /// home (deterministic hash), so the race at worst duplicates one JIT
    /// compile, which the shared cache converges.
    pub fn planned_worker(&self, key: u64) -> usize {
        self.route_decision(key).0
    }

    /// One route-table read: returns the chosen worker and whether the
    /// sticky entry must be updated to match it.
    fn route_decision(&self, key: u64) -> (usize, bool) {
        let n = self.workers.len();
        let sticky =
            self.route.read().expect("route table poisoned").get(&key).copied();
        let home = sticky.unwrap_or((key % n as u64) as usize);
        // single allocation-free pass over the load counters
        let mut home_load = 0;
        let mut least = home;
        let mut least_load = usize::MAX;
        for (i, w) in self.workers.iter().enumerate() {
            let l = w.load.load(Ordering::SeqCst);
            if i == home {
                home_load = l;
            }
            if l < least_load {
                least_load = l;
                least = i;
            }
        }
        let chosen = if home_load > least_load + self.max_queue_skew { least } else { home };
        (chosen, sticky != Some(chosen))
    }

    /// Enqueue a request; returns the reply channel immediately.
    ///
    /// Submitting many requests before draining any replies is how callers
    /// express pipelining. Each worker serves its queue in FIFO order, so
    /// per-submitter, per-composition ordering holds while the route is
    /// stable; a spill migrates the composition to another queue, so
    /// requests already queued at the old worker may execute after newer
    /// ones at the new worker. Today's compositions are stateless, so only
    /// reply order per client matters (which submit/recv pairing preserves);
    /// callers needing strict per-key FIFO should disable spilling via a
    /// large [`ServiceConfig::max_queue_skew`].
    pub fn submit(&self, request: Request) -> Result<mpsc::Receiver<Result<Response>>> {
        let key = request.comp.cache_key();
        // the routing table is written only when the decision changed — the
        // steady state (repeat composition, stable route) stays on the read
        // path and never serializes submitters
        let (w, stale) = self.route_decision(key);
        if stale {
            self.route.write().expect("route table poisoned").insert(key, w);
        }
        let (rtx, rrx) = mpsc::channel();
        let mut job = Job { request, reply: rtx };
        match self.try_send(w, job) {
            Ok(()) => return Ok(rrx),
            Err(j) => job = j,
        }
        // worker `w` is dead (its receiver dropped, e.g. a panicked
        // thread). Fail over to the other workers — lowest load first so a
        // dead worker's frozen 0 counter can't keep attracting traffic —
        // and repoint the sticky route at whoever accepted.
        let mut candidates: Vec<usize> = (0..self.workers.len()).filter(|&i| i != w).collect();
        candidates.sort_by_key(|&i| self.workers[i].load.load(Ordering::SeqCst));
        for c in candidates {
            match self.try_send(c, job) {
                Ok(()) => {
                    self.route.write().expect("route table poisoned").insert(key, c);
                    return Ok(rrx);
                }
                Err(j) => job = j,
            }
        }
        Err(Error::Runtime("every pool worker is gone".into()))
    }

    /// Enqueue on worker `w`, keeping the load counter consistent; returns
    /// the job when the worker's receiver is gone.
    fn try_send(&self, w: usize, job: Job) -> std::result::Result<(), Job> {
        let worker = &self.workers[w];
        worker.load.fetch_add(1, Ordering::SeqCst);
        match worker.tx.lock().expect("worker sender poisoned").send(job) {
            Ok(()) => Ok(()),
            Err(mpsc::SendError(job)) => {
                worker.load.fetch_sub(1, Ordering::SeqCst);
                Err(job)
            }
        }
    }

    /// Enqueue a request and block for its response.
    pub fn submit_wait(&self, request: Request) -> Result<Response> {
        self.submit(request)?
            .recv()
            .map_err(|_| Error::Runtime("pool worker dropped the reply".into()))?
    }

    /// Drain all queues, stop every worker, and return the final report.
    pub fn shutdown(self) -> PoolReport {
        let WorkerPool { workers, metrics, cache, .. } = self;
        let mut per_worker = Vec::with_capacity(workers.len());
        let mut per_worker_residency = Vec::with_capacity(workers.len());
        let mut panicked_workers = Vec::new();
        for (w, WorkerHandle { tx, handle, .. }) in workers.into_iter().enumerate() {
            // dropping the sender ends the worker's recv loop after it
            // drains everything already queued
            drop(tx);
            let exit = handle.join().unwrap_or_else(|_| {
                panicked_workers.push(w);
                WorkerExit { metrics: Metrics::default(), resident_tiles: 0, total_tiles: 0 }
            });
            per_worker.push(exit.metrics);
            per_worker_residency.push((exit.resident_tiles, exit.total_tiles));
        }
        PoolReport {
            aggregate: metrics.snapshot(),
            per_worker,
            per_worker_residency,
            cached_accelerators: cache.len(),
            panicked_workers,
        }
    }

    #[cfg(test)]
    fn force_load(&self, worker: usize, load: usize) {
        self.workers[worker].load.store(load, Ordering::SeqCst);
    }
}

/// One worker's request loop: serve jobs FIFO, fold metric deltas into the
/// pool aggregate, and report the final fabric occupancy on exit.
fn worker_loop(
    mut coord: Coordinator,
    rx: mpsc::Receiver<Job>,
    agg: Arc<AtomicMetrics>,
    load: Arc<AtomicUsize>,
) -> WorkerExit {
    while let Ok(job) = rx.recv() {
        let before = coord.metrics;
        let resp = coord.submit(&job.request);
        agg.record(&coord.metrics.delta_since(&before));
        load.fetch_sub(1, Ordering::SeqCst);
        // a hung-up client is not a worker error
        let _ = job.reply.send(resp);
    }
    let (resident_tiles, total_tiles) = coord.engine.residency();
    WorkerExit { metrics: coord.metrics, resident_tiles, total_tiles }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitstream::OperatorKind;
    use crate::patterns::Composition;
    use crate::workload;

    fn pool(workers: usize) -> WorkerPool {
        WorkerPool::new(OverlayConfig::default(), ServiceConfig::with_workers(workers)).unwrap()
    }

    fn vmul_req(n: usize, seed: u64) -> Request {
        Request::dynamic(
            Composition::vmul_reduce(n),
            vec![workload::vector(n, seed, 0.1, 1.0), workload::vector(n, seed + 1, 0.1, 1.0)],
        )
    }

    fn map_req(n: usize) -> Request {
        Request::dynamic(Composition::map(OperatorKind::Abs, n), vec![vec![-1.0; n]])
    }

    #[test]
    fn pool_round_trips_and_aggregates() {
        let pool = pool(2);
        let mut pending = Vec::new();
        for k in 0..4 {
            pending.push(pool.submit(vmul_req(256, k)).unwrap());
            pending.push(pool.submit(map_req(256)).unwrap());
        }
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        assert_eq!(pool.snapshot().requests, 8);
        let report = pool.shutdown();
        assert_eq!(report.aggregate.requests, 8);
        assert_eq!(report.per_worker.len(), 2);
        assert_eq!(report.cached_accelerators, 2);
        assert!(report.panicked_workers.is_empty());
        // pool aggregate == sum of worker records
        let sum = report.worker_sum();
        assert_eq!(sum.requests, report.aggregate.requests);
        assert_eq!(sum.jit_compiles, report.aggregate.jit_compiles);
        assert_eq!(sum.cache_hits, report.aggregate.cache_hits);
        assert_eq!(sum.pr_downloads, report.aggregate.pr_downloads);
        assert_eq!(sum.pr_region_hits, report.aggregate.pr_region_hits);
    }

    #[test]
    fn affinity_keeps_a_composition_on_one_worker() {
        let pool = pool(4);
        for k in 0..6 {
            pool.submit_wait(vmul_req(512, k)).unwrap();
        }
        let report = pool.shutdown();
        let serving: Vec<usize> = report
            .per_worker
            .iter()
            .enumerate()
            .filter(|(_, m)| m.requests > 0)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(serving.len(), 1, "one composition must stay on one worker");
        // all repeats after the first hit the shared JIT cache
        assert_eq!(report.aggregate.jit_compiles, 1);
        assert_eq!(report.aggregate.cache_hits, 5);
        // ... and the home fabric kept the operators resident
        assert_eq!(report.aggregate.pr_downloads, 2);
        assert_eq!(report.aggregate.pr_region_hits, 2 * 5);
    }

    #[test]
    fn scheduler_spills_to_least_loaded_when_home_is_deep() {
        let pool = pool(2);
        let key = Composition::vmul_reduce(128).cache_key();
        let home = (key % 2) as usize;
        let other = 1 - home;
        // same loads: stay home
        assert_eq!(pool.planned_worker(key), home);
        // overload home beyond the skew threshold: spill
        pool.force_load(home, ServiceConfig::default().max_queue_skew + 1);
        pool.force_load(other, 0);
        assert_eq!(pool.planned_worker(key), other);
        pool.force_load(home, 0);
        let report = pool.shutdown();
        assert_eq!(report.aggregate.requests, 0);
    }

    #[test]
    fn sticky_routing_follows_a_spill() {
        let pool = pool(2);
        let req = vmul_req(128, 1);
        let key = req.comp.cache_key();
        let home = (key % 2) as usize;
        let other = 1 - home;
        pool.force_load(home, ServiceConfig::default().max_queue_skew + 1);
        pool.submit_wait(req).unwrap();
        pool.force_load(home, 0);
        // home is idle again, but the composition now lives on `other`
        assert_eq!(pool.planned_worker(key), other);
        pool.shutdown();
    }

    #[test]
    fn submit_wait_surfaces_request_errors_and_pool_survives() {
        let pool = pool(2);
        // wrong channel count → structured error, worker stays alive
        let bad = Request::dynamic(Composition::vmul_reduce(64), vec![vec![0.0; 64]]);
        assert!(pool.submit_wait(bad).is_err());
        pool.submit_wait(vmul_req(64, 3)).unwrap();
        let report = pool.shutdown();
        assert_eq!(report.aggregate.requests, 1); // failed request not counted
    }

    #[test]
    fn residency_reported_per_fabric() {
        let pool = pool(2);
        pool.submit_wait(vmul_req(128, 1)).unwrap();
        let report = pool.shutdown();
        // exactly one fabric hosts the two vmul stages; the other is empty
        let resident: usize = report.per_worker_residency.iter().map(|(r, _)| r).sum();
        assert_eq!(resident, 2);
        for (_, total) in report.per_worker_residency {
            assert_eq!(total, 9);
        }
    }
}
