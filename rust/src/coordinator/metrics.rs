//! Coordinator metrics: the counters a deployment would scrape.
//!
//! [`Metrics`] is the plain per-worker record (owned by one coordinator,
//! no synchronization). [`AtomicMetrics`] is the pool-level aggregate:
//! every worker folds its per-request deltas into one shared atomic
//! snapshot, so `pool.metrics.snapshot()` is always consistent with the sum
//! of the per-worker records without stopping the world.

use std::sync::atomic::{AtomicU64, Ordering};

/// Cumulative service counters.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Metrics {
    /// Requests served.
    pub requests: u64,
    /// JIT compilations performed (accelerator-cache misses: front end +
    /// placement).
    pub jit_compiles: u64,
    /// Full accelerator-cache hits: shared program *and* a live plan for
    /// this fabric. Per key, `cache_hits + placement_respecializations +
    /// jit_compiles == requests` (absent request errors).
    pub cache_hits: u64,
    /// Placement-only recompiles: the program was cached but this fabric
    /// had no (or a stale) specialized placement plan.
    pub placement_respecializations: u64,
    /// Respecializations that replaced a plan which would have overwritten
    /// this fabric's residents even though free tiles could host it — the
    /// clobbers the pre-specialization cache silently committed.
    pub residency_clobbers_avoided: u64,
    /// Wall-clock seconds spent in the JIT.
    pub jit_seconds: f64,
    /// PR bitstream downloads issued.
    pub pr_downloads: u64,
    /// PR downloads skipped because the operator was already resident.
    pub pr_region_hits: u64,
    /// PR downloads that overwrote a different resident operator (thrash).
    pub pr_replaced: u64,
    /// Modeled seconds spent reconfiguring.
    pub pr_seconds: f64,
    /// Modeled fabric-busy seconds across all requests.
    pub busy_seconds: f64,
    /// Whole-fabric evictions forced by placement capacity misses.
    pub evictions: u64,
    /// Drain bursts served by pool workers (a burst is one queue window
    /// reordered by the reconfiguration-aware scheduler and served with a
    /// single metrics fold).
    pub bursts: u64,
    /// Composition-group switches *within* served bursts: the number of
    /// adjacent same-burst job pairs whose composition keys differ after
    /// reordering. FIFO draining of an interleaved stream maximizes this;
    /// burst draining collapses it to (groups − 1) per window.
    pub burst_group_switches: u64,
    /// Work-stealing events: an idle worker took a whole composition group
    /// from the deepest queue (counted on the thief).
    pub steals: u64,
    /// Submissions rejected with [`crate::error::Error::PoolBusy`]
    /// (bounded-queue backpressure). Pool-level: recorded in the aggregate
    /// only, never in a worker's own record.
    pub rejected: u64,
    /// Entries evicted from the LRU-capped accelerator cache.
    pub lru_evictions: u64,
    /// Client sessions opened on the reactor front end.
    pub sessions: u64,
    /// Completions drained from the shared completion queue by reactors
    /// (equals async requests finished; the blocking channel path does not
    /// count here).
    pub completions: u64,
    /// Reactor poll iterations (one drain + deliver + admit pass each).
    pub reactor_polls: u64,
    /// Admission attempts deferred by the front end: a session at its
    /// in-flight cap, the front-end-wide in-flight cap reached, or the pool
    /// answering `PoolBusy`. A deferred request stays queued in its session
    /// and is retried — this counts pressure events, not lost requests.
    pub admission_rejections: u64,
    /// Socket connections accepted by the serving tier.
    pub connections: u64,
    /// Connections shed by the serving tier: idle/read timeouts, framing
    /// violations (oversized or malformed frames), or a mid-frame
    /// disconnect. Clean closes do not count.
    pub conns_shed: u64,
    /// Wire requests answered `BUSY` without being served: the
    /// per-connection pending cap, or a request rejected at the network
    /// boundary (bad pattern / oversized `n`) — the connection-level face
    /// of the pool's backpressure.
    pub net_rejections: u64,
    /// Adjacent stage pairs collapsed by the JIT fusion pass (counted per
    /// full compile; a fused cache hit re-counts nothing).
    pub stages_fused: u64,
    /// PR downloads the fusion pass removed from requests that actually
    /// reconfigured the fabric: one per fused pair on every submit whose
    /// run issued at least one download (upper bound — some avoided tiles
    /// might have been residency hits unfused).
    pub downloads_avoided: u64,
    /// Fused placements that failed for capacity and fell back to the
    /// unfused pipeline shape (the first rung of the fallback ladder).
    pub fusion_fallbacks: u64,
    /// Requests no pipeline shape could place even on an empty fabric,
    /// served by CPU interpretation instead of an error (the ladder's
    /// bottom rung; excluded from the hits+respecs+compiles==requests
    /// conservation law).
    pub cpu_fallbacks: u64,
    /// PR download attempts re-armed after a transient fault: ICAP
    /// transfers that aborted and were retried within the
    /// [`crate::config::ServiceConfig::download_retries`] budget, plus one
    /// per transient tile-fault re-submit (the wrong-bits clear +
    /// re-download rung).
    pub download_retries: u64,
    /// Tiles permanently quarantined after a region fault (capacity lost
    /// for the fabric's lifetime; the placer routes around them).
    pub tiles_quarantined: u64,
    /// Worker threads respawned by pool supervision after a panic.
    pub workers_restarted: u64,
    /// Jobs whose burst was replayed after an injected worker panic
    /// (supervision caught the crash before the burst was consumed, so
    /// every job still got exactly one reply).
    pub jobs_replayed: u64,
    /// Requests whose composition the predictor had already prefetched:
    /// the PR download happened in an idle window, off the critical path.
    pub prefetch_hits: u64,
    /// Prefetched plans the next request did not use (mispredictions; the
    /// speculative download's tiles are reclaimed like any idle resident).
    pub prefetch_wasted: u64,
    /// Residents relocated by the background compactor (each migration is
    /// one PR download into the destination tile plus a source clear).
    pub migrations: u64,
    /// Pools that joined a cluster's consistent-hash ring (initial
    /// members included).
    pub pool_joins: u64,
    /// Cluster evacuation events: one per retired/dead pool whose queued
    /// backlog was re-routed through the shrunken ring.
    pub pool_evacuations: u64,
    /// Queued jobs migrated between pools by the cluster's last-resort
    /// steal tier (above in-pool stealing, below the CPU floor).
    pub cross_pool_steals: u64,
    /// First claims of warm-started keys: a request routed to a joined
    /// pool whose program was shipped at join, paying a placement-only
    /// respecialization instead of a JIT recompile.
    pub warm_start_hits: u64,
}

impl Metrics {
    /// Full accelerator-cache hit rate in [0, 1]: the share of requests
    /// that paid *no* JIT work at all. The denominator covers every
    /// resolution outcome (hits + placement respecializations + full
    /// compiles — the conservation law), so a spill-heavy stream whose
    /// respecializations pay real placement time is not counted as cached.
    pub fn hit_rate(&self) -> f64 {
        let total = self.jit_compiles + self.placement_respecializations + self.cache_hits;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// PR-region residency hit rate in [0, 1]: how often a placed stage
    /// found its operator already downloaded (Fig. 3 amortization working).
    pub fn pr_hit_rate(&self) -> f64 {
        let total = self.pr_downloads + self.pr_region_hits;
        if total == 0 {
            0.0
        } else {
            self.pr_region_hits as f64 / total as f64
        }
    }

    /// Field-wise accumulate (used to sum per-worker records).
    pub fn merge(&mut self, other: &Metrics) {
        self.requests += other.requests;
        self.jit_compiles += other.jit_compiles;
        self.cache_hits += other.cache_hits;
        self.placement_respecializations += other.placement_respecializations;
        self.residency_clobbers_avoided += other.residency_clobbers_avoided;
        self.jit_seconds += other.jit_seconds;
        self.pr_downloads += other.pr_downloads;
        self.pr_region_hits += other.pr_region_hits;
        self.pr_replaced += other.pr_replaced;
        self.pr_seconds += other.pr_seconds;
        self.busy_seconds += other.busy_seconds;
        self.evictions += other.evictions;
        self.bursts += other.bursts;
        self.burst_group_switches += other.burst_group_switches;
        self.steals += other.steals;
        self.rejected += other.rejected;
        self.lru_evictions += other.lru_evictions;
        self.sessions += other.sessions;
        self.completions += other.completions;
        self.reactor_polls += other.reactor_polls;
        self.admission_rejections += other.admission_rejections;
        self.connections += other.connections;
        self.conns_shed += other.conns_shed;
        self.net_rejections += other.net_rejections;
        self.stages_fused += other.stages_fused;
        self.downloads_avoided += other.downloads_avoided;
        self.fusion_fallbacks += other.fusion_fallbacks;
        self.cpu_fallbacks += other.cpu_fallbacks;
        self.download_retries += other.download_retries;
        self.tiles_quarantined += other.tiles_quarantined;
        self.workers_restarted += other.workers_restarted;
        self.jobs_replayed += other.jobs_replayed;
        self.prefetch_hits += other.prefetch_hits;
        self.prefetch_wasted += other.prefetch_wasted;
        self.migrations += other.migrations;
        self.pool_joins += other.pool_joins;
        self.pool_evacuations += other.pool_evacuations;
        self.cross_pool_steals += other.cross_pool_steals;
        self.warm_start_hits += other.warm_start_hits;
    }

    /// Field-wise difference vs an earlier snapshot of the same record
    /// (counters are monotonic, so this is the per-request delta).
    ///
    /// Saturating: after a supervised worker restart, the respawned
    /// coordinator carries the crashed worker's merged counters forward, so
    /// an `earlier` snapshot taken against the *fresh* record can exceed a
    /// later one taken before the carry landed. A raw `-` here
    /// underflow-panics in debug builds; an out-of-order pair now yields
    /// zero for the affected fields instead.
    pub fn delta_since(&self, earlier: &Metrics) -> Metrics {
        Metrics {
            requests: self.requests.saturating_sub(earlier.requests),
            jit_compiles: self.jit_compiles.saturating_sub(earlier.jit_compiles),
            cache_hits: self.cache_hits.saturating_sub(earlier.cache_hits),
            placement_respecializations: self
                .placement_respecializations
                .saturating_sub(earlier.placement_respecializations),
            residency_clobbers_avoided: self
                .residency_clobbers_avoided
                .saturating_sub(earlier.residency_clobbers_avoided),
            jit_seconds: (self.jit_seconds - earlier.jit_seconds).max(0.0),
            pr_downloads: self.pr_downloads.saturating_sub(earlier.pr_downloads),
            pr_region_hits: self.pr_region_hits.saturating_sub(earlier.pr_region_hits),
            pr_replaced: self.pr_replaced.saturating_sub(earlier.pr_replaced),
            pr_seconds: (self.pr_seconds - earlier.pr_seconds).max(0.0),
            busy_seconds: (self.busy_seconds - earlier.busy_seconds).max(0.0),
            evictions: self.evictions.saturating_sub(earlier.evictions),
            bursts: self.bursts.saturating_sub(earlier.bursts),
            burst_group_switches: self
                .burst_group_switches
                .saturating_sub(earlier.burst_group_switches),
            steals: self.steals.saturating_sub(earlier.steals),
            rejected: self.rejected.saturating_sub(earlier.rejected),
            lru_evictions: self.lru_evictions.saturating_sub(earlier.lru_evictions),
            sessions: self.sessions.saturating_sub(earlier.sessions),
            completions: self.completions.saturating_sub(earlier.completions),
            reactor_polls: self.reactor_polls.saturating_sub(earlier.reactor_polls),
            admission_rejections: self
                .admission_rejections
                .saturating_sub(earlier.admission_rejections),
            connections: self.connections.saturating_sub(earlier.connections),
            conns_shed: self.conns_shed.saturating_sub(earlier.conns_shed),
            net_rejections: self.net_rejections.saturating_sub(earlier.net_rejections),
            stages_fused: self.stages_fused.saturating_sub(earlier.stages_fused),
            downloads_avoided: self.downloads_avoided.saturating_sub(earlier.downloads_avoided),
            fusion_fallbacks: self.fusion_fallbacks.saturating_sub(earlier.fusion_fallbacks),
            cpu_fallbacks: self.cpu_fallbacks.saturating_sub(earlier.cpu_fallbacks),
            download_retries: self.download_retries.saturating_sub(earlier.download_retries),
            tiles_quarantined: self.tiles_quarantined.saturating_sub(earlier.tiles_quarantined),
            workers_restarted: self.workers_restarted.saturating_sub(earlier.workers_restarted),
            jobs_replayed: self.jobs_replayed.saturating_sub(earlier.jobs_replayed),
            prefetch_hits: self.prefetch_hits.saturating_sub(earlier.prefetch_hits),
            prefetch_wasted: self.prefetch_wasted.saturating_sub(earlier.prefetch_wasted),
            migrations: self.migrations.saturating_sub(earlier.migrations),
            pool_joins: self.pool_joins.saturating_sub(earlier.pool_joins),
            pool_evacuations: self.pool_evacuations.saturating_sub(earlier.pool_evacuations),
            cross_pool_steals: self.cross_pool_steals.saturating_sub(earlier.cross_pool_steals),
            warm_start_hits: self.warm_start_hits.saturating_sub(earlier.warm_start_hits),
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "requests={} jit={} hits={} ({:.0}%) respec={} clob_avoid={} pr_downloads={} pr_hits={} ({:.0}%) replaced={} pr={:.3}ms busy={:.3}ms bursts={} switches={} steals={} rejected={} lru_evict={} sessions={} completions={} polls={} adm_rej={} conns={} shed={} net_rej={} fused={} dl_avoided={} fuse_fb={} cpu_fb={} dl_retry={} quar={} w_restart={} replay={} pf_hit={} pf_waste={} migr={} pjoin={} pevac={} xsteal={} warm={}",
            self.requests,
            self.jit_compiles,
            self.cache_hits,
            self.hit_rate() * 100.0,
            self.placement_respecializations,
            self.residency_clobbers_avoided,
            self.pr_downloads,
            self.pr_region_hits,
            self.pr_hit_rate() * 100.0,
            self.pr_replaced,
            self.pr_seconds * 1e3,
            self.busy_seconds * 1e3,
            self.bursts,
            self.burst_group_switches,
            self.steals,
            self.rejected,
            self.lru_evictions,
            self.sessions,
            self.completions,
            self.reactor_polls,
            self.admission_rejections,
            self.connections,
            self.conns_shed,
            self.net_rejections,
            self.stages_fused,
            self.downloads_avoided,
            self.fusion_fallbacks,
            self.cpu_fallbacks,
            self.download_retries,
            self.tiles_quarantined,
            self.workers_restarted,
            self.jobs_replayed,
            self.prefetch_hits,
            self.prefetch_wasted,
            self.migrations,
            self.pool_joins,
            self.pool_evacuations,
            self.cross_pool_steals,
            self.warm_start_hits,
        )
    }
}

/// Pool-level metrics aggregate: lock-free folding of per-worker deltas.
///
/// Second-denominated fields are stored as integer nanoseconds so they can
/// live in `AtomicU64`s; the rounding error (< 1 ns per fold) is far below
/// the model's fidelity.
#[derive(Debug, Default)]
pub struct AtomicMetrics {
    requests: AtomicU64,
    jit_compiles: AtomicU64,
    cache_hits: AtomicU64,
    placement_respecializations: AtomicU64,
    residency_clobbers_avoided: AtomicU64,
    pr_downloads: AtomicU64,
    pr_region_hits: AtomicU64,
    pr_replaced: AtomicU64,
    evictions: AtomicU64,
    bursts: AtomicU64,
    burst_group_switches: AtomicU64,
    steals: AtomicU64,
    rejected: AtomicU64,
    lru_evictions: AtomicU64,
    sessions: AtomicU64,
    completions: AtomicU64,
    reactor_polls: AtomicU64,
    admission_rejections: AtomicU64,
    connections: AtomicU64,
    conns_shed: AtomicU64,
    net_rejections: AtomicU64,
    stages_fused: AtomicU64,
    downloads_avoided: AtomicU64,
    fusion_fallbacks: AtomicU64,
    cpu_fallbacks: AtomicU64,
    download_retries: AtomicU64,
    tiles_quarantined: AtomicU64,
    workers_restarted: AtomicU64,
    jobs_replayed: AtomicU64,
    prefetch_hits: AtomicU64,
    prefetch_wasted: AtomicU64,
    migrations: AtomicU64,
    pool_joins: AtomicU64,
    pool_evacuations: AtomicU64,
    cross_pool_steals: AtomicU64,
    warm_start_hits: AtomicU64,
    jit_nanos: AtomicU64,
    pr_nanos: AtomicU64,
    busy_nanos: AtomicU64,
}

fn to_nanos(seconds: f64) -> u64 {
    (seconds * 1e9).round() as u64
}

impl AtomicMetrics {
    /// Fold one worker's per-request delta into the aggregate.
    pub fn record(&self, d: &Metrics) {
        self.requests.fetch_add(d.requests, Ordering::Relaxed);
        self.jit_compiles.fetch_add(d.jit_compiles, Ordering::Relaxed);
        self.cache_hits.fetch_add(d.cache_hits, Ordering::Relaxed);
        self.placement_respecializations
            .fetch_add(d.placement_respecializations, Ordering::Relaxed);
        self.residency_clobbers_avoided
            .fetch_add(d.residency_clobbers_avoided, Ordering::Relaxed);
        self.pr_downloads.fetch_add(d.pr_downloads, Ordering::Relaxed);
        self.pr_region_hits.fetch_add(d.pr_region_hits, Ordering::Relaxed);
        self.pr_replaced.fetch_add(d.pr_replaced, Ordering::Relaxed);
        self.evictions.fetch_add(d.evictions, Ordering::Relaxed);
        self.bursts.fetch_add(d.bursts, Ordering::Relaxed);
        self.burst_group_switches.fetch_add(d.burst_group_switches, Ordering::Relaxed);
        self.steals.fetch_add(d.steals, Ordering::Relaxed);
        self.rejected.fetch_add(d.rejected, Ordering::Relaxed);
        self.lru_evictions.fetch_add(d.lru_evictions, Ordering::Relaxed);
        self.sessions.fetch_add(d.sessions, Ordering::Relaxed);
        self.completions.fetch_add(d.completions, Ordering::Relaxed);
        self.reactor_polls.fetch_add(d.reactor_polls, Ordering::Relaxed);
        self.admission_rejections.fetch_add(d.admission_rejections, Ordering::Relaxed);
        self.connections.fetch_add(d.connections, Ordering::Relaxed);
        self.conns_shed.fetch_add(d.conns_shed, Ordering::Relaxed);
        self.net_rejections.fetch_add(d.net_rejections, Ordering::Relaxed);
        self.stages_fused.fetch_add(d.stages_fused, Ordering::Relaxed);
        self.downloads_avoided.fetch_add(d.downloads_avoided, Ordering::Relaxed);
        self.fusion_fallbacks.fetch_add(d.fusion_fallbacks, Ordering::Relaxed);
        self.cpu_fallbacks.fetch_add(d.cpu_fallbacks, Ordering::Relaxed);
        self.download_retries.fetch_add(d.download_retries, Ordering::Relaxed);
        self.tiles_quarantined.fetch_add(d.tiles_quarantined, Ordering::Relaxed);
        self.workers_restarted.fetch_add(d.workers_restarted, Ordering::Relaxed);
        self.jobs_replayed.fetch_add(d.jobs_replayed, Ordering::Relaxed);
        self.prefetch_hits.fetch_add(d.prefetch_hits, Ordering::Relaxed);
        self.prefetch_wasted.fetch_add(d.prefetch_wasted, Ordering::Relaxed);
        self.migrations.fetch_add(d.migrations, Ordering::Relaxed);
        self.pool_joins.fetch_add(d.pool_joins, Ordering::Relaxed);
        self.pool_evacuations.fetch_add(d.pool_evacuations, Ordering::Relaxed);
        self.cross_pool_steals.fetch_add(d.cross_pool_steals, Ordering::Relaxed);
        self.warm_start_hits.fetch_add(d.warm_start_hits, Ordering::Relaxed);
        self.jit_nanos.fetch_add(to_nanos(d.jit_seconds), Ordering::Relaxed);
        self.pr_nanos.fetch_add(to_nanos(d.pr_seconds), Ordering::Relaxed);
        self.busy_nanos.fetch_add(to_nanos(d.busy_seconds), Ordering::Relaxed);
    }

    /// Current aggregate as a plain record.
    pub fn snapshot(&self) -> Metrics {
        Metrics {
            requests: self.requests.load(Ordering::Relaxed),
            jit_compiles: self.jit_compiles.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            placement_respecializations: self
                .placement_respecializations
                .load(Ordering::Relaxed),
            residency_clobbers_avoided: self.residency_clobbers_avoided.load(Ordering::Relaxed),
            jit_seconds: self.jit_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            pr_downloads: self.pr_downloads.load(Ordering::Relaxed),
            pr_region_hits: self.pr_region_hits.load(Ordering::Relaxed),
            pr_replaced: self.pr_replaced.load(Ordering::Relaxed),
            pr_seconds: self.pr_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            busy_seconds: self.busy_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            evictions: self.evictions.load(Ordering::Relaxed),
            bursts: self.bursts.load(Ordering::Relaxed),
            burst_group_switches: self.burst_group_switches.load(Ordering::Relaxed),
            steals: self.steals.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            lru_evictions: self.lru_evictions.load(Ordering::Relaxed),
            sessions: self.sessions.load(Ordering::Relaxed),
            completions: self.completions.load(Ordering::Relaxed),
            reactor_polls: self.reactor_polls.load(Ordering::Relaxed),
            admission_rejections: self.admission_rejections.load(Ordering::Relaxed),
            connections: self.connections.load(Ordering::Relaxed),
            conns_shed: self.conns_shed.load(Ordering::Relaxed),
            net_rejections: self.net_rejections.load(Ordering::Relaxed),
            stages_fused: self.stages_fused.load(Ordering::Relaxed),
            downloads_avoided: self.downloads_avoided.load(Ordering::Relaxed),
            fusion_fallbacks: self.fusion_fallbacks.load(Ordering::Relaxed),
            cpu_fallbacks: self.cpu_fallbacks.load(Ordering::Relaxed),
            download_retries: self.download_retries.load(Ordering::Relaxed),
            tiles_quarantined: self.tiles_quarantined.load(Ordering::Relaxed),
            workers_restarted: self.workers_restarted.load(Ordering::Relaxed),
            jobs_replayed: self.jobs_replayed.load(Ordering::Relaxed),
            prefetch_hits: self.prefetch_hits.load(Ordering::Relaxed),
            prefetch_wasted: self.prefetch_wasted.load(Ordering::Relaxed),
            migrations: self.migrations.load(Ordering::Relaxed),
            pool_joins: self.pool_joins.load(Ordering::Relaxed),
            pool_evacuations: self.pool_evacuations.load(Ordering::Relaxed),
            cross_pool_steals: self.cross_pool_steals.load(Ordering::Relaxed),
            warm_start_hits: self.warm_start_hits.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_rate_handles_zero() {
        assert_eq!(Metrics::default().hit_rate(), 0.0);
        assert_eq!(Metrics::default().pr_hit_rate(), 0.0);
    }

    #[test]
    fn hit_rate_computes() {
        let m = Metrics { jit_compiles: 1, cache_hits: 3, ..Default::default() };
        assert!((m.hit_rate() - 0.75).abs() < 1e-12);
        // respecializations pay placement time: they dilute the hit rate
        let m = Metrics {
            jit_compiles: 1,
            placement_respecializations: 4,
            cache_hits: 3,
            ..Default::default()
        };
        assert!((m.hit_rate() - 0.375).abs() < 1e-12);
        let m = Metrics { pr_downloads: 1, pr_region_hits: 4, ..Default::default() };
        assert!((m.pr_hit_rate() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn summary_contains_key_fields() {
        let m = Metrics {
            requests: 5,
            download_retries: 2,
            tiles_quarantined: 1,
            workers_restarted: 3,
            jobs_replayed: 4,
            prefetch_hits: 6,
            prefetch_wasted: 2,
            migrations: 7,
            pool_joins: 1,
            pool_evacuations: 2,
            cross_pool_steals: 3,
            warm_start_hits: 8,
            ..Default::default()
        };
        let s = m.summary();
        assert!(s.contains("requests=5"));
        assert!(s.contains("dl_retry=2"));
        assert!(s.contains("quar=1"));
        assert!(s.contains("w_restart=3"));
        assert!(s.contains("replay=4"));
        assert!(s.contains("pf_hit=6"));
        assert!(s.contains("pf_waste=2"));
        assert!(s.contains("migr=7"));
        assert!(s.contains("pjoin=1"));
        assert!(s.contains("pevac=2"));
        assert!(s.contains("xsteal=3"));
        assert!(s.contains("warm=8"));
    }

    #[test]
    fn merge_and_delta_are_inverse() {
        let a = Metrics {
            requests: 3,
            jit_compiles: 1,
            cache_hits: 2,
            placement_respecializations: 2,
            residency_clobbers_avoided: 1,
            jit_seconds: 0.5,
            pr_downloads: 4,
            pr_region_hits: 6,
            pr_replaced: 2,
            pr_seconds: 0.25,
            busy_seconds: 1.5,
            evictions: 1,
            bursts: 2,
            burst_group_switches: 3,
            steals: 1,
            rejected: 4,
            lru_evictions: 2,
            sessions: 3,
            completions: 5,
            reactor_polls: 9,
            admission_rejections: 2,
            connections: 7,
            conns_shed: 2,
            net_rejections: 3,
            stages_fused: 4,
            downloads_avoided: 3,
            fusion_fallbacks: 2,
            cpu_fallbacks: 1,
            download_retries: 5,
            tiles_quarantined: 1,
            workers_restarted: 2,
            jobs_replayed: 6,
            prefetch_hits: 3,
            prefetch_wasted: 2,
            migrations: 1,
            pool_joins: 2,
            pool_evacuations: 3,
            cross_pool_steals: 4,
            warm_start_hits: 5,
        };
        let mut b = a;
        b.merge(&a);
        let d = b.delta_since(&a);
        assert_eq!(d.requests, a.requests);
        assert_eq!(d.placement_respecializations, a.placement_respecializations);
        assert_eq!(d.residency_clobbers_avoided, a.residency_clobbers_avoided);
        assert_eq!(d.pr_region_hits, a.pr_region_hits);
        assert_eq!(d.bursts, a.bursts);
        assert_eq!(d.burst_group_switches, a.burst_group_switches);
        assert_eq!(d.steals, a.steals);
        assert_eq!(d.rejected, a.rejected);
        assert_eq!(d.lru_evictions, a.lru_evictions);
        assert_eq!(d.sessions, a.sessions);
        assert_eq!(d.completions, a.completions);
        assert_eq!(d.reactor_polls, a.reactor_polls);
        assert_eq!(d.admission_rejections, a.admission_rejections);
        assert_eq!(d.connections, a.connections);
        assert_eq!(d.conns_shed, a.conns_shed);
        assert_eq!(d.net_rejections, a.net_rejections);
        assert_eq!(d.stages_fused, a.stages_fused);
        assert_eq!(d.downloads_avoided, a.downloads_avoided);
        assert_eq!(d.fusion_fallbacks, a.fusion_fallbacks);
        assert_eq!(d.cpu_fallbacks, a.cpu_fallbacks);
        assert_eq!(d.download_retries, a.download_retries);
        assert_eq!(d.tiles_quarantined, a.tiles_quarantined);
        assert_eq!(d.workers_restarted, a.workers_restarted);
        assert_eq!(d.jobs_replayed, a.jobs_replayed);
        assert_eq!(d.prefetch_hits, a.prefetch_hits);
        assert_eq!(d.prefetch_wasted, a.prefetch_wasted);
        assert_eq!(d.migrations, a.migrations);
        assert_eq!(d.pool_joins, a.pool_joins);
        assert_eq!(d.pool_evacuations, a.pool_evacuations);
        assert_eq!(d.cross_pool_steals, a.cross_pool_steals);
        assert_eq!(d.warm_start_hits, a.warm_start_hits);
        assert!((d.jit_seconds - a.jit_seconds).abs() < 1e-12);
    }

    /// Regression: a supervised restart can hand `delta_since` an
    /// out-of-order snapshot pair (the respawned coordinator carries the
    /// crashed worker's merged totals, so `earlier` may exceed `self`).
    /// The raw subtraction this replaces underflow-panicked in debug
    /// builds; saturation must yield zeros instead.
    #[test]
    fn delta_since_saturates_on_out_of_order_snapshots() {
        let before_carry = Metrics { requests: 2, pr_downloads: 1, ..Default::default() };
        let after_carry = Metrics {
            requests: 10,
            pr_downloads: 7,
            jit_seconds: 0.5,
            pr_seconds: 0.25,
            busy_seconds: 1.0,
            workers_restarted: 1,
            ..Default::default()
        };
        let d = before_carry.delta_since(&after_carry);
        assert_eq!(d.requests, 0);
        assert_eq!(d.pr_downloads, 0);
        assert_eq!(d.workers_restarted, 0);
        assert_eq!(d.jit_seconds, 0.0);
        assert_eq!(d.pr_seconds, 0.0);
        assert_eq!(d.busy_seconds, 0.0);
        // the in-order direction is unchanged
        let fwd = after_carry.delta_since(&before_carry);
        assert_eq!(fwd.requests, 8);
        assert_eq!(fwd.pr_downloads, 6);
    }

    #[test]
    fn atomic_record_snapshot_roundtrip() {
        let agg = AtomicMetrics::default();
        let d = Metrics {
            requests: 2,
            jit_compiles: 1,
            cache_hits: 1,
            placement_respecializations: 1,
            residency_clobbers_avoided: 1,
            jit_seconds: 0.001,
            pr_downloads: 3,
            pr_region_hits: 5,
            pr_replaced: 1,
            pr_seconds: 0.002,
            busy_seconds: 0.003,
            evictions: 0,
            bursts: 1,
            burst_group_switches: 2,
            steals: 1,
            rejected: 3,
            lru_evictions: 1,
            sessions: 1,
            completions: 2,
            reactor_polls: 4,
            admission_rejections: 1,
            connections: 5,
            conns_shed: 1,
            net_rejections: 2,
            stages_fused: 2,
            downloads_avoided: 2,
            fusion_fallbacks: 1,
            cpu_fallbacks: 1,
            download_retries: 3,
            tiles_quarantined: 1,
            workers_restarted: 1,
            jobs_replayed: 4,
            prefetch_hits: 2,
            prefetch_wasted: 1,
            migrations: 3,
            pool_joins: 1,
            pool_evacuations: 2,
            cross_pool_steals: 3,
            warm_start_hits: 4,
        };
        agg.record(&d);
        agg.record(&d);
        let s = agg.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.placement_respecializations, 2);
        assert_eq!(s.residency_clobbers_avoided, 2);
        assert_eq!(s.pr_downloads, 6);
        assert_eq!(s.pr_region_hits, 10);
        assert_eq!(s.pr_replaced, 2);
        assert_eq!(s.bursts, 2);
        assert_eq!(s.burst_group_switches, 4);
        assert_eq!(s.steals, 2);
        assert_eq!(s.rejected, 6);
        assert_eq!(s.lru_evictions, 2);
        assert_eq!(s.sessions, 2);
        assert_eq!(s.completions, 4);
        assert_eq!(s.reactor_polls, 8);
        assert_eq!(s.admission_rejections, 2);
        assert_eq!(s.connections, 10);
        assert_eq!(s.conns_shed, 2);
        assert_eq!(s.net_rejections, 4);
        assert_eq!(s.stages_fused, 4);
        assert_eq!(s.downloads_avoided, 4);
        assert_eq!(s.fusion_fallbacks, 2);
        assert_eq!(s.cpu_fallbacks, 2);
        assert_eq!(s.download_retries, 6);
        assert_eq!(s.tiles_quarantined, 2);
        assert_eq!(s.workers_restarted, 2);
        assert_eq!(s.jobs_replayed, 8);
        assert_eq!(s.prefetch_hits, 4);
        assert_eq!(s.prefetch_wasted, 2);
        assert_eq!(s.migrations, 6);
        assert_eq!(s.pool_joins, 2);
        assert_eq!(s.pool_evacuations, 4);
        assert_eq!(s.cross_pool_steals, 6);
        assert_eq!(s.warm_start_hits, 8);
        assert!((s.jit_seconds - 0.002).abs() < 1e-9);
        assert!((s.busy_seconds - 0.006).abs() < 1e-9);
    }

    #[test]
    fn atomic_metrics_is_shareable() {
        // compile-time: Sync + Send (threads fold deltas concurrently)
        fn assert_sync<T: Sync + Send>() {}
        assert_sync::<AtomicMetrics>();
    }
}
