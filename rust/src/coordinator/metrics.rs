//! Coordinator metrics: the counters a deployment would scrape.


/// Cumulative service counters.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Metrics {
    /// Requests served.
    pub requests: u64,
    /// JIT compilations performed (accelerator-cache misses).
    pub jit_compiles: u64,
    /// Accelerator-cache hits.
    pub cache_hits: u64,
    /// Wall-clock seconds spent in the JIT.
    pub jit_seconds: f64,
    /// PR bitstream downloads issued.
    pub pr_downloads: u64,
    /// Modeled seconds spent reconfiguring.
    pub pr_seconds: f64,
    /// Modeled fabric-busy seconds across all requests.
    pub busy_seconds: f64,
    /// Whole-fabric evictions forced by placement capacity misses.
    pub evictions: u64,
}

impl Metrics {
    /// Accelerator-cache hit rate in [0, 1].
    pub fn hit_rate(&self) -> f64 {
        let total = self.jit_compiles + self.cache_hits;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "requests={} jit={} hits={} ({:.0}%) pr_downloads={} pr={:.3}ms busy={:.3}ms",
            self.requests,
            self.jit_compiles,
            self.cache_hits,
            self.hit_rate() * 100.0,
            self.pr_downloads,
            self.pr_seconds * 1e3,
            self.busy_seconds * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_rate_handles_zero() {
        assert_eq!(Metrics::default().hit_rate(), 0.0);
    }

    #[test]
    fn hit_rate_computes() {
        let m = Metrics { jit_compiles: 1, cache_hits: 3, ..Default::default() };
        assert!((m.hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn summary_contains_key_fields() {
        let m = Metrics { requests: 5, ..Default::default() };
        assert!(m.summary().contains("requests=5"));
    }
}
