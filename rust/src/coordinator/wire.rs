//! Length-prefixed wire protocol for the socket serving tier.
//!
//! Framing: every message is a `u32` little-endian payload length followed
//! by the payload; the payload's first byte is a message tag. Client tags
//! sit below `0x80`, server tags at or above it, so a misdirected frame is
//! caught at decode rather than misparsed.
//!
//! ```text
//! REQUEST  0x01  id:u64  n:u32  seed:u64  pattern:str     (client → server)
//! SHUTDOWN 0x02                                           (client → server)
//! OK       0x81  id:u64  cached:u8  jit_nanos:u64  value  (server → client)
//! ERR      0x82  id:u64  message:str                      (server → client)
//! BUSY     0x83  id:u64                                   (server → client)
//! ```
//!
//! `str` is a `u32` length + UTF-8 bytes; `value` is a kind byte (`0` =
//! scalar, `1` = vector) followed by one `f32` or a `u32` count + that
//! many `f32`s. A request names its inputs by `(n, seed)` instead of
//! shipping vectors: the server synthesizes them with
//! [`crate::workload::vector`], so a loadgen driving thousands of
//! connections moves tens of bytes per request, not kilobytes, and the
//! reply value is still checkable by recomputing from the same seed.
//!
//! Decoding is split in two layers so it is testable without sockets:
//! [`FrameDecoder`] turns an arbitrary byte-chunk stream into complete
//! payloads (rejecting oversized lengths *from the prefix alone*, before
//! buffering a hostile frame), and [`ClientMsg::decode`] /
//! [`ServerMsg::decode`] parse one payload. The blocking helpers
//! [`read_frame`] / [`write_frame`] wrap the same framing over
//! `std::io` streams for the serving tier and the loadgen.

use std::io::{self, Read, Write};

use crate::error::{Error, Result};
use crate::exec::cpu::Value;

/// Default cap on a single frame's payload (1 MiB): large enough for a
/// 200k-element vector reply, small enough that a hostile length prefix
/// cannot balloon a connection's buffer.
pub const DEFAULT_MAX_FRAME: usize = 1 << 20;

const TAG_REQUEST: u8 = 0x01;
const TAG_SHUTDOWN: u8 = 0x02;
const TAG_OK: u8 = 0x81;
const TAG_ERR: u8 = 0x82;
const TAG_BUSY: u8 = 0x83;

/// What a client sends.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientMsg {
    /// One request: `id` is echoed verbatim in the reply; `pattern` is a
    /// composition in the CLI grammar (see [`crate::patterns::parse_pattern`]);
    /// inputs are synthesized server-side from `(n, seed)`.
    Request { id: u64, n: u32, seed: u64, pattern: String },
    /// Ask the server to stop (honored only when enabled at serve time).
    Shutdown,
}

/// What the server sends. Every `Request` gets exactly one of these, with
/// the request's `id` echoed back — the id, not arrival order, pairs
/// replies to requests, so a client may pipeline freely.
#[derive(Debug, Clone, PartialEq)]
pub enum ServerMsg {
    /// Served: the computed value plus cache/JIT accounting.
    Ok { id: u64, cached: bool, jit_nanos: u64, value: Value },
    /// Failed: the error message is this request's one reply.
    Err { id: u64, message: String },
    /// Shed: admission caps or pool backpressure rejected the request
    /// without serving it. The client may retry later.
    Busy { id: u64 },
}

impl ClientMsg {
    /// Encode as a complete frame (length prefix included).
    pub fn to_frame(&self) -> Vec<u8> {
        let mut p = Vec::with_capacity(32);
        match self {
            ClientMsg::Request { id, n, seed, pattern } => {
                p.push(TAG_REQUEST);
                put_u64(&mut p, *id);
                put_u32(&mut p, *n);
                put_u64(&mut p, *seed);
                put_str(&mut p, pattern);
            }
            ClientMsg::Shutdown => p.push(TAG_SHUTDOWN),
        }
        frame(p)
    }

    /// Decode one payload (as produced by [`FrameDecoder`] / [`read_frame`]).
    pub fn decode(payload: &[u8]) -> Result<ClientMsg> {
        let mut r = Reader::new(payload);
        let msg = match r.u8("tag")? {
            TAG_REQUEST => ClientMsg::Request {
                id: r.u64("id")?,
                n: r.u32("n")?,
                seed: r.u64("seed")?,
                pattern: r.str("pattern")?,
            },
            TAG_SHUTDOWN => ClientMsg::Shutdown,
            t => return Err(Error::Parse(format!("unknown client message tag 0x{t:02x}"))),
        };
        r.finish()?;
        Ok(msg)
    }
}

impl ServerMsg {
    /// Encode as a complete frame (length prefix included).
    pub fn to_frame(&self) -> Vec<u8> {
        let mut p = Vec::with_capacity(32);
        match self {
            ServerMsg::Ok { id, cached, jit_nanos, value } => {
                p.push(TAG_OK);
                put_u64(&mut p, *id);
                p.push(u8::from(*cached));
                put_u64(&mut p, *jit_nanos);
                match value {
                    Value::Scalar(x) => {
                        p.push(0);
                        put_f32(&mut p, *x);
                    }
                    Value::Vector(v) => {
                        p.push(1);
                        put_u32(&mut p, v.len() as u32);
                        for x in v {
                            put_f32(&mut p, *x);
                        }
                    }
                }
            }
            ServerMsg::Err { id, message } => {
                p.push(TAG_ERR);
                put_u64(&mut p, *id);
                put_str(&mut p, message);
            }
            ServerMsg::Busy { id } => {
                p.push(TAG_BUSY);
                put_u64(&mut p, *id);
            }
        }
        frame(p)
    }

    /// Decode one payload (as produced by [`FrameDecoder`] / [`read_frame`]).
    pub fn decode(payload: &[u8]) -> Result<ServerMsg> {
        let mut r = Reader::new(payload);
        let msg = match r.u8("tag")? {
            TAG_OK => {
                let id = r.u64("id")?;
                let cached = match r.u8("cached")? {
                    0 => false,
                    1 => true,
                    b => return Err(Error::Parse(format!("bad cached flag {b}"))),
                };
                let jit_nanos = r.u64("jit_nanos")?;
                let value = match r.u8("value kind")? {
                    0 => Value::Scalar(r.f32("scalar")?),
                    1 => {
                        let len = r.u32("vector length")? as usize;
                        let mut v = Vec::with_capacity(len.min(DEFAULT_MAX_FRAME / 4));
                        for i in 0..len {
                            v.push(r.f32(&format!("vector[{i}]"))?);
                        }
                        Value::Vector(v)
                    }
                    k => return Err(Error::Parse(format!("unknown value kind {k}"))),
                };
                ServerMsg::Ok { id, cached, jit_nanos, value }
            }
            TAG_ERR => ServerMsg::Err { id: r.u64("id")?, message: r.str("message")? },
            TAG_BUSY => ServerMsg::Busy { id: r.u64("id")? },
            t => return Err(Error::Parse(format!("unknown server message tag 0x{t:02x}"))),
        };
        r.finish()?;
        Ok(msg)
    }
}

/// Prepend the `u32` LE length prefix to a payload.
fn frame(payload: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + payload.len());
    put_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(&payload);
    out
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

/// A strict little-endian payload reader: every read names the field it is
/// for (so truncation errors say *what* was cut off), and [`Reader::finish`]
/// rejects trailing garbage.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, len: usize, what: &str) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(len).filter(|&e| e <= self.buf.len());
        let Some(end) = end else {
            return Err(Error::Parse(format!(
                "frame truncated reading {what}: need {len} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            )));
        };
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32(&mut self, what: &str) -> Result<f32> {
        let b = self.take(4, what)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn str(&mut self, what: &str) -> Result<String> {
        let len = self.u32(what)? as usize;
        let b = self.take(len, what)?;
        String::from_utf8(b.to_vec())
            .map_err(|_| Error::Parse(format!("{what} is not valid UTF-8")))
    }

    fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(Error::Parse(format!(
                "{} trailing bytes after message",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

/// Incremental frame extractor over an arbitrary byte-chunk stream.
///
/// Feed whatever a socket read returned with [`FrameDecoder::push`]; pull
/// complete payloads with [`FrameDecoder::next_frame`]. Frames split
/// across pushes reassemble; multiple frames in one push come out one by
/// one. A length prefix above `max_frame` is rejected *before* any of
/// that frame's payload is buffered — the error is sticky, because after
/// a framing violation the stream has no recoverable sync point.
#[derive(Debug)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    max_frame: usize,
    poisoned: bool,
}

impl FrameDecoder {
    /// `max_frame` caps a single payload's length (`0` = use
    /// [`DEFAULT_MAX_FRAME`]).
    pub fn new(max_frame: usize) -> FrameDecoder {
        FrameDecoder {
            buf: Vec::new(),
            max_frame: if max_frame == 0 { DEFAULT_MAX_FRAME } else { max_frame },
            poisoned: false,
        }
    }

    /// Append raw bytes from the stream.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Extract the next complete payload, if one is buffered. `Ok(None)`
    /// means "need more bytes"; an error means the stream is framing-broken
    /// (oversized prefix) and every later call repeats the error.
    pub fn next_frame(&mut self) -> Result<Option<Vec<u8>>> {
        if self.poisoned {
            return Err(Error::Parse("frame stream already failed".into()));
        }
        if self.buf.len() < 4 {
            return Ok(None);
        }
        let len =
            u32::from_le_bytes([self.buf[0], self.buf[1], self.buf[2], self.buf[3]]) as usize;
        if len > self.max_frame {
            self.poisoned = true;
            return Err(Error::Parse(format!(
                "frame length {len} exceeds cap {}",
                self.max_frame
            )));
        }
        if self.buf.len() < 4 + len {
            return Ok(None);
        }
        let payload = self.buf[4..4 + len].to_vec();
        self.buf.drain(..4 + len);
        Ok(Some(payload))
    }

    /// True when a partial frame (or prefix) is buffered — a disconnect
    /// now is a mid-frame cut, not a clean close.
    pub fn is_mid_frame(&self) -> bool {
        !self.buf.is_empty()
    }

    /// Bytes currently buffered (diagnostics).
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }
}

/// Write one frame (length prefix + payload) to a blocking stream, then
/// flush it. The loop is explicit rather than `write_all` so the contract
/// is visible and testable: a short write advances and retries from where
/// the stream stopped, [`io::ErrorKind::Interrupted`] retries the same
/// syscall, and a `write` that accepts zero bytes is
/// [`io::ErrorKind::WriteZero`] — never a silently truncated frame that
/// would desynchronize every later message on the connection.
pub fn write_frame(w: &mut impl Write, frame_bytes: &[u8]) -> io::Result<()> {
    let mut sent = 0;
    while sent < frame_bytes.len() {
        match w.write(&frame_bytes[sent..]) {
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::WriteZero,
                    format!("stream accepted {sent} of {} frame bytes", frame_bytes.len()),
                ))
            }
            Ok(n) => sent += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    // flush is a syscall too: it can take the same EINTR the writes can
    loop {
        match w.flush() {
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            r => return r,
        }
    }
}

/// Read one frame from a blocking stream. `Ok(None)` is a clean EOF at a
/// frame boundary; EOF inside a prefix or payload is
/// [`io::ErrorKind::UnexpectedEof`], and an oversized prefix is
/// [`io::ErrorKind::InvalidData`] — raised before the payload is read.
pub fn read_frame(r: &mut impl Read, max_frame: usize) -> io::Result<Option<Vec<u8>>> {
    let max_frame = if max_frame == 0 { DEFAULT_MAX_FRAME } else { max_frame };
    let mut prefix = [0u8; 4];
    match read_exact_or_eof(r, &mut prefix)? {
        Filled::CleanEof => return Ok(None),
        Filled::Full => {}
    }
    let len = u32::from_le_bytes(prefix) as usize;
    if len > max_frame {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds cap {max_frame}"),
        ));
    }
    let mut payload = vec![0u8; len];
    read_payload(r, &mut payload)?;
    Ok(Some(payload))
}

/// `read_exact` for a frame payload, with the retry contract explicit:
/// short reads advance, [`io::ErrorKind::Interrupted`] retries the same
/// syscall, and EOF anywhere inside the payload is
/// [`io::ErrorKind::UnexpectedEof`] naming how much arrived.
fn read_payload(r: &mut impl Read, buf: &mut [u8]) -> io::Result<()> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    format!("stream cut {got} bytes into a {}-byte payload", buf.len()),
                ))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

enum Filled {
    Full,
    CleanEof,
}

/// `read_exact`, except EOF *before the first byte* is reported as clean.
fn read_exact_or_eof(r: &mut impl Read, buf: &mut [u8]) -> io::Result<Filled> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) if got == 0 => return Ok(Filled::CleanEof),
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    format!("stream cut {got} bytes into a frame prefix"),
                ))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(Filled::Full)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reader_rejects_trailing_and_truncated() {
        let mut p = Vec::new();
        p.push(TAG_BUSY);
        put_u64(&mut p, 7);
        p.push(0xFF); // trailing garbage
        assert!(ServerMsg::decode(&p).is_err());
        assert!(ServerMsg::decode(&p[..4]).is_err(), "truncated id");
    }

    #[test]
    fn frame_prefix_matches_payload_len() {
        let f = ClientMsg::Shutdown.to_frame();
        assert_eq!(f.len(), 5);
        assert_eq!(u32::from_le_bytes([f[0], f[1], f[2], f[3]]), 1);
        assert_eq!(f[4], TAG_SHUTDOWN);
    }

    /// A hostile-scheduler stand-in: reads hand out one byte at a time,
    /// writes accept one byte at a time, and every `interrupt_every`-th
    /// operation fails with [`io::ErrorKind::Interrupted`] first — the
    /// worst legal behavior of a blocking socket under signal delivery.
    struct ChunkStream {
        data: Vec<u8>,
        pos: usize,
        written: Vec<u8>,
        ops: usize,
        interrupt_every: usize,
    }

    impl ChunkStream {
        fn reading(data: Vec<u8>, interrupt_every: usize) -> ChunkStream {
            ChunkStream { data, pos: 0, written: Vec::new(), ops: 0, interrupt_every }
        }

        fn writing(interrupt_every: usize) -> ChunkStream {
            ChunkStream::reading(Vec::new(), interrupt_every)
        }

        fn maybe_interrupt(&mut self) -> io::Result<()> {
            self.ops += 1;
            if self.interrupt_every != 0 && self.ops % self.interrupt_every == 0 {
                return Err(io::Error::new(io::ErrorKind::Interrupted, "signal"));
            }
            Ok(())
        }
    }

    impl Read for ChunkStream {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            self.maybe_interrupt()?;
            if self.pos >= self.data.len() || buf.is_empty() {
                return Ok(0);
            }
            buf[0] = self.data[self.pos];
            self.pos += 1;
            Ok(1)
        }
    }

    impl Write for ChunkStream {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.maybe_interrupt()?;
            if buf.is_empty() {
                return Ok(0);
            }
            self.written.push(buf[0]);
            Ok(1)
        }

        fn flush(&mut self) -> io::Result<()> {
            self.maybe_interrupt()
        }
    }

    #[test]
    fn read_frame_survives_one_byte_chunks_and_interrupts() {
        let msg = ClientMsg::Request { id: 9, n: 64, seed: 3, pattern: "vmul|reduce+".into() };
        let mut stream = ChunkStream::reading(msg.to_frame(), 3);
        let payload = read_frame(&mut stream, 0).unwrap().expect("one frame");
        assert_eq!(ClientMsg::decode(&payload).unwrap(), msg);
        // the next read is a clean EOF at the frame boundary
        assert!(read_frame(&mut stream, 0).unwrap().is_none());
    }

    #[test]
    fn write_frame_survives_one_byte_chunks_and_interrupts() {
        let msg = ServerMsg::Ok {
            id: 4,
            cached: true,
            jit_nanos: 17,
            value: Value::Vector(vec![1.0, 2.5, -3.0]),
        };
        let frame_bytes = msg.to_frame();
        let mut stream = ChunkStream::writing(2);
        write_frame(&mut stream, &frame_bytes).unwrap();
        assert_eq!(stream.written, frame_bytes, "every byte arrives, in order");
    }

    #[test]
    fn read_frame_reports_mid_payload_eof() {
        let mut f = ClientMsg::Shutdown.to_frame();
        f.pop(); // cut the stream one byte short of the payload
        let mut stream = ChunkStream::reading(f, 0);
        let err = read_frame(&mut stream, 0).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn read_frame_reports_mid_prefix_eof() {
        let mut stream = ChunkStream::reading(vec![0x01, 0x00], 0);
        let err = read_frame(&mut stream, 0).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }
}
