//! Cluster sharding: consistent-hash routing of composition families
//! across multiple [`WorkerPool`]s — the scale unit above one pool.
//!
//! One pool is one node (N workers, one shared accelerator cache); a
//! [`Cluster`] is several, with composition keys routed to pools by a
//! consistent-hash **ring** ([`HashRing`]): every pool contributes
//! `ClusterConfig::vnodes` splitmix64-mixed virtual points, and a key is
//! owned by the first point clockwise of its own mixed hash. A pool join
//! or leave therefore moves only the keys falling on the arcs the new
//! (or departed) points carve out — ~1/N of the key space — instead of
//! the near-total remap a `key % n` scheme suffers on any membership
//! change. The same ring (same mix, same discipline) backs the pool's
//! own worker home hash, so both routing levels survive growth.
//!
//! Three cluster behaviors ride on top of the ring:
//!
//! * **Warm-start on join** — a joining pool receives every cached
//!   `AcceleratorProgram` (+ one donor [`crate::jit::PlacementPlan`])
//!   from the existing pools' shared caches. Programs are
//!   fabric-independent (the PR 4 split), so the first request for a
//!   shipped key pays only a placement-only respecialization on the new
//!   pool's fabric — never a JIT recompile. Scored in
//!   `Metrics::warm_start_hits`.
//! * **Evacuation on leave/death** — [`Cluster::retire`] removes the
//!   pool from the ring, drains its queued (not in-flight) backlog and
//!   re-routes every job through the shrunken ring, then quiesces the
//!   pool so in-flight bursts still reply. Counted in
//!   `Metrics::pool_evacuations`.
//! * **Cross-pool stealing** — [`Cluster::rebalance_once`] is the
//!   last-resort rung of the steal ladder (in-pool steal → cross-pool
//!   steal → CPU floor): an idle pool takes the whole tail composition
//!   group of the deepest backlogged pool. The ring still owns the key —
//!   the migration is transient load shedding, not a route repoint.
//!   Counted in `Metrics::cross_pool_steals`.
//!
//! The router is **fabric-shape-aware**: pools may host differently
//! shaped fabrics (e.g. `TileSizing { large_every: 0 }` builds a pool
//! with no Large PR regions), and a key whose composition needs a region
//! class a pool lacks skips that pool's arc ([`HashRing::owner_where`]).
//! The exclusion is an optimization, not a correctness requirement: if
//! no pool fits, the key routes normally and the pool's resource ladder
//! degrades to the bit-identical CPU floor (PR 7).

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard};

use super::frontend::{Dispatch, Rejected};
use super::pool::{CompletionQueue, Ticket, WorkerPool};
use super::{AtomicMetrics, Metrics, Request, Response};
use crate::bitstream::{Footprint, RegionClass};
use crate::config::{ClusterConfig, OverlayConfig, ServiceConfig};
use crate::error::{Error, Result};
use crate::jit::FUSED_KEY_SALT;
use crate::patterns::Composition;

/// The splitmix64 finalizer (same constants as [`crate::workload::Rng`]):
/// a cheap, stateless, full-avalanche u64 mix. Both routing levels hash
/// through it — raw composition keys are structured (`DefaultHasher`
/// output XOR a fusion salt), and ring arithmetic needs them uniform.
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// A consistent-hash ring over `slots` (pool or worker indices).
///
/// Each slot seed contributes `vnodes` points at
/// `splitmix64(splitmix64(seed) ^ v)`; a key is owned by the first point
/// at or clockwise of `splitmix64(key)`. Adding a slot moves exactly the
/// keys landing on the new points' arcs — every moved key lands **on the
/// added slot** — and removing one moves exactly the departed slot's
/// keys, redistributed to the clockwise survivors.
#[derive(Debug, Clone)]
pub struct HashRing {
    /// `(point, slot)` sorted by point — binary-searchable.
    points: Vec<(u64, usize)>,
}

impl HashRing {
    /// Build a ring where slot `i` is seeded by `slot_seeds[i]`. Seeds
    /// must be distinct per slot (pool ids, worker indices); vnode
    /// points of different slots colliding is theoretically possible and
    /// resolved deterministically by the `(point, slot)` sort.
    pub fn new(slot_seeds: &[u64], vnodes: usize) -> HashRing {
        let mut points = Vec::with_capacity(slot_seeds.len() * vnodes);
        for (slot, &seed) in slot_seeds.iter().enumerate() {
            let base = splitmix64(seed);
            for v in 0..vnodes as u64 {
                points.push((splitmix64(base ^ v), slot));
            }
        }
        points.sort_unstable();
        HashRing { points }
    }

    /// The slot owning `key`. Panics on an empty ring.
    pub fn owner(&self, key: u64) -> usize {
        self.owner_where(key, |_| true).expect("owner() on an empty ring")
    }

    /// The first slot at or clockwise of `key`'s point for which
    /// `eligible` holds — the fabric-shape-aware lookup: an ineligible
    /// slot's arc is walked past as if its points were absent, so the
    /// keys it would own spill deterministically to the next eligible
    /// slot. `None` when no slot is eligible (or the ring is empty).
    pub fn owner_where(&self, key: u64, eligible: impl Fn(usize) -> bool) -> Option<usize> {
        if self.points.is_empty() {
            return None;
        }
        let h = splitmix64(key);
        let start = self.points.partition_point(|&(p, _)| p < h);
        for i in 0..self.points.len() {
            let (_, slot) = self.points[(start + i) % self.points.len()];
            if eligible(slot) {
                return Some(slot);
            }
        }
        None
    }

    /// Total virtual points on the ring.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// True when no slot contributed any point.
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

/// One member pool and its cluster-side bookkeeping.
struct Slot {
    /// Stable member id (monotonic per cluster) — the ring seed, so a
    /// pool's arcs never depend on its position in the member list.
    id: u64,
    pool: Arc<WorkerPool>,
    /// Whether this pool's fabrics host any Large PR region (shape-aware
    /// routing excludes Large-needing keys from small-only pools).
    has_large: bool,
    /// Keys whose programs were shipped to this pool at join and not yet
    /// claimed by a routed request — each first claim is one
    /// `warm_start_hits`.
    shipped: HashSet<u64>,
}

struct ClusterState {
    slots: Vec<Slot>,
    ring: HashRing,
    /// Retired pools, kept so their served work still counts in
    /// [`Cluster::snapshot`] / [`Cluster::shutdown`] aggregates.
    graveyard: Vec<Arc<WorkerPool>>,
}

/// Final cluster accounting returned by [`Cluster::shutdown`].
#[derive(Debug, Clone)]
pub struct ClusterReport {
    /// Cluster-level counters merged with every member and retired
    /// pool's final aggregate.
    pub aggregate: Metrics,
    /// `(member id, final metrics)` for each pool still in the ring.
    pub per_pool: Vec<(u64, Metrics)>,
    /// Final metrics of each retired pool, in retirement order.
    pub retired: Vec<Metrics>,
    /// Compiled accelerators across the live pools' caches at shutdown.
    pub cached_accelerators: usize,
}

/// N worker pools behind one consistent-hash router (see module docs).
///
/// Thread-safe: membership is a single mutex taken per routed request
/// (the per-request work — JIT, PR download, execution — dwarfs one
/// uncontended lock), and implements [`Dispatch`], so the reactor front
/// end and the socket tier serve through a cluster exactly as they serve
/// through one pool.
pub struct Cluster {
    state: Mutex<ClusterState>,
    /// Cluster-level counters (`pool_joins`, `pool_evacuations`,
    /// `cross_pool_steals`, `warm_start_hits`). Pool-served counters
    /// live in each member's own aggregate; [`Cluster::snapshot`] merges
    /// both views.
    pub metrics: Arc<AtomicMetrics>,
    cfg: ClusterConfig,
    next_id: AtomicU64,
}

impl Cluster {
    /// An empty cluster. Add members with [`Cluster::join`]; routing
    /// fails until at least one pool joined.
    pub fn new(cfg: ClusterConfig) -> Result<Cluster> {
        cfg.validate()?;
        Ok(Cluster {
            state: Mutex::new(ClusterState {
                slots: Vec::new(),
                ring: HashRing::new(&[], 0),
                graveyard: Vec::new(),
            }),
            metrics: Arc::new(AtomicMetrics::default()),
            cfg,
            next_id: AtomicU64::new(0),
        })
    }

    /// A cluster of `pools` identically configured members.
    pub fn homogeneous(
        cfg: OverlayConfig,
        service: ServiceConfig,
        ccfg: ClusterConfig,
        pools: usize,
    ) -> Result<Cluster> {
        if pools == 0 {
            return Err(Error::Config("a cluster needs at least one pool".into()));
        }
        let cluster = Cluster::new(ccfg)?;
        for _ in 0..pools {
            cluster.join(cfg.clone(), service.clone())?;
        }
        Ok(cluster)
    }

    fn lock(&self) -> MutexGuard<'_, ClusterState> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn ring_of(slots: &[Slot], vnodes: usize) -> HashRing {
        let seeds: Vec<u64> = slots.iter().map(|s| s.id).collect();
        HashRing::new(&seeds, vnodes)
    }

    /// The fusion-salted cluster routing key — the same key the pools'
    /// caches index, so warm-start bookkeeping and routing agree.
    fn salted_key(&self, comp: &Composition) -> u64 {
        comp.cache_key() ^ if self.cfg.fuse { FUSED_KEY_SALT } else { 0 }
    }

    /// Whether any stage of `comp` only fits a Large PR region (its
    /// per-operator footprint overflows the Small budget). Fused tails
    /// are not modeled here: fusion may widen a footprint past Small,
    /// but a small-only pool then degrades fused → unfused → CPU
    /// bit-identically, so under-exclusion is safe.
    fn needs_large(comp: &Composition) -> bool {
        comp.stages().iter().any(|s| {
            matches!(
                RegionClass::smallest_fitting(&Footprint::for_operator(s.op)),
                Some(RegionClass::Large)
            )
        })
    }

    /// Ring lookup + warm-start scoring for one key. Caller holds the
    /// state lock and has checked the member list is non-empty.
    fn route_slot(&self, st: &mut ClusterState, key: u64, needs_large: bool) -> usize {
        let idx = if needs_large {
            // skip small-only pools' arcs; if *no* pool hosts Large
            // regions, route normally — the CPU floor serves anywhere
            st.ring
                .owner_where(key, |s| st.slots[s].has_large)
                .unwrap_or_else(|| st.ring.owner(key))
        } else {
            st.ring.owner(key)
        };
        if st.slots[idx].shipped.remove(&key) {
            self.metrics.record(&Metrics { warm_start_hits: 1, ..Metrics::default() });
        }
        idx
    }

    /// The pool that owns `comp` right now.
    fn route(&self, comp: &Composition) -> Result<Arc<WorkerPool>> {
        let key = self.salted_key(comp);
        let needs_large = Self::needs_large(comp);
        let mut st = self.lock();
        if st.slots.is_empty() {
            return Err(Error::Runtime("cluster has no member pools".into()));
        }
        let idx = self.route_slot(&mut st, key, needs_large);
        Ok(st.slots[idx].pool.clone())
    }

    /// Add a member pool built from `cfg`/`service` and return its id.
    ///
    /// With `ClusterConfig::warm_start` on, every accelerator program
    /// cached by the existing members is shipped into the new pool's
    /// cache first (deduplicated by key, paired with one donor placement
    /// plan). The donor plan is keyed by the *donor's* fabric, so the
    /// joining pool's first request for a shipped key finds the program
    /// but no local plan — a placement-only respecialization, never a
    /// recompile.
    pub fn join(&self, cfg: OverlayConfig, service: ServiceConfig) -> Result<u64> {
        let pool = Arc::new(WorkerPool::new(cfg.clone(), service)?);
        let has_large = cfg.large_tiles() > 0;
        let mut st = self.lock();
        let mut shipped = HashSet::new();
        if self.cfg.warm_start {
            for donor in &st.slots {
                for &fid in donor.pool.fabric_ids() {
                    for (key, spec, plan) in donor.pool.cache().plans_for_fabric(fid) {
                        if shipped.insert(key) {
                            pool.cache().insert(key, spec, plan);
                        }
                    }
                }
            }
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        st.slots.push(Slot { id, pool, has_large, shipped });
        st.ring = Self::ring_of(&st.slots, self.cfg.vnodes);
        drop(st);
        self.metrics.record(&Metrics { pool_joins: 1, ..Metrics::default() });
        Ok(id)
    }

    /// Remove member `id` from the ring — graceful leave and detected
    /// death share this path — evacuating its queued backlog through the
    /// shrunken ring, then quiescing the pool (workers finish in-flight
    /// bursts, reply, and exit). Returns the number of evacuated jobs.
    /// The last member cannot retire.
    pub fn retire(&self, id: u64) -> Result<usize> {
        let mut st = self.lock();
        if st.slots.len() <= 1 {
            return Err(Error::Config("cannot retire the cluster's last pool".into()));
        }
        let pos = st
            .slots
            .iter()
            .position(|s| s.id == id)
            .ok_or_else(|| Error::Runtime(format!("no pool {id} in the cluster")))?;
        let slot = st.slots.remove(pos);
        st.ring = Self::ring_of(&st.slots, self.cfg.vnodes);
        // nothing new can route here (the lock is held and the ring no
        // longer lists the pool); what's queued moves, what's in flight
        // finishes on the departing workers
        let orphans = slot.pool.extract_backlog();
        slot.pool.quiesce();
        let mut moved = 0;
        for job in orphans {
            let key = job.request.comp.cache_key()
                ^ if self.cfg.fuse { FUSED_KEY_SALT } else { 0 };
            let needs_large = Self::needs_large(&job.request.comp);
            let idx = self.route_slot(&mut st, key, needs_large);
            // blocking re-injection: evacuation must not shed load. A
            // failure hands the job back and its reply sink fails safe.
            if st.slots[idx].pool.route_and_enqueue(job, true).is_ok() {
                moved += 1;
            }
        }
        st.graveyard.push(slot.pool);
        drop(st);
        self.metrics.record(&Metrics { pool_evacuations: 1, ..Metrics::default() });
        Ok(moved)
    }

    /// One cross-pool steal attempt — the rung between in-pool stealing
    /// and the CPU floor. An idle member (zero queued jobs) takes the
    /// whole tail composition group of the deepest member holding at
    /// least `ClusterConfig::cross_steal_depth` jobs. Returns how many
    /// jobs moved (0: no idle thief, no deep victim, or nothing
    /// stealable). The ring still owns the moved key: this is transient
    /// load shedding, and the next submit routes by ring as before.
    pub fn rebalance_once(&self) -> usize {
        let st = self.lock();
        if st.slots.len() < 2 {
            return 0;
        }
        let Some(thief) =
            st.slots.iter().position(|s| s.pool.total_queue_depth() == 0)
        else {
            return 0;
        };
        let victim = st
            .slots
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != thief)
            .map(|(i, s)| (s.pool.total_queue_depth(), i))
            .max()
            .filter(|&(d, _)| d >= self.cfg.cross_steal_depth);
        let Some((_, victim)) = victim else {
            return 0;
        };
        let group = st.slots[victim].pool.export_tail_group(self.cfg.cross_steal_depth);
        let thief_pool = st.slots[thief].pool.clone();
        drop(st);
        let mut moved = 0;
        for job in group {
            // the thief was idle: blocking enqueue cannot wait long
            if thief_pool.route_and_enqueue(job, true).is_ok() {
                moved += 1;
            }
        }
        if moved > 0 {
            self.metrics
                .record(&Metrics { cross_pool_steals: moved as u64, ..Metrics::default() });
        }
        moved
    }

    /// Route and enqueue a request; the reply channel is returned
    /// immediately (blocking backpressure, like [`WorkerPool::submit`]).
    pub fn submit(&self, request: Request) -> Result<mpsc::Receiver<Result<Response>>> {
        self.route(&request.comp)?.submit(request)
    }

    /// Route a request and block for its response.
    pub fn submit_wait(&self, request: Request) -> Result<Response> {
        self.route(&request.comp)?.submit_wait(request)
    }

    /// Current member count.
    pub fn pools(&self) -> usize {
        self.lock().slots.len()
    }

    /// Current member ids, in join order.
    pub fn pool_ids(&self) -> Vec<u64> {
        self.lock().slots.iter().map(|s| s.id).collect()
    }

    /// Live metrics of member `id`, if it is still in the ring.
    pub fn pool_snapshot(&self, id: u64) -> Option<Metrics> {
        self.lock().slots.iter().find(|s| s.id == id).map(|s| s.pool.snapshot())
    }

    /// Compiled accelerators across the live members' caches. Shipped
    /// programs count once per pool holding them (caches are per pool).
    pub fn cached_accelerators(&self) -> usize {
        self.lock().slots.iter().map(|s| s.pool.cached_accelerators()).sum()
    }

    /// Cluster-wide live aggregate: cluster-level counters merged with
    /// every member's and every retired pool's snapshot.
    pub fn snapshot(&self) -> Metrics {
        let st = self.lock();
        let mut m = self.metrics.snapshot();
        for s in &st.slots {
            m.merge(&s.pool.snapshot());
        }
        for p in &st.graveyard {
            m.merge(&p.snapshot());
        }
        m
    }

    /// Drain every member, stop all workers, and return the final
    /// report. Members still shared elsewhere (an undropped `Arc`) are
    /// quiesced and snapshotted instead of joined.
    pub fn shutdown(self) -> ClusterReport {
        let st = self.state.into_inner().unwrap_or_else(|p| p.into_inner());
        let cached_accelerators =
            st.slots.iter().map(|s| s.pool.cached_accelerators()).sum();
        let mut aggregate = self.metrics.snapshot();
        let mut per_pool = Vec::new();
        for slot in st.slots {
            let m = match Arc::try_unwrap(slot.pool) {
                Ok(pool) => pool.shutdown().aggregate,
                Err(shared) => {
                    shared.quiesce();
                    shared.snapshot()
                }
            };
            aggregate.merge(&m);
            per_pool.push((slot.id, m));
        }
        let mut retired = Vec::new();
        for pool in st.graveyard {
            let m = match Arc::try_unwrap(pool) {
                Ok(pool) => pool.shutdown().aggregate,
                Err(shared) => shared.snapshot(),
            };
            aggregate.merge(&m);
            retired.push(m);
        }
        ClusterReport { aggregate, per_pool, retired, cached_accelerators }
    }
}

impl Dispatch for Cluster {
    /// The cluster half of the reactor front end: route by ring, then
    /// delegate to the owning pool's async submission. A routing failure
    /// (empty cluster) consumes the request — its error is the one
    /// reply — while pool backpressure hands it back for retry, exactly
    /// like dispatching into a single pool.
    fn submit_async(
        &self,
        request: Request,
        completions: &Arc<CompletionQueue>,
    ) -> std::result::Result<Ticket, Rejected> {
        let pool = match self.route(&request.comp) {
            Ok(pool) => pool,
            Err(e) => return Err(Rejected::Failed(e)),
        };
        pool.submit_async_reclaim(request, completions).map_err(|(request, e)| match e {
            Error::PoolBusy { .. } => Rejected::Busy(request),
            other => Rejected::Failed(other),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitstream::OperatorKind;
    use crate::workload;

    fn service() -> ServiceConfig {
        ServiceConfig::with_workers(2)
    }

    fn req(comp: &Composition, k: u64) -> Request {
        Request::dynamic(comp.clone(), workload::request_inputs(comp, k))
    }

    #[test]
    fn ring_growth_moves_only_arcs_of_the_new_slot() {
        for p in [2usize, 3, 4, 7] {
            let seeds: Vec<u64> = (0..p as u64).map(|i| i * 11 + 3).collect();
            let mut grown = seeds.clone();
            grown.push(997);
            let before = HashRing::new(&seeds, 64);
            let after = HashRing::new(&grown, 64);
            let total = 512u64;
            let mut moved = 0usize;
            for k in 0..total {
                let key = k.wrapping_mul(0x9e37_79b9_7f4a_7c15);
                let (a, b) = (before.owner(key), after.owner(key));
                if a != b {
                    assert_eq!(b, p, "a moved key must land on the added slot");
                    moved += 1;
                }
            }
            let frac = moved as f64 / total as f64;
            assert!(
                frac <= 2.0 / (p as f64 + 1.0),
                "{p}→{} pools moved {frac:.3} of keys",
                p + 1
            );
            assert!(moved > 0, "the new slot must own something");
        }
    }

    #[test]
    fn ring_removal_moves_only_the_departed_slots_keys() {
        let seeds = [3u64, 14, 25, 36];
        let full = HashRing::new(&seeds, 64);
        let survivors = [3u64, 14, 36]; // slot 2 departs
        let shrunk = HashRing::new(&survivors, 64);
        for k in 0..512u64 {
            let key = k.wrapping_mul(0x9e37_79b9_7f4a_7c15);
            let before = full.owner(key);
            let after = shrunk.owner(key);
            if before != 2 {
                // survivors keep their keys (index shifts down past the
                // removed slot)
                let expect = if before < 2 { before } else { before - 1 };
                assert_eq!(after, expect, "a surviving slot's key must not move");
            }
        }
    }

    #[test]
    fn owner_where_skips_ineligible_slots_deterministically() {
        let ring = HashRing::new(&[1, 2, 3], 16);
        for k in 0..256u64 {
            let unrestricted = ring.owner(k);
            let only_zero = ring.owner_where(k, |s| s == 0).unwrap();
            assert_eq!(only_zero, 0);
            let not_owner = ring.owner_where(k, |s| s != unrestricted).unwrap();
            assert_ne!(not_owner, unrestricted);
            // repeatable
            assert_eq!(not_owner, ring.owner_where(k, |s| s != unrestricted).unwrap());
        }
        assert!(ring.owner_where(7, |_| false).is_none());
        assert!(HashRing::new(&[], 8).is_empty());
        assert!(HashRing::new(&[], 8).owner_where(7, |_| true).is_none());
    }

    #[test]
    fn cluster_round_trips_and_conserves() {
        let cluster = Cluster::homogeneous(
            OverlayConfig::default(),
            service(),
            ClusterConfig::default(),
            2,
        )
        .unwrap();
        assert_eq!(cluster.pools(), 2);
        let stream = workload::mixed_compositions(24, 128, 5);
        for (k, comp) in stream.iter().enumerate() {
            cluster.submit_wait(req(comp, k as u64)).unwrap();
        }
        let snap = cluster.snapshot();
        assert_eq!(snap.requests, 24);
        assert_eq!(snap.pool_joins, 2);
        let report = cluster.shutdown();
        assert_eq!(report.aggregate.requests, 24);
        assert_eq!(report.per_pool.len(), 2);
        assert!(report.retired.is_empty());
        // every request is a full hit, a placement respec, or a compile
        assert_eq!(
            report.aggregate.cache_hits
                + report.aggregate.placement_respecializations
                + report.aggregate.jit_compiles,
            24
        );
    }

    #[test]
    fn empty_cluster_rejects_and_last_pool_cannot_retire() {
        let cluster = Cluster::new(ClusterConfig::default()).unwrap();
        let comp = Composition::map(OperatorKind::Abs, 64);
        assert!(cluster.submit_wait(req(&comp, 0)).is_err());
        let id = cluster.join(OverlayConfig::default(), service()).unwrap();
        assert!(cluster.retire(id).is_err(), "last member must not retire");
        assert!(cluster.retire(id + 99).is_err(), "unknown id is an error");
        cluster.submit_wait(req(&comp, 0)).unwrap();
        let report = cluster.shutdown();
        assert_eq!(report.aggregate.requests, 1);
        assert_eq!(report.aggregate.pool_joins, 1);
    }

    #[test]
    fn shape_aware_routing_excludes_small_only_pools() {
        // pool 0: full-shape fabric; pool 1: no Large regions at all
        let cluster = Cluster::new(ClusterConfig::default()).unwrap();
        let full = cluster.join(OverlayConfig::default(), service()).unwrap();
        let mut small_only = OverlayConfig::default();
        small_only.sizing.large_every = 0;
        let small = cluster.join(small_only, service()).unwrap();
        // Sin only fits a Large region: every such key must route to the
        // full-shape pool no matter where its hash lands
        for i in 0..12usize {
            let comp = Composition::map(OperatorKind::Sin, 64 + i);
            cluster.submit_wait(req(&comp, i as u64)).unwrap();
        }
        let full_m = cluster.pool_snapshot(full).unwrap();
        let small_m = cluster.pool_snapshot(small).unwrap();
        assert_eq!(full_m.requests, 12, "Large-needing keys all go to the full pool");
        assert_eq!(small_m.requests, 0);
        assert_eq!(full_m.cpu_fallbacks, 0, "no ladder degradation needed");
        cluster.shutdown();
    }

    #[test]
    fn warm_start_ships_programs_and_scores_first_claims() {
        let cfg = OverlayConfig::default();
        let cluster =
            Cluster::homogeneous(cfg.clone(), service(), ClusterConfig::default(), 2).unwrap();
        // compile a wide cohort across the two members
        let cohort = workload::wide_cohort(32);
        for (k, comp) in cohort.iter().enumerate() {
            cluster.submit_wait(req(comp, k as u64)).unwrap();
        }
        let compiled_before = cluster.snapshot().jit_compiles;
        assert_eq!(compiled_before, 32, "every distinct-key cohort member compiles once");
        let joined = cluster.join(cfg, service()).unwrap();
        // replay the cohort: keys now owned by the joiner find their
        // shipped program — placement-only respecialization, no compile
        for (k, comp) in cohort.iter().enumerate() {
            cluster.submit_wait(req(comp, 100 + k as u64)).unwrap();
        }
        let report = cluster.shutdown();
        assert_eq!(
            report.aggregate.jit_compiles, compiled_before,
            "warm-started members must never recompile shipped programs"
        );
        assert!(report.aggregate.warm_start_hits > 0, "the joiner must claim shipped keys");
        let (_, joined_m) =
            report.per_pool.iter().find(|(id, _)| *id == joined).unwrap();
        assert_eq!(joined_m.jit_compiles, 0);
        assert_eq!(
            joined_m.requests, joined_m.cache_hits + joined_m.placement_respecializations,
            "every joiner-served request rode a shipped program"
        );
    }

    #[test]
    fn retire_evacuates_the_backlog_and_keeps_every_reply() {
        // paused members so a backlog actually accumulates
        let ccfg = ClusterConfig::default();
        let cluster = Cluster::new(ccfg).unwrap();
        let cfg = OverlayConfig::default();
        let svc = ServiceConfig { queue_capacity: 64, ..ServiceConfig::with_workers(1) };
        let a = cluster.join(cfg.clone(), svc.clone()).unwrap();
        let b = cluster.join(cfg, svc).unwrap();
        let cohort = workload::wide_cohort(8);
        let mut pending = Vec::new();
        for (k, comp) in cohort.iter().enumerate() {
            pending.push(cluster.submit(req(comp, k as u64)).unwrap());
        }
        // retire a live member: its in-flight jobs finish there, its
        // queued ones move to the survivor (possibly 0 moved — the
        // workers race the retire and may have drained everything)
        cluster.retire(a).unwrap();
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let snap = cluster.snapshot();
        assert_eq!(snap.requests, 8, "no request may be lost by an evacuation");
        assert_eq!(snap.pool_evacuations, 1);
        assert_eq!(cluster.pool_ids(), vec![b]);
        let report = cluster.shutdown();
        assert_eq!(report.aggregate.requests, 8);
        assert_eq!(report.retired.len(), 1);
    }

    #[test]
    fn cross_pool_steal_moves_a_whole_group_to_an_idle_pool() {
        let ccfg = ClusterConfig { cross_steal_depth: 2, ..ClusterConfig::default() };
        let cluster = Cluster::new(ccfg).unwrap();
        let cfg = OverlayConfig::default();
        let svc = ServiceConfig { queue_capacity: 64, ..ServiceConfig::with_workers(1) };
        // a deep same-key backlog on the only member, then an idle joiner
        let _a = cluster.join(cfg.clone(), svc.clone()).unwrap();
        let comp = Composition::vmul_reduce(128);
        let mut pending = Vec::new();
        for k in 0..6 {
            pending.push(cluster.submit(req(&comp, k)).unwrap());
        }
        let _b = cluster.join(cfg, svc).unwrap();
        // rebalance while the victim still holds queued jobs; the loop
        // tolerates the race where the victim drains everything first
        let mut moved = 0;
        for _ in 0..50 {
            moved = cluster.rebalance_once();
            if moved > 0 {
                break;
            }
            let all_done = cluster.snapshot().requests >= 6;
            if all_done {
                break;
            }
            std::thread::yield_now();
        }
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let report = cluster.shutdown();
        assert_eq!(report.aggregate.requests, 6);
        // when a steal happened it moved whole jobs and was counted
        assert_eq!(report.aggregate.cross_pool_steals, moved as u64);
        assert_eq!(report.aggregate.requests, 6, "stolen jobs still reply exactly once");
        assert_eq!(report.aggregate.pool_joins, 2);
        // moved jobs (if any) were served by the thief; either way the
        // conservation law holds cluster-wide
        assert_eq!(
            report.aggregate.cache_hits
                + report.aggregate.placement_respecializations
                + report.aggregate.jit_compiles,
            6
        );
    }
}
