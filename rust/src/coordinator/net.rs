//! Socket serving tier: a TCP / Unix-socket acceptor speaking the
//! length-prefixed [`wire`](super::wire) protocol in front of the reactor
//! front end ([`Frontend`]).
//!
//! Layering mirrors the rest of the coordinator: all protocol *decisions*
//! live in [`ConnDriver`], a deterministic state machine fed complete
//! frames and caller-supplied milliseconds — unit-testable without a
//! socket, a thread, or a real clock. The I/O shell around it is thin:
//! one acceptor thread plus a reader/writer thread pair per connection.
//!
//! Lifecycle rules enforced here:
//!
//! - **Backpressure**: each connection may have at most
//!   `max_pending_per_conn` requests awaiting replies; excess requests are
//!   answered `BUSY` immediately (counted in `net_rejections`) instead of
//!   being queued without bound. Session-level admission caps
//!   (`inflight_per_session`, `max_inflight`) still apply underneath.
//! - **Shedding**: idle connections (no complete frame within
//!   `idle_timeout_ms` — partial frames do *not* reset the clock), framing
//!   violations (oversized prefix, malformed payload) and mid-frame
//!   disconnects are shed: the session closes, undelivered completions are
//!   accounted as late replies, and `conns_shed` increments. A clean EOF
//!   at a frame boundary is a polite hangup and is not counted.
//! - **Reply pairing**: the reactor delivers session replies in submission
//!   order, so wire ids are paired to replies through a per-connection
//!   FIFO — no id needs to travel through the backend.
//! - **Shutdown**: a `SHUTDOWN` frame stops the whole server only when
//!   [`NetConfig::allow_remote_shutdown`] is set; otherwise the sender is
//!   shed as a protocol violation.

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::frontend::{Dispatch, Frontend, SessionRecv, SessionReplies, SessionSubmitter};
use super::metrics::{AtomicMetrics, Metrics};
use super::wire::{write_frame, ClientMsg, FrameDecoder, ServerMsg};
use super::Request;
use crate::config::NetConfig;
use crate::error::{Error, Result};
use crate::patterns::parse_pattern;
use crate::workload;

/// How often blocked reads and reply waits wake to check deadlines and
/// the server stop flag. Bounds shutdown latency, not correctness.
const TICK_MS: u64 = 50;

/// Stack size for per-connection reader/writer threads. They hold a few
/// KB of live state; the default 8 MB stack would cap connection counts
/// long before anything else does.
const CONN_STACK: usize = 128 * 1024;

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

// ---------------------------------------------------------------------------
// ConnDriver: the per-connection protocol state machine
// ---------------------------------------------------------------------------

/// What the I/O shell must do next, as decided by [`ConnDriver`].
#[derive(Debug)]
pub enum WireStep {
    /// Submit into the session; the reply is written when it arrives.
    Submit { id: u64, request: Request },
    /// Write this rejection immediately (`BUSY` on the pending cap, `ERR`
    /// on a boundary-invalid request). Counted in `net_rejections`.
    Reject(ServerMsg),
    /// Honored remote shutdown: stop the server, close this connection
    /// cleanly.
    Shutdown,
    /// Protocol violation: shed the connection (counted in `conns_shed`).
    Shed(String),
}

/// Deterministic per-connection protocol logic. Time is injected as
/// milliseconds-since-accept so tests can replay any interleaving of
/// frames, silence, and backpressure without sockets or clocks.
pub struct ConnDriver {
    cfg: NetConfig,
    last_frame_ms: u64,
}

impl ConnDriver {
    /// `now_ms` starts the idle clock: a freshly accepted connection has
    /// `idle_timeout_ms` to produce its first complete frame.
    pub fn new(cfg: NetConfig, now_ms: u64) -> ConnDriver {
        ConnDriver { cfg, last_frame_ms: now_ms }
    }

    /// True once no *complete* frame has arrived for `idle_timeout_ms`.
    /// Partial frames never reset the clock, so a peer trickling one byte
    /// per tick cannot hold a session open (`idle_timeout_ms == 0`
    /// disables the deadline).
    pub fn idle_exceeded(&self, now_ms: u64) -> bool {
        self.cfg.idle_timeout_ms != 0
            && now_ms.saturating_sub(self.last_frame_ms) >= self.cfg.idle_timeout_ms
    }

    /// Decide what one complete frame means. `pending` is the number of
    /// requests currently awaiting replies on this connection.
    pub fn on_frame(&mut self, payload: &[u8], now_ms: u64, pending: usize) -> WireStep {
        self.last_frame_ms = now_ms;
        let msg = match ClientMsg::decode(payload) {
            Ok(m) => m,
            Err(e) => return WireStep::Shed(format!("malformed frame: {e}")),
        };
        match msg {
            ClientMsg::Shutdown => {
                if self.cfg.allow_remote_shutdown {
                    WireStep::Shutdown
                } else {
                    WireStep::Shed("remote shutdown not permitted".into())
                }
            }
            ClientMsg::Request { id, n, seed, pattern } => {
                if n as usize > self.cfg.max_n {
                    let message = format!("n={} exceeds the server cap {}", n, self.cfg.max_n);
                    return WireStep::Reject(ServerMsg::Err { id, message });
                }
                if pending >= self.cfg.max_pending_per_conn {
                    return WireStep::Reject(ServerMsg::Busy { id });
                }
                match parse_pattern(&pattern, n as usize) {
                    Ok(comp) => {
                        // requests name inputs by (n, seed); synthesize the
                        // channels server-side so frames stay tiny — same
                        // 0.1..2.0 domain as workload::request_inputs, safe
                        // for every operator
                        let inputs: Vec<Vec<f32>> = (0..comp.inputs)
                            .map(|c| {
                                workload::vector(n as usize, seed.wrapping_add(c as u64), 0.1, 2.0)
                            })
                            .collect();
                        WireStep::Submit { id, request: Request::dynamic(comp, inputs) }
                    }
                    Err(e) => WireStep::Reject(ServerMsg::Err { id, message: e.to_string() }),
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Stream / listener shims: one code path for TCP and Unix sockets
// ---------------------------------------------------------------------------

enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Conn {
    fn try_clone(&self) -> io::Result<Conn> {
        match self {
            Conn::Tcp(s) => s.try_clone().map(Conn::Tcp),
            #[cfg(unix)]
            Conn::Unix(s) => s.try_clone().map(Conn::Unix),
        }
    }

    fn set_read_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_read_timeout(d),
            #[cfg(unix)]
            Conn::Unix(s) => s.set_read_timeout(d),
        }
    }

    fn shutdown_both(&self) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.shutdown(Shutdown::Both),
            #[cfg(unix)]
            Conn::Unix(s) => s.shutdown(Shutdown::Both),
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Conn::Unix(s) => s.flush(),
        }
    }
}

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    /// `"unix:<path>"` binds a Unix socket (replacing a stale file);
    /// anything else is a TCP address like `127.0.0.1:7000` (`:0` picks a
    /// free port — read it back via [`NetServer::local_addr`]).
    fn bind(addr: &str) -> Result<(Listener, String, Option<String>)> {
        if let Some(path) = addr.strip_prefix("unix:") {
            #[cfg(unix)]
            {
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path)?;
                return Ok((Listener::Unix(l), addr.to_string(), Some(path.to_string())));
            }
            #[cfg(not(unix))]
            {
                return Err(Error::Config(format!(
                    "unix sockets are unavailable on this platform: {addr}"
                )));
            }
        }
        let l = TcpListener::bind(addr)?;
        let local = l.local_addr()?.to_string();
        Ok((Listener::Tcp(l), local, None))
    }

    fn set_nonblocking(&self) -> io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(true),
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(true),
        }
    }

    /// Non-blocking accept: `Ok(None)` when no peer is waiting.
    fn poll_accept(&self) -> io::Result<Option<Conn>> {
        let r = match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Conn::Tcp(s)),
            #[cfg(unix)]
            Listener::Unix(l) => l.accept().map(|(s, _)| Conn::Unix(s)),
        };
        match r {
            Ok(c) => Ok(Some(c)),
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => Ok(None),
            Err(e) => Err(e),
        }
    }
}

// ---------------------------------------------------------------------------
// NetServer: acceptor + per-connection thread pairs
// ---------------------------------------------------------------------------

/// Counter snapshot for the serving tier (drawn from the shared metrics).
#[derive(Debug, Clone, Copy, Default)]
pub struct ServerStats {
    pub connections: u64,
    pub conns_shed: u64,
    pub net_rejections: u64,
}

/// A running socket server in front of a [`Frontend`]. Sessions shard
/// across the front end's reactors exactly as in-process sessions do
/// (round-robin by session id), so `--reactors N` scales the socket tier
/// with no extra plumbing here.
pub struct NetServer {
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    metrics: Arc<AtomicMetrics>,
    local_addr: String,
    unix_path: Option<String>,
}

impl NetServer {
    /// Bind `addr` and start accepting. The front end's reactors must be
    /// running (see [`Frontend::spawn`]) or sessions will queue forever.
    pub fn bind<B>(
        addr: &str,
        front: Arc<Frontend<B>>,
        cfg: NetConfig,
        metrics: Arc<AtomicMetrics>,
    ) -> Result<NetServer>
    where
        B: Dispatch + Send + Sync + 'static,
    {
        cfg.validate()?;
        let (listener, local_addr, unix_path) = Listener::bind(addr)?;
        listener.set_nonblocking()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let (stop, conns, metrics) = (stop.clone(), conns.clone(), metrics.clone());
            std::thread::Builder::new()
                .name("overlay-acceptor".into())
                .spawn(move || accept_loop(listener, front, cfg, stop, conns, metrics))
                .map_err(Error::Io)?
        };
        Ok(NetServer {
            stop,
            accept: Some(accept),
            conns,
            metrics,
            local_addr,
            unix_path,
        })
    }

    /// The bound address: the actual `ip:port` for TCP (resolving `:0`),
    /// the `unix:<path>` string for Unix sockets.
    pub fn local_addr(&self) -> &str {
        &self.local_addr
    }

    /// True once a stop was requested (locally or by an authorized remote
    /// `SHUTDOWN` frame).
    pub fn stop_requested(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }

    /// Serving-tier counters so far.
    pub fn stats(&self) -> ServerStats {
        let m = self.metrics.snapshot();
        ServerStats {
            connections: m.connections,
            conns_shed: m.conns_shed,
            net_rejections: m.net_rejections,
        }
    }

    /// Ask the acceptor and every connection to wind down. Returns
    /// immediately; pair with [`NetServer::join`].
    pub fn request_stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }

    /// Block until the server stops: the acceptor exits once the stop
    /// flag is set (locally via [`NetServer::request_stop`], or remotely
    /// via an authorized `SHUTDOWN` frame), then every connection thread
    /// is joined. Connections notice the flag within one tick.
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles: Vec<_> = lock(&self.conns).drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        if let Some(p) = &self.unix_path {
            let _ = std::fs::remove_file(p);
        }
    }

    /// `request_stop` + `join`.
    pub fn stop(self) {
        self.request_stop();
        self.join();
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        // a dropped-without-join server must not pin its threads forever
        self.stop.store(true, Ordering::Relaxed);
    }
}

fn accept_loop<B>(
    listener: Listener,
    front: Arc<Frontend<B>>,
    cfg: NetConfig,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    metrics: Arc<AtomicMetrics>,
) where
    B: Dispatch + Send + Sync + 'static,
{
    while !stop.load(Ordering::Relaxed) {
        match listener.poll_accept() {
            Ok(Some(conn)) => serve_conn(conn, &front, &cfg, &stop, &conns, &metrics),
            // no peer waiting (or a transient accept error): nap one beat
            Ok(None) | Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Wire one accepted stream to a fresh session: a reader thread (frames
/// in, protocol decisions, submissions) and a writer thread (in-order
/// replies out). The reader owns the connection's fate; the writer exits
/// when the reader is done and the reply FIFO has drained.
fn serve_conn<B>(
    conn: Conn,
    front: &Arc<Frontend<B>>,
    cfg: &NetConfig,
    stop: &Arc<AtomicBool>,
    conns: &Arc<Mutex<Vec<JoinHandle<()>>>>,
    metrics: &Arc<AtomicMetrics>,
) where
    B: Dispatch + Send + Sync + 'static,
{
    let write_half = match conn.try_clone() {
        Ok(c) => Arc::new(Mutex::new(c)),
        Err(_) => return, // peer already gone
    };
    metrics.record(&Metrics { connections: 1, ..Default::default() });
    let (sub, replies) = front.open_session().split();
    let pending: Arc<Mutex<VecDeque<u64>>> = Arc::new(Mutex::new(VecDeque::new()));
    let reader_done = Arc::new(AtomicBool::new(false));

    let writer = {
        let (write_half, pending, reader_done) =
            (write_half.clone(), pending.clone(), reader_done.clone());
        std::thread::Builder::new()
            .name("overlay-net-w".into())
            .stack_size(CONN_STACK)
            .spawn(move || run_writer(replies, write_half, pending, reader_done))
    };
    let reader = {
        let (cfg, stop, metrics) = (cfg.clone(), stop.clone(), metrics.clone());
        std::thread::Builder::new()
            .name("overlay-net-r".into())
            .stack_size(CONN_STACK)
            .spawn(move || run_reader(conn, write_half, sub, pending, reader_done, stop, cfg, metrics))
    };
    // a failed spawn drops its closure: the submitter drop closes the
    // session, which disconnects the writer — nothing leaks
    let mut g = lock(conns);
    g.extend(writer.ok());
    g.extend(reader.ok());
}

#[allow(clippy::too_many_arguments)]
fn run_reader(
    mut stream: Conn,
    write_half: Arc<Mutex<Conn>>,
    sub: SessionSubmitter,
    pending: Arc<Mutex<VecDeque<u64>>>,
    reader_done: Arc<AtomicBool>,
    stop: Arc<AtomicBool>,
    cfg: NetConfig,
    metrics: Arc<AtomicMetrics>,
) {
    let start = Instant::now();
    let _ = stream.set_read_timeout(Some(Duration::from_millis(TICK_MS)));
    let mut dec = FrameDecoder::new(cfg.max_frame);
    let mut driver = ConnDriver::new(cfg, 0);
    let mut buf = [0u8; 8192];
    let mut shed = false;
    'conn: loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let now = start.elapsed().as_millis() as u64;
        if driver.idle_exceeded(now) {
            shed = true;
            break;
        }
        let k = match stream.read(&mut buf) {
            Ok(0) => {
                shed = dec.is_mid_frame();
                break;
            }
            Ok(k) => k,
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut | io::ErrorKind::Interrupted
                ) =>
            {
                continue;
            }
            Err(_) => {
                shed = true;
                break;
            }
        };
        dec.push(&buf[..k]);
        loop {
            let payload = match dec.next_frame() {
                Ok(Some(p)) => p,
                Ok(None) => break,
                Err(_) => {
                    shed = true;
                    break 'conn;
                }
            };
            let now = start.elapsed().as_millis() as u64;
            let pending_now = lock(&pending).len();
            match driver.on_frame(&payload, now, pending_now) {
                WireStep::Submit { id, request } => {
                    lock(&pending).push_back(id);
                    if sub.submit(request).is_err() {
                        // front end is shutting down: no completion will
                        // come, so take the id back and answer directly
                        lock(&pending).pop_back();
                        let msg = ServerMsg::Err { id, message: "server shutting down".into() };
                        let _ = send(&write_half, &msg);
                        break 'conn;
                    }
                }
                WireStep::Reject(msg) => {
                    metrics.record(&Metrics { net_rejections: 1, ..Default::default() });
                    if send(&write_half, &msg).is_err() {
                        shed = true;
                        break 'conn;
                    }
                }
                WireStep::Shutdown => {
                    stop.store(true, Ordering::Relaxed);
                    break 'conn;
                }
                WireStep::Shed(_reason) => {
                    shed = true;
                    break 'conn;
                }
            }
        }
    }
    if shed {
        metrics.record(&Metrics { conns_shed: 1, ..Default::default() });
    }
    reader_done.store(true, Ordering::Relaxed);
    // closing the session disconnects the reply stream, unblocking the
    // writer; in-flight completions are accounted late by the reactor
    drop(sub);
    let _ = stream.shutdown_both();
}

fn run_writer(
    replies: SessionReplies,
    write_half: Arc<Mutex<Conn>>,
    pending: Arc<Mutex<VecDeque<u64>>>,
    reader_done: Arc<AtomicBool>,
) {
    loop {
        match replies.recv_timeout(Duration::from_millis(TICK_MS)) {
            SessionRecv::Reply(result) => {
                // in-session replies arrive in submission order, so the
                // oldest pending wire id is this reply's id
                let Some(id) = lock(&pending).pop_front() else { return };
                let msg = match result {
                    Ok(resp) => ServerMsg::Ok {
                        id,
                        cached: resp.cached,
                        jit_nanos: (resp.jit_seconds * 1e9) as u64,
                        value: resp.run.output,
                    },
                    Err(Error::PoolBusy { .. }) => ServerMsg::Busy { id },
                    Err(e) => ServerMsg::Err { id, message: e.to_string() },
                };
                if send(&write_half, &msg).is_err() {
                    return;
                }
            }
            SessionRecv::Timeout => {
                if reader_done.load(Ordering::Relaxed) && lock(&pending).is_empty() {
                    return;
                }
            }
            SessionRecv::Disconnected => return,
        }
    }
}

fn send(write_half: &Mutex<Conn>, msg: &ServerMsg) -> io::Result<()> {
    let frame = msg.to_frame();
    write_frame(&mut *lock(write_half), &frame)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req_frame(id: u64, n: u32, seed: u64, pattern: &str) -> Vec<u8> {
        let f = ClientMsg::Request { id, n, seed, pattern: pattern.into() }.to_frame();
        f[4..].to_vec() // payload only, as the decoder hands it over
    }

    fn driver(cfg: NetConfig) -> ConnDriver {
        ConnDriver::new(cfg, 0)
    }

    #[test]
    fn driver_submits_a_valid_request_with_synthesized_inputs() {
        let mut d = driver(NetConfig::default());
        match d.on_frame(&req_frame(7, 64, 3, "vmul-reduce"), 10, 0) {
            WireStep::Submit { id, request } => {
                assert_eq!(id, 7);
                assert_eq!(request.inputs.len(), 2);
                assert_eq!(request.inputs[0].len(), 64);
                assert_ne!(request.inputs[0], request.inputs[1], "per-channel seeds differ");
            }
            other => panic!("expected Submit, got {other:?}"),
        }
    }

    #[test]
    fn driver_rejects_over_cap_and_bad_patterns_without_shedding() {
        let cfg = NetConfig { max_n: 128, max_pending_per_conn: 2, ..NetConfig::default() };
        let mut d = driver(cfg);
        assert!(matches!(
            d.on_frame(&req_frame(1, 129, 0, "vmul-reduce"), 0, 0),
            WireStep::Reject(ServerMsg::Err { id: 1, .. })
        ));
        assert!(matches!(
            d.on_frame(&req_frame(2, 64, 0, "map:add"), 0, 0),
            WireStep::Reject(ServerMsg::Err { id: 2, .. })
        ));
        // pending at the cap: BUSY, below it: Submit
        assert!(matches!(
            d.on_frame(&req_frame(3, 64, 0, "vmul-reduce"), 0, 2),
            WireStep::Reject(ServerMsg::Busy { id: 3 })
        ));
        assert!(matches!(
            d.on_frame(&req_frame(4, 64, 0, "vmul-reduce"), 0, 1),
            WireStep::Submit { id: 4, .. }
        ));
    }

    #[test]
    fn driver_idle_clock_resets_only_on_complete_frames() {
        let cfg = NetConfig { idle_timeout_ms: 100, ..NetConfig::default() };
        let mut d = driver(cfg);
        assert!(!d.idle_exceeded(99));
        assert!(d.idle_exceeded(100), "deadline is inclusive");
        // a frame at t=90 pushes the deadline to t=190
        let _ = d.on_frame(&req_frame(1, 8, 0, "vmul-reduce"), 90, 0);
        assert!(!d.idle_exceeded(189));
        assert!(d.idle_exceeded(190));
        // idle_timeout_ms == 0 disables the deadline entirely
        let d = driver(NetConfig { idle_timeout_ms: 0, ..NetConfig::default() });
        assert!(!d.idle_exceeded(u64::MAX));
    }

    #[test]
    fn driver_gates_remote_shutdown_on_config() {
        let payload = ClientMsg::Shutdown.to_frame()[4..].to_vec();
        let mut open = driver(NetConfig { allow_remote_shutdown: true, ..NetConfig::default() });
        assert!(matches!(open.on_frame(&payload, 0, 0), WireStep::Shutdown));
        let mut closed = driver(NetConfig::default());
        assert!(matches!(closed.on_frame(&payload, 0, 0), WireStep::Shed(_)));
    }

    #[test]
    fn driver_sheds_malformed_payloads() {
        let mut d = driver(NetConfig::default());
        assert!(matches!(d.on_frame(&[0x7F, 0, 1], 0, 0), WireStep::Shed(_)));
    }
}
