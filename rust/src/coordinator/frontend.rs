//! Event-driven session front end for the worker pool.
//!
//! The thread-per-client model costs one OS thread, one `mpsc` channel and
//! one blocked `recv` per outstanding request; at thousands of sessions
//! the serving layer — not the JIT — becomes the bottleneck. This module
//! replaces it with a reactor: a small, fixed set of reactor threads
//! (default 1) multiplexes many client sessions, polling **one shared
//! [`CompletionQueue`]** for every in-flight request instead of blocking
//! on per-request receivers — the epoll shape, with the completion queue
//! standing in for the readiness list.
//!
//! Each session is a small state machine
//!
//! ```text
//! Accepting → Queued → Dispatched → Replying → (Accepting | Closed)
//! ```
//!
//! holding its pending compositions: client submissions land in the
//! session's **inbox** (`Queued`), admission moves them into the backend
//! (`Dispatched`, via [`Dispatch::submit_async`] — a ticket, not a
//! receiver), and completions are reordered per session so replies reach
//! the client **in submission order** (`Replying`) even though bursts,
//! spills and steals complete out of order. A closed session delivers
//! nothing further; late completions are dropped and counted.
//!
//! Admission is controlled on two axes — per-session in-flight
//! (`FrontendConfig::inflight_per_session`, which also bounds the reorder
//! buffer) and front-end-wide in-flight (`FrontendConfig::max_inflight`) —
//! and folds into the pool's existing [`Error::PoolBusy`] backpressure: a
//! rejected admission stays queued in its inbox and is retried, counted in
//! `Metrics::admission_rejections`, never dropped. Between ready sessions
//! the reactor rotates a **readiness ring**, admitting one request per
//! session per turn, so a chatty session cannot starve quiet ones.
//!
//! Everything observable happens inside [`Reactor::poll_once`], which the
//! production loop ([`Frontend::spawn`]) calls from its own thread and the
//! deterministic test harness ([`crate::testkit`]) calls directly,
//! interleaved with a virtual-clock engine — so ordering, fairness and
//! starvation properties are checked without a single sleep.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Duration;

use super::pool::{CompletionQueue, Ticket};
use super::{AtomicMetrics, Metrics, Request, Response, WorkerPool};
use crate::config::FrontendConfig;
use crate::error::{Error, Result};

/// How long a reactor thread parks when a poll makes no progress. Client
/// submissions, completions, closes and shutdown all wake it explicitly;
/// the timeout only covers cross-reactor transitions (a shared in-flight
/// slot freed on another reactor's queue).
const REACTOR_PARK: Duration = Duration::from_millis(5);

/// Why [`Dispatch::submit_async`] did not accept a request.
#[derive(Debug)]
pub enum Rejected {
    /// Backpressure: the backend is saturated. The request is handed back
    /// untouched so the caller retries later without cloning it; nothing
    /// will ever complete for it.
    Busy(Request),
    /// Hard failure: the backend cannot serve this request, ever. The
    /// request is consumed and the error becomes its one reply.
    Failed(Error),
}

/// An async backend the reactor can dispatch admitted requests into.
///
/// [`WorkerPool`] is the production implementation;
/// [`crate::testkit::ScriptedEngine`] is the deterministic virtual-time
/// one the front-end test suite drives.
pub trait Dispatch {
    /// Non-blocking async submission: on success the reply arrives as a
    /// [`super::pool::Completion`] for the returned ticket on
    /// `completions`.
    fn submit_async(
        &self,
        request: Request,
        completions: &Arc<CompletionQueue>,
    ) -> std::result::Result<Ticket, Rejected>;
}

impl Dispatch for WorkerPool {
    fn submit_async(
        &self,
        request: Request,
        completions: &Arc<CompletionQueue>,
    ) -> std::result::Result<Ticket, Rejected> {
        self.submit_async_reclaim(request, completions).map_err(|(request, e)| match e {
            Error::PoolBusy { .. } => Rejected::Busy(request),
            other => Rejected::Failed(other),
        })
    }
}

/// Where a session currently is in its lifecycle. With requests in several
/// stages at once the *latest* stage wins: replies awaiting in-order
/// delivery (`Replying`) over work in the backend (`Dispatched`) over work
/// waiting for admission (`Queued`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionState {
    /// Idle: no pending work, waiting for the client.
    Accepting,
    /// Requests queued in the inbox, not yet admitted to the backend.
    Queued,
    /// Requests in flight in the backend.
    Dispatched,
    /// Completions buffered, waiting for an in-order delivery gap to fill.
    Replying,
    /// Closed by the client; nothing is delivered anymore.
    Closed,
}

/// One client session, owned by its reactor's table.
struct Session {
    /// In-order reply channel to the client; `None` once closed.
    out: Option<mpsc::Sender<Result<Response>>>,
    /// Submitted but not yet admitted: `(seq, request)` in arrival order.
    inbox: VecDeque<(u64, Request)>,
    /// Requests currently dispatched into the backend.
    inflight: usize,
    /// Completed out of submission order, awaiting their delivery gap.
    /// Bounded by `inflight_per_session`.
    ready: BTreeMap<u64, Result<Response>>,
    /// Next sequence number assigned at submit.
    next_seq: u64,
    /// Next sequence to deliver to the client.
    next_deliver: u64,
    /// Derived lifecycle label (see [`SessionState`]).
    state: SessionState,
    /// Currently a member of the readiness ring?
    ringed: bool,
}

impl Session {
    fn new(out: mpsc::Sender<Result<Response>>) -> Session {
        Session {
            out: Some(out),
            inbox: VecDeque::new(),
            inflight: 0,
            ready: BTreeMap::new(),
            next_seq: 0,
            next_deliver: 0,
            state: SessionState::Accepting,
            ringed: false,
        }
    }

    fn refresh_state(&mut self) {
        self.state = if self.out.is_none() {
            SessionState::Closed
        } else if !self.ready.is_empty() {
            SessionState::Replying
        } else if self.inflight > 0 {
            SessionState::Dispatched
        } else if !self.inbox.is_empty() {
            SessionState::Queued
        } else {
            SessionState::Accepting
        };
    }
}

/// One reactor's session table, behind its mutex.
struct Table {
    sessions: HashMap<u64, Session>,
    /// Ticket → (session, seq) for every request this reactor dispatched.
    inflight: HashMap<Ticket, (u64, u64)>,
    /// Readiness ring: sessions with admissible work, in fairness order.
    ring: VecDeque<u64>,
    /// Requests sitting in session inboxes (all sessions).
    queued_total: usize,
    /// Completions dropped undelivered because their session closed —
    /// arrived after the close, or sitting gap-buffered in the reorder
    /// buffer when the close cleared it. Per reactor,
    /// `delivered + late_replies == completions drained`.
    late_replies: u64,
    /// Set once by shutdown, under this lock: submissions observe it (and
    /// fail) in the same critical section where the reactor's exit
    /// decision reads the queue state, so an accepted request can never
    /// outlive the last poll.
    stopped: bool,
}

impl Table {
    fn ring_session(&mut self, sid: u64) {
        if let Some(s) = self.sessions.get_mut(&sid) {
            if !s.ringed && s.out.is_some() && !s.inbox.is_empty() {
                s.ringed = true;
                self.ring.push_back(sid);
            }
        }
    }
}

/// State shared by a reactor's thread, its session handles, and the
/// frontend that built it.
struct ReactorShared {
    /// The reactor's event source: worker completions plus bare wakeups
    /// from submits/closes/shutdown.
    completions: Arc<CompletionQueue>,
    table: Mutex<Table>,
}

impl ReactorShared {
    fn lock(&self) -> MutexGuard<'_, Table> {
        self.table.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Stop accepting submissions (idempotent) and wake the reactor.
    fn signal_stop(&self) {
        self.lock().stopped = true;
        self.completions.wake();
    }
}

/// What one [`Reactor::poll_once`] accomplished. Drives the run loop's
/// parking decision and the test harness's quiescence check.
#[derive(Debug, Default, Clone, Copy)]
pub struct PollStats {
    /// Completions drained from the shared queue.
    pub completions: usize,
    /// Replies delivered to clients in order.
    pub delivered: usize,
    /// Requests admitted into the backend.
    pub admitted: usize,
    /// Admissions deferred (caps or a busy backend).
    pub admission_rejections: usize,
    /// Requests still queued in session inboxes after the poll.
    pub queued: usize,
    /// Requests dispatched-but-uncompleted via this reactor after the poll.
    pub inflight: usize,
    /// Shutdown was requested, read in the same critical section as
    /// `queued`/`inflight` — together they form the run loop's consistent
    /// exit condition (no submission can slip between them).
    pub stopped: bool,
}

impl PollStats {
    /// Did this poll move anything?
    pub fn progressed(&self) -> bool {
        self.completions + self.delivered + self.admitted > 0
    }

    /// No progress and no outstanding work: the reactor is quiescent.
    ///
    /// This is the front end's view of the backend's *quiet window*: an
    /// idle reactor admits nothing, so pool workers see empty queues and
    /// spend the window on speculative maintenance
    /// ([`crate::coordinator::Coordinator::maintain`] — predictive
    /// prefetch and online defragmentation) instead of parking outright.
    pub fn idle(&self) -> bool {
        !self.progressed() && self.queued == 0 && self.inflight == 0
    }
}

/// A stepper over one reactor's event loop. The production thread calls
/// [`Reactor::run`]; deterministic tests call [`Reactor::poll_once`]
/// directly, interleaved with a scripted engine.
pub struct Reactor<B: Dispatch> {
    shared: Arc<ReactorShared>,
    backend: Arc<B>,
    metrics: Arc<AtomicMetrics>,
    cfg: FrontendConfig,
    /// Front-end-wide in-flight count, shared across reactors.
    total_inflight: Arc<AtomicUsize>,
}

impl<B: Dispatch> Reactor<B> {
    /// One full event-loop iteration: drain completions, admit queued work
    /// fairly, deliver in-order replies. Never blocks.
    pub fn poll_once(&self) -> PollStats {
        let mut stats = PollStats::default();
        let completed = self.shared.completions.drain();
        let mut guard = self.shared.lock();
        let t = &mut *guard;
        // sessions whose reorder buffer gained entries this poll — only
        // they can have become deliverable
        let mut touched: Vec<u64> = Vec::new();

        // 1) route completions to their sessions
        for c in completed {
            stats.completions += 1;
            let Some((sid, seq)) = t.inflight.remove(&c.ticket) else {
                continue; // foreign ticket: not ours, ignore
            };
            self.total_inflight.fetch_sub(1, Ordering::Relaxed);
            let Some(s) = t.sessions.get_mut(&sid) else {
                // defensive: a tracked ticket whose session vanished must
                // still be accounted, or the completion disappears with
                // neither a delivery nor a late count and the conservation
                // law `delivered + late_replies == completions` breaks
                t.late_replies += 1;
                continue;
            };
            s.inflight -= 1;
            if s.out.is_some() {
                s.ready.insert(seq, c.result);
                touched.push(sid);
            } else {
                t.late_replies += 1;
                if s.inflight == 0 {
                    t.sessions.remove(&sid);
                }
            }
        }

        // 2) admission with fairness rotation: one request per session per
        // ring turn, until every ready session is blocked or drained.
        // Freed in-flight slots from step 1 are already visible here.
        let mut blocked: Vec<u64> = Vec::new();
        while let Some(sid) = t.ring.pop_front() {
            let Some(s) = t.sessions.get_mut(&sid) else { continue };
            s.ringed = false;
            if s.out.is_none() || s.inbox.is_empty() {
                s.refresh_state();
                continue;
            }
            if s.inflight >= self.cfg.inflight_per_session {
                stats.admission_rejections += 1;
                blocked.push(sid);
                continue;
            }
            // reserve the front-end-wide slot atomically: a check-then-add
            // would let two reactors race past the cap together
            let reserved = self
                .total_inflight
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| {
                    (n < self.cfg.max_inflight).then_some(n + 1)
                })
                .is_ok();
            if !reserved {
                stats.admission_rejections += 1;
                blocked.push(sid);
                continue;
            }
            let (seq, request) = s.inbox.pop_front().expect("nonempty inbox");
            match self.backend.submit_async(request, &self.shared.completions) {
                Ok(ticket) => {
                    s.inflight += 1;
                    s.refresh_state();
                    let more = !s.inbox.is_empty();
                    if more {
                        s.ringed = true;
                    }
                    t.queued_total -= 1;
                    t.inflight.insert(ticket, (sid, seq));
                    stats.admitted += 1;
                    if more {
                        t.ring.push_back(sid); // fairness: back of the line
                    }
                }
                Err(Rejected::Busy(request)) => {
                    self.total_inflight.fetch_sub(1, Ordering::Relaxed);
                    s.inbox.push_front((seq, request));
                    s.refresh_state();
                    stats.admission_rejections += 1;
                    blocked.push(sid);
                }
                Err(Rejected::Failed(e)) => {
                    self.total_inflight.fetch_sub(1, Ordering::Relaxed);
                    // the request is consumed: the error is its one reply,
                    // delivered in order like any completion
                    s.ready.insert(seq, Err(e));
                    s.refresh_state();
                    let more = !s.inbox.is_empty();
                    if more {
                        s.ringed = true;
                    }
                    t.queued_total -= 1;
                    touched.push(sid);
                    if more {
                        t.ring.push_back(sid);
                    }
                }
            }
        }
        // blocked sessions rejoin the ring (in order) for the next poll
        for sid in blocked {
            if let Some(s) = t.sessions.get_mut(&sid) {
                if !s.ringed {
                    s.ringed = true;
                    t.ring.push_back(sid);
                }
            }
        }

        // 3) in-order delivery for sessions whose buffers changed
        for sid in touched {
            let Some(s) = t.sessions.get_mut(&sid) else { continue };
            while let Some(result) = s.ready.remove(&s.next_deliver) {
                s.next_deliver += 1;
                stats.delivered += 1;
                if let Some(out) = &s.out {
                    // a hung-up client is not a reactor error
                    let _ = out.send(result);
                }
            }
            s.refresh_state();
        }

        stats.queued = t.queued_total;
        stats.inflight = t.inflight.len();
        stats.stopped = t.stopped;
        drop(guard);
        self.metrics.record(&Metrics {
            completions: stats.completions as u64,
            reactor_polls: 1,
            admission_rejections: stats.admission_rejections as u64,
            ..Default::default()
        });
        stats
    }

    /// The production event loop: poll, park when idle, exit once stopped
    /// *and* drained. `stopped`/`queued`/`inflight` come from one critical
    /// section, and submissions check `stopped` under the same lock — so a
    /// request either lands before the exit-deciding poll (which then sees
    /// it queued) or is rejected; none can be accepted and never served.
    pub fn run(&self) {
        loop {
            let stats = self.poll_once();
            if stats.stopped && stats.queued == 0 && stats.inflight == 0 {
                return;
            }
            if !stats.progressed() {
                self.shared.completions.wait(REACTOR_PARK);
            }
        }
    }

    /// Completions dropped undelivered because their session closed.
    pub fn late_replies(&self) -> u64 {
        self.shared.lock().late_replies
    }

    /// Sessions currently tracked by this reactor (closed sessions linger
    /// only while they still have requests in flight).
    pub fn session_count(&self) -> usize {
        self.shared.lock().sessions.len()
    }
}

/// A client's handle to one session: submit requests, receive replies in
/// submission order, close. Handles are independent — one per client —
/// and their cost is one channel per *session*, not per request.
///
/// Dropping the handle (or its [`SessionSubmitter`] half after
/// [`SessionHandle::split`]) closes the session — a client that walks away
/// without calling [`SessionHandle::close`] must not leak its session in
/// the reactor table forever, silently "delivering" every future
/// completion into a disconnected channel.
pub struct SessionHandle {
    sub: SessionSubmitter,
    replies: mpsc::Receiver<Result<Response>>,
}

impl SessionHandle {
    /// This session's id (unique within its front end).
    pub fn id(&self) -> u64 {
        self.sub.id()
    }

    /// Queue one request. Returns an error if the session is closed or the
    /// front end is shutting down; otherwise the request WILL get exactly
    /// one reply, in submission order.
    pub fn submit(&self, request: Request) -> Result<()> {
        self.sub.submit(request)
    }

    /// Block for the next in-order reply. Errors when the session's reply
    /// stream is gone (closed, or the front end shut down).
    pub fn recv(&self) -> Result<Response> {
        self.replies
            .recv()
            .map_err(|_| Error::Runtime("front end dropped the session".into()))?
    }

    /// Non-blocking receive: `None` when nothing is currently deliverable.
    pub fn try_recv(&self) -> Option<Result<Response>> {
        self.replies.try_recv().ok()
    }

    /// The session's current lifecycle state (`Closed` once it is gone).
    pub fn state(&self) -> SessionState {
        self.sub.state()
    }

    /// Close the session: pending inbox requests are cancelled, in-flight
    /// completions are dropped on arrival (counted as late replies), and
    /// nothing is delivered anymore — the reply stream disconnects.
    pub fn close(&self) {
        self.sub.close()
    }

    /// Split into independent submit and receive halves, so one thread can
    /// feed the session while another blocks on its replies (the socket
    /// tier's reader/writer pair). Closing remains tied to the submit
    /// half: dropping the [`SessionSubmitter`] closes the session, which
    /// disconnects the reply half and unblocks its `recv`.
    pub fn split(self) -> (SessionSubmitter, SessionReplies) {
        let SessionHandle { sub, replies } = self;
        (sub, SessionReplies { replies })
    }
}

/// The submit half of a split [`SessionHandle`]: queue requests, observe
/// state, close. Owns the session's lifetime — dropping it closes the
/// session.
pub struct SessionSubmitter {
    id: u64,
    shared: Arc<ReactorShared>,
}

impl SessionSubmitter {
    /// This session's id (unique within its front end).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Queue one request (see [`SessionHandle::submit`]).
    pub fn submit(&self, request: Request) -> Result<()> {
        let mut guard = self.shared.lock();
        let t = &mut *guard;
        // checked under the table lock: either this request lands before
        // the reactor's exit-deciding poll (which then sees it queued and
        // serves it), or it is rejected here — never accepted-and-dropped
        if t.stopped {
            return Err(Error::Runtime("front end is shutting down".into()));
        }
        let s = t
            .sessions
            .get_mut(&self.id)
            .ok_or_else(|| Error::Runtime("session is closed".into()))?;
        if s.out.is_none() {
            return Err(Error::Runtime("session is closed".into()));
        }
        let seq = s.next_seq;
        s.next_seq += 1;
        s.inbox.push_back((seq, request));
        s.refresh_state();
        t.queued_total += 1;
        t.ring_session(self.id);
        drop(guard);
        self.shared.completions.wake();
        Ok(())
    }

    /// The session's current lifecycle state (`Closed` once it is gone).
    pub fn state(&self) -> SessionState {
        self.shared
            .lock()
            .sessions
            .get(&self.id)
            .map(|s| s.state)
            .unwrap_or(SessionState::Closed)
    }

    /// Close the session (idempotent; see [`SessionHandle::close`]).
    pub fn close(&self) {
        let mut guard = self.shared.lock();
        let t = &mut *guard;
        if let Some(s) = t.sessions.get_mut(&self.id) {
            s.out = None;
            t.queued_total -= s.inbox.len();
            s.inbox.clear();
            // gap-buffered completions die undelivered with the session:
            // account them, or delivered + late would undercount drains
            t.late_replies += s.ready.len() as u64;
            s.ready.clear();
            s.refresh_state();
            if s.inflight == 0 {
                t.sessions.remove(&self.id);
            }
        }
        drop(guard);
        self.shared.completions.wake();
    }
}

impl Drop for SessionSubmitter {
    fn drop(&mut self) {
        self.close();
    }
}

/// What [`SessionReplies::recv_timeout`] observed. A delivered
/// per-request error ([`SessionRecv::Reply`] holding `Err`) is that one
/// request's reply; [`SessionRecv::Disconnected`] means the session itself
/// is gone — the two must not be conflated, or a serving tier would
/// misreport a dead session as a request failure.
pub enum SessionRecv {
    /// One in-order reply: the request's response, or its error.
    Reply(Result<Response>),
    /// Nothing became deliverable within the timeout.
    Timeout,
    /// The session is gone (closed, or the front end shut down).
    Disconnected,
}

/// The receive half of a split [`SessionHandle`].
pub struct SessionReplies {
    replies: mpsc::Receiver<Result<Response>>,
}

impl SessionReplies {
    /// Block for the next in-order reply (see [`SessionHandle::recv`]).
    pub fn recv(&self) -> Result<Response> {
        self.replies
            .recv()
            .map_err(|_| Error::Runtime("front end dropped the session".into()))?
    }

    /// Block up to `timeout` for the next in-order reply.
    pub fn recv_timeout(&self, timeout: Duration) -> SessionRecv {
        match self.replies.recv_timeout(timeout) {
            Ok(r) => SessionRecv::Reply(r),
            Err(mpsc::RecvTimeoutError::Timeout) => SessionRecv::Timeout,
            Err(mpsc::RecvTimeoutError::Disconnected) => SessionRecv::Disconnected,
        }
    }

    /// Non-blocking receive: `None` when nothing is currently deliverable.
    pub fn try_recv(&self) -> Option<Result<Response>> {
        self.replies.try_recv().ok()
    }
}

/// The session front end: builds sessions, hands out reactor steppers, and
/// spawns the production reactor threads.
pub struct Frontend<B: Dispatch> {
    backend: Arc<B>,
    cfg: FrontendConfig,
    metrics: Arc<AtomicMetrics>,
    reactors: Vec<Arc<ReactorShared>>,
    total_inflight: Arc<AtomicUsize>,
    next_session: AtomicU64,
}

impl<B: Dispatch> Frontend<B> {
    /// Build a front end over `backend`. `metrics` receives the reactor
    /// counters (sessions, completions, polls, admission rejections) — pass
    /// the pool's own aggregate to fold them into one snapshot.
    pub fn new(
        backend: Arc<B>,
        cfg: FrontendConfig,
        metrics: Arc<AtomicMetrics>,
    ) -> Result<Frontend<B>> {
        cfg.validate()?;
        let reactors = (0..cfg.reactors)
            .map(|_| {
                Arc::new(ReactorShared {
                    completions: Arc::new(CompletionQueue::new()),
                    table: Mutex::new(Table {
                        sessions: HashMap::new(),
                        inflight: HashMap::new(),
                        ring: VecDeque::new(),
                        queued_total: 0,
                        late_replies: 0,
                        stopped: false,
                    }),
                })
            })
            .collect();
        Ok(Frontend {
            backend,
            cfg,
            metrics,
            reactors,
            total_inflight: Arc::new(AtomicUsize::new(0)),
            next_session: AtomicU64::new(0),
        })
    }

    /// Open a session, assigned round-robin to a reactor.
    pub fn open_session(&self) -> SessionHandle {
        let id = self.next_session.fetch_add(1, Ordering::Relaxed);
        let shared = self.reactors[(id % self.reactors.len() as u64) as usize].clone();
        let (tx, rx) = mpsc::channel();
        shared.lock().sessions.insert(id, Session::new(tx));
        self.metrics.record(&Metrics { sessions: 1, ..Default::default() });
        SessionHandle { sub: SessionSubmitter { id, shared }, replies: rx }
    }

    /// A stepper for reactor `i` (deterministic tests drive this directly).
    pub fn reactor(&self, i: usize) -> Reactor<B> {
        Reactor {
            shared: self.reactors[i].clone(),
            backend: self.backend.clone(),
            metrics: self.metrics.clone(),
            cfg: self.cfg.clone(),
            total_inflight: self.total_inflight.clone(),
        }
    }

    /// Number of reactors.
    pub fn reactor_count(&self) -> usize {
        self.reactors.len()
    }

    /// Completions dropped undelivered because their session closed,
    /// summed across reactors.
    pub fn late_replies(&self) -> u64 {
        self.reactors.iter().map(|r| r.lock().late_replies).sum()
    }

    /// Spawn one thread per reactor; the returned handle shuts them down.
    pub fn spawn(&self) -> Result<FrontendThreads>
    where
        B: Send + Sync + 'static,
    {
        let mut handles = Vec::with_capacity(self.reactors.len());
        for i in 0..self.reactors.len() {
            let reactor = self.reactor(i);
            let spawned = std::thread::Builder::new()
                .name(format!("overlay-reactor-{i}"))
                .spawn(move || reactor.run())
                .map_err(Error::from);
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // stop the reactors already running before surfacing
                    for r in &self.reactors {
                        r.signal_stop();
                    }
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(e);
                }
            }
        }
        Ok(FrontendThreads { shareds: self.reactors.clone(), handles })
    }
}

/// Running reactor threads. Dropping without [`FrontendThreads::shutdown`]
/// still stops the reactors (without joining them).
pub struct FrontendThreads {
    shareds: Vec<Arc<ReactorShared>>,
    handles: Vec<JoinHandle<()>>,
}

impl FrontendThreads {
    /// Stop accepting new submissions, drain what is queued and in flight,
    /// and join every reactor thread.
    pub fn shutdown(mut self) {
        self.signal_stop();
        for h in std::mem::take(&mut self.handles) {
            let _ = h.join();
        }
    }

    fn signal_stop(&self) {
        for r in &self.shareds {
            r.signal_stop();
        }
    }
}

impl Drop for FrontendThreads {
    fn drop(&mut self) {
        self.signal_stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OverlayConfig;
    use crate::patterns::Composition;
    use crate::testkit::ScriptedEngine;
    use crate::workload;

    fn vmul_req(n: usize, seed: u64) -> Request {
        Request::dynamic(
            Composition::vmul_reduce(n),
            vec![workload::vector(n, seed, 0.1, 1.0), workload::vector(n, seed + 1, 0.1, 1.0)],
        )
    }

    fn front(
        capacity: usize,
        cfg: FrontendConfig,
    ) -> (Frontend<ScriptedEngine>, Reactor<ScriptedEngine>, Arc<ScriptedEngine>) {
        let engine = Arc::new(
            ScriptedEngine::constant(OverlayConfig::default(), capacity, 1).unwrap(),
        );
        let fe =
            Frontend::new(engine.clone(), cfg, Arc::new(AtomicMetrics::default())).unwrap();
        let reactor = fe.reactor(0);
        (fe, reactor, engine)
    }

    #[test]
    fn invalid_config_is_rejected_at_construction() {
        let engine =
            Arc::new(ScriptedEngine::constant(OverlayConfig::default(), 4, 1).unwrap());
        let cfg = FrontendConfig { reactors: 0, ..Default::default() };
        assert!(Frontend::new(engine, cfg, Arc::new(AtomicMetrics::default())).is_err());
    }

    #[test]
    fn submit_after_close_errors_and_close_is_idempotent() {
        let (fe, reactor, _engine) = front(4, FrontendConfig::default());
        let s = fe.open_session();
        assert_eq!(s.state(), SessionState::Accepting);
        s.close();
        s.close();
        assert_eq!(s.state(), SessionState::Closed);
        assert!(s.submit(vmul_req(64, 1)).is_err());
        assert!(reactor.poll_once().idle());
        assert_eq!(reactor.session_count(), 0);
    }

    #[test]
    fn sessions_partition_round_robin_across_reactors() {
        let engine =
            Arc::new(ScriptedEngine::constant(OverlayConfig::default(), 4, 1).unwrap());
        let cfg = FrontendConfig { reactors: 2, ..Default::default() };
        let fe = Frontend::new(engine, cfg, Arc::new(AtomicMetrics::default())).unwrap();
        assert_eq!(fe.reactor_count(), 2);
        let handles: Vec<SessionHandle> = (0..4).map(|_| fe.open_session()).collect();
        for h in &handles {
            h.submit(vmul_req(64, h.id())).unwrap();
        }
        // each reactor sees exactly its own two sessions
        assert_eq!(fe.reactor(0).session_count(), 2);
        assert_eq!(fe.reactor(1).session_count(), 2);
        // ... and the other reactor's poll never touches them
        let stats = fe.reactor(0).poll_once();
        assert_eq!(stats.admitted, 2);
    }
}
