//! Deterministic workload generation for examples, benches and tests.
//!
//! Ships its own splitmix64-seeded xoshiro256++ generator so the crate
//! builds offline without the `rand` family; the streams are stable across
//! platforms and runs (required: EXPERIMENTS.md records exact values).

/// xoshiro256++ PRNG (public-domain reference algorithm).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so any u64 (including 0) yields a good state.
    pub fn new(seed: u64) -> Rng {
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) / (1u32 << 24) as f32
    }

    /// Uniform f32 in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform usize in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }
}

/// Base seed for property-style tests: `$JIT_OVERLAY_SEED` when set (the
/// CI seed matrix), else `default`. Tests mix it into their own fixed
/// stream seeds, so every matrix entry explores a distinct deterministic
/// universe and failures still reproduce exactly (re-run with the same
/// env).
pub fn env_seed(default: u64) -> u64 {
    std::env::var("JIT_OVERLAY_SEED")
        .ok()
        .and_then(|s| s.trim().parse::<u64>().ok())
        .unwrap_or(default)
}

/// A reproducible random f32 vector in `[lo, hi)`.
pub fn vector(n: usize, seed: u64, lo: f32, hi: f32) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.range(lo, hi)).collect()
}

/// The paper's Fig. 3 workload: two 16 KB operand vectors (4096 × f32).
pub fn paper_16kb(seed: u64) -> (Vec<f32>, Vec<f32>) {
    (vector(4096, seed, -2.0, 2.0), vector(4096, seed + 1, -2.0, 2.0))
}

/// Data sizes for the PR-amortization sweep (bytes per operand).
pub const SWEEP_SIZES: [usize; 5] = [1024, 4096, 16384, 65536, 262144];

/// Double-precision reference dot product (ground truth for tolerances).
pub fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum()
}

// ---------------------------------------------------------------------------
// Service request streams
// ---------------------------------------------------------------------------

use crate::bitstream::OperatorKind;
use crate::patterns::Composition;

/// The skewed composition mix a service bench drives the coordinator with:
/// 80% of requests repeat one of four "hot" compositions (where affinity
/// scheduling and both caches should win), 20% draw from a "cold" tail of
/// distinct pipelines (which forces JIT compiles and PR churn).
pub fn mixed_compositions(count: usize, n: usize, seed: u64) -> Vec<Composition> {
    use OperatorKind::*;
    let hot = [
        Composition::vmul_reduce(n),
        Composition::map(Sqrt, n),
        Composition::filter_reduce(0.25, n),
        Composition::axpy(1.5, n),
    ];
    let cold = [
        Composition::chain(&[Abs, Square], n).expect("static chain"),
        Composition::chain(&[Neg, Abs, Relu], n).expect("static chain"),
        Composition::map(Exp, n),
        Composition::chain(&[Square, Neg], n).expect("static chain"),
    ];
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| {
            if rng.below(10) < 8 {
                hot[rng.below(hot.len())].clone()
            } else {
                cold[rng.below(cold.len())].clone()
            }
        })
        .collect()
}

/// Deterministic input channels for one request of a stream (`k` is the
/// request index — every request gets distinct data). The 0.1..2.0 domain
/// is safe for every operator in the mixed stream (sqrt, exp, ...).
pub fn request_inputs(comp: &Composition, k: u64) -> Vec<Vec<f32>> {
    (0..comp.inputs)
        .map(|c| vector(comp.n, k.wrapping_mul(31).wrapping_add(c as u64), 0.1, 2.0))
        .collect()
}

/// Chaos-soak stream: `count` compositions round-robining the four hot
/// compositions of [`mixed_compositions`] (no cold tail, no randomness).
/// Every key repeats `count/4` times, so a fault injected at any ordinal
/// is always followed by clean repeats of the same composition — the
/// pattern the recovery ladder's quarantine/re-place and residency
/// re-validation rungs are exercised against in the soak tests.
pub fn soak_compositions(count: usize, n: usize) -> Vec<Composition> {
    use OperatorKind::*;
    let hot = [
        Composition::vmul_reduce(n),
        Composition::map(Sqrt, n),
        Composition::filter_reduce(0.25, n),
        Composition::axpy(1.5, n),
    ];
    (0..count).map(|i| hot[i % hot.len()].clone()).collect()
}

/// Spill-heavy stream: `distinct` small compositions (distinct cache keys,
/// 1–2 tiles each) drawn uniformly at random. With many keys and a low
/// `max_queue_skew`, affinity routing constantly migrates compositions
/// between fabrics, so nearly every landing is a spill — the worst case
/// for a pool-wide placement cache and the workload that makes placement
/// respecialization (and the clobbers it avoids) visible in the bench
/// series.
pub fn spill_heavy_compositions(count: usize, distinct: usize, seed: u64) -> Vec<Composition> {
    use OperatorKind::*;
    let unary = [Abs, Neg, Square, Relu];
    let pool: Vec<Composition> = (0..distinct.max(1))
        .map(|i| {
            let n = 64 * (1 + i % 8); // distinct n ⇒ distinct cache keys per op mix
            match i % 3 {
                0 => Composition::map(unary[i / 3 % unary.len()], n),
                1 => Composition::vmul_reduce(n),
                _ => Composition::chain(&[unary[i % unary.len()], unary[(i + 1) % unary.len()]], n)
                    .expect("static chain"),
            }
        })
        .collect();
    let mut rng = Rng::new(seed);
    (0..count).map(|_| pool[rng.below(pool.len())].clone()).collect()
}

/// `distinct` small compositions with *guaranteed* pairwise-distinct cache
/// keys, in a fixed order (no RNG — the cohort is the same in every
/// process). Unlike [`spill_heavy_compositions`]'s pool, which only makes
/// distinctness likely, candidates here are filtered on their actual
/// `cache_key`, so tests may assert exact compile counts: serving the
/// cohort once on a cold service costs exactly `distinct` JIT compiles.
/// Every member fits Small regions (1–2 tiles), so the cohort routes
/// freely on shape-aware clusters.
pub fn wide_cohort(distinct: usize) -> Vec<Composition> {
    use OperatorKind::*;
    let unary = [Abs, Neg, Square, Relu];
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::with_capacity(distinct);
    let mut i = 0usize;
    while out.len() < distinct {
        let n = 64 + 8 * i; // strictly increasing n ⇒ unbounded key space
        let comp = match i % 3 {
            0 => Composition::map(unary[i / 3 % unary.len()], n),
            1 => Composition::vmul_reduce(n),
            _ => Composition::chain(&[unary[i % unary.len()], unary[(i + 1) % unary.len()]], n)
                .expect("static chain"),
        };
        if seen.insert(comp.cache_key()) {
            out.push(comp);
        }
        i += 1;
    }
    out
}

/// Pool-churn stream: the cluster-lifecycle workload. The 80/20 hot/cold
/// mix of [`mixed_compositions`] with every fifth request replaced by a
/// key from a 16-member [`wide_cohort`], cycling — so a cluster serving
/// it exercises both sticky arcs (hot keys keep their owners across
/// membership changes) and warm-start (by mid-stream the cohort keys are
/// cached cluster-wide, ready to ship to a joiner). Deterministic in
/// `seed`.
pub fn churn_compositions(count: usize, n: usize, seed: u64) -> Vec<Composition> {
    let cohort = wide_cohort(16);
    mixed_compositions(count, n, seed)
        .into_iter()
        .enumerate()
        .map(|(i, c)| if i % 5 == 4 { cohort[(i / 5) % cohort.len()].clone() } else { c })
        .collect()
}

/// Three distinct 5-stage chains. On the default 9-tile fabric any two of
/// them cannot co-reside (5 + 5 > 9 tiles), so switching between them
/// forces whole-fabric eviction + re-download — the adversarial case the
/// burst drainer and the affinity scheduler exist to amortize.
pub fn conflicting_chains(n: usize) -> [Composition; 3] {
    use OperatorKind::*;
    [
        Composition::chain(&[Neg, Abs, Square, Relu, Neg], n).expect("static chain"),
        Composition::chain(&[Abs, Neg, Relu, Square, Abs], n).expect("static chain"),
        Composition::chain(&[Relu, Square, Abs, Neg, Relu], n).expect("static chain"),
    ]
}

/// Adversarial round-robin interleaving: `A,B,C,A,B,C,...` for `rounds`
/// cycles over `comps`. Served FIFO on one fabric this thrashes the PR
/// regions on every request; a reconfiguration-aware drain regroups it to
/// one reconfiguration per composition group per window.
pub fn interleaved_stream(comps: &[Composition], rounds: usize) -> Vec<Composition> {
    (0..rounds * comps.len()).map(|i| comps[i % comps.len()].clone()).collect()
}

/// Two conflicting chains whose composition keys are congruent mod
/// `modulus` — on a pool of `modulus` workers (or any divisor of it) both
/// hash to the *same* home, so an interleaved stream of the pair actually
/// contends for one fabric instead of hashing apart. Scans 48 workload
/// lengths × the three chain pairs; `None` is astronomically unlikely
/// (≈ (1−1/m)^144) and impossible for `modulus = 2` (pigeonhole over
/// three keys).
pub fn home_aligned_conflicting_pair(modulus: u64) -> Option<(Composition, Composition)> {
    for i in 0..48usize {
        let n = 512 + 32 * i;
        let [a, b, c] = conflicting_chains(n);
        for (x, y) in [(&a, &b), (&a, &c), (&b, &c)] {
            if x.cache_key() % modulus == y.cache_key() % modulus {
                return Some((x.clone(), y.clone()));
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vectors_are_deterministic() {
        assert_eq!(vector(64, 7, 0.0, 1.0), vector(64, 7, 0.0, 1.0));
        assert_ne!(vector(64, 7, 0.0, 1.0), vector(64, 8, 0.0, 1.0));
    }

    #[test]
    fn ranges_respected() {
        for v in vector(10_000, 1, -0.5, 0.5) {
            assert!((-0.5..0.5).contains(&v));
        }
    }

    #[test]
    fn rough_uniformity() {
        let v = vector(100_000, 3, 0.0, 1.0);
        let mean = v.iter().sum::<f32>() / v.len() as f32;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
        let below_half = v.iter().filter(|&&x| x < 0.5).count();
        assert!((below_half as f64 / v.len() as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn zero_seed_is_fine() {
        let mut r = Rng::new(0);
        let a = r.next_u64();
        let b = r.next_u64();
        assert_ne!(a, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn paper_workload_is_16kb_per_operand() {
        let (a, b) = paper_16kb(0);
        assert_eq!(a.len() * 4, 16 * 1024);
        assert_eq!(b.len() * 4, 16 * 1024);
    }

    #[test]
    fn mixed_stream_is_deterministic_and_skewed() {
        let a = mixed_compositions(200, 256, 42);
        let b = mixed_compositions(200, 256, 42);
        assert_eq!(a.len(), 200);
        let keys_a: Vec<u64> = a.iter().map(|c| c.cache_key()).collect();
        let keys_b: Vec<u64> = b.iter().map(|c| c.cache_key()).collect();
        assert_eq!(keys_a, keys_b, "stream must be reproducible");
        // skew: the four hot compositions dominate
        let hot_keys: std::collections::HashSet<u64> = [
            Composition::vmul_reduce(256).cache_key(),
            Composition::map(OperatorKind::Sqrt, 256).cache_key(),
            Composition::filter_reduce(0.25, 256).cache_key(),
            Composition::axpy(1.5, 256).cache_key(),
        ]
        .into_iter()
        .collect();
        let hot_count = keys_a.iter().filter(|k| hot_keys.contains(k)).count();
        assert!(hot_count > 140 && hot_count < 190, "hot share was {hot_count}/200");
    }

    #[test]
    fn soak_stream_round_robins_the_hot_mix() {
        let s = soak_compositions(12, 128);
        assert_eq!(s.len(), 12);
        let keys: Vec<u64> = s.iter().map(|c| c.cache_key()).collect();
        let distinct: std::collections::HashSet<u64> = keys.iter().copied().collect();
        assert_eq!(distinct.len(), 4, "exactly the four hot compositions");
        // strict round-robin: the cycle repeats with period 4
        for (i, k) in keys.iter().enumerate() {
            assert_eq!(*k, keys[i % 4]);
        }
        let again: Vec<u64> =
            soak_compositions(12, 128).iter().map(|c| c.cache_key()).collect();
        assert_eq!(keys, again);
    }

    #[test]
    fn spill_heavy_stream_is_deterministic_and_wide() {
        let comps = spill_heavy_compositions(200, 16, 7);
        assert_eq!(comps.len(), 200);
        let keys: std::collections::HashSet<u64> =
            comps.iter().map(|c| c.cache_key()).collect();
        assert!(keys.len() >= 12, "want a wide key set, got {}", keys.len());
        let again = spill_heavy_compositions(200, 16, 7);
        assert_eq!(
            comps.iter().map(|c| c.cache_key()).collect::<Vec<_>>(),
            again.iter().map(|c| c.cache_key()).collect::<Vec<_>>(),
            "stream must be reproducible"
        );
    }

    #[test]
    fn wide_cohort_keys_are_distinct_and_deterministic() {
        let a = wide_cohort(64);
        assert_eq!(a.len(), 64);
        let keys: std::collections::HashSet<u64> = a.iter().map(|c| c.cache_key()).collect();
        assert_eq!(keys.len(), 64, "cache keys must be pairwise distinct — guaranteed");
        let again: Vec<u64> = wide_cohort(64).iter().map(|c| c.cache_key()).collect();
        assert_eq!(a.iter().map(|c| c.cache_key()).collect::<Vec<_>>(), again);
        // a smaller cohort is a strict prefix: tests of different sizes
        // share keys, so caches warmed by one cover the other
        let small: Vec<u64> = wide_cohort(8).iter().map(|c| c.cache_key()).collect();
        assert_eq!(small, again[..8]);
    }

    #[test]
    fn churn_stream_is_deterministic_and_mixes_cohort_keys() {
        let a = churn_compositions(100, 256, 9);
        assert_eq!(a.len(), 100);
        let ka: Vec<u64> = a.iter().map(|c| c.cache_key()).collect();
        let kb: Vec<u64> = churn_compositions(100, 256, 9).iter().map(|c| c.cache_key()).collect();
        assert_eq!(ka, kb, "stream must be reproducible");
        let cohort: std::collections::HashSet<u64> =
            wide_cohort(16).iter().map(|c| c.cache_key()).collect();
        // every fifth slot carries a cohort key; the rest is the hot mix
        for (i, k) in ka.iter().enumerate() {
            if i % 5 == 4 {
                assert!(cohort.contains(k), "slot {i} must be a cohort key");
            }
        }
        assert!(ka.iter().any(|k| !cohort.contains(k)), "the hot mix must survive");
    }

    #[test]
    fn conflicting_chains_are_distinct_and_oversized_pairwise() {
        let chains = conflicting_chains(256);
        let keys: std::collections::HashSet<u64> =
            chains.iter().map(|c| c.cache_key()).collect();
        assert_eq!(keys.len(), 3, "chains must have distinct cache keys");
        for c in &chains {
            assert_eq!(c.stages().len(), 5, "two 5-stage chains must overflow 9 tiles");
            assert_eq!(c.inputs, 1);
        }
    }

    #[test]
    fn home_aligned_pair_is_aligned_and_conflicting() {
        for workers in [2u64, 4, 8] {
            let (a, b) =
                home_aligned_conflicting_pair(workers).expect("alignment search must succeed");
            assert_eq!(a.cache_key() % workers, b.cache_key() % workers);
            assert_ne!(a.cache_key(), b.cache_key());
            assert_eq!(a.stages().len() + b.stages().len(), 10, "pair must overflow 9 tiles");
        }
    }

    #[test]
    fn interleaved_stream_round_robins() {
        let chains = conflicting_chains(128);
        let s = interleaved_stream(&chains, 4);
        assert_eq!(s.len(), 12);
        for (i, comp) in s.iter().enumerate() {
            assert_eq!(comp.cache_key(), chains[i % 3].cache_key());
        }
        // adjacent requests always conflict — the worst case for FIFO
        for w in s.windows(2) {
            assert_ne!(w[0].cache_key(), w[1].cache_key());
        }
    }

    #[test]
    fn request_inputs_match_composition_shape() {
        for comp in mixed_compositions(20, 128, 7) {
            let inputs = request_inputs(&comp, 3);
            assert_eq!(inputs.len(), comp.inputs as usize);
            for ch in &inputs {
                assert_eq!(ch.len(), 128);
                assert!(ch.iter().all(|v| (0.1..2.0).contains(v)));
            }
        }
    }
}
