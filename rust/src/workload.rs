//! Deterministic workload generation for examples, benches and tests.
//!
//! Ships its own splitmix64-seeded xoshiro256++ generator so the crate
//! builds offline without the `rand` family; the streams are stable across
//! platforms and runs (required: EXPERIMENTS.md records exact values).

/// xoshiro256++ PRNG (public-domain reference algorithm).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so any u64 (including 0) yields a good state.
    pub fn new(seed: u64) -> Rng {
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) / (1u32 << 24) as f32
    }

    /// Uniform f32 in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform usize in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }
}

/// A reproducible random f32 vector in `[lo, hi)`.
pub fn vector(n: usize, seed: u64, lo: f32, hi: f32) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.range(lo, hi)).collect()
}

/// The paper's Fig. 3 workload: two 16 KB operand vectors (4096 × f32).
pub fn paper_16kb(seed: u64) -> (Vec<f32>, Vec<f32>) {
    (vector(4096, seed, -2.0, 2.0), vector(4096, seed + 1, -2.0, 2.0))
}

/// Data sizes for the PR-amortization sweep (bytes per operand).
pub const SWEEP_SIZES: [usize; 5] = [1024, 4096, 16384, 65536, 262144];

/// Double-precision reference dot product (ground truth for tolerances).
pub fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vectors_are_deterministic() {
        assert_eq!(vector(64, 7, 0.0, 1.0), vector(64, 7, 0.0, 1.0));
        assert_ne!(vector(64, 7, 0.0, 1.0), vector(64, 8, 0.0, 1.0));
    }

    #[test]
    fn ranges_respected() {
        for v in vector(10_000, 1, -0.5, 0.5) {
            assert!((-0.5..0.5).contains(&v));
        }
    }

    #[test]
    fn rough_uniformity() {
        let v = vector(100_000, 3, 0.0, 1.0);
        let mean = v.iter().sum::<f32>() / v.len() as f32;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
        let below_half = v.iter().filter(|&&x| x < 0.5).count();
        assert!((below_half as f64 / v.len() as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn zero_seed_is_fine() {
        let mut r = Rng::new(0);
        let a = r.next_u64();
        let b = r.next_u64();
        assert_ne!(a, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn paper_workload_is_16kb_per_operand() {
        let (a, b) = paper_16kb(0);
        assert_eq!(a.len() * 4, 16 * 1024);
        assert_eq!(b.len() * 4, 16 * 1024);
    }
}
