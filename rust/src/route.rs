//! Mesh stream routing: shortest N-E-S-W paths between placed operators.
//!
//! The JIT must connect producer tiles to consumer tiles. Adjacent tiles
//! connect directly (the dynamic overlay's goal — zero pass-through);
//! non-adjacent tiles route through intermediate tiles configured as
//! **bypass** lanes. The router finds a shortest path that avoids tiles
//! hosting *other* operators' consume ports, then emits the interconnect
//! instructions that realize it.

use std::collections::{HashMap, VecDeque};

use crate::error::{Error, Result};
use crate::isa::{Dir, Instr, Opcode};
use crate::overlay::Mesh;

/// A realized route: the producer's exit direction plus the bypass chain.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Route {
    pub from: usize,
    pub to: usize,
    /// Tiles strictly between `from` and `to`, in traversal order.
    pub via: Vec<usize>,
    /// Direction the stream leaves `from` on.
    pub out_dir: Dir,
    /// Direction the stream arrives at `to` on (the consumer's in-port).
    pub in_dir: Dir,
}

impl Route {
    /// Pass-through tile count — Fig. 2's penalty metric.
    pub fn hops(&self) -> usize {
        self.via.len()
    }

    /// Interconnect instructions realizing this route: one bypass per
    /// intermediate tile, `set.out` at the producer, `set.in` at the
    /// consumer. (`pr.connect` is the placer's job.)
    pub fn interconnect_instrs(&self, mesh: &Mesh) -> Result<Vec<Instr>> {
        let mut out = Vec::with_capacity(2 + self.via.len());
        out.push(Instr::op(set_out_op(self.out_dir), self.from as u8));

        let mut prev = self.from;
        let mut dir = self.out_dir;
        for &mid in &self.via {
            let arrive = mesh
                .direction(prev, mid)
                .ok_or(Error::Routing { from: prev, to: mid })?
                .opposite();
            // leave toward the next tile in the chain
            let next = self
                .via
                .iter()
                .copied()
                .skip_while(|&t| t != mid)
                .nth(1)
                .unwrap_or(self.to);
            let leave = mesh
                .direction(mid, next)
                .ok_or(Error::Routing { from: mid, to: next })?;
            let op = Opcode::bypass_for(arrive, leave).ok_or(Error::Routing {
                from: mid,
                to: next,
            })?;
            out.push(Instr::op(op, mid as u8));
            prev = mid;
            dir = leave;
        }
        let _ = dir;
        out.push(Instr::op(set_in_op(self.in_dir), self.to as u8));
        Ok(out)
    }
}

fn set_out_op(d: Dir) -> Opcode {
    match d {
        Dir::N => Opcode::SetOutN,
        Dir::E => Opcode::SetOutE,
        Dir::S => Opcode::SetOutS,
        Dir::W => Opcode::SetOutW,
    }
}

fn set_in_op(d: Dir) -> Opcode {
    match d {
        Dir::N => Opcode::SetInN,
        Dir::E => Opcode::SetInE,
        Dir::S => Opcode::SetInS,
        Dir::W => Opcode::SetInW,
    }
}

/// BFS shortest path from `from` to `to` over the mesh, treating every tile
/// in `blocked` as unusable for pass-through (they host consuming
/// operators). `from`/`to` themselves are always usable.
pub fn shortest_route(
    mesh: &Mesh,
    from: usize,
    to: usize,
    blocked: &[bool],
) -> Result<Route> {
    if from == to {
        return Err(Error::Routing { from, to });
    }
    let mut prev: HashMap<usize, usize> = HashMap::new();
    let mut q = VecDeque::from([from]);
    while let Some(cur) = q.pop_front() {
        if cur == to {
            break;
        }
        for d in Dir::ALL {
            if let Some(n) = mesh.neighbor(cur, d) {
                if prev.contains_key(&n) || n == from {
                    continue;
                }
                if n != to && blocked.get(n).copied().unwrap_or(false) {
                    continue;
                }
                prev.insert(n, cur);
                q.push_back(n);
            }
        }
    }
    if !prev.contains_key(&to) {
        return Err(Error::Routing { from, to });
    }
    // reconstruct
    let mut path = vec![to];
    let mut cur = to;
    while cur != from {
        cur = prev[&cur];
        path.push(cur);
    }
    path.reverse();

    let out_dir = mesh.direction(path[0], path[1]).unwrap();
    let in_dir = mesh
        .direction(path[path.len() - 2], path[path.len() - 1])
        .unwrap()
        .opposite();
    Ok(Route {
        from,
        to,
        via: path[1..path.len() - 1].to_vec(),
        out_dir,
        in_dir,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mesh() -> Mesh {
        Mesh::new(3, 3)
    }

    #[test]
    fn adjacent_route_has_no_hops() {
        let r = shortest_route(&mesh(), 0, 1, &[false; 9]).unwrap();
        assert_eq!(r.hops(), 0);
        assert_eq!(r.out_dir, Dir::E);
        assert_eq!(r.in_dir, Dir::W);
    }

    #[test]
    fn corner_to_corner_is_manhattan() {
        let r = shortest_route(&mesh(), 0, 8, &[false; 9]).unwrap();
        assert_eq!(r.hops(), 3); // manhattan 4 → 3 intermediate tiles
    }

    #[test]
    fn blocked_tiles_are_avoided() {
        let mut blocked = [false; 9];
        blocked[1] = true; // block the straight path 0→1→2
        blocked[4] = true;
        let r = shortest_route(&mesh(), 0, 2, &blocked).unwrap();
        assert!(!r.via.contains(&1));
        assert!(!r.via.contains(&4));
        // forced the long way round: 0→3→6→7→8→5→2 ⇒ 5 pass-through tiles
        assert_eq!(r.hops(), 5);
    }

    #[test]
    fn unroutable_when_fully_blocked() {
        let mut blocked = [true; 9];
        blocked[0] = false;
        blocked[8] = false;
        assert!(shortest_route(&mesh(), 0, 8, &blocked).is_err());
    }

    #[test]
    fn self_route_rejected() {
        assert!(shortest_route(&mesh(), 4, 4, &[false; 9]).is_err());
    }

    #[test]
    fn route_instrs_adjacent() {
        let m = mesh();
        let r = shortest_route(&m, 0, 1, &[false; 9]).unwrap();
        let instrs = r.interconnect_instrs(&m).unwrap();
        assert_eq!(instrs.len(), 2);
        assert_eq!(instrs[0].op, Opcode::SetOutE);
        assert_eq!(instrs[0].tile, 0);
        assert_eq!(instrs[1].op, Opcode::SetInW);
        assert_eq!(instrs[1].tile, 1);
    }

    #[test]
    fn route_instrs_with_passthrough() {
        let m = mesh();
        let r = shortest_route(&m, 0, 2, &[false; 9]).unwrap();
        assert_eq!(r.via, vec![1]);
        let instrs = r.interconnect_instrs(&m).unwrap();
        assert_eq!(instrs.len(), 3);
        assert_eq!(instrs[1].op, Opcode::BypassWE);
        assert_eq!(instrs[1].tile, 1);
    }

    #[test]
    fn bfs_path_is_shortest_and_legal() {
        // property-style sweep over all pairs on a 4×4 mesh
        let m = Mesh::new(4, 4);
        let blocked = vec![false; 16];
        for from in 0..16 {
            for to in 0..16 {
                if from == to {
                    continue;
                }
                let r = shortest_route(&m, from, to, &blocked).unwrap();
                assert_eq!(r.hops() + 1, m.manhattan(from, to), "{from}->{to}");
                // every consecutive pair adjacent
                let mut chain = vec![from];
                chain.extend(&r.via);
                chain.push(to);
                for w in chain.windows(2) {
                    assert_eq!(m.manhattan(w[0], w[1]), 1);
                }
            }
        }
    }
}
