//! Analytic timing models for the paper's five evaluation targets.
//!
//! Fig. 3 plots *total execution time = data transfer + execution* for
//! VMUL&Reduce over 16 KB on: the static overlay under three scheduling
//! scenarios, the dynamic overlay, and a fully-custom HLS module, with a
//! 660 MHz ARM software run as the software reference. These models price
//! each target from first principles (clocks, bandwidths, pipeline fills,
//! store-and-forward penalties) using the parameters in [`crate::config`].
//!
//! The controller interpreter produces *measured* cycle counts for the
//! dynamic overlay; these analytic models must agree with it (cross-checked
//! in tests) and extend the pricing to targets the interpreter does not
//! execute (ARM, HLS, static store-and-forward).

pub mod arm;
pub mod hls;
pub mod overlay;
pub mod transfer;

/// Seconds, decomposed the way the paper reports them.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TimingBreakdown {
    /// DDR ↔ fabric data movement.
    pub transfer_s: f64,
    /// Pipeline fill (stage latencies + hop fills).
    pub fill_s: f64,
    /// Steady-state streaming.
    pub stream_s: f64,
    /// Store-and-forward re-staging at pass-through tiles.
    pub hop_s: f64,
    /// Controller sequencing overhead.
    pub control_s: f64,
}

impl TimingBreakdown {
    /// Total "execution time" in the paper's sense (transfer + execution).
    pub fn total(&self) -> f64 {
        self.transfer_s + self.fill_s + self.stream_s + self.hop_s + self.control_s
    }

    /// Total in milliseconds (the Fig. 3 axis).
    pub fn total_ms(&self) -> f64 {
        self.total() * 1e3
    }
}

/// An evaluation target of Fig. 3.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Target {
    /// The paper's contribution: contiguous, pipelined, JIT-assembled.
    DynamicOverlay,
    /// The original static overlay under a Fig. 2 scenario.
    StaticOverlay(crate::place::StaticScenario),
    /// Fully-custom Vivado-HLS-style module.
    HlsCustom,
    /// 660 MHz ARM (Zedboard) software.
    ArmSoftware,
}

impl Target {
    /// The series Fig. 3 plots (ARM is the software reference line).
    pub const ALL: [Target; 6] = [
        Target::ArmSoftware,
        Target::StaticOverlay(crate::place::StaticScenario::S3),
        Target::StaticOverlay(crate::place::StaticScenario::S2),
        Target::StaticOverlay(crate::place::StaticScenario::S1),
        Target::DynamicOverlay,
        Target::HlsCustom,
    ];

    pub fn name(&self) -> String {
        match self {
            Target::DynamicOverlay => "dynamic-overlay".into(),
            Target::StaticOverlay(s) => s.name().into(),
            Target::HlsCustom => "hls-custom".into(),
            Target::ArmSoftware => "arm-660mhz".into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_total_sums_components() {
        let b = TimingBreakdown {
            transfer_s: 1.0,
            fill_s: 2.0,
            stream_s: 3.0,
            hop_s: 4.0,
            control_s: 5.0,
        };
        assert_eq!(b.total(), 15.0);
        assert_eq!(b.total_ms(), 15_000.0);
    }

    #[test]
    fn six_series_cover_paper_figure() {
        assert_eq!(Target::ALL.len(), 6);
        let names: Vec<String> = Target::ALL.iter().map(|t| t.name()).collect();
        assert!(names.contains(&"dynamic-overlay".to_string()));
        assert!(names.contains(&"static-s3".to_string()));
        assert!(names.contains(&"hls-custom".to_string()));
        assert!(names.contains(&"arm-660mhz".to_string()));
    }
}
