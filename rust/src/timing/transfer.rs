//! DMA / data-movement pricing shared by all fabric targets.

use crate::config::ClockConfig;

/// Seconds to move `words` f32 words between DDR and the fabric.
pub fn dma_seconds(clocks: &ClockConfig, words: usize) -> f64 {
    (words * 4) as f64 / clocks.dma_bytes_per_sec
}

/// Total transfer for the paper's workload shape: `inputs` vectors of `n`
/// words in, one scalar out.
pub fn pattern_transfer_seconds(clocks: &ClockConfig, inputs: usize, n: usize) -> f64 {
    dma_seconds(clocks, inputs * n) + dma_seconds(clocks, 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClockConfig;

    #[test]
    fn dma_scales_linearly() {
        let c = ClockConfig::default();
        let one = dma_seconds(&c, 1024);
        let two = dma_seconds(&c, 2048);
        assert!((two / one - 2.0).abs() < 1e-12);
    }

    #[test]
    fn paper_16kb_transfer_order() {
        // 2 × 4096 words at 400 MB/s ≈ 82 µs
        let c = ClockConfig::default();
        let s = pattern_transfer_seconds(&c, 2, 4096);
        assert!(s > 70e-6 && s < 95e-6, "got {s}");
    }
}
